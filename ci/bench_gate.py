#!/usr/bin/env python3
"""Bench-regression gate: compare quick-mode bench reports against the
committed BENCH_*.json headline ratios.

CI runs the bench targets with `--quick` (reduced traces), which write
reports under target/bench-reports/. This script checks every headline
ratio that exists in BOTH the committed baseline and the quick report,
failing the job when any drifts beyond the tolerance (quick-vs-full ratio
drift is ~5-7% on these workloads; 15% flags real scheduler/router/cost
regressions without flaking). Metrics absent from the quick report — e.g.
the DP4 rows `serve_cluster` only runs in full mode — are skipped. A few
headline claims (FLOORS) are additionally pinned as absolute bounds on the
committed baselines themselves, where the quick trace is too coarse to
gate them relatively.

Usage:
    python3 ci/bench_gate.py             # gate the reports
    python3 ci/bench_gate.py --selftest  # first prove the gate fails on a
                                         # perturbed ratio, then gate

Exit code 0 = all gated ratios in tolerance, 1 = regression (or missing
report/baseline).
"""

import copy
import json
import os
import sys

TOLERANCE = 0.15

# (committed baseline, quick report, headline ratio paths)
GATES = [
    (
        "BENCH_serve.json",
        "target/bench-reports/serve_mixed.json",
        [
            "speedup.decode_throughput",
            "speedup.ttft_p95_ratio",
        ],
    ),
    (
        "BENCH_cluster.json",
        "target/bench-reports/serve_cluster.json",
        [
            f"results.dp{dp}.affinity_vs_sq.{metric}"
            for dp in (1, 2, 4)
            for metric in ("peak_pages_ratio", "ttft_p95_ratio", "throughput_ratio")
        ],
    ),
    (
        "BENCH_disagg.json",
        "target/bench-reports/serve_disagg.json",
        [
            f"results.n{n}.disagg_vs_colocated.{metric}"
            for n in (2, 4)
            for metric in (
                "ttft_p95_ratio",
                "itl_p95_ratio",
                "throughput_ratio",
                "wire_bytes_ratio",
            )
        ],
    ),
    (
        "BENCH_straggler.json",
        "target/bench-reports/serve_straggler.json",
        [
            f"results.{policy}.straggler_vs_uniform.{metric}"
            for policy in ("shortest_queue", "prefix_affinity")
            for metric in ("throughput_ratio", "ttft_p95_ratio")
        ]
        + [
            "affinity_vs_sq_straggler.throughput_ratio",
            "affinity_vs_sq_straggler.ttft_p95_ratio",
            "affinity_vs_sq_straggler.peak_pages_ratio",
        ],
    ),
    (
        "BENCH_elastic.json",
        "target/bench-reports/serve_elastic.json",
        [
            "failure.recovered_frac",
            "failure.recover_vs_drop.completed_ratio",
            "failure.recover_vs_drop.throughput_ratio",
            "autoscale.peak_active_ranks",
            "autoscale.mean_active_ranks",
        ],
    ),
    (
        "BENCH_spec.json",
        "target/bench-reports/serve_spec.json",
        [
            f"frontier.accept{a}.accepted_tokens_per_step" for a in (50, 70, 90)
        ]
        + [
            f"frontier.accept70.vs_baseline.{metric}"
            for metric in ("throughput_ratio", "itl_p50_ratio", "itl_p95_ratio")
        ],
    ),
    (
        # Tiered-cache bench: the relative gate covers the quick-stable
        # ratios. The concurrency headline is a small-integer peak_running
        # ratio that legitimately differs on the 12-request quick trace, so
        # it is pinned as an absolute FLOOR on the committed baseline below
        # instead of gated relatively here.
        "BENCH_tiered.json",
        "target/bench-reports/serve_tiered.json",
        [
            "tiered_async.vs_sync.concurrency_ratio",
            "tiered_async.vs_sync.throughput_ratio",
            "tiered_async_comp.vs_sync.throughput_ratio",
            "tiered_async_comp.vs_sync.itl_p95_ratio",
        ],
    ),
    (
        "BENCH_kernels.json",
        "target/bench-reports/kernel_frontier.json",
        [
            "results.ctx4096.amla_vs_snapmla.speedup",
            "results.ctx4096.pcast_vs_snapmla.speedup",
            "results.ctx4096.snapmla_vs_flashmla.speedup",
            "results.ctx4096.snapmla.rel_l2",
        ],
    ),
    (
        # Simulator-throughput bench: the quick report carries the recorded
        # events/sec section forward verbatim (wall-clock is not
        # bit-reproducible), so gating it here pins the COMMITTED record —
        # a refreshed BENCH_sim.json whose indexed arm lost its speedup
        # fails the gate instead of landing silently. The determinism rows
        # are regenerated every quick run and must hold exactly (drift 0%).
        "BENCH_sim.json",
        "target/bench-reports/perf_sim.json",
        ["measured.dp32.indexed_events_per_s"]
        + [f"measured.dp{dp}.speedup" for dp in (8, 32, 128)]
        + [
            f"determinism.dp{dp}.{metric}"
            for dp in (8, 32, 128)
            for metric in ("events", "tok_per_s", "peak_pages")
        ],
    ),
]


# Absolute floors on COMMITTED baselines: headline claims the paper repro
# stands on, enforced on the committed record itself (not the quick report)
# so a refreshed baseline that lost its headline fails here instead of
# landing silently. The tiered concurrency headline lives here because its
# quick-mode value is a small-integer peak_running ratio too coarse for the
# relative gate above.
FLOORS = [
    ("BENCH_tiered.json", "tiered_async_comp.vs_sync.concurrency_ratio", 1.5),
    ("BENCH_tiered.json", "tiered_async.vs_sync.throughput_ratio", 1.0),
]


def lookup(obj, dotted):
    for key in dotted.split("."):
        if not isinstance(obj, dict) or key not in obj:
            return None
        obj = obj[key]
    return obj


def check(baseline, report, paths, label):
    """Returns a list of failure strings (empty = pass)."""
    failures = []
    gated = 0
    for path in paths:
        want = lookup(baseline, path)
        got = lookup(report, path)
        if want is None:
            failures.append(f"{label}: baseline is missing {path}")
            continue
        if got is None:
            print(f"  skip {label}:{path} (absent in quick mode)")
            continue
        gated += 1
        drift = abs(got - want) / abs(want)
        status = "ok" if drift <= TOLERANCE else "REGRESSION"
        print(
            f"  {status:>10} {label}:{path} baseline {want:.4f} "
            f"quick {got:.4f} drift {drift * 100:.1f}%"
        )
        if drift > TOLERANCE:
            failures.append(
                f"{label}: {path} drifted {drift * 100:.1f}% "
                f"(baseline {want:.4f}, quick {got:.4f}, tolerance "
                f"{TOLERANCE * 100:.0f}%)"
            )
    if gated == 0:
        failures.append(f"{label}: no ratios were gated (all absent?)")
    return failures


def check_floor(baseline, path, floor, label):
    """Returns a list of failure strings (empty = pass)."""
    got = lookup(baseline, path)
    if got is None:
        return [f"{label}: floor path {path} is missing from the baseline"]
    status = "ok" if got >= floor else "REGRESSION"
    print(
        f"  {status:>10} {label}:{path} committed {got:.4f} "
        f"floor >= {floor:.2f}"
    )
    if got < floor:
        return [
            f"{label}: {path} = {got:.4f} fell below the committed "
            f"floor {floor:.2f}"
        ]
    return []


def load(path):
    """Read a report/baseline; exits with a clear one-line error (no
    traceback) when the file is missing or malformed."""
    try:
        with open(path) as f:
            return json.load(f)
    except OSError as e:
        print(f"bench-gate error: cannot read {path}: {e.strerror or e}")
        sys.exit(1)
    except json.JSONDecodeError as e:
        print(f"bench-gate error: {path} is not valid JSON ({e})")
        sys.exit(1)


def run_gate():
    failures = []
    for baseline_path, report_path, paths in GATES:
        if not os.path.exists(baseline_path):
            failures.append(f"missing committed baseline {baseline_path}")
            continue
        if not os.path.exists(report_path):
            failures.append(
                f"missing quick report {report_path} (did the bench run?)"
            )
            continue
        label = os.path.basename(report_path).removesuffix(".json")
        print(f"gating {report_path} against {baseline_path}:")
        failures.extend(check(load(baseline_path), load(report_path), paths, label))
    print("pinning committed headline floors:")
    for baseline_path, path, floor in FLOORS:
        if not os.path.exists(baseline_path):
            failures.append(f"missing committed baseline {baseline_path}")
            continue
        label = os.path.basename(baseline_path).removesuffix(".json")
        failures.extend(check_floor(load(baseline_path), path, floor, label))
    return failures


def selftest():
    """The gate must demonstrably fail when a headline ratio is perturbed
    beyond tolerance — run EVERY gate family against a perturbed copy of
    its own baseline, in BOTH directions (a throughput can regress by
    falling: −2x-tolerance on BENCH_sim's events/sec must trip exactly like
    +2x-tolerance on a ratio), and require a reported regression."""
    for baseline_path, _, paths in GATES:
        if not os.path.exists(baseline_path):
            print(f"selftest FAILED: committed baseline {baseline_path} is missing")
            return 1
        baseline = load(baseline_path)
        path = paths[0]
        keys = path.split(".")
        label = f"selftest:{os.path.basename(baseline_path)}"
        for scale, sign in ((1.0 + 2 * TOLERANCE, "+"), (1.0 - 2 * TOLERANCE, "-")):
            perturbed = copy.deepcopy(baseline)
            node = perturbed
            for k in keys[:-1]:
                node = node[k]
            node[keys[-1]] *= scale
            print(
                f"selftest: perturbing {baseline_path}:{path} by "
                f"{sign}{2 * TOLERANCE * 100:.0f}%…"
            )
            failures = check(baseline, perturbed, paths, label)
            if not any("drifted" in f for f in failures):
                print(f"selftest FAILED: the gate did not flag a {sign}2x-tolerance "
                      f"drift in {baseline_path}")
                return 1
        # and an untouched copy must pass clean
        if any("drifted" in f for f in check(baseline, baseline, paths, label)):
            print(f"selftest FAILED: the gate flagged an identical {baseline_path}")
            return 1
    # the floor check must flag a baseline nudged just below its floor and
    # pass the committed record untouched
    for baseline_path, path, floor in FLOORS:
        if not os.path.exists(baseline_path):
            print(f"selftest FAILED: committed baseline {baseline_path} is missing")
            return 1
        baseline = load(baseline_path)
        label = f"selftest:{os.path.basename(baseline_path)}"
        sunk = copy.deepcopy(baseline)
        node = sunk
        keys = path.split(".")
        for k in keys[:-1]:
            node = node[k]
        node[keys[-1]] = floor * 0.99
        print(f"selftest: sinking {baseline_path}:{path} below its floor…")
        if not check_floor(sunk, path, floor, label):
            print(f"selftest FAILED: the floor did not flag {path} below "
                  f"{floor:.2f} in {baseline_path}")
            return 1
        if check_floor(baseline, path, floor, label):
            print(f"selftest FAILED: the floor flagged the committed "
                  f"{baseline_path} itself")
            return 1
    print("selftest ok: every gate fails on perturbation (both directions), "
          "every floor fails below its bound, passes on identity")
    return 0


def main():
    os.chdir(os.path.join(os.path.dirname(os.path.abspath(__file__)), ".."))
    if "--selftest" in sys.argv:
        rc = selftest()
        if rc != 0:
            return rc
        print()
    failures = run_gate()
    if failures:
        print("\nbench-regression gate FAILED:")
        for f in failures:
            print(f"  - {f}")
        return 1
    print("\nbench-regression gate passed")
    return 0


if __name__ == "__main__":
    sys.exit(main())
