//! Tiny CLI argument parser (clap is unavailable offline).
//!
//! Supports `--flag`, `--key value`, `--key=value` and positional arguments.

use std::collections::BTreeMap;

#[derive(Debug, Default)]
pub struct Args {
    pub positional: Vec<String>,
    flags: BTreeMap<String, String>,
    present: Vec<String>,
}

impl Args {
    pub fn parse_from<I: IntoIterator<Item = String>>(it: I) -> Args {
        Args::parse_from_with_flags(it, &[])
    }

    /// `bool_flags` names flags that never take a value, resolving the
    /// `--verbose file.json` ambiguity (file.json stays positional).
    pub fn parse_from_with_flags<I: IntoIterator<Item = String>>(
        it: I,
        bool_flags: &[&str],
    ) -> Args {
        let mut out = Args::default();
        let mut it = it.into_iter().peekable();
        while let Some(arg) = it.next() {
            if let Some(stripped) = arg.strip_prefix("--") {
                if let Some((k, v)) = stripped.split_once('=') {
                    out.flags.insert(k.to_string(), v.to_string());
                    out.present.push(k.to_string());
                } else if !bool_flags.contains(&stripped)
                    && it.peek().map(|n| !n.starts_with("--")).unwrap_or(false)
                {
                    let v = it.next().unwrap();
                    out.flags.insert(stripped.to_string(), v);
                    out.present.push(stripped.to_string());
                } else {
                    out.flags.insert(stripped.to_string(), "true".to_string());
                    out.present.push(stripped.to_string());
                }
            } else {
                out.positional.push(arg);
            }
        }
        out
    }

    /// Parse from the process environment (skips argv[0]).
    pub fn parse() -> Args {
        Args::parse_from(std::env::args().skip(1))
    }

    /// Parse from the environment with known boolean flags.
    pub fn parse_with_flags(bool_flags: &[&str]) -> Args {
        Args::parse_from_with_flags(std::env::args().skip(1), bool_flags)
    }

    pub fn has(&self, key: &str) -> bool {
        self.present.iter().any(|k| k == key)
    }

    pub fn get(&self, key: &str) -> Option<&str> {
        self.flags.get(key).map(|s| s.as_str())
    }

    pub fn get_or<'a>(&'a self, key: &str, default: &'a str) -> &'a str {
        self.get(key).unwrap_or(default)
    }

    pub fn usize_or(&self, key: &str, default: usize) -> usize {
        self.get(key)
            .map(|v| v.parse().unwrap_or_else(|_| panic!("--{key} expects an integer")))
            .unwrap_or(default)
    }

    pub fn f64_or(&self, key: &str, default: f64) -> f64 {
        self.get(key)
            .map(|v| v.parse().unwrap_or_else(|_| panic!("--{key} expects a number")))
            .unwrap_or(default)
    }

    pub fn u64_or(&self, key: &str, default: u64) -> u64 {
        self.get(key)
            .map(|v| v.parse().unwrap_or_else(|_| panic!("--{key} expects an integer")))
            .unwrap_or(default)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse(args: &[&str]) -> Args {
        Args::parse_from_with_flags(args.iter().map(|s| s.to_string()), &["verbose"])
    }

    #[test]
    fn mixed_forms() {
        let a = parse(&["serve", "--batch", "8", "--mode=fp8", "--verbose", "trace.json"]);
        assert_eq!(a.positional, vec!["serve", "trace.json"]);
        assert_eq!(a.usize_or("batch", 1), 8);
        assert_eq!(a.get("mode"), Some("fp8"));
        assert!(a.has("verbose"));
        assert!(!a.has("quiet"));
    }

    #[test]
    fn defaults() {
        let a = parse(&[]);
        assert_eq!(a.usize_or("n", 3), 3);
        assert_eq!(a.f64_or("x", 0.5), 0.5);
        assert_eq!(a.get_or("mode", "bf16"), "bf16");
    }

    #[test]
    fn flag_before_flag_is_boolean() {
        let a = parse(&["--a", "--b", "7"]);
        assert!(a.has("a"));
        assert_eq!(a.get("a"), Some("true"));
        assert_eq!(a.usize_or("b", 0), 7);
    }
}
