//! Summary statistics for benchmark reporting (std-only substrate).

use std::cell::RefCell;

/// Online accumulator + percentile support over a retained sample vector.
///
/// The canonical recorder type: every latency/throughput recorder in the
/// serving stack (bench harness, `simulate`, `coordinator::metrics`) backs
/// onto this — no bench or scenario keeps a private stats implementation.
#[derive(Clone, Debug, Default)]
pub struct Stats {
    xs: Vec<f64>,
    /// Lazily built sorted view of `xs`, valid iff the lengths match
    /// (`push` clears it; `xs` only grows, so a stale same-length cache
    /// cannot exist). Repeated percentile queries between pushes — the
    /// autoscaler's rolling TTFT p95 every `eval_interval_s`, the
    /// multi-percentile report rows — sort once instead of per call.
    sorted: RefCell<Vec<f64>>,
}

impl Stats {
    pub fn new() -> Self {
        Stats::default()
    }

    // An inherent `from` (not the trait): callers read `Stats::from(&xs)`
    // at many bench sites; the trait form would force type annotations.
    #[allow(clippy::should_implement_trait)]
    pub fn from(xs: &[f64]) -> Self {
        Stats { xs: xs.to_vec(), sorted: RefCell::new(Vec::new()) }
    }

    pub fn push(&mut self, x: f64) {
        self.xs.push(x);
        self.sorted.get_mut().clear();
    }

    pub fn len(&self) -> usize {
        self.xs.len()
    }

    pub fn is_empty(&self) -> bool {
        self.xs.is_empty()
    }

    pub fn sum(&self) -> f64 {
        self.xs.iter().sum()
    }

    pub fn mean(&self) -> f64 {
        if self.xs.is_empty() {
            return f64::NAN;
        }
        self.sum() / self.xs.len() as f64
    }

    pub fn var(&self) -> f64 {
        if self.xs.len() < 2 {
            return 0.0;
        }
        let m = self.mean();
        self.xs.iter().map(|x| (x - m) * (x - m)).sum::<f64>() / (self.xs.len() - 1) as f64
    }

    pub fn std(&self) -> f64 {
        self.var().sqrt()
    }

    pub fn min(&self) -> f64 {
        self.xs.iter().cloned().fold(f64::INFINITY, f64::min)
    }

    pub fn max(&self) -> f64 {
        self.xs.iter().cloned().fold(f64::NEG_INFINITY, f64::max)
    }

    /// Linear-interpolated percentile, p in [0, 100].
    pub fn percentile(&self, p: f64) -> f64 {
        if self.xs.is_empty() {
            return f64::NAN;
        }
        let mut sorted = self.sorted.borrow_mut();
        if sorted.len() != self.xs.len() {
            sorted.clear();
            sorted.extend_from_slice(&self.xs);
            sorted.sort_by(|a, b| a.partial_cmp(b).unwrap());
        }
        let rank = (p / 100.0) * (sorted.len() - 1) as f64;
        let lo = rank.floor() as usize;
        let hi = rank.ceil() as usize;
        if lo == hi {
            sorted[lo]
        } else {
            let frac = rank - lo as f64;
            sorted[lo] * (1.0 - frac) + sorted[hi] * frac
        }
    }

    pub fn median(&self) -> f64 {
        self.percentile(50.0)
    }
}

/// Mean squared error between two equal-length slices.
pub fn mse(a: &[f32], b: &[f32]) -> f64 {
    assert_eq!(a.len(), b.len());
    if a.is_empty() {
        return 0.0;
    }
    a.iter()
        .zip(b)
        .map(|(&x, &y)| ((x - y) as f64).powi(2))
        .sum::<f64>()
        / a.len() as f64
}

/// Relative L2 error ||a-b|| / ||b||.
pub fn rel_l2(a: &[f32], b: &[f32]) -> f64 {
    assert_eq!(a.len(), b.len());
    let num: f64 = a.iter().zip(b).map(|(&x, &y)| ((x - y) as f64).powi(2)).sum();
    let den: f64 = b.iter().map(|&y| (y as f64).powi(2)).sum();
    (num / den.max(1e-30)).sqrt()
}

/// Cosine similarity of two vectors.
pub fn cosine(a: &[f32], b: &[f32]) -> f64 {
    assert_eq!(a.len(), b.len());
    let dot: f64 = a.iter().zip(b).map(|(&x, &y)| x as f64 * y as f64).sum();
    let na: f64 = a.iter().map(|&x| (x as f64).powi(2)).sum::<f64>().sqrt();
    let nb: f64 = b.iter().map(|&y| (y as f64).powi(2)).sum::<f64>().sqrt();
    dot / (na * nb).max(1e-30)
}

/// Pearson correlation.
pub fn pearson(a: &[f32], b: &[f32]) -> f64 {
    assert_eq!(a.len(), b.len());
    let n = a.len() as f64;
    let ma = a.iter().map(|&x| x as f64).sum::<f64>() / n;
    let mb = b.iter().map(|&x| x as f64).sum::<f64>() / n;
    let mut cov = 0.0;
    let mut va = 0.0;
    let mut vb = 0.0;
    for (&x, &y) in a.iter().zip(b) {
        let dx = x as f64 - ma;
        let dy = y as f64 - mb;
        cov += dx * dy;
        va += dx * dx;
        vb += dy * dy;
    }
    cov / (va.sqrt() * vb.sqrt()).max(1e-30)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn stats_basics() {
        let s = Stats::from(&[1.0, 2.0, 3.0, 4.0]);
        assert_eq!(s.mean(), 2.5);
        assert_eq!(s.min(), 1.0);
        assert_eq!(s.max(), 4.0);
        assert!((s.std() - 1.2909944).abs() < 1e-6);
    }

    #[test]
    fn percentiles() {
        let s = Stats::from(&[10.0, 20.0, 30.0, 40.0, 50.0]);
        assert_eq!(s.percentile(0.0), 10.0);
        assert_eq!(s.percentile(100.0), 50.0);
        assert_eq!(s.median(), 30.0);
        assert_eq!(s.percentile(25.0), 20.0);
    }

    #[test]
    fn percentile_cache_survives_interleaved_pushes() {
        // Same values as `percentiles`, but pushed out of order with
        // percentile queries interleaved: every query after a push must
        // see the refreshed sort, and repeated queries must not change.
        let mut s = Stats::new();
        s.push(30.0);
        s.push(10.0);
        s.push(50.0);
        assert_eq!(s.median(), 30.0); // builds the cached sorted view
        s.push(20.0); // must invalidate it
        s.push(40.0);
        assert_eq!(s.percentile(0.0), 10.0);
        assert_eq!(s.percentile(25.0), 20.0);
        assert_eq!(s.median(), 30.0);
        assert_eq!(s.percentile(100.0), 50.0);
        assert_eq!(s.percentile(95.0), s.percentile(95.0));
        // the retained-sample accessors still see insertion order
        assert_eq!(s.len(), 5);
        assert_eq!(s.min(), 10.0);
        assert_eq!(s.max(), 50.0);
    }

    #[test]
    fn error_metrics() {
        let a = [1.0f32, 2.0, 3.0];
        let b = [1.0f32, 2.0, 3.0];
        assert_eq!(mse(&a, &b), 0.0);
        assert_eq!(rel_l2(&a, &b), 0.0);
        assert!((cosine(&a, &b) - 1.0).abs() < 1e-12);
        assert!((pearson(&a, &b) - 1.0).abs() < 1e-12);

        let c = [2.0f32, 4.0, 6.0];
        assert!((cosine(&a, &c) - 1.0).abs() < 1e-12); // colinear
        assert!(rel_l2(&c, &a) > 0.9);
    }

    #[test]
    fn pearson_sign() {
        let a = [1.0f32, 2.0, 3.0, 4.0];
        let b = [4.0f32, 3.0, 2.0, 1.0];
        assert!((pearson(&a, &b) + 1.0).abs() < 1e-12);
    }
}
