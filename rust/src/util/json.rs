//! Minimal JSON parser/serializer (std-only substrate; serde is unavailable
//! in the offline crate set). Covers the full JSON grammar we produce and
//! consume: artifacts/manifest.json, bench reports, config files.

use crate::anyhow;
use std::collections::BTreeMap;
use std::fmt;

#[derive(Clone, Debug, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

#[derive(Debug)]
pub struct JsonError {
    pub msg: String,
    pub offset: usize,
}

impl fmt::Display for JsonError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "json error at byte {}: {}", self.offset, self.msg)
    }
}

impl std::error::Error for JsonError {}

impl Json {
    pub fn parse(text: &str) -> Result<Json, JsonError> {
        let mut p = Parser { b: text.as_bytes(), i: 0 };
        p.ws();
        let v = p.value()?;
        p.ws();
        if p.i != p.b.len() {
            return Err(p.err("trailing characters"));
        }
        Ok(v)
    }

    pub fn from_file(path: &std::path::Path) -> anyhow::Result<Json> {
        let text = std::fs::read_to_string(path)?;
        Json::parse(&text).map_err(|e| anyhow::anyhow!("{path:?}: {e}"))
    }

    // -- typed accessors ----------------------------------------------------
    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(m) => m.get(key),
            _ => None,
        }
    }

    /// Path access: `j.at(&["model", "d_c"])`.
    pub fn at(&self, path: &[&str]) -> Option<&Json> {
        let mut cur = self;
        for k in path {
            cur = cur.get(k)?;
        }
        Some(cur)
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(x) => Some(*x),
            _ => None,
        }
    }

    pub fn as_usize(&self) -> Option<usize> {
        self.as_f64().map(|x| x as usize)
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }

    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(v) => Some(v),
            _ => None,
        }
    }

    pub fn as_obj(&self) -> Option<&BTreeMap<String, Json>> {
        match self {
            Json::Obj(m) => Some(m),
            _ => None,
        }
    }

    // -- builders ------------------------------------------------------------
    pub fn obj(pairs: Vec<(&str, Json)>) -> Json {
        Json::Obj(pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
    }

    pub fn arr<I: IntoIterator<Item = Json>>(items: I) -> Json {
        Json::Arr(items.into_iter().collect())
    }

    pub fn num(x: f64) -> Json {
        Json::Num(x)
    }

    pub fn str(s: &str) -> Json {
        Json::Str(s.to_string())
    }

    // -- serialization --------------------------------------------------------
    pub fn to_string_pretty(&self) -> String {
        let mut out = String::new();
        self.write(&mut out, 0, true);
        out
    }

    fn write(&self, out: &mut String, indent: usize, pretty: bool) {
        let pad = |out: &mut String, n: usize| {
            if pretty {
                out.push('\n');
                for _ in 0..n {
                    out.push(' ');
                }
            }
        };
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Json::Num(x) => {
                if x.fract() == 0.0 && x.abs() < 1e15 {
                    out.push_str(&format!("{}", *x as i64));
                } else {
                    out.push_str(&format!("{x}"));
                }
            }
            Json::Str(s) => write_escaped(out, s),
            Json::Arr(v) => {
                out.push('[');
                for (i, item) in v.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    pad(out, indent + 1);
                    item.write(out, indent + 1, pretty);
                }
                if !v.is_empty() {
                    pad(out, indent);
                }
                out.push(']');
            }
            Json::Obj(m) => {
                out.push('{');
                for (i, (k, v)) in m.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    pad(out, indent + 1);
                    write_escaped(out, k);
                    out.push(':');
                    if pretty {
                        out.push(' ');
                    }
                    v.write(out, indent + 1, pretty);
                }
                if !m.is_empty() {
                    pad(out, indent);
                }
                out.push('}');
            }
        }
    }
}

fn write_escaped(out: &mut String, s: &str) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => out.push_str(&format!("\\u{:04x}", c as u32)),
            c => out.push(c),
        }
    }
    out.push('"');
}

struct Parser<'a> {
    b: &'a [u8],
    i: usize,
}

impl<'a> Parser<'a> {
    fn err(&self, msg: &str) -> JsonError {
        JsonError { msg: msg.to_string(), offset: self.i }
    }

    fn ws(&mut self) {
        while self.i < self.b.len() && matches!(self.b[self.i], b' ' | b'\t' | b'\n' | b'\r') {
            self.i += 1;
        }
    }

    fn peek(&self) -> Option<u8> {
        self.b.get(self.i).copied()
    }

    fn eat(&mut self, c: u8) -> Result<(), JsonError> {
        if self.peek() == Some(c) {
            self.i += 1;
            Ok(())
        } else {
            Err(self.err(&format!("expected '{}'", c as char)))
        }
    }

    fn lit(&mut self, s: &str, v: Json) -> Result<Json, JsonError> {
        if self.b[self.i..].starts_with(s.as_bytes()) {
            self.i += s.len();
            Ok(v)
        } else {
            Err(self.err(&format!("expected '{s}'")))
        }
    }

    fn value(&mut self) -> Result<Json, JsonError> {
        match self.peek() {
            Some(b'n') => self.lit("null", Json::Null),
            Some(b't') => self.lit("true", Json::Bool(true)),
            Some(b'f') => self.lit("false", Json::Bool(false)),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b'[') => self.array(),
            Some(b'{') => self.object(),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            _ => Err(self.err("unexpected character")),
        }
    }

    fn array(&mut self) -> Result<Json, JsonError> {
        self.eat(b'[')?;
        let mut out = Vec::new();
        self.ws();
        if self.peek() == Some(b']') {
            self.i += 1;
            return Ok(Json::Arr(out));
        }
        loop {
            self.ws();
            out.push(self.value()?);
            self.ws();
            match self.peek() {
                Some(b',') => self.i += 1,
                Some(b']') => {
                    self.i += 1;
                    return Ok(Json::Arr(out));
                }
                _ => return Err(self.err("expected ',' or ']'")),
            }
        }
    }

    fn object(&mut self) -> Result<Json, JsonError> {
        self.eat(b'{')?;
        let mut out = BTreeMap::new();
        self.ws();
        if self.peek() == Some(b'}') {
            self.i += 1;
            return Ok(Json::Obj(out));
        }
        loop {
            self.ws();
            let key = self.string()?;
            self.ws();
            self.eat(b':')?;
            self.ws();
            out.insert(key, self.value()?);
            self.ws();
            match self.peek() {
                Some(b',') => self.i += 1,
                Some(b'}') => {
                    self.i += 1;
                    return Ok(Json::Obj(out));
                }
                _ => return Err(self.err("expected ',' or '}'")),
            }
        }
    }

    fn string(&mut self) -> Result<String, JsonError> {
        self.eat(b'"')?;
        let mut out = String::new();
        loop {
            match self.peek() {
                None => return Err(self.err("unterminated string")),
                Some(b'"') => {
                    self.i += 1;
                    return Ok(out);
                }
                Some(b'\\') => {
                    self.i += 1;
                    match self.peek() {
                        Some(b'"') => out.push('"'),
                        Some(b'\\') => out.push('\\'),
                        Some(b'/') => out.push('/'),
                        Some(b'n') => out.push('\n'),
                        Some(b't') => out.push('\t'),
                        Some(b'r') => out.push('\r'),
                        Some(b'b') => out.push('\u{8}'),
                        Some(b'f') => out.push('\u{c}'),
                        Some(b'u') => {
                            if self.i + 4 >= self.b.len() {
                                return Err(self.err("bad \\u escape"));
                            }
                            let hex =
                                std::str::from_utf8(&self.b[self.i + 1..self.i + 5])
                                    .map_err(|_| self.err("bad \\u escape"))?;
                            let cp = u32::from_str_radix(hex, 16)
                                .map_err(|_| self.err("bad \\u escape"))?;
                            // BMP only (sufficient for our ascii manifests)
                            out.push(char::from_u32(cp).unwrap_or('\u{fffd}'));
                            self.i += 4;
                        }
                        _ => return Err(self.err("bad escape")),
                    }
                    self.i += 1;
                }
                Some(_) => {
                    // copy a full utf-8 scalar
                    let start = self.i;
                    let text = std::str::from_utf8(&self.b[start..])
                        .map_err(|_| self.err("invalid utf-8"))?;
                    let c = text.chars().next().unwrap();
                    out.push(c);
                    self.i += c.len_utf8();
                }
            }
        }
    }

    fn number(&mut self) -> Result<Json, JsonError> {
        let start = self.i;
        if self.peek() == Some(b'-') {
            self.i += 1;
        }
        while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
            self.i += 1;
        }
        if self.peek() == Some(b'.') {
            self.i += 1;
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.i += 1;
            }
        }
        if matches!(self.peek(), Some(b'e') | Some(b'E')) {
            self.i += 1;
            if matches!(self.peek(), Some(b'+') | Some(b'-')) {
                self.i += 1;
            }
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.i += 1;
            }
        }
        let text = std::str::from_utf8(&self.b[start..self.i]).unwrap();
        text.parse::<f64>()
            .map(Json::Num)
            .map_err(|_| self.err("bad number"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_scalars() {
        assert_eq!(Json::parse("null").unwrap(), Json::Null);
        assert_eq!(Json::parse("true").unwrap(), Json::Bool(true));
        assert_eq!(Json::parse("-12.5e2").unwrap(), Json::Num(-1250.0));
        assert_eq!(Json::parse("\"a\\nb\"").unwrap(), Json::Str("a\nb".into()));
    }

    #[test]
    fn parses_nested() {
        let j = Json::parse(r#"{"a":[1,2,{"b":"x"}],"c":{}}"#).unwrap();
        assert_eq!(j.at(&["a"]).unwrap().as_arr().unwrap().len(), 3);
        assert_eq!(
            j.at(&["a"]).unwrap().as_arr().unwrap()[2].get("b").unwrap().as_str(),
            Some("x")
        );
    }

    #[test]
    fn rejects_garbage() {
        assert!(Json::parse("{").is_err());
        assert!(Json::parse("[1,]").is_err());
        assert!(Json::parse("12 34").is_err());
        assert!(Json::parse("\"open").is_err());
    }

    #[test]
    fn roundtrip() {
        let j = Json::obj(vec![
            ("name", Json::str("snap\"mla\n")),
            ("xs", Json::arr((0..4).map(|i| Json::num(i as f64 * 0.5)))),
            ("flag", Json::Bool(false)),
            ("none", Json::Null),
        ]);
        let text = j.to_string_pretty();
        assert_eq!(Json::parse(&text).unwrap(), j);
    }

    #[test]
    fn integers_serialize_without_fraction() {
        assert_eq!(Json::num(42.0).to_string_pretty(), "42");
        assert_eq!(Json::num(0.25).to_string_pretty(), "0.25");
    }

    #[test]
    fn unicode_escape() {
        assert_eq!(Json::parse("\"\\u0041\"").unwrap(), Json::Str("A".into()));
    }

    #[test]
    fn handles_utf8_passthrough() {
        let j = Json::parse("\"héllo → world\"").unwrap();
        assert_eq!(j.as_str(), Some("héllo → world"));
    }
}
