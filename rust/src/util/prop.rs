//! Mini property-testing harness (proptest is unavailable offline).
//!
//! `check(seed, cases, gen, prop)` runs `prop` on `cases` generated inputs;
//! on failure it performs a bounded greedy shrink via the generator's
//! `shrink` hook and panics with the minimal counterexample found.

use crate::util::rng::Rng;
use std::fmt::Debug;

/// A generator produces a random value and can propose smaller variants.
pub trait Gen {
    type Value: Clone + Debug;
    fn generate(&self, rng: &mut Rng) -> Self::Value;
    /// Candidate shrinks, largest reduction first. Default: no shrinking.
    fn shrink(&self, _v: &Self::Value) -> Vec<Self::Value> {
        Vec::new()
    }
}

/// Run a property over `cases` random inputs.
pub fn check<G: Gen>(
    seed: u64,
    cases: usize,
    gen: &G,
    prop: impl Fn(&G::Value) -> Result<(), String>,
) {
    let mut rng = Rng::new(seed);
    for case in 0..cases {
        let v = gen.generate(&mut rng);
        if let Err(msg) = prop(&v) {
            // greedy shrink
            let mut cur = v;
            let mut cur_msg = msg;
            let mut budget = 200;
            'outer: while budget > 0 {
                for cand in gen.shrink(&cur) {
                    budget -= 1;
                    if let Err(m) = prop(&cand) {
                        cur = cand;
                        cur_msg = m;
                        continue 'outer;
                    }
                    if budget == 0 {
                        break;
                    }
                }
                break;
            }
            panic!(
                "property failed (case {case}, seed {seed}): {cur_msg}\ncounterexample: {cur:?}"
            );
        }
    }
}

/// Generator: usize in [lo, hi], shrinks toward lo.
pub struct UsizeIn(pub usize, pub usize);

impl Gen for UsizeIn {
    type Value = usize;
    fn generate(&self, rng: &mut Rng) -> usize {
        rng.range_usize(self.0, self.1 + 1)
    }
    fn shrink(&self, v: &usize) -> Vec<usize> {
        let mut out = Vec::new();
        if *v > self.0 {
            out.push(self.0);
            out.push(self.0 + (*v - self.0) / 2);
            out.push(v - 1);
        }
        out.dedup();
        out
    }
}

/// Generator: `Vec<f32>` of length in `[min_len, max_len]`, N(0, std)
/// entries; shrinks by halving length and zeroing entries.
pub struct VecF32 {
    pub min_len: usize,
    pub max_len: usize,
    pub std: f32,
}

impl Gen for VecF32 {
    type Value = Vec<f32>;
    fn generate(&self, rng: &mut Rng) -> Vec<f32> {
        let n = rng.range_usize(self.min_len, self.max_len + 1);
        rng.normal_vec(n, self.std)
    }
    fn shrink(&self, v: &Vec<f32>) -> Vec<Vec<f32>> {
        let mut out = Vec::new();
        if v.len() > self.min_len {
            let half = self.min_len.max(v.len() / 2);
            out.push(v[..half].to_vec());
            out.push(v[..v.len() - 1].to_vec());
        }
        if v.iter().any(|&x| x != 0.0) {
            out.push(v.iter().map(|_| 0.0).collect());
        }
        out
    }
}

/// Pair of independent generators.
pub struct Pair<A, B>(pub A, pub B);

impl<A: Gen, B: Gen> Gen for Pair<A, B> {
    type Value = (A::Value, B::Value);
    fn generate(&self, rng: &mut Rng) -> Self::Value {
        (self.0.generate(rng), self.1.generate(rng))
    }
    fn shrink(&self, v: &Self::Value) -> Vec<Self::Value> {
        let mut out: Vec<Self::Value> = self
            .0
            .shrink(&v.0)
            .into_iter()
            .map(|a| (a, v.1.clone()))
            .collect();
        out.extend(self.1.shrink(&v.1).into_iter().map(|b| (v.0.clone(), b)));
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property_runs_all_cases() {
        check(1, 50, &UsizeIn(0, 100), |&v| {
            if v <= 100 {
                Ok(())
            } else {
                Err("impossible".into())
            }
        });
    }

    #[test]
    fn failing_property_shrinks() {
        let result = std::panic::catch_unwind(|| {
            check(2, 200, &UsizeIn(0, 1000), |&v| {
                if v < 17 {
                    Ok(())
                } else {
                    Err(format!("{v} >= 17"))
                }
            });
        });
        let msg = format!("{:?}", result.unwrap_err().downcast_ref::<String>());
        // greedy shrink should land at or very near the boundary value 17
        assert!(msg.contains("counterexample: 17"), "{msg}");
    }

    #[test]
    fn vec_generator_respects_bounds() {
        let gen = VecF32 { min_len: 2, max_len: 8, std: 1.0 };
        let mut rng = Rng::new(3);
        for _ in 0..100 {
            let v = gen.generate(&mut rng);
            assert!((2..=8).contains(&v.len()));
        }
    }
}
