//! ASCII table rendering for bench reports (paper tables/figures are printed
//! as aligned text tables; see EXPERIMENTS.md for captured outputs).

pub struct Table {
    title: String,
    header: Vec<String>,
    rows: Vec<Vec<String>>,
}

impl Table {
    pub fn new(title: &str, header: &[&str]) -> Self {
        Table {
            title: title.to_string(),
            header: header.iter().map(|s| s.to_string()).collect(),
            rows: Vec::new(),
        }
    }

    pub fn row(&mut self, cells: Vec<String>) -> &mut Self {
        assert_eq!(cells.len(), self.header.len(), "row arity mismatch");
        self.rows.push(cells);
        self
    }

    pub fn render(&self) -> String {
        let ncol = self.header.len();
        let mut widths: Vec<usize> = self.header.iter().map(|h| h.len()).collect();
        for row in &self.rows {
            for (i, c) in row.iter().enumerate() {
                widths[i] = widths[i].max(c.len());
            }
        }
        let sep: String = {
            let mut s = String::from("+");
            for w in &widths {
                s.push_str(&"-".repeat(w + 2));
                s.push('+');
            }
            s
        };
        let fmt_row = |cells: &[String]| -> String {
            let mut s = String::from("|");
            for i in 0..ncol {
                s.push(' ');
                s.push_str(&cells[i]);
                s.push_str(&" ".repeat(widths[i] - cells[i].len() + 1));
                s.push('|');
            }
            s
        };
        let mut out = String::new();
        if !self.title.is_empty() {
            out.push_str(&format!("## {}\n", self.title));
        }
        out.push_str(&sep);
        out.push('\n');
        out.push_str(&fmt_row(&self.header));
        out.push('\n');
        out.push_str(&sep);
        out.push('\n');
        for row in &self.rows {
            out.push_str(&fmt_row(row));
            out.push('\n');
        }
        out.push_str(&sep);
        out.push('\n');
        out
    }

    pub fn print(&self) {
        println!("{}", self.render());
    }
}

/// Format helpers used throughout the benches.
pub fn f1(x: f64) -> String {
    format!("{x:.1}")
}

pub fn f2(x: f64) -> String {
    format!("{x:.2}")
}

pub fn f3(x: f64) -> String {
    format!("{x:.3}")
}

pub fn f4(x: f64) -> String {
    format!("{x:.4}")
}

pub fn sci(x: f64) -> String {
    format!("{x:.3e}")
}

pub fn speedup(new: f64, old: f64) -> String {
    format!("{:.2}x", new / old)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn renders_aligned() {
        let mut t = Table::new("demo", &["name", "val"]);
        t.row(vec!["a".into(), "1".into()]);
        t.row(vec!["longer".into(), "2.5".into()]);
        let r = t.render();
        assert!(r.contains("## demo"));
        let lines: Vec<&str> = r.lines().collect();
        // all rows same width
        let w = lines[1].len();
        assert!(lines.iter().skip(1).all(|l| l.len() == w), "{r}");
        assert!(r.contains("| longer | 2.5 |"));
    }

    #[test]
    #[should_panic]
    fn arity_checked() {
        let mut t = Table::new("x", &["a", "b"]);
        t.row(vec!["only-one".into()]);
    }

    #[test]
    fn formatters() {
        assert_eq!(f2(1.234), "1.23");
        assert_eq!(speedup(19.1, 10.0), "1.91x");
    }
}
