//! Minimal `anyhow`-compatible error type (the offline crate set has no
//! external dependencies, so the crate carries its own error substrate).
//!
//! The [`crate::anyhow`] facade module re-exports this type plus the
//! `anyhow!` / `bail!` / `ensure!` macros, so call sites keep the exact
//! idiom of the `anyhow` crate: `use crate::anyhow;` then
//! `anyhow::Result<T>`, `anyhow::ensure!(..)`, `anyhow::bail!(..)`.

use std::fmt;

/// A flattened, message-carrying error (the `anyhow::Error` analogue).
pub struct Error {
    msg: String,
}

/// Drop-in for `anyhow::Result`.
pub type Result<T, E = Error> = std::result::Result<T, E>;

impl Error {
    pub fn msg(msg: impl Into<String>) -> Error {
        Error { msg: msg.into() }
    }

    /// Prepend context, anyhow-style: `err.context("loading manifest")`.
    pub fn context(self, ctx: impl fmt::Display) -> Error {
        Error { msg: format!("{ctx}: {}", self.msg) }
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.msg)
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.msg)
    }
}

// Like anyhow: `Error` itself does NOT implement `std::error::Error`, which
// is what makes this blanket conversion coherent — `?` works on any
// std-error type without conflicting with `impl From<T> for T`.
impl<E: std::error::Error> From<E> for Error {
    fn from(e: E) -> Error {
        Error { msg: e.to_string() }
    }
}

/// `anyhow!`-style message constructor.
#[macro_export]
#[doc(hidden)]
macro_rules! __anyhow {
    ($($t:tt)*) => {
        $crate::util::error::Error::msg(format!($($t)*))
    };
}

/// Early-return with a formatted [`Error`].
#[macro_export]
macro_rules! bail {
    ($($t:tt)*) => {
        return Err($crate::__anyhow!($($t)*))
    };
}

/// Assert-or-bail with a formatted [`Error`].
#[macro_export]
macro_rules! ensure {
    ($cond:expr, $($t:tt)*) => {
        if !($cond) {
            $crate::bail!($($t)*);
        }
    };
    ($cond:expr $(,)?) => {
        if !($cond) {
            $crate::bail!("condition failed: {}", stringify!($cond));
        }
    };
}

#[cfg(test)]
mod tests {
    use crate::anyhow;

    fn io_fail() -> anyhow::Result<String> {
        let text = std::fs::read_to_string("/nonexistent/snapmla/path")?;
        Ok(text)
    }

    fn checked(x: usize) -> anyhow::Result<usize> {
        anyhow::ensure!(x < 10, "x too large: {x}");
        if x == 7 {
            anyhow::bail!("seven is right out");
        }
        Ok(x)
    }

    #[test]
    fn question_mark_converts_std_errors() {
        let e = io_fail().unwrap_err();
        assert!(!e.to_string().is_empty());
    }

    #[test]
    fn ensure_and_bail() {
        assert_eq!(checked(3).unwrap(), 3);
        assert!(checked(12).unwrap_err().to_string().contains("12"));
        assert!(checked(7).unwrap_err().to_string().contains("seven"));
    }

    #[test]
    fn anyhow_macro_and_context() {
        let e = anyhow::anyhow!("bad value {}", 42).context("loading");
        assert_eq!(format!("{e}"), "loading: bad value 42");
        assert_eq!(format!("{e:?}"), "loading: bad value 42");
    }
}
