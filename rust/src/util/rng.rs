//! Deterministic RNG (SplitMix64 + xoshiro256**) with the distributions the
//! workload generators and synthetic-KV models need. Std-only substrate.

/// xoshiro256** seeded via SplitMix64 — fast, high-quality, reproducible.
#[derive(Clone, Debug)]
pub struct Rng {
    s: [u64; 4],
}

impl Rng {
    pub fn new(seed: u64) -> Self {
        // SplitMix64 to fill the state (never all-zero).
        let mut x = seed.wrapping_add(0x9E3779B97F4A7C15);
        let mut next = || {
            x = x.wrapping_add(0x9E3779B97F4A7C15);
            let mut z = x;
            z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
            z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
            z ^ (z >> 31)
        };
        Rng { s: [next(), next(), next(), next()] }
    }

    /// Derive an independent stream (for per-sequence / per-worker RNGs).
    pub fn fork(&mut self, tag: u64) -> Rng {
        Rng::new(self.next_u64() ^ tag.wrapping_mul(0xA24BAED4963EE407))
    }

    pub fn next_u64(&mut self) -> u64 {
        let r = self.s[1]
            .wrapping_mul(5)
            .rotate_left(7)
            .wrapping_mul(9);
        let t = self.s[1] << 17;
        self.s[2] ^= self.s[0];
        self.s[3] ^= self.s[1];
        self.s[1] ^= self.s[2];
        self.s[0] ^= self.s[3];
        self.s[2] ^= t;
        self.s[3] = self.s[3].rotate_left(45);
        r
    }

    /// Uniform in [0, 1).
    pub fn f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    pub fn f32(&mut self) -> f32 {
        self.f64() as f32
    }

    /// Uniform integer in [0, n).
    pub fn below(&mut self, n: usize) -> usize {
        assert!(n > 0);
        // 128-bit multiply rejection-free bounded sampling (Lemire).
        ((self.next_u64() as u128 * n as u128) >> 64) as usize
    }

    /// Uniform in [lo, hi).
    pub fn range_f64(&mut self, lo: f64, hi: f64) -> f64 {
        lo + self.f64() * (hi - lo)
    }

    pub fn range_usize(&mut self, lo: usize, hi: usize) -> usize {
        assert!(hi > lo);
        lo + self.below(hi - lo)
    }

    pub fn bool(&mut self, p: f64) -> bool {
        self.f64() < p
    }

    /// Standard normal (Box–Muller; one value per call for simplicity).
    pub fn normal(&mut self) -> f64 {
        loop {
            let u = self.f64();
            if u > 1e-12 {
                let v = self.f64();
                return (-2.0 * u.ln()).sqrt() * (2.0 * std::f64::consts::PI * v).cos();
            }
        }
    }

    pub fn normal_scaled(&mut self, mean: f64, std: f64) -> f64 {
        mean + std * self.normal()
    }

    pub fn lognormal(&mut self, mu: f64, sigma: f64) -> f64 {
        (mu + sigma * self.normal()).exp()
    }

    /// Exponential with the given mean (inter-arrival times).
    pub fn exponential(&mut self, mean: f64) -> f64 {
        let u = self.f64().max(1e-12);
        -mean * u.ln()
    }

    /// Student-t via normal / sqrt(chi2/df) (heavy-tailed rope values).
    pub fn student_t(&mut self, df: f64) -> f64 {
        let n = self.normal();
        let mut chi2 = 0.0;
        let k = df.round().max(1.0) as usize;
        for _ in 0..k {
            let z = self.normal();
            chi2 += z * z;
        }
        n / (chi2 / df).sqrt()
    }

    /// Fill a vector of standard normals (f32).
    pub fn normal_vec(&mut self, n: usize, std: f32) -> Vec<f32> {
        (0..n).map(|_| (self.normal() * std as f64) as f32).collect()
    }

    /// Sample an index from unnormalized weights.
    pub fn weighted(&mut self, weights: &[f64]) -> usize {
        let total: f64 = weights.iter().sum();
        let mut x = self.f64() * total;
        for (i, w) in weights.iter().enumerate() {
            if x < *w {
                return i;
            }
            x -= w;
        }
        weights.len() - 1
    }

    /// Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.below(i + 1);
            xs.swap(i, j);
        }
    }

    /// Softmax-temperature sampling over logits; returns the index.
    pub fn sample_logits(&mut self, logits: &[f32], temperature: f32) -> usize {
        if temperature <= 0.0 {
            return argmax(logits);
        }
        let m = logits.iter().cloned().fold(f32::NEG_INFINITY, f32::max);
        let exps: Vec<f64> =
            logits.iter().map(|&x| (((x - m) / temperature) as f64).exp()).collect();
        self.weighted(&exps)
    }
}

/// Index of the maximum element (first on ties).
pub fn argmax(xs: &[f32]) -> usize {
    let mut best = 0;
    for (i, &x) in xs.iter().enumerate() {
        if x > xs[best] {
            best = i;
        }
    }
    best
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_streams() {
        let mut a = Rng::new(7);
        let mut b = Rng::new(7);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn forked_streams_differ() {
        let mut a = Rng::new(7);
        let mut f1 = a.fork(1);
        let mut f2 = a.fork(2);
        assert_ne!(f1.next_u64(), f2.next_u64());
    }

    #[test]
    fn uniform_mean_and_bounds() {
        let mut r = Rng::new(1);
        let n = 20_000;
        let mut sum = 0.0;
        for _ in 0..n {
            let x = r.f64();
            assert!((0.0..1.0).contains(&x));
            sum += x;
        }
        assert!((sum / n as f64 - 0.5).abs() < 0.01);
    }

    #[test]
    fn below_is_bounded_and_covers() {
        let mut r = Rng::new(2);
        let mut seen = [false; 7];
        for _ in 0..1000 {
            let i = r.below(7);
            assert!(i < 7);
            seen[i] = true;
        }
        assert!(seen.iter().all(|&s| s));
    }

    #[test]
    fn normal_moments() {
        let mut r = Rng::new(3);
        let n = 50_000;
        let (mut s1, mut s2) = (0.0, 0.0);
        for _ in 0..n {
            let x = r.normal();
            s1 += x;
            s2 += x * x;
        }
        let mean = s1 / n as f64;
        let var = s2 / n as f64 - mean * mean;
        assert!(mean.abs() < 0.02, "mean {mean}");
        assert!((var - 1.0).abs() < 0.05, "var {var}");
    }

    #[test]
    fn exponential_mean() {
        let mut r = Rng::new(4);
        let n = 50_000;
        let mean: f64 = (0..n).map(|_| r.exponential(3.0)).sum::<f64>() / n as f64;
        assert!((mean - 3.0).abs() < 0.1, "{mean}");
    }

    #[test]
    fn student_t_heavier_than_normal() {
        let mut r = Rng::new(5);
        let n = 40_000;
        let big_t = (0..n).filter(|_| r.student_t(2.0).abs() > 4.0).count();
        let big_n = (0..n).filter(|_| r.normal().abs() > 4.0).count();
        assert!(big_t > 10 * (big_n + 1), "t {big_t} vs n {big_n}");
    }

    #[test]
    fn weighted_prefers_heavy_bucket() {
        let mut r = Rng::new(6);
        let w = [1.0, 0.0, 9.0];
        let picks: Vec<usize> = (0..5000).map(|_| r.weighted(&w)).collect();
        assert!(!picks.contains(&1));
        let heavy = picks.iter().filter(|&&i| i == 2).count();
        assert!(heavy > 4000, "{heavy}");
    }

    #[test]
    fn sample_logits_greedy_at_zero_temp() {
        let mut r = Rng::new(8);
        assert_eq!(r.sample_logits(&[0.1, 3.0, -1.0], 0.0), 1);
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Rng::new(9);
        let mut xs: Vec<usize> = (0..50).collect();
        r.shuffle(&mut xs);
        let mut sorted = xs.clone();
        sorted.sort();
        assert_eq!(sorted, (0..50).collect::<Vec<_>>());
    }
}
