//! Std-only utility substrates (the offline crate set has no serde/clap/rand).

pub mod cli;
pub mod error;
pub mod json;
pub mod prop;
pub mod rng;
pub mod stats;
pub mod table;
