//! Model execution: artifact manifest, weight loading, the backend
//! abstraction and the model engine.
//!
//! * [`manifest`] — artifact index + model metadata (artifacts/manifest.json)
//! * [`weights`]  — weights.bin loader (custom binary bundle)
//! * [`backend`]  — [`backend::ExecBackend`]: upload/download, executable
//!   load, step execution behind one object-safe trait
//! * [`sim`]      — offline pure-Rust backend (reference MLA math + bit-exact
//!   FP8 quantizers over a deterministic induction model)
//! * [`sim_model`] — the sim model's constructed weights + forward pass
//! * [`spec`]     — deterministic induction-rule draft model for
//!   speculative decoding (drafts verified via [`engine::ModelEngine::verify`])
//! * `client` (feature `pjrt`) — PJRT backend executing AOT HLO artifacts
//! * [`engine`]   — bucketized decode/prefill execution over the paged cache

pub mod backend;
#[cfg(feature = "pjrt")]
pub mod client;
pub mod engine;
pub mod manifest;
pub mod sim;
pub mod sim_model;
pub mod spec;
pub mod weights;

pub use backend::{BufId, ExecBackend, ExecId};
#[cfg(feature = "pjrt")]
pub use client::{PjrtBackend, Runtime};
pub use engine::{
    DecodeResult, EngineBuilder, KernelArgs, MixedResult, ModelEngine, PrefillResult, VerifyResult,
};
pub use manifest::{ArtifactInfo, ArtifactKind, Manifest, ModelMeta};
pub use sim::{SimBackend, MIXED_CHUNK, VERIFY_CHUNK};
pub use sim_model::SimSpec;
pub use spec::DraftModel;
pub use weights::Weights;
