//! PJRT runtime: load AOT artifacts (HLO text), manage weights on device,
//! and execute decode/prefill steps from the rust hot path.
//!
//! Python runs only at build time (`make artifacts`); this module is the
//! entire model-execution surface at serve time:
//!
//! * [`manifest`] — artifact index + model metadata (artifacts/manifest.json)
//! * [`weights`]  — weights.bin loader (custom binary bundle)
//! * [`client`]   — thin `xla` crate wrapper (PJRT CPU client)
//! * [`engine`]   — bucketized decode/prefill execution over the paged cache

pub mod client;
pub mod engine;
pub mod manifest;
pub mod weights;

pub use client::Runtime;
pub use engine::{DecodeResult, ModelEngine, PrefillResult};
pub use manifest::{ArtifactInfo, ArtifactKind, Manifest, ModelMeta};
pub use weights::Weights;
