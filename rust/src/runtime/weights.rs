//! weights.bin loader — the custom binary bundle written by aot.py:
//! magic "SNAPW001", u32 count, then per tensor:
//! u16 name_len | name | u8 dtype (0=f32) | u8 ndim | u32 dims… | f32 LE data.

use crate::anyhow;
use std::collections::BTreeMap;
use std::io::Read;
use std::path::Path;

#[derive(Debug)]
pub struct Weights {
    pub tensors: BTreeMap<String, Tensor>,
}

#[derive(Debug)]
pub struct Tensor {
    pub dims: Vec<usize>,
    pub data: Vec<f32>,
}

impl Weights {
    pub fn load(path: &Path) -> anyhow::Result<Weights> {
        let mut f = std::io::BufReader::new(std::fs::File::open(path)?);
        let mut magic = [0u8; 8];
        f.read_exact(&mut magic)?;
        anyhow::ensure!(&magic == b"SNAPW001", "bad weights magic {magic:?}");
        let count = read_u32(&mut f)? as usize;
        let mut tensors = BTreeMap::new();
        for _ in 0..count {
            let name_len = read_u16(&mut f)? as usize;
            let mut name = vec![0u8; name_len];
            f.read_exact(&mut name)?;
            let name = String::from_utf8(name)?;
            let mut hdr = [0u8; 2];
            f.read_exact(&mut hdr)?;
            anyhow::ensure!(hdr[0] == 0, "{name}: unsupported dtype {}", hdr[0]);
            let ndim = hdr[1] as usize;
            let mut dims = Vec::with_capacity(ndim);
            for _ in 0..ndim {
                dims.push(read_u32(&mut f)? as usize);
            }
            let n: usize = dims.iter().product();
            let mut bytes = vec![0u8; n * 4];
            f.read_exact(&mut bytes)?;
            let data = bytes
                .chunks_exact(4)
                .map(|c| f32::from_le_bytes([c[0], c[1], c[2], c[3]]))
                .collect();
            tensors.insert(name, Tensor { dims, data });
        }
        Ok(Weights { tensors })
    }

    pub fn get(&self, name: &str) -> anyhow::Result<&Tensor> {
        self.tensors
            .get(name)
            .ok_or_else(|| anyhow::anyhow!("missing weight tensor {name}"))
    }

    pub fn total_params(&self) -> usize {
        self.tensors.values().map(|t| t.data.len()).sum()
    }
}

fn read_u16<R: Read>(r: &mut R) -> anyhow::Result<u16> {
    let mut b = [0u8; 2];
    r.read_exact(&mut b)?;
    Ok(u16::from_le_bytes(b))
}

fn read_u32<R: Read>(r: &mut R) -> anyhow::Result<u32> {
    let mut b = [0u8; 4];
    r.read_exact(&mut b)?;
    Ok(u32::from_le_bytes(b))
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::io::Write;

    fn write_test_bundle(path: &Path) {
        let mut f = std::fs::File::create(path).unwrap();
        f.write_all(b"SNAPW001").unwrap();
        f.write_all(&2u32.to_le_bytes()).unwrap();
        // tensor "a": [2, 3]
        f.write_all(&(1u16).to_le_bytes()).unwrap();
        f.write_all(b"a").unwrap();
        f.write_all(&[0u8, 2u8]).unwrap();
        f.write_all(&2u32.to_le_bytes()).unwrap();
        f.write_all(&3u32.to_le_bytes()).unwrap();
        for i in 0..6 {
            f.write_all(&(i as f32).to_le_bytes()).unwrap();
        }
        // tensor "ln": scalar-ish [4]
        f.write_all(&(2u16).to_le_bytes()).unwrap();
        f.write_all(b"ln").unwrap();
        f.write_all(&[0u8, 1u8]).unwrap();
        f.write_all(&4u32.to_le_bytes()).unwrap();
        for _ in 0..4 {
            f.write_all(&1.5f32.to_le_bytes()).unwrap();
        }
    }

    #[test]
    fn roundtrip_synthetic_bundle() {
        let dir = std::env::temp_dir().join("snapmla_wtest");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("w.bin");
        write_test_bundle(&path);
        let w = Weights::load(&path).unwrap();
        assert_eq!(w.tensors.len(), 2);
        let a = w.get("a").unwrap();
        assert_eq!(a.dims, vec![2, 3]);
        assert_eq!(a.data, vec![0.0, 1.0, 2.0, 3.0, 4.0, 5.0]);
        assert_eq!(w.get("ln").unwrap().data, vec![1.5; 4]);
        assert_eq!(w.total_params(), 10);
        assert!(w.get("nope").is_err());
    }

    #[test]
    fn rejects_bad_magic() {
        let dir = std::env::temp_dir().join("snapmla_wtest2");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("bad.bin");
        std::fs::write(&path, b"NOTMAGIC....").unwrap();
        assert!(Weights::load(&path).is_err());
    }

    #[test]
    fn loads_real_bundle_when_present() {
        let path = Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts/weights.bin");
        if !path.exists() {
            return;
        }
        let w = Weights::load(&path).unwrap();
        assert!(w.total_params() > 20_000_000);
        assert!(w.get("embed").is_ok());
        assert!(w.get("layer00.w_dkv").is_ok());
    }
}
