//! The model engine: bucketized decode/prefill execution of the AOT
//! artifacts over the paged KV cache.
//!
//! One engine = one model replica (a DP rank). Weights are uploaded to the
//! device once at load; each step uploads only the step inputs (token ids,
//! positions, gathered cache views) and downloads logits + the new KV
//! entries, which are appended to the rust-owned paged cache (the canonical
//! store — u8 E4M3 + bf16, bit-exact with the in-graph quantization).

use super::client::Runtime;
use super::manifest::{ArtifactKind, Manifest};
use super::weights::Weights;
use crate::kvcache::{CacheConfig, CacheMode, PagedKvCache, SeqHandle};
use std::collections::BTreeMap;
use std::path::Path;
use std::time::Instant;

#[derive(Clone, Debug, Default)]
pub struct EngineStats {
    pub decode_steps: u64,
    pub decode_tokens: u64,
    pub prefill_calls: u64,
    pub prefill_tokens: u64,
    pub compiles: u64,
    pub gather_s: f64,
    pub execute_s: f64,
    pub append_s: f64,
}

pub struct ModelEngine {
    pub rt: Runtime,
    pub manifest: Manifest,
    pub mode: CacheMode,
    mode_str: &'static str,
    weight_bufs: Vec<xla::PjRtBuffer>,
    execs: BTreeMap<String, xla::PjRtLoadedExecutable>,
    pub stats: EngineStats,
}

#[derive(Debug)]
pub struct DecodeResult {
    /// per input item: full next-token logits [vocab]
    pub logits: Vec<Vec<f32>>,
}

#[derive(Debug)]
pub struct PrefillResult {
    /// per input item: logits after the last prompt token [vocab]
    pub logits: Vec<Vec<f32>>,
}

impl ModelEngine {
    /// Load manifest + weights and upload weights to the device.
    pub fn load(artifacts_dir: &Path, mode: CacheMode) -> anyhow::Result<ModelEngine> {
        let rt = Runtime::cpu()?;
        let manifest = Manifest::load(artifacts_dir)?;
        let weights = Weights::load(&artifacts_dir.join("weights.bin"))?;
        anyhow::ensure!(
            weights.total_params() == manifest.model.params,
            "weights/manifest param count mismatch"
        );
        let mut weight_bufs = Vec::with_capacity(manifest.param_order.len());
        for name in &manifest.param_order {
            let t = weights.get(name)?;
            weight_bufs.push(rt.buf_f32(&t.data, &t.dims)?);
        }
        Ok(ModelEngine {
            rt,
            manifest,
            mode,
            mode_str: match mode {
                CacheMode::Fp8 => "fp8",
                CacheMode::Bf16 => "bf16",
            },
            weight_bufs,
            execs: BTreeMap::new(),
            stats: EngineStats::default(),
        })
    }

    pub fn mode_str(&self) -> &'static str {
        self.mode_str
    }

    /// A cache config sized for this engine's largest decode bucket.
    pub fn cache_config(&self, capacity_pages: usize) -> CacheConfig {
        CacheConfig {
            n_layers: self.manifest.model.n_layers,
            d_c: self.manifest.model.d_c,
            d_r: self.manifest.model.d_r,
            mode: self.mode,
            capacity_pages,
        }
    }

    /// Largest supported context (largest decode bucket).
    pub fn max_context(&self) -> usize {
        self.manifest.max_context(self.mode_str)
    }

    fn ensure_compiled(&mut self, name: &str) -> anyhow::Result<()> {
        if !self.execs.contains_key(name) {
            let path = self.manifest.hlo_path(name);
            let exe = self.rt.load_hlo(&path)?;
            self.execs.insert(name.to_string(), exe);
            self.stats.compiles += 1;
        }
        Ok(())
    }

    /// Execute an arbitrary artifact with explicit (non-weight) args —
    /// used by the kernel benches.
    pub fn execute_kernel(
        &mut self,
        name: &str,
        args: &[&xla::PjRtBuffer],
    ) -> anyhow::Result<Vec<Vec<f32>>> {
        self.ensure_compiled(name)?;
        let exe = self.execs.get(name).unwrap();
        self.rt.run_to_f32(exe, args)
    }

    /// One decode step for `items` = (sequence, input token) pairs. Appends
    /// the new KV entries to `cache` and returns next-token logits per item.
    pub fn decode(
        &mut self,
        cache: &mut PagedKvCache,
        items: &[(SeqHandle, i32)],
    ) -> anyhow::Result<DecodeResult> {
        anyhow::ensure!(!items.is_empty(), "empty decode batch");
        let m = &self.manifest.model;
        let (l, d_c, d_r, vocab) = (m.n_layers, m.d_c, m.d_r, m.vocab);
        let max_ctx = items
            .iter()
            .map(|&(s, _)| cache.tokens_of(s) + 1)
            .max()
            .unwrap();
        let bucket = self
            .manifest
            .decode_bucket(self.mode_str, items.len(), max_ctx)
            .ok_or_else(|| {
                anyhow::anyhow!(
                    "no decode bucket for batch {} ctx {max_ctx} ({})",
                    items.len(),
                    self.mode_str
                )
            })?;
        let (bb, ss, name) = (bucket.batch, bucket.seq, bucket.name.clone());
        self.ensure_compiled(&name)?;

        // ---- stage inputs ---------------------------------------------------
        let t0 = Instant::now();
        let mut token_ids = vec![0i32; bb];
        let mut positions = vec![0i32; bb];
        for (i, &(seq, tok)) in items.iter().enumerate() {
            token_ids[i] = tok;
            positions[i] = cache.tokens_of(seq) as i32;
        }
        let fp8 = self.mode == CacheMode::Fp8;
        let mut k_c = vec![0.0f32; l * bb * ss * d_c];
        let mut k_r = vec![0.0f32; l * bb * ss * d_r];
        let mut sigma = vec![1.0f32; l * bb * ss];
        for (b, &(seq, _)) in items.iter().enumerate() {
            for layer in 0..l {
                let off = (layer * bb + b) * ss;
                cache.gather_kernel_view(
                    seq,
                    layer,
                    ss,
                    &mut k_c[off * d_c..(off + ss) * d_c],
                    &mut k_r[off * d_r..(off + ss) * d_r],
                    &mut sigma[off..off + ss],
                );
            }
        }
        let tok_buf = self.rt.buf_i32(&token_ids, &[bb, 1])?;
        let pos_buf = self.rt.buf_i32(&positions, &[bb])?;
        let kc_buf = self.rt.buf_f32(&k_c, &[l, bb, ss, d_c])?;
        let kr_buf = self.rt.buf_f32(&k_r, &[l, bb, ss, d_r])?;
        let sg_buf = if fp8 { Some(self.rt.buf_f32(&sigma, &[l, bb, ss, 1])?) } else { None };
        self.stats.gather_s += t0.elapsed().as_secs_f64();

        // ---- execute --------------------------------------------------------
        let t1 = Instant::now();
        let exe = self.execs.get(&name).unwrap();
        let mut args: Vec<&xla::PjRtBuffer> = self.weight_bufs.iter().collect();
        args.push(&tok_buf);
        args.push(&pos_buf);
        args.push(&kc_buf);
        args.push(&kr_buf);
        if let Some(ref sg) = sg_buf {
            args.push(sg);
        }
        let outs = self.rt.run_to_f32(exe, &args)?;
        self.stats.execute_s += t1.elapsed().as_secs_f64();
        anyhow::ensure!(outs.len() == if fp8 { 4 } else { 3 }, "bad output arity");

        // ---- append new KV entries + collect logits -------------------------
        let t2 = Instant::now();
        let logits_flat = &outs[0]; // [bb, 1, vocab]
        let new_kc = &outs[1]; // [l, bb, 1, d_c]
        let new_kr = &outs[2]; // [l, bb, 1, d_r]
        let mut logits = Vec::with_capacity(items.len());
        let mut kc_tok = vec![0.0f32; l * d_c];
        let mut kr_tok = vec![0.0f32; l * d_r];
        for (b, &(seq, _)) in items.iter().enumerate() {
            for layer in 0..l {
                let src = (layer * bb + b) * d_c;
                kc_tok[layer * d_c..(layer + 1) * d_c]
                    .copy_from_slice(&new_kc[src..src + d_c]);
                let src = (layer * bb + b) * d_r;
                kr_tok[layer * d_r..(layer + 1) * d_r]
                    .copy_from_slice(&new_kr[src..src + d_r]);
            }
            if fp8 {
                let new_sg = &outs[3]; // [l, bb, 1, 1]
                let sg_tok: Vec<f32> =
                    (0..l).map(|layer| new_sg[layer * bb + b]).collect();
                cache
                    .append_prequantized(seq, &kc_tok, &kr_tok, &sg_tok)
                    .map_err(|e| anyhow::anyhow!("cache append: {e:?}"))?;
            } else {
                cache
                    .append_token(seq, &kc_tok, &kr_tok)
                    .map_err(|e| anyhow::anyhow!("cache append: {e:?}"))?;
            }
            logits.push(logits_flat[b * vocab..(b + 1) * vocab].to_vec());
        }
        self.stats.append_s += t2.elapsed().as_secs_f64();
        self.stats.decode_steps += 1;
        self.stats.decode_tokens += items.len() as u64;
        Ok(DecodeResult { logits })
    }

    /// Prefill `items` = (sequence, prompt tokens). Appends all prompt KV
    /// entries to `cache`; returns last-token logits per item.
    pub fn prefill(
        &mut self,
        cache: &mut PagedKvCache,
        items: &[(SeqHandle, Vec<i32>)],
    ) -> anyhow::Result<PrefillResult> {
        anyhow::ensure!(!items.is_empty(), "empty prefill batch");
        let m = &self.manifest.model;
        let (l, d_c, d_r, vocab) = (m.n_layers, m.d_c, m.d_r, m.vocab);
        let max_p = items.iter().map(|(_, p)| p.len()).max().unwrap();
        let bucket = self
            .manifest
            .prefill_bucket(self.mode_str, items.len(), max_p)
            .ok_or_else(|| {
                anyhow::anyhow!("no prefill bucket for batch {} prompt {max_p}", items.len())
            })?;
        let (bb, pp, name) = (bucket.batch, bucket.seq, bucket.name.clone());
        self.ensure_compiled(&name)?;

        let t0 = Instant::now();
        let mut token_ids = vec![0i32; bb * pp];
        let mut plens = vec![1i32; bb]; // dummy rows use plen 1
        for (i, (_, prompt)) in items.iter().enumerate() {
            token_ids[i * pp..i * pp + prompt.len()].copy_from_slice(prompt);
            plens[i] = prompt.len() as i32;
        }
        let tok_buf = self.rt.buf_i32(&token_ids, &[bb, pp])?;
        let len_buf = self.rt.buf_i32(&plens, &[bb])?;
        self.stats.gather_s += t0.elapsed().as_secs_f64();

        let t1 = Instant::now();
        let exe = self.execs.get(&name).unwrap();
        let mut args: Vec<&xla::PjRtBuffer> = self.weight_bufs.iter().collect();
        args.push(&tok_buf);
        args.push(&len_buf);
        let outs = self.rt.run_to_f32(exe, &args)?;
        self.stats.execute_s += t1.elapsed().as_secs_f64();
        let fp8 = self.mode == CacheMode::Fp8;
        anyhow::ensure!(outs.len() == if fp8 { 4 } else { 3 }, "bad output arity");

        let t2 = Instant::now();
        let last_logits = &outs[0]; // [bb, vocab]
        let e_kc = &outs[1]; // [l, bb, pp, d_c]
        let e_kr = &outs[2]; // [l, bb, pp, d_r]
        let mut logits = Vec::with_capacity(items.len());
        let mut kc_tok = vec![0.0f32; l * d_c];
        let mut kr_tok = vec![0.0f32; l * d_r];
        for (b, (seq, prompt)) in items.iter().enumerate() {
            for t in 0..prompt.len() {
                for layer in 0..l {
                    let src = ((layer * bb + b) * pp + t) * d_c;
                    kc_tok[layer * d_c..(layer + 1) * d_c]
                        .copy_from_slice(&e_kc[src..src + d_c]);
                    let src = ((layer * bb + b) * pp + t) * d_r;
                    kr_tok[layer * d_r..(layer + 1) * d_r]
                        .copy_from_slice(&e_kr[src..src + d_r]);
                }
                if fp8 {
                    let e_sg = &outs[3]; // [l, bb, pp, 1]
                    let sg_tok: Vec<f32> = (0..l)
                        .map(|layer| e_sg[(layer * bb + b) * pp + t])
                        .collect();
                    cache
                        .append_prequantized(*seq, &kc_tok, &kr_tok, &sg_tok)
                        .map_err(|e| anyhow::anyhow!("cache append: {e:?}"))?;
                } else {
                    cache
                        .append_token(*seq, &kc_tok, &kr_tok)
                        .map_err(|e| anyhow::anyhow!("cache append: {e:?}"))?;
                }
            }
            logits.push(last_logits[b * vocab..(b + 1) * vocab].to_vec());
            self.stats.prefill_tokens += prompt.len() as u64;
        }
        self.stats.append_s += t2.elapsed().as_secs_f64();
        self.stats.prefill_calls += 1;
        Ok(PrefillResult { logits })
    }
}

/// Kernel-artifact argument staging (shared by benches): builds the buffers
/// for a `kernel_snapmla_*` / `kernel_flashmla_*` artifact invocation.
pub struct KernelArgs {
    pub bufs: Vec<xla::PjRtBuffer>,
}

impl KernelArgs {
    pub fn snapmla(
        rt: &Runtime,
        t_q: usize,
        heads: usize,
        d_c: usize,
        d_r: usize,
        n: usize,
        length: usize,
        seed: u64,
    ) -> anyhow::Result<KernelArgs> {
        use crate::util::rng::Rng;
        let mut rng = Rng::new(seed);
        let q_c = rng.normal_vec(t_q * heads * d_c, 1.0);
        let q_r = rng.normal_vec(t_q * heads * d_r, 0.3);
        let sq = vec![0.01f32; t_q * heads];
        let k_c = rng.normal_vec(n * d_c, 1.0);
        let k_r = rng.normal_vec(n * d_r, 0.3);
        let sk = vec![0.02f32; n];
        Ok(KernelArgs {
            bufs: vec![
                rt.buf_f32(&q_c, &[t_q, heads, d_c])?,
                rt.buf_f32(&q_r, &[t_q, heads, d_r])?,
                rt.buf_f32(&sq, &[t_q, heads, 1])?,
                rt.buf_f32(&k_c, &[n, d_c])?,
                rt.buf_f32(&k_r, &[n, d_r])?,
                rt.buf_f32(&sk, &[n, 1])?,
                rt.buf_i32(&[length as i32], &[1])?,
            ],
        })
    }

    pub fn flashmla(
        rt: &Runtime,
        t_q: usize,
        heads: usize,
        d_c: usize,
        d_r: usize,
        n: usize,
        length: usize,
        seed: u64,
    ) -> anyhow::Result<KernelArgs> {
        use crate::util::rng::Rng;
        let mut rng = Rng::new(seed);
        let q_c = rng.normal_vec(t_q * heads * d_c, 1.0);
        let q_r = rng.normal_vec(t_q * heads * d_r, 0.3);
        let k_c = rng.normal_vec(n * d_c, 1.0);
        let k_r = rng.normal_vec(n * d_r, 0.3);
        Ok(KernelArgs {
            bufs: vec![
                rt.buf_f32(&q_c, &[t_q, heads, d_c])?,
                rt.buf_f32(&q_r, &[t_q, heads, d_r])?,
                rt.buf_f32(&k_c, &[n, d_c])?,
                rt.buf_f32(&k_r, &[n, d_r])?,
                rt.buf_i32(&[length as i32], &[1])?,
            ],
        })
    }

    pub fn refs(&self) -> Vec<&xla::PjRtBuffer> {
        self.bufs.iter().collect()
    }
}
