//! The model engine: bucketized decode/prefill execution over the paged KV
//! cache, on top of an [`ExecBackend`].
//!
//! One engine = one model replica (a DP rank). Weights are uploaded to the
//! backend once at load; each step uploads only the step inputs (token ids,
//! positions, gathered cache views) and downloads logits + the new KV
//! entries, which are appended to the rust-owned paged cache (the canonical
//! store — u8 E4M3 + bf16, bit-exact with the in-graph quantization).
//!
//! The engine is backend-agnostic: [`ModelEngine::sim`] builds the offline
//! pure-Rust backend (default); [`ModelEngine::load`] (feature `pjrt`)
//! drives AOT HLO artifacts through PJRT; [`ModelEngine::auto`] picks
//! whichever is available.

use super::backend::{BufId, ExecBackend, ExecId};
use super::manifest::Manifest;
use super::sim::{sim_manifest, sim_weights, SimBackend};
use super::sim_model::SimSpec;
use super::spec::DraftModel;
use super::weights::Weights;
use crate::anyhow;
use crate::kvcache::{CacheConfig, CacheMode, PagedKvCache, SeqHandle};
use crate::mla::VariantKind;
use std::collections::BTreeMap;
use std::path::{Path, PathBuf};
use std::time::Instant;

#[derive(Clone, Debug, Default)]
pub struct EngineStats {
    pub decode_steps: u64,
    pub decode_tokens: u64,
    pub prefill_calls: u64,
    pub prefill_tokens: u64,
    pub mixed_steps: u64,
    pub chunk_tokens: u64,
    pub verify_calls: u64,
    pub verify_tokens: u64,
    pub compiles: u64,
    pub gather_s: f64,
    pub execute_s: f64,
    pub append_s: f64,
}

pub struct ModelEngine {
    backend: Box<dyn ExecBackend>,
    pub manifest: Manifest,
    pub mode: CacheMode,
    mode_str: &'static str,
    weight_bufs: Vec<BufId>,
    execs: BTreeMap<String, ExecId>,
    pub stats: EngineStats,
    /// Speculative drafter this engine proposes tokens with (configured via
    /// [`EngineBuilder::draft_window`]; full-fidelity MTP by default).
    pub draft: DraftModel,
}

/// Builder unifying engine construction: execution backend (sim vs PJRT
/// artifacts), decode-kernel variant, and speculative-draft options in one
/// place. [`ModelEngine::sim`] and [`ModelEngine::auto`] are thin delegates.
#[derive(Clone, Debug)]
pub struct EngineBuilder {
    mode: CacheMode,
    variant: VariantKind,
    artifacts: Option<PathBuf>,
    draft_window: Option<usize>,
}

impl EngineBuilder {
    pub fn new(mode: CacheMode) -> EngineBuilder {
        EngineBuilder {
            mode,
            variant: VariantKind::SnapMla,
            artifacts: None,
            draft_window: None,
        }
    }

    /// Decode-kernel variant for the FP8 attention path (the CLI's
    /// `--kernel snapmla|amla|pcast`). Sim backend only; the PJRT artifact
    /// path compiles just the SnapMLA kernel and rejects other variants.
    pub fn kernel(mut self, variant: VariantKind) -> EngineBuilder {
        self.variant = variant;
        self
    }

    /// Prefer AOT artifacts from this dir: the PJRT backend is used when the
    /// `pjrt` feature is on AND the dir holds a compiled manifest; otherwise
    /// the builder falls back to the sim backend.
    pub fn artifacts(mut self, dir: &Path) -> EngineBuilder {
        self.artifacts = Some(dir.to_path_buf());
        self
    }

    /// Bound the speculative drafter's history window (fidelity knob for
    /// `serve --spec`). Unset = full-context MTP-grade drafting.
    pub fn draft_window(mut self, window: usize) -> EngineBuilder {
        self.draft_window = Some(window);
        self
    }

    pub fn build(self) -> anyhow::Result<ModelEngine> {
        #[allow(unused_mut)]
        let mut use_pjrt = false;
        #[cfg(feature = "pjrt")]
        if let Some(dir) = &self.artifacts {
            use_pjrt = dir.join("manifest.json").exists();
        }
        let mut engine = if use_pjrt {
            anyhow::ensure!(
                self.variant == VariantKind::SnapMla,
                "the PJRT artifact path supports only --kernel snapmla"
            );
            #[cfg(feature = "pjrt")]
            {
                ModelEngine::load(self.artifacts.as_deref().unwrap(), self.mode)?
            }
            #[cfg(not(feature = "pjrt"))]
            unreachable!()
        } else {
            let spec = SimSpec::small();
            let manifest = sim_manifest(&spec);
            let weights = sim_weights(&spec);
            ModelEngine::with_backend(
                Box::new(SimBackend::with_variant(spec, self.variant)),
                manifest,
                &weights,
                self.mode,
            )?
        };
        if let Some(w) = self.draft_window {
            engine.draft = DraftModel::with_window(w);
        }
        Ok(engine)
    }
}

#[derive(Debug)]
pub struct DecodeResult {
    /// per input item: full next-token logits [vocab]
    pub logits: Vec<Vec<f32>>,
}

#[derive(Debug)]
pub struct PrefillResult {
    /// per input item: logits after the last prompt token [vocab]
    pub logits: Vec<Vec<f32>>,
}

#[derive(Debug)]
pub struct MixedResult {
    /// per prefill-chunk item: logits after the chunk's last token [vocab]
    pub chunk_logits: Vec<Vec<f32>>,
    /// per decode item: next-token logits [vocab]
    pub decode_logits: Vec<Vec<f32>>,
}

#[derive(Debug)]
pub struct VerifyResult {
    /// per item: logits at EVERY advanced position [inputs][vocab] — position
    /// k scores the token following input k, so one call judges a whole
    /// draft run
    pub logits: Vec<Vec<Vec<f32>>>,
}

impl ModelEngine {
    /// Build an engine over an explicit backend + manifest + weights.
    pub fn with_backend(
        mut backend: Box<dyn ExecBackend>,
        manifest: Manifest,
        weights: &Weights,
        mode: CacheMode,
    ) -> anyhow::Result<ModelEngine> {
        anyhow::ensure!(
            weights.total_params() == manifest.model.params,
            "weights/manifest param count mismatch"
        );
        let mut weight_bufs = Vec::with_capacity(manifest.param_order.len());
        for name in &manifest.param_order {
            let t = weights.get(name)?;
            weight_bufs.push(backend.upload_f32(&t.data, &t.dims)?);
        }
        Ok(ModelEngine {
            backend,
            manifest,
            mode,
            mode_str: match mode {
                CacheMode::Fp8 => "fp8",
                CacheMode::Bf16 => "bf16",
            },
            weight_bufs,
            execs: BTreeMap::new(),
            stats: EngineStats::default(),
            draft: DraftModel::default(),
        })
    }

    /// Configure an engine: backend, kernel variant, draft options.
    pub fn builder(mode: CacheMode) -> EngineBuilder {
        EngineBuilder::new(mode)
    }

    /// The offline engine: pure-Rust [`SimBackend`] over the deterministic
    /// hand-constructed induction model. Needs no artifacts, no deps.
    pub fn sim(mode: CacheMode) -> anyhow::Result<ModelEngine> {
        EngineBuilder::new(mode).build()
    }

    /// Load manifest + weights from an AOT artifacts dir and upload weights
    /// to the PJRT device.
    #[cfg(feature = "pjrt")]
    pub fn load(artifacts_dir: &Path, mode: CacheMode) -> anyhow::Result<ModelEngine> {
        let backend = super::client::PjrtBackend::cpu()?;
        let manifest = Manifest::load(artifacts_dir)?;
        let weights = Weights::load(&artifacts_dir.join("weights.bin"))?;
        ModelEngine::with_backend(Box::new(backend), manifest, &weights, mode)
    }

    /// Backend auto-selection: the PJRT path when the `pjrt` feature is on
    /// AND `artifacts_dir` holds compiled artifacts; the sim otherwise.
    pub fn auto(artifacts_dir: &Path, mode: CacheMode) -> anyhow::Result<ModelEngine> {
        EngineBuilder::new(mode).artifacts(artifacts_dir).build()
    }

    /// The execution backend (kernel benches stage their own buffers).
    pub fn backend_mut(&mut self) -> &mut dyn ExecBackend {
        self.backend.as_mut()
    }

    pub fn backend_name(&self) -> &'static str {
        self.backend.name()
    }

    pub fn mode_str(&self) -> &'static str {
        self.mode_str
    }

    /// A cache config sized for this engine's largest decode bucket.
    pub fn cache_config(&self, capacity_pages: usize) -> CacheConfig {
        CacheConfig {
            n_layers: self.manifest.model.n_layers,
            d_c: self.manifest.model.d_c,
            d_r: self.manifest.model.d_r,
            mode: self.mode,
            capacity_pages,
        }
    }

    /// Largest supported context (largest decode bucket).
    pub fn max_context(&self) -> usize {
        self.manifest.max_context(self.mode_str)
    }

    fn ensure_compiled(&mut self, name: &str) -> anyhow::Result<ExecId> {
        if let Some(&id) = self.execs.get(name) {
            return Ok(id);
        }
        let id = self.backend.load_exec(&self.manifest, name)?;
        self.execs.insert(name.to_string(), id);
        self.stats.compiles += 1;
        Ok(id)
    }

    /// Execute an arbitrary artifact with explicit (non-weight) args —
    /// used by the kernel benches.
    pub fn execute_kernel(
        &mut self,
        name: &str,
        args: &[BufId],
    ) -> anyhow::Result<Vec<Vec<f32>>> {
        let exec = self.ensure_compiled(name)?;
        self.backend.execute(exec, args)
    }

    /// One decode step for `items` = (sequence, input token) pairs. Appends
    /// the new KV entries to `cache` and returns next-token logits per item.
    pub fn decode(
        &mut self,
        cache: &mut PagedKvCache,
        items: &[(SeqHandle, i32)],
    ) -> anyhow::Result<DecodeResult> {
        anyhow::ensure!(!items.is_empty(), "empty decode batch");
        let m = &self.manifest.model;
        let (l, d_c, d_r, vocab) = (m.n_layers, m.d_c, m.d_r, m.vocab);
        let max_ctx = items
            .iter()
            .map(|&(s, _)| cache.tokens_of(s) + 1)
            .max()
            .unwrap();
        let bucket = self
            .manifest
            .decode_bucket(self.mode_str, items.len(), max_ctx)
            .ok_or_else(|| {
                anyhow::anyhow!(
                    "no decode bucket for batch {} ctx {max_ctx} ({})",
                    items.len(),
                    self.mode_str
                )
            })?;
        let (bb, ss, name) = (bucket.batch, bucket.seq, bucket.name.clone());
        let exec = self.ensure_compiled(&name)?;

        // ---- stage inputs ---------------------------------------------------
        let t0 = Instant::now();
        let mut token_ids = vec![0i32; bb];
        let mut positions = vec![0i32; bb];
        for (i, &(seq, tok)) in items.iter().enumerate() {
            token_ids[i] = tok;
            positions[i] = cache.tokens_of(seq) as i32;
        }
        let fp8 = self.mode == CacheMode::Fp8;
        let mut k_c = vec![0.0f32; l * bb * ss * d_c];
        let mut k_r = vec![0.0f32; l * bb * ss * d_r];
        let mut sigma = vec![1.0f32; l * bb * ss];
        for (b, &(seq, _)) in items.iter().enumerate() {
            for layer in 0..l {
                let off = (layer * bb + b) * ss;
                cache.gather_kernel_view(
                    seq,
                    layer,
                    ss,
                    &mut k_c[off * d_c..(off + ss) * d_c],
                    &mut k_r[off * d_r..(off + ss) * d_r],
                    &mut sigma[off..off + ss],
                );
            }
        }
        // step buffers are freed on every exit path (incl. failed uploads)
        let mut step_bufs: Vec<BufId> = Vec::new();
        let staged = {
            let backend = self.backend.as_mut();
            let bufs = &mut step_bufs;
            let mut stage = || -> anyhow::Result<()> {
                bufs.push(backend.upload_i32(&token_ids, &[bb, 1])?);
                bufs.push(backend.upload_i32(&positions, &[bb])?);
                bufs.push(backend.upload_f32(&k_c, &[l, bb, ss, d_c])?);
                bufs.push(backend.upload_f32(&k_r, &[l, bb, ss, d_r])?);
                if fp8 {
                    bufs.push(backend.upload_f32(&sigma, &[l, bb, ss, 1])?);
                }
                Ok(())
            };
            stage()
        };
        if let Err(e) = staged {
            for id in step_bufs {
                self.backend.free(id);
            }
            return Err(e);
        }
        self.stats.gather_s += t0.elapsed().as_secs_f64();

        // ---- execute --------------------------------------------------------
        let t1 = Instant::now();
        let mut args: Vec<BufId> = self.weight_bufs.clone();
        args.extend(&step_bufs);
        let result = self.backend.execute(exec, &args);
        for id in step_bufs {
            self.backend.free(id);
        }
        let outs = result?;
        self.stats.execute_s += t1.elapsed().as_secs_f64();
        anyhow::ensure!(outs.len() == if fp8 { 4 } else { 3 }, "bad output arity");

        // ---- append new KV entries + collect logits -------------------------
        let t2 = Instant::now();
        let logits_flat = &outs[0]; // [bb, 1, vocab]
        let new_kc = &outs[1]; // [l, bb, 1, d_c]
        let new_kr = &outs[2]; // [l, bb, 1, d_r]
        let mut logits = Vec::with_capacity(items.len());
        let mut kc_tok = vec![0.0f32; l * d_c];
        let mut kr_tok = vec![0.0f32; l * d_r];
        for (b, &(seq, _)) in items.iter().enumerate() {
            for layer in 0..l {
                let src = (layer * bb + b) * d_c;
                kc_tok[layer * d_c..(layer + 1) * d_c]
                    .copy_from_slice(&new_kc[src..src + d_c]);
                let src = (layer * bb + b) * d_r;
                kr_tok[layer * d_r..(layer + 1) * d_r]
                    .copy_from_slice(&new_kr[src..src + d_r]);
            }
            if fp8 {
                let new_sg = &outs[3]; // [l, bb, 1, 1]
                let sg_tok: Vec<f32> =
                    (0..l).map(|layer| new_sg[layer * bb + b]).collect();
                cache
                    .append_prequantized(seq, &kc_tok, &kr_tok, &sg_tok)
                    .map_err(|e| anyhow::anyhow!("cache append: {e:?}"))?;
            } else {
                cache
                    .append_token(seq, &kc_tok, &kr_tok)
                    .map_err(|e| anyhow::anyhow!("cache append: {e:?}"))?;
            }
            logits.push(logits_flat[b * vocab..(b + 1) * vocab].to_vec());
        }
        self.stats.append_s += t2.elapsed().as_secs_f64();
        self.stats.decode_steps += 1;
        self.stats.decode_tokens += items.len() as u64;
        Ok(DecodeResult { logits })
    }

    /// One mixed step: interleaved prefill-chunk items (sequence, chunk
    /// tokens — appended after the sequence's current cache) and decode
    /// items (sequence, input token) in ONE backend call, so decode never
    /// waits for a separate prefill launch. Every new token's KV lands in
    /// `cache` through the same bit-exact append as `decode`.
    pub fn step_mixed(
        &mut self,
        cache: &mut PagedKvCache,
        chunks: &[(SeqHandle, Vec<i32>)],
        decodes: &[(SeqHandle, i32)],
    ) -> anyhow::Result<MixedResult> {
        anyhow::ensure!(!(chunks.is_empty() && decodes.is_empty()), "empty mixed step");
        let m = &self.manifest.model;
        let (l, d_c, d_r, vocab) = (m.n_layers, m.d_c, m.d_r, m.vocab);
        let n_items = chunks.len() + decodes.len();
        let max_ctx = chunks
            .iter()
            .map(|(s, t)| cache.tokens_of(*s) + t.len())
            .chain(decodes.iter().map(|&(s, _)| cache.tokens_of(s) + 1))
            .max()
            .unwrap();
        let max_chunk = chunks.iter().map(|(_, t)| t.len()).max().unwrap_or(1);
        let bucket = self
            .manifest
            .mixed_bucket(self.mode_str, n_items, max_ctx)
            .ok_or_else(|| {
                anyhow::anyhow!(
                    "no mixed bucket for {n_items} items ctx {max_ctx} ({})",
                    self.mode_str
                )
            })?;
        let (bb, ss, cc, name) = (bucket.batch, bucket.seq, bucket.t_q, bucket.name.clone());
        anyhow::ensure!(
            max_chunk <= cc,
            "prefill chunk {max_chunk} exceeds the mixed bucket cap {cc}"
        );
        let exec = self.ensure_compiled(&name)?;

        // ---- stage inputs: chunk items first, then decode items -------------
        let t0 = Instant::now();
        let mut token_ids = vec![0i32; bb * cc];
        let mut lens = vec![0i32; bb]; // padding rows advance 0 tokens
        let mut positions = vec![0i32; bb];
        let item_seq = |i: usize| -> SeqHandle {
            if i < chunks.len() {
                chunks[i].0
            } else {
                decodes[i - chunks.len()].0
            }
        };
        for (i, (seq, toks)) in chunks.iter().enumerate() {
            token_ids[i * cc..i * cc + toks.len()].copy_from_slice(toks);
            lens[i] = toks.len() as i32;
            positions[i] = cache.tokens_of(*seq) as i32;
        }
        for (k, &(seq, tok)) in decodes.iter().enumerate() {
            let i = chunks.len() + k;
            token_ids[i * cc] = tok;
            lens[i] = 1;
            positions[i] = cache.tokens_of(seq) as i32;
        }
        let fp8 = self.mode == CacheMode::Fp8;
        let mut k_c = vec![0.0f32; l * bb * ss * d_c];
        let mut k_r = vec![0.0f32; l * bb * ss * d_r];
        let mut sigma = vec![1.0f32; l * bb * ss];
        for i in 0..n_items {
            let seq = item_seq(i);
            for layer in 0..l {
                let off = (layer * bb + i) * ss;
                cache.gather_kernel_view(
                    seq,
                    layer,
                    ss,
                    &mut k_c[off * d_c..(off + ss) * d_c],
                    &mut k_r[off * d_r..(off + ss) * d_r],
                    &mut sigma[off..off + ss],
                );
            }
        }
        let mut step_bufs: Vec<BufId> = Vec::new();
        let staged = {
            let backend = self.backend.as_mut();
            let bufs = &mut step_bufs;
            let mut stage = || -> anyhow::Result<()> {
                bufs.push(backend.upload_i32(&token_ids, &[bb, cc])?);
                bufs.push(backend.upload_i32(&lens, &[bb])?);
                bufs.push(backend.upload_i32(&positions, &[bb])?);
                bufs.push(backend.upload_f32(&k_c, &[l, bb, ss, d_c])?);
                bufs.push(backend.upload_f32(&k_r, &[l, bb, ss, d_r])?);
                if fp8 {
                    bufs.push(backend.upload_f32(&sigma, &[l, bb, ss, 1])?);
                }
                Ok(())
            };
            stage()
        };
        if let Err(e) = staged {
            for id in step_bufs {
                self.backend.free(id);
            }
            return Err(e);
        }
        self.stats.gather_s += t0.elapsed().as_secs_f64();

        // ---- execute --------------------------------------------------------
        let t1 = Instant::now();
        let mut args: Vec<BufId> = self.weight_bufs.clone();
        args.extend(&step_bufs);
        let result = self.backend.execute(exec, &args);
        for id in step_bufs {
            self.backend.free(id);
        }
        let outs = result?;
        self.stats.execute_s += t1.elapsed().as_secs_f64();
        anyhow::ensure!(outs.len() == if fp8 { 4 } else { 3 }, "bad output arity");

        // ---- append new KV entries + collect logits -------------------------
        let t2 = Instant::now();
        let logits_flat = &outs[0]; // [bb, vocab]
        let e_kc = &outs[1]; // [l, bb, cc, d_c]
        let e_kr = &outs[2]; // [l, bb, cc, d_r]
        let mut all_logits = Vec::with_capacity(n_items);
        let mut kc_tok = vec![0.0f32; l * d_c];
        let mut kr_tok = vec![0.0f32; l * d_r];
        for i in 0..n_items {
            let seq = item_seq(i);
            let len = lens[i] as usize;
            for k in 0..len {
                for layer in 0..l {
                    let src = ((layer * bb + i) * cc + k) * d_c;
                    kc_tok[layer * d_c..(layer + 1) * d_c]
                        .copy_from_slice(&e_kc[src..src + d_c]);
                    let src = ((layer * bb + i) * cc + k) * d_r;
                    kr_tok[layer * d_r..(layer + 1) * d_r]
                        .copy_from_slice(&e_kr[src..src + d_r]);
                }
                if fp8 {
                    let e_sg = &outs[3]; // [l, bb, cc]
                    let sg_tok: Vec<f32> =
                        (0..l).map(|layer| e_sg[(layer * bb + i) * cc + k]).collect();
                    cache
                        .append_prequantized(seq, &kc_tok, &kr_tok, &sg_tok)
                        .map_err(|e| anyhow::anyhow!("cache append: {e:?}"))?;
                } else {
                    cache
                        .append_token(seq, &kc_tok, &kr_tok)
                        .map_err(|e| anyhow::anyhow!("cache append: {e:?}"))?;
                }
            }
            all_logits.push(logits_flat[i * vocab..(i + 1) * vocab].to_vec());
        }
        self.stats.append_s += t2.elapsed().as_secs_f64();
        self.stats.mixed_steps += 1;
        self.stats.chunk_tokens += chunks.iter().map(|(_, t)| t.len() as u64).sum::<u64>();
        self.stats.decode_tokens += decodes.len() as u64;
        let decode_logits = all_logits.split_off(chunks.len());
        Ok(MixedResult { chunk_logits: all_logits, decode_logits })
    }

    /// One speculative verification step: `items` = (sequence, verify
    /// inputs) where the inputs are the carried next token followed by the
    /// draft proposals. All inputs advance the cache (the caller rolls back
    /// rejected tokens via [`PagedKvCache::rollback_to`]); logits come back
    /// at EVERY advanced position, so one call scores the whole draft run.
    pub fn verify(
        &mut self,
        cache: &mut PagedKvCache,
        items: &[(SeqHandle, Vec<i32>)],
    ) -> anyhow::Result<VerifyResult> {
        anyhow::ensure!(!items.is_empty(), "empty verify batch");
        anyhow::ensure!(items.iter().all(|(_, t)| !t.is_empty()), "verify item with no inputs");
        let m = &self.manifest.model;
        let (l, d_c, d_r, vocab) = (m.n_layers, m.d_c, m.d_r, m.vocab);
        let n_items = items.len();
        let max_ctx = items
            .iter()
            .map(|(s, t)| cache.tokens_of(*s) + t.len())
            .max()
            .unwrap();
        let max_run = items.iter().map(|(_, t)| t.len()).max().unwrap();
        let bucket = self
            .manifest
            .verify_bucket(self.mode_str, n_items, max_ctx)
            .ok_or_else(|| {
                anyhow::anyhow!(
                    "no verify bucket for {n_items} items ctx {max_ctx} ({})",
                    self.mode_str
                )
            })?;
        let (bb, ss, cc, name) = (bucket.batch, bucket.seq, bucket.t_q, bucket.name.clone());
        anyhow::ensure!(max_run <= cc, "verify run {max_run} exceeds the verify bucket cap {cc}");
        let exec = self.ensure_compiled(&name)?;

        // ---- stage inputs ---------------------------------------------------
        let t0 = Instant::now();
        let mut token_ids = vec![0i32; bb * cc];
        let mut lens = vec![0i32; bb]; // padding rows advance 0 tokens
        let mut positions = vec![0i32; bb];
        for (i, (seq, toks)) in items.iter().enumerate() {
            token_ids[i * cc..i * cc + toks.len()].copy_from_slice(toks);
            lens[i] = toks.len() as i32;
            positions[i] = cache.tokens_of(*seq) as i32;
        }
        let fp8 = self.mode == CacheMode::Fp8;
        let mut k_c = vec![0.0f32; l * bb * ss * d_c];
        let mut k_r = vec![0.0f32; l * bb * ss * d_r];
        let mut sigma = vec![1.0f32; l * bb * ss];
        for (i, (seq, _)) in items.iter().enumerate() {
            for layer in 0..l {
                let off = (layer * bb + i) * ss;
                cache.gather_kernel_view(
                    *seq,
                    layer,
                    ss,
                    &mut k_c[off * d_c..(off + ss) * d_c],
                    &mut k_r[off * d_r..(off + ss) * d_r],
                    &mut sigma[off..off + ss],
                );
            }
        }
        let mut step_bufs: Vec<BufId> = Vec::new();
        let staged = {
            let backend = self.backend.as_mut();
            let bufs = &mut step_bufs;
            let mut stage = || -> anyhow::Result<()> {
                bufs.push(backend.upload_i32(&token_ids, &[bb, cc])?);
                bufs.push(backend.upload_i32(&lens, &[bb])?);
                bufs.push(backend.upload_i32(&positions, &[bb])?);
                bufs.push(backend.upload_f32(&k_c, &[l, bb, ss, d_c])?);
                bufs.push(backend.upload_f32(&k_r, &[l, bb, ss, d_r])?);
                if fp8 {
                    bufs.push(backend.upload_f32(&sigma, &[l, bb, ss, 1])?);
                }
                Ok(())
            };
            stage()
        };
        if let Err(e) = staged {
            for id in step_bufs {
                self.backend.free(id);
            }
            return Err(e);
        }
        self.stats.gather_s += t0.elapsed().as_secs_f64();

        // ---- execute --------------------------------------------------------
        let t1 = Instant::now();
        let mut args: Vec<BufId> = self.weight_bufs.clone();
        args.extend(&step_bufs);
        let result = self.backend.execute(exec, &args);
        for id in step_bufs {
            self.backend.free(id);
        }
        let outs = result?;
        self.stats.execute_s += t1.elapsed().as_secs_f64();
        anyhow::ensure!(outs.len() == if fp8 { 4 } else { 3 }, "bad output arity");

        // ---- append new KV entries + collect per-position logits ------------
        let t2 = Instant::now();
        let logits_flat = &outs[0]; // [bb, cc, vocab]
        let e_kc = &outs[1]; // [l, bb, cc, d_c]
        let e_kr = &outs[2]; // [l, bb, cc, d_r]
        let mut all_logits = Vec::with_capacity(n_items);
        let mut kc_tok = vec![0.0f32; l * d_c];
        let mut kr_tok = vec![0.0f32; l * d_r];
        for (i, (seq, toks)) in items.iter().enumerate() {
            let mut item_logits = Vec::with_capacity(toks.len());
            for k in 0..toks.len() {
                for layer in 0..l {
                    let src = ((layer * bb + i) * cc + k) * d_c;
                    kc_tok[layer * d_c..(layer + 1) * d_c]
                        .copy_from_slice(&e_kc[src..src + d_c]);
                    let src = ((layer * bb + i) * cc + k) * d_r;
                    kr_tok[layer * d_r..(layer + 1) * d_r]
                        .copy_from_slice(&e_kr[src..src + d_r]);
                }
                if fp8 {
                    let e_sg = &outs[3]; // [l, bb, cc]
                    let sg_tok: Vec<f32> =
                        (0..l).map(|layer| e_sg[(layer * bb + i) * cc + k]).collect();
                    cache
                        .append_prequantized(*seq, &kc_tok, &kr_tok, &sg_tok)
                        .map_err(|e| anyhow::anyhow!("cache append: {e:?}"))?;
                } else {
                    cache
                        .append_token(*seq, &kc_tok, &kr_tok)
                        .map_err(|e| anyhow::anyhow!("cache append: {e:?}"))?;
                }
                let off = (i * cc + k) * vocab;
                item_logits.push(logits_flat[off..off + vocab].to_vec());
            }
            self.stats.verify_tokens += toks.len() as u64;
            all_logits.push(item_logits);
        }
        self.stats.append_s += t2.elapsed().as_secs_f64();
        self.stats.verify_calls += 1;
        Ok(VerifyResult { logits: all_logits })
    }

    /// Prefill `items` = (sequence, prompt tokens). Appends all prompt KV
    /// entries to `cache`; returns last-token logits per item.
    pub fn prefill(
        &mut self,
        cache: &mut PagedKvCache,
        items: &[(SeqHandle, Vec<i32>)],
    ) -> anyhow::Result<PrefillResult> {
        anyhow::ensure!(!items.is_empty(), "empty prefill batch");
        let m = &self.manifest.model;
        let (l, d_c, d_r, vocab) = (m.n_layers, m.d_c, m.d_r, m.vocab);
        let max_p = items.iter().map(|(_, p)| p.len()).max().unwrap();
        let bucket = self
            .manifest
            .prefill_bucket(self.mode_str, items.len(), max_p)
            .ok_or_else(|| {
                anyhow::anyhow!("no prefill bucket for batch {} prompt {max_p}", items.len())
            })?;
        let (bb, pp, name) = (bucket.batch, bucket.seq, bucket.name.clone());
        let exec = self.ensure_compiled(&name)?;

        let t0 = Instant::now();
        let mut token_ids = vec![0i32; bb * pp];
        let mut plens = vec![1i32; bb]; // dummy rows use plen 1
        for (i, (_, prompt)) in items.iter().enumerate() {
            token_ids[i * pp..i * pp + prompt.len()].copy_from_slice(prompt);
            plens[i] = prompt.len() as i32;
        }
        let tok_buf = self.backend.upload_i32(&token_ids, &[bb, pp])?;
        let len_buf = match self.backend.upload_i32(&plens, &[bb]) {
            Ok(id) => id,
            Err(e) => {
                self.backend.free(tok_buf);
                return Err(e);
            }
        };
        self.stats.gather_s += t0.elapsed().as_secs_f64();

        let t1 = Instant::now();
        let mut args: Vec<BufId> = self.weight_bufs.clone();
        args.push(tok_buf);
        args.push(len_buf);
        let result = self.backend.execute(exec, &args);
        self.backend.free(tok_buf);
        self.backend.free(len_buf);
        let outs = result?;
        self.stats.execute_s += t1.elapsed().as_secs_f64();
        let fp8 = self.mode == CacheMode::Fp8;
        anyhow::ensure!(outs.len() == if fp8 { 4 } else { 3 }, "bad output arity");

        let t2 = Instant::now();
        let last_logits = &outs[0]; // [bb, vocab]
        let e_kc = &outs[1]; // [l, bb, pp, d_c]
        let e_kr = &outs[2]; // [l, bb, pp, d_r]
        let mut logits = Vec::with_capacity(items.len());
        let mut kc_tok = vec![0.0f32; l * d_c];
        let mut kr_tok = vec![0.0f32; l * d_r];
        for (b, (seq, prompt)) in items.iter().enumerate() {
            for t in 0..prompt.len() {
                for layer in 0..l {
                    let src = ((layer * bb + b) * pp + t) * d_c;
                    kc_tok[layer * d_c..(layer + 1) * d_c]
                        .copy_from_slice(&e_kc[src..src + d_c]);
                    let src = ((layer * bb + b) * pp + t) * d_r;
                    kr_tok[layer * d_r..(layer + 1) * d_r]
                        .copy_from_slice(&e_kr[src..src + d_r]);
                }
                if fp8 {
                    let e_sg = &outs[3]; // [l, bb, pp, 1]
                    let sg_tok: Vec<f32> = (0..l)
                        .map(|layer| e_sg[(layer * bb + b) * pp + t])
                        .collect();
                    cache
                        .append_prequantized(*seq, &kc_tok, &kr_tok, &sg_tok)
                        .map_err(|e| anyhow::anyhow!("cache append: {e:?}"))?;
                } else {
                    cache
                        .append_token(*seq, &kc_tok, &kr_tok)
                        .map_err(|e| anyhow::anyhow!("cache append: {e:?}"))?;
                }
            }
            logits.push(last_logits[b * vocab..(b + 1) * vocab].to_vec());
            self.stats.prefill_tokens += prompt.len() as u64;
        }
        self.stats.append_s += t2.elapsed().as_secs_f64();
        self.stats.prefill_calls += 1;
        Ok(PrefillResult { logits })
    }
}

/// Kernel-artifact argument staging (shared by benches): builds the buffers
/// for a `kernel_snapmla_*` / `kernel_flashmla_*` artifact invocation.
pub struct KernelArgs {
    pub bufs: Vec<BufId>,
}

impl KernelArgs {
    #[allow(clippy::too_many_arguments)]
    pub fn snapmla(
        backend: &mut dyn ExecBackend,
        t_q: usize,
        heads: usize,
        d_c: usize,
        d_r: usize,
        n: usize,
        length: usize,
        seed: u64,
    ) -> anyhow::Result<KernelArgs> {
        use crate::util::rng::Rng;
        let mut rng = Rng::new(seed);
        let q_c = rng.normal_vec(t_q * heads * d_c, 1.0);
        let q_r = rng.normal_vec(t_q * heads * d_r, 0.3);
        let sq = vec![0.01f32; t_q * heads];
        let k_c = rng.normal_vec(n * d_c, 1.0);
        let k_r = rng.normal_vec(n * d_r, 0.3);
        let sk = vec![0.02f32; n];
        Ok(KernelArgs {
            bufs: vec![
                backend.upload_f32(&q_c, &[t_q, heads, d_c])?,
                backend.upload_f32(&q_r, &[t_q, heads, d_r])?,
                backend.upload_f32(&sq, &[t_q, heads, 1])?,
                backend.upload_f32(&k_c, &[n, d_c])?,
                backend.upload_f32(&k_r, &[n, d_r])?,
                backend.upload_f32(&sk, &[n, 1])?,
                backend.upload_i32(&[length as i32], &[1])?,
            ],
        })
    }

    #[allow(clippy::too_many_arguments)]
    pub fn flashmla(
        backend: &mut dyn ExecBackend,
        t_q: usize,
        heads: usize,
        d_c: usize,
        d_r: usize,
        n: usize,
        length: usize,
        seed: u64,
    ) -> anyhow::Result<KernelArgs> {
        use crate::util::rng::Rng;
        let mut rng = Rng::new(seed);
        let q_c = rng.normal_vec(t_q * heads * d_c, 1.0);
        let q_r = rng.normal_vec(t_q * heads * d_r, 0.3);
        let k_c = rng.normal_vec(n * d_c, 1.0);
        let k_r = rng.normal_vec(n * d_r, 0.3);
        Ok(KernelArgs {
            bufs: vec![
                backend.upload_f32(&q_c, &[t_q, heads, d_c])?,
                backend.upload_f32(&q_r, &[t_q, heads, d_r])?,
                backend.upload_f32(&k_c, &[n, d_c])?,
                backend.upload_f32(&k_r, &[n, d_r])?,
                backend.upload_i32(&[length as i32], &[1])?,
            ],
        })
    }

    /// Release the staged buffers.
    pub fn release(self, backend: &mut dyn ExecBackend) {
        for id in self.bufs {
            backend.free(id);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn sim_engine_loads_and_reports_buckets() {
        let eng = ModelEngine::sim(CacheMode::Fp8).unwrap();
        assert_eq!(eng.backend_name(), "sim");
        assert_eq!(eng.mode_str(), "fp8");
        assert_eq!(eng.max_context(), 2048);
        let cfg = eng.cache_config(16);
        assert_eq!(cfg.n_layers, eng.manifest.model.n_layers);
        assert_eq!(cfg.capacity_pages, 16);
    }

    #[test]
    fn auto_falls_back_to_sim_without_artifacts() {
        let eng = ModelEngine::auto(Path::new("/definitely/not/there"), CacheMode::Bf16).unwrap();
        assert_eq!(eng.backend_name(), "sim");
    }

    #[test]
    fn mixed_step_is_chunk_schedule_invariant() {
        // the same token stream fed as (3+2)-token chunks or one 5-token
        // chunk must produce identical cache state and logits — chunked
        // prefill runs per-token decode math, so chunk boundaries are
        // numerically irrelevant (preemption/resume correctness rests on
        // this)
        let toks = vec![1, 70, 71, 70, 71];
        let mut eng_a = ModelEngine::sim(CacheMode::Fp8).unwrap();
        let mut cache_a = PagedKvCache::new(eng_a.cache_config(8));
        cache_a.register(1);
        let r1 = eng_a.step_mixed(&mut cache_a, &[(1, toks[..3].to_vec())], &[]).unwrap();
        assert_eq!(r1.chunk_logits.len(), 1);
        let r2 = eng_a.step_mixed(&mut cache_a, &[(1, toks[3..].to_vec())], &[]).unwrap();

        let mut eng_b = ModelEngine::sim(CacheMode::Fp8).unwrap();
        let mut cache_b = PagedKvCache::new(eng_b.cache_config(8));
        cache_b.register(1);
        let rb = eng_b.step_mixed(&mut cache_b, &[(1, toks.clone())], &[]).unwrap();

        assert_eq!(cache_a.tokens_of(1), 5);
        assert_eq!(cache_b.tokens_of(1), 5);
        assert_eq!(r2.chunk_logits[0], rb.chunk_logits[0]);

        // and a follow-up decode sees identical state on both
        let da = eng_a.decode(&mut cache_a, &[(1, 70)]).unwrap();
        let db = eng_b.decode(&mut cache_b, &[(1, 70)]).unwrap();
        assert_eq!(da.logits[0], db.logits[0]);
    }

    #[test]
    fn mixed_step_interleaves_chunks_and_decodes() {
        let mut eng = ModelEngine::sim(CacheMode::Fp8).unwrap();
        let mut cache = PagedKvCache::new(eng.cache_config(16));
        // seq 1 decodes while seq 2 chunk-prefills in the SAME call
        cache.register(1);
        eng.step_mixed(&mut cache, &[(1, vec![1, 70, 71, 70])], &[]).unwrap();
        cache.register(2);
        let out = eng
            .step_mixed(&mut cache, &[(2, vec![1, 90, 91])], &[(1, 71)])
            .unwrap();
        assert_eq!(out.chunk_logits.len(), 1);
        assert_eq!(out.decode_logits.len(), 1);
        assert_eq!(cache.tokens_of(1), 5);
        assert_eq!(cache.tokens_of(2), 3);
        assert!(out.decode_logits[0].iter().all(|x| x.is_finite()));

        // the interleaved decode matches a pure decode step from the same
        // cache state
        let mut eng2 = ModelEngine::sim(CacheMode::Fp8).unwrap();
        let mut cache2 = PagedKvCache::new(eng2.cache_config(16));
        cache2.register(1);
        eng2.step_mixed(&mut cache2, &[(1, vec![1, 70, 71, 70])], &[]).unwrap();
        let pure = eng2.decode(&mut cache2, &[(1, 71)]).unwrap();
        assert_eq!(out.decode_logits[0], pure.logits[0]);
        assert_eq!(eng.stats.mixed_steps, 2);
        assert_eq!(eng.stats.chunk_tokens, 7);
    }

    #[test]
    fn variant_engines_preserve_induction_semantics() {
        // the hand-constructed circuit's logit margins (>2 nats) dominate
        // every variant's quantization noise, so greedy decode agrees
        for variant in VariantKind::ALL {
            let mut eng = EngineBuilder::new(CacheMode::Fp8).kernel(variant).build().unwrap();
            let mut cache = PagedKvCache::new(eng.cache_config(8));
            cache.register(1);
            eng.prefill(&mut cache, &[(1, vec![1, 70, 71, 70])]).unwrap();
            let r = eng.decode(&mut cache, &[(1, 71)]).unwrap();
            let best = r.logits[0]
                .iter()
                .enumerate()
                .max_by(|a, b| a.1.partial_cmp(b.1).unwrap())
                .unwrap()
                .0;
            assert_eq!(best, 70, "{variant:?}: induction should predict the successor");
        }
    }

    #[test]
    fn builder_configures_draft_window() {
        let history = [70, 71, 9, 70];
        let eng = ModelEngine::builder(CacheMode::Fp8).draft_window(2).build().unwrap();
        assert_eq!(eng.draft.draft(&history, 1), vec![70]); // window misses the pair
        let eng = ModelEngine::sim(CacheMode::Fp8).unwrap();
        assert_eq!(eng.draft.draft(&history, 1), vec![71]); // full MTP recalls it
    }

    #[test]
    fn verify_matches_stepwise_decode() {
        // one verify call over [next, d0, d1] must equal three decode steps:
        // same per-position logits, same final cache state
        let inputs = vec![70i32, 71, 70];
        let mut eng_v = ModelEngine::sim(CacheMode::Fp8).unwrap();
        let mut cache_v = PagedKvCache::new(eng_v.cache_config(8));
        cache_v.register(1);
        eng_v.prefill(&mut cache_v, &[(1, vec![1, 70, 71, 70])]).unwrap();
        let v = eng_v.verify(&mut cache_v, &[(1, inputs.clone())]).unwrap();
        assert_eq!(v.logits[0].len(), 3);

        let mut eng_d = ModelEngine::sim(CacheMode::Fp8).unwrap();
        let mut cache_d = PagedKvCache::new(eng_d.cache_config(8));
        cache_d.register(1);
        eng_d.prefill(&mut cache_d, &[(1, vec![1, 70, 71, 70])]).unwrap();
        for (k, &tok) in inputs.iter().enumerate() {
            let d = eng_d.decode(&mut cache_d, &[(1, tok)]).unwrap();
            assert_eq!(v.logits[0][k], d.logits[0], "position {k}");
        }
        assert_eq!(cache_v.tokens_of(1), cache_d.tokens_of(1));
        assert_eq!(eng_v.stats.verify_calls, 1);
        assert_eq!(eng_v.stats.verify_tokens, 3);
    }

    #[test]
    fn decode_roundtrip_updates_cache() {
        let mut eng = ModelEngine::sim(CacheMode::Fp8).unwrap();
        let mut cache = PagedKvCache::new(eng.cache_config(8));
        cache.register(1);
        let out = eng.prefill(&mut cache, &[(1, vec![1, 70, 71, 70])]).unwrap();
        assert_eq!(out.logits[0].len(), eng.manifest.model.vocab);
        assert_eq!(cache.tokens_of(1), 4);
        let r = eng.decode(&mut cache, &[(1, 71)]).unwrap();
        assert!(r.logits[0].iter().all(|x| x.is_finite()));
        assert_eq!(cache.tokens_of(1), 5);
        assert_eq!(eng.stats.decode_steps, 1);
        assert_eq!(eng.stats.prefill_calls, 1);
    }
}
