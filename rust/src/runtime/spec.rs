//! The speculative draft model.
//!
//! A deterministic, weight-free drafter playing the MTP-head role: it
//! proposes `draft_len` continuation tokens from the token history alone,
//! using the same induction rule the sim model's constructed circuit
//! implements — predict the token that followed the most recent previous
//! occurrence of the current token, falling back to repeating it. On
//! induction-friendly streams the target model's greedy argmax agrees with
//! the drafter almost always, so verification accepts long runs; the
//! `window` knob truncates the history the drafter sees, degrading its
//! fidelity (and the acceptance rate) in a controlled, deterministic way.

/// Proposes draft tokens for speculative decoding.
#[derive(Clone, Copy, Debug)]
pub struct DraftModel {
    /// History tokens the drafter may look back over. `usize::MAX` = the
    /// full context (MTP-grade fidelity); small windows miss induction
    /// pairs and drive the acceptance rate down.
    window: usize,
}

impl Default for DraftModel {
    fn default() -> DraftModel {
        DraftModel::mtp()
    }
}

impl DraftModel {
    /// Full-context drafter (the DeepSeek-style MTP-head stand-in).
    pub fn mtp() -> DraftModel {
        DraftModel { window: usize::MAX }
    }

    /// A drafter that only sees the trailing `window` history tokens.
    pub fn with_window(window: usize) -> DraftModel {
        assert!(window >= 1, "drafter needs at least the current token");
        DraftModel { window }
    }

    /// Propose `draft_len` tokens continuing `history` (prompt + generated
    /// so far, ending with the token about to be fed to the target model).
    /// Pure and deterministic; an empty history drafts nothing.
    pub fn draft(&self, history: &[i32], draft_len: usize) -> Vec<i32> {
        if history.is_empty() {
            return Vec::new();
        }
        let start = history.len().saturating_sub(self.window);
        let mut h: Vec<i32> = history[start..].to_vec();
        let mut out = Vec::with_capacity(draft_len);
        for _ in 0..draft_len {
            let cur = *h.last().unwrap();
            // induction rule: the successor of the last previous occurrence
            let next = h[..h.len() - 1]
                .iter()
                .rposition(|&t| t == cur)
                .map(|i| h[i + 1])
                .unwrap_or(cur);
            out.push(next);
            h.push(next);
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn induction_rule_continues_a_period_two_stream() {
        let d = DraftModel::mtp();
        // …70 71 70 71 70 → the rule alternates onward
        assert_eq!(d.draft(&[1, 70, 71, 70, 71, 70], 4), vec![71, 70, 71, 70]);
    }

    #[test]
    fn fallback_repeats_an_unseen_token() {
        let d = DraftModel::mtp();
        assert_eq!(d.draft(&[5], 3), vec![5, 5, 5]);
        assert_eq!(d.draft(&[], 3), Vec::<i32>::new());
    }

    #[test]
    fn window_truncation_loses_induction_pairs() {
        // the pair (70 → 71) sits outside a 2-token window, so the
        // truncated drafter falls back to repetition while the full one
        // recalls the successor
        let history = [70, 71, 9, 70];
        assert_eq!(DraftModel::mtp().draft(&history, 1), vec![71]);
        assert_eq!(DraftModel::with_window(2).draft(&history, 1), vec![70]);
    }

    #[test]
    fn drafting_is_deterministic() {
        let d = DraftModel::mtp();
        let history = [1, 70, 71, 70];
        assert_eq!(d.draft(&history, 3), d.draft(&history, 3));
    }
}
