//! PJRT execution backend (cargo feature `pjrt`): wraps the `xla` crate's
//! PJRT CPU client behind [`super::backend::ExecBackend`].
//!
//! Interchange is HLO *text* (xla_extension 0.5.1 rejects jax≥0.5 protos —
//! see DESIGN.md). Executables are compiled once per artifact and cached by
//! the engine; weights live on device as `PjRtBuffer`s and are passed by
//! handle to `execute`, so the request path never re-uploads them.
//!
//! Offline builds compile this module against the in-repo `third_party/
//! xla-stub` crate, which type-checks the full surface and fails at runtime;
//! point the `xla` path dependency at a real xla-rs checkout to execute AOT
//! artifacts for real.

use super::backend::{BufId, ExecBackend, ExecId, Slots};
use super::manifest::Manifest;
use crate::anyhow;
use std::path::Path;

/// Thin wrapper around the `xla` crate's PJRT CPU client.
pub struct Runtime {
    pub client: xla::PjRtClient,
}

impl Runtime {
    pub fn cpu() -> anyhow::Result<Runtime> {
        Ok(Runtime { client: xla::PjRtClient::cpu()? })
    }

    /// Load + compile one HLO-text artifact.
    pub fn load_hlo(&self, path: &Path) -> anyhow::Result<xla::PjRtLoadedExecutable> {
        let proto = xla::HloModuleProto::from_text_file(
            path.to_str().ok_or_else(|| anyhow::anyhow!("non-utf8 path"))?,
        )?;
        let comp = xla::XlaComputation::from_proto(&proto);
        Ok(self.client.compile(&comp)?)
    }

    /// Host f32 data → device buffer with the given dims.
    pub fn buf_f32(&self, data: &[f32], dims: &[usize]) -> anyhow::Result<xla::PjRtBuffer> {
        Ok(self.client.buffer_from_host_buffer(data, dims, None)?)
    }

    /// Host i32 data → device buffer with the given dims.
    pub fn buf_i32(&self, data: &[i32], dims: &[usize]) -> anyhow::Result<xla::PjRtBuffer> {
        Ok(self.client.buffer_from_host_buffer(data, dims, None)?)
    }

    /// Execute with buffer args; returns the flattened output tuple as
    /// host-side f32 vectors (all our model outputs are f32).
    pub fn run_to_f32(
        &self,
        exe: &xla::PjRtLoadedExecutable,
        args: &[&xla::PjRtBuffer],
    ) -> anyhow::Result<Vec<Vec<f32>>> {
        let result = exe.execute_b(args)?[0][0].to_literal_sync()?;
        let outs = result.to_tuple()?;
        outs.iter()
            .map(|l| Ok(l.to_vec::<f32>()?))
            .collect::<anyhow::Result<Vec<_>>>()
    }
}

/// [`ExecBackend`] over the PJRT runtime.
pub struct PjrtBackend {
    rt: Runtime,
    bufs: Slots<xla::PjRtBuffer>,
    execs: Vec<xla::PjRtLoadedExecutable>,
}

impl PjrtBackend {
    pub fn cpu() -> anyhow::Result<PjrtBackend> {
        Ok(PjrtBackend { rt: Runtime::cpu()?, bufs: Slots::new(), execs: Vec::new() })
    }

    fn buf(&self, id: BufId) -> anyhow::Result<&xla::PjRtBuffer> {
        self.bufs.get(id).ok_or_else(|| anyhow::anyhow!("pjrt: unknown buffer {id}"))
    }
}

impl ExecBackend for PjrtBackend {
    fn name(&self) -> &'static str {
        "pjrt"
    }

    fn upload_f32(&mut self, data: &[f32], dims: &[usize]) -> anyhow::Result<BufId> {
        let buf = self.rt.buf_f32(data, dims)?;
        Ok(self.bufs.insert(buf))
    }

    fn upload_i32(&mut self, data: &[i32], dims: &[usize]) -> anyhow::Result<BufId> {
        let buf = self.rt.buf_i32(data, dims)?;
        Ok(self.bufs.insert(buf))
    }

    fn download_f32(&mut self, buf: BufId) -> anyhow::Result<Vec<f32>> {
        let lit = self.buf(buf)?.to_literal_sync()?;
        Ok(lit.to_vec::<f32>()?)
    }

    fn free(&mut self, buf: BufId) {
        self.bufs.remove(buf);
    }

    fn load_exec(&mut self, manifest: &Manifest, name: &str) -> anyhow::Result<ExecId> {
        let exe = self.rt.load_hlo(&manifest.hlo_path(name))?;
        self.execs.push(exe);
        Ok(self.execs.len() - 1)
    }

    fn execute(&mut self, exec: ExecId, args: &[BufId]) -> anyhow::Result<Vec<Vec<f32>>> {
        let exe = self
            .execs
            .get(exec)
            .ok_or_else(|| anyhow::anyhow!("pjrt: unknown executable {exec}"))?;
        let refs: Vec<&xla::PjRtBuffer> =
            args.iter().map(|&id| self.buf(id)).collect::<anyhow::Result<_>>()?;
        self.rt.run_to_f32(exe, &refs)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    // These exercise the real PJRT runtime; under the offline xla stub they
    // would fail at runtime, so they are ignored by default. Run with a real
    // xla-rs checkout via `cargo test --features pjrt -- --ignored`.
    #[test]
    #[ignore = "requires a real PJRT runtime (xla stub fails at runtime)"]
    fn buffer_roundtrip() {
        let rt = Runtime::cpu().unwrap();
        let b = rt.buf_f32(&[1.0, 2.0, 3.0, 4.0], &[2, 2]).unwrap();
        let lit = b.to_literal_sync().unwrap();
        assert_eq!(lit.to_vec::<f32>().unwrap(), vec![1.0, 2.0, 3.0, 4.0]);
    }

    #[test]
    #[ignore = "requires a real PJRT runtime (xla stub fails at runtime)"]
    fn wrong_dims_rejected() {
        let rt = Runtime::cpu().unwrap();
        assert!(rt.buf_f32(&[1.0, 2.0, 3.0], &[2, 2]).is_err());
    }
}
