//! Thin wrapper around the `xla` crate's PJRT CPU client.
//!
//! Interchange is HLO *text* (xla_extension 0.5.1 rejects jax≥0.5 protos —
//! see DESIGN.md). Executables are compiled once per artifact and cached by
//! the engine; weights live on device as `PjRtBuffer`s and are passed by
//! reference to `execute_b`, so the request path never re-uploads them.

use std::path::Path;

pub struct Runtime {
    pub client: xla::PjRtClient,
}

impl Runtime {
    pub fn cpu() -> anyhow::Result<Runtime> {
        Ok(Runtime { client: xla::PjRtClient::cpu()? })
    }

    /// Load + compile one HLO-text artifact.
    pub fn load_hlo(&self, path: &Path) -> anyhow::Result<xla::PjRtLoadedExecutable> {
        let proto = xla::HloModuleProto::from_text_file(
            path.to_str().ok_or_else(|| anyhow::anyhow!("non-utf8 path"))?,
        )?;
        let comp = xla::XlaComputation::from_proto(&proto);
        Ok(self.client.compile(&comp)?)
    }

    /// Host f32 data → device buffer with the given dims.
    pub fn buf_f32(&self, data: &[f32], dims: &[usize]) -> anyhow::Result<xla::PjRtBuffer> {
        Ok(self.client.buffer_from_host_buffer(data, dims, None)?)
    }

    /// Host i32 data → device buffer with the given dims.
    pub fn buf_i32(&self, data: &[i32], dims: &[usize]) -> anyhow::Result<xla::PjRtBuffer> {
        Ok(self.client.buffer_from_host_buffer(data, dims, None)?)
    }

    /// Execute with buffer args; returns the flattened output tuple as
    /// host-side f32 vectors (all our model outputs are f32).
    pub fn run_to_f32(
        &self,
        exe: &xla::PjRtLoadedExecutable,
        args: &[&xla::PjRtBuffer],
    ) -> anyhow::Result<Vec<Vec<f32>>> {
        let result = exe.execute_b(args)?[0][0].to_literal_sync()?;
        let outs = result.to_tuple()?;
        outs.iter()
            .map(|l| Ok(l.to_vec::<f32>()?))
            .collect::<anyhow::Result<Vec<_>>>()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn buffer_roundtrip() {
        let rt = Runtime::cpu().unwrap();
        let b = rt.buf_f32(&[1.0, 2.0, 3.0, 4.0], &[2, 2]).unwrap();
        let lit = b.to_literal_sync().unwrap();
        assert_eq!(lit.to_vec::<f32>().unwrap(), vec![1.0, 2.0, 3.0, 4.0]);
    }

    #[test]
    fn wrong_dims_rejected() {
        let rt = Runtime::cpu().unwrap();
        assert!(rt.buf_f32(&[1.0, 2.0, 3.0], &[2, 2]).is_err());
    }
}
