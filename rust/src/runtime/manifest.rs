//! artifacts/manifest.json — the contract between `python/compile/aot.py`
//! and the rust runtime (model dims, artifact shapes, flattened param order).

use crate::anyhow;
use crate::util::json::Json;
use std::collections::BTreeMap;
use std::path::{Path, PathBuf};

#[derive(Clone, Debug)]
pub struct ModelMeta {
    pub vocab: usize,
    pub d_model: usize,
    pub n_layers: usize,
    pub n_heads: usize,
    pub d_c: usize,
    pub d_r: usize,
    pub d_ffn: usize,
    pub sm_scale: f64,
    pub params: usize,
    pub eos: i32,
    pub bos: i32,
}

#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ArtifactKind {
    Decode,
    Prefill,
    /// one step over interleaved prefill-chunk and decode items: `batch`
    /// items, each advancing 1..=`t_q` tokens against a `seq`-long cache
    Mixed,
    /// speculative verification: like `Mixed`, but emits logits at EVERY
    /// advanced position (`t_q` = max draft inputs per item), so one call
    /// scores a whole draft run
    Verify,
    Kernel,
}

#[derive(Clone, Debug)]
pub struct ArtifactInfo {
    pub name: String,
    pub kind: ArtifactKind,
    /// "fp8" | "bf16" for model artifacts; kernel name for kernels
    pub mode: String,
    pub batch: usize,
    /// decode: cache bucket length; prefill: prompt bucket; kernel: seq
    pub seq: usize,
    pub heads: usize,
    pub t_q: usize,
}

#[derive(Debug)]
pub struct Manifest {
    pub dir: PathBuf,
    pub model: ModelMeta,
    pub param_order: Vec<String>,
    pub artifacts: BTreeMap<String, ArtifactInfo>,
}

impl Manifest {
    pub fn load(dir: &Path) -> anyhow::Result<Manifest> {
        let j = Json::from_file(&dir.join("manifest.json"))?;
        let need = |path: &[&str]| -> anyhow::Result<f64> {
            j.at(path)
                .and_then(|v| v.as_f64())
                .ok_or_else(|| anyhow::anyhow!("manifest missing {path:?}"))
        };
        let model = ModelMeta {
            vocab: need(&["model", "vocab"])? as usize,
            d_model: need(&["model", "d_model"])? as usize,
            n_layers: need(&["model", "n_layers"])? as usize,
            n_heads: need(&["model", "n_heads"])? as usize,
            d_c: need(&["model", "d_c"])? as usize,
            d_r: need(&["model", "d_r"])? as usize,
            d_ffn: need(&["model", "d_ffn"])? as usize,
            sm_scale: need(&["model", "sm_scale"])?,
            params: need(&["model", "params"])? as usize,
            eos: need(&["tokens", "eos"])? as i32,
            bos: need(&["tokens", "bos"])? as i32,
        };
        let param_order = j
            .get("param_order")
            .and_then(|v| v.as_arr())
            .ok_or_else(|| anyhow::anyhow!("manifest missing param_order"))?
            .iter()
            .filter_map(|v| v.as_str().map(str::to_string))
            .collect();

        let mut artifacts = BTreeMap::new();
        let arts = j
            .get("artifacts")
            .and_then(|v| v.as_obj())
            .ok_or_else(|| anyhow::anyhow!("manifest missing artifacts"))?;
        for (name, info) in arts {
            let kind = match info.get("kind").and_then(|v| v.as_str()) {
                Some("decode") => ArtifactKind::Decode,
                Some("prefill") => ArtifactKind::Prefill,
                Some("mixed") => ArtifactKind::Mixed,
                Some("verify") => ArtifactKind::Verify,
                Some("kernel") => ArtifactKind::Kernel,
                other => anyhow::bail!("artifact {name}: bad kind {other:?}"),
            };
            let get = |k: &str| info.get(k).and_then(|v| v.as_usize()).unwrap_or(0);
            let mode = info
                .get("mode")
                .or_else(|| info.get("kernel"))
                .and_then(|v| v.as_str())
                .unwrap_or("")
                .to_string();
            artifacts.insert(
                name.clone(),
                ArtifactInfo {
                    name: name.clone(),
                    kind,
                    mode,
                    batch: get("batch").max(1),
                    seq: match kind {
                        ArtifactKind::Prefill => get("prompt"),
                        _ => get("seq"),
                    },
                    heads: get("heads"),
                    t_q: get("t_q").max(1),
                },
            );
        }
        Ok(Manifest { dir: dir.to_path_buf(), model, param_order, artifacts })
    }

    pub fn hlo_path(&self, name: &str) -> PathBuf {
        self.dir.join(format!("{name}.hlo.txt"))
    }

    /// Smallest decode bucket covering (batch, context) in `mode`.
    pub fn decode_bucket(&self, mode: &str, batch: usize, context: usize) -> Option<&ArtifactInfo> {
        self.artifacts
            .values()
            .filter(|a| {
                a.kind == ArtifactKind::Decode
                    && a.mode == mode
                    && a.batch >= batch
                    && a.seq >= context
            })
            .min_by_key(|a| (a.seq, a.batch))
    }

    /// Smallest prefill bucket covering (batch, prompt len) in `mode`.
    pub fn prefill_bucket(&self, mode: &str, batch: usize, prompt: usize) -> Option<&ArtifactInfo> {
        self.artifacts
            .values()
            .filter(|a| {
                a.kind == ArtifactKind::Prefill
                    && a.mode == mode
                    && a.batch >= batch
                    && a.seq >= prompt
            })
            .min_by_key(|a| (a.seq, a.batch))
    }

    /// Smallest mixed-step bucket covering (items, context) in `mode`.
    /// `context` must cover every item's cache length *after* its new
    /// tokens; each item may advance at most `t_q` tokens.
    pub fn mixed_bucket(&self, mode: &str, items: usize, context: usize) -> Option<&ArtifactInfo> {
        self.artifacts
            .values()
            .filter(|a| {
                a.kind == ArtifactKind::Mixed
                    && a.mode == mode
                    && a.batch >= items
                    && a.seq >= context
            })
            .min_by_key(|a| (a.seq, a.batch))
    }

    /// Smallest verify bucket covering (items, context) in `mode`.
    /// `context` must cover every item's cache length *after* its draft
    /// inputs; each item may advance at most `t_q` tokens.
    pub fn verify_bucket(&self, mode: &str, items: usize, context: usize) -> Option<&ArtifactInfo> {
        self.artifacts
            .values()
            .filter(|a| {
                a.kind == ArtifactKind::Verify
                    && a.mode == mode
                    && a.batch >= items
                    && a.seq >= context
            })
            .min_by_key(|a| (a.seq, a.batch))
    }

    /// Largest decode context supported for a mode.
    pub fn max_context(&self, mode: &str) -> usize {
        self.artifacts
            .values()
            .filter(|a| a.kind == ArtifactKind::Decode && a.mode == mode)
            .map(|a| a.seq)
            .max()
            .unwrap_or(0)
    }

    pub fn kernel_artifact(
        &self,
        kernel: &str,
        heads: usize,
        t_q: usize,
        seq: usize,
    ) -> Option<&ArtifactInfo> {
        self.artifacts.values().find(|a| {
            a.kind == ArtifactKind::Kernel
                && a.mode == kernel
                && a.heads == heads
                && a.t_q == t_q
                && a.seq == seq
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// These tests run against the real artifacts when present (CI runs
    /// `make artifacts` first — see Makefile `test` target).
    fn manifest() -> Option<Manifest> {
        let dir = Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
        Manifest::load(&dir).ok()
    }

    #[test]
    fn loads_model_meta() {
        let Some(m) = manifest() else { return };
        assert_eq!(m.model.d_c, 128);
        assert_eq!(m.model.d_r, 32);
        assert_eq!(m.model.n_layers, 8);
        assert!(m.model.params > 20_000_000);
        assert_eq!(m.param_order.len(), 2 + 10 * m.model.n_layers);
        assert_eq!(m.param_order[0], "embed");
    }

    #[test]
    fn bucket_selection() {
        let Some(m) = manifest() else { return };
        let b = m.decode_bucket("fp8", 3, 400).expect("bucket");
        assert!(b.batch >= 3 && b.seq >= 400);
        // smallest covering bucket: batch 4, seq 512
        assert_eq!((b.batch, b.seq), (4, 512));
        assert!(m.decode_bucket("fp8", 9, 512).is_none()); // beyond largest
        let p = m.prefill_bucket("bf16", 1, 64).expect("prefill bucket");
        assert_eq!(p.seq, 128);
    }

    #[test]
    fn kernel_artifacts_present() {
        let Some(m) = manifest() else { return };
        for h in [16, 32, 64, 128] {
            assert!(m.kernel_artifact("snapmla", h, 1, 1024).is_some(), "h{h}");
            assert!(m.kernel_artifact("flashmla", h, 1, 1024).is_some(), "h{h}");
        }
        assert!(m.kernel_artifact("snapmla", 64, 1, 8192).is_some());
    }
}
