//! The execution-backend abstraction: everything `ModelEngine` needs from a
//! device — buffer upload/download, executable loading, step execution —
//! behind one object-safe trait.
//!
//! Two implementations ship:
//! * [`super::sim::SimBackend`] — pure-Rust reference execution through
//!   `mla::ref_attn` / `mla::variant` plus the bit-exact `fp8` quantizers.
//!   No external dependencies; the default build is fully offline.
//! * `super::client::PjrtBackend` (cargo feature `pjrt`) — the PJRT path
//!   that compiles and runs the AOT HLO artifacts via the `xla` crate.
//!
//! Buffers and executables are opaque integer handles so the trait stays
//! object-safe and backends own their device state. Handles are only valid
//! on the backend that issued them.

use super::manifest::Manifest;
use crate::anyhow;

/// Opaque device-buffer handle.
pub type BufId = usize;

/// Opaque loaded-executable handle.
pub type ExecId = usize;

/// A model-execution device.
pub trait ExecBackend {
    /// Human-readable backend name ("sim" / "pjrt").
    fn name(&self) -> &'static str;

    /// Upload host f32 data shaped `dims`; fails on element-count mismatch.
    fn upload_f32(&mut self, data: &[f32], dims: &[usize]) -> anyhow::Result<BufId>;

    /// Upload host i32 data shaped `dims`; fails on element-count mismatch.
    fn upload_i32(&mut self, data: &[i32], dims: &[usize]) -> anyhow::Result<BufId>;

    /// Read a buffer back as f32 (tests / debugging surface).
    fn download_f32(&mut self, buf: BufId) -> anyhow::Result<Vec<f32>>;

    /// Release a buffer. Releasing an unknown/freed handle is a no-op.
    fn free(&mut self, buf: BufId);

    /// Load (and compile, where applicable) the executable for manifest
    /// artifact `name`.
    fn load_exec(&mut self, manifest: &Manifest, name: &str) -> anyhow::Result<ExecId>;

    /// Execute with positional buffer arguments (weights first, in manifest
    /// `param_order`, then the step inputs); returns the flattened f32
    /// output tuple.
    fn execute(&mut self, exec: ExecId, args: &[BufId]) -> anyhow::Result<Vec<Vec<f32>>>;
}

/// Shared handle-table plumbing for backends (slot reuse via a free list).
pub(crate) struct Slots<T> {
    slots: Vec<Option<T>>,
    free: Vec<usize>,
}

impl<T> Default for Slots<T> {
    fn default() -> Slots<T> {
        Slots { slots: Vec::new(), free: Vec::new() }
    }
}

impl<T> Slots<T> {
    pub fn new() -> Slots<T> {
        Slots::default()
    }

    pub fn insert(&mut self, value: T) -> usize {
        if let Some(id) = self.free.pop() {
            self.slots[id] = Some(value);
            id
        } else {
            self.slots.push(Some(value));
            self.slots.len() - 1
        }
    }

    pub fn get(&self, id: usize) -> Option<&T> {
        self.slots.get(id).and_then(|s| s.as_ref())
    }

    pub fn remove(&mut self, id: usize) {
        if id < self.slots.len() && self.slots[id].is_some() {
            self.slots[id] = None;
            self.free.push(id);
        }
    }

    pub fn live(&self) -> usize {
        self.slots.len() - self.free.len()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn slots_reuse_freed_ids() {
        let mut s: Slots<u32> = Slots::new();
        let a = s.insert(10);
        let b = s.insert(20);
        assert_ne!(a, b);
        assert_eq!(s.live(), 2);
        s.remove(a);
        assert_eq!(s.get(a), None);
        assert_eq!(s.live(), 1);
        let c = s.insert(30);
        assert_eq!(c, a, "freed slot must be reused");
        assert_eq!(s.get(c), Some(&30));
        // double-free and unknown ids are no-ops
        s.remove(b);
        s.remove(b);
        s.remove(999);
        assert_eq!(s.live(), 1);
    }
}
