//! `SimBackend` — the offline-first execution backend.
//!
//! Implements [`super::backend::ExecBackend`] entirely in safe, dependency-
//! free Rust: decode/prefill steps run the reference MLA math
//! (`mla::ref_attn` for BF16, the selected `mla::variant` decode pipeline
//! for FP8) over the engine's gathered paged-cache views, with the bit-exact
//! `fp8` quantizers producing the new cache entries; kernel artifacts
//! execute the same paper-shape math the Pallas kernels implement.
//! Everything is deterministic via `util::rng`, so serving runs reproduce
//! exactly.
//!
//! The backend interprets the same artifact names, bucket shapes and
//! positional calling convention as the AOT HLO artifacts, so `ModelEngine`
//! is byte-for-byte agnostic to which backend it drives.

use super::backend::{BufId, ExecBackend, ExecId, Slots};
use super::manifest::{ArtifactInfo, ArtifactKind, Manifest, ModelMeta};
use super::sim_model::{self, DecodeCache, SimParams, SimSpec};
use super::weights::Weights;
use crate::anyhow;
use crate::fp8::bf16_round;
use crate::mla::ref_attn::attention_with_values;
use crate::mla::variant::{QuantCache, VariantKind};
use crate::mla::{Query, Shape};
use std::collections::BTreeMap;
use std::path::PathBuf;

/// Decode/prefill bucket shapes — mirrors `DECODE_BUCKETS`/`PREFILL_BUCKETS`
/// in `python/compile/aot.py` so scheduler behavior matches the PJRT path.
const DECODE_BUCKETS: [(usize, usize); 8] =
    [(1, 128), (4, 128), (8, 128), (1, 512), (4, 512), (8, 512), (4, 2048), (8, 2048)];
const PREFILL_BUCKETS: [(usize, usize); 6] =
    [(1, 32), (4, 32), (8, 32), (1, 128), (4, 128), (8, 128)];
/// Mixed chunked-prefill/decode step buckets mirror the decode shapes; each
/// item advances at most `MIXED_CHUNK` tokens (one KV page) per step.
const MIXED_BUCKETS: [(usize, usize); 8] = DECODE_BUCKETS;
pub const MIXED_CHUNK: usize = 64;
/// Speculative-verify buckets mirror the decode shapes; each item feeds at
/// most `VERIFY_CHUNK` inputs (the carried token + up to 7 draft tokens)
/// and gets logits back at every position.
const VERIFY_BUCKETS: [(usize, usize); 8] = DECODE_BUCKETS;
pub const VERIFY_CHUNK: usize = 8;

/// Paper-shape kernel sweep (heads, t_q, seq) — mirrors `KERNEL_SWEEP`.
fn kernel_sweep() -> Vec<(usize, usize, usize)> {
    let mut sweep = Vec::new();
    for h in [16, 32, 64, 128] {
        for t in [1, 2] {
            sweep.push((h, t, 1024));
        }
    }
    for n in [2048, 4096, 8192] {
        sweep.push((64, 1, n));
    }
    sweep
}

/// Build the in-memory manifest describing the sim model + its "artifacts".
pub fn sim_manifest(spec: &SimSpec) -> Manifest {
    let model = ModelMeta {
        vocab: spec.vocab,
        d_model: spec.d_model,
        n_layers: spec.n_layers,
        n_heads: spec.n_heads,
        d_c: spec.d_c,
        d_r: spec.d_r,
        d_ffn: spec.d_ffn,
        sm_scale: spec.sm_scale(),
        params: spec.param_count(),
        eos: 0,
        bos: 1,
    };
    let param_order: Vec<String> =
        spec.param_shapes().into_iter().map(|(name, _)| name).collect();

    let mut artifacts = BTreeMap::new();
    for mode in ["fp8", "bf16"] {
        for (batch, seq) in DECODE_BUCKETS {
            let name = format!("model_{mode}_decode_b{batch}_s{seq}");
            artifacts.insert(
                name.clone(),
                ArtifactInfo {
                    name,
                    kind: ArtifactKind::Decode,
                    mode: mode.to_string(),
                    batch,
                    seq,
                    heads: spec.n_heads,
                    t_q: 1,
                },
            );
        }
        for (batch, prompt) in PREFILL_BUCKETS {
            let name = format!("model_{mode}_prefill_b{batch}_p{prompt}");
            artifacts.insert(
                name.clone(),
                ArtifactInfo {
                    name,
                    kind: ArtifactKind::Prefill,
                    mode: mode.to_string(),
                    batch,
                    seq: prompt,
                    heads: spec.n_heads,
                    t_q: 1,
                },
            );
        }
        for (batch, seq) in MIXED_BUCKETS {
            let name = format!("model_{mode}_mixed_b{batch}_s{seq}");
            artifacts.insert(
                name.clone(),
                ArtifactInfo {
                    name,
                    kind: ArtifactKind::Mixed,
                    mode: mode.to_string(),
                    batch,
                    seq,
                    heads: spec.n_heads,
                    t_q: MIXED_CHUNK,
                },
            );
        }
        for (batch, seq) in VERIFY_BUCKETS {
            let name = format!("model_{mode}_verify_b{batch}_s{seq}");
            artifacts.insert(
                name.clone(),
                ArtifactInfo {
                    name,
                    kind: ArtifactKind::Verify,
                    mode: mode.to_string(),
                    batch,
                    seq,
                    heads: spec.n_heads,
                    t_q: VERIFY_CHUNK,
                },
            );
        }
    }
    for kernel in ["snapmla", "amla", "pcast", "flashmla"] {
        for (heads, t_q, seq) in kernel_sweep() {
            let name = format!("kernel_{kernel}_h{heads}_t{t_q}_n{seq}");
            artifacts.insert(
                name.clone(),
                ArtifactInfo {
                    name,
                    kind: ArtifactKind::Kernel,
                    mode: kernel.to_string(),
                    batch: 1,
                    seq,
                    heads,
                    t_q,
                },
            );
        }
    }
    Manifest { dir: PathBuf::from("artifacts"), model, param_order, artifacts }
}

/// The deterministically constructed sim weights.
pub fn sim_weights(spec: &SimSpec) -> Weights {
    sim_model::build_weights(spec, sim_model::SIM_WEIGHT_SEED)
}

enum SimBuffer {
    F32 { data: Vec<f32>, dims: Vec<usize> },
    I32 { data: Vec<i32>, dims: Vec<usize> },
}

#[derive(Clone)]
struct SimExec {
    info: ArtifactInfo,
    model: ModelMeta,
    param_order: Vec<String>,
}

/// Pure-Rust execution backend (no device, no external deps).
pub struct SimBackend {
    spec: SimSpec,
    /// Decode-kernel variant used by the model's FP8 attention path
    /// (kernel artifacts name their own variant and ignore this).
    variant: VariantKind,
    bufs: Slots<SimBuffer>,
    execs: Vec<SimExec>,
}

impl Default for SimBackend {
    fn default() -> SimBackend {
        SimBackend::new(SimSpec::small())
    }
}

impl SimBackend {
    pub fn new(spec: SimSpec) -> SimBackend {
        SimBackend::with_variant(spec, VariantKind::SnapMla)
    }

    /// A backend whose FP8 model path runs `variant`'s decode pipeline.
    pub fn with_variant(spec: SimSpec, variant: VariantKind) -> SimBackend {
        SimBackend { spec, variant, bufs: Slots::new(), execs: Vec::new() }
    }

    /// Live buffer count (leak checks in tests).
    pub fn live_buffers(&self) -> usize {
        self.bufs.live()
    }

    fn f32_buf(&self, id: BufId) -> anyhow::Result<(&[f32], &[usize])> {
        match self.bufs.get(id) {
            Some(SimBuffer::F32 { data, dims }) => Ok((data, dims)),
            Some(SimBuffer::I32 { .. }) => anyhow::bail!("sim: buffer {id} is i32, want f32"),
            None => anyhow::bail!("sim: unknown buffer {id}"),
        }
    }

    fn i32_buf(&self, id: BufId) -> anyhow::Result<(&[i32], &[usize])> {
        match self.bufs.get(id) {
            Some(SimBuffer::I32 { data, dims }) => Ok((data, dims)),
            Some(SimBuffer::F32 { .. }) => anyhow::bail!("sim: buffer {id} is f32, want i32"),
            None => anyhow::bail!("sim: unknown buffer {id}"),
        }
    }

    fn named_weights<'a>(
        &'a self,
        exec: &'a SimExec,
        args: &[BufId],
    ) -> anyhow::Result<BTreeMap<&'a str, &'a [f32]>> {
        let mut named = BTreeMap::new();
        for (name, &id) in exec.param_order.iter().zip(args) {
            named.insert(name.as_str(), self.f32_buf(id)?.0);
        }
        Ok(named)
    }

    fn exec_decode(&self, exec: &SimExec, args: &[BufId]) -> anyhow::Result<Vec<Vec<f32>>> {
        let m = &exec.model;
        let (l, d_c, d_r, vocab) = (m.n_layers, m.d_c, m.d_r, m.vocab);
        let (bb, ss) = (exec.info.batch, exec.info.seq);
        let fp8 = exec.info.mode == "fp8";
        let nw = exec.param_order.len();
        anyhow::ensure!(
            args.len() == nw + 4 + usize::from(fp8),
            "sim decode {}: got {} args, want {}",
            exec.info.name,
            args.len(),
            nw + 4 + usize::from(fp8)
        );
        let named = self.named_weights(exec, args)?;
        let params = SimParams::resolve(m, &named)?;

        let (tok, _) = self.i32_buf(args[nw])?;
        let (pos, _) = self.i32_buf(args[nw + 1])?;
        let (k_c, _) = self.f32_buf(args[nw + 2])?;
        let (k_r, _) = self.f32_buf(args[nw + 3])?;
        let sigma = if fp8 { Some(self.f32_buf(args[nw + 4])?.0) } else { None };
        anyhow::ensure!(tok.len() == bb && pos.len() == bb, "sim decode: bad tok/pos arity");
        anyhow::ensure!(
            k_c.len() == l * bb * ss * d_c && k_r.len() == l * bb * ss * d_r,
            "sim decode: bad cache view size"
        );
        if let Some(sg) = sigma {
            anyhow::ensure!(sg.len() == l * bb * ss, "sim decode: bad sigma size");
        }

        let mut logits = vec![0.0f32; bb * vocab];
        let mut new_kc = vec![0.0f32; l * bb * d_c];
        let mut new_kr = vec![0.0f32; l * bb * d_r];
        let mut new_sg = vec![1.0f32; l * bb];
        // The per-row DecodeCache copies the gathered view so the new token
        // can be written in place before attention; borrowing the uploaded
        // buffers with a scratch row would save a copy — acceptable at sim
        // scale, revisit if the sim model grows.
        for b in 0..bb {
            let p = pos[b].max(0) as usize;
            anyhow::ensure!(p < ss, "sim decode: position {p} exceeds bucket {ss}");
            let mut cache = DecodeCache {
                content: (0..l)
                    .map(|li| {
                        let off = (li * bb + b) * ss;
                        k_c[off * d_c..(off + ss) * d_c].to_vec()
                    })
                    .collect(),
                rope: (0..l)
                    .map(|li| {
                        let off = (li * bb + b) * ss;
                        k_r[off * d_r..(off + ss) * d_r].to_vec()
                    })
                    .collect(),
                sigma: (0..l)
                    .map(|li| {
                        let off = (li * bb + b) * ss;
                        match sigma {
                            Some(sg) => sg[off..off + ss].to_vec(),
                            None => vec![1.0; ss],
                        }
                    })
                    .collect(),
            };
            let out = sim_model::decode_one(
                m,
                &params,
                self.spec.rope_base,
                fp8,
                self.variant,
                tok[b],
                p,
                &mut cache,
            );
            logits[b * vocab..(b + 1) * vocab].copy_from_slice(&out.logits);
            for li in 0..l {
                let dst = (li * bb + b) * d_c;
                new_kc[dst..dst + d_c].copy_from_slice(&out.new_kc[li * d_c..(li + 1) * d_c]);
                let dst = (li * bb + b) * d_r;
                new_kr[dst..dst + d_r].copy_from_slice(&out.new_kr[li * d_r..(li + 1) * d_r]);
                new_sg[li * bb + b] = out.new_sg[li];
            }
        }
        let mut outs = vec![logits, new_kc, new_kr];
        if fp8 {
            outs.push(new_sg);
        }
        Ok(outs)
    }

    fn exec_prefill(&self, exec: &SimExec, args: &[BufId]) -> anyhow::Result<Vec<Vec<f32>>> {
        let m = &exec.model;
        let (l, d_c, d_r, vocab) = (m.n_layers, m.d_c, m.d_r, m.vocab);
        let (bb, pp) = (exec.info.batch, exec.info.seq);
        let fp8 = exec.info.mode == "fp8";
        let nw = exec.param_order.len();
        anyhow::ensure!(
            args.len() == nw + 2,
            "sim prefill {}: got {} args, want {}",
            exec.info.name,
            args.len(),
            nw + 2
        );
        let named = self.named_weights(exec, args)?;
        let params = SimParams::resolve(m, &named)?;
        let (tok, _) = self.i32_buf(args[nw])?;
        let (plens, _) = self.i32_buf(args[nw + 1])?;
        anyhow::ensure!(tok.len() == bb * pp && plens.len() == bb, "sim prefill: bad args");

        let mut last_logits = vec![0.0f32; bb * vocab];
        let mut e_kc = vec![0.0f32; l * bb * pp * d_c];
        let mut e_kr = vec![0.0f32; l * bb * pp * d_r];
        let mut e_sg = vec![0.0f32; l * bb * pp];
        for b in 0..bb {
            let plen = (plens[b].max(1) as usize).min(pp);
            let out = sim_model::prefill_one(
                m,
                &params,
                self.spec.rope_base,
                fp8,
                &tok[b * pp..b * pp + plen],
            );
            last_logits[b * vocab..(b + 1) * vocab].copy_from_slice(&out.last_logits);
            for li in 0..l {
                for t in 0..plen {
                    let dst = ((li * bb + b) * pp + t) * d_c;
                    let src = (li * plen + t) * d_c;
                    e_kc[dst..dst + d_c].copy_from_slice(&out.e_kc[src..src + d_c]);
                    let dst = ((li * bb + b) * pp + t) * d_r;
                    let src = (li * plen + t) * d_r;
                    e_kr[dst..dst + d_r].copy_from_slice(&out.e_kr[src..src + d_r]);
                    e_sg[(li * bb + b) * pp + t] = out.e_sg[li * plen + t];
                }
            }
        }
        let mut outs = vec![last_logits, e_kc, e_kr];
        if fp8 {
            outs.push(e_sg);
        }
        Ok(outs)
    }

    /// Mixed step: interleaved prefill-chunk and decode items in ONE
    /// executable call. Item `b` advances `lens[b]` tokens (1 for decode
    /// items, up to the chunk cap for prefill chunks) starting at cache
    /// position `pos[b]`; every new token runs the same per-token
    /// decode/append math as `exec_decode`, so chunk boundaries never
    /// change the numerics.
    fn exec_mixed(&self, exec: &SimExec, args: &[BufId]) -> anyhow::Result<Vec<Vec<f32>>> {
        let m = &exec.model;
        let (l, d_c, d_r, vocab) = (m.n_layers, m.d_c, m.d_r, m.vocab);
        let (bb, ss, cc) = (exec.info.batch, exec.info.seq, exec.info.t_q);
        let fp8 = exec.info.mode == "fp8";
        let nw = exec.param_order.len();
        anyhow::ensure!(
            args.len() == nw + 5 + usize::from(fp8),
            "sim mixed {}: got {} args, want {}",
            exec.info.name,
            args.len(),
            nw + 5 + usize::from(fp8)
        );
        let named = self.named_weights(exec, args)?;
        let params = SimParams::resolve(m, &named)?;

        let (tok, _) = self.i32_buf(args[nw])?;
        let (lens, _) = self.i32_buf(args[nw + 1])?;
        let (pos, _) = self.i32_buf(args[nw + 2])?;
        let (k_c, _) = self.f32_buf(args[nw + 3])?;
        let (k_r, _) = self.f32_buf(args[nw + 4])?;
        let sigma = if fp8 { Some(self.f32_buf(args[nw + 5])?.0) } else { None };
        anyhow::ensure!(
            tok.len() == bb * cc && lens.len() == bb && pos.len() == bb,
            "sim mixed: bad tok/len/pos arity"
        );
        anyhow::ensure!(
            k_c.len() == l * bb * ss * d_c && k_r.len() == l * bb * ss * d_r,
            "sim mixed: bad cache view size"
        );

        let mut logits = vec![0.0f32; bb * vocab];
        let mut new_kc = vec![0.0f32; l * bb * cc * d_c];
        let mut new_kr = vec![0.0f32; l * bb * cc * d_r];
        let mut new_sg = vec![1.0f32; l * bb * cc];
        for b in 0..bb {
            let len = (lens[b].max(0) as usize).min(cc);
            if len == 0 {
                continue; // padding row
            }
            let start = pos[b].max(0) as usize;
            anyhow::ensure!(
                start + len <= ss,
                "sim mixed: item {b} reaches {} past bucket {ss}",
                start + len
            );
            let mut cache = DecodeCache {
                content: (0..l)
                    .map(|li| {
                        let off = (li * bb + b) * ss;
                        k_c[off * d_c..(off + ss) * d_c].to_vec()
                    })
                    .collect(),
                rope: (0..l)
                    .map(|li| {
                        let off = (li * bb + b) * ss;
                        k_r[off * d_r..(off + ss) * d_r].to_vec()
                    })
                    .collect(),
                sigma: (0..l)
                    .map(|li| {
                        let off = (li * bb + b) * ss;
                        match sigma {
                            Some(sg) => sg[off..off + ss].to_vec(),
                            None => vec![1.0; ss],
                        }
                    })
                    .collect(),
            };
            for k in 0..len {
                let out = sim_model::decode_one(
                    m,
                    &params,
                    self.spec.rope_base,
                    fp8,
                    self.variant,
                    tok[b * cc + k],
                    start + k,
                    &mut cache,
                );
                for li in 0..l {
                    let dst = ((li * bb + b) * cc + k) * d_c;
                    new_kc[dst..dst + d_c]
                        .copy_from_slice(&out.new_kc[li * d_c..(li + 1) * d_c]);
                    let dst = ((li * bb + b) * cc + k) * d_r;
                    new_kr[dst..dst + d_r]
                        .copy_from_slice(&out.new_kr[li * d_r..(li + 1) * d_r]);
                    new_sg[(li * bb + b) * cc + k] = out.new_sg[li];
                }
                if k == len - 1 {
                    logits[b * vocab..(b + 1) * vocab].copy_from_slice(&out.logits);
                }
            }
        }
        let mut outs = vec![logits, new_kc, new_kr];
        if fp8 {
            outs.push(new_sg);
        }
        Ok(outs)
    }

    /// Speculative verify: the mixed-step math with one difference — logits
    /// come back at EVERY advanced position (`[bb, cc, vocab]`, padded rows
    /// zeroed), so one call scores a carried token plus a whole draft run.
    fn exec_verify(&self, exec: &SimExec, args: &[BufId]) -> anyhow::Result<Vec<Vec<f32>>> {
        let m = &exec.model;
        let (l, d_c, d_r, vocab) = (m.n_layers, m.d_c, m.d_r, m.vocab);
        let (bb, ss, cc) = (exec.info.batch, exec.info.seq, exec.info.t_q);
        let fp8 = exec.info.mode == "fp8";
        let nw = exec.param_order.len();
        anyhow::ensure!(
            args.len() == nw + 5 + usize::from(fp8),
            "sim verify {}: got {} args, want {}",
            exec.info.name,
            args.len(),
            nw + 5 + usize::from(fp8)
        );
        let named = self.named_weights(exec, args)?;
        let params = SimParams::resolve(m, &named)?;

        let (tok, _) = self.i32_buf(args[nw])?;
        let (lens, _) = self.i32_buf(args[nw + 1])?;
        let (pos, _) = self.i32_buf(args[nw + 2])?;
        let (k_c, _) = self.f32_buf(args[nw + 3])?;
        let (k_r, _) = self.f32_buf(args[nw + 4])?;
        let sigma = if fp8 { Some(self.f32_buf(args[nw + 5])?.0) } else { None };
        anyhow::ensure!(
            tok.len() == bb * cc && lens.len() == bb && pos.len() == bb,
            "sim verify: bad tok/len/pos arity"
        );
        anyhow::ensure!(
            k_c.len() == l * bb * ss * d_c && k_r.len() == l * bb * ss * d_r,
            "sim verify: bad cache view size"
        );

        let mut logits = vec![0.0f32; bb * cc * vocab];
        let mut new_kc = vec![0.0f32; l * bb * cc * d_c];
        let mut new_kr = vec![0.0f32; l * bb * cc * d_r];
        let mut new_sg = vec![1.0f32; l * bb * cc];
        for b in 0..bb {
            let len = (lens[b].max(0) as usize).min(cc);
            if len == 0 {
                continue; // padding row
            }
            let start = pos[b].max(0) as usize;
            anyhow::ensure!(
                start + len <= ss,
                "sim verify: item {b} reaches {} past bucket {ss}",
                start + len
            );
            let mut cache = DecodeCache {
                content: (0..l)
                    .map(|li| {
                        let off = (li * bb + b) * ss;
                        k_c[off * d_c..(off + ss) * d_c].to_vec()
                    })
                    .collect(),
                rope: (0..l)
                    .map(|li| {
                        let off = (li * bb + b) * ss;
                        k_r[off * d_r..(off + ss) * d_r].to_vec()
                    })
                    .collect(),
                sigma: (0..l)
                    .map(|li| {
                        let off = (li * bb + b) * ss;
                        match sigma {
                            Some(sg) => sg[off..off + ss].to_vec(),
                            None => vec![1.0; ss],
                        }
                    })
                    .collect(),
            };
            for k in 0..len {
                let out = sim_model::decode_one(
                    m,
                    &params,
                    self.spec.rope_base,
                    fp8,
                    self.variant,
                    tok[b * cc + k],
                    start + k,
                    &mut cache,
                );
                for li in 0..l {
                    let dst = ((li * bb + b) * cc + k) * d_c;
                    new_kc[dst..dst + d_c]
                        .copy_from_slice(&out.new_kc[li * d_c..(li + 1) * d_c]);
                    let dst = ((li * bb + b) * cc + k) * d_r;
                    new_kr[dst..dst + d_r]
                        .copy_from_slice(&out.new_kr[li * d_r..(li + 1) * d_r]);
                    new_sg[(li * bb + b) * cc + k] = out.new_sg[li];
                }
                let dst = (b * cc + k) * vocab;
                logits[dst..dst + vocab].copy_from_slice(&out.logits);
            }
        }
        let mut outs = vec![logits, new_kc, new_kr];
        if fp8 {
            outs.push(new_sg);
        }
        Ok(outs)
    }

    /// FP8 kernel artifact: `kind`'s decode-attention pipeline on paper-shape
    /// operands (already quantized/aligned by the caller). All FP8 variants
    /// share the 7-arg calling convention — they consume the same cache.
    fn exec_kernel_fp8(&self, kind: VariantKind, args: &[BufId]) -> anyhow::Result<Vec<Vec<f32>>> {
        anyhow::ensure!(args.len() == 7, "fp8 kernel wants 7 args");
        let (q_c, qd) = self.f32_buf(args[0])?;
        let (q_r, qrd) = self.f32_buf(args[1])?;
        let (sq, _) = self.f32_buf(args[2])?;
        let (k_c, _) = self.f32_buf(args[3])?;
        let (k_r, _) = self.f32_buf(args[4])?;
        let (sk, _) = self.f32_buf(args[5])?;
        let (len, _) = self.i32_buf(args[6])?;
        anyhow::ensure!(qd.len() == 3 && qrd.len() == 3, "fp8 kernel: bad query dims");
        let (t_q, heads, d_c) = (qd[0], qd[1], qd[2]);
        let d_r = qrd[2];
        let n = k_c.len() / d_c;
        let shape = Shape { heads, d_c, d_r };
        let sm = shape.sm_scale();
        let length = (len[0].max(0) as usize).min(n);
        let cache =
            QuantCache { k_c_q: k_c.to_vec(), sigma_k: sk.to_vec(), k_r_al: k_r.to_vec(), n };

        let v = kind.instance();
        let mut o = Vec::with_capacity(t_q * heads * d_c);
        let mut lse = Vec::with_capacity(t_q * heads);
        for ti in 0..t_q {
            let out = v.pipeline(
                &shape,
                &q_c[ti * heads * d_c..(ti + 1) * heads * d_c],
                &sq[ti * heads..(ti + 1) * heads],
                &q_r[ti * heads * d_r..(ti + 1) * heads * d_r],
                &cache,
                length,
                sm,
            );
            o.extend_from_slice(&out.o);
            lse.extend_from_slice(&out.lse);
        }
        Ok(vec![o, lse])
    }

    /// FlashMLA baseline kernel artifact: BF16 decode attention.
    fn exec_kernel_flashmla(&self, args: &[BufId]) -> anyhow::Result<Vec<Vec<f32>>> {
        anyhow::ensure!(args.len() == 5, "flashmla kernel wants 5 args");
        let (q_c, qd) = self.f32_buf(args[0])?;
        let (q_r, qrd) = self.f32_buf(args[1])?;
        let (k_c, _) = self.f32_buf(args[2])?;
        let (k_r, _) = self.f32_buf(args[3])?;
        let (len, _) = self.i32_buf(args[4])?;
        anyhow::ensure!(qd.len() == 3 && qrd.len() == 3, "flashmla kernel: bad query dims");
        let (t_q, heads, d_c) = (qd[0], qd[1], qd[2]);
        let d_r = qrd[2];
        let n = k_c.len() / d_c;
        let shape = Shape { heads, d_c, d_r };
        let sm = shape.sm_scale();
        let length = (len[0].max(0) as usize).min(n);
        let kc_b: Vec<f32> = k_c.iter().map(|&x| bf16_round(x)).collect();
        let kr_b: Vec<f32> = k_r.iter().map(|&x| bf16_round(x)).collect();

        let mut o = Vec::with_capacity(t_q * heads * d_c);
        let mut lse = Vec::with_capacity(t_q * heads);
        for ti in 0..t_q {
            let q = Query {
                q_c: q_c[ti * heads * d_c..(ti + 1) * heads * d_c]
                    .iter()
                    .map(|&x| bf16_round(x))
                    .collect(),
                q_r: q_r[ti * heads * d_r..(ti + 1) * heads * d_r]
                    .iter()
                    .map(|&x| bf16_round(x))
                    .collect(),
            };
            let out = attention_with_values(&shape, &q, &kc_b, &kr_b, length, sm);
            o.extend_from_slice(&out.o);
            lse.extend_from_slice(&out.lse);
        }
        Ok(vec![o, lse])
    }
}

impl ExecBackend for SimBackend {
    fn name(&self) -> &'static str {
        "sim"
    }

    fn upload_f32(&mut self, data: &[f32], dims: &[usize]) -> anyhow::Result<BufId> {
        let n: usize = dims.iter().product();
        anyhow::ensure!(n == data.len(), "sim: {} elems do not fit dims {dims:?}", data.len());
        Ok(self.bufs.insert(SimBuffer::F32 { data: data.to_vec(), dims: dims.to_vec() }))
    }

    fn upload_i32(&mut self, data: &[i32], dims: &[usize]) -> anyhow::Result<BufId> {
        let n: usize = dims.iter().product();
        anyhow::ensure!(n == data.len(), "sim: {} elems do not fit dims {dims:?}", data.len());
        Ok(self.bufs.insert(SimBuffer::I32 { data: data.to_vec(), dims: dims.to_vec() }))
    }

    fn download_f32(&mut self, buf: BufId) -> anyhow::Result<Vec<f32>> {
        Ok(self.f32_buf(buf)?.0.to_vec())
    }

    fn free(&mut self, buf: BufId) {
        self.bufs.remove(buf);
    }

    fn load_exec(&mut self, manifest: &Manifest, name: &str) -> anyhow::Result<ExecId> {
        let info = manifest
            .artifacts
            .get(name)
            .ok_or_else(|| anyhow::anyhow!("sim: unknown artifact {name}"))?;
        self.execs.push(SimExec {
            info: info.clone(),
            model: manifest.model.clone(),
            param_order: manifest.param_order.clone(),
        });
        Ok(self.execs.len() - 1)
    }

    fn execute(&mut self, exec: ExecId, args: &[BufId]) -> anyhow::Result<Vec<Vec<f32>>> {
        let se = self
            .execs
            .get(exec)
            .ok_or_else(|| anyhow::anyhow!("sim: unknown executable {exec}"))?;
        match se.info.kind {
            ArtifactKind::Decode => self.exec_decode(se, args),
            ArtifactKind::Prefill => self.exec_prefill(se, args),
            ArtifactKind::Mixed => self.exec_mixed(se, args),
            ArtifactKind::Verify => self.exec_verify(se, args),
            ArtifactKind::Kernel => match se.info.mode.as_str() {
                "flashmla" => self.exec_kernel_flashmla(args),
                other => match VariantKind::parse(other) {
                    Some(kind) => self.exec_kernel_fp8(kind, args),
                    None => anyhow::bail!("sim: unknown kernel flavor {other}"),
                },
            },
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn manifest_mirrors_python_buckets() {
        let m = sim_manifest(&SimSpec::small());
        assert_eq!(m.param_order.len(), 2 + 10 * m.model.n_layers);
        assert_eq!(m.param_order[0], "embed");
        let b = m.decode_bucket("fp8", 3, 400).expect("bucket");
        assert_eq!((b.batch, b.seq), (4, 512));
        assert!(m.decode_bucket("fp8", 9, 512).is_none());
        assert_eq!(m.prefill_bucket("bf16", 1, 64).expect("prefill").seq, 128);
        let mx = m.mixed_bucket("fp8", 3, 400).expect("mixed bucket");
        assert_eq!((mx.batch, mx.seq, mx.t_q), (4, 512, MIXED_CHUNK));
        assert!(m.mixed_bucket("fp8", 9, 512).is_none());
        let vf = m.verify_bucket("fp8", 3, 400).expect("verify bucket");
        assert_eq!((vf.batch, vf.seq, vf.t_q), (4, 512, VERIFY_CHUNK));
        assert!(m.verify_bucket("fp8", 9, 512).is_none());
        assert_eq!(m.max_context("fp8"), 2048);
        for h in [16, 32, 64, 128] {
            for kernel in ["snapmla", "amla", "pcast", "flashmla"] {
                assert!(m.kernel_artifact(kernel, h, 1, 1024).is_some(), "{kernel} h{h}");
            }
        }
        assert!(m.kernel_artifact("snapmla", 64, 1, 8192).is_some());
        assert!(m.kernel_artifact("amla", 64, 1, 8192).is_some());
        assert!(m.kernel_artifact("pcast", 64, 1, 8192).is_some());
    }

    #[test]
    fn weights_match_manifest_param_count() {
        let spec = SimSpec::small();
        let w = sim_weights(&spec);
        assert_eq!(w.total_params(), sim_manifest(&spec).model.params);
        for name in sim_manifest(&spec).param_order {
            assert!(w.get(&name).is_ok(), "missing {name}");
        }
    }

    #[test]
    fn upload_validates_dims() {
        let mut b = SimBackend::default();
        assert!(b.upload_f32(&[1.0, 2.0, 3.0], &[2, 2]).is_err());
        let id = b.upload_f32(&[1.0, 2.0, 3.0, 4.0], &[2, 2]).unwrap();
        assert_eq!(b.download_f32(id).unwrap(), vec![1.0, 2.0, 3.0, 4.0]);
        b.free(id);
        assert!(b.download_f32(id).is_err());
        assert_eq!(b.live_buffers(), 0);
    }

    #[test]
    fn kernel_dispatch_runs_both_flavors() {
        let spec = SimSpec::small();
        let manifest = sim_manifest(&spec);
        let mut b = SimBackend::new(spec);
        let (heads, d_c, d_r, n) = (16usize, 512usize, 64usize, 1024usize);
        let mut rng = crate::util::rng::Rng::new(7);
        let q_c = rng.normal_vec(heads * d_c, 1.0);
        let q_r = rng.normal_vec(heads * d_r, 0.3);
        let k_c = rng.normal_vec(n * d_c, 1.0);
        let k_r = rng.normal_vec(n * d_r, 0.3);

        let sq = vec![0.01f32; heads];
        let sk = vec![0.02f32; n];
        for kernel in ["snapmla", "amla", "pcast"] {
            let exec =
                b.load_exec(&manifest, &format!("kernel_{kernel}_h16_t1_n1024")).unwrap();
            let args = vec![
                b.upload_f32(&q_c, &[1, heads, d_c]).unwrap(),
                b.upload_f32(&q_r, &[1, heads, d_r]).unwrap(),
                b.upload_f32(&sq, &[1, heads, 1]).unwrap(),
                b.upload_f32(&k_c, &[n, d_c]).unwrap(),
                b.upload_f32(&k_r, &[n, d_r]).unwrap(),
                b.upload_f32(&sk, &[n, 1]).unwrap(),
                b.upload_i32(&[1000], &[1]).unwrap(),
            ];
            let outs = b.execute(exec, &args).unwrap();
            assert_eq!(outs.len(), 2, "{kernel}");
            assert_eq!(outs[0].len(), heads * d_c, "{kernel}");
            assert_eq!(outs[1].len(), heads, "{kernel}");
            assert!(outs[0].iter().all(|x| x.is_finite()), "{kernel}");
        }

        let exec = b.load_exec(&manifest, "kernel_flashmla_h16_t1_n1024").unwrap();
        let args = vec![
            b.upload_f32(&q_c, &[1, heads, d_c]).unwrap(),
            b.upload_f32(&q_r, &[1, heads, d_r]).unwrap(),
            b.upload_f32(&k_c, &[n, d_c]).unwrap(),
            b.upload_f32(&k_r, &[n, d_r]).unwrap(),
            b.upload_i32(&[1000], &[1]).unwrap(),
        ];
        let outs = b.execute(exec, &args).unwrap();
        assert_eq!(outs.len(), 2);
        assert!(outs[0].iter().all(|x| x.is_finite()));
    }
}
