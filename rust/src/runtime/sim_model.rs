//! The SimBackend's model: a small absorbed-MLA transformer with
//! *hand-constructed* weights implementing a textbook induction circuit.
//!
//! Architecture mirrors `python/compile/model.py` (same parameter names,
//! shapes pattern, RMSNorm/RoPE/SwiGLU semantics) at reduced dimensions, so
//! the pure-Rust execution path exercises the exact serving contract of the
//! AOT artifacts. The weights are not trained: they are built so the model
//! *provably* performs induction ("…A B … A → B"), which gives the serving
//! and parity tests a deterministic, offline, semantically meaningful model:
//!
//! * **Layer 0 — previous-token head.** Content queries are zero; the RoPE
//!   pair is constructed so `q_r(i)·k_r(j) = Σ_f cos(θ_f·(i-j-1))`, peaked
//!   at `j = i-1`. The value path copies the attended token's identity
//!   subspace (E1) into the residual "previous token" slot (E2).
//! * **Layer 1 — induction head.** Queries project the current token's E1
//!   against cached E2 (the prev-token slot), so position `j` wins when
//!   `token[j-1] == token[i]`; the value's E1 half then writes `token[j]`'s
//!   identity toward the tied unembedding — predicting the successor.
//!
//! Margins (measured on an exact numpy port of this construction, including
//! a bit-exact `util::rng` port, over the integration tests' prompts):
//! greedy motif continuation is exact, FP8-vs-BF16 greedy decode agrees,
//! and final-logit gaps are ≈2.4–4 nats — far above the FP8 pipeline's
//! quantization noise. The integration tests assert these behaviors.

use super::manifest::ModelMeta;
use super::weights::{Tensor, Weights};
use crate::anyhow;
use crate::fp8::{bf16_round, e4m3_round, per_token_scale};
use crate::mla::ref_attn::attention_with_values;
use crate::mla::variant::{QuantCache, VariantKind};
use crate::mla::{Query, Shape};
use crate::util::rng::Rng;
use std::collections::BTreeMap;

/// Sim model dimensions (the sim analogue of `ModelConfig` in model.py).
#[derive(Clone, Copy, Debug)]
pub struct SimSpec {
    pub vocab: usize,
    pub d_model: usize,
    pub n_layers: usize,
    pub n_heads: usize,
    pub d_c: usize,
    pub d_r: usize,
    pub d_ffn: usize,
    pub rope_base: f32,
}

/// Width of the identity subspaces E1/E2 in the residual stream.
const SUB: usize = 32;
/// Residual-stream layout: E1 = token identity, E2 = previous-token slot,
/// BIAS = constant channel driving the positional (RoPE) circuit.
const E2: usize = SUB;
const BIAS: usize = 2 * SUB;

// Circuit gains (tuned so softmax is sharp and final-logit gaps stay >2 nats
// under FP8 quantization; see module docs).
const G_Q0: f32 = 1.0;
const G_K0: f32 = 1.2;
const G_V0: f32 = 1.0 / 6.0;
const G_Q1: f32 = 7.0;
const G_A: f32 = 1.0;
const G_B: f32 = 1.0;
const G_O: f32 = 1.0;
const FFN_SCALE: f32 = 0.01;

/// Deterministic seed of the constructed weights.
pub const SIM_WEIGHT_SEED: u64 = 0x5EED_0001;

impl SimSpec {
    /// The shipped sim model (vocab covers the synthetic token language).
    pub fn small() -> SimSpec {
        SimSpec {
            vocab: 512,
            d_model: 72,
            n_layers: 2,
            n_heads: 4,
            d_c: 2 * SUB,
            d_r: 16,
            d_ffn: 32,
            rope_base: 30.0,
        }
    }

    pub fn sm_scale(&self) -> f64 {
        1.0 / ((self.d_c + self.d_r) as f64).sqrt()
    }

    /// Deterministic (name, shape) list — same naming contract as
    /// `model.param_shapes` in python (manifest `param_order`).
    pub fn param_shapes(&self) -> Vec<(String, Vec<usize>)> {
        let mut shapes = vec![("embed".to_string(), vec![self.vocab, self.d_model])];
        for l in 0..self.n_layers {
            let p = format!("layer{l:02}.");
            shapes.push((format!("{p}ln1"), vec![self.d_model]));
            shapes.push((format!("{p}w_q_c"), vec![self.d_model, self.n_heads * self.d_c]));
            shapes.push((format!("{p}w_q_r"), vec![self.d_model, self.n_heads * self.d_r]));
            shapes.push((format!("{p}w_dkv"), vec![self.d_model, self.d_c]));
            shapes.push((format!("{p}w_kr"), vec![self.d_model, self.d_r]));
            shapes.push((format!("{p}w_o"), vec![self.n_heads * self.d_c, self.d_model]));
            shapes.push((format!("{p}ln2"), vec![self.d_model]));
            shapes.push((format!("{p}w_gate"), vec![self.d_model, self.d_ffn]));
            shapes.push((format!("{p}w_up"), vec![self.d_model, self.d_ffn]));
            shapes.push((format!("{p}w_down"), vec![self.d_ffn, self.d_model]));
        }
        shapes.push(("ln_f".to_string(), vec![self.d_model]));
        shapes
    }

    pub fn param_count(&self) -> usize {
        self.param_shapes().iter().map(|(_, s)| s.iter().product::<usize>()).sum()
    }
}

fn unit_vec(rng: &mut Rng, n: usize) -> Vec<f32> {
    let mut v = rng.normal_vec(n, 1.0);
    let norm = v.iter().map(|&x| (x as f64).powi(2)).sum::<f64>().sqrt().max(1e-9) as f32;
    for x in v.iter_mut() {
        *x /= norm;
    }
    v
}

/// Build the hand-constructed induction weights for `spec`.
///
/// The construction is specific to the `SimSpec::small` layout (two identity
/// subspaces of width `SUB` plus a bias channel; exactly two layers).
pub fn build_weights(spec: &SimSpec, seed: u64) -> Weights {
    assert!(spec.n_layers == 2, "sim construction is a 2-layer circuit");
    assert!(spec.d_model > BIAS, "d_model must fit E1+E2+bias");
    assert!(spec.d_c == 2 * SUB, "d_c must split into A/B halves of SUB");
    assert!(spec.d_r >= 4 && spec.d_r % 2 == 0, "rope needs paired channels");

    let (d, h, d_c, d_r, f) = (spec.d_model, spec.n_heads, spec.d_c, spec.d_r, spec.d_ffn);
    let half = d_r / 2;
    let mut rng = Rng::new(seed);
    let mut tensors: BTreeMap<String, Tensor> = BTreeMap::new();
    let mut put = |name: &str, dims: Vec<usize>, data: Vec<f32>| {
        assert_eq!(dims.iter().product::<usize>(), data.len(), "{name}");
        tensors.insert(name.to_string(), Tensor { dims, data });
    };

    // embed: E1 = random unit identity vector, bias channel = 1 (all rows
    // share the exact norm, so rmsnorm scales every token identically).
    let mut embed = vec![0.0f32; spec.vocab * d];
    for t in 0..spec.vocab {
        let u = unit_vec(&mut rng, SUB);
        embed[t * d..t * d + SUB].copy_from_slice(&u);
        embed[t * d + BIAS] = 1.0;
    }
    put("embed", vec![spec.vocab, d], embed);

    let theta = |fi: usize| spec.rope_base.powf(-(fi as f32) / half as f32);

    for l in 0..spec.n_layers {
        let p = format!("layer{l:02}.");
        put(&format!("{p}ln1"), vec![d], vec![1.0; d]);
        put(&format!("{p}ln2"), vec![d], vec![1.0; d]);

        let mut w_q_c = vec![0.0f32; d * h * d_c];
        let mut w_q_r = vec![0.0f32; d * h * d_r];
        let mut w_dkv = vec![0.0f32; d * d_c];
        let mut w_kr = vec![0.0f32; d * d_r];
        let mut w_o = vec![0.0f32; h * d_c * d];

        if l == 0 {
            // Previous-token head: purely positional attention.
            // q_r (pre-RoPE) = g·[cos θ_f; -sin θ_f] from the bias channel,
            // k_r (pre-RoPE) = g·[1; 0] — after RoPE the logit at distance
            // Δ = i - j is Σ_f cos(θ_f (Δ - 1)), peaked at Δ = 1.
            for head in 0..h {
                for fi in 0..half {
                    w_q_r[BIAS * (h * d_r) + head * d_r + fi] = G_Q0 * theta(fi).cos();
                    w_q_r[BIAS * (h * d_r) + head * d_r + half + fi] = -G_Q0 * theta(fi).sin();
                }
            }
            for fi in 0..half {
                w_kr[BIAS * d_r + fi] = G_K0;
            }
            // value: copy E1 (token identity) into the cache's A half …
            for i in 0..SUB {
                w_dkv[i * d_c + i] = 1.0;
            }
            // … and write head 0's attended A half into the E2 slot.
            for i in 0..SUB {
                w_o[i * d + E2 + i] = G_V0;
            }
        } else {
            // Induction head: match current E1 against cached E2 (the
            // prev-token identity), value = cached E1 (the successor).
            for head in 0..h {
                for i in 0..SUB {
                    w_q_c[i * (h * d_c) + head * d_c + SUB + i] = G_Q1;
                }
            }
            for i in 0..SUB {
                w_dkv[i * d_c + i] = G_A; // E1 -> A half (value payload)
                w_dkv[(E2 + i) * d_c + SUB + i] = G_B; // E2 -> B half (match key)
            }
            for head in 0..h {
                for i in 0..SUB {
                    w_o[(head * d_c + i) * d + i] = G_O / h as f32; // A half -> E1
                }
            }
        }

        put(&format!("{p}w_q_c"), vec![d, h * d_c], w_q_c);
        put(&format!("{p}w_q_r"), vec![d, h * d_r], w_q_r);
        put(&format!("{p}w_dkv"), vec![d, d_c], w_dkv);
        put(&format!("{p}w_kr"), vec![d, d_r], w_kr);
        put(&format!("{p}w_o"), vec![h * d_c, d], w_o);

        // Tiny random SwiGLU: keeps the FFN path exercised without
        // perturbing the circuit (output magnitude ~1e-4).
        let scale_in = FFN_SCALE / (d as f32).sqrt();
        let scale_down = FFN_SCALE / (f as f32).sqrt();
        put(&format!("{p}w_gate"), vec![d, f], rng.normal_vec(d * f, scale_in));
        put(&format!("{p}w_up"), vec![d, f], rng.normal_vec(d * f, scale_in));
        put(&format!("{p}w_down"), vec![f, d], rng.normal_vec(f * d, scale_down));
    }
    put("ln_f", vec![d], vec![1.0; d]);

    Weights { tensors }
}

// ---------------------------------------------------------------------------
// Forward math (mirrors model.py's rmsnorm / rope / SwiGLU exactly)
// ---------------------------------------------------------------------------

/// Per-layer weight views resolved from backend buffers.
pub struct SimLayer<'a> {
    pub ln1: &'a [f32],
    pub w_q_c: &'a [f32],
    pub w_q_r: &'a [f32],
    pub w_dkv: &'a [f32],
    pub w_kr: &'a [f32],
    pub w_o: &'a [f32],
    pub ln2: &'a [f32],
    pub w_gate: &'a [f32],
    pub w_up: &'a [f32],
    pub w_down: &'a [f32],
}

/// Full weight view in the sim forward.
pub struct SimParams<'a> {
    pub embed: &'a [f32],
    pub layers: Vec<SimLayer<'a>>,
    pub ln_f: &'a [f32],
}

impl<'a> SimParams<'a> {
    /// Resolve named weight slices (uploaded in manifest `param_order`).
    pub fn resolve(
        m: &ModelMeta,
        named: &BTreeMap<&str, &'a [f32]>,
    ) -> anyhow::Result<SimParams<'a>> {
        let get = |name: &str, len: usize| -> anyhow::Result<&'a [f32]> {
            let s = *named
                .get(name)
                .ok_or_else(|| anyhow::anyhow!("sim: missing weight {name}"))?;
            anyhow::ensure!(s.len() == len, "sim: weight {name} has {} elems, want {len}", s.len());
            Ok(s)
        };
        let (d, h, d_c, d_r, f) = (m.d_model, m.n_heads, m.d_c, m.d_r, m.d_ffn);
        let mut layers = Vec::with_capacity(m.n_layers);
        for l in 0..m.n_layers {
            let p = format!("layer{l:02}.");
            layers.push(SimLayer {
                ln1: get(&format!("{p}ln1"), d)?,
                w_q_c: get(&format!("{p}w_q_c"), d * h * d_c)?,
                w_q_r: get(&format!("{p}w_q_r"), d * h * d_r)?,
                w_dkv: get(&format!("{p}w_dkv"), d * d_c)?,
                w_kr: get(&format!("{p}w_kr"), d * d_r)?,
                w_o: get(&format!("{p}w_o"), h * d_c * d)?,
                ln2: get(&format!("{p}ln2"), d)?,
                w_gate: get(&format!("{p}w_gate"), d * f)?,
                w_up: get(&format!("{p}w_up"), d * f)?,
                w_down: get(&format!("{p}w_down"), f * d)?,
            });
        }
        Ok(SimParams {
            embed: get("embed", m.vocab * d)?,
            layers,
            ln_f: get("ln_f", d)?,
        })
    }
}

/// `out[j] = Σ_i x[i]·w[i·out_dim + j]` for row-major `w: [x.len(), out_dim]`.
fn matvec(x: &[f32], w: &[f32], out_dim: usize) -> Vec<f32> {
    debug_assert_eq!(w.len(), x.len() * out_dim);
    let mut out = vec![0.0f32; out_dim];
    for (i, &xi) in x.iter().enumerate() {
        if xi == 0.0 {
            continue;
        }
        let row = &w[i * out_dim..(i + 1) * out_dim];
        for (o, &wij) in out.iter_mut().zip(row) {
            *o += xi * wij;
        }
    }
    out
}

fn rmsnorm(x: &[f32], scale: &[f32]) -> Vec<f32> {
    let ms = x.iter().map(|&v| (v as f64).powi(2)).sum::<f64>() / x.len() as f64;
    let r = (1.0 / (ms + 1e-6).sqrt()) as f32;
    x.iter().zip(scale).map(|(&v, &s)| v * r * s).collect()
}

/// Half-split rotary embedding at absolute position `pos` (model.py `rope`).
pub fn rope_in_place(x: &mut [f32], pos: f32, base: f32) {
    let half = x.len() / 2;
    for fi in 0..half {
        let theta = base.powf(-(fi as f32) / half as f32);
        let (s, c) = (pos * theta).sin_cos();
        let (x1, x2) = (x[fi], x[half + fi]);
        x[fi] = x1 * c - x2 * s;
        x[half + fi] = x1 * s + x2 * c;
    }
}

fn mlp(layer: &SimLayer, x: &[f32], d_ffn: usize, d_model: usize) -> Vec<f32> {
    let g = matvec(x, layer.w_gate, d_ffn);
    let u = matvec(x, layer.w_up, d_ffn);
    let act: Vec<f32> = g
        .iter()
        .zip(&u)
        .map(|(&gi, &ui)| gi / (1.0 + (-gi).exp()) * ui)
        .collect();
    matvec(&act, layer.w_down, d_model)
}

fn unembed(h: &[f32], embed: &[f32], vocab: usize, d: usize) -> Vec<f32> {
    let mut logits = vec![0.0f32; vocab];
    for (t, l) in logits.iter_mut().enumerate() {
        let row = &embed[t * d..(t + 1) * d];
        *l = h.iter().zip(row).map(|(&a, &b)| a * b).sum();
    }
    logits
}

/// One sequence's gathered cache views for a decode step (mutable working
/// copies; the new token's entry is written at row `pos` before attention,
/// exactly like the in-graph cache update of `model._attn_decode`).
pub struct DecodeCache {
    /// per layer: content on the E4M3 grid (fp8) / bf16 values, `[ss, d_c]`
    pub content: Vec<Vec<f32>>,
    /// per layer: aligned rope (fp8) / bf16 rope, `[ss, d_r]`
    pub rope: Vec<Vec<f32>>,
    /// per layer: per-token scales (1.0 in bf16 mode), `[ss]`
    pub sigma: Vec<Vec<f32>>,
}

/// Output of one decode item: next-token logits + the new cache entries.
pub struct DecodeItemOut {
    pub logits: Vec<f32>,
    /// `[n_layers, d_c]` on the storage grid (E4M3 staging / bf16)
    pub new_kc: Vec<f32>,
    /// `[n_layers, d_r]` aligned rope (fp8) / bf16 rope
    pub new_kr: Vec<f32>,
    /// `[n_layers]` content scales (fp8 only; 1.0 in bf16)
    pub new_sg: Vec<f32>,
}

/// One decode step for one sequence (one new token at absolute `pos`).
/// In FP8 mode the attention runs `variant`'s decode pipeline; the cache
/// append is the shared SnapMLA layout regardless of variant.
#[allow(clippy::too_many_arguments)]
pub fn decode_one(
    m: &ModelMeta,
    params: &SimParams,
    rope_base: f32,
    fp8: bool,
    variant: VariantKind,
    token: i32,
    pos: usize,
    cache: &mut DecodeCache,
) -> DecodeItemOut {
    let (d, h, d_c, d_r) = (m.d_model, m.n_heads, m.d_c, m.d_r);
    let shape = Shape { heads: h, d_c, d_r };
    let sm = m.sm_scale as f32;
    let tok = (token.max(0) as usize).min(m.vocab - 1);

    let mut hid = params.embed[tok * d..(tok + 1) * d].to_vec();
    let mut new_kc = vec![0.0f32; m.n_layers * d_c];
    let mut new_kr = vec![0.0f32; m.n_layers * d_r];
    let mut new_sg = vec![1.0f32; m.n_layers];

    for (l, layer) in params.layers.iter().enumerate() {
        let x = rmsnorm(&hid, layer.ln1);
        let mut q_c = matvec(&x, layer.w_q_c, h * d_c);
        let mut q_r = matvec(&x, layer.w_q_r, h * d_r);
        for head in 0..h {
            rope_in_place(&mut q_r[head * d_r..(head + 1) * d_r], pos as f32, rope_base);
        }
        let c_kv = matvec(&x, layer.w_dkv, d_c);
        let mut k_r = matvec(&x, layer.w_kr, d_r);
        rope_in_place(&mut k_r, pos as f32, rope_base);

        let content = &mut cache.content[l];
        let rope_v = &mut cache.rope[l];
        let sigma_v = &mut cache.sigma[l];
        let o = if fp8 {
            // Fused-K-Append of the new token, bit-exact with the cache.
            let s = per_token_scale(&c_kv);
            for i in 0..d_c {
                content[pos * d_c + i] = e4m3_round(c_kv[i] / s);
            }
            for i in 0..d_r {
                rope_v[pos * d_r + i] = bf16_round(k_r[i]) / s;
            }
            sigma_v[pos] = s;
            new_kc[l * d_c..(l + 1) * d_c].copy_from_slice(&content[pos * d_c..(pos + 1) * d_c]);
            new_kr[l * d_r..(l + 1) * d_r].copy_from_slice(&rope_v[pos * d_r..(pos + 1) * d_r]);
            new_sg[l] = s;

            let ss = sigma_v.len();
            let qcache = QuantCache {
                k_c_q: std::mem::take(content),
                sigma_k: std::mem::take(sigma_v),
                k_r_al: std::mem::take(rope_v),
                n: ss,
            };
            let v = variant.instance();
            let qq = v.quantize_query(
                &shape,
                &Query { q_c: std::mem::take(&mut q_c), q_r: std::mem::take(&mut q_r) },
            );
            let out =
                v.pipeline(&shape, &qq.q_c_q, &qq.sigma_q, &qq.q_r_al, &qcache, pos + 1, sm);
            // hand the working buffers back
            *content = qcache.k_c_q;
            *sigma_v = qcache.sigma_k;
            *rope_v = qcache.k_r_al;
            out.o
        } else {
            for i in 0..d_c {
                content[pos * d_c + i] = bf16_round(c_kv[i]);
            }
            for i in 0..d_r {
                rope_v[pos * d_r + i] = bf16_round(k_r[i]);
            }
            new_kc[l * d_c..(l + 1) * d_c].copy_from_slice(&content[pos * d_c..(pos + 1) * d_c]);
            new_kr[l * d_r..(l + 1) * d_r].copy_from_slice(&rope_v[pos * d_r..(pos + 1) * d_r]);
            let out = attention_with_values(
                &shape,
                &Query { q_c: std::mem::take(&mut q_c), q_r: std::mem::take(&mut q_r) },
                content,
                rope_v,
                pos + 1,
                sm,
            );
            out.o
        };

        let a = matvec(&o, layer.w_o, d);
        for (hi, ai) in hid.iter_mut().zip(&a) {
            *hi += ai;
        }
        let mo = mlp(layer, &rmsnorm(&hid, layer.ln2), m.d_ffn, d);
        for (hi, mi) in hid.iter_mut().zip(&mo) {
            *hi += mi;
        }
    }

    let hf = rmsnorm(&hid, params.ln_f);
    DecodeItemOut { logits: unembed(&hf, params.embed, m.vocab, d), new_kc, new_kr, new_sg }
}

/// Output of one prefill item: last-token logits + all prompt cache entries.
pub struct PrefillItemOut {
    pub last_logits: Vec<f32>,
    /// `[n_layers, plen, d_c]` storage-grid content
    pub e_kc: Vec<f32>,
    /// `[n_layers, plen, d_r]` aligned/bf16 rope
    pub e_kr: Vec<f32>,
    /// `[n_layers, plen]` scales (fp8; 1.0 in bf16)
    pub e_sg: Vec<f32>,
}

/// Full-precision prefill of one prompt (attention over the dequantized
/// entries — the Fused-Fetch-Dequant semantics of `model.prefill`).
pub fn prefill_one(
    m: &ModelMeta,
    params: &SimParams,
    rope_base: f32,
    fp8: bool,
    tokens: &[i32],
) -> PrefillItemOut {
    let (d, h, d_c, d_r) = (m.d_model, m.n_heads, m.d_c, m.d_r);
    let shape = Shape { heads: h, d_c, d_r };
    let sm = m.sm_scale as f32;
    let plen = tokens.len();

    let mut hs = vec![0.0f32; plen * d];
    for (t, &tok) in tokens.iter().enumerate() {
        let ti = (tok.max(0) as usize).min(m.vocab - 1);
        hs[t * d..(t + 1) * d].copy_from_slice(&params.embed[ti * d..(ti + 1) * d]);
    }
    let mut e_kc = vec![0.0f32; m.n_layers * plen * d_c];
    let mut e_kr = vec![0.0f32; m.n_layers * plen * d_r];
    let mut e_sg = vec![1.0f32; m.n_layers * plen];

    for (l, layer) in params.layers.iter().enumerate() {
        let mut q_c = vec![0.0f32; plen * h * d_c];
        let mut q_r = vec![0.0f32; plen * h * d_r];
        let mut kc_d = vec![0.0f32; plen * d_c]; // dequantized values
        let mut kr_d = vec![0.0f32; plen * d_r];
        for t in 0..plen {
            let x = rmsnorm(&hs[t * d..(t + 1) * d], layer.ln1);
            let qc = matvec(&x, layer.w_q_c, h * d_c);
            q_c[t * h * d_c..(t + 1) * h * d_c].copy_from_slice(&qc);
            let mut qr = matvec(&x, layer.w_q_r, h * d_r);
            for head in 0..h {
                rope_in_place(&mut qr[head * d_r..(head + 1) * d_r], t as f32, rope_base);
            }
            q_r[t * h * d_r..(t + 1) * h * d_r].copy_from_slice(&qr);

            let c_kv = matvec(&x, layer.w_dkv, d_c);
            let mut k_r = matvec(&x, layer.w_kr, d_r);
            rope_in_place(&mut k_r, t as f32, rope_base);

            let kc_row = &mut e_kc[(l * plen + t) * d_c..(l * plen + t + 1) * d_c];
            let kr_row = &mut e_kr[(l * plen + t) * d_r..(l * plen + t + 1) * d_r];
            if fp8 {
                let s = per_token_scale(&c_kv);
                for i in 0..d_c {
                    kc_row[i] = e4m3_round(c_kv[i] / s);
                    kc_d[t * d_c + i] = kc_row[i] * s;
                }
                for i in 0..d_r {
                    kr_row[i] = bf16_round(k_r[i]) / s;
                    kr_d[t * d_r + i] = kr_row[i] * s;
                }
                e_sg[l * plen + t] = s;
            } else {
                for i in 0..d_c {
                    kc_row[i] = bf16_round(c_kv[i]);
                    kc_d[t * d_c + i] = kc_row[i];
                }
                for i in 0..d_r {
                    kr_row[i] = bf16_round(k_r[i]);
                    kr_d[t * d_r + i] = kr_row[i];
                }
            }
        }
        // causal attention per query position over the dequantized entries
        for t in 0..plen {
            let q = Query {
                q_c: q_c[t * h * d_c..(t + 1) * h * d_c].to_vec(),
                q_r: q_r[t * h * d_r..(t + 1) * h * d_r].to_vec(),
            };
            let out = attention_with_values(&shape, &q, &kc_d, &kr_d, t + 1, sm);
            let a = matvec(&out.o, layer.w_o, d);
            let row = &mut hs[t * d..(t + 1) * d];
            for (hi, ai) in row.iter_mut().zip(&a) {
                *hi += ai;
            }
        }
        for t in 0..plen {
            let mo = {
                let row = &hs[t * d..(t + 1) * d];
                mlp(layer, &rmsnorm(row, layer.ln2), m.d_ffn, d)
            };
            let row = &mut hs[t * d..(t + 1) * d];
            for (hi, mi) in row.iter_mut().zip(&mo) {
                *hi += mi;
            }
        }
    }

    let hf = rmsnorm(&hs[(plen - 1) * d..plen * d], params.ln_f);
    PrefillItemOut {
        last_logits: unembed(&hf, params.embed, m.vocab, d),
        e_kc,
        e_kr,
        e_sg,
    }
}
