//! Criterion-style micro/macro benchmark harness (criterion itself is not in
//! the offline crate set). Used by all `cargo bench` targets (`harness =
//! false` binaries under benches/).
//!
//! Provides warmup + repeated sampling with summary statistics, and a tiny
//! report-file helper so every bench drops machine-readable JSON next to the
//! human-readable table (EXPERIMENTS.md links both).

use crate::util::json::Json;
use crate::util::stats::Stats;
use std::time::Instant;

#[derive(Clone, Debug)]
pub struct Measurement {
    pub name: String,
    pub samples: usize,
    pub mean_s: f64,
    pub median_s: f64,
    pub std_s: f64,
    pub min_s: f64,
}

impl Measurement {
    pub fn per_sec(&self) -> f64 {
        1.0 / self.mean_s
    }
}

pub struct Bench {
    pub warmup: usize,
    pub samples: usize,
}

impl Default for Bench {
    fn default() -> Self {
        Bench { warmup: 2, samples: 7 }
    }
}

impl Bench {
    pub fn quick() -> Bench {
        Bench { warmup: 1, samples: 3 }
    }

    /// Time `f` (one sample = one call).
    pub fn measure<F: FnMut()>(&self, name: &str, mut f: F) -> Measurement {
        for _ in 0..self.warmup {
            f();
        }
        let mut s = Stats::new();
        for _ in 0..self.samples {
            let t0 = Instant::now();
            f();
            s.push(t0.elapsed().as_secs_f64());
        }
        Measurement {
            name: name.to_string(),
            samples: self.samples,
            mean_s: s.mean(),
            median_s: s.median(),
            std_s: s.std(),
            min_s: s.min(),
        }
    }
}

/// Write a bench report JSON under target/bench-reports/.
pub fn write_report(bench_name: &str, payload: Json) {
    let dir = std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("target/bench-reports");
    let _ = std::fs::create_dir_all(&dir);
    let path = dir.join(format!("{bench_name}.json"));
    if let Err(e) = std::fs::write(&path, payload.to_string_pretty()) {
        eprintln!("warn: could not write {path:?}: {e}");
    } else {
        println!("[report] {}", path.display());
    }
}

/// Standard bench CLI: `--quick` (fewer samples) is honored everywhere.
pub fn bench_from_args(args: &crate::util::cli::Args) -> Bench {
    if args.has("quick") {
        Bench::quick()
    } else {
        Bench::default()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn measures_monotonic_work() {
        let b = Bench { warmup: 1, samples: 3 };
        let m = b.measure("spin", || {
            let mut x = 0u64;
            for i in 0..100_000 {
                x = x.wrapping_add(i);
            }
            std::hint::black_box(x);
        });
        assert!(m.mean_s > 0.0);
        assert!(m.min_s <= m.mean_s);
        assert_eq!(m.samples, 3);
    }
}
