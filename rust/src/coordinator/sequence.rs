//! A live sequence: request + generation state + sampling RNG + stopwatch.

use super::metrics::Stopwatch;
use super::request::{FinishReason, RequestOutcome, ServeRequest};
use crate::kvcache::SpilledKv;
use crate::util::rng::Rng;

#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum SeqPhase {
    Waiting,
    Running,
    Finished(FinishReason),
}

pub struct Sequence {
    pub request: ServeRequest,
    pub phase: SeqPhase,
    pub generated: Vec<i32>,
    /// the token to feed into the next decode step
    pub next_input: i32,
    /// prompt tokens already in the KV cache (chunked-prefill progress;
    /// equals the prompt length once decoding)
    pub prefilled: usize,
    /// spilled KV pages held while preempted (page-spill preemption keeps
    /// the generated-token KV state instead of discarding it)
    pub spilled: Option<SpilledKv>,
    pub rng: Rng,
    pub watch: Stopwatch,
    pub eos: i32,
}

impl Sequence {
    pub fn new(request: ServeRequest, eos: i32) -> Sequence {
        let rng = Rng::new(request.seed ^ 0x5EED);
        let next_input = *request.prompt.last().unwrap_or(&1);
        Sequence {
            request,
            phase: SeqPhase::Waiting,
            generated: Vec::new(),
            next_input,
            prefilled: 0,
            spilled: None,
            rng,
            watch: Stopwatch::start(),
            eos,
        }
    }

    pub fn id(&self) -> u64 {
        self.request.id
    }

    /// Logical context (prompt + generated tokens).
    pub fn context_len(&self) -> usize {
        self.request.prompt.len() + self.generated.len()
    }

    /// Prompt tokens not yet in the KV cache.
    pub fn pending_prefill(&self) -> usize {
        self.request.prompt.len().saturating_sub(self.prefilled)
    }

    /// The next `n` prompt tokens to chunk-prefill (clamped to the
    /// remaining prompt).
    pub fn next_chunk(&self, n: usize) -> Vec<i32> {
        let start = self.prefilled;
        let end = (start + n).min(self.request.prompt.len());
        self.request.prompt[start..end].to_vec()
    }

    /// Sample the next token from logits; updates state and returns whether
    /// the sequence finished.
    pub fn accept_logits(&mut self, logits: &[f32]) -> bool {
        let tok = self.rng.sample_logits(logits, self.request.temperature) as i32;
        self.generated.push(tok);
        self.watch.on_token();
        if tok == self.eos && !self.request.ignore_eos {
            self.phase = SeqPhase::Finished(FinishReason::Eos);
            return true;
        }
        if self.generated.len() >= self.request.max_new_tokens {
            self.phase = SeqPhase::Finished(FinishReason::MaxTokens);
            return true;
        }
        self.next_input = tok;
        false
    }

    /// Park after a page-spill preemption: the KV pages travel with the
    /// sequence and are restored verbatim on resume — no recompute, so a
    /// preempted run stays byte-identical to an uninterrupted one.
    pub fn preempt(&mut self, spilled: SpilledKv) {
        self.phase = SeqPhase::Waiting;
        self.spilled = Some(spilled);
        self.watch.preemptions += 1;
    }

    /// Take the spilled snapshot for a restore.
    pub fn take_spilled(&mut self) -> Option<SpilledKv> {
        self.spilled.take()
    }

    pub fn into_outcome(self) -> RequestOutcome {
        let finish = match self.phase {
            SeqPhase::Finished(f) => f,
            _ => FinishReason::Preempted,
        };
        RequestOutcome {
            id: self.request.id,
            prompt_tokens: self.request.prompt.len(),
            generated: self.generated,
            finish,
            metrics: self.watch.finish(),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::kvcache::{CacheConfig, CacheMode, PagedKvCache};

    fn seq(max_new: usize, temperature: f32) -> Sequence {
        Sequence::new(
            ServeRequest { id: 1, prompt: vec![1, 70, 71], max_new_tokens: max_new,
                temperature, seed: 9, ignore_eos: false },
            0,
        )
    }

    #[test]
    fn greedy_takes_argmax_and_respects_max_tokens() {
        let mut s = seq(2, 0.0);
        let mut logits = vec![0.0f32; 8];
        logits[5] = 3.0;
        assert!(!s.accept_logits(&logits));
        assert_eq!(s.generated, vec![5]);
        assert_eq!(s.next_input, 5);
        assert!(s.accept_logits(&logits)); // hits max_new_tokens
        assert_eq!(s.phase, SeqPhase::Finished(FinishReason::MaxTokens));
    }

    #[test]
    fn eos_finishes() {
        let mut s = seq(10, 0.0);
        let mut logits = vec![0.0f32; 8];
        logits[0] = 5.0; // EOS
        assert!(s.accept_logits(&logits));
        assert_eq!(s.phase, SeqPhase::Finished(FinishReason::Eos));
    }

    #[test]
    fn sampling_is_seed_deterministic() {
        let mut a = seq(5, 1.0);
        let mut b = seq(5, 1.0);
        let logits: Vec<f32> = (0..16).map(|i| (i as f32 * 0.37).sin()).collect();
        for _ in 0..5 {
            let fa = a.accept_logits(&logits);
            let fb = b.accept_logits(&logits);
            assert_eq!(fa, fb);
        }
        assert_eq!(a.generated, b.generated);
    }

    #[test]
    fn chunked_prefill_progress() {
        let mut s = seq(10, 0.0);
        assert_eq!(s.pending_prefill(), 3);
        assert_eq!(s.next_chunk(2), vec![1, 70]);
        s.prefilled += 2;
        assert_eq!(s.pending_prefill(), 1);
        assert_eq!(s.next_chunk(64), vec![71]); // clamped to the prompt tail
        s.prefilled += 1;
        assert_eq!(s.pending_prefill(), 0);
        assert!(s.next_chunk(4).is_empty());
    }

    #[test]
    fn preemption_parks_spilled_kv() {
        // build a real spill snapshot so the sequence carries actual pages
        let cfg = CacheConfig {
            n_layers: 1, d_c: 8, d_r: 4, mode: CacheMode::Fp8, capacity_pages: 2,
        };
        let mut cache = PagedKvCache::new(cfg);
        cache.register(1);
        cache.append_token(1, &[1.0; 8], &[1.0; 4]).unwrap();
        let sp = cache.spill(1).unwrap();

        let mut s = seq(10, 0.0);
        let mut logits = vec![0.0f32; 8];
        logits[3] = 1.0;
        s.accept_logits(&logits);
        s.phase = SeqPhase::Running;
        s.preempt(sp);
        assert_eq!(s.phase, SeqPhase::Waiting);
        assert_eq!(s.watch.preemptions, 1);
        // the generated-token state survives preemption untouched
        assert_eq!(s.generated, vec![3]);
        assert_eq!(s.next_input, 3);
        let sp = s.take_spilled().expect("spill snapshot travels with the seq");
        assert_eq!(sp.tokens(), 1);
        assert!(s.take_spilled().is_none());
    }

    #[test]
    fn context_len_tracks_cache() {
        let mut s = seq(10, 0.0);
        assert_eq!(s.context_len(), 3);
        let mut logits = vec![0.0f32; 8];
        logits[3] = 1.0;
        s.accept_logits(&logits);
        assert_eq!(s.context_len(), 4);
    }
}
