//! A live sequence: request + generation state + sampling RNG + stopwatch.

use super::metrics::Stopwatch;
use super::request::{FinishReason, RequestOutcome, ServeRequest};
use crate::util::rng::Rng;

#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum SeqPhase {
    Waiting,
    Running,
    Finished(FinishReason),
}

pub struct Sequence {
    pub request: ServeRequest,
    pub phase: SeqPhase,
    pub generated: Vec<i32>,
    /// the token to feed into the next decode step
    pub next_input: i32,
    pub rng: Rng,
    pub watch: Stopwatch,
    pub eos: i32,
}

impl Sequence {
    pub fn new(request: ServeRequest, eos: i32) -> Sequence {
        let rng = Rng::new(request.seed ^ 0x5EED);
        let next_input = *request.prompt.last().unwrap_or(&1);
        Sequence {
            request,
            phase: SeqPhase::Waiting,
            generated: Vec::new(),
            next_input,
            rng,
            watch: Stopwatch::start(),
            eos,
        }
    }

    pub fn id(&self) -> u64 {
        self.request.id
    }

    /// Tokens currently in the KV cache once running (prompt + generated).
    pub fn context_len(&self) -> usize {
        self.request.prompt.len() + self.generated.len()
    }

    /// Sample the next token from logits; updates state and returns whether
    /// the sequence finished.
    pub fn accept_logits(&mut self, logits: &[f32]) -> bool {
        let tok = self.rng.sample_logits(logits, self.request.temperature) as i32;
        self.generated.push(tok);
        self.watch.on_token();
        if tok == self.eos && !self.request.ignore_eos {
            self.phase = SeqPhase::Finished(FinishReason::Eos);
            return true;
        }
        if self.generated.len() >= self.request.max_new_tokens {
            self.phase = SeqPhase::Finished(FinishReason::MaxTokens);
            return true;
        }
        self.next_input = tok;
        false
    }

    /// Reset to Waiting after a preemption (KV pages were released; the
    /// prompt + generated tokens will be re-prefilled).
    pub fn preempt(&mut self) {
        self.phase = SeqPhase::Waiting;
        self.watch.preemptions += 1;
    }

    /// The token sequence to prefill when (re)admitted: prompt + generated.
    pub fn prefill_tokens(&self) -> Vec<i32> {
        let mut t = self.request.prompt.clone();
        t.extend(&self.generated);
        t
    }

    pub fn into_outcome(self) -> RequestOutcome {
        let finish = match self.phase {
            SeqPhase::Finished(f) => f,
            _ => FinishReason::Preempted,
        };
        RequestOutcome {
            id: self.request.id,
            prompt_tokens: self.request.prompt.len(),
            generated: self.generated,
            finish,
            metrics: self.watch.finish(),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn seq(max_new: usize, temperature: f32) -> Sequence {
        Sequence::new(
            ServeRequest { id: 1, prompt: vec![1, 70, 71], max_new_tokens: max_new,
                temperature, seed: 9, ignore_eos: false },
            0,
        )
    }

    #[test]
    fn greedy_takes_argmax_and_respects_max_tokens() {
        let mut s = seq(2, 0.0);
        let mut logits = vec![0.0f32; 8];
        logits[5] = 3.0;
        assert!(!s.accept_logits(&logits));
        assert_eq!(s.generated, vec![5]);
        assert_eq!(s.next_input, 5);
        assert!(s.accept_logits(&logits)); // hits max_new_tokens
        assert_eq!(s.phase, SeqPhase::Finished(FinishReason::MaxTokens));
    }

    #[test]
    fn eos_finishes() {
        let mut s = seq(10, 0.0);
        let mut logits = vec![0.0f32; 8];
        logits[0] = 5.0; // EOS
        assert!(s.accept_logits(&logits));
        assert_eq!(s.phase, SeqPhase::Finished(FinishReason::Eos));
    }

    #[test]
    fn sampling_is_seed_deterministic() {
        let mut a = seq(5, 1.0);
        let mut b = seq(5, 1.0);
        let logits: Vec<f32> = (0..16).map(|i| (i as f32 * 0.37).sin()).collect();
        for _ in 0..5 {
            let fa = a.accept_logits(&logits);
            let fb = b.accept_logits(&logits);
            assert_eq!(fa, fb);
        }
        assert_eq!(a.generated, b.generated);
    }

    #[test]
    fn preemption_resets_and_replays() {
        let mut s = seq(10, 0.0);
        let mut logits = vec![0.0f32; 8];
        logits[3] = 1.0;
        s.accept_logits(&logits);
        s.phase = SeqPhase::Running;
        s.preempt();
        assert_eq!(s.phase, SeqPhase::Waiting);
        assert_eq!(s.prefill_tokens(), vec![1, 70, 71, 3]);
        assert_eq!(s.watch.preemptions, 1);
    }

    #[test]
    fn context_len_tracks_cache() {
        let mut s = seq(10, 0.0);
        assert_eq!(s.context_len(), 3);
        let mut logits = vec![0.0f32; 8];
        logits[3] = 1.0;
        s.accept_logits(&logits);
        assert_eq!(s.context_len(), 4);
    }
}
