//! Continuous-batching scheduler: each iteration decides whether to prefill
//! admitted requests or run a decode step over the running set, with
//! KV-capacity admission control and recompute-preemption backpressure.
//!
//! Pure decision logic over a snapshot — fully unit-testable without the
//! engine. The paper-relevant property: per-token instant quantization means
//! admission only needs PAGE accounting (no tail-buffer reservations), which
//! is exactly the "framework compatibility" argument of §3.1.1.

/// Scheduler view of one waiting sequence.
#[derive(Clone, Copy, Debug)]
pub struct WaitingSeq {
    pub idx: usize,
    /// tokens to prefill (prompt, or prompt+generated after preemption)
    pub tokens: usize,
}

/// Scheduler view of one running sequence.
#[derive(Clone, Copy, Debug)]
pub struct RunningSeq {
    pub idx: usize,
    /// current context length (cache tokens)
    pub context: usize,
}

#[derive(Clone, Copy, Debug)]
pub struct SchedulerConfig {
    /// max sequences per decode step (largest decode bucket batch)
    pub max_decode_batch: usize,
    /// max sequences per prefill call (largest prefill bucket batch)
    pub max_prefill_batch: usize,
    /// max prompt tokens per prefill call (prefill bucket length)
    pub max_prefill_tokens: usize,
    /// max context the decode buckets support
    pub max_context: usize,
    /// tokens per KV page
    pub page_tokens: usize,
}

#[derive(Clone, Debug, PartialEq, Eq)]
pub enum Action {
    /// admit + prefill these waiting indices
    Prefill(Vec<usize>),
    /// run one decode step over these running indices
    Decode(Vec<usize>),
    /// release this running sequence's pages and move it back to waiting
    Preempt(usize),
    Idle,
}

pub struct Scheduler {
    pub cfg: SchedulerConfig,
}

impl Scheduler {
    pub fn new(cfg: SchedulerConfig) -> Scheduler {
        Scheduler { cfg }
    }

    fn pages_for(&self, tokens: usize) -> usize {
        tokens.div_ceil(self.cfg.page_tokens)
    }

    /// Decide the next action.
    ///
    /// Policy (vLLM-flavoured):
    /// 1. prefill-priority admission while capacity and bucket space allow
    ///    (FCFS; a waiting request is admitted only if its prefill fits the
    ///    bucket and its pages fit the free pool),
    /// 2. otherwise decode the running set (capped at the decode bucket);
    ///    if the step would exceed free pages, preempt the YOUNGEST running
    ///    sequence (recompute policy) and retry.
    pub fn decide(
        &self,
        waiting: &[WaitingSeq],
        running: &[RunningSeq],
        free_pages: usize,
    ) -> Action {
        // 1) admission
        if !waiting.is_empty() && running.len() < self.cfg.max_decode_batch {
            let mut admitted = Vec::new();
            let mut pages_needed = 0;
            let slots = self.cfg.max_decode_batch - running.len();
            for w in waiting.iter().take(self.cfg.max_prefill_batch.min(slots)) {
                if w.tokens > self.cfg.max_prefill_tokens {
                    break; // FCFS: an oversized head blocks (rejected upstream)
                }
                let need = self.pages_for(w.tokens + 1); // +1 headroom token
                if pages_needed + need > free_pages {
                    break;
                }
                pages_needed += need;
                admitted.push(w.idx);
            }
            if !admitted.is_empty() {
                return Action::Prefill(admitted);
            }
        }

        // 2) decode
        if !running.is_empty() {
            // growth check: a decode step appends one token per sequence
            let growth: usize = running
                .iter()
                .take(self.cfg.max_decode_batch)
                .filter(|r| r.context % self.cfg.page_tokens == 0)
                .count();
            if growth > free_pages {
                // preempt the youngest (largest idx = most recently admitted)
                let victim = running.iter().map(|r| r.idx).max().unwrap();
                return Action::Preempt(victim);
            }
            let batch: Vec<usize> = running
                .iter()
                .take(self.cfg.max_decode_batch)
                .filter(|r| r.context < self.cfg.max_context)
                .map(|r| r.idx)
                .collect();
            if !batch.is_empty() {
                return Action::Decode(batch);
            }
        }
        Action::Idle
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sched() -> Scheduler {
        Scheduler::new(SchedulerConfig {
            max_decode_batch: 4,
            max_prefill_batch: 2,
            max_prefill_tokens: 128,
            max_context: 512,
            page_tokens: 64,
        })
    }

    fn w(idx: usize, tokens: usize) -> WaitingSeq {
        WaitingSeq { idx, tokens }
    }

    fn r(idx: usize, context: usize) -> RunningSeq {
        RunningSeq { idx, context }
    }

    #[test]
    fn admits_waiting_first() {
        let s = sched();
        let a = s.decide(&[w(0, 30), w(1, 50), w(2, 10)], &[], 100);
        assert_eq!(a, Action::Prefill(vec![0, 1])); // capped at prefill batch
    }

    #[test]
    fn admission_respects_capacity() {
        let s = sched();
        // each 30-token prompt needs 1 page (+1 headroom still 1 page)
        let a = s.decide(&[w(0, 30), w(1, 200)], &[], 1);
        assert_eq!(a, Action::Prefill(vec![0]));
        // no pages at all → fall through to idle (nothing running)
        let a = s.decide(&[w(0, 30)], &[], 0);
        assert_eq!(a, Action::Idle);
    }

    #[test]
    fn oversized_prompt_blocks_fcfs() {
        let s = sched();
        let a = s.decide(&[w(0, 4000), w(1, 10)], &[], 100);
        // head of queue can never fit a prefill bucket → do not bypass FCFS
        assert_eq!(a, Action::Idle);
    }

    #[test]
    fn decodes_when_no_waiting() {
        let s = sched();
        let a = s.decide(&[], &[r(0, 70), r(1, 130)], 10);
        assert_eq!(a, Action::Decode(vec![0, 1]));
    }

    #[test]
    fn decode_batch_capped() {
        let s = sched();
        let running: Vec<RunningSeq> = (0..6).map(|i| r(i, 100 + i)).collect();
        if let Action::Decode(batch) = s.decide(&[], &running, 100) {
            assert_eq!(batch.len(), 4);
        } else {
            panic!("expected decode");
        }
    }

    #[test]
    fn preempts_youngest_under_pressure() {
        let s = sched();
        // both sequences sit exactly at page boundaries → each needs a new
        // page to decode, but only 1 page is free
        let a = s.decide(&[], &[r(0, 64), r(1, 128)], 1);
        assert_eq!(a, Action::Preempt(1));
    }

    #[test]
    fn no_preemption_when_pages_suffice() {
        let s = sched();
        let a = s.decide(&[], &[r(0, 64), r(1, 128)], 2);
        assert_eq!(a, Action::Decode(vec![0, 1]));
    }

    #[test]
    fn context_cap_excludes_full_sequences() {
        let s = sched();
        let a = s.decide(&[], &[r(0, 512)], 100);
        assert_eq!(a, Action::Idle); // at max context: cannot decode further
    }

    #[test]
    fn running_full_blocks_admission() {
        let s = sched();
        let running: Vec<RunningSeq> = (0..4).map(|i| r(i, 100)).collect();
        let a = s.decide(&[w(9, 10)], &running, 100);
        assert!(matches!(a, Action::Decode(_)));
    }

    #[test]
    fn mid_page_decode_needs_no_new_page() {
        let s = sched();
        let a = s.decide(&[], &[r(0, 65), r(1, 70)], 0);
        assert_eq!(a, Action::Decode(vec![0, 1]));
    }
}
