//! Continuous-batching scheduler: each iteration either runs the legacy
//! alternating prefill/decode policy or builds one **mixed step** that
//! interleaves chunked-prefill items with the decode batch, with
//! KV-capacity admission control and page-spill preemption backpressure.
//!
//! Pure decision logic over a snapshot — fully unit-testable without the
//! engine. The paper-relevant property: per-token instant quantization means
//! admission only needs PAGE accounting (no tail-buffer reservations), which
//! is exactly the "framework compatibility" argument of §3.1.1; mixed
//! batching keeps the decode batch full while long prompts prefill, which is
//! what makes the end-to-end dataflow optimization (§3.3) pay off at
//! long context.

/// Scheduling policy.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum SchedPolicy {
    /// strict prefill-priority alternation (the pre-chunking baseline):
    /// one step is either a prefill call or a decode call, never both
    Alternating,
    /// chunked prefill riding along with the decode batch in one step
    MixedChunked,
}

/// Scheduler view of one waiting sequence.
#[derive(Clone, Copy, Debug)]
pub struct WaitingSeq {
    pub idx: usize,
    /// fresh: prompt tokens to prefill; spilled: cache tokens to restore
    pub tokens: usize,
    /// preempted-and-spilled: admission restores pages instead of prefilling
    pub spilled: bool,
}

/// Scheduler view of one running sequence.
#[derive(Clone, Copy, Debug)]
pub struct RunningSeq {
    pub idx: usize,
    /// current cache tokens (the next decode appends at this position)
    pub context: usize,
    /// prompt tokens not yet in the cache (0 once decoding)
    pub pending_prefill: usize,
}

/// Speculative multi-token decoding policy knobs. Disabled configs take
/// exactly the non-spec decision path — `decide` returns byte-identical
/// actions, so turning spec off IS the legacy scheduler.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct SpecConfig {
    /// gate: when false, `draft_len` is ignored and no `SpecDecode` is
    /// ever emitted
    pub enabled: bool,
    /// draft tokens proposed per sequence per speculative step
    pub draft_len: usize,
}

impl SpecConfig {
    pub fn disabled() -> SpecConfig {
        SpecConfig { enabled: false, draft_len: 0 }
    }

    pub fn mtp(draft_len: usize) -> SpecConfig {
        SpecConfig { enabled: true, draft_len }
    }
}

impl Default for SpecConfig {
    fn default() -> SpecConfig {
        SpecConfig::disabled()
    }
}

/// Tiered KV-cache policy knobs (`kvcache::tiered`). Disabled configs take
/// exactly the non-tiered decision path — `decide` returns byte-identical
/// actions, so turning the tier off IS the synchronous binary scheduler.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct TieredConfig {
    /// gate: when false every other knob is ignored
    pub enabled: bool,
    /// spills/prefetches overlap with decode as EventLoop flights: the
    /// scheduler emits `SpillAsync`/`Prefetch` instead of the synchronous
    /// `Preempt`/`Resume` stalls
    pub async_io: bool,
    /// hot window in tokens: pages fully older than this re-encode into
    /// the rank-reduced cold format (0 = compression off). MUST be a page
    /// multiple so every page is wholly hot or wholly cold; per-token
    /// `resident_pages` deltas then stay in {-1, 0, 1} (a page crossing
    /// into the cold window can FREE capacity, so growth sums are signed).
    pub cold_after: usize,
    /// resident bytes of a cold page relative to the FP8 hot format
    /// (`kvcache::compress::ColdPageCodec::page_ratio`)
    pub comp_ratio: f64,
    /// latent rank r < d_c of the cold codec (prices decompress-on-access)
    pub comp_rank: usize,
}

impl TieredConfig {
    pub fn disabled() -> TieredConfig {
        TieredConfig {
            enabled: false,
            async_io: false,
            cold_after: 0,
            comp_ratio: 1.0,
            comp_rank: 0,
        }
    }

    /// Pages actually resident for a `tokens`-deep cache under this tier
    /// policy: pages fully below the hot window count at the cold codec's
    /// ratio. Identical to the plain page count when the gate is off.
    pub fn resident_pages(&self, tokens: usize, page_tokens: usize) -> usize {
        let total = tokens.div_ceil(page_tokens);
        if !self.enabled || self.cold_after == 0 {
            return total;
        }
        let cold = tokens.saturating_sub(self.cold_after) / page_tokens;
        total - cold + (cold as f64 * self.comp_ratio).ceil() as usize
    }
}

impl Default for TieredConfig {
    fn default() -> TieredConfig {
        TieredConfig::disabled()
    }
}

#[derive(Clone, Copy, Debug)]
pub struct SchedulerConfig {
    /// max sequences per decode step (largest decode bucket batch)
    pub max_decode_batch: usize,
    /// max prompts mid-prefill at once (and per alternating prefill call)
    pub max_prefill_batch: usize,
    /// max prompt tokens per monolithic prefill call (prefill bucket)
    pub max_prefill_tokens: usize,
    /// max context the decode buckets support
    pub max_context: usize,
    /// tokens per KV page
    pub page_tokens: usize,
    /// total new prefill tokens per mixed step (the chunk budget)
    pub prefill_chunk_tokens: usize,
    /// cap on chunk tokens per sequence per step (mixed bucket `t_q`)
    pub chunk_per_seq: usize,
    /// max items (decode + chunk) per mixed step (mixed bucket batch)
    pub max_step_items: usize,
    /// concurrency cap for the running set (mixed policy): decoupled from
    /// the decode batch so chunk-prefilling prompts never evict decoders
    pub max_running: usize,
    /// disaggregated-serving prefill rank: sequences never decode here — a
    /// running sequence whose prefill completed is handed off to a decode
    /// rank (`Action::Handoff`) instead of entering the decode batch
    pub disagg_prefill: bool,
    /// speculative multi-token decoding (MTP draft/verify) gate
    pub spec: SpecConfig,
    /// tiered KV cache (async host spill/prefetch + cold compression) gate
    pub tiered: TieredConfig,
    pub policy: SchedPolicy,
}

/// One chunk of prefill work inside a mixed step.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct PrefillChunk {
    /// true: admit `waiting[idx]` and prefill its first chunk;
    /// false: continue `running[idx]`'s in-flight prefill
    pub from_waiting: bool,
    pub idx: usize,
    /// new prompt tokens to advance this step
    pub tokens: usize,
}

#[derive(Clone, Debug, PartialEq, Eq)]
#[non_exhaustive]
pub enum Action {
    /// admit + fully prefill these waiting indices (alternating policy)
    Prefill(Vec<usize>),
    /// run one decode step over these running indices
    Decode(Vec<usize>),
    /// one engine step interleaving prefill chunks with the decode batch
    Mixed { prefill_chunks: Vec<PrefillChunk>, decode_idxs: Vec<usize> },
    /// one draft-then-verify speculative step over these running indices:
    /// each sequence drafts `draft_len` tokens through the MTP head, one
    /// verify pass scores them, rejected tails roll back via the cache
    /// checkpoint — the step emits 1..=draft_len+1 tokens per sequence
    SpecDecode { idxs: Vec<usize>, draft_len: usize },
    /// restore this spilled waiting sequence's pages (no engine call)
    Resume(usize),
    /// spill this running sequence's pages and move it back to waiting
    Preempt(usize),
    /// tiered async: issue a host-to-HBM prefetch of this spilled waiting
    /// sequence's pages ahead of its resume — the sequence joins the
    /// running set when the flight lands, overlapped with decode
    Prefetch(usize),
    /// tiered async: spill this running sequence's pages to host as an
    /// overlapped flight; its pages stay `TierState::SpillInFlight`
    /// (not yet free) until the transfer lands
    SpillAsync(usize),
    /// disaggregated prefill rank: this running sequence finished its
    /// prefill — serialize its KV (`kvcache::transfer::KvWireBlock`) and
    /// migrate it to a decode rank (no engine call)
    Handoff(usize),
    Idle,
}

pub struct Scheduler {
    pub cfg: SchedulerConfig,
}

impl Scheduler {
    pub fn new(cfg: SchedulerConfig) -> Scheduler {
        if cfg.tiered.enabled && cfg.tiered.cold_after > 0 {
            // a page-aligned hot window keeps every page wholly hot or
            // wholly cold, bounding per-token resident deltas to
            // {-1, 0, 1} (the growth sums below are signed for the -1)
            assert_eq!(
                cfg.tiered.cold_after % cfg.page_tokens,
                0,
                "tiered cold_after must be a page multiple"
            );
            assert!(
                cfg.tiered.comp_ratio > 0.0 && cfg.tiered.comp_ratio <= 1.0,
                "tiered comp_ratio must be in (0, 1]"
            );
        }
        Scheduler { cfg }
    }

    fn pages_for(&self, tokens: usize) -> usize {
        tokens.div_ceil(self.cfg.page_tokens)
    }

    /// Residency-aware page count (== `pages_for` with the tier off).
    fn resident_pages(&self, tokens: usize) -> usize {
        self.cfg.tiered.resident_pages(tokens, self.cfg.page_tokens)
    }

    /// How deep into a FCFS waiting queue `decide` can possibly look: a
    /// `max_prefill_batch`-sized admission prefix plus one break-check
    /// entry (admission is prefix-only under both policies, and every
    /// non-breaking iteration fills one of at most `max_prefill_batch`
    /// candidate slots). Callers holding very long queues — the simulate
    /// harness — pass `waiting[..len.min(bound)]` and get a
    /// decision-identical view without materializing thousands of entries.
    pub fn waiting_view_bound(&self) -> usize {
        self.cfg.max_prefill_batch.max(1) + 1
    }

    /// Decide the next action.
    pub fn decide(
        &self,
        waiting: &[WaitingSeq],
        running: &[RunningSeq],
        free_pages: usize,
    ) -> Action {
        // disaggregated prefill rank: a completed prefill hands off before
        // anything else — it frees this rank's pages for the next prompt
        // and never enters a decode batch here
        if self.cfg.disagg_prefill {
            if let Some(r) = running.iter().find(|r| r.pending_prefill == 0) {
                return Action::Handoff(r.idx);
            }
        }
        match self.cfg.policy {
            SchedPolicy::Alternating => self.decide_alternating(waiting, running, free_pages),
            SchedPolicy::MixedChunked => self.decide_mixed(waiting, running, free_pages),
        }
    }

    /// If the head of the queue is a spilled sequence, it resumes before
    /// anything else is admitted (FCFS: preempted work ages first). Returns
    /// None when the head is not spilled or its pages do not fit yet.
    fn resume_head(
        &self,
        waiting: &[WaitingSeq],
        running: &[RunningSeq],
        free_pages: usize,
        slot_cap: usize,
    ) -> Option<usize> {
        let w = waiting.first()?;
        if !w.spilled {
            return None;
        }
        // residency-aware (== pages_for with the tier off; the tiered gate
        // only supports the mixed policy)
        if running.len() < slot_cap && self.resident_pages(w.tokens + 1) <= free_pages {
            return Some(w.idx);
        }
        None
    }

    /// FCFS monolithic-prefill admission scan (shared by the alternating
    /// policy and the mixed policy's idle fallback): a queue prefix whose
    /// prompts fit the prefill bucket and whose pages (+1 headroom each)
    /// fit the free pool.
    fn admit_monolithic(
        &self,
        waiting: &[WaitingSeq],
        running_len: usize,
        slot_cap: usize,
        free_pages: usize,
    ) -> Vec<usize> {
        let mut admitted = Vec::new();
        if waiting.is_empty() || running_len >= slot_cap {
            return admitted;
        }
        let mut pages_needed = 0;
        let slots = slot_cap - running_len;
        for w in waiting.iter().take(self.cfg.max_prefill_batch.min(slots)) {
            if w.spilled || w.tokens > self.cfg.max_prefill_tokens {
                break; // FCFS: an oversized/parked head blocks
            }
            // residency-aware (== pages_for with the tier off): with the
            // cold-compression tier on, a long prompt's cold pages reserve
            // only ratio * pages — this is where the tier buys concurrency
            let need = self.resident_pages(w.tokens + 1); // +1 headroom token
            if pages_needed + need > free_pages {
                break;
            }
            pages_needed += need;
            admitted.push(w.idx);
        }
        admitted
    }

    /// Legacy policy (vLLM-flavoured):
    /// 1. resume a spilled head when its pages fit,
    /// 2. prefill-priority admission while capacity and bucket space allow
    ///    (FCFS; a waiting request is admitted only if its prefill fits the
    ///    bucket and its pages fit the free pool),
    /// 3. otherwise decode the running set (capped at the decode bucket);
    ///    if the step would exceed free pages, preempt (spill) the YOUNGEST
    ///    running sequence and retry.
    fn decide_alternating(
        &self,
        waiting: &[WaitingSeq],
        running: &[RunningSeq],
        free_pages: usize,
    ) -> Action {
        // pages the current decode set needs this step — a resume may only
        // use what is left over, or a preempt/resume pair ping-pongs forever
        // when decoders sit at page boundaries (context-capped sequences
        // never decode, so they never grow)
        let growth: usize = running
            .iter()
            .take(self.cfg.max_decode_batch)
            .filter(|r| r.context < self.cfg.max_context && r.context % self.cfg.page_tokens == 0)
            .count();
        if let Some(idx) = self.resume_head(
            waiting,
            running,
            free_pages.saturating_sub(growth),
            self.cfg.max_decode_batch,
        ) {
            return Action::Resume(idx);
        }
        let head_parked = waiting.first().map(|w| w.spilled).unwrap_or(false);

        // admission (skipped entirely while a spilled head waits for pages:
        // FCFS admission order admits no one past it)
        if !head_parked {
            let cap = self.cfg.max_decode_batch;
            let admitted = self.admit_monolithic(waiting, running.len(), cap, free_pages);
            if !admitted.is_empty() {
                return Action::Prefill(admitted);
            }
        }

        // decode
        if !running.is_empty() {
            // growth check: a decode appends one token at position `context`
            if growth > free_pages {
                // preempt the youngest (latest-admitted) sequence
                let victim = running.last().unwrap().idx;
                return Action::Preempt(victim);
            }
            let batch: Vec<usize> = running
                .iter()
                .take(self.cfg.max_decode_batch)
                .filter(|r| r.context < self.cfg.max_context)
                .map(|r| r.idx)
                .collect();
            if !batch.is_empty() {
                return Action::Decode(batch);
            }
        }
        Action::Idle
    }

    /// Mixed policy: one step = the decode batch + prefill chunks that share
    /// a per-step token budget.
    ///
    /// * decode first: the decode set is every running sequence whose
    ///   prefill is complete (one step item stays reserved for chunk
    ///   progress whenever prefill work exists); a page-growth overrun
    ///   preempts (spills) the youngest running sequence,
    /// * when nothing is decoding and no chunked prefill is in flight,
    ///   dribbling chunks would pay one weight pass per step for nothing —
    ///   fall back to a monolithic prefill through the prefill bucket,
    /// * at most `max_prefill_batch` prompts are mid-prefill at once (an
    ///   idle half-prefilled prompt would hold pages and a running slot
    ///   while starved of budget),
    /// * the chunk budget is served shortest-remaining-prefill-first within
    ///   the admitted set (admission itself stays FCFS): short prompts
    ///   finish in one chunk and refill the decode pool immediately, long
    ///   prompts drain on the leftover budget; every candidate is
    ///   guaranteed one token so admissions stay a full queue prefix,
    /// * fresh admission reserves the FULL remaining prefill of every
    ///   in-flight prompt (+1 headroom page each), so an admitted prompt
    ///   can always finish its prefill — chunked prefill never wedges
    ///   itself.
    fn decide_mixed(
        &self,
        waiting: &[WaitingSeq],
        running: &[RunningSeq],
        free_pages: usize,
    ) -> Action {
        let head_parked = waiting.first().map(|w| w.spilled).unwrap_or(false);

        // 1) decode set + page growth (reserve one step item for chunks
        //    whenever prefill work exists)
        let prefill_pending = running.iter().any(|r| r.pending_prefill > 0)
            || waiting.first().map(|w| !w.spilled).unwrap_or(false);
        let decode_cap = self.cfg.max_decode_batch.min(if prefill_pending {
            self.cfg.max_step_items.saturating_sub(1)
        } else {
            self.cfg.max_step_items
        });
        let decodable =
            |r: &&RunningSeq| r.pending_prefill == 0 && r.context < self.cfg.max_context;
        let decode_idxs: Vec<usize> = running
            .iter()
            .filter(decodable)
            .take(decode_cap)
            .map(|r| r.idx)
            .collect();
        // residency-aware growth: with the cold-compression tier on, a
        // boundary crossing whose oldest page simultaneously falls out of
        // the hot window can cost 0 new pages; identical to the plain
        // `context % page == 0` count when the tier is off (the resident
        // delta is 1 exactly at page boundaries)
        let growth: isize = running
            .iter()
            .filter(decodable)
            .take(decode_cap)
            .map(|r| {
                self.resident_pages(r.context + 1) as isize
                    - self.resident_pages(r.context) as isize
            })
            .sum();
        // pages left after the decode set grows; a negative growth (a page
        // crossing into the cold window frees capacity) ADDS headroom
        let after_growth = (free_pages as isize - growth).max(0) as usize;
        let tiered_async = self.cfg.tiered.enabled && self.cfg.tiered.async_io;
        // a resume may only use pages beyond the decode set's growth, or a
        // boundary-parked decode batch ping-pongs preempt/resume forever
        if let Some(idx) = self.resume_head(waiting, running, after_growth, self.cfg.max_running) {
            // the tiered gate turns the synchronous restore stall into a
            // prefetch issued ahead of the sequence joining the batch
            return if tiered_async { Action::Prefetch(idx) } else { Action::Resume(idx) };
        }
        if growth > free_pages as isize {
            // ... and the synchronous spill stall into an async host
            // eviction whose pages stay SpillInFlight — not yet free
            let victim = running.last().unwrap().idx;
            return if tiered_async {
                Action::SpillAsync(victim)
            } else {
                Action::Preempt(victim)
            };
        }
        let mut page_budget = (free_pages as isize - growth) as usize;

        // 2) monolithic fallback when chunking has nothing to ride on.
        //    Disabled on disaggregated prefill ranks: there is never a
        //    decode batch to ride, and only chunked admission adopts
        //    published prompt prefixes — prefill ranks run big-chunk
        //    admission instead of re-prefilling shared prefixes.
        if decode_idxs.is_empty()
            && !running.iter().any(|r| r.pending_prefill > 0)
            && !head_parked
            && !self.cfg.disagg_prefill
        {
            let admitted =
                self.admit_monolithic(waiting, running.len(), self.cfg.max_running, free_pages);
            if !admitted.is_empty() {
                return Action::Prefill(admitted);
            }
        }

        // 3) chunk candidates: (from_waiting, idx, cached tokens, pending)
        let mut item_slots = self.cfg.max_step_items.saturating_sub(decode_idxs.len());
        let mut admit_slots = self.cfg.max_running.saturating_sub(running.len());
        let mut cands: Vec<(bool, usize, usize, usize)> = Vec::new();
        for r in running.iter().filter(|r| r.pending_prefill > 0) {
            if item_slots == 0 || cands.len() >= self.cfg.max_prefill_batch {
                break;
            }
            cands.push((false, r.idx, r.context, r.pending_prefill));
            item_slots -= 1;
        }
        // full-reservation admission: every in-flight prefill (and each
        // admission) keeps pages for its entire remaining prompt + headroom
        let mut reserved: isize = running
            .iter()
            .filter(|r| r.pending_prefill > 0)
            .map(|r| {
                self.resident_pages(r.context + r.pending_prefill + 1) as isize
                    - self.resident_pages(r.context) as isize
            })
            .sum();
        if !head_parked {
            for w in waiting {
                if w.spilled || item_slots == 0 || admit_slots == 0 {
                    break; // FCFS: never admit past a parked spilled sequence
                }
                if cands.len() >= self.cfg.max_prefill_batch {
                    break;
                }
                if w.tokens + 1 > self.cfg.max_context {
                    break; // oversized head blocks (rejected upstream)
                }
                // residency-aware admission is where the compressed cold
                // tier buys concurrency: a long prompt's cold pages reserve
                // only ratio * pages, so more sequences fit the same HBM
                let need = self.resident_pages(w.tokens + 1) as isize;
                if reserved + need > after_growth as isize {
                    break; // FCFS: the head admission must fit first
                }
                reserved += need;
                cands.push((true, w.idx, 0, w.tokens));
                item_slots -= 1;
                admit_slots -= 1;
            }
        }

        // 4) shortest-remaining-prefill-first service over the candidates
        cands.sort_by_key(|&(_, _, _, pending)| pending);
        let mut token_budget = self.cfg.prefill_chunk_tokens;
        let mut chunks: Vec<PrefillChunk> = Vec::new();
        for (k, &(from_waiting, idx, cached, pending)) in cands.iter().enumerate() {
            // every remaining candidate is guaranteed one token while the
            // budget lasts, so the admitted set stays a full FCFS prefix of
            // the waiting queue
            let rest = cands.len() - k - 1;
            let mut take = self
                .cfg
                .chunk_per_seq
                .min(pending)
                .min(token_budget.saturating_sub(rest).max(1))
                .min(token_budget);
            let held_capacity = self.pages_for(cached) * self.cfg.page_tokens;
            let absorbable =
                (held_capacity + page_budget * self.cfg.page_tokens).saturating_sub(cached);
            take = take.min(absorbable);
            if take == 0 && !from_waiting {
                continue; // a page/budget-parked in-flight prefill just waits
            }
            // a from_waiting candidate ALWAYS emits its chunk (even with 0
            // tokens): run_mixed pops exactly the emitted admissions, so
            // dropping one would desynchronize the queue-prefix mapping
            let need = self.pages_for(cached + take) - self.pages_for(cached);
            page_budget -= need;
            token_budget -= take;
            chunks.push(PrefillChunk { from_waiting, idx, tokens: take });
        }

        if chunks.is_empty() && decode_idxs.is_empty() {
            return Action::Idle;
        }
        // 5) speculative upgrade: a pure-decode step (no chunks riding
        //    along) drafts `draft_len` tokens per sequence and verifies
        //    them in one step, provided the worst case (every draft
        //    accepted, +1 bonus token per sequence) fits the free pool —
        //    otherwise fall back to the plain mixed step. Disabled configs
        //    never reach this arm, keeping their decisions byte-identical.
        if self.cfg.spec.enabled && !decode_idxs.is_empty() && chunks.is_empty() {
            let d = self.cfg.spec.draft_len;
            let spec_growth: usize = running
                .iter()
                .filter(decodable)
                .take(decode_cap)
                .map(|r| self.pages_for(r.context + d + 1) - self.pages_for(r.context))
                .sum();
            if spec_growth <= free_pages {
                return Action::SpecDecode { idxs: decode_idxs, draft_len: d };
            }
        }
        Action::Mixed { prefill_chunks: chunks, decode_idxs }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn cfg(policy: SchedPolicy) -> SchedulerConfig {
        SchedulerConfig {
            max_decode_batch: 4,
            max_prefill_batch: 2,
            max_prefill_tokens: 128,
            max_context: 512,
            page_tokens: 64,
            prefill_chunk_tokens: 128,
            chunk_per_seq: 64,
            max_step_items: 4,
            max_running: 4,
            disagg_prefill: false,
            spec: SpecConfig::disabled(),
            tiered: TieredConfig::disabled(),
            policy,
        }
    }

    fn sched() -> Scheduler {
        Scheduler::new(cfg(SchedPolicy::Alternating))
    }

    fn mixed() -> Scheduler {
        Scheduler::new(cfg(SchedPolicy::MixedChunked))
    }

    fn w(idx: usize, tokens: usize) -> WaitingSeq {
        WaitingSeq { idx, tokens, spilled: false }
    }

    fn ws(idx: usize, tokens: usize) -> WaitingSeq {
        WaitingSeq { idx, tokens, spilled: true }
    }

    fn r(idx: usize, context: usize) -> RunningSeq {
        RunningSeq { idx, context, pending_prefill: 0 }
    }

    fn rp(idx: usize, context: usize, pending: usize) -> RunningSeq {
        RunningSeq { idx, context, pending_prefill: pending }
    }

    // --- alternating policy (the legacy baseline) ---------------------------

    #[test]
    fn admits_waiting_first() {
        let s = sched();
        let a = s.decide(&[w(0, 30), w(1, 50), w(2, 10)], &[], 100);
        assert_eq!(a, Action::Prefill(vec![0, 1])); // capped at prefill batch
    }

    #[test]
    fn admission_respects_capacity() {
        let s = sched();
        // each 30-token prompt needs 1 page (+1 headroom still 1 page)
        let a = s.decide(&[w(0, 30), w(1, 200)], &[], 1);
        assert_eq!(a, Action::Prefill(vec![0]));
        // no pages at all → fall through to idle (nothing running)
        let a = s.decide(&[w(0, 30)], &[], 0);
        assert_eq!(a, Action::Idle);
    }

    #[test]
    fn oversized_prompt_blocks_fcfs() {
        let s = sched();
        let a = s.decide(&[w(0, 4000), w(1, 10)], &[], 100);
        // head of queue can never fit a prefill bucket → do not bypass FCFS
        assert_eq!(a, Action::Idle);
    }

    #[test]
    fn decodes_when_no_waiting() {
        let s = sched();
        let a = s.decide(&[], &[r(0, 70), r(1, 130)], 10);
        assert_eq!(a, Action::Decode(vec![0, 1]));
    }

    #[test]
    fn decode_batch_capped() {
        let s = sched();
        let running: Vec<RunningSeq> = (0..6).map(|i| r(i, 100 + i)).collect();
        if let Action::Decode(batch) = s.decide(&[], &running, 100) {
            assert_eq!(batch.len(), 4);
        } else {
            panic!("expected decode");
        }
    }

    #[test]
    fn preempts_youngest_under_pressure() {
        let s = sched();
        // both sequences sit exactly at page boundaries → each needs a new
        // page to decode, but only 1 page is free
        let a = s.decide(&[], &[r(0, 64), r(1, 128)], 1);
        assert_eq!(a, Action::Preempt(1));
    }

    #[test]
    fn no_preemption_when_pages_suffice() {
        let s = sched();
        let a = s.decide(&[], &[r(0, 64), r(1, 128)], 2);
        assert_eq!(a, Action::Decode(vec![0, 1]));
    }

    #[test]
    fn context_cap_excludes_full_sequences() {
        let s = sched();
        let a = s.decide(&[], &[r(0, 512)], 100);
        assert_eq!(a, Action::Idle); // at max context: cannot decode further
    }

    #[test]
    fn running_full_blocks_admission() {
        let s = sched();
        let running: Vec<RunningSeq> = (0..4).map(|i| r(i, 100)).collect();
        let a = s.decide(&[w(9, 10)], &running, 100);
        assert!(matches!(a, Action::Decode(_)));
    }

    #[test]
    fn mid_page_decode_needs_no_new_page() {
        let s = sched();
        let a = s.decide(&[], &[r(0, 65), r(1, 70)], 0);
        assert_eq!(a, Action::Decode(vec![0, 1]));
    }

    #[test]
    fn spilled_head_resumes_before_admission() {
        let s = sched();
        // spilled head holds 100 cached tokens → restore needs 2 pages
        let a = s.decide(&[ws(0, 100), w(1, 10)], &[], 2);
        assert_eq!(a, Action::Resume(0));
        // without pages, the parked head blocks admission entirely (FCFS)
        let a = s.decide(&[ws(0, 100), w(1, 10)], &[r(0, 70)], 1);
        assert_eq!(a, Action::Decode(vec![0]));
    }

    // --- mixed chunked-prefill policy ---------------------------------------

    #[test]
    fn mixed_interleaves_decode_and_chunks() {
        let s = mixed();
        let a = s.decide(&[w(0, 200)], &[r(0, 70), r(1, 130)], 100);
        match a {
            Action::Mixed { prefill_chunks, decode_idxs } => {
                assert_eq!(decode_idxs, vec![0, 1]);
                assert_eq!(
                    prefill_chunks,
                    vec![PrefillChunk { from_waiting: true, idx: 0, tokens: 64 }]
                );
            }
            other => panic!("expected mixed, got {other:?}"),
        }
    }

    #[test]
    fn mixed_continues_inflight_prefill_before_admitting() {
        let s = mixed();
        // one in-flight prefill (256 of 456 done) + one fresh waiting
        let a = s.decide(&[w(0, 100)], &[rp(0, 256, 200), r(1, 70)], 100);
        match a {
            Action::Mixed { prefill_chunks, decode_idxs } => {
                assert_eq!(decode_idxs, vec![1]);
                // SRPT service order: the fresh 100-token prompt (shorter
                // remaining prefill) is served before the 200-token tail
                assert_eq!(
                    prefill_chunks,
                    vec![
                        PrefillChunk { from_waiting: true, idx: 0, tokens: 64 },
                        PrefillChunk { from_waiting: false, idx: 0, tokens: 64 },
                    ]
                );
            }
            other => panic!("expected mixed, got {other:?}"),
        }
    }

    #[test]
    fn mixed_falls_back_to_monolithic_prefill_when_idle() {
        let s = mixed();
        // nothing decoding, nothing mid-prefill: dribbling chunks would pay
        // a weight pass per step — admit through the prefill bucket instead
        let a = s.decide(&[w(0, 30), w(1, 50)], &[], 100);
        assert_eq!(a, Action::Prefill(vec![0, 1]));
        // …but continue chunking while a prefill is in flight
        let a = s.decide(&[], &[rp(0, 64, 100)], 100);
        assert!(matches!(a, Action::Mixed { .. }));
    }

    #[test]
    fn mixed_decode_never_starves_behind_long_prompt() {
        let s = mixed();
        // a very long prompt is mid-prefill; decodes still run every step
        let a = s.decide(&[], &[rp(0, 64, 440), r(1, 100), r(2, 200)], 50);
        match a {
            Action::Mixed { prefill_chunks, decode_idxs } => {
                assert_eq!(decode_idxs, vec![1, 2]);
                assert_eq!(prefill_chunks.len(), 1);
                assert_eq!(prefill_chunks[0].idx, 0);
                assert!(prefill_chunks[0].tokens > 0);
            }
            other => panic!("expected mixed, got {other:?}"),
        }
    }

    #[test]
    fn mixed_chunk_respects_per_seq_cap_and_budget() {
        let s = mixed();
        // single candidate: capped at chunk_per_seq (64), not the 128 budget
        let a = s.decide(&[], &[rp(0, 0, 400)], 100);
        match a {
            Action::Mixed { prefill_chunks, decode_idxs } => {
                assert!(decode_idxs.is_empty());
                assert_eq!(
                    prefill_chunks,
                    vec![PrefillChunk { from_waiting: false, idx: 0, tokens: 64 }]
                );
            }
            other => panic!("expected mixed, got {other:?}"),
        }
    }

    #[test]
    fn mixed_admission_reserves_inflight_prefill_tail() {
        let s = mixed();
        // in-flight prompt still needs 200 tokens → reserves 4 pages
        // (pages_for(64+200+1)=5 minus held 1); admitting w(0,100) needs 2
        // more; 5 free pages cover the reservation but not the admission
        let a = s.decide(&[w(0, 100)], &[rp(0, 64, 200)], 5);
        match a {
            Action::Mixed { prefill_chunks, .. } => {
                assert_eq!(prefill_chunks.len(), 1);
                assert!(!prefill_chunks[0].from_waiting);
            }
            other => panic!("expected mixed, got {other:?}"),
        }
        // with 7 free pages the admission fits alongside the reservation
        // (SRPT serves the fresh shorter prompt first)
        let a = s.decide(&[w(0, 100)], &[rp(0, 64, 200)], 7);
        match a {
            Action::Mixed { prefill_chunks, .. } => {
                assert_eq!(prefill_chunks.len(), 2);
                assert!(prefill_chunks[0].from_waiting);
                assert!(!prefill_chunks[1].from_waiting);
            }
            other => panic!("expected mixed, got {other:?}"),
        }
    }

    #[test]
    fn mixed_fcfs_admission_is_a_queue_prefix() {
        let s = mixed();
        // the 129-token head exceeds the monolithic bucket (128) and needs
        // 3 pages (+1 headroom); with only 2 free nothing admits, even
        // though w(1, 10) alone would fit — FCFS admission is a prefix
        let a = s.decide(&[w(0, 129), w(1, 10)], &[], 2);
        assert_eq!(a, Action::Idle);
        // with room, both admit this step (SRPT serves the short first,
        // but the admitted set is exactly the queue prefix {0, 1})
        let a = s.decide(&[w(0, 129), w(1, 10)], &[], 10);
        match a {
            Action::Mixed { prefill_chunks, .. } => {
                assert_eq!(prefill_chunks.len(), 2);
                assert!(prefill_chunks.iter().all(|c| c.from_waiting));
                let mut idxs: Vec<usize> = prefill_chunks.iter().map(|c| c.idx).collect();
                idxs.sort_unstable();
                assert_eq!(idxs, vec![0, 1]);
                // every admitted candidate got at least one token
                assert!(prefill_chunks.iter().all(|c| c.tokens > 0));
            }
            other => panic!("expected mixed, got {other:?}"),
        }
    }

    #[test]
    fn mixed_preempts_youngest_on_decode_growth() {
        let s = mixed();
        let a = s.decide(&[], &[r(0, 64), r(1, 128)], 1);
        assert_eq!(a, Action::Preempt(1));
    }

    #[test]
    fn mixed_resume_has_priority() {
        let s = mixed();
        let a = s.decide(&[ws(0, 100), w(1, 10)], &[], 4);
        assert_eq!(a, Action::Resume(0));
        // a parked spilled head blocks fresh admission but not decode
        let a = s.decide(&[ws(0, 500), w(1, 10)], &[r(0, 70)], 2);
        match a {
            Action::Mixed { prefill_chunks, decode_idxs } => {
                assert!(prefill_chunks.is_empty());
                assert_eq!(decode_idxs, vec![0]);
            }
            other => panic!("expected mixed, got {other:?}"),
        }
    }

    #[test]
    fn mixed_context_cap_and_idle() {
        let s = mixed();
        assert_eq!(s.decide(&[], &[r(0, 512)], 100), Action::Idle);
        assert_eq!(s.decide(&[], &[], 100), Action::Idle);
    }

    // --- speculative decoding gate ------------------------------------------

    fn spec_sched(draft_len: usize) -> Scheduler {
        let mut c = cfg(SchedPolicy::MixedChunked);
        c.spec = SpecConfig::mtp(draft_len);
        Scheduler::new(c)
    }

    #[test]
    fn spec_upgrades_pure_decode_steps() {
        let s = spec_sched(2);
        let a = s.decide(&[], &[r(0, 70), r(1, 130)], 100);
        assert_eq!(a, Action::SpecDecode { idxs: vec![0, 1], draft_len: 2 });
    }

    #[test]
    fn spec_never_fires_with_chunks_riding() {
        let s = spec_sched(2);
        // a waiting prompt produces chunks → the step stays a plain mixed
        // step (verify cost modeling only covers pure-decode batches)
        let a = s.decide(&[w(0, 200)], &[r(0, 70)], 100);
        assert!(matches!(a, Action::Mixed { .. }));
        // and a mid-prefill prompt keeps chunking too
        let a = s.decide(&[], &[rp(0, 64, 100), r(1, 70)], 100);
        assert!(matches!(a, Action::Mixed { .. }));
    }

    #[test]
    fn spec_falls_back_when_worst_case_growth_does_not_fit() {
        let s = spec_sched(4);
        // mid-page decoders: the plain decode grows 0 pages, but the
        // worst-case spec step (4 drafts + bonus each) needs 2 new pages —
        // with 1 free page the step downgrades to a plain decode
        let a = s.decide(&[], &[r(0, 60), r(1, 126)], 1);
        assert_eq!(a, Action::Mixed { prefill_chunks: vec![], decode_idxs: vec![0, 1] });
        // with room it upgrades
        let a = s.decide(&[], &[r(0, 60), r(1, 126)], 2);
        assert_eq!(a, Action::SpecDecode { idxs: vec![0, 1], draft_len: 4 });
    }

    #[test]
    fn spec_disabled_config_is_decision_identical() {
        // enabled: false must take the original return paths even with a
        // draft_len set — the gate is the ONLY thing consulted
        let mut c = cfg(SchedPolicy::MixedChunked);
        c.spec = SpecConfig { enabled: false, draft_len: 4 };
        let off = Scheduler::new(c);
        let base = mixed();
        let states: Vec<(Vec<WaitingSeq>, Vec<RunningSeq>, usize)> = vec![
            (vec![], vec![r(0, 70), r(1, 130)], 100),
            (vec![w(0, 200)], vec![r(0, 70)], 100),
            (vec![], vec![r(0, 64), r(1, 128)], 1),
            (vec![ws(0, 100), w(1, 10)], vec![], 4),
        ];
        for (wv, rv, free) in states {
            assert_eq!(off.decide(&wv, &rv, free), base.decide(&wv, &rv, free));
        }
    }

    // --- tiered KV-cache gate -----------------------------------------------

    fn tiered_sched(async_io: bool, cold_after: usize, ratio: f64) -> Scheduler {
        let mut c = cfg(SchedPolicy::MixedChunked);
        c.tiered = TieredConfig {
            enabled: true,
            async_io,
            cold_after,
            comp_ratio: ratio,
            comp_rank: 192,
        };
        Scheduler::new(c)
    }

    #[test]
    fn tiered_async_swaps_stalls_for_flights() {
        let s = tiered_sched(true, 0, 1.0);
        // growth overrun: the victim spills asynchronously instead of
        // taking a synchronous preempt stall
        let a = s.decide(&[], &[r(0, 64), r(1, 128)], 1);
        assert_eq!(a, Action::SpillAsync(1));
        // a spilled head that fits prefetches ahead of its resume
        let a = s.decide(&[ws(0, 100), w(1, 10)], &[], 4);
        assert_eq!(a, Action::Prefetch(0));
    }

    #[test]
    fn tiered_compression_admits_more_at_fixed_pages() {
        // hot window = 1 page, cold pages at half price: a 129-token prompt
        // resides in ceil(130/64)=3 total pages but only 1 of them is cold
        // at admission time... use a longer prompt so the effect is visible:
        // 257 tokens -> 5 total pages, hot window 64 -> cold = (258-64)/64
        // = 3 pages -> resident = 5 - 3 + ceil(1.5) = 4 pages
        let s = tiered_sched(true, 64, 0.5);
        assert_eq!(s.cfg.tiered.resident_pages(258, 64), 4);
        // plain scheduler needs 5 free pages to admit; tiered admits at 4
        // (257 tokens exceed the 128-token prefill bucket, so admission
        // goes through the chunk path in both cases)
        let plain = mixed();
        assert_eq!(plain.decide(&[w(0, 257)], &[], 4), Action::Idle);
        match s.decide(&[w(0, 257)], &[], 4) {
            Action::Mixed { prefill_chunks, .. } => {
                assert_eq!(prefill_chunks.len(), 1);
                assert!(prefill_chunks[0].from_waiting);
                assert!(prefill_chunks[0].tokens > 0);
            }
            other => panic!("expected chunked admission, got {other:?}"),
        }
    }

    #[test]
    fn tiered_resident_deltas_stay_bounded_and_go_negative() {
        // 128 tokens -> 2 pages, cold = 64/64 = 1 -> resident 2 - 1 +
        // ceil(0.5) = 2
        let s = tiered_sched(true, 64, 0.5);
        assert_eq!(s.cfg.tiered.resident_pages(128, 64), 2);
        // page-aligned cold_after bounds per-token deltas to {-1, 0, 1};
        // the -1 (a page crossing into the cold window frees capacity) is
        // WHY the scheduler growth sums are signed
        let mut saw_negative = false;
        for t in 0..512 {
            let d = s.cfg.tiered.resident_pages(t + 1, 64) as isize
                - s.cfg.tiered.resident_pages(t, 64) as isize;
            assert!((-1..=1).contains(&d), "delta {d} at {t}");
            saw_negative |= d < 0;
        }
        assert!(saw_negative, "half-ratio compression must free a page somewhere");
    }

    #[test]
    fn tiered_disabled_config_is_decision_identical() {
        // enabled: false must take the original return paths even with the
        // other knobs set — the gate is the ONLY thing consulted
        let mut c = cfg(SchedPolicy::MixedChunked);
        c.tiered = TieredConfig {
            enabled: false,
            async_io: true,
            cold_after: 64,
            comp_ratio: 0.5,
            comp_rank: 192,
        };
        let off = Scheduler::new(c);
        let base = mixed();
        let states: Vec<(Vec<WaitingSeq>, Vec<RunningSeq>, usize)> = vec![
            (vec![], vec![r(0, 70), r(1, 130)], 100),
            (vec![w(0, 200)], vec![r(0, 70)], 100),
            (vec![], vec![r(0, 64), r(1, 128)], 1),
            (vec![ws(0, 100), w(1, 10)], vec![], 4),
            (vec![w(0, 129), w(1, 10)], vec![], 10),
            (vec![w(0, 30), w(1, 50)], vec![], 100),
        ];
        for (wv, rv, free) in states {
            assert_eq!(off.decide(&wv, &rv, free), base.decide(&wv, &rv, free));
        }
    }

    #[test]
    fn tiered_sync_arm_keeps_blocking_actions() {
        // async_io off: the compression residency math applies but the
        // actions stay the synchronous Resume/Preempt pair
        let s = tiered_sched(false, 0, 1.0);
        assert_eq!(s.decide(&[], &[r(0, 64), r(1, 128)], 1), Action::Preempt(1));
        assert_eq!(s.decide(&[ws(0, 100)], &[], 4), Action::Resume(0));
    }

    // --- disaggregated prefill rank -----------------------------------------

    fn prefill_rank() -> Scheduler {
        let mut c = cfg(SchedPolicy::MixedChunked);
        c.disagg_prefill = true;
        Scheduler::new(c)
    }

    #[test]
    fn disagg_hands_off_completed_prefill_before_anything_else() {
        let s = prefill_rank();
        // a completed prefill (pending 0) hands off even with admissions
        // waiting and another prompt mid-prefill
        let a = s.decide(&[w(0, 100)], &[rp(0, 64, 200), r(1, 128)], 100);
        assert_eq!(a, Action::Handoff(1));
        // without any completed prefill the rank behaves like a normal
        // mixed-chunked scheduler over prefill work
        let a = s.decide(&[w(0, 100)], &[rp(0, 64, 200)], 100);
        match a {
            Action::Mixed { prefill_chunks, decode_idxs } => {
                assert!(decode_idxs.is_empty(), "prefill ranks never decode");
                assert_eq!(prefill_chunks.len(), 2);
            }
            other => panic!("expected mixed, got {other:?}"),
        }
    }

    #[test]
    fn disagg_admission_is_chunked_so_prefix_hits_adopt() {
        let s = prefill_rank();
        // nothing running: admission still goes through the CHUNK path
        // (the monolithic fallback would re-prefill adopted prefixes)
        let a = s.decide(&[w(0, 30), w(1, 50)], &[], 100);
        match a {
            Action::Mixed { prefill_chunks, decode_idxs } => {
                assert!(decode_idxs.is_empty());
                assert_eq!(prefill_chunks.len(), 2);
                assert!(prefill_chunks.iter().all(|c| c.from_waiting));
            }
            other => panic!("expected chunked admission, got {other:?}"),
        }
        // empty rank is idle
        assert_eq!(s.decide(&[], &[], 100), Action::Idle);
        // a colocated rank with the same state still goes monolithic
        let a = mixed().decide(&[w(0, 30), w(1, 50)], &[], 100);
        assert_eq!(a, Action::Prefill(vec![0, 1]));
    }

    #[test]
    fn colocated_rank_never_hands_off() {
        let s = mixed();
        let a = s.decide(&[], &[r(0, 128)], 100);
        assert_eq!(a, Action::Mixed { prefill_chunks: vec![], decode_idxs: vec![0] });
    }
}
