//! DP request router: spread requests over data-parallel ranks by
//! outstanding-token load with KV-capacity awareness (vllm-router-style
//! shortest-queue policy).
//!
//! The routing *policy* is a pure function (`pick_rank`) so it can be tested
//! and reused by the Fig. 1 simulator; `Router` wires it to real `Server`
//! ranks for the multi-rank serving examples.

use super::request::{RequestOutcome, ServeRequest};
use super::server::Server;
use crate::anyhow;

/// Snapshot of one rank's load.
#[derive(Clone, Copy, Debug)]
pub struct RankLoad {
    /// outstanding tokens (queued + remaining generation)
    pub tokens: usize,
    /// free KV pages
    pub free_pages: usize,
    /// pages the incoming request would need
    pub pages_needed: usize,
}

/// Shortest-queue with capacity awareness: prefer ranks that can hold the
/// request's KV immediately; among those, least outstanding tokens.
pub fn pick_rank(loads: &[RankLoad]) -> usize {
    let feasible = loads
        .iter()
        .enumerate()
        .filter(|(_, l)| l.free_pages >= l.pages_needed)
        .min_by_key(|(_, l)| l.tokens)
        .map(|(i, _)| i);
    feasible.unwrap_or_else(|| {
        // all ranks saturated: fall back to global shortest queue
        loads
            .iter()
            .enumerate()
            .min_by_key(|(_, l)| l.tokens)
            .map(|(i, _)| i)
            .unwrap_or(0)
    })
}

pub struct Router {
    pub ranks: Vec<Server>,
}

impl Router {
    pub fn new(ranks: Vec<Server>) -> Router {
        assert!(!ranks.is_empty());
        Router { ranks }
    }

    pub fn dp(&self) -> usize {
        self.ranks.len()
    }

    pub fn submit(&mut self, req: ServeRequest) -> usize {
        let pages_needed =
            (req.prompt.len() + req.max_new_tokens).div_ceil(crate::kvcache::PAGE_TOKENS);
        let loads: Vec<RankLoad> = self
            .ranks
            .iter()
            .map(|r| RankLoad {
                tokens: r.load_tokens(),
                free_pages: r.cache.free_pages(),
                pages_needed,
            })
            .collect();
        let rank = pick_rank(&loads);
        self.ranks[rank].submit(req);
        rank
    }

    /// Step every rank once (round-robin fairness); true if any progressed.
    pub fn step_all(&mut self) -> anyhow::Result<bool> {
        let mut any = false;
        for r in &mut self.ranks {
            any |= r.step()?;
        }
        Ok(any)
    }

    pub fn pending(&self) -> usize {
        self.ranks.iter().map(|r| r.pending()).sum()
    }

    /// Drive all ranks to completion; returns all outcomes.
    pub fn run_to_completion(&mut self) -> anyhow::Result<Vec<RequestOutcome>> {
        let t0 = std::time::Instant::now();
        while self.pending() > 0 {
            if !self.step_all()? && self.pending() > 0 {
                anyhow::bail!("router deadlock");
            }
        }
        let wall = t0.elapsed().as_secs_f64();
        let mut outcomes = Vec::new();
        for r in &mut self.ranks {
            r.metrics.wall_s += wall;
            outcomes.extend(r.finished.drain(..));
        }
        outcomes.sort_by_key(|o| o.id);
        Ok(outcomes)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn load(tokens: usize, free: usize, need: usize) -> RankLoad {
        RankLoad { tokens, free_pages: free, pages_needed: need }
    }

    #[test]
    fn picks_least_loaded_feasible() {
        let loads = [load(100, 10, 2), load(50, 10, 2), load(10, 1, 2)];
        // rank 2 is least loaded but lacks pages → rank 1
        assert_eq!(pick_rank(&loads), 1);
    }

    #[test]
    fn falls_back_when_all_saturated() {
        let loads = [load(100, 0, 2), load(50, 1, 2), load(70, 0, 2)];
        assert_eq!(pick_rank(&loads), 1);
    }

    #[test]
    fn ties_break_deterministically() {
        let loads = [load(10, 5, 1), load(10, 5, 1)];
        assert_eq!(pick_rank(&loads), 0);
    }
}
