//! DP request router: spread requests over data-parallel ranks.
//!
//! Two policies:
//!
//! * **shortest queue** (the vllm-router-style baseline): outstanding-token
//!   load with KV-capacity awareness,
//! * **prefix affinity**: consult each rank's prefix trie
//!   (`kvcache::prefix`) so requests sharing a prompt prefix land on the
//!   rank already holding those pages — the rank prefills only the unshared
//!   tail and the shared pages exist once per cluster instead of once per
//!   rank. A queue-imbalance window bounds how far affinity may override
//!   load balance, and when every rank is saturated the fallback prefers
//!   spill-capable ranks (largest reclaimable headroom) over raw queue
//!   depth.
//!
//! The routing *policies* are pure functions (`pick_rank`,
//! `pick_rank_affinity`) so they can be tested and reused by the
//! virtual-time cluster bench; `Router` wires them to real `Server` ranks
//! for the multi-rank serving path (`cluster::ClusterServer`).

use super::request::{RequestOutcome, ServeRequest};
use super::server::Server;
use crate::anyhow;
use crate::kvcache::PAGE_TOKENS;
use std::cmp::Reverse;

/// Routing policy for a DP rank set.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum RoutePolicy {
    /// capacity-aware shortest queue (the baseline)
    ShortestQueue,
    /// prefix-affinity first, shortest queue as fallback
    PrefixAffinity,
    /// disaggregated serving: admissions go to the least-loaded *prefill*
    /// rank (the first `Router::prefill_ranks` ranks); decode ranks only
    /// receive migrated sequences, placed by [`pick_handoff_rank`]
    Disagg,
}

/// Liveness of one DP rank (elastic fleet membership). Every rank starts
/// `Active`; only `cluster::ClusterServer`'s membership operations move a
/// rank out of it, so a fixed fleet never observes the other states.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum RankHealth {
    /// in the routing set, serving
    Active,
    /// finishing its queued work; receives no new admissions, retires
    /// (→ `Dead`) once empty
    Draining,
    /// failed or retired: invisible to routing, affinity probes and stepping
    Dead,
}

/// Snapshot of one rank's load.
#[derive(Clone, Copy, Debug)]
pub struct RankLoad {
    /// outstanding tokens (queued + remaining generation)
    pub tokens: usize,
    /// free KV pages
    pub free_pages: usize,
    /// pages the incoming request would need
    pub pages_needed: usize,
    /// prompt tokens this rank's prefix cache already holds for the request
    pub prefix_hit_tokens: usize,
    /// trie-retained pages reclaimable on demand (spill-free headroom)
    pub evictable_pages: usize,
}

/// Queue-imbalance guard for affinity routing: a prefix hit may pull a
/// request onto a busier rank only while that rank's outstanding tokens stay
/// within this multiple of the hit tokens above the least-loaded feasible
/// rank (re-prefilling `hit` tokens elsewhere costs about one engine pass
/// per token; queued tokens drain batched, so a few tokens of queue depth
/// per hit token is a good trade).
pub const AFFINITY_IMBALANCE_WINDOW: usize = 4;

/// Shortest-queue with capacity awareness: prefer ranks that can hold the
/// request's KV immediately; among those, least outstanding tokens.
pub fn pick_rank(loads: &[RankLoad]) -> usize {
    let feasible = loads
        .iter()
        .enumerate()
        .filter(|(_, l)| l.free_pages >= l.pages_needed)
        .min_by_key(|(_, l)| l.tokens)
        .map(|(i, _)| i);
    feasible.unwrap_or_else(|| {
        // all ranks saturated: fall back to global shortest queue
        loads
            .iter()
            .enumerate()
            .min_by_key(|(_, l)| l.tokens)
            .map(|(i, _)| i)
            .unwrap_or(0)
    })
}

/// Prefix-affinity routing. Feasibility counts evictable prefix-cache pages
/// as headroom and discounts the pages a hit would adopt; among feasible
/// ranks the largest in-window prefix hit wins, else the capacity-aware
/// shortest queue; with every rank saturated, rank pressure rebalances
/// toward the most spill-capable rank.
pub fn pick_rank_affinity(loads: &[RankLoad], page_tokens: usize) -> usize {
    if loads.is_empty() {
        return 0;
    }
    let eff_needed =
        |l: &RankLoad| l.pages_needed.saturating_sub(l.prefix_hit_tokens / page_tokens);
    let feasible: Vec<usize> = (0..loads.len())
        .filter(|&i| loads[i].free_pages + loads[i].evictable_pages >= eff_needed(&loads[i]))
        .collect();
    if feasible.is_empty() {
        // all ranks saturated: prefer the most spill-capable rank (largest
        // reclaimable headroom), then the shortest queue
        return (0..loads.len())
            .min_by_key(|&i| {
                let l = &loads[i];
                (Reverse(l.free_pages + l.evictable_pages), l.tokens, i)
            })
            .unwrap();
    }
    let min_tokens = feasible.iter().map(|&i| loads[i].tokens).min().unwrap();
    let hit = feasible
        .iter()
        .copied()
        .filter(|&i| {
            let l = &loads[i];
            l.prefix_hit_tokens > 0
                && l.tokens <= min_tokens + AFFINITY_IMBALANCE_WINDOW * l.prefix_hit_tokens
        })
        .min_by_key(|&i| (Reverse(loads[i].prefix_hit_tokens), loads[i].tokens, i));
    if let Some(i) = hit {
        return i;
    }
    feasible.into_iter().min_by_key(|i| (loads[*i].tokens, *i)).unwrap()
}

/// Decode-rank placement for a migrated sequence (disaggregated serving):
/// among ranks whose reclaimable headroom (`free + evictable`) covers the
/// sequence's full page need, prefer the largest prefix hit (normally zero
/// on decode ranks — kept so a warmed decode trie is honored), then the
/// least outstanding tokens, then index. `None` parks the transfer until a
/// rank drains — callers mark slot-saturated ranks infeasible by inflating
/// their `pages_needed` past the headroom.
pub fn pick_handoff_rank(loads: &[RankLoad]) -> Option<usize> {
    (0..loads.len())
        .filter(|&i| loads[i].free_pages + loads[i].evictable_pages >= loads[i].pages_needed)
        .min_by_key(|&i| (Reverse(loads[i].prefix_hit_tokens), loads[i].tokens, i))
}

pub struct Router {
    pub ranks: Vec<Server>,
    pub policy: RoutePolicy,
    /// disaggregated mode: ranks `0..prefill_ranks` prefill, the rest
    /// decode (0 = every rank serves the full lifecycle)
    pub prefill_ranks: usize,
    /// per-rank liveness; all `Active` on a fixed fleet
    health: Vec<RankHealth>,
}

impl Router {
    /// Shortest-queue router (the historical default).
    pub fn new(ranks: Vec<Server>) -> Router {
        Router::with_policy(ranks, RoutePolicy::ShortestQueue)
    }

    pub fn with_policy(ranks: Vec<Server>, policy: RoutePolicy) -> Router {
        assert!(!ranks.is_empty());
        assert_ne!(policy, RoutePolicy::Disagg, "use Router::disaggregated");
        let health = vec![RankHealth::Active; ranks.len()];
        Router { ranks, policy, prefill_ranks: 0, health }
    }

    /// Disaggregated router: admissions go to the least-loaded of the
    /// first `prefill_ranks` ranks; the remaining ranks decode migrants.
    pub fn disaggregated(ranks: Vec<Server>, prefill_ranks: usize) -> Router {
        assert!(prefill_ranks >= 1, "disaggregation needs a prefill rank");
        assert!(prefill_ranks < ranks.len(), "disaggregation needs a decode rank");
        let health = vec![RankHealth::Active; ranks.len()];
        Router { ranks, policy: RoutePolicy::Disagg, prefill_ranks, health }
    }

    pub fn dp(&self) -> usize {
        self.ranks.len()
    }

    pub fn health(&self, i: usize) -> RankHealth {
        self.health[i]
    }

    pub fn set_health(&mut self, i: usize, h: RankHealth) {
        self.health[i] = h;
    }

    /// Indices of ranks currently in the routing set.
    pub fn active_ranks(&self) -> Vec<usize> {
        (0..self.ranks.len()).filter(|&i| self.health[i] == RankHealth::Active).collect()
    }

    /// Grow the fleet by one active rank; returns its index.
    pub fn push_rank(&mut self, rank: Server) -> usize {
        self.ranks.push(rank);
        self.health.push(RankHealth::Active);
        self.ranks.len() - 1
    }

    /// Load snapshot of every rank for `req` (the policy input). The trie
    /// probes (prefix match + evictable scan) cost O(trie) per rank, so
    /// they run only when the affinity policy will actually read them. A
    /// disaggregated prefill rank holds only the prompt's pages (the KV
    /// migrates at handoff), so its feasibility need excludes generation.
    pub fn loads(&self, req: &ServeRequest) -> Vec<RankLoad> {
        let all: Vec<usize> = (0..self.ranks.len()).collect();
        self.loads_for(&all, req)
    }

    /// Load snapshots for a subset of ranks (in `idxs` order) — the
    /// admission path only probes ranks still in the routing set, so a
    /// drained or dead rank never sees an affinity probe.
    fn loads_for(&self, idxs: &[usize], req: &ServeRequest) -> Vec<RankLoad> {
        let pages_needed = match self.policy {
            RoutePolicy::Disagg => req.prompt.len().div_ceil(PAGE_TOKENS),
            _ => (req.prompt.len() + req.max_new_tokens).div_ceil(PAGE_TOKENS),
        };
        let probe = self.policy == RoutePolicy::PrefixAffinity;
        idxs.iter()
            .map(|&i| {
                let r = &self.ranks[i];
                let prefix_hit_tokens =
                    if probe { r.cache.prefix_match_tokens(&req.prompt) } else { 0 };
                RankLoad {
                    tokens: r.load_tokens(),
                    free_pages: r.cache.free_pages(),
                    pages_needed,
                    prefix_hit_tokens,
                    evictable_pages: if probe { r.cache.evictable_pages() } else { 0 },
                }
            })
            .collect()
    }

    pub fn submit(&mut self, req: ServeRequest) -> usize {
        // admissions see only active ranks (Disagg: active prefill ranks)
        let targets: Vec<usize> = match self.policy {
            RoutePolicy::Disagg => (0..self.prefill_ranks)
                .filter(|&i| self.health[i] == RankHealth::Active)
                .collect(),
            _ => self.active_ranks(),
        };
        assert!(!targets.is_empty(), "no active rank to route request {} to", req.id);
        let loads = self.loads_for(&targets, &req);
        let rank = targets[match self.policy {
            RoutePolicy::ShortestQueue | RoutePolicy::Disagg => pick_rank(&loads),
            RoutePolicy::PrefixAffinity => pick_rank_affinity(&loads, PAGE_TOKENS),
        }];
        self.ranks[rank].submit(req);
        rank
    }

    /// Step every live rank once (round-robin fairness); true if any
    /// progressed. Dead ranks hold no work and are skipped.
    pub fn step_all(&mut self) -> anyhow::Result<bool> {
        let mut any = false;
        for (i, r) in self.ranks.iter_mut().enumerate() {
            if self.health[i] == RankHealth::Dead {
                continue;
            }
            any |= r.step()?;
        }
        Ok(any)
    }

    pub fn pending(&self) -> usize {
        self.ranks.iter().map(|r| r.pending()).sum()
    }

    /// Drive all ranks to completion; returns all outcomes.
    pub fn run_to_completion(&mut self) -> anyhow::Result<Vec<RequestOutcome>> {
        let t0 = std::time::Instant::now();
        while self.pending() > 0 {
            if !self.step_all()? && self.pending() > 0 {
                anyhow::bail!("router deadlock");
            }
        }
        Ok(self.drain_finished(t0.elapsed().as_secs_f64()))
    }

    /// Charge `wall_s` to every rank and drain all finished outcomes,
    /// merged id-sorted (shared by this and `cluster::ClusterServer`).
    pub fn drain_finished(&mut self, wall_s: f64) -> Vec<RequestOutcome> {
        let mut outcomes = Vec::new();
        for r in &mut self.ranks {
            r.metrics.wall_s += wall_s;
            outcomes.extend(r.finished.drain(..));
        }
        outcomes.sort_by_key(|o| o.id);
        outcomes
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn load(tokens: usize, free: usize, need: usize) -> RankLoad {
        RankLoad {
            tokens,
            free_pages: free,
            pages_needed: need,
            prefix_hit_tokens: 0,
            evictable_pages: 0,
        }
    }

    fn load_hit(tokens: usize, free: usize, need: usize, hit: usize, evict: usize) -> RankLoad {
        RankLoad {
            tokens,
            free_pages: free,
            pages_needed: need,
            prefix_hit_tokens: hit,
            evictable_pages: evict,
        }
    }

    // --- shortest queue -----------------------------------------------------

    #[test]
    fn picks_least_loaded_feasible() {
        let loads = [load(100, 10, 2), load(50, 10, 2), load(10, 1, 2)];
        // rank 2 is least loaded but lacks pages → rank 1
        assert_eq!(pick_rank(&loads), 1);
    }

    #[test]
    fn falls_back_when_all_saturated() {
        let loads = [load(100, 0, 2), load(50, 1, 2), load(70, 0, 2)];
        assert_eq!(pick_rank(&loads), 1);
    }

    #[test]
    fn ties_break_deterministically() {
        let loads = [load(10, 5, 1), load(10, 5, 1)];
        assert_eq!(pick_rank(&loads), 0);
    }

    #[test]
    fn empty_feasible_set_saturated_ties_and_degenerate_input() {
        // empty feasible set: every rank lacks pages → global shortest queue
        let loads = [load(30, 0, 4), load(30, 3, 4), load(29, 0, 4)];
        assert_eq!(pick_rank(&loads), 2);
        // saturated tie on tokens → lowest index wins
        let loads = [load(30, 0, 4), load(30, 1, 4)];
        assert_eq!(pick_rank(&loads), 0);
        // no ranks at all → 0 (callers assert non-empty rank sets)
        assert_eq!(pick_rank(&[]), 0);
        assert_eq!(pick_rank_affinity(&[], 64), 0);
        // single saturated rank still routes somewhere
        assert_eq!(pick_rank(&[load(10, 0, 5)]), 0);
    }

    // --- prefix affinity ----------------------------------------------------

    #[test]
    fn affinity_prefers_prefix_hit_over_shorter_queue() {
        // rank 1 holds a 256-token prefix; rank 0 is less loaded
        let loads = [load(10, 50, 10), load_hit(100, 50, 10, 256, 0)];
        assert_eq!(pick_rank_affinity(&loads, 64), 1);
        // no hits anywhere → capacity-aware shortest queue
        let loads = [load(10, 50, 10), load(100, 50, 10)];
        assert_eq!(pick_rank_affinity(&loads, 64), 0);
    }

    #[test]
    fn affinity_imbalance_window_restores_load_balance() {
        // the hit rank's queue exceeds min + 4×hit → ignore the hit
        let loads = [load(0, 50, 10), load_hit(300, 50, 10, 64, 0)];
        assert_eq!(pick_rank_affinity(&loads, 64), 0);
        // just inside the window → affinity wins
        let loads = [load(0, 50, 10), load_hit(256, 50, 10, 64, 0)];
        assert_eq!(pick_rank_affinity(&loads, 64), 1);
    }

    #[test]
    fn affinity_largest_hit_wins_then_tokens_then_index() {
        let loads = [
            load_hit(20, 50, 10, 128, 0),
            load_hit(10, 50, 10, 256, 0),
            load_hit(30, 50, 10, 256, 0),
        ];
        assert_eq!(pick_rank_affinity(&loads, 64), 1);
        let loads = [load_hit(10, 50, 10, 256, 0), load_hit(10, 50, 10, 256, 0)];
        assert_eq!(pick_rank_affinity(&loads, 64), 0);
    }

    #[test]
    fn affinity_feasibility_discounts_adopted_pages_and_counts_evictable() {
        // 10 pages needed, 4 free: infeasible alone, but a 256-token hit
        // adopts 4 pages and 2 are evictable → 10 - 4 = 6 ≤ 4 + 2
        let loads = [load(5, 5, 10), load_hit(50, 4, 10, 256, 2)];
        assert_eq!(pick_rank_affinity(&loads, 64), 1);
        // without the hit the same rank is infeasible and rank 0 also lacks
        // pages → saturated fallback kicks in
        let loads = [load(5, 5, 10), load(50, 4, 10)];
        assert_eq!(pick_rank_affinity(&loads, 64), 0);
    }

    // --- handoff placement (disaggregated serving) --------------------------

    #[test]
    fn handoff_picks_least_loaded_feasible_decode_rank() {
        // rank 1 is least loaded and fits
        let loads = [load(100, 20, 10), load(40, 20, 10), load(60, 20, 10)];
        assert_eq!(pick_handoff_rank(&loads), Some(1));
        // least-loaded rank lacks pages, evictable headroom rescues rank 2
        let loads = [load(100, 20, 10), load(40, 5, 10), load_hit(60, 5, 10, 0, 6)];
        assert_eq!(pick_handoff_rank(&loads), Some(2));
        // nobody fits → park the transfer
        let loads = [load(10, 2, 10), load(5, 3, 10)];
        assert_eq!(pick_handoff_rank(&loads), None);
        assert_eq!(pick_handoff_rank(&[]), None);
    }

    #[test]
    fn handoff_prefers_prefix_hit_then_tokens_then_index() {
        // a warmed decode trie wins over a shorter queue
        let loads = [load(10, 20, 10), load_hit(80, 20, 10, 256, 0)];
        assert_eq!(pick_handoff_rank(&loads), Some(1));
        // ties break on index
        let loads = [load(10, 20, 10), load(10, 20, 10)];
        assert_eq!(pick_handoff_rank(&loads), Some(0));
    }

    // --- elastic membership -------------------------------------------------

    #[test]
    fn submit_skips_drained_and_dead_ranks() {
        let mk = || {
            Server::new(
                crate::runtime::ModelEngine::sim(crate::kvcache::CacheMode::Fp8).unwrap(),
                64,
            )
        };
        let mut router = Router::new(vec![mk(), mk(), mk()]);
        assert_eq!(router.active_ranks(), vec![0, 1, 2]);
        router.set_health(0, RankHealth::Draining);
        router.set_health(2, RankHealth::Dead);
        assert_eq!(router.active_ranks(), vec![1]);
        let req = ServeRequest {
            id: 7,
            prompt: vec![1, 2, 3],
            max_new_tokens: 4,
            temperature: 0.0,
            seed: 7,
            ignore_eos: true,
        };
        // the only active rank wins despite higher indices existing
        assert_eq!(router.submit(req), 1);
        let ri = router.push_rank(mk());
        assert_eq!(ri, 3);
        assert_eq!(router.active_ranks(), vec![1, 3]);
    }

    #[test]
    fn affinity_saturated_prefers_spill_capable_rank() {
        // nobody fits; rank 1 has the most reclaimable headroom (3+4) even
        // though rank 0 has the shortest queue
        let loads = [load(10, 1, 20), load_hit(80, 3, 20, 0, 4), load_hit(40, 2, 20, 0, 1)];
        assert_eq!(pick_rank_affinity(&loads, 64), 1);
        // headroom tie → shortest queue, then index
        let loads = [load_hit(80, 3, 20, 0, 4), load_hit(40, 5, 20, 0, 2)];
        assert_eq!(pick_rank_affinity(&loads, 64), 1);
    }
}
