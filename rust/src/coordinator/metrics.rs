//! Serving metrics: per-request latency breakdown and server aggregates.

use crate::util::stats::Stats;
use crate::util::table::{f1, f2, Table};
use std::time::Instant;

/// Per-request latency metrics (wall clock).
#[derive(Clone, Debug, Default)]
pub struct RequestMetrics {
    /// time to first token, seconds
    pub ttft_s: f64,
    /// mean time per output token after the first, seconds
    pub tpot_s: f64,
    /// end-to-end latency, seconds
    pub e2e_s: f64,
    /// times the request was preempted and recomputed
    pub preemptions: u32,
}

/// Wall-clock tracker attached to a live sequence.
#[derive(Clone, Debug)]
pub struct Stopwatch {
    pub submitted: Instant,
    pub first_token: Option<Instant>,
    pub last_token: Option<Instant>,
    pub tokens: usize,
    pub preemptions: u32,
}

impl Stopwatch {
    pub fn start() -> Stopwatch {
        Stopwatch {
            submitted: Instant::now(),
            first_token: None,
            last_token: None,
            tokens: 0,
            preemptions: 0,
        }
    }

    pub fn on_token(&mut self) {
        let now = Instant::now();
        if self.first_token.is_none() {
            self.first_token = Some(now);
        }
        self.last_token = Some(now);
        self.tokens += 1;
    }

    pub fn finish(&self) -> RequestMetrics {
        let first = self.first_token.unwrap_or(self.submitted);
        let last = self.last_token.unwrap_or(first);
        let ttft = (first - self.submitted).as_secs_f64();
        let decode_span = (last - first).as_secs_f64();
        let tpot = if self.tokens > 1 {
            decode_span / (self.tokens - 1) as f64
        } else {
            0.0
        };
        RequestMetrics {
            ttft_s: ttft,
            tpot_s: tpot,
            e2e_s: (last - self.submitted).as_secs_f64(),
            preemptions: self.preemptions,
        }
    }
}

/// Server-level aggregates.
#[derive(Debug, Default)]
pub struct ServerMetrics {
    pub ttft: Stats,
    pub tpot: Stats,
    pub e2e: Stats,
    pub total_prompt_tokens: u64,
    pub total_generated_tokens: u64,
    pub total_preemptions: u64,
    pub wall_s: f64,
    pub decode_steps: u64,
    pub decode_batch: Stats,
    /// mixed steps executed (chunked-prefill policy)
    pub mixed_steps: u64,
    /// mixed steps whose decode batch was non-empty (non-starvation signal)
    pub mixed_steps_with_decode: u64,
    /// prompt tokens prefilled through chunks
    pub chunk_tokens: u64,
    /// prompt tokens served from the prefix cache instead of prefilling
    pub prefix_hit_tokens: u64,
    /// page-spill preemptions performed
    pub spills: u64,
    /// spilled sequences restored
    pub restores: u64,
    /// pages moved to host memory by spills
    pub spilled_pages: u64,
    /// per-sequence speculative steps executed (one per sequence per
    /// draft-then-verify batch)
    pub spec_steps: u64,
    /// draft tokens proposed across all spec steps
    pub spec_drafted: u64,
    /// draft tokens accepted by verification (≤ spec_drafted)
    pub spec_accepted: u64,
    /// sequences handed off to a decode rank (disaggregated prefill rank)
    pub handoffs_out: u64,
    /// migrated sequences accepted from a prefill rank (decode rank)
    pub handoffs_in: u64,
    /// KV bytes serialized onto the wire by outbound handoffs
    pub handoff_wire_bytes: u64,
}

impl ServerMetrics {
    pub fn record(&mut self, m: &RequestMetrics, prompt_tokens: usize, gen_tokens: usize) {
        self.ttft.push(m.ttft_s);
        self.tpot.push(m.tpot_s);
        self.e2e.push(m.e2e_s);
        self.total_prompt_tokens += prompt_tokens as u64;
        self.total_generated_tokens += gen_tokens as u64;
        self.total_preemptions += m.preemptions as u64;
    }

    /// The wall-clock-free counters: two runs over the same trace must agree
    /// on every one of these exactly (the serving determinism contract).
    pub fn counters(&self) -> Vec<(&'static str, u64)> {
        vec![
            ("requests", self.e2e.len() as u64),
            ("prompt_tokens", self.total_prompt_tokens),
            ("generated_tokens", self.total_generated_tokens),
            ("preemptions", self.total_preemptions),
            ("decode_steps", self.decode_steps),
            ("decode_batches", self.decode_batch.len() as u64),
            ("decode_tokens_batched", self.decode_batch.sum() as u64),
            ("mixed_steps", self.mixed_steps),
            ("mixed_steps_with_decode", self.mixed_steps_with_decode),
            ("chunk_tokens", self.chunk_tokens),
            ("prefix_hit_tokens", self.prefix_hit_tokens),
            ("spec_steps", self.spec_steps),
            ("spec_drafted", self.spec_drafted),
            ("spec_accepted", self.spec_accepted),
            ("spills", self.spills),
            ("restores", self.restores),
            ("spilled_pages", self.spilled_pages),
            ("handoffs_out", self.handoffs_out),
            ("handoffs_in", self.handoffs_in),
            ("handoff_wire_bytes", self.handoff_wire_bytes),
        ]
    }

    /// Decode throughput over the run (generated tokens / wall time).
    pub fn gen_tokens_per_s(&self) -> f64 {
        if self.wall_s > 0.0 {
            self.total_generated_tokens as f64 / self.wall_s
        } else {
            0.0
        }
    }

    pub fn render(&self, title: &str) -> String {
        let mut t = Table::new(title, &["metric", "value"]);
        t.row(vec!["requests".into(), format!("{}", self.e2e.len())]);
        t.row(vec!["generated tokens".into(), format!("{}", self.total_generated_tokens)]);
        t.row(vec!["wall time (s)".into(), f2(self.wall_s)]);
        t.row(vec!["gen throughput (tok/s)".into(), f1(self.gen_tokens_per_s())]);
        t.row(vec!["mean decode batch".into(), f2(self.decode_batch.mean())]);
        let p50_p95 =
            |s: &Stats| format!("{} / {}", f1(s.median() * 1e3), f1(s.percentile(95.0) * 1e3));
        t.row(vec!["TTFT p50/p95 (ms)".into(), p50_p95(&self.ttft)]);
        t.row(vec!["TPOT p50/p95 (ms)".into(), p50_p95(&self.tpot)]);
        t.row(vec!["preemptions (spills)".into(), format!("{}", self.total_preemptions)]);
        if self.handoffs_out + self.handoffs_in > 0 {
            t.row(vec![
                "handoffs (out / in)".into(),
                format!("{} / {}", self.handoffs_out, self.handoffs_in),
            ]);
            t.row(vec![
                "handoff wire MB".into(),
                f2(self.handoff_wire_bytes as f64 / 1e6),
            ]);
        }
        if self.spec_steps > 0 {
            t.row(vec![
                "spec steps (drafted / accepted)".into(),
                format!("{} ({} / {})", self.spec_steps, self.spec_drafted, self.spec_accepted),
            ]);
            t.row(vec![
                "accepted per spec step".into(),
                f2(1.0 + self.spec_accepted as f64 / self.spec_steps as f64),
            ]);
        }
        if self.mixed_steps > 0 {
            t.row(vec![
                "mixed steps (w/ decode)".into(),
                format!("{} ({})", self.mixed_steps, self.mixed_steps_with_decode),
            ]);
            t.row(vec!["chunk-prefilled tokens".into(), format!("{}", self.chunk_tokens)]);
            t.row(vec![
                "prefix-cache hit tokens".into(),
                format!("{}", self.prefix_hit_tokens),
            ]);
        }
        t.render()
    }
}

/// Cluster-level aggregates no single rank can observe: where requests were
/// routed and the peak of total page allocation across all ranks (the
/// capacity metric prefix-affinity routing is meant to shrink — shared
/// prefixes held once per cluster instead of once per rank).
#[derive(Clone, Debug, Default)]
pub struct ClusterMetrics {
    /// requests routed to each rank
    pub routed: Vec<u64>,
    /// max over lock-step rounds of Σ per-rank allocated pages
    pub peak_pages_used: usize,
    /// elastic membership (all zero on a fixed fleet): rank failures
    /// injected, ranks joined, drains initiated
    pub fails: u64,
    pub joins: u64,
    pub drains: u64,
    /// live sequences exported off a failed rank for re-migration
    pub evacuated: u64,
    /// evacuated sequences re-imported on a survivor (≤ evacuated)
    pub recovered: u64,
    /// requests dropped: KV unrecoverable (spilled to the dead host or
    /// recovery disabled) or no surviving rank could ever place them
    pub dropped: u64,
}

impl ClusterMetrics {
    pub fn new(dp: usize) -> ClusterMetrics {
        ClusterMetrics {
            routed: vec![0; dp],
            peak_pages_used: 0,
            fails: 0,
            joins: 0,
            drains: 0,
            evacuated: 0,
            recovered: 0,
            dropped: 0,
        }
    }

    /// Fold one round's total allocated-page count into the peak.
    pub fn observe_pages(&mut self, used: usize) {
        self.peak_pages_used = self.peak_pages_used.max(used);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn cluster_metrics_track_peak_and_routing() {
        let mut cm = ClusterMetrics::new(2);
        cm.observe_pages(10);
        cm.observe_pages(25);
        cm.observe_pages(7);
        cm.routed[1] += 3;
        assert_eq!(cm.peak_pages_used, 25);
        assert_eq!(cm.routed, vec![0, 3]);
    }

    #[test]
    fn stopwatch_counts_tokens() {
        let mut sw = Stopwatch::start();
        for _ in 0..5 {
            sw.on_token();
        }
        let m = sw.finish();
        assert_eq!(sw.tokens, 5);
        assert!(m.ttft_s >= 0.0 && m.e2e_s >= m.ttft_s);
    }

    #[test]
    fn aggregates_and_render() {
        let mut sm = ServerMetrics::default();
        sm.wall_s = 2.0;
        sm.record(
            &RequestMetrics { ttft_s: 0.1, tpot_s: 0.02, e2e_s: 0.5, preemptions: 1 },
            10,
            20,
        );
        sm.record(
            &RequestMetrics { ttft_s: 0.2, tpot_s: 0.03, e2e_s: 0.8, preemptions: 0 },
            5,
            10,
        );
        assert_eq!(sm.total_generated_tokens, 30);
        assert_eq!(sm.gen_tokens_per_s(), 15.0);
        assert_eq!(sm.total_preemptions, 1);
        let r = sm.render("test");
        assert!(r.contains("gen throughput"));
    }
}
