//! Serving request/response types.

/// An inference request submitted to the coordinator.
#[derive(Clone, Debug)]
pub struct ServeRequest {
    pub id: u64,
    pub prompt: Vec<i32>,
    pub max_new_tokens: usize,
    /// 0.0 → greedy
    pub temperature: f32,
    /// sampling seed (deterministic parity runs share seeds across pipelines)
    pub seed: u64,
    /// benchmark mode: never stop on EOS (length controlled by max_new_tokens)
    pub ignore_eos: bool,
}

impl ServeRequest {
    pub fn greedy(id: u64, prompt: Vec<i32>, max_new_tokens: usize) -> ServeRequest {
        ServeRequest { id, prompt, max_new_tokens, temperature: 0.0, seed: id,
            ignore_eos: false }
    }
}

#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum FinishReason {
    Eos,
    MaxTokens,
    Preempted, // terminal only if the server is draining
}

/// A completed request.
#[derive(Clone, Debug)]
pub struct RequestOutcome {
    pub id: u64,
    pub prompt_tokens: usize,
    pub generated: Vec<i32>,
    pub finish: FinishReason,
    pub metrics: super::metrics::RequestMetrics,
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn greedy_constructor() {
        let r = ServeRequest::greedy(7, vec![1, 2, 3], 10);
        assert_eq!(r.temperature, 0.0);
        assert_eq!(r.seed, 7);
        assert_eq!(r.max_new_tokens, 10);
    }
}
