//! The serving loop: one DP rank = one engine + one paged cache + the
//! continuous-batching scheduler. Ranks compose into a data-parallel
//! cluster through `cluster::ClusterServer`, which routes requests by
//! prefix affinity against this cache's trie and drives ranks lock-step.
//!
//! Default policy is **mixed chunked-prefill**: every step runs the full
//! decode batch plus prefill chunks in ONE engine call, so a long prompt
//! never stalls running decoders. Admission adopts shared prompt prefixes
//! from the cache's prefix trie, completed prompt pages are published back,
//! and preemption spills pages to host memory (restored verbatim on
//! resume — a preempted sequence emits byte-identical output).

use super::metrics::ServerMetrics;
use super::request::{FinishReason, RequestOutcome, ServeRequest};
use super::scheduler::{
    Action, PrefillChunk, RunningSeq, SchedPolicy, Scheduler, SchedulerConfig, SpecConfig,
    TieredConfig, WaitingSeq,
};
use super::sequence::{SeqPhase, Sequence};
use crate::anyhow;
use crate::kvcache::{KvWireBlock, PagedKvCache, PAGE_TOKENS};
use crate::runtime::{ArtifactKind, ModelEngine};
use std::collections::VecDeque;
use std::time::Instant;

/// Consecutive unproductive scheduler steps tolerated before bailing
/// (preempt/resume churn without any engine progress = livelock).
const STALL_LIMIT: usize = 10_000;

/// What `Server::evacuate` salvaged off a failed rank.
pub struct Evacuation {
    /// fresh waiting requests (no KV yet) — resubmit through the router
    pub resubmit: Vec<ServeRequest>,
    /// live sequences exported to the wire for re-migration elsewhere
    pub migrate: Vec<(Sequence, KvWireBlock)>,
    /// sequences whose state was unrecoverable
    pub dropped: usize,
}

pub struct Server {
    pub engine: ModelEngine,
    pub cache: PagedKvCache,
    pub scheduler: Scheduler,
    waiting: VecDeque<Sequence>,
    running: Vec<Sequence>,
    pub finished: Vec<RequestOutcome>,
    /// disaggregated prefill rank: sequences whose prefill completed,
    /// serialized and awaiting migration — the cluster layer drains this
    /// and delivers each to a decode rank (`accept_handoff`)
    pub handoff_outbox: Vec<(Sequence, KvWireBlock)>,
    pub metrics: ServerMetrics,
    eos: i32,
}

impl Server {
    /// Build a server around a loaded engine with `capacity_pages` of KV,
    /// using the mixed chunked-prefill scheduler.
    pub fn new(engine: ModelEngine, capacity_pages: usize) -> Server {
        Server::with_policy(engine, capacity_pages, SchedPolicy::MixedChunked)
    }

    /// Build a server with an explicit scheduling policy (the alternating
    /// baseline remains available for A/B comparison).
    pub fn with_policy(
        engine: ModelEngine,
        capacity_pages: usize,
        policy: SchedPolicy,
    ) -> Server {
        let cache = PagedKvCache::new(engine.cache_config(capacity_pages));
        let mode = engine.mode_str();
        let max_for = |kind: ArtifactKind, field: fn(&crate::runtime::ArtifactInfo) -> usize| {
            engine
                .manifest
                .artifacts
                .values()
                .filter(|a| a.kind == kind && a.mode == mode)
                .map(field)
                .max()
        };
        let max_decode_batch = max_for(ArtifactKind::Decode, |a| a.batch).unwrap_or(1);
        let max_prefill_batch = max_for(ArtifactKind::Prefill, |a| a.batch).unwrap_or(1);
        let max_prefill_tokens = max_for(ArtifactKind::Prefill, |a| a.seq).unwrap_or(0);
        let chunk_per_seq = max_for(ArtifactKind::Mixed, |a| a.t_q).unwrap_or(PAGE_TOKENS);
        let max_step_items = max_for(ArtifactKind::Mixed, |a| a.batch).unwrap_or(max_decode_batch);
        let cfg = SchedulerConfig {
            max_decode_batch,
            max_prefill_batch,
            max_prefill_tokens,
            max_context: engine.max_context(),
            page_tokens: PAGE_TOKENS,
            // default chunk budget: two page-sized chunks per step — one
            // keeps the longest prompt moving, the other admits/advances a
            // second prompt, while decode throughput stays flat
            prefill_chunk_tokens: 2 * chunk_per_seq,
            chunk_per_seq,
            max_step_items,
            // concurrency beyond the decode bucket: chunk-prefilling
            // prompts must not evict decoders from the running set
            max_running: max_decode_batch + max_prefill_batch,
            disagg_prefill: false,
            spec: SpecConfig::disabled(),
            tiered: TieredConfig::disabled(),
            policy,
        };
        let eos = engine.manifest.model.eos;
        Server {
            engine,
            cache,
            scheduler: Scheduler::new(cfg),
            waiting: VecDeque::new(),
            running: Vec::new(),
            finished: Vec::new(),
            handoff_outbox: Vec::new(),
            metrics: ServerMetrics::default(),
            eos,
        }
    }

    /// Turn this rank into a disaggregated **prefill** rank: the scheduler
    /// hands completed prefills off (`Action::Handoff`) instead of ever
    /// decoding them.
    pub fn set_disagg_prefill(&mut self) {
        self.scheduler.cfg.disagg_prefill = true;
    }

    /// Enable speculative multi-token decoding: pure-decode steps upgrade to
    /// draft-then-verify (`Action::SpecDecode`), emitting up to
    /// `draft_len + 1` tokens per sequence per step. Requires verify buckets
    /// wide enough for the carried token plus the drafts.
    pub fn enable_spec(&mut self, draft_len: usize) -> anyhow::Result<()> {
        anyhow::ensure!(draft_len >= 1, "speculative decoding needs draft_len >= 1");
        let cap = self
            .engine
            .manifest
            .artifacts
            .values()
            .filter(|a| a.kind == ArtifactKind::Verify && a.mode == self.engine.mode_str())
            .map(|a| a.t_q)
            .max()
            .unwrap_or(0);
        anyhow::ensure!(
            draft_len + 1 <= cap,
            "draft_len {draft_len} needs a verify bucket with t_q >= {} (largest: {cap})",
            draft_len + 1
        );
        self.scheduler.cfg.spec = SpecConfig::mtp(draft_len);
        Ok(())
    }

    pub fn submit(&mut self, req: ServeRequest) {
        assert!(!req.prompt.is_empty(), "empty prompt");
        match self.scheduler.cfg.policy {
            SchedPolicy::Alternating => assert!(
                req.prompt.len() <= self.scheduler.cfg.max_prefill_tokens,
                "prompt {} exceeds prefill bucket {}",
                req.prompt.len(),
                self.scheduler.cfg.max_prefill_tokens
            ),
            SchedPolicy::MixedChunked => assert!(
                req.prompt.len() < self.scheduler.cfg.max_context,
                "prompt {} exceeds max context {}",
                req.prompt.len(),
                self.scheduler.cfg.max_context
            ),
        }
        self.waiting.push_back(Sequence::new(req, self.eos));
    }

    pub fn pending(&self) -> usize {
        self.waiting.len() + self.running.len() + self.handoff_outbox.len()
    }

    /// Queue-depth signal for the DP router (tokens outstanding).
    pub fn load_tokens(&self) -> usize {
        let queued: usize =
            self.waiting.iter().map(|s| s.request.prompt.len() + s.request.max_new_tokens).sum();
        let remaining: usize =
            self.running.iter().map(|s| s.request.max_new_tokens - s.generated.len()).sum();
        queued + remaining
    }

    /// (id, cache tokens, pending prefill tokens, generated tokens) per
    /// running sequence — read-only observability for tests and debugging.
    pub fn running_info(&self) -> Vec<(u64, usize, usize, usize)> {
        self.running
            .iter()
            .map(|s| {
                (s.id(), self.cache.tokens_of(s.id()), s.pending_prefill(), s.generated.len())
            })
            .collect()
    }

    /// Waiting-queue ids in FCFS order.
    pub fn waiting_ids(&self) -> Vec<u64> {
        self.waiting.iter().map(|s| s.id()).collect()
    }

    /// (waiting, running) queue depths — the cluster drive's stuck-rank
    /// diagnostics read this when a rank stops making progress.
    pub fn queue_depths(&self) -> (usize, usize) {
        (self.waiting.len(), self.running.len())
    }

    /// One scheduling iteration. Returns false when fully idle.
    pub fn step(&mut self) -> anyhow::Result<bool> {
        // length-cap sweep: a sequence whose cache reached the largest
        // decode bucket can never decode again — finish it as a length stop
        // instead of wedging the scheduler into a permanent Idle
        let max_ctx = self.scheduler.cfg.max_context;
        let mut capped: Vec<usize> = (0..self.running.len())
            .filter(|&i| self.cache.tokens_of(self.running[i].id()) >= max_ctx)
            .collect();
        if !capped.is_empty() {
            capped.sort_unstable_by(|a, b| b.cmp(a));
            for i in capped {
                let mut seq = self.running.remove(i);
                seq.phase = SeqPhase::Finished(FinishReason::MaxTokens);
                self.cache.release(seq.id());
                self.finish(seq);
            }
            return Ok(true);
        }

        // handoffs are free for this rank (serialize + async send), so a
        // disaggregated prefill rank drains every completed prefill into
        // the outbox and still takes its real action within this step
        let mut handed = false;
        let action = loop {
            let waiting_view: Vec<WaitingSeq> = self
                .waiting
                .iter()
                .enumerate()
                .map(|(i, s)| WaitingSeq {
                    idx: i,
                    tokens: match &s.spilled {
                        Some(sp) => sp.tokens(),
                        None => s.request.prompt.len(),
                    },
                    spilled: s.spilled.is_some(),
                })
                .collect();
            let running_view: Vec<RunningSeq> = self
                .running
                .iter()
                .enumerate()
                .map(|(i, s)| RunningSeq {
                    idx: i,
                    context: self.cache.tokens_of(s.id()),
                    pending_prefill: s.pending_prefill(),
                })
                .collect();
            let action =
                self.scheduler
                    .decide(&waiting_view, &running_view, self.cache.available_pages());
            match action {
                Action::Handoff(idx) => {
                    // serialize the sequence's KV into the wire format and
                    // park it in the outbox — the cluster layer migrates it
                    // to a decode rank. The pages free immediately (the
                    // wire block carries the bytes).
                    let seq = self.running.remove(idx);
                    let wire = self
                        .cache
                        .export_wire(seq.id())
                        .map_err(|e| anyhow::anyhow!("export seq {}: {e:?}", seq.id()))?;
                    self.cache.release(seq.id());
                    self.metrics.handoffs_out += 1;
                    self.metrics.handoff_wire_bytes += wire.wire_bytes() as u64;
                    self.handoff_outbox.push((seq, wire));
                    handed = true;
                }
                other => break other,
            }
        };

        match action {
            Action::Prefill(idxs) => {
                // idxs are FCFS-prefix indices into `waiting` (fresh only)
                let mut batch = Vec::new();
                for _ in 0..idxs.len() {
                    let mut seq = self.waiting.pop_front().unwrap();
                    seq.phase = SeqPhase::Running;
                    batch.push(seq);
                }
                let items: Vec<(u64, Vec<i32>)> = batch
                    .iter()
                    .map(|s| {
                        self.cache.register(s.id());
                        (s.id(), s.request.prompt.clone())
                    })
                    .collect();
                let out = self.engine.prefill(&mut self.cache, &items)?;
                for (mut seq, logits) in batch.into_iter().zip(out.logits) {
                    seq.prefilled = seq.request.prompt.len();
                    // publish the prompt's full pages for prefix reuse
                    // (mixed policy only — the alternating baseline pre-dates
                    // sharing; monolithic admission still re-prefills on a
                    // hit since the whole-prompt engine call cannot skip
                    // adopted tokens, but later chunked admissions benefit)
                    if self.scheduler.cfg.policy == SchedPolicy::MixedChunked {
                        let full = (seq.prefilled / PAGE_TOKENS) * PAGE_TOKENS;
                        if full > 0 {
                            self.cache.publish_prefix(seq.id(), &seq.request.prompt[..full]);
                        }
                    }
                    let done = seq.accept_logits(&logits);
                    if done {
                        let id = seq.id();
                        self.cache.release(id);
                        self.finish(seq);
                    } else {
                        self.running.push(seq);
                    }
                }
            }
            Action::Decode(idxs) => {
                let items: Vec<(u64, i32)> = idxs
                    .iter()
                    .map(|&i| (self.running[i].id(), self.running[i].next_input))
                    .collect();
                self.metrics.decode_steps += 1;
                self.metrics.decode_batch.push(items.len() as f64);
                let out = self.engine.decode(&mut self.cache, &items)?;
                // accept logits; collect finished (iterate in reverse index
                // order so removals do not shift pending indices)
                let mut done: Vec<usize> = Vec::new();
                for (k, &i) in idxs.iter().enumerate() {
                    if self.running[i].accept_logits(&out.logits[k]) {
                        done.push(i);
                    }
                }
                done.sort_unstable_by(|a, b| b.cmp(a));
                for i in done {
                    let seq = self.running.remove(i);
                    self.cache.release(seq.id());
                    self.finish(seq);
                }
            }
            Action::Mixed { prefill_chunks, decode_idxs } => {
                self.run_mixed(prefill_chunks, decode_idxs)?;
            }
            Action::SpecDecode { idxs, draft_len } => {
                self.run_spec(idxs, draft_len)?;
            }
            // The in-process server has no virtual clock to overlap host
            // transfers against, so the async tier actions degrade to their
            // blocking equivalents: a prefetch is a synchronous restore, an
            // async spill a synchronous preempt. Only the simulate harness
            // (and the cluster layer's virtual drive) model the overlap.
            Action::Resume(idx) | Action::Prefetch(idx) => {
                debug_assert_eq!(idx, 0, "only the queue head resumes");
                let mut seq = self.waiting.pop_front().unwrap();
                let sp = seq.take_spilled().expect("resume target carries spilled KV");
                self.cache
                    .restore(seq.id(), sp)
                    .map_err(|e| anyhow::anyhow!("restore seq {}: {e:?}", seq.id()))?;
                seq.phase = SeqPhase::Running;
                self.metrics.restores += 1;
                self.running.push(seq);
            }
            Action::Preempt(idx) | Action::SpillAsync(idx) => {
                let mut seq = self.running.remove(idx);
                let sp = self
                    .cache
                    .spill(seq.id())
                    .map_err(|e| anyhow::anyhow!("spill seq {}: {e:?}", seq.id()))?;
                self.metrics.spills += 1;
                self.metrics.spilled_pages += sp.pages() as u64;
                seq.preempt(sp);
                // re-queue at the FRONT: preempted work ages first
                self.waiting.push_front(seq);
            }
            Action::Handoff(_) => unreachable!("drained by the handoff loop above"),
            Action::Idle => return Ok(handed),
        }
        Ok(true)
    }

    /// Execute one mixed step: admit the scheduled waiting sequences
    /// (adopting shared prompt prefixes), then run their prefill chunks
    /// interleaved with the decode batch in one engine call.
    fn run_mixed(
        &mut self,
        chunks: Vec<PrefillChunk>,
        decode_idxs: Vec<usize>,
    ) -> anyhow::Result<()> {
        // 1) admissions — the from_waiting chunks reference a FCFS prefix
        //    of the waiting queue by position (the chunk LIST is in service
        //    order, shortest remaining prefill first)
        let base = self.running.len();
        let n_admit = chunks.iter().filter(|c| c.from_waiting).count();
        #[cfg(debug_assertions)]
        {
            let mut idxs: Vec<usize> =
                chunks.iter().filter(|c| c.from_waiting).map(|c| c.idx).collect();
            idxs.sort_unstable();
            debug_assert_eq!(idxs, (0..n_admit).collect::<Vec<_>>(), "queue-prefix admissions");
        }
        for _ in 0..n_admit {
            let mut seq = self.waiting.pop_front().unwrap();
            seq.phase = SeqPhase::Running;
            self.cache.register(seq.id());
            let hit = self.cache.adopt_prefix(seq.id(), &seq.request.prompt);
            if hit > 0 {
                seq.prefilled = hit;
                self.metrics.prefix_hit_tokens += hit as u64;
            }
            self.running.push(seq);
        }
        // pops preserve order: waiting[idx] is now running[base + idx]
        let granted: Vec<(usize, usize)> = chunks
            .iter()
            .map(|c| (if c.from_waiting { base + c.idx } else { c.idx }, c.tokens))
            .collect();

        // 2) engine items (a prefix hit may shrink or absorb a grant)
        let mut chunk_owners: Vec<usize> = Vec::with_capacity(granted.len());
        let mut engine_chunks: Vec<(u64, Vec<i32>)> = Vec::with_capacity(granted.len());
        for &(ridx, grant) in &granted {
            let s = &self.running[ridx];
            let toks = s.next_chunk(grant);
            if !toks.is_empty() {
                chunk_owners.push(ridx);
                engine_chunks.push((s.id(), toks));
            }
        }
        let decode_items: Vec<(u64, i32)> = decode_idxs
            .iter()
            .map(|&i| (self.running[i].id(), self.running[i].next_input))
            .collect();
        if engine_chunks.is_empty() && decode_items.is_empty() {
            return Ok(()); // the admissions alone were the step's progress
        }

        let out = self.engine.step_mixed(&mut self.cache, &engine_chunks, &decode_items)?;
        self.metrics.mixed_steps += 1;
        if !decode_items.is_empty() {
            self.metrics.mixed_steps_with_decode += 1;
            self.metrics.decode_steps += 1;
            self.metrics.decode_batch.push(decode_items.len() as f64);
        }

        // 3) chunk results: advance prefill, publish completed prompt pages,
        //    sample the first token when the prompt just completed
        let mut done: Vec<usize> = Vec::new();
        let mut publishes: Vec<(u64, Vec<i32>)> = Vec::new();
        for (k, &ridx) in chunk_owners.iter().enumerate() {
            let took = engine_chunks[k].1.len();
            let s = &mut self.running[ridx];
            let full_before = s.prefilled / PAGE_TOKENS;
            s.prefilled += took;
            self.metrics.chunk_tokens += took as u64;
            // publish only when this chunk completed a new full page (the
            // trie is first-publisher-wins, so re-publishing is a no-op walk)
            let full = (s.prefilled / PAGE_TOKENS) * PAGE_TOKENS;
            if full > full_before * PAGE_TOKENS {
                publishes.push((s.id(), s.request.prompt[..full].to_vec()));
            }
            if s.pending_prefill() == 0 && s.accept_logits(&out.chunk_logits[k]) {
                done.push(ridx);
            }
        }
        for (id, prefix) in publishes {
            self.cache.publish_prefix(id, &prefix);
        }

        // 4) decode results
        for (k, &ridx) in decode_idxs.iter().enumerate() {
            if self.running[ridx].accept_logits(&out.decode_logits[k]) {
                done.push(ridx);
            }
        }
        done.sort_unstable_by(|a, b| b.cmp(a));
        for i in done {
            let seq = self.running.remove(i);
            self.cache.release(seq.id());
            self.finish(seq);
        }
        Ok(())
    }

    /// Execute one speculative step over a pure-decode batch: checkpoint
    /// each sequence's cache, draft `draft_len` tokens through the engine's
    /// drafter, score the carried token plus the drafts in ONE verify call,
    /// then accept the longest draft prefix the target model reproduces and
    /// roll the rejected tail's KV back to the checkpoint.
    fn run_spec(&mut self, idxs: Vec<usize>, draft_len: usize) -> anyhow::Result<()> {
        let mut ckpts = Vec::with_capacity(idxs.len());
        let mut drafts = Vec::with_capacity(idxs.len());
        let mut items: Vec<(u64, Vec<i32>)> = Vec::with_capacity(idxs.len());
        let max_ctx = self.scheduler.cfg.max_context;
        for &i in &idxs {
            let s = &self.running[i];
            let id = s.id();
            let ckpt = self
                .cache
                .checkpoint(id)
                .map_err(|e| anyhow::anyhow!("checkpoint seq {id}: {e:?}"))?;
            let mut history = s.request.prompt.clone();
            history.extend_from_slice(&s.generated);
            // near the context limit the draft shrinks so the verify inputs
            // never push the cache past the largest bucket
            let ctx = self.cache.tokens_of(id);
            let cap = max_ctx.saturating_sub(ctx + 1).min(draft_len);
            let draft = self.engine.draft.draft(&history, cap);
            let mut inputs = Vec::with_capacity(draft.len() + 1);
            inputs.push(s.next_input);
            inputs.extend_from_slice(&draft);
            ckpts.push(ckpt);
            drafts.push(draft);
            items.push((id, inputs));
        }
        self.metrics.spec_steps += idxs.len() as u64;
        self.metrics.decode_batch.push(idxs.len() as f64);
        let out = self.engine.verify(&mut self.cache, &items)?;

        let mut done: Vec<usize> = Vec::new();
        for (k, &ridx) in idxs.iter().enumerate() {
            let draft = &drafts[k];
            self.metrics.spec_drafted += draft.len() as u64;
            let mut accepted = 0usize;
            let mut finished = false;
            for (pos, logits) in out.logits[k].iter().enumerate() {
                let s = &mut self.running[ridx];
                finished = s.accept_logits(logits);
                if finished {
                    break;
                }
                // the token the target sampled must equal the draft fed at
                // the next position, or every later verify logit is
                // off-policy and the walk stops here
                if pos < draft.len() && s.next_input == draft[pos] {
                    accepted += 1;
                } else {
                    break;
                }
            }
            self.metrics.spec_accepted += accepted as u64;
            if finished {
                done.push(ridx);
            } else {
                // keep the carried token plus the accepted drafts
                self.cache
                    .rollback_to(&ckpts[k], accepted + 1)
                    .map_err(|e| {
                        anyhow::anyhow!("rollback seq {}: {e:?}", self.running[ridx].id())
                    })?;
            }
        }
        done.sort_unstable_by(|a, b| b.cmp(a));
        for i in done {
            let seq = self.running.remove(i);
            self.cache.release(seq.id());
            self.finish(seq);
        }
        Ok(())
    }

    /// Can this rank take a migrated sequence right now? Needs a running
    /// slot and pages for the wire block plus the remaining generation
    /// (full reservation, so an accepted migrant never wedges on pages
    /// another migrant needs).
    pub fn can_accept_handoff(&self, wire_tokens: usize, remaining_tokens: usize) -> bool {
        self.running.len() < self.scheduler.cfg.max_running
            && self.cache.available_pages()
                >= (wire_tokens + remaining_tokens).div_ceil(PAGE_TOKENS)
    }

    /// Accept a migrated sequence on this (decode) rank: map its wire block
    /// into the local pool and enter it into the running set. The imported
    /// KV is bit-identical to the prefill rank's, so decoding continues
    /// exactly as if the sequence had prefilled here.
    pub fn accept_handoff(&mut self, mut seq: Sequence, wire: KvWireBlock) -> anyhow::Result<()> {
        self.cache
            .import_wire(seq.id(), &wire)
            .map_err(|e| anyhow::anyhow!("import seq {}: {e:?}", seq.id()))?;
        seq.phase = SeqPhase::Running;
        self.metrics.handoffs_in += 1;
        self.running.push(seq);
        Ok(())
    }

    /// Tear this rank down after a failure, leaving it empty. Where each
    /// queued sequence goes depends on where its state lives:
    ///
    /// * fresh waiting (no KV yet) → `resubmit`: re-route through the
    ///   cluster as if just arrived (same request, deterministic replay);
    /// * running (live device KV) → `migrate` when `recover`: exported to
    ///   the wire format for re-import on a survivor, else dropped;
    /// * already-serialized outbox transfers ride `migrate` the same way;
    /// * spilled waiting → dropped: their KV lived in this rank's host
    ///   memory, which died with it.
    pub fn evacuate(&mut self, recover: bool) -> anyhow::Result<Evacuation> {
        let mut ev = Evacuation { resubmit: Vec::new(), migrate: Vec::new(), dropped: 0 };
        for seq in std::mem::take(&mut self.waiting) {
            if seq.spilled.is_some() {
                ev.dropped += 1;
            } else {
                ev.resubmit.push(seq.request);
            }
        }
        for seq in std::mem::take(&mut self.running) {
            if recover {
                let wire = self
                    .cache
                    .export_wire(seq.id())
                    .map_err(|e| anyhow::anyhow!("evacuate seq {}: {e:?}", seq.id()))?;
                self.cache.release(seq.id());
                ev.migrate.push((seq, wire));
            } else {
                self.cache.release(seq.id());
                ev.dropped += 1;
            }
        }
        for (seq, wire) in std::mem::take(&mut self.handoff_outbox) {
            if recover {
                ev.migrate.push((seq, wire));
            } else {
                ev.dropped += 1;
            }
        }
        Ok(ev)
    }

    fn finish(&mut self, seq: Sequence) {
        let outcome = {
            let prompt = seq.request.prompt.len();
            let gen = seq.generated.len();
            let o = seq.into_outcome();
            self.metrics.record(&o.metrics, prompt, gen);
            o
        };
        self.finished.push(outcome);
    }

    /// Monotone progress signal: tokens the engine has actually produced or
    /// ingested (preempt/resume churn does not move it).
    fn engine_work(&self) -> u64 {
        let s = &self.engine.stats;
        s.decode_tokens + s.prefill_tokens + s.chunk_tokens + s.verify_tokens
    }

    /// Run until all submitted requests complete; returns wall seconds.
    pub fn run_to_completion(&mut self) -> anyhow::Result<f64> {
        let t0 = Instant::now();
        let mut stalled = 0usize;
        while self.pending() > 0 {
            let work = self.engine_work();
            let progressed = self.step()?;
            if !progressed && self.pending() > 0 {
                anyhow::bail!(
                    "scheduler deadlock: {} waiting, {} running, {} free pages",
                    self.waiting.len(),
                    self.running.len(),
                    self.cache.free_pages()
                );
            }
            if self.engine_work() > work {
                stalled = 0;
            } else {
                stalled += 1;
                anyhow::ensure!(
                    stalled <= STALL_LIMIT,
                    "scheduler livelock: {stalled} steps without engine progress \
                     ({} waiting, {} running, {} free pages)",
                    self.waiting.len(),
                    self.running.len(),
                    self.cache.free_pages()
                );
            }
        }
        let wall = t0.elapsed().as_secs_f64();
        self.metrics.wall_s += wall;
        Ok(wall)
    }
}
