//! The serving loop: one DP rank = one engine + one paged cache + the
//! continuous-batching scheduler.

use super::metrics::ServerMetrics;
use super::request::{RequestOutcome, ServeRequest};
use super::scheduler::{Action, RunningSeq, Scheduler, SchedulerConfig, WaitingSeq};
use super::sequence::{SeqPhase, Sequence};
use crate::anyhow;
use crate::kvcache::{PagedKvCache, PAGE_TOKENS};
use crate::runtime::ModelEngine;
use std::collections::VecDeque;
use std::time::Instant;

pub struct Server {
    pub engine: ModelEngine,
    pub cache: PagedKvCache,
    pub scheduler: Scheduler,
    waiting: VecDeque<Sequence>,
    running: Vec<Sequence>,
    pub finished: Vec<RequestOutcome>,
    pub metrics: ServerMetrics,
    eos: i32,
}

impl Server {
    /// Build a server around a loaded engine with `capacity_pages` of KV.
    pub fn new(engine: ModelEngine, capacity_pages: usize) -> Server {
        let cache = PagedKvCache::new(engine.cache_config(capacity_pages));
        let mode = engine.mode_str();
        let max_decode_batch = engine
            .manifest
            .artifacts
            .values()
            .filter(|a| a.kind == crate::runtime::ArtifactKind::Decode && a.mode == mode)
            .map(|a| a.batch)
            .max()
            .unwrap_or(1);
        let max_prefill_batch = engine
            .manifest
            .artifacts
            .values()
            .filter(|a| a.kind == crate::runtime::ArtifactKind::Prefill && a.mode == mode)
            .map(|a| a.batch)
            .max()
            .unwrap_or(1);
        let max_prefill_tokens = engine
            .manifest
            .artifacts
            .values()
            .filter(|a| a.kind == crate::runtime::ArtifactKind::Prefill && a.mode == mode)
            .map(|a| a.seq)
            .max()
            .unwrap_or(0);
        let cfg = SchedulerConfig {
            max_decode_batch,
            max_prefill_batch,
            max_prefill_tokens,
            max_context: engine.max_context(),
            page_tokens: PAGE_TOKENS,
        };
        let eos = engine.manifest.model.eos;
        Server {
            engine,
            cache,
            scheduler: Scheduler::new(cfg),
            waiting: VecDeque::new(),
            running: Vec::new(),
            finished: Vec::new(),
            metrics: ServerMetrics::default(),
            eos,
        }
    }

    pub fn submit(&mut self, req: ServeRequest) {
        assert!(
            req.prompt.len() <= self.scheduler.cfg.max_prefill_tokens,
            "prompt {} exceeds prefill bucket {}",
            req.prompt.len(),
            self.scheduler.cfg.max_prefill_tokens
        );
        self.waiting.push_back(Sequence::new(req, self.eos));
    }

    pub fn pending(&self) -> usize {
        self.waiting.len() + self.running.len()
    }

    /// Queue-depth signal for the DP router (tokens outstanding).
    pub fn load_tokens(&self) -> usize {
        let queued: usize =
            self.waiting.iter().map(|s| s.request.prompt.len() + s.request.max_new_tokens).sum();
        let remaining: usize =
            self.running.iter().map(|s| s.request.max_new_tokens - s.generated.len()).sum();
        queued + remaining
    }

    /// One scheduling iteration. Returns false when fully idle.
    pub fn step(&mut self) -> anyhow::Result<bool> {
        let waiting_view: Vec<WaitingSeq> = self
            .waiting
            .iter()
            .enumerate()
            .map(|(i, s)| WaitingSeq { idx: i, tokens: s.prefill_tokens().len() })
            .collect();
        let running_view: Vec<RunningSeq> = self
            .running
            .iter()
            .enumerate()
            .map(|(i, s)| RunningSeq { idx: i, context: s.context_len() })
            .collect();
        let action = self
            .scheduler
            .decide(&waiting_view, &running_view, self.cache.free_pages());

        match action {
            Action::Prefill(idxs) => {
                // idxs are FCFS-prefix indices into `waiting`
                let mut batch = Vec::new();
                for _ in 0..idxs.len() {
                    let mut seq = self.waiting.pop_front().unwrap();
                    seq.phase = SeqPhase::Running;
                    batch.push(seq);
                }
                let items: Vec<(u64, Vec<i32>)> = batch
                    .iter()
                    .map(|s| {
                        self.cache.register(s.id());
                        (s.id(), s.prefill_tokens())
                    })
                    .collect();
                let out = self.engine.prefill(&mut self.cache, &items)?;
                for (mut seq, logits) in batch.into_iter().zip(out.logits) {
                    let done = seq.accept_logits(&logits);
                    if done {
                        self.finish(seq);
                    } else {
                        self.running.push(seq);
                    }
                }
            }
            Action::Decode(idxs) => {
                let items: Vec<(u64, i32)> = idxs
                    .iter()
                    .map(|&i| (self.running[i].id(), self.running[i].next_input))
                    .collect();
                self.metrics.decode_steps += 1;
                self.metrics.decode_batch.push(items.len() as f64);
                let out = self.engine.decode(&mut self.cache, &items)?;
                // accept logits; collect finished (iterate in reverse index
                // order so removals do not shift pending indices)
                let mut done: Vec<usize> = Vec::new();
                for (k, &i) in idxs.iter().enumerate() {
                    if self.running[i].accept_logits(&out.logits[k]) {
                        done.push(i);
                    }
                }
                done.sort_unstable_by(|a, b| b.cmp(a));
                for i in done {
                    let seq = self.running.remove(i);
                    self.cache.release(seq.id());
                    self.finish(seq);
                }
            }
            Action::Preempt(idx) => {
                let mut seq = self.running.remove(idx);
                self.cache.release(seq.id());
                seq.preempt();
                // re-queue at the FRONT: preempted work ages first
                self.waiting.push_front(seq);
            }
            Action::Idle => return Ok(false),
        }
        Ok(true)
    }

    fn finish(&mut self, seq: Sequence) {
        let outcome = {
            let prompt = seq.request.prompt.len();
            let gen = seq.generated.len();
            let o = seq.into_outcome();
            self.metrics.record(&o.metrics, prompt, gen);
            o
        };
        self.finished.push(outcome);
    }

    /// Run until all submitted requests complete; returns wall seconds.
    pub fn run_to_completion(&mut self) -> anyhow::Result<f64> {
        let t0 = Instant::now();
        while self.pending() > 0 {
            let progressed = self.step()?;
            if !progressed && self.pending() > 0 {
                anyhow::bail!(
                    "scheduler deadlock: {} waiting, {} running, {} free pages",
                    self.waiting.len(),
                    self.running.len(),
                    self.cache.free_pages()
                );
            }
        }
        let wall = t0.elapsed().as_secs_f64();
        self.metrics.wall_s += wall;
        Ok(wall)
    }
}
