//! L3 serving coordinator: requests, sequences, scheduling, the serving
//! loop, DP routing and metrics — the vLLM/SGLang-shaped layer the paper's
//! system-level contributions (§3.3, per-token instant quantization,
//! framework compatibility) plug into.

pub mod metrics;
pub mod request;
pub mod router;
pub mod scheduler;
pub mod sequence;
pub mod server;

pub use metrics::{RequestMetrics, ServerMetrics};
pub use request::{FinishReason, RequestOutcome, ServeRequest};
pub use router::Router;
pub use scheduler::{Action, Scheduler, SchedulerConfig};
pub use sequence::{SeqPhase, Sequence};
pub use server::Server;
