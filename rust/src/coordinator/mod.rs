//! L3 serving coordinator: requests, sequences, scheduling, the serving
//! loop, DP routing and metrics — the vLLM/SGLang-shaped layer the paper's
//! system-level contributions (§3.3, per-token instant quantization,
//! framework compatibility) plug into.
//!
//! The scheduler runs **mixed batches**: chunked prefill rides along with
//! the decode batch in one engine step (`Action::Mixed`), prompt prefixes
//! are shared through the cache's prefix trie, and preemption spills KV
//! pages instead of recomputing.

pub mod metrics;
pub mod request;
pub mod router;
pub mod scheduler;
pub mod sequence;
pub mod server;

pub use metrics::{ClusterMetrics, RequestMetrics, ServerMetrics};
pub use request::{FinishReason, RequestOutcome, ServeRequest};
pub use router::{RankHealth, RankLoad, RoutePolicy, Router};
pub use scheduler::{Action, PrefillChunk, SchedPolicy, Scheduler, SchedulerConfig, SpecConfig};
pub use sequence::{SeqPhase, Sequence};
pub use server::{Evacuation, Server};
