//! Bit-exact E4M3 (OCP FP8, finite-only) codec.
//!
//! Layout: 1 sign | 4 exponent (bias 7) | 3 mantissa. Max finite 448
//! (0b0_1111_110); 0b0_1111_111 is NaN (no infinities). Subnormal step 2^-9.
//!
//! `e4m3_encode` rounds to nearest-even and SATURATES out-of-range values to
//! ±448 (matching the python `quant.e4m3_round` convention — our quantizers
//! divide by sigma = amax/448 first, so saturation only guards the boundary).

pub const E4M3_MAX: f32 = 448.0;
const EXP_BIAS: i32 = 7;
const MANT_BITS: u32 = 3;

/// Encode an f32 to the nearest E4M3 byte (round-half-to-even, saturating).
pub fn e4m3_encode(x: f32) -> u8 {
    if x.is_nan() {
        return 0x7F; // canonical NaN
    }
    let sign = if x.is_sign_negative() { 0x80u8 } else { 0 };
    let a = x.abs();
    if a == 0.0 {
        return sign; // +-0 → signed zero encoding (decodes to +0/-0)
    }
    if a >= E4M3_MAX {
        return sign | 0x7E; // saturate to ±448
    }
    // Decompose a = m * 2^e with m in [1, 2).
    let bits = a.to_bits();
    let e_unb = ((bits >> 23) & 0xFF) as i32 - 127;
    // Normal E4M3 range: exponent in [-6, 8].
    if e_unb >= -6 {
        // quantum is 2^(e-3); use f32 arithmetic rounding via scaled round.
        let step = e_unb - MANT_BITS as i32;
        let q = round_half_even(a / exp2i(step));
        // q in [8, 16]; q==16 means carry into the next exponent.
        let (mant, e_final) = if q >= 16.0 { (0u32, e_unb + 1) } else { (q as u32 - 8, e_unb) };
        if e_final > 8 {
            return sign | 0x7E; // carried past the max exponent → saturate
        }
        let exp_field = (e_final + EXP_BIAS) as u8;
        sign | (exp_field << 3) | mant as u8
    } else {
        // Subnormal: value = mant * 2^-9, mant in [0, 7].
        let q = round_half_even(a / exp2i(-9));
        if q == 0.0 {
            return sign;
        }
        if q >= 8.0 {
            // rounds up into the first normal (2^-6)
            return sign | (1 << 3);
        }
        sign | q as u8
    }
}

/// Decode an E4M3 byte to f32 (NaN for 0x7F/0xFF).
pub fn e4m3_decode(b: u8) -> f32 {
    let sign = if b & 0x80 != 0 { -1.0f32 } else { 1.0 };
    let exp_field = ((b >> 3) & 0x0F) as i32;
    let mant = (b & 0x07) as i32;
    if exp_field == 0x0F && mant == 0x07 {
        return f32::NAN;
    }
    if exp_field == 0 {
        return sign * mant as f32 * exp2i(-9);
    }
    let e = exp_field - EXP_BIAS;
    sign * (1.0 + mant as f32 / 8.0) * exp2i(e)
}

/// Round an f32 to the E4M3 grid (encode+decode).
pub fn e4m3_round(x: f32) -> f32 {
    e4m3_decode(e4m3_encode(x))
}

fn exp2i(e: i32) -> f32 {
    f32::from_bits((((e + 127) as u32) & 0xFF) << 23)
}

fn round_half_even(x: f32) -> f32 {
    // f32 has exact integers in this range; emulate round-half-to-even.
    let floor = x.floor();
    let frac = x - floor;
    if frac > 0.5 {
        floor + 1.0
    } else if frac < 0.5 {
        floor
    } else if (floor as i64) % 2 == 0 {
        floor
    } else {
        floor + 1.0
    }
}

/// Encode a slice (e.g. one token's content vector) into bytes.
pub fn encode_slice(xs: &[f32], out: &mut Vec<u8>) {
    out.clear();
    out.extend(xs.iter().map(|&x| e4m3_encode(x)));
}

/// Decode bytes into f32s.
pub fn decode_slice(bs: &[u8], out: &mut Vec<f32>) {
    out.clear();
    out.extend(bs.iter().map(|&b| e4m3_decode(b)));
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Enumerate all finite E4M3 values.
    fn all_finite() -> Vec<(u8, f32)> {
        (0u16..256)
            .map(|b| (b as u8, e4m3_decode(b as u8)))
            .filter(|(_, v)| v.is_finite())
            .collect()
    }

    #[test]
    fn decode_known_values() {
        assert_eq!(e4m3_decode(0x00), 0.0);
        assert_eq!(e4m3_decode(0x38), 1.0); // exp=7 → 2^0, mant 0
        assert_eq!(e4m3_decode(0x39), 1.125);
        assert_eq!(e4m3_decode(0x7E), 448.0);
        assert_eq!(e4m3_decode(0xFE), -448.0);
        assert_eq!(e4m3_decode(0x01), 2.0f32.powi(-9)); // smallest subnormal
        assert_eq!(e4m3_decode(0x08), 2.0f32.powi(-6)); // smallest normal
        assert!(e4m3_decode(0x7F).is_nan());
    }

    #[test]
    fn grid_points_are_fixed_points() {
        for (b, v) in all_finite() {
            let enc = e4m3_encode(v);
            // sign of zero: 0x00 and 0x80 both decode to 0.0/-0.0
            assert_eq!(
                e4m3_decode(enc),
                v,
                "byte {b:#04x} value {v} re-encoded to {enc:#04x}"
            );
        }
    }

    #[test]
    fn rounds_to_nearest() {
        // 1.0 + small eps stays at 1.0; above midpoint goes to 1.125
        assert_eq!(e4m3_round(1.01), 1.0);
        assert_eq!(e4m3_round(1.12), 1.125);
        // midpoint 1.0625 → even mantissa (1.0)
        assert_eq!(e4m3_round(1.0625), 1.0);
        // midpoint 1.1875 between 1.125 and 1.25 → 1.25 (even mantissa 2)
        assert_eq!(e4m3_round(1.1875), 1.25);
    }

    #[test]
    fn saturates() {
        assert_eq!(e4m3_round(1e9), 448.0);
        assert_eq!(e4m3_round(-1e9), -448.0);
        assert_eq!(e4m3_round(460.0), 448.0);
    }

    #[test]
    fn subnormals() {
        let step = 2.0f32.powi(-9);
        assert_eq!(e4m3_round(step), step);
        assert_eq!(e4m3_round(step * 0.4), 0.0);
        assert_eq!(e4m3_round(step * 2.6), step * 3.0);
        // just below the first normal: 7.6 steps rounds UP into 2^-6 …
        assert_eq!(e4m3_round(2.0f32.powi(-6) - step * 0.4), 2.0f32.powi(-6));
        // … while 7.4 steps rounds down to the top subnormal
        assert_eq!(e4m3_round(2.0f32.powi(-6) - step * 0.6), 2.0f32.powi(-6) - step);
    }

    #[test]
    fn relative_error_bound_normals() {
        let mut x = 2.0f32.powi(-6);
        while x < 448.0 {
            let q = e4m3_round(x * 1.03);
            let rel = ((q - x * 1.03) / (x * 1.03)).abs();
            assert!(rel <= 0.0625 + 1e-6, "x={x} rel={rel}");
            x *= 1.37;
        }
    }

    #[test]
    fn matches_python_grid_definition() {
        // spot-check values against python quant.e4m3_round outputs
        // (generated once with ml_dtypes; keep in sync with test_quant.py)
        let cases: [(f32, f32); 8] = [
            (3.3, 3.25),
            (-3.3, -3.25),
            (0.07, 0.0703125),
            (447.0, 448.0),
            (0.001, 0.001953125), // subnormal: nearest multiple of 2^-9
            (100.0, 96.0),
            (0.0196, 0.01953125),
            (5.7, 5.5),
        ];
        for (x, want) in cases {
            let got = e4m3_round(x);
            assert_eq!(got, want, "x={x}");
        }
    }

    #[test]
    fn slice_roundtrip() {
        let xs: Vec<f32> = (0..100).map(|i| (i as f32 - 50.0) * 3.7).collect();
        let mut enc = Vec::new();
        let mut dec = Vec::new();
        encode_slice(&xs, &mut enc);
        decode_slice(&enc, &mut dec);
        assert_eq!(enc.len(), 100);
        for (x, d) in xs.iter().zip(&dec) {
            assert_eq!(*d, e4m3_round(*x));
        }
    }
}
