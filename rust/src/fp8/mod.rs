//! FP8 (E4M3) and BF16 codecs plus the paper's quantizer family.
//!
//! The rust KV cache stores *true* u8 E4M3 encodings (real 4x memory
//! reduction vs f32 staging, 2x vs bf16) and u16 bf16 for the RoPE part; the
//! grid definition is shared bit-for-bit with the python side
//! (`python/compile/kernels/quant.py`, tested against `ml_dtypes`).

pub mod bf16;
pub mod e4m3;
pub mod quantize;

pub use bf16::{bf16_decode, bf16_encode, bf16_round};
pub use e4m3::{e4m3_decode, e4m3_encode, e4m3_round, E4M3_MAX};
pub use quantize::{
    dequant_per_block, per_token_scale, quant_per_block, quant_per_tensor,
    quant_per_token, QuantizedBlock, QuantizedToken, SCALE_EPS,
};
