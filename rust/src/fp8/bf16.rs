//! BF16 codec (the RoPE cache precision; `half` crate unavailable offline).
//!
//! bf16 = top 16 bits of f32 with round-to-nearest-even on the truncated bits.

/// Encode f32 → bf16 bits (round-half-to-even).
pub fn bf16_encode(x: f32) -> u16 {
    let bits = x.to_bits();
    if x.is_nan() {
        return 0x7FC0; // canonical NaN
    }
    // canonical round-to-nearest-even: add 0x7FFF + lsb, then truncate
    let lsb = (bits >> 16) & 1;
    ((bits.wrapping_add(0x7FFF + lsb)) >> 16) as u16
}

/// Decode bf16 bits → f32 (exact).
pub fn bf16_decode(b: u16) -> f32 {
    f32::from_bits((b as u32) << 16)
}

/// Round an f32 to the bf16 grid.
pub fn bf16_round(x: f32) -> f32 {
    bf16_decode(bf16_encode(x))
}

pub fn encode_slice(xs: &[f32], out: &mut Vec<u16>) {
    out.clear();
    out.extend(xs.iter().map(|&x| bf16_encode(x)));
}

pub fn decode_slice(bs: &[u16], out: &mut Vec<f32>) {
    out.clear();
    out.extend(bs.iter().map(|&b| bf16_decode(b)));
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn exact_values_roundtrip() {
        for x in [0.0f32, 1.0, -2.5, 448.0, 1024.0, 3.140625] {
            assert_eq!(bf16_round(x), x, "{x}");
        }
    }

    #[test]
    fn rounds_to_nearest() {
        // bf16 has 7 mantissa bits → grid spacing 2^-7 at 1.0, so the
        // round-to-nearest error is bounded by 2^-8 relative.
        let x = 1.0 + 2.0f32.powi(-9);
        let r = bf16_round(x);
        assert!(r == 1.0 || r == 1.0 + 2.0f32.powi(-7));
        assert!(((r - x) / x).abs() <= 2.0f32.powi(-8) + 1e-9);
    }

    #[test]
    fn relative_error_bound() {
        let mut x = 1e-3f32;
        while x < 1e3 {
            let r = bf16_round(x * 1.017);
            let rel = ((r - x * 1.017) / (x * 1.017)).abs();
            assert!(rel <= 2.0f32.powi(-8) + 1e-9, "x={x} rel={rel}");
            x *= 2.31;
        }
    }

    #[test]
    fn nan_and_signs() {
        assert!(bf16_decode(bf16_encode(f32::NAN)).is_nan());
        assert_eq!(bf16_round(-0.0), 0.0);
        assert!(bf16_round(-3.3) < 0.0);
    }

    #[test]
    fn wide_rope_range_preserved() {
        // RoPE values up to ±10³ keep ~2^-8 relative accuracy (the paper's
        // reason for keeping RoPE in bf16: 2^-8 << the FP8 2^-4).
        for x in [999.5f32, -1000.0, 512.25, -717.0] {
            let r = bf16_round(x);
            assert!(((r - x) / x).abs() <= 2.0f32.powi(-8) + 1e-9);
        }
    }

    #[test]
    fn slice_roundtrip() {
        let xs: Vec<f32> = (0..64).map(|i| (i as f32) * 17.3 - 500.0).collect();
        let mut enc = Vec::new();
        let mut dec = Vec::new();
        encode_slice(&xs, &mut enc);
        decode_slice(&enc, &mut dec);
        for (x, d) in xs.iter().zip(&dec) {
            assert_eq!(*d, bf16_round(*x));
        }
    }
}
