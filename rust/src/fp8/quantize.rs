//! The paper's quantizer family (Appendix C granularities) over E4M3.
//!
//! Per-token is SnapMLA's decode-centric choice (§3.1.1): instant
//! quantization of each new token, no tail buffers. Per-tensor and per-block
//! exist for the Table-3 fidelity configs and the granularity ablation.

use super::e4m3::{e4m3_decode, e4m3_encode, E4M3_MAX};

/// Dynamic-scale lower bound (App. D: "dynamic scales are lower-bounded by a
/// small epsilon before division").
pub const SCALE_EPS: f32 = 1e-8;

/// One quantized token row: u8 codes + its scale.
#[derive(Clone, Debug, PartialEq)]
pub struct QuantizedToken {
    pub codes: Vec<u8>,
    pub scale: f32,
}

/// A block-quantized matrix: codes in row-major order + per-block scales.
#[derive(Clone, Debug)]
pub struct QuantizedBlock {
    pub codes: Vec<u8>,
    pub rows: usize,
    pub cols: usize,
    pub block_rows: usize,
    pub block_cols: usize,
    pub scales: Vec<f32>, // [rows/block_rows * cols/block_cols], row-major
}

/// sigma = max|x| / 448, lower-bounded by SCALE_EPS.
pub fn per_token_scale(xs: &[f32]) -> f32 {
    let amax = xs.iter().fold(0.0f32, |m, &x| m.max(x.abs()));
    (amax / E4M3_MAX).max(SCALE_EPS)
}

/// Per-token quantization of one row (paper Fig. 4(2)).
pub fn quant_per_token(xs: &[f32]) -> QuantizedToken {
    let scale = per_token_scale(xs);
    let codes = xs.iter().map(|&x| e4m3_encode(x / scale)).collect();
    QuantizedToken { codes, scale }
}

impl QuantizedToken {
    pub fn dequant(&self) -> Vec<f32> {
        self.codes.iter().map(|&b| e4m3_decode(b) * self.scale).collect()
    }

    /// Dequantize into a caller buffer (hot path: no allocation).
    pub fn dequant_into(&self, out: &mut [f32]) {
        assert_eq!(out.len(), self.codes.len());
        for (o, &b) in out.iter_mut().zip(&self.codes) {
            *o = e4m3_decode(b) * self.scale;
        }
    }
}

/// Per-tensor quantization (paper Fig. 4(1)); `scale=None` → dynamic.
pub fn quant_per_tensor(xs: &[f32], scale: Option<f32>) -> (Vec<u8>, f32) {
    let s = scale.unwrap_or_else(|| per_token_scale(xs));
    (xs.iter().map(|&x| e4m3_encode(x / s)).collect(), s)
}

/// Per-block quantization (paper Fig. 4(4)) of a row-major [rows, cols]
/// matrix with block_rows x block_cols tiles (must divide evenly).
pub fn quant_per_block(
    xs: &[f32],
    rows: usize,
    cols: usize,
    block_rows: usize,
    block_cols: usize,
) -> QuantizedBlock {
    assert_eq!(xs.len(), rows * cols);
    assert!(rows % block_rows == 0 && cols % block_cols == 0);
    let brs = rows / block_rows;
    let bcs = cols / block_cols;
    let mut scales = vec![0.0f32; brs * bcs];
    for br in 0..brs {
        for bc in 0..bcs {
            let mut amax = 0.0f32;
            for r in 0..block_rows {
                let row = br * block_rows + r;
                for c in 0..block_cols {
                    amax = amax.max(xs[row * cols + bc * block_cols + c].abs());
                }
            }
            scales[br * bcs + bc] = (amax / E4M3_MAX).max(SCALE_EPS);
        }
    }
    let mut codes = vec![0u8; xs.len()];
    for r in 0..rows {
        for c in 0..cols {
            let s = scales[(r / block_rows) * bcs + c / block_cols];
            codes[r * cols + c] = e4m3_encode(xs[r * cols + c] / s);
        }
    }
    QuantizedBlock { codes, rows, cols, block_rows, block_cols, scales }
}

/// Inverse of `quant_per_block`.
pub fn dequant_per_block(q: &QuantizedBlock) -> Vec<f32> {
    let bcs = q.cols / q.block_cols;
    let mut out = vec![0.0f32; q.rows * q.cols];
    for r in 0..q.rows {
        for c in 0..q.cols {
            let s = q.scales[(r / q.block_rows) * bcs + c / q.block_cols];
            out[r * q.cols + c] = e4m3_decode(q.codes[r * q.cols + c]) * s;
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop::{check, VecF32};
    use crate::util::rng::Rng;

    #[test]
    fn per_token_roundtrip_error_bound() {
        let mut rng = Rng::new(1);
        for _ in 0..50 {
            let xs = rng.normal_vec(128, 5.0);
            let q = quant_per_token(&xs);
            let d = q.dequant();
            let amax = xs.iter().fold(0.0f32, |m, &x| m.max(x.abs()));
            for (x, y) in xs.iter().zip(&d) {
                let tol = amax * 0.0625 / 448.0 * 448.0 * 0.0625 + amax * 2.0_f32.powi(-4);
                assert!((x - y).abs() <= tol, "x={x} y={y} amax={amax}");
            }
        }
    }

    #[test]
    fn per_token_relative_error_property() {
        // property: every element within 2^-4 relative of the grid OR below
        // the subnormal resolution sigma * 2^-9.
        let gen = VecF32 { min_len: 1, max_len: 256, std: 10.0 };
        check(7, 100, &gen, |xs| {
            let q = quant_per_token(xs);
            let d = q.dequant();
            for (i, (&x, &y)) in xs.iter().zip(&d).enumerate() {
                let tol = (x.abs() * 0.0625).max(q.scale * 2.0f32.powi(-9) * 0.5 + 1e-12);
                if (x - y).abs() > tol + 1e-9 {
                    return Err(format!("elem {i}: x={x} dequant={y} tol={tol}"));
                }
            }
            Ok(())
        });
    }

    #[test]
    fn zero_rows() {
        let q = quant_per_token(&[0.0; 16]);
        assert_eq!(q.scale, SCALE_EPS);
        assert!(q.dequant().iter().all(|&x| x == 0.0));
    }

    #[test]
    fn scale_is_amax_over_448() {
        let q = quant_per_token(&[1.0, -448.0, 3.0]);
        assert_eq!(q.scale, 1.0);
        // the max element encodes exactly
        assert_eq!(q.dequant()[1], -448.0);
    }

    #[test]
    fn dequant_into_matches_dequant() {
        let mut rng = Rng::new(2);
        let xs = rng.normal_vec(64, 2.0);
        let q = quant_per_token(&xs);
        let mut buf = vec![0.0f32; 64];
        q.dequant_into(&mut buf);
        assert_eq!(buf, q.dequant());
    }

    #[test]
    fn per_tensor_static_vs_dynamic() {
        let xs: Vec<f32> = (0..64).map(|i| (i as f32 - 32.0) / 10.0).collect();
        let (qs, ss) = quant_per_tensor(&xs, Some(1.0));
        assert_eq!(ss, 1.0);
        let (qd, sd) = quant_per_tensor(&xs, None);
        assert!((sd - 3.2 / 448.0).abs() < 1e-6);
        // dynamic scale gives lower error on small-magnitude data
        let err = |codes: &[u8], s: f32| -> f64 {
            xs.iter()
                .zip(codes)
                .map(|(&x, &c)| ((x - e4m3_decode(c) * s) as f64).powi(2))
                .sum()
        };
        assert!(err(&qd, sd) <= err(&qs, ss));
    }

    #[test]
    fn per_block_shapes_and_outlier_containment() {
        let rows = 128;
        let cols = 128;
        let mut xs = vec![1.0f32; rows * cols];
        xs[0] = 400.0; // outlier in block (0,0)
        let q = quant_per_block(&xs, rows, cols, 64, 64);
        assert_eq!(q.scales.len(), 4);
        let d = dequant_per_block(&q);
        // far block unaffected by the outlier
        let far = d[(64 + 1) * cols + 64 + 1];
        assert!((far - 1.0).abs() <= 1.0 * 0.0625 + 1e-6, "{far}");
        // outlier block sees coarse steps for the 1.0 entries
        let near = d[1];
        assert!((near - 1.0).abs() <= 400.0 / 448.0 * 0.5 + 0.2, "{near}");
    }

    #[test]
    fn per_block_roundtrip_grid() {
        let mut rng = Rng::new(3);
        let xs = rng.normal_vec(64 * 64, 3.0);
        let q = quant_per_block(&xs, 64, 64, 64, 64);
        let d = dequant_per_block(&q);
        let q2 = quant_per_block(&d, 64, 64, 64, 64);
        // double quantization is idempotent on the values
        assert_eq!(dequant_per_block(&q2), d);
    }
}
