//! End-to-end decoding throughput model (Figure 1).
//!
//! Models one decode step of a large MLA MoE model on an 8-GPU Hopper node
//! under a (DP, TP) layout, for BF16-FlashMLA vs SnapMLA-FP8 pipelines:
//!
//! * per-layer attention time from the kernel model (`kernel.rs`),
//! * expert/dense weight streaming (decode is weight-bandwidth-bound),
//! * TP all-reduce cost per layer over NVLink,
//! * fused-dataflow launch savings (SnapMLA's §3.3 single-launch
//!   token-preparation vs separate quant/copy kernels),
//! * **KV-capacity-driven batch size**: the FP8 cache is ~1.8x denser, so
//!   more sequences fit per rank — the paper's main lever for long-context
//!   throughput (matched per-rank input shapes use the same batch for both;
//!   Fig. 1's serving mode lets each pipeline use its capacity).

use super::gpu::GpuSpec;
use super::kernel::{kernel_time_s, KernelKind, KernelShape};
use crate::cluster::collective::{allreduce_time_s, transfer_time_s, CollectiveSpec};

/// Per-collective launch/sync latency (one all-reduce per layer).
const COLLECTIVE_LATENCY_S: f64 = 5.0e-6;

/// Hidden-state bytes one token row's per-layer all-reduce moves (~d_model
/// in bf16).
fn hidden_bytes_per_token(model: &ModelSpec) -> f64 {
    (model.d_c * model.heads / 64) as f64 * 2.0
}

/// TP collective time for `units` concurrent token rows through all layers:
/// one ring all-reduce of the hidden state per layer, priced by the
/// `cluster::collective` model over the GPU's NVLink. Zero at TP = 1 — this
/// is what makes TP > 1 layouts pay for their communication in decode,
/// prefill AND mixed steps.
fn tp_comm_s(gpu: &GpuSpec, model: &ModelSpec, cfg: &DeploymentConfig, units: f64) -> f64 {
    if cfg.tp <= 1 {
        return 0.0;
    }
    let spec = CollectiveSpec { link_bw: gpu.nvlink_bw, latency_s: COLLECTIVE_LATENCY_S };
    allreduce_time_s(&spec, hidden_bytes_per_token(model) * units, cfg.tp)
        * model.n_layers as f64
}

/// A served model (DeepSeek-V3.1 / LongCat-Flash class MoE with MLA).
#[derive(Clone, Copy, Debug)]
pub struct ModelSpec {
    pub name: &'static str,
    pub n_layers: usize,
    pub heads: usize,
    pub d_c: usize,
    pub d_r: usize,
    /// total parameters (bytes assume FP8 weight storage, as deployed)
    pub total_params: f64,
    /// activated parameters per token
    pub active_params: f64,
}

impl ModelSpec {
    pub fn deepseek_v31() -> ModelSpec {
        ModelSpec {
            name: "DeepSeek-V3.1",
            n_layers: 61,
            heads: 128,
            d_c: 512,
            d_r: 64,
            total_params: 671e9,
            active_params: 37e9,
        }
    }

    pub fn longcat_flash() -> ModelSpec {
        ModelSpec {
            name: "LongCat-Flash-Thinking",
            n_layers: 60,
            heads: 64,
            d_c: 512,
            d_r: 64,
            total_params: 560e9,
            // zero-computation experts: 18.6-31.3B active; use the mean
            active_params: 25e9,
        }
    }

    /// KV-cache bytes per token (all layers) under a pipeline.
    pub fn kv_bytes_per_token(&self, kind: KernelKind) -> f64 {
        let per_layer = match kind {
            KernelKind::SnapMlaFp8 | KernelKind::AmlaFp8 | KernelKind::PCastFp8 => {
                (self.d_c + 2 * self.d_r + 4) as f64
            }
            KernelKind::FlashMlaBf16 => (2 * (self.d_c + self.d_r)) as f64,
        };
        per_layer * self.n_layers as f64
    }
}

/// A parallelism layout on the 8-GPU node.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct DeploymentConfig {
    pub dp: usize,
    pub tp: usize,
}

impl DeploymentConfig {
    pub const FIG1: [DeploymentConfig; 3] = [
        DeploymentConfig { dp: 1, tp: 8 },
        DeploymentConfig { dp: 4, tp: 2 },
        DeploymentConfig { dp: 8, tp: 1 },
    ];

    pub fn label(&self) -> String {
        format!("DP{}/TP{}", self.dp, self.tp)
    }

    pub fn gpus(&self) -> usize {
        self.dp * self.tp
    }
}

/// One evaluated serving point.
#[derive(Clone, Debug)]
pub struct ServingPoint {
    pub config: DeploymentConfig,
    pub context: usize,
    pub kind: KernelKind,
    /// decode batch per DP rank (KV-capacity limited)
    pub batch_per_rank: usize,
    /// one decode step latency, seconds
    pub step_s: f64,
    /// node tokens/second
    pub tokens_per_s: f64,
}

/// Maximum decode batch per rank given the KV memory budget.
pub fn max_batch_per_rank(
    gpu: &GpuSpec,
    model: &ModelSpec,
    cfg: &DeploymentConfig,
    context: usize,
    kind: KernelKind,
) -> usize {
    // FP8 weights sharded TP-ways; MoE experts additionally spread over DP
    // ranks via EP in real deployments — model weight residency per GPU as
    // total/(all 8 gpus) (the node holds one model copy).
    let weight_bytes_per_gpu = model.total_params / cfg.gpus() as f64;
    let runtime_reserve = 8e9; // activations, workspace, fragmentation
    let kv_budget = (gpu.hbm_bytes - weight_bytes_per_gpu - runtime_reserve).max(0.0);
    // the latent cache is REPLICATED across TP ranks (shared by all heads),
    // so TP does not increase per-sequence KV capacity.
    let per_seq = model.kv_bytes_per_token(kind) * context as f64;
    (kv_budget / per_seq).floor() as usize
}

/// Expert/dense weight bytes one step streams for `units` concurrent token
/// rows (batching improves expert reuse sublinearly — dispersion exponent
/// 0.35 — capped by the full model once all experts are touched).
fn expert_stream_read(model: &ModelSpec, units: f64) -> f64 {
    (model.active_params * units.powf(0.35)).min(model.total_params)
}

/// One decode step time for a batch of `batch` sequences at `context`.
pub fn decode_step_s(
    gpu: &GpuSpec,
    model: &ModelSpec,
    cfg: &DeploymentConfig,
    batch: usize,
    context: usize,
    kind: KernelKind,
) -> f64 {
    if batch == 0 {
        return f64::INFINITY;
    }
    // --- attention: per layer, heads sharded TP-ways, full KV read ---------
    let shape = KernelShape {
        batch,
        heads: model.heads / cfg.tp,
        t_q: 1,
        seq: context,
        d_c: model.d_c,
        d_r: model.d_r,
    };
    let attn = kernel_time_s(gpu, &shape, kind) * model.n_layers as f64;

    // --- expert/dense weight streaming --------------------------------------
    // Decode reads the activated parameters; FP8 weights: 1 byte/param.
    let read = expert_stream_read(model, batch as f64);
    let weights = read / cfg.gpus() as f64 / gpu.hbm_bw;
    // GEMM compute for the activated params (FP8 tensor cores)
    let gemm_flops = 2.0 * model.active_params * batch as f64 / cfg.gpus() as f64;
    let gemm = gemm_flops / (gpu.fp8_tflops * 1e12 * gpu.peak_util);

    // --- TP collectives: one all-reduce of the hidden state per layer -------
    let allreduce = tp_comm_s(gpu, model, cfg, batch as f64);

    // --- dataflow launches (§3.3): BF16 path needs separate quant-free
    // copies; SnapMLA fuses token-prep+append+quant into the step ----------
    let launches_per_layer = match kind {
        // fused Q-quant + fused K-append (all variants share the dataflow)
        KernelKind::SnapMlaFp8 | KernelKind::AmlaFp8 | KernelKind::PCastFp8 => 2.0,
        KernelKind::FlashMlaBf16 => 3.0, // proj copy + rope copy + append
    };
    let launches = launches_per_layer * model.n_layers as f64 * gpu.launch_s;

    attn + weights.max(gemm) + allreduce + launches
}

/// Head dims of the NON-absorbed MLA form prefill attention runs in
/// (absorption is decode-only: a 512-dim latent per head is
/// flop-prohibitive for multi-token queries, so production MLA serving
/// prefills in the naive form — cf. the hardware-centric MLA analysis).
const PREFILL_V_HEAD: usize = 128;
const PREFILL_ROPE_HEAD: usize = 64;

/// Prefill attention time for `t_q` new tokens against a `ctx`-token cache.
fn prefill_attn_s(
    gpu: &GpuSpec,
    model: &ModelSpec,
    cfg: &DeploymentConfig,
    t_q: usize,
    ctx: usize,
    kind: KernelKind,
) -> f64 {
    let shape = KernelShape {
        batch: 1,
        heads: model.heads / cfg.tp,
        t_q,
        seq: ctx.max(1),
        d_c: PREFILL_V_HEAD,
        d_r: PREFILL_ROPE_HEAD,
    };
    kernel_time_s(gpu, &shape, kind) * model.n_layers as f64
}

/// One standalone prefill call over `tokens` prompt tokens (the alternating
/// scheduler's dedicated prefill step): prompt GEMMs, one expert
/// weight-streaming pass, causal attention over the growing context, and
/// the separate token-preparation launches. While it runs, every decoder
/// stalls — that serialization is exactly what mixed batching removes.
pub fn prefill_step_s(
    gpu: &GpuSpec,
    model: &ModelSpec,
    cfg: &DeploymentConfig,
    tokens: usize,
    kind: KernelKind,
) -> f64 {
    if tokens == 0 {
        return 0.0;
    }
    let t = tokens as f64;
    let weights = expert_stream_read(model, t) / cfg.gpus() as f64 / gpu.hbm_bw;
    let peak_tflops = match kind {
        KernelKind::SnapMlaFp8 | KernelKind::AmlaFp8 | KernelKind::PCastFp8 => gpu.fp8_tflops,
        KernelKind::FlashMlaBf16 => gpu.bf16_tflops,
    };
    let gemm_flops = 2.0 * model.active_params * t / cfg.gpus() as f64;
    let gemm = gemm_flops / (peak_tflops * 1e12 * gpu.peak_util);
    // causal attention ≈ every token attends to half the prompt on average
    let attn = prefill_attn_s(gpu, model, cfg, tokens, (tokens / 2).max(1), kind);
    let launches = 3.0 * model.n_layers as f64 * gpu.launch_s;
    weights.max(gemm) + attn + tp_comm_s(gpu, model, cfg, t) + launches
}

/// One **mixed** step: the decode batch at `context` plus `chunk_tokens` of
/// piggybacked chunked prefill whose own cache reaches `chunk_context`.
/// Decode at serving batch sizes is weight-streaming bound, so the chunk's
/// GEMM compute hides inside the decode step's memory phase (the §3.3
/// fused-dataflow argument: one weight pass feeds both token streams); only
/// the excess compute extends the step.
#[allow(clippy::too_many_arguments)]
pub fn mixed_step_s(
    gpu: &GpuSpec,
    model: &ModelSpec,
    cfg: &DeploymentConfig,
    decode_batch: usize,
    context: usize,
    chunk_tokens: usize,
    chunk_context: usize,
    kind: KernelKind,
) -> f64 {
    if chunk_tokens == 0 {
        return decode_step_s(gpu, model, cfg, decode_batch, context, kind);
    }
    let c = chunk_tokens as f64;
    let peak_tflops = match kind {
        KernelKind::SnapMlaFp8 | KernelKind::AmlaFp8 | KernelKind::PCastFp8 => gpu.fp8_tflops,
        KernelKind::FlashMlaBf16 => gpu.bf16_tflops,
    };
    let eff = peak_tflops * 1e12 * gpu.peak_util;
    let gemm_c = 2.0 * model.active_params * c / cfg.gpus() as f64 / eff;
    let attn_c =
        prefill_attn_s(gpu, model, cfg, chunk_tokens, chunk_context.max(chunk_tokens), kind);
    let chunk_compute = gemm_c + attn_c;
    if decode_batch == 0 {
        // nothing to hide behind: the chunk pays its own weight pass
        let weights = expert_stream_read(model, c) / cfg.gpus() as f64 / gpu.hbm_bw;
        return weights.max(chunk_compute)
            + tp_comm_s(gpu, model, cfg, c)
            + 2.0 * model.n_layers as f64 * gpu.launch_s;
    }
    let base = decode_step_s(gpu, model, cfg, decode_batch, context, kind);
    let weights_mem =
        expert_stream_read(model, decode_batch as f64) / cfg.gpus() as f64 / gpu.hbm_bw;
    let gemm_d = 2.0 * model.active_params * decode_batch as f64 / cfg.gpus() as f64 / eff;
    // compute idle while the decode streams weights — the piggyback budget
    let hidden = (weights_mem - gemm_d).max(0.0);
    // the chunk's share of each layer's all-reduce rides the wire serially
    // with the decode rows — communication does not hide behind HBM reads
    base + (chunk_compute - hidden).max(0.0) + tp_comm_s(gpu, model, cfg, c) + gpu.launch_s
}

/// Layers the deterministic MTP draft head runs (DeepSeek ships one
/// next-token-prediction head; the draft pass streams this fraction of the
/// expert weights per drafted token).
pub const SPEC_DRAFT_LAYERS: usize = 1;

/// One **speculative** step: the decode batch drafts `draft_len` tokens per
/// sequence through the MTP head, then one verify pass scores all drafted
/// positions. Verify behaves like a small-batch prefill riding the decode
/// step (cf. the hardware-centric MLA analysis): its `batch * draft_len`
/// extra query rows add GEMM + attention compute that hides inside the
/// decode weight-streaming phase exactly like a mixed-step chunk — only the
/// excess extends the step. The draft head pays `draft_len` sequential
/// single-layer passes (attention + its weight fraction + its share of the
/// TP all-reduce).
pub fn spec_step_s(
    gpu: &GpuSpec,
    model: &ModelSpec,
    cfg: &DeploymentConfig,
    batch: usize,
    context: usize,
    draft_len: usize,
    kind: KernelKind,
) -> f64 {
    if batch == 0 {
        return f64::INFINITY;
    }
    let peak_tflops = match kind {
        KernelKind::SnapMlaFp8 | KernelKind::AmlaFp8 | KernelKind::PCastFp8 => gpu.fp8_tflops,
        KernelKind::FlashMlaBf16 => gpu.bf16_tflops,
    };
    let eff = peak_tflops * 1e12 * gpu.peak_util;
    let base = decode_step_s(gpu, model, cfg, batch, context, kind);
    // --- verify: batch*draft_len extra rows against the full context -------
    let extra = (batch * draft_len) as f64;
    let gemm_x = 2.0 * model.active_params * extra / cfg.gpus() as f64 / eff;
    let shape_x = KernelShape {
        batch,
        heads: model.heads / cfg.tp,
        t_q: draft_len,
        seq: context,
        d_c: model.d_c,
        d_r: model.d_r,
    };
    let attn_x = kernel_time_s(gpu, &shape_x, kind) * model.n_layers as f64;
    let weights_mem =
        expert_stream_read(model, batch as f64) / cfg.gpus() as f64 / gpu.hbm_bw;
    let gemm_d = 2.0 * model.active_params * batch as f64 / cfg.gpus() as f64 / eff;
    let hidden = (weights_mem - gemm_d).max(0.0);
    let verify = (gemm_x + attn_x - hidden).max(0.0);
    // --- draft: draft_len sequential MTP-head passes -----------------------
    let frac = SPEC_DRAFT_LAYERS as f64 / model.n_layers as f64;
    let shape_d = KernelShape {
        batch,
        heads: model.heads / cfg.tp,
        t_q: 1,
        seq: context,
        d_c: model.d_c,
        d_r: model.d_r,
    };
    let d_attn = kernel_time_s(gpu, &shape_d, kind) * SPEC_DRAFT_LAYERS as f64;
    let d_weights =
        expert_stream_read(model, batch as f64) * frac / cfg.gpus() as f64 / gpu.hbm_bw;
    let d_gemm = 2.0 * model.active_params * frac * batch as f64 / cfg.gpus() as f64 / eff;
    let d_launch = 2.0 * SPEC_DRAFT_LAYERS as f64 * gpu.launch_s;
    let draft = draft_len as f64
        * (d_attn + d_weights.max(d_gemm) + tp_comm_s(gpu, model, cfg, batch as f64) * frac
            + d_launch);
    base + verify + draft + tp_comm_s(gpu, model, cfg, extra) + gpu.launch_s
}

/// Host-side page-spill (or restore) time for a preempted sequence:
/// moving `tokens` of KV to host DRAM over the PCIe link plus a fixed
/// launch pair. Spills cross the host link, not HBM: the old HBM-bandwidth
/// pricing understated a preemption stall by ~60x on an H20, which is what
/// made synchronous spill look free and the tiered overlap look pointless.
pub fn spill_s(gpu: &GpuSpec, model: &ModelSpec, tokens: usize, kind: KernelKind) -> f64 {
    host_spill_s(gpu, model, tokens, kind)
}

/// Device→host KV eviction time for `tokens` of cache over PCIe.
pub fn host_spill_s(gpu: &GpuSpec, model: &ModelSpec, tokens: usize, kind: KernelKind) -> f64 {
    model.kv_bytes_per_token(kind) * tokens as f64 / gpu.pcie_bw + 2.0 * gpu.launch_s
}

/// Host→device KV prefetch time (symmetric PCIe link, full duplex — an
/// in-flight spill does not slow a concurrent prefetch).
pub fn prefetch_s(gpu: &GpuSpec, model: &ModelSpec, tokens: usize, kind: KernelKind) -> f64 {
    model.kv_bytes_per_token(kind) * tokens as f64 / gpu.pcie_bw + 2.0 * gpu.launch_s
}

/// Cost of attending over rank-reduced cold pages: a d_c x r up-projection
/// per cold token per layer on the tensor cores (the decompression-on-access
/// half of the tiered cache's compression codec — see `kvcache::compress`).
pub fn decompress_s(gpu: &GpuSpec, model: &ModelSpec, rank_r: usize, tokens: usize) -> f64 {
    2.0 * rank_r as f64 * model.d_c as f64 * model.n_layers as f64 * tokens as f64
        / (gpu.bf16_tflops * 1e12 * gpu.peak_util)
}

/// Prefill→decode KV migration time for a handed-off sequence: the wire
/// block (`tokens` of the pipeline's per-token KV bytes — the `KvWireBlock`
/// format is exactly the cache's storage bytes) over the inter-rank link,
/// priced by `cluster::collective::transfer_time_s`. The transfer overlaps
/// the prefill rank's next step; this is the latency until the decode rank
/// holds the sequence.
pub fn handoff_s(gpu: &GpuSpec, model: &ModelSpec, tokens: usize, kind: KernelKind) -> f64 {
    let spec = CollectiveSpec { link_bw: gpu.nvlink_bw, latency_s: COLLECTIVE_LATENCY_S };
    transfer_time_s(&spec, model.kv_bytes_per_token(kind) * tokens as f64)
}

/// Evaluate one Fig. 1 serving point (batch chosen by KV capacity).
pub fn serving_point(
    gpu: &GpuSpec,
    model: &ModelSpec,
    cfg: &DeploymentConfig,
    context: usize,
    kind: KernelKind,
) -> ServingPoint {
    let batch = max_batch_per_rank(gpu, model, cfg, context, kind).max(1);
    let step = decode_step_s(gpu, model, cfg, batch, context, kind);
    ServingPoint {
        config: *cfg,
        context,
        kind,
        batch_per_rank: batch,
        step_s: step,
        tokens_per_s: (batch * cfg.dp) as f64 / step,
    }
}

/// Same-batch comparison (the paper's "matched per-rank input shapes").
pub fn matched_point(
    gpu: &GpuSpec,
    model: &ModelSpec,
    cfg: &DeploymentConfig,
    context: usize,
    batch: usize,
    kind: KernelKind,
) -> ServingPoint {
    let step = decode_step_s(gpu, model, cfg, batch, context, kind);
    ServingPoint {
        config: *cfg,
        context,
        kind,
        batch_per_rank: batch,
        step_s: step,
        tokens_per_s: (batch * cfg.dp) as f64 / step,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn setup() -> (GpuSpec, ModelSpec) {
        (GpuSpec::h20(), ModelSpec::deepseek_v31())
    }

    #[test]
    fn kv_bytes_per_token_paper_values() {
        let m = ModelSpec::deepseek_v31();
        // FP8: 512 + 128 + 4 = 644 B/layer; BF16: 1152 B/layer
        assert_eq!(m.kv_bytes_per_token(KernelKind::SnapMlaFp8), 644.0 * 61.0);
        assert_eq!(m.kv_bytes_per_token(KernelKind::FlashMlaBf16), 1152.0 * 61.0);
    }

    #[test]
    fn fp8_fits_more_sequences() {
        let (g, m) = setup();
        for cfg in DeploymentConfig::FIG1 {
            for ctx in [16_384usize, 65_536, 131_072] {
                let b8 = max_batch_per_rank(&g, &m, &cfg, ctx, KernelKind::SnapMlaFp8);
                let b16 = max_batch_per_rank(&g, &m, &cfg, ctx, KernelKind::FlashMlaBf16);
                assert!(
                    b8 as f64 >= 1.6 * b16.max(1) as f64,
                    "{} ctx {ctx}: fp8 {b8} vs bf16 {b16}",
                    cfg.label()
                );
            }
        }
    }

    #[test]
    fn speedup_in_paper_band() {
        // serving-mode speedup must be >1 everywhere and reach ~1.7-2.0x
        // somewhere in the sweep (paper: up to 1.91x)
        let (g, m) = setup();
        let mut best: f64 = 0.0;
        for cfg in DeploymentConfig::FIG1 {
            for ctx in [16_384usize, 32_768, 65_536, 131_072] {
                let fp8 = serving_point(&g, &m, &cfg, ctx, KernelKind::SnapMlaFp8);
                let bf16 = serving_point(&g, &m, &cfg, ctx, KernelKind::FlashMlaBf16);
                let s = fp8.tokens_per_s / bf16.tokens_per_s;
                assert!(s > 1.0, "{} ctx {ctx}: speedup {s}", cfg.label());
                assert!(s < 2.6, "{} ctx {ctx}: speedup {s} implausible", cfg.label());
                best = best.max(s);
            }
        }
        assert!(best > 1.6 && best < 2.2, "best speedup {best} (paper: 1.91x)");
    }

    #[test]
    fn matched_shapes_still_win() {
        // even at identical batch, FP8 wins on kernel + dataflow time
        let (g, m) = setup();
        let cfg = DeploymentConfig { dp: 8, tp: 1 };
        for ctx in [16_384usize, 131_072] {
            let fp8 = matched_point(&g, &m, &cfg, ctx, 8, KernelKind::SnapMlaFp8);
            let bf16 = matched_point(&g, &m, &cfg, ctx, 8, KernelKind::FlashMlaBf16);
            assert!(fp8.step_s < bf16.step_s);
        }
    }

    #[test]
    fn longer_context_grows_attention_share() {
        let (g, m) = setup();
        let cfg = DeploymentConfig { dp: 8, tp: 1 };
        let t16 = decode_step_s(&g, &m, &cfg, 8, 16_384, KernelKind::FlashMlaBf16);
        let t128 = decode_step_s(&g, &m, &cfg, 8, 131_072, KernelKind::FlashMlaBf16);
        assert!(t128 > 2.0 * t16, "{t16} vs {t128}");
    }

    #[test]
    fn dp_beats_tp_for_mla_at_long_context() {
        // the latent cache is replicated under TP, so DP8/TP1 serves more
        // total sequences — the known MLA serving preference.
        let (g, m) = setup();
        let dp8 = serving_point(&g, &m, &DeploymentConfig { dp: 8, tp: 1 }, 65_536,
            KernelKind::SnapMlaFp8);
        let tp8 = serving_point(&g, &m, &DeploymentConfig { dp: 1, tp: 8 }, 65_536,
            KernelKind::SnapMlaFp8);
        assert!(dp8.tokens_per_s > tp8.tokens_per_s);
    }

    #[test]
    fn mixed_step_piggybacks_cheaper_than_separate_prefill() {
        // the whole point of mixed batching: the marginal cost of riding a
        // prompt chunk on a decode step is far below a standalone prefill
        // of the same tokens (the chunk's GEMM hides in the decode's
        // weight-streaming phase)
        let (g, m) = setup();
        let cfg = DeploymentConfig { dp: 8, tp: 1 };
        for ctx in [4096usize, 16_384, 65_536] {
            for chunk in [64usize, 128] {
                let decode_only = decode_step_s(&g, &m, &cfg, 8, ctx, KernelKind::SnapMlaFp8);
                let mixed =
                    mixed_step_s(&g, &m, &cfg, 8, ctx, chunk, chunk, KernelKind::SnapMlaFp8);
                let extra = mixed - decode_only;
                let standalone = prefill_step_s(&g, &m, &cfg, chunk, KernelKind::SnapMlaFp8);
                assert!(
                    extra < 0.6 * standalone,
                    "ctx {ctx} chunk {chunk}: extra {extra} vs standalone {standalone}"
                );
                // and the chunk is never free below the decode-only step
                assert!(mixed >= decode_only, "ctx {ctx} chunk {chunk}");
            }
        }
    }

    #[test]
    fn tp_layouts_price_their_collectives_everywhere() {
        // isolate the collective term by varying ONLY the link bandwidth:
        // the step-time delta must equal the all-reduce wire-time delta
        // exactly, in decode, standalone prefill, and both mixed branches
        let (g, m) = setup();
        let fast = GpuSpec { nvlink_bw: g.nvlink_bw * 1e6, ..g };
        let tp4 = DeploymentConfig { dp: 2, tp: 4 };
        let k = KernelKind::SnapMlaFp8;
        let wire = |units: f64| tp_comm_s(&g, &m, &tp4, units) - tp_comm_s(&fast, &m, &tp4, units);
        assert!(wire(512.0) > 0.0);
        assert!(tp_comm_s(&g, &m, &tp4, 1.0) >= COLLECTIVE_LATENCY_S * m.n_layers as f64);

        let close = |a: f64, b: f64| (a - b).abs() <= 1e-9 * a.abs().max(1.0);
        let dd = decode_step_s(&g, &m, &tp4, 8, 8192, k)
            - decode_step_s(&fast, &m, &tp4, 8, 8192, k);
        assert!(close(dd, wire(8.0)), "decode: {dd} vs {}", wire(8.0));
        let dp = prefill_step_s(&g, &m, &tp4, 512, k) - prefill_step_s(&fast, &m, &tp4, 512, k);
        assert!(close(dp, wire(512.0)), "prefill: {dp} vs {}", wire(512.0));
        let dm = mixed_step_s(&g, &m, &tp4, 8, 8192, 128, 128, k)
            - mixed_step_s(&fast, &m, &tp4, 8, 8192, 128, 128, k);
        assert!(close(dm, wire(8.0) + wire(128.0)), "mixed: {dm}");
        let ds = mixed_step_s(&g, &m, &tp4, 0, 0, 128, 128, k)
            - mixed_step_s(&fast, &m, &tp4, 0, 0, 128, 128, k);
        assert!(close(ds, wire(128.0)), "chunk-only: {ds}");
        // TP = 1 moves no bytes: link bandwidth is irrelevant
        let tp1 = DeploymentConfig { dp: 8, tp: 1 };
        assert_eq!(tp_comm_s(&g, &m, &tp1, 64.0), 0.0);
        assert_eq!(
            prefill_step_s(&g, &m, &tp1, 512, k),
            prefill_step_s(&fast, &m, &tp1, 512, k)
        );
    }

    #[test]
    fn prefill_cost_scales_with_prompt() {
        let (g, m) = setup();
        let cfg = DeploymentConfig { dp: 8, tp: 1 };
        let t256 = prefill_step_s(&g, &m, &cfg, 256, KernelKind::SnapMlaFp8);
        let t2048 = prefill_step_s(&g, &m, &cfg, 2048, KernelKind::SnapMlaFp8);
        assert!(t2048 > 4.0 * t256, "{t256} vs {t2048}");
        assert_eq!(prefill_step_s(&g, &m, &cfg, 0, KernelKind::SnapMlaFp8), 0.0);
    }

    #[test]
    fn mixed_with_no_decode_still_pays_weight_pass() {
        let (g, m) = setup();
        let cfg = DeploymentConfig { dp: 8, tp: 1 };
        let solo = mixed_step_s(&g, &m, &cfg, 0, 0, 64, 64, KernelKind::SnapMlaFp8);
        assert!(solo > 0.0 && solo.is_finite());
        // zero chunk tokens degrades exactly to a decode step
        let d = decode_step_s(&g, &m, &cfg, 4, 8192, KernelKind::SnapMlaFp8);
        assert_eq!(mixed_step_s(&g, &m, &cfg, 4, 8192, 0, 0, KernelKind::SnapMlaFp8), d);
    }

    #[test]
    fn handoff_is_cheaper_than_re_prefill_and_fp8_wire_beats_bf16() {
        let (g, m) = setup();
        let cfg = DeploymentConfig { dp: 8, tp: 1 };
        // migrating 8k tokens of KV must be far cheaper than re-prefilling
        // them on the decode rank (the case for KV migration)
        let hand = handoff_s(&g, &m, 8192, KernelKind::SnapMlaFp8);
        let recompute = prefill_step_s(&g, &m, &cfg, 8192, KernelKind::SnapMlaFp8);
        assert!(hand * 4.0 < recompute, "{hand} vs {recompute}");
        // and the FP8 wire format moves ~56% of the bf16-everything bytes
        let bf16 = handoff_s(&g, &m, 8192, KernelKind::FlashMlaBf16);
        let ratio = (hand - COLLECTIVE_LATENCY_S) / (bf16 - COLLECTIVE_LATENCY_S);
        assert!((ratio - 644.0 / 1152.0).abs() < 1e-9, "{ratio}");
    }

    #[test]
    fn spill_cost_is_small_vs_recompute() {
        let (g, m) = setup();
        let cfg = DeploymentConfig { dp: 8, tp: 1 };
        // spilling 8k tokens of latent KV must be much cheaper than
        // re-prefilling them (the case for page-spill preemption)
        let spill = spill_s(&g, &m, 8192, KernelKind::SnapMlaFp8);
        let recompute = prefill_step_s(&g, &m, &cfg, 8192, KernelKind::SnapMlaFp8);
        assert!(spill * 20.0 < recompute, "{spill} vs {recompute}");
    }

    #[test]
    fn host_spill_crosses_pcie_not_nvlink() {
        let (g, m) = setup();
        let tokens = 8192;
        // same bytes, three links: HBM copy < NVLink handoff < PCIe spill —
        // the regression this pins is spill_s pricing through the HBM/NVLink
        // path, which understated preemption stalls by the bw ratio
        let spill = host_spill_s(&g, &m, tokens, KernelKind::SnapMlaFp8);
        let hand = handoff_s(&g, &m, tokens, KernelKind::SnapMlaFp8);
        assert!(spill > hand, "{spill} vs {hand}");
        let bytes = m.kv_bytes_per_token(KernelKind::SnapMlaFp8) * tokens as f64;
        assert!((spill - (bytes / g.pcie_bw + 2.0 * g.launch_s)).abs() < 1e-12);
        // spill and prefetch price the same symmetric link
        assert_eq!(
            host_spill_s(&g, &m, tokens, KernelKind::SnapMlaFp8),
            prefetch_s(&g, &m, tokens, KernelKind::SnapMlaFp8)
        );
        // and a spilled token is ~7x slower to move than a handed-off one
        let ratio = (spill - 2.0 * g.launch_s) / (hand - COLLECTIVE_LATENCY_S);
        assert!((ratio - g.nvlink_bw / g.pcie_bw).abs() < 1e-9, "{ratio}");
    }

    #[test]
    fn longcat_also_wins() {
        let g = GpuSpec::h20();
        let m = ModelSpec::longcat_flash();
        let cfg = DeploymentConfig { dp: 4, tp: 2 };
        let fp8 = serving_point(&g, &m, &cfg, 65_536, KernelKind::SnapMlaFp8);
        let bf16 = serving_point(&g, &m, &cfg, 65_536, KernelKind::FlashMlaBf16);
        assert!(fp8.tokens_per_s > 1.2 * bf16.tokens_per_s);
    }
}
