//! GPU constants (H20-class Hopper device; see module docs in `perfmodel`).

#[derive(Clone, Copy, Debug)]
pub struct GpuSpec {
    /// dense BF16 tensor-core peak, TFLOPS
    pub bf16_tflops: f64,
    /// dense FP8 tensor-core peak, TFLOPS (2x BF16 on Hopper)
    pub fp8_tflops: f64,
    /// HBM bandwidth, bytes/s
    pub hbm_bw: f64,
    /// HBM capacity, bytes
    pub hbm_bytes: f64,
    /// NVLink per-GPU bandwidth, bytes/s (for TP collectives)
    pub nvlink_bw: f64,
    /// PCIe host-link bandwidth, bytes/s (for KV spill/prefetch to host
    /// DRAM — an order of magnitude below NVLink, which is why host
    /// spills must overlap with decode rather than stall it)
    pub pcie_bw: f64,
    /// kernel launch + scheduling overhead per launch, seconds
    pub launch_s: f64,
    /// achievable fraction of peak for a well-tuned kernel (App. I: ~85%)
    pub peak_util: f64,
    /// f32 CUDA-core (vector unit) peak, TFLOPS — prices the softmax /
    /// rescale vector stages that the AMLA and P-Cast variants shrink
    pub vec_f32_tflops: f64,
}

impl GpuSpec {
    /// The paper's testbed GPU (H20-class: BF16 peak 148 TFLOPS per App. H).
    pub fn h20() -> GpuSpec {
        GpuSpec {
            bf16_tflops: 148.0,
            fp8_tflops: 296.0,
            hbm_bw: 4.0e12,
            hbm_bytes: 141.0e9,
            nvlink_bw: 450.0e9,
            pcie_bw: 64.0e9,
            launch_s: 4.0e-6,
            peak_util: 0.88,
            vec_f32_tflops: 44.0,
        }
    }

    /// Effective FP8 peak of the SnapMLA mixed-precision MLA kernel
    /// (App. H Eq. 14): 17 tiles of BF16-equivalent work executed in
    /// 16/2 + 1 = 9 BF16-tile time units.
    pub fn snapmla_effective_peak_tflops(&self) -> f64 {
        self.bf16_tflops * 17.0 / 9.0
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn effective_peak_matches_paper() {
        let g = GpuSpec::h20();
        let p = g.snapmla_effective_peak_tflops();
        assert!((p - 279.6).abs() < 0.2, "{p}"); // paper: ≈ 279.6 TFLOPS
    }

    #[test]
    fn fp8_is_double_bf16() {
        let g = GpuSpec::h20();
        assert_eq!(g.fp8_tflops, 2.0 * g.bf16_tflops);
    }

    #[test]
    fn pcie_is_much_slower_than_nvlink_and_hbm() {
        let g = GpuSpec::h20();
        assert!(g.pcie_bw < g.nvlink_bw / 5.0);
        assert!(g.nvlink_bw < g.hbm_bw);
    }
}
