//! Calibrated analytical performance model of the paper's testbed.
//!
//! The paper's GPU is an undisclosed Hopper part with a **BF16 peak of 148
//! TFLOPS** (App. H) — the signature of an H20-class device (148 BF16 / 296
//! FP8 TFLOPS, HBM3e). We model kernel and end-to-end step times from first
//! principles (bytes moved, FLOPs issued, tile utilization, launch overhead)
//! with constants calibrated to the paper's own numbers:
//!
//! * effective FP8 MLA peak = 148 × 17/9 ≈ 279.6 TFLOPS (App. H Eq. 14 —
//!   sixteen FP8 content tiles at 2× rate + one BF16 RoPE tile),
//! * kernel efficiency saturating at ~85% of that peak for H ≥ 64 (App. I).
//!
//! This model regenerates the *shape* of Figures 1, 6 and 7 — who wins, by
//! what factor, where curves saturate — on our CPU substrate, where absolute
//! Hopper timings cannot be measured (DESIGN.md §Substitutions). Its byte
//! and FLOP accounting is exact and unit-tested; only the rate constants are
//! calibrated.

pub mod e2e;
pub mod gpu;
pub mod kernel;

pub use e2e::{DeploymentConfig, ModelSpec, ServingPoint};
pub use gpu::GpuSpec;
pub use kernel::{kernel_time_s, KernelKind, KernelShape};
