//! Kernel-level timing model for the MLA decode-attention kernels
//! (SnapMLA FP8 vs FlashMLA BF16), backing Figs. 6 and 7.

use super::gpu::GpuSpec;

/// Which kernel (determines compute rate and KV-cache byte width).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum KernelKind {
    /// SnapMLA FP8: E4M3 content + bf16 RoPE cache, 17/9 effective peak.
    SnapMlaFp8,
    /// FlashMLA BF16 baseline.
    FlashMlaBf16,
}

/// One decode-attention invocation shape (absorbed MLA decode).
#[derive(Clone, Copy, Debug)]
pub struct KernelShape {
    pub batch: usize,
    pub heads: usize,
    /// query tokens per sequence (MTP; 1 or 2)
    pub t_q: usize,
    /// KV-cache length (tokens attended)
    pub seq: usize,
    pub d_c: usize,
    pub d_r: usize,
}

impl KernelShape {
    pub fn paper(batch: usize, heads: usize, t_q: usize, seq: usize) -> KernelShape {
        KernelShape { batch, heads, t_q, seq, d_c: 512, d_r: 64 }
    }

    /// FLOPs of one invocation: QK GEMM over (d_c + d_r) + PV GEMM over d_c,
    /// per (batch, head, query token, cache token), 2 flops per MAC.
    pub fn flops(&self) -> f64 {
        let rows = (self.batch * self.heads * self.t_q) as f64;
        let n = self.seq as f64;
        let qk = rows * n * (self.d_c + self.d_r) as f64 * 2.0;
        let pv = rows * n * self.d_c as f64 * 2.0;
        qk + pv
    }

    /// HBM bytes of one invocation. The latent KV cache is read ONCE per
    /// sequence (shared across heads — MLA's core memory property); Q in and
    /// O out are negligible at decode shapes but included.
    pub fn bytes(&self, kind: KernelKind) -> f64 {
        let per_token = match kind {
            // u8 content + bf16 rope + f32 scale
            KernelKind::SnapMlaFp8 => self.d_c + 2 * self.d_r + 4,
            // bf16 content + bf16 rope
            KernelKind::FlashMlaBf16 => 2 * (self.d_c + self.d_r),
        } as f64;
        let kv = (self.batch * self.seq) as f64 * per_token;
        let qo = (self.batch * self.heads * self.t_q * (2 * self.d_c + self.d_r)) as f64 * 4.0;
        kv + qo
    }

    /// Arithmetic intensity (flops per HBM byte).
    pub fn intensity(&self, kind: KernelKind) -> f64 {
        self.flops() / self.bytes(kind)
    }
}

/// MXU/WGMMA row-tile utilization: the decode GEMM's M dimension is
/// heads × t_q per CTA; tiles are 64 rows, so small head counts leave the
/// tensor core underfed (App. I: saturation at H ≥ 64, ~85% of peak).
fn row_tile_util(heads: usize, t_q: usize) -> f64 {
    let m = (heads * t_q) as f64;
    (m / 64.0).clamp(1.0 / 64.0, 1.0)
}

/// Pipeline ramp: prologue/epilogue amortize over the KV length (the fig. 6
/// rising trend toward the roofline).
fn ramp(seq: usize) -> f64 {
    let n = seq as f64;
    n / (n + 400.0)
}

/// Predicted execution time (seconds) of one kernel invocation.
pub fn kernel_time_s(gpu: &GpuSpec, shape: &KernelShape, kind: KernelKind) -> f64 {
    let peak_tflops = match kind {
        KernelKind::SnapMlaFp8 => gpu.snapmla_effective_peak_tflops(),
        KernelKind::FlashMlaBf16 => gpu.bf16_tflops,
    };
    let eff = gpu.peak_util * row_tile_util(shape.heads, shape.t_q) * ramp(shape.seq);
    let compute = shape.flops() / (peak_tflops * 1e12 * eff);
    let memory = shape.bytes(kind) / gpu.hbm_bw;
    compute.max(memory) + gpu.launch_s
}

/// Achieved TFLOPS under the model (what Figs. 6/7 plot).
pub fn kernel_tflops(gpu: &GpuSpec, shape: &KernelShape, kind: KernelKind) -> f64 {
    shape.flops() / kernel_time_s(gpu, shape, kind) / 1e12
}

#[cfg(test)]
mod tests {
    use super::*;

    fn gpu() -> GpuSpec {
        GpuSpec::h20()
    }

    #[test]
    fn flop_accounting_exact() {
        let s = KernelShape::paper(1, 1, 1, 1);
        // 1 row, 1 token: (512+64)*2 + 512*2 = 2176
        assert_eq!(s.flops(), 2176.0);
    }

    #[test]
    fn byte_accounting_exact() {
        let s = KernelShape::paper(1, 1, 1, 1);
        // fp8 token: 512 + 128 + 4 = 644; bf16 token: 1152
        assert_eq!(s.bytes(KernelKind::SnapMlaFp8), 644.0 + (1024.0 + 64.0) * 4.0);
        assert_eq!(s.bytes(KernelKind::FlashMlaBf16), 1152.0 + (1024.0 + 64.0) * 4.0);
    }

    #[test]
    fn fp8_cache_is_smaller() {
        let s = KernelShape::paper(8, 128, 1, 65536);
        assert!(s.bytes(KernelKind::SnapMlaFp8) < 0.6 * s.bytes(KernelKind::FlashMlaBf16));
    }

    #[test]
    fn snapmla_never_slower_under_model() {
        for &(b, h, t, n) in
            &[(1usize, 16usize, 1usize, 4096usize), (8, 64, 1, 16384), (32, 128, 2, 131072)]
        {
            let s = KernelShape::paper(b, h, t, n);
            let t_fp8 = kernel_time_s(&gpu(), &s, KernelKind::SnapMlaFp8);
            let t_bf16 = kernel_time_s(&gpu(), &s, KernelKind::FlashMlaBf16);
            assert!(t_fp8 <= t_bf16 * 1.001, "{b} {h} {t} {n}: {t_fp8} vs {t_bf16}");
        }
    }

    #[test]
    fn tflops_below_effective_peak_and_saturates() {
        let g = gpu();
        let peak = g.snapmla_effective_peak_tflops();
        // long-context, many-head shape → approaches ~85% of effective peak
        let s = KernelShape::paper(32, 128, 1, 131072);
        let tf = kernel_tflops(&g, &s, KernelKind::SnapMlaFp8);
        assert!(tf <= peak);
        assert!(tf > 0.75 * peak, "{tf} vs peak {peak}");
    }

    #[test]
    fn head_scaling_matches_fig7() {
        // TFLOPS increases with head count and saturates at H >= 64
        let g = gpu();
        let tf = |h: usize| {
            kernel_tflops(&g, &KernelShape::paper(32, h, 1, 8192), KernelKind::SnapMlaFp8)
        };
        assert!(tf(16) < tf(32) && tf(32) < tf(64));
        let sat = (tf(128) - tf(64)).abs() / tf(64);
        assert!(sat < 0.1, "saturated region should be flat: {sat}");
    }

    #[test]
    fn mtp2_helps_at_low_heads() {
        let g = gpu();
        let t1 = kernel_tflops(&g, &KernelShape::paper(32, 16, 1, 8192), KernelKind::SnapMlaFp8);
        let t2 = kernel_tflops(&g, &KernelShape::paper(32, 16, 2, 8192), KernelKind::SnapMlaFp8);
        assert!(t2 > 1.2 * t1, "{t1} vs {t2}");
    }

    #[test]
    fn seqlen_ramp_matches_fig6() {
        let g = gpu();
        let tf = |n: usize| {
            kernel_tflops(&g, &KernelShape::paper(8, 64, 1, n), KernelKind::SnapMlaFp8)
        };
        assert!(tf(1024) < tf(4096) && tf(4096) < tf(16384));
    }

    #[test]
    fn high_head_decode_is_compute_bound() {
        // the paper's premise: FlashMLA-style decode at H=128 is compute-bound
        let s = KernelShape::paper(32, 128, 1, 65536);
        let g = gpu();
        let compute_intensity_break = g.bf16_tflops * 1e12 / g.hbm_bw;
        assert!(s.intensity(KernelKind::FlashMlaBf16) > compute_intensity_break);
    }
}
