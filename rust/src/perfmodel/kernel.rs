//! Kernel-level timing model for the MLA decode-attention kernels
//! (SnapMLA FP8 and its AMLA / P-Cast variants vs FlashMLA BF16), backing
//! Figs. 6 and 7 and the kernel-variant frontier bench.
//!
//! The three FP8 variants share the SnapMLA cache layout and tensor-core
//! schedule, so they price identically on the GEMM and HBM axes; they differ
//! only in the *vector* (CUDA-core) work interleaved with the MMA pipeline.
//! That difference is modeled as a per-variant saving subtracted from the
//! compute term and clamped to the memory floor — SnapMLA's own pricing is
//! untouched (the committed fig6/fig7/serve baselines pin it).

use super::gpu::GpuSpec;

/// Accumulator-rescale vector ops per (row, block, d_c lane) that AMLA's
/// exponent-ADD removes: the FMA-pipeline multiply + its dependency stall.
const AMLA_RESCALE_STALL_OPS: f64 = 3.0;
/// Per (row, token) vector ops that P-Cast's static P scale removes: the
/// block amax reduction and dynamic-scale divide of the P quantizer.
const PCAST_PSCALE_OPS: f64 = 4.0;

/// Which kernel (determines compute rate and KV-cache byte width).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum KernelKind {
    /// SnapMLA FP8: E4M3 content + bf16 RoPE cache, 17/9 effective peak.
    SnapMlaFp8,
    /// AMLA on the SnapMLA cache: integer-grid running max, exponent-ADD
    /// accumulator rescale (arXiv 2509.25224).
    AmlaFp8,
    /// P-Cast on the SnapMLA cache: static P scale S = 2^8, no per-block
    /// amax pass (arXiv 2606.06521).
    PCastFp8,
    /// FlashMLA BF16 baseline.
    FlashMlaBf16,
}

/// One decode-attention invocation shape (absorbed MLA decode).
#[derive(Clone, Copy, Debug)]
pub struct KernelShape {
    pub batch: usize,
    pub heads: usize,
    /// query tokens per sequence (MTP; 1 or 2)
    pub t_q: usize,
    /// KV-cache length (tokens attended)
    pub seq: usize,
    pub d_c: usize,
    pub d_r: usize,
}

impl KernelShape {
    pub fn paper(batch: usize, heads: usize, t_q: usize, seq: usize) -> KernelShape {
        KernelShape { batch, heads, t_q, seq, d_c: 512, d_r: 64 }
    }

    /// FLOPs of one invocation: QK GEMM over (d_c + d_r) + PV GEMM over d_c,
    /// per (batch, head, query token, cache token), 2 flops per MAC.
    pub fn flops(&self) -> f64 {
        let rows = (self.batch * self.heads * self.t_q) as f64;
        let n = self.seq as f64;
        let qk = rows * n * (self.d_c + self.d_r) as f64 * 2.0;
        let pv = rows * n * self.d_c as f64 * 2.0;
        qk + pv
    }

    /// HBM bytes of one invocation. The latent KV cache is read ONCE per
    /// sequence (shared across heads — MLA's core memory property); Q in and
    /// O out are negligible at decode shapes but included.
    pub fn bytes(&self, kind: KernelKind) -> f64 {
        let per_token = match kind {
            // u8 content + bf16 rope + f32 scale (one layout for all variants)
            KernelKind::SnapMlaFp8 | KernelKind::AmlaFp8 | KernelKind::PCastFp8 => {
                self.d_c + 2 * self.d_r + 4
            }
            // bf16 content + bf16 rope
            KernelKind::FlashMlaBf16 => 2 * (self.d_c + self.d_r),
        } as f64;
        let kv = (self.batch * self.seq) as f64 * per_token;
        let qo = (self.batch * self.heads * self.t_q * (2 * self.d_c + self.d_r)) as f64 * 4.0;
        kv + qo
    }

    /// Arithmetic intensity (flops per HBM byte).
    pub fn intensity(&self, kind: KernelKind) -> f64 {
        self.flops() / self.bytes(kind)
    }
}

/// MXU/WGMMA row-tile utilization: the decode GEMM's M dimension is
/// heads × t_q per CTA; tiles are 64 rows, so small head counts leave the
/// tensor core underfed (App. I: saturation at H ≥ 64, ~85% of peak).
fn row_tile_util(heads: usize, t_q: usize) -> f64 {
    let m = (heads * t_q) as f64;
    (m / 64.0).clamp(1.0 / 64.0, 1.0)
}

/// Pipeline ramp: prologue/epilogue amortize over the KV length (the fig. 6
/// rising trend toward the roofline).
fn ramp(seq: usize) -> f64 {
    let n = seq as f64;
    n / (n + 400.0)
}

/// Vector-stage time the variant saves relative to SnapMLA's fully dynamic
/// softmax pipeline (zero for SnapMLA itself and the BF16 baseline).
fn vector_stage_saving_s(gpu: &GpuSpec, shape: &KernelShape, kind: KernelKind) -> f64 {
    let rows = (shape.batch * shape.heads * shape.t_q) as f64;
    match kind {
        // the accumulator rescale runs once per 64-token block over d_c lanes
        KernelKind::AmlaFp8 => {
            let blocks = shape.seq.div_ceil(64) as f64;
            rows * blocks * shape.d_c as f64 * AMLA_RESCALE_STALL_OPS
                / (gpu.vec_f32_tflops * 1e12)
        }
        // the P-scale amax pass touches every probability once
        KernelKind::PCastFp8 => {
            rows * shape.seq as f64 * PCAST_PSCALE_OPS / (gpu.vec_f32_tflops * 1e12)
        }
        KernelKind::SnapMlaFp8 | KernelKind::FlashMlaBf16 => 0.0,
    }
}

/// Predicted execution time (seconds) of one kernel invocation.
pub fn kernel_time_s(gpu: &GpuSpec, shape: &KernelShape, kind: KernelKind) -> f64 {
    let peak_tflops = match kind {
        KernelKind::SnapMlaFp8 | KernelKind::AmlaFp8 | KernelKind::PCastFp8 => {
            gpu.snapmla_effective_peak_tflops()
        }
        KernelKind::FlashMlaBf16 => gpu.bf16_tflops,
    };
    let eff = gpu.peak_util * row_tile_util(shape.heads, shape.t_q) * ramp(shape.seq);
    let compute = shape.flops() / (peak_tflops * 1e12 * eff);
    let memory = shape.bytes(kind) / gpu.hbm_bw;
    let saved = vector_stage_saving_s(gpu, shape, kind);
    (compute - saved).max(memory) + gpu.launch_s
}

/// Achieved TFLOPS under the model (what Figs. 6/7 plot).
pub fn kernel_tflops(gpu: &GpuSpec, shape: &KernelShape, kind: KernelKind) -> f64 {
    shape.flops() / kernel_time_s(gpu, shape, kind) / 1e12
}

#[cfg(test)]
mod tests {
    use super::*;

    fn gpu() -> GpuSpec {
        GpuSpec::h20()
    }

    #[test]
    fn flop_accounting_exact() {
        let s = KernelShape::paper(1, 1, 1, 1);
        // 1 row, 1 token: (512+64)*2 + 512*2 = 2176
        assert_eq!(s.flops(), 2176.0);
    }

    #[test]
    fn byte_accounting_exact() {
        let s = KernelShape::paper(1, 1, 1, 1);
        // fp8 token: 512 + 128 + 4 = 644; bf16 token: 1152
        assert_eq!(s.bytes(KernelKind::SnapMlaFp8), 644.0 + (1024.0 + 64.0) * 4.0);
        assert_eq!(s.bytes(KernelKind::FlashMlaBf16), 1152.0 + (1024.0 + 64.0) * 4.0);
    }

    #[test]
    fn fp8_cache_is_smaller() {
        let s = KernelShape::paper(8, 128, 1, 65536);
        assert!(s.bytes(KernelKind::SnapMlaFp8) < 0.6 * s.bytes(KernelKind::FlashMlaBf16));
    }

    #[test]
    fn snapmla_never_slower_under_model() {
        for &(b, h, t, n) in
            &[(1usize, 16usize, 1usize, 4096usize), (8, 64, 1, 16384), (32, 128, 2, 131072)]
        {
            let s = KernelShape::paper(b, h, t, n);
            let t_fp8 = kernel_time_s(&gpu(), &s, KernelKind::SnapMlaFp8);
            let t_bf16 = kernel_time_s(&gpu(), &s, KernelKind::FlashMlaBf16);
            assert!(t_fp8 <= t_bf16 * 1.001, "{b} {h} {t} {n}: {t_fp8} vs {t_bf16}");
        }
    }

    #[test]
    fn tflops_below_effective_peak_and_saturates() {
        let g = gpu();
        let peak = g.snapmla_effective_peak_tflops();
        // long-context, many-head shape → approaches ~85% of effective peak
        let s = KernelShape::paper(32, 128, 1, 131072);
        let tf = kernel_tflops(&g, &s, KernelKind::SnapMlaFp8);
        assert!(tf <= peak);
        assert!(tf > 0.75 * peak, "{tf} vs peak {peak}");
    }

    #[test]
    fn head_scaling_matches_fig7() {
        // TFLOPS increases with head count and saturates at H >= 64
        let g = gpu();
        let tf = |h: usize| {
            kernel_tflops(&g, &KernelShape::paper(32, h, 1, 8192), KernelKind::SnapMlaFp8)
        };
        assert!(tf(16) < tf(32) && tf(32) < tf(64));
        let sat = (tf(128) - tf(64)).abs() / tf(64);
        assert!(sat < 0.1, "saturated region should be flat: {sat}");
    }

    #[test]
    fn mtp2_helps_at_low_heads() {
        let g = gpu();
        let t1 = kernel_tflops(&g, &KernelShape::paper(32, 16, 1, 8192), KernelKind::SnapMlaFp8);
        let t2 = kernel_tflops(&g, &KernelShape::paper(32, 16, 2, 8192), KernelKind::SnapMlaFp8);
        assert!(t2 > 1.2 * t1, "{t1} vs {t2}");
    }

    #[test]
    fn seqlen_ramp_matches_fig6() {
        let g = gpu();
        let tf = |n: usize| {
            kernel_tflops(&g, &KernelShape::paper(8, 64, 1, n), KernelKind::SnapMlaFp8)
        };
        assert!(tf(1024) < tf(4096) && tf(4096) < tf(16384));
    }

    #[test]
    fn fp8_variants_share_the_cache_layout() {
        let s = KernelShape::paper(8, 128, 1, 65536);
        let b = s.bytes(KernelKind::SnapMlaFp8);
        assert_eq!(s.bytes(KernelKind::AmlaFp8), b);
        assert_eq!(s.bytes(KernelKind::PCastFp8), b);
    }

    #[test]
    fn variant_frontier_ordering() {
        // AMLA saves the most vector work, P-Cast a little, SnapMLA none —
        // and all three beat the BF16 baseline at the paper's decode shape.
        let g = gpu();
        let s = KernelShape::paper(8, 128, 1, 65536);
        let t = |k: KernelKind| kernel_time_s(&g, &s, k);
        assert!(t(KernelKind::AmlaFp8) < t(KernelKind::PCastFp8));
        assert!(t(KernelKind::PCastFp8) < t(KernelKind::SnapMlaFp8));
        assert!(t(KernelKind::SnapMlaFp8) < t(KernelKind::FlashMlaBf16));
    }

    #[test]
    fn variant_savings_are_modest() {
        // the vector stages are a single-digit percentage of kernel time;
        // the model must not invent a >15% win out of them
        let g = gpu();
        for &n in &[4096usize, 16384, 65536, 131072] {
            let s = KernelShape::paper(8, 128, 1, n);
            let t_snap = kernel_time_s(&g, &s, KernelKind::SnapMlaFp8);
            for k in [KernelKind::AmlaFp8, KernelKind::PCastFp8] {
                let t = kernel_time_s(&g, &s, k);
                assert!(t > 0.85 * t_snap, "{k:?} at n={n}: {t} vs {t_snap}");
                assert!(t < t_snap, "{k:?} at n={n}: {t} vs {t_snap}");
            }
        }
    }

    #[test]
    fn savings_never_break_the_memory_floor() {
        let g = gpu();
        for &(b, h, n) in &[(1usize, 1usize, 4096usize), (1, 16, 131072), (32, 128, 65536)] {
            let s = KernelShape::paper(b, h, 1, n);
            for k in [KernelKind::AmlaFp8, KernelKind::PCastFp8] {
                let floor = s.bytes(k) / g.hbm_bw + g.launch_s;
                assert!(kernel_time_s(&g, &s, k) >= floor);
            }
        }
    }

    #[test]
    fn high_head_decode_is_compute_bound() {
        // the paper's premise: FlashMLA-style decode at H=128 is compute-bound
        let s = KernelShape::paper(32, 128, 1, 65536);
        let g = gpu();
        let compute_intensity_break = g.bf16_tflops * 1e12 / g.hbm_bw;
        assert!(s.intensity(KernelKind::FlashMlaBf16) > compute_intensity_break);
    }
}
