//! snapmla — CLI for the SnapMLA serving stack.
//!
//! Subcommands:
//!   info                         — artifact/model summary
//!   serve   [--mode fp8|bf16|disagg] [--kernel snapmla|amla|pcast]
//!           [--requests N] [--dp N] [--pages N]
//!           [--spec N [--accept-rate F]]
//!           [--prefill-ranks N] [--route affinity|shortest]
//!           [--shared-frac F] [--shared-groups N] [--shared-tokens N]
//!           [--tiered]
//!           [--elastic [--fail-at S] [--fail-rank N] [--no-recover]] …
//!                                — serve a synthetic trace through the
//!                                  cluster (prefix-affinity routing by
//!                                  default; `--mode disagg` splits the dp
//!                                  ranks into `--prefill-ranks` prefill
//!                                  ranks migrating KV to the rest; the FP8
//!                                  attention path runs the `--kernel`
//!                                  decode variant; `--spec N` drafts N
//!                                  tokens per sequence per step through the
//!                                  MTP-style drafter and verifies them in
//!                                  one engine call, `--accept-rate F`
//!                                  degrades the drafter's history window to
//!                                  approximate that acceptance rate;
//!                                  `--tiered` arms the async host-tier
//!                                  link: spill/restore transfers overlap
//!                                  decode in virtual time instead of
//!                                  stalling the rank;
//!                                  `--elastic` kills a
//!                                  rank mid-trace and re-migrates its live
//!                                  KV to the survivors over the FP8 wire),
//!                                  print per-rank metrics
//!   fidelity [--ctx N] [--layers N] [--kernel snapmla|amla|pcast]
//!                                — Table-3 config fidelity study plus the
//!                                  kernel-variant comparison (rust sim)
//!   perf    [--model deepseek|longcat] [--kernel snapmla|amla|pcast]
//!                                — Fig.-1-style analytical throughput sweep
//!                                  pricing the selected FP8 kernel variant
//!
//! `cargo run --release -- serve --requests 16`
//!
//! Without compiled artifacts (default offline build) every subcommand runs
//! against the pure-Rust `SimBackend`; with `--features pjrt` and an
//! `artifacts/` dir the same commands drive the AOT HLO via PJRT.

use snapmla::anyhow;
use snapmla::cluster::{ClusterServer, NodeTopology};
use snapmla::coordinator::{RoutePolicy, ServeRequest, Server};
use snapmla::kvcache::CacheMode;
use snapmla::mla::fidelity::{build_stimuli, layerwise_errors, variant_errors};
use snapmla::mla::quant_configs::QuantConfig;
use snapmla::mla::{Shape, VariantKind};
use snapmla::perfmodel::{self, GpuSpec, KernelKind, ModelSpec};
use snapmla::runtime::{Manifest, ModelEngine};
use snapmla::util::cli::Args;
use snapmla::util::rng::Rng;
use snapmla::util::table::{f1, f2, f4, Table};
use snapmla::workload::{TraceConfig, TraceGen};
use std::path::PathBuf;

fn artifacts_dir(args: &Args) -> PathBuf {
    PathBuf::from(args.get_or("artifacts", "artifacts"))
}

fn kernel_variant(args: &Args) -> anyhow::Result<VariantKind> {
    let s = args.get_or("kernel", "snapmla");
    VariantKind::parse(s)
        .ok_or_else(|| anyhow::anyhow!("--kernel must be 'snapmla', 'amla' or 'pcast', got '{s}'"))
}

fn main() -> anyhow::Result<()> {
    let args = Args::parse_with_flags(&["quick", "verbose", "elastic", "no-recover", "tiered"]);
    match args.positional.first().map(String::as_str) {
        Some("info") => info(&args),
        Some("serve") => serve(&args),
        Some("fidelity") => fidelity(&args),
        Some("perf") => perf(&args),
        _ => {
            eprintln!("usage: snapmla <info|serve|fidelity|perf> [flags]");
            eprintln!("see rust/src/main.rs docs for flags");
            Ok(())
        }
    }
}

fn info(args: &Args) -> anyhow::Result<()> {
    let dir = artifacts_dir(args);
    let m = if dir.join("manifest.json").exists() {
        if !cfg!(feature = "pjrt") {
            println!(
                "(note: offline build — serving subcommands execute the sim backend; \
                 rebuild with --features pjrt to run these artifacts)"
            );
        }
        Manifest::load(&dir)?
    } else {
        println!("(no artifacts at {dir:?} — describing the sim model)");
        snapmla::runtime::sim::sim_manifest(&snapmla::runtime::SimSpec::small())
    };
    println!(
        "model: {} params, d_model {}, {} layers, H{} d_c {} d_r {} vocab {}",
        m.model.params, m.model.d_model, m.model.n_layers, m.model.n_heads,
        m.model.d_c, m.model.d_r, m.model.vocab
    );
    let mut t = Table::new("artifacts", &["name", "kind", "mode", "batch", "seq", "heads"]);
    for a in m.artifacts.values() {
        t.row(vec![
            a.name.clone(),
            format!("{:?}", a.kind),
            a.mode.clone(),
            a.batch.to_string(),
            a.seq.to_string(),
            a.heads.to_string(),
        ]);
    }
    t.print();
    Ok(())
}

fn serve(args: &Args) -> anyhow::Result<()> {
    let (mode, disagg) = match args.get_or("mode", "fp8") {
        "bf16" => (CacheMode::Bf16, false),
        "fp8" => (CacheMode::Fp8, false),
        // disaggregated prefill/decode serving over the FP8 wire format
        "disagg" => (CacheMode::Fp8, true),
        other => anyhow::bail!("--mode must be 'fp8', 'bf16' or 'disagg', got '{other}'"),
    };
    let elastic = args.has("elastic");
    anyhow::ensure!(!(elastic && disagg), "--elastic demos the colocated topology");
    let dp = args.usize_or("dp", if disagg { 2 } else if elastic { 3 } else { 1 });
    anyhow::ensure!(!elastic || dp >= 2, "--elastic needs --dp >= 2 (a survivor must remain)");
    let pages = args.usize_or("pages", 256);
    let dir = artifacts_dir(args);
    let trace = TraceGen::generate(&TraceConfig {
        seed: args.u64_or("seed", 0),
        num_requests: args.usize_or("requests", 8),
        mean_interarrival_s: args.f64_or("interarrival", 0.0),
        prompt_min: args.usize_or("prompt-min", 8),
        prompt_max: args.usize_or("prompt-max", 96),
        out_min: args.usize_or("out-min", 16),
        out_max: args.usize_or("out-max", 96),
        temperature: args.f64_or("temperature", 0.7) as f32,
        long_frac: args.f64_or("long-frac", 0.0),
        long_prompt_min: args.usize_or("long-prompt-min", 512),
        long_prompt_max: args.usize_or("long-prompt-max", 1024),
        shared_prefix_frac: args.f64_or("shared-frac", 0.0),
        shared_prefix_groups: args.usize_or("shared-groups", 4),
        shared_prefix_tokens: args.usize_or("shared-tokens", 256),
        max_total_tokens: args.usize_or("token-budget", 0),
        diurnal_period_s: args.f64_or("diurnal-period", 0.0),
        diurnal_amp: args.f64_or("diurnal-amp", 1.0),
    });
    let policy = match args.get_or("route", "affinity") {
        "shortest" => RoutePolicy::ShortestQueue,
        "affinity" => RoutePolicy::PrefixAffinity,
        other => anyhow::bail!("--route must be 'affinity' or 'shortest', got '{other}'"),
    };

    let kernel = kernel_variant(args)?;
    let spec = args.usize_or("spec", 0);
    let accept = args.f64_or("accept-rate", 1.0);
    anyhow::ensure!(
        spec == 0 || (accept > 0.0 && accept <= 1.0),
        "--accept-rate must be in (0, 1], got {accept}"
    );
    let ranks: anyhow::Result<Vec<Server>> = (0..dp)
        .map(|_| {
            let mut b = ModelEngine::builder(mode).kernel(kernel).artifacts(&dir);
            if spec > 0 && accept < 0.999 {
                // drafter-fidelity knob: a tighter history window misses
                // induction pairs, approximating a lower acceptance rate
                b = b.draft_window(((2.0 / (1.0 - accept)).round() as usize).max(1));
            }
            let mut srv = Server::new(b.build()?, pages);
            if spec > 0 {
                srv.enable_spec(spec)?;
            }
            Ok(srv)
        })
        .collect();
    let mut cluster = if disagg {
        let prefill_ranks = args.usize_or("prefill-ranks", 1);
        anyhow::ensure!(
            prefill_ranks >= 1 && prefill_ranks < dp,
            "--prefill-ranks must be in 1..dp (dp {dp}, got {prefill_ranks})"
        );
        ClusterServer::disaggregated(ranks?, prefill_ranks)
    } else {
        ClusterServer::new(ranks?, policy)
    };
    if args.has("tiered") {
        // tiered KV cache demo: price each host spill/restore as a PCIe
        // transfer of a typical preempted context and overlap the flights
        // with decode in virtual time (the sync baseline would stall the
        // rank for every transfer)
        let (gpu, model) = (GpuSpec::h20(), ModelSpec::deepseek_v31());
        let tokens = (args.usize_or("prompt-max", 96) + args.usize_or("out-max", 96)) / 2;
        let transfer_s = perfmodel::e2e::host_spill_s(&gpu, &model, tokens, KernelKind::SnapMlaFp8);
        cluster.set_tier_link(transfer_s, true);
    }
    let mut rng = Rng::new(1234);
    for r in &trace {
        let prompt = synth_prompt(&mut rng, r);
        cluster.submit(ServeRequest {
            id: r.id,
            prompt,
            max_new_tokens: r.max_new_tokens,
            temperature: r.temperature,
            seed: r.id, ignore_eos: false });
        // drive the cluster while the queue fills: affinity routing probes
        // prefixes PUBLISHED by earlier requests, so routing the whole
        // trace up front would leave every trie empty and degenerate to
        // shortest-queue
        cluster.step_all()?;
    }
    if elastic {
        // drive to the failure instant, kill the rank, and let the
        // survivors pick up its re-migrated KV
        let fail_at = args.f64_or("fail-at", 10.0);
        let fi = args.usize_or("fail-rank", dp - 1);
        anyhow::ensure!(fi < dp, "--fail-rank must be < dp (dp {dp}, got {fi})");
        let costs = vec![1.0; cluster.dp()];
        cluster.run_until(&costs, fail_at)?;
        cluster.fail_rank(fi, !args.has("no-recover"))?;
    }
    let outcomes = cluster.run_to_completion()?;
    println!(
        "completed {} requests over {} rank(s) ({:?}): routed {:?}, \
         peak pages {}, prefix-hit tokens {}",
        outcomes.len(),
        cluster.dp(),
        cluster.mode,
        cluster.metrics.routed,
        cluster.metrics.peak_pages_used,
        cluster.prefix_hit_tokens()
    );
    if disagg {
        println!(
            "disagg: {} handoffs, {:.2} MB on the FP8 wire",
            cluster.handoffs(),
            cluster.handoff_wire_bytes() as f64 / 1e6
        );
    }
    if let Some(link) = cluster.tier_link() {
        println!(
            "tiered: {} host transfers overlapped with decode, {} stalled \
             ({:.3} ms each on the PCIe link)",
            link.overlapped,
            link.stalls,
            link.transfer_s * 1e3
        );
    }
    if elastic {
        let m = &cluster.metrics;
        println!(
            "elastic: {} evacuated, {} recovered over the FP8 wire, {} dropped",
            m.evacuated, m.recovered, m.dropped
        );
        for (t, kind, ri, after) in &cluster.membership_log {
            println!("  t={t:.1}s {} rank {ri} -> {after} active", kind.as_str());
        }
    }
    for (i, rank) in cluster.router.ranks.iter().enumerate() {
        println!("{}", rank.metrics.render(&format!("rank {i} ({mode:?})")));
        let s = &rank.engine.stats;
        println!(
            "engine: {} decode steps, {} verify calls, {} compiles, \
             gather {:.2}s exec {:.2}s append {:.2}s",
            s.decode_steps, s.verify_calls, s.compiles, s.gather_s, s.execute_s, s.append_s
        );
    }
    Ok(())
}

fn synth_prompt(rng: &mut Rng, r: &snapmla::workload::Request) -> Vec<i32> {
    // repeat-family prompt in the synthetic token language; requests in the
    // same shared-prefix group start with an identical group-seeded prefix
    // so the prefix trie (and affinity routing) can actually share pages
    let mut p = vec![1];
    if let Some(g) = r.prefix_group {
        let mut grng = Rng::new(0xC1A5_7E50 + g as u64);
        let mlen = grng.range_usize(2, 6);
        let motif: Vec<i32> = (0..mlen).map(|_| 64 + grng.below(256) as i32).collect();
        for i in 0..r.prefix_tokens {
            p.push(motif[i % mlen]);
        }
    }
    let mlen = rng.range_usize(2, 6);
    let motif: Vec<i32> = (0..mlen).map(|_| 64 + rng.below(256) as i32).collect();
    while p.len() < r.prompt_tokens {
        p.push(motif[(p.len() - 1) % mlen]);
    }
    p
}

fn fidelity(args: &Args) -> anyhow::Result<()> {
    let ctx = args.usize_or("ctx", 2048);
    let layers = args.usize_or("layers", 8);
    let kernel = kernel_variant(args)?;
    let shape = Shape { heads: 8, d_c: 128, d_r: 32 };
    let stimuli = build_stimuli(7, layers, ctx, &shape);
    let mut t = Table::new(
        &format!("layer-wise fidelity (ctx {ctx})"),
        &["config", "mean rel-l2", "final rel-l2", "final cosine"],
    );
    for cfg in QuantConfig::ALL {
        let r = layerwise_errors(cfg, &stimuli, &shape, 13);
        t.row(vec![
            cfg.name().to_string(),
            f4(r.mean_rel()),
            f4(r.final_rel()),
            f4(r.per_layer.last().unwrap().cosine),
        ]);
    }
    t.print();

    let mut tv = Table::new(
        &format!("kernel-variant fidelity (ctx {ctx})"),
        &["kernel", "mean rel-l2", "final rel-l2", "final cosine"],
    );
    for kind in VariantKind::ALL {
        let r = variant_errors(kind, &stimuli, &shape, 13);
        tv.row(vec![
            kind.name().to_string(),
            f4(r.mean_rel()),
            f4(r.final_rel()),
            f4(r.per_layer.last().unwrap().cosine),
        ]);
    }
    tv.print();

    let mut td = Table::new(
        &format!("per-layer rel-l2 — {} (ctx {ctx})", kernel.name()),
        &["layer", "rel-l2", "cosine"],
    );
    for le in &variant_errors(kernel, &stimuli, &shape, 13).per_layer {
        td.row(vec![le.layer.to_string(), f4(le.rel_l2), f4(le.cosine)]);
    }
    td.print();
    Ok(())
}

fn perf(args: &Args) -> anyhow::Result<()> {
    let gpu = GpuSpec::h20();
    let model = match args.get_or("model", "deepseek") {
        "longcat" => ModelSpec::longcat_flash(),
        _ => ModelSpec::deepseek_v31(),
    };
    let kernel = kernel_variant(args)?;
    let fp8_kind = kernel.kernel_kind();
    let mut t = Table::new(
        &format!("modeled decode throughput — {} ({} kernel)", model.name, kernel.name()),
        &["config", "ctx", "bf16 tok/s", "fp8 tok/s", "speedup", "b/rank bf16", "b/rank fp8"],
    );
    for topo in NodeTopology::enumerate(8) {
        for ctx in [16_384usize, 32_768, 65_536, 131_072] {
            let cfg = topo.config;
            let bf =
                perfmodel::e2e::serving_point(&gpu, &model, &cfg, ctx, KernelKind::FlashMlaBf16);
            let fp = perfmodel::e2e::serving_point(&gpu, &model, &cfg, ctx, fp8_kind);
            t.row(vec![
                cfg.label(),
                format!("{}k", ctx / 1024),
                f1(bf.tokens_per_s),
                f1(fp.tokens_per_s),
                format!("{}x", f2(fp.tokens_per_s / bf.tokens_per_s)),
                bf.batch_per_rank.to_string(),
                fp.batch_per_rank.to_string(),
            ]);
        }
    }
    t.print();
    Ok(())
}
