//! The multi-layer, multi-sequence paged KV cache.
//!
//! One *logical page* spans all model layers for 64 consecutive tokens of one
//! sequence (so the page table is shared across layers, as in vLLM). Storage
//! is per (logical page, layer): FP8 mode holds u8 E4M3 content + f32 scales
//! + bf16 aligned RoPE; BF16 mode (FlashMLA baseline) holds bf16 content +
//! bf16 RoPE.

use super::allocator::{AllocError, PageAllocator};
use super::page::{Page, PAGE_TOKENS};
use crate::fp8::{bf16_decode, bf16_encode};
use std::collections::BTreeMap;

/// Cache precision mode (SnapMLA FP8 vs FlashMLA BF16 baseline).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum CacheMode {
    Fp8,
    Bf16,
}

#[derive(Clone, Copy, Debug)]
pub struct CacheConfig {
    pub n_layers: usize,
    pub d_c: usize,
    pub d_r: usize,
    pub mode: CacheMode,
    /// pool capacity in logical pages (each backs all layers)
    pub capacity_pages: usize,
}

impl CacheConfig {
    /// Bytes of one logical page (all layers).
    pub fn page_bytes(&self) -> usize {
        let per_layer = match self.mode {
            CacheMode::Fp8 => Page::nbytes(self.d_c, self.d_r),
            CacheMode::Bf16 => PAGE_TOKENS * 2 * (self.d_c + self.d_r),
        };
        per_layer * self.n_layers
    }

    /// Bytes an f32 cache would need for the same tokens (for the memory-
    /// reduction stat the paper's batch-size gains derive from).
    pub fn page_bytes_f32(&self) -> usize {
        PAGE_TOKENS * 4 * (self.d_c + self.d_r) * self.n_layers
    }
}

/// BF16 page (baseline mode).
#[derive(Clone)]
struct Bf16Page {
    content: Vec<u16>,
    rope: Vec<u16>,
}

enum PageData {
    Fp8(Vec<Page>),      // [n_layers]
    Bf16(Vec<Bf16Page>), // [n_layers]
}

/// Sequence handle.
pub type SeqHandle = u64;

struct SeqState {
    tokens: usize,
}

/// The paged KV cache.
pub struct PagedKvCache {
    pub cfg: CacheConfig,
    alloc: PageAllocator,
    pages: Vec<Option<PageData>>, // indexed by physical page id
    seqs: BTreeMap<SeqHandle, SeqState>,
    appends: u64, // stats: token-append operations
}

impl PagedKvCache {
    pub fn new(cfg: CacheConfig) -> Self {
        let mut pages = Vec::with_capacity(cfg.capacity_pages);
        pages.resize_with(cfg.capacity_pages, || None);
        PagedKvCache {
            cfg,
            alloc: PageAllocator::new(cfg.capacity_pages),
            pages,
            seqs: BTreeMap::new(),
            appends: 0,
        }
    }

    pub fn register(&mut self, seq: SeqHandle) {
        self.alloc.register(seq);
        self.seqs.entry(seq).or_insert(SeqState { tokens: 0 });
    }

    pub fn release(&mut self, seq: SeqHandle) {
        if let Some(pages) = self.alloc.pages_of(seq).map(|p| p.to_vec()) {
            for p in pages {
                self.pages[p] = None;
            }
        }
        self.alloc.release(seq);
        self.seqs.remove(&seq);
    }

    pub fn tokens_of(&self, seq: SeqHandle) -> usize {
        self.seqs.get(&seq).map(|s| s.tokens).unwrap_or(0)
    }

    pub fn free_pages(&self) -> usize {
        self.alloc.free_pages()
    }

    pub fn used_pages(&self) -> usize {
        self.alloc.used_pages()
    }

    pub fn can_append(&self, seq: SeqHandle, extra_tokens: usize) -> bool {
        self.alloc.can_grow(seq, self.tokens_of(seq), extra_tokens)
    }

    pub fn appends(&self) -> u64 {
        self.appends
    }

    /// Real bytes held by allocated pages vs the f32 baseline.
    pub fn memory_stats(&self) -> (usize, usize) {
        let used = self.alloc.used_pages();
        (used * self.cfg.page_bytes(), used * self.cfg.page_bytes_f32())
    }

    fn new_page_data(&self) -> PageData {
        match self.cfg.mode {
            CacheMode::Fp8 => PageData::Fp8(
                (0..self.cfg.n_layers).map(|_| Page::new(self.cfg.d_c, self.cfg.d_r)).collect(),
            ),
            CacheMode::Bf16 => PageData::Bf16(
                (0..self.cfg.n_layers)
                    .map(|_| Bf16Page {
                        content: vec![0; PAGE_TOKENS * self.cfg.d_c],
                        rope: vec![0; PAGE_TOKENS * self.cfg.d_r],
                    })
                    .collect(),
            ),
        }
    }

    /// Fused-K-Append: quantize (mode-dependent) + paged write of ONE token
    /// across all layers. `c_kv` and `k_r` are [n_layers * d_c] / [n_layers *
    /// d_r] raw f32 values for this token.
    pub fn append_token(
        &mut self,
        seq: SeqHandle,
        c_kv: &[f32],
        k_r: &[f32],
    ) -> Result<(), AllocError> {
        let (d_c, d_r, layers) = (self.cfg.d_c, self.cfg.d_r, self.cfg.n_layers);
        assert_eq!(c_kv.len(), layers * d_c);
        assert_eq!(k_r.len(), layers * d_r);
        let state = self.seqs.get_mut(&seq).ok_or(AllocError::UnknownSequence)?;
        let pos = state.tokens;
        let slot = pos % PAGE_TOKENS;
        let page_idx = pos / PAGE_TOKENS;
        let table_len = self.alloc.pages_of(seq).map(|p| p.len()).unwrap_or(0);
        let phys = if page_idx >= table_len {
            let p = self.alloc.grow(seq)?;
            self.pages[p] = Some(self.new_page_data());
            p
        } else {
            self.alloc.pages_of(seq).unwrap()[page_idx]
        };
        let data = self.pages[phys].as_mut().expect("allocated page must exist");
        match data {
            PageData::Fp8(layers_pages) => {
                for (l, page) in layers_pages.iter_mut().enumerate() {
                    page.append_raw(
                        slot,
                        d_c,
                        d_r,
                        &c_kv[l * d_c..(l + 1) * d_c],
                        &k_r[l * d_r..(l + 1) * d_r],
                    );
                }
            }
            PageData::Bf16(layers_pages) => {
                for (l, page) in layers_pages.iter_mut().enumerate() {
                    for i in 0..d_c {
                        page.content[slot * d_c + i] = bf16_encode(c_kv[l * d_c + i]);
                    }
                    for i in 0..d_r {
                        page.rope[slot * d_r + i] = bf16_encode(k_r[l * d_r + i]);
                    }
                }
            }
        }
        let state = self.seqs.get_mut(&seq).unwrap();
        state.tokens = pos + 1;
        self.appends += 1;
        Ok(())
    }

    /// Append a token whose FP8 quantization was already done by the XLA
    /// graph (the decode step returns E4M3-grid values + scales): store the
    /// codes directly, bit-exact with the in-graph quantization.
    pub fn append_prequantized(
        &mut self,
        seq: SeqHandle,
        k_c_grid: &[f32], // [layers * d_c] values on the E4M3 grid
        k_r_aligned: &[f32],
        sigma: &[f32], // [layers]
    ) -> Result<(), AllocError> {
        assert_eq!(self.cfg.mode, CacheMode::Fp8);
        let (d_c, d_r, _layers) = (self.cfg.d_c, self.cfg.d_r, self.cfg.n_layers);
        let state = self.seqs.get_mut(&seq).ok_or(AllocError::UnknownSequence)?;
        let pos = state.tokens;
        let slot = pos % PAGE_TOKENS;
        let page_idx = pos / PAGE_TOKENS;
        let table_len = self.alloc.pages_of(seq).map(|p| p.len()).unwrap_or(0);
        let phys = if page_idx >= table_len {
            let p = self.alloc.grow(seq)?;
            self.pages[p] = Some(self.new_page_data());
            p
        } else {
            self.alloc.pages_of(seq).unwrap()[page_idx]
        };
        let data = self.pages[phys].as_mut().unwrap();
        if let PageData::Fp8(layers_pages) = data {
            for (l, page) in layers_pages.iter_mut().enumerate() {
                let codes: Vec<u8> = k_c_grid[l * d_c..(l + 1) * d_c]
                    .iter()
                    .map(|&x| crate::fp8::e4m3_encode(x))
                    .collect();
                page.write_token(
                    slot,
                    d_c,
                    d_r,
                    &codes,
                    &k_r_aligned[l * d_r..(l + 1) * d_r],
                    sigma[l],
                );
            }
        }
        let state = self.seqs.get_mut(&seq).unwrap();
        state.tokens = pos + 1;
        self.appends += 1;
        Ok(())
    }

    /// Gather the kernel view of one (sequence, layer) into contiguous
    /// buffers of `max_tokens` rows (padded with zeros): content values on
    /// the E4M3 grid (or bf16 values in BF16 mode), aligned rope, and
    /// per-token sigma (1.0 in BF16 mode).
    pub fn gather_kernel_view(
        &self,
        seq: SeqHandle,
        layer: usize,
        max_tokens: usize,
        content_out: &mut [f32],
        rope_out: &mut [f32],
        sigma_out: &mut [f32],
    ) {
        let (d_c, d_r) = (self.cfg.d_c, self.cfg.d_r);
        assert!(content_out.len() >= max_tokens * d_c);
        assert!(rope_out.len() >= max_tokens * d_r);
        assert!(sigma_out.len() >= max_tokens);
        content_out[..max_tokens * d_c].fill(0.0);
        rope_out[..max_tokens * d_r].fill(0.0);
        sigma_out[..max_tokens].fill(1.0);
        let tokens = self.tokens_of(seq).min(max_tokens);
        let Some(table) = self.alloc.pages_of(seq) else { return };
        for t in 0..tokens {
            let phys = table[t / PAGE_TOKENS];
            let slot = t % PAGE_TOKENS;
            match self.pages[phys].as_ref().unwrap() {
                PageData::Fp8(layers_pages) => {
                    let page = &layers_pages[layer];
                    sigma_out[t] = page.kernel_view(
                        slot,
                        d_c,
                        d_r,
                        &mut content_out[t * d_c..(t + 1) * d_c],
                        &mut rope_out[t * d_r..(t + 1) * d_r],
                    );
                }
                PageData::Bf16(layers_pages) => {
                    let page = &layers_pages[layer];
                    for i in 0..d_c {
                        content_out[t * d_c + i] = bf16_decode(page.content[slot * d_c + i]);
                    }
                    for i in 0..d_r {
                        rope_out[t * d_r + i] = bf16_decode(page.rope[slot * d_r + i]);
                    }
                    sigma_out[t] = 1.0;
                }
            }
        }
    }

    /// Fused-Fetch-Dequant of a token range into f32 (chunked prefill /
    /// prefix-cache reuse path).
    pub fn fetch_dequant_range(
        &self,
        seq: SeqHandle,
        layer: usize,
        start: usize,
        count: usize,
        content_out: &mut [f32],
        rope_out: &mut [f32],
    ) {
        let (d_c, d_r) = (self.cfg.d_c, self.cfg.d_r);
        let table = self.alloc.pages_of(seq).expect("sequence registered");
        for k in 0..count {
            let t = start + k;
            let phys = table[t / PAGE_TOKENS];
            let slot = t % PAGE_TOKENS;
            match self.pages[phys].as_ref().unwrap() {
                PageData::Fp8(layers_pages) => {
                    layers_pages[layer].fetch_dequant(
                        slot,
                        d_c,
                        d_r,
                        &mut content_out[k * d_c..(k + 1) * d_c],
                        &mut rope_out[k * d_r..(k + 1) * d_r],
                    );
                }
                PageData::Bf16(layers_pages) => {
                    let page = &layers_pages[layer];
                    for i in 0..d_c {
                        content_out[k * d_c + i] = bf16_decode(page.content[slot * d_c + i]);
                    }
                    for i in 0..d_r {
                        rope_out[k * d_r + i] = bf16_decode(page.rope[slot * d_r + i]);
                    }
                }
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    fn cfg(mode: CacheMode) -> CacheConfig {
        CacheConfig { n_layers: 2, d_c: 16, d_r: 8, mode, capacity_pages: 8 }
    }

    fn rand_token(rng: &mut Rng, cfg: &CacheConfig) -> (Vec<f32>, Vec<f32>) {
        (
            rng.normal_vec(cfg.n_layers * cfg.d_c, 2.0),
            rng.normal_vec(cfg.n_layers * cfg.d_r, 30.0),
        )
    }

    #[test]
    fn append_and_gather_fp8() {
        let c = cfg(CacheMode::Fp8);
        let mut cache = PagedKvCache::new(c);
        cache.register(1);
        let mut rng = Rng::new(1);
        let mut raw = Vec::new();
        for _ in 0..70 {
            let (ck, kr) = rand_token(&mut rng, &c);
            cache.append_token(1, &ck, &kr).unwrap();
            raw.push((ck, kr));
        }
        assert_eq!(cache.tokens_of(1), 70);
        assert_eq!(cache.used_pages(), 2); // 70 tokens → 2 pages

        let mut content = vec![0.0f32; 128 * c.d_c];
        let mut rope = vec![0.0f32; 128 * c.d_r];
        let mut sigma = vec![0.0f32; 128];
        for layer in 0..2 {
            cache.gather_kernel_view(1, layer, 128, &mut content, &mut rope, &mut sigma);
            for (t, (ck, kr)) in raw.iter().enumerate() {
                let row = &ck[layer * c.d_c..(layer + 1) * c.d_c];
                let amax = row.iter().fold(0.0f32, |m, &x| m.max(x.abs()));
                for i in 0..c.d_c {
                    let got = content[t * c.d_c + i] * sigma[t];
                    assert!((got - row[i]).abs() <= amax * 0.0625 + 1e-6);
                }
                for i in 0..c.d_r {
                    let got = rope[t * c.d_r + i] * sigma[t];
                    let want = kr[layer * c.d_r + i];
                    assert!(((got - want) / want).abs() < 0.02, "{got} {want}");
                }
            }
            // padding rows zeroed with sigma 1
            assert_eq!(content[70 * c.d_c], 0.0);
            assert_eq!(sigma[127], 1.0);
        }
    }

    #[test]
    fn bf16_mode_roundtrip() {
        let c = cfg(CacheMode::Bf16);
        let mut cache = PagedKvCache::new(c);
        cache.register(9);
        let mut rng = Rng::new(2);
        let (ck, kr) = rand_token(&mut rng, &c);
        cache.append_token(9, &ck, &kr).unwrap();
        let mut content = vec![0.0f32; 64 * c.d_c];
        let mut rope = vec![0.0f32; 64 * c.d_r];
        let mut sigma = vec![0.0f32; 64];
        cache.gather_kernel_view(9, 1, 64, &mut content, &mut rope, &mut sigma);
        for i in 0..c.d_c {
            let want = ck[c.d_c + i];
            assert!(((content[i] - want) / want).abs() < 0.01);
        }
        assert_eq!(sigma[0], 1.0);
    }

    #[test]
    fn prequantized_append_is_bit_exact() {
        let c = cfg(CacheMode::Fp8);
        let mut cache = PagedKvCache::new(c);
        cache.register(5);
        // values already on the E4M3 grid
        let grid: Vec<f32> = (0..c.n_layers * c.d_c)
            .map(|i| crate::fp8::e4m3_round((i as f32 - 16.0) * 0.25))
            .collect();
        let rope: Vec<f32> = (0..c.n_layers * c.d_r).map(|i| i as f32 * 0.5).collect();
        let sigma = vec![0.013f32, 2.5];
        cache.append_prequantized(5, &grid, &rope, &sigma).unwrap();
        let mut content = vec![0.0f32; 64 * c.d_c];
        let mut r = vec![0.0f32; 64 * c.d_r];
        let mut s = vec![0.0f32; 64];
        for layer in 0..2 {
            cache.gather_kernel_view(5, layer, 64, &mut content, &mut r, &mut s);
            assert_eq!(s[0], sigma[layer]);
            for i in 0..c.d_c {
                assert_eq!(content[i], grid[layer * c.d_c + i], "layer {layer} i {i}");
            }
        }
    }

    #[test]
    fn release_frees_pages_and_data() {
        let c = cfg(CacheMode::Fp8);
        let mut cache = PagedKvCache::new(c);
        cache.register(1);
        let mut rng = Rng::new(3);
        for _ in 0..65 {
            let (ck, kr) = rand_token(&mut rng, &c);
            cache.append_token(1, &ck, &kr).unwrap();
        }
        assert_eq!(cache.used_pages(), 2);
        cache.release(1);
        assert_eq!(cache.used_pages(), 0);
        assert_eq!(cache.tokens_of(1), 0);
    }

    #[test]
    fn capacity_exhaustion_and_can_append() {
        let mut c = cfg(CacheMode::Fp8);
        c.capacity_pages = 1;
        let mut cache = PagedKvCache::new(c);
        cache.register(1);
        let mut rng = Rng::new(4);
        for _ in 0..64 {
            let (ck, kr) = rand_token(&mut rng, &c);
            cache.append_token(1, &ck, &kr).unwrap();
        }
        assert!(!cache.can_append(1, 1));
        let (ck, kr) = rand_token(&mut rng, &c);
        assert!(cache.append_token(1, &ck, &kr).is_err());
    }

    #[test]
    fn memory_stats_show_reduction() {
        let c = cfg(CacheMode::Fp8);
        let mut cache = PagedKvCache::new(c);
        cache.register(1);
        let mut rng = Rng::new(5);
        let (ck, kr) = rand_token(&mut rng, &c);
        cache.append_token(1, &ck, &kr).unwrap();
        let (used, f32_equiv) = cache.memory_stats();
        assert!(used * 2 < f32_equiv, "{used} vs {f32_equiv}");
    }

    #[test]
    fn interleaved_sequences_stay_isolated() {
        let c = cfg(CacheMode::Fp8);
        let mut cache = PagedKvCache::new(c);
        cache.register(1);
        cache.register(2);
        let mut rng = Rng::new(6);
        let (ck1, kr1) = rand_token(&mut rng, &c);
        let (ck2, kr2) = rand_token(&mut rng, &c);
        cache.append_token(1, &ck1, &kr1).unwrap();
        cache.append_token(2, &ck2, &kr2).unwrap();
        cache.append_token(1, &ck1, &kr1).unwrap();
        assert_eq!(cache.tokens_of(1), 2);
        assert_eq!(cache.tokens_of(2), 1);
        let mut c1 = vec![0.0f32; 64 * c.d_c];
        let mut c2 = vec![0.0f32; 64 * c.d_c];
        let mut r = vec![0.0f32; 64 * c.d_r];
        let mut s = vec![0.0f32; 64];
        cache.gather_kernel_view(1, 0, 64, &mut c1, &mut r, &mut s);
        cache.gather_kernel_view(2, 0, 64, &mut c2, &mut r, &mut s);
        // token 0 of each sequence must reflect its own data
        assert_ne!(&c1[..c.d_c], &c2[..c.d_c]);
        // seq 1 token 1 equals token 0 (same input appended twice)
        assert_eq!(&c1[..c.d_c], &c1[c.d_c..2 * c.d_c]);
    }
}
