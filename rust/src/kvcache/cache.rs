//! The multi-layer, multi-sequence paged KV cache.
//!
//! One *logical page* spans all model layers for 64 consecutive tokens of one
//! sequence (so the page table is shared across layers, as in vLLM). Storage
//! is per (logical page, layer): FP8 mode holds u8 E4M3 content + f32 scales
//! + bf16 aligned RoPE; BF16 mode (FlashMLA baseline) holds bf16 content +
//! bf16 RoPE.
//!
//! Serving-grade lifecycle on top of the storage:
//! * **prefix sharing** — full prompt-prefix pages are published to a
//!   [`PrefixTrie`]; later sequences with the same prefix `adopt` the same
//!   physical pages (refcounted, copy-on-write on divergence inside a
//!   shared page). Trie-retained pages are evicted LRU under page pressure.
//! * **page-spill preemption** — `spill` clones a sequence's pages to host
//!   memory and frees them; `restore` maps them back bit-exactly, so a
//!   preempted-then-resumed sequence replays nothing and emits the same
//!   tokens as an uninterrupted run (recompute-preemption would re-prefill
//!   through the full-precision prefill path and diverge from the FP8
//!   decode path).

use super::allocator::{AllocError, PageAllocator};
use super::compress::ColdPage;
use super::page::{Page, PAGE_TOKENS};
use super::prefix::PrefixTrie;
use super::tiered::TierState;
use super::transfer::{KvWireBlock, WirePayload};
use crate::fp8::{bf16_decode, bf16_encode, e4m3_encode};
use std::collections::BTreeMap;

/// Cache precision mode (SnapMLA FP8 vs FlashMLA BF16 baseline).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum CacheMode {
    Fp8,
    Bf16,
}

#[derive(Clone, Copy, Debug)]
pub struct CacheConfig {
    pub n_layers: usize,
    pub d_c: usize,
    pub d_r: usize,
    pub mode: CacheMode,
    /// pool capacity in logical pages (each backs all layers)
    pub capacity_pages: usize,
}

impl CacheConfig {
    /// Bytes of one logical page (all layers).
    pub fn page_bytes(&self) -> usize {
        let per_layer = match self.mode {
            CacheMode::Fp8 => Page::nbytes(self.d_c, self.d_r),
            CacheMode::Bf16 => PAGE_TOKENS * 2 * (self.d_c + self.d_r),
        };
        per_layer * self.n_layers
    }

    /// Bytes an f32 cache would need for the same tokens (for the memory-
    /// reduction stat the paper's batch-size gains derive from).
    pub fn page_bytes_f32(&self) -> usize {
        PAGE_TOKENS * 4 * (self.d_c + self.d_r) * self.n_layers
    }
}

/// BF16 page (baseline mode).
#[derive(Clone)]
struct Bf16Page {
    content: Vec<u16>,
    rope: Vec<u16>,
}

#[derive(Clone)]
enum PageData {
    Fp8(Vec<Page>),      // [n_layers]
    Bf16(Vec<Bf16Page>), // [n_layers]
    /// rank-reduced cold format (tiered compression, FP8 mode only) —
    /// the page table is a heterogeneous heap: any physical slot can hold
    /// either format and readers dispatch per access
    Cold(Vec<ColdPage>), // [n_layers]
}

/// Sequence handle.
pub type SeqHandle = u64;

struct SeqState {
    tokens: usize,
}

/// A sequence-length snapshot taken before speculative draft tokens are
/// appended; [`PagedKvCache::rollback_to`] rewinds to it plus the accepted
/// prefix.
#[derive(Clone, Copy, Debug)]
pub struct KvCheckpoint {
    seq: SeqHandle,
    tokens: usize,
    pages: usize,
}

impl KvCheckpoint {
    /// The sequence this checkpoint belongs to.
    pub fn seq(&self) -> SeqHandle {
        self.seq
    }

    /// Cache tokens at checkpoint time.
    pub fn tokens(&self) -> usize {
        self.tokens
    }
}

/// A preempted sequence's KV pages, spilled to host memory. Opaque: only
/// the cache that produced it can map it back.
pub struct SpilledKv {
    tokens: usize,
    pages: Vec<PageData>,
}

impl SpilledKv {
    /// Cache tokens this spill snapshot holds.
    pub fn tokens(&self) -> usize {
        self.tokens
    }

    /// Pages the restore will need.
    pub fn pages(&self) -> usize {
        self.pages.len()
    }
}

/// The paged KV cache.
pub struct PagedKvCache {
    pub cfg: CacheConfig,
    alloc: PageAllocator,
    pages: Vec<Option<PageData>>, // indexed by physical page id
    /// residency state per physical page (tiered spill/prefetch lifecycle)
    tier: Vec<TierState>,
    seqs: BTreeMap<SeqHandle, SeqState>,
    trie: PrefixTrie,
    appends: u64, // stats: token-append operations
    cow_copies: u64,
    cold_promotions: u64,
}

impl PagedKvCache {
    pub fn new(cfg: CacheConfig) -> Self {
        let mut pages = Vec::with_capacity(cfg.capacity_pages);
        pages.resize_with(cfg.capacity_pages, || None);
        PagedKvCache {
            cfg,
            alloc: PageAllocator::new(cfg.capacity_pages),
            pages,
            tier: vec![TierState::Hbm; cfg.capacity_pages],
            seqs: BTreeMap::new(),
            trie: PrefixTrie::new(),
            appends: 0,
            cow_copies: 0,
            cold_promotions: 0,
        }
    }

    pub fn register(&mut self, seq: SeqHandle) {
        self.alloc.register(seq);
        self.seqs.entry(seq).or_insert(SeqState { tokens: 0 });
    }

    pub fn release(&mut self, seq: SeqHandle) {
        for p in self.alloc.release(seq) {
            self.pages[p] = None;
        }
        self.seqs.remove(&seq);
    }

    pub fn tokens_of(&self, seq: SeqHandle) -> usize {
        self.seqs.get(&seq).map(|s| s.tokens).unwrap_or(0)
    }

    pub fn free_pages(&self) -> usize {
        self.alloc.free_pages()
    }

    pub fn used_pages(&self) -> usize {
        self.alloc.used_pages()
    }

    /// Trie-retained pages no live sequence references — reclaimable on
    /// demand by LRU eviction. The DP router reads this as a rank's
    /// spill-free headroom beyond the free list. O(1): the allocator
    /// maintains the count at every rc transition of a tracked page; debug
    /// builds re-derive the trie sweep and pin the two equal.
    pub fn evictable_pages(&self) -> usize {
        let fast = self.alloc.tracked_evictable();
        #[cfg(debug_assertions)]
        {
            let mut sweep = 0usize;
            self.trie.for_each_page(|p| {
                if self.alloc.ref_count(p) == 1 {
                    sweep += 1;
                }
            });
            debug_assert_eq!(
                fast, sweep,
                "incremental evictable counter drifted from the trie sweep"
            );
        }
        fast
    }

    /// Pages obtainable without touching live sequences: the free list plus
    /// trie-retained pages no sequence references (evictable on demand).
    /// This is the scheduler's admission/backpressure signal — prefix-cache
    /// retention must not masquerade as pressure.
    pub fn available_pages(&self) -> usize {
        self.alloc.free_pages() + self.evictable_pages()
    }

    /// Prompt tokens a new sequence could adopt from the prefix cache right
    /// now (full published pages of the longest matching prefix, always
    /// leaving ≥1 token to prefill — the same limit `adopt_prefix` applies).
    /// Read-only: routing probes must not refresh trie recency.
    pub fn prefix_match_tokens(&self, prompt: &[i32]) -> usize {
        let limit = prompt.len().saturating_sub(1);
        self.trie.peek_match_pages(prompt, limit) * PAGE_TOKENS
    }

    /// Pages currently retained by the prefix cache.
    pub fn retained_pages(&self) -> usize {
        self.trie.retained_pages()
    }

    /// Copy-on-write page copies performed (divergence inside shared pages).
    pub fn cow_copies(&self) -> u64 {
        self.cow_copies
    }

    /// Drop the whole prefix cache (releases every trie retention ref).
    pub fn drop_prefix_cache(&mut self) {
        while self.evict_one() {}
    }

    pub fn can_append(&self, seq: SeqHandle, extra_tokens: usize) -> bool {
        self.alloc.can_grow(seq, self.tokens_of(seq), extra_tokens)
    }

    pub fn appends(&self) -> u64 {
        self.appends
    }

    /// Real bytes held by allocated pages vs the f32 baseline.
    pub fn memory_stats(&self) -> (usize, usize) {
        let used = self.alloc.used_pages();
        (used * self.cfg.page_bytes(), used * self.cfg.page_bytes_f32())
    }

    /// Structural consistency check (property suite): refcounts match the
    /// sequence maps + trie retention, the free list is exact, and storage
    /// exists iff a page is live.
    pub fn validate(&self) -> Result<(), String> {
        self.alloc.validate(&self.trie.pages())?;
        for p in 0..self.cfg.capacity_pages {
            let live = self.alloc.ref_count(p) > 0;
            if live != self.pages[p].is_some() {
                let stored = self.pages[p].is_some();
                return Err(format!("page {p}: live {live} but storage {stored}"));
            }
            // tier invariants: a live page is never marked host-resident, and
            // a free slot never claims an in-flight transfer
            match self.tier[p] {
                TierState::Host if live => {
                    return Err(format!("page {p}: live but tiered Host"));
                }
                TierState::SpillInFlight | TierState::PrefetchInFlight if !live => {
                    return Err(format!("page {p}: free but in a tier flight"));
                }
                _ => {}
            }
        }
        Ok(())
    }

    /// Raw storage bytes of `seq`'s pages in table order — content codes,
    /// rope, scales, and used counts across every layer, including slots
    /// past the live token count. The property suite compares this after a
    /// speculative rollback against a run that never drafted: they must be
    /// identical down to the erased bytes.
    pub fn raw_seq_bytes(&self, seq: SeqHandle) -> Vec<u8> {
        let mut out = Vec::new();
        let Some(table) = self.alloc.pages_of(seq) else { return out };
        for &phys in table {
            match self.pages[phys].as_ref().expect("mapped page") {
                PageData::Fp8(layers_pages) => {
                    for page in layers_pages {
                        out.extend_from_slice(&page.content);
                        for &r in &page.rope {
                            out.extend_from_slice(&r.to_le_bytes());
                        }
                        for &s in &page.scales {
                            out.extend_from_slice(&s.to_le_bytes());
                        }
                        out.extend_from_slice(&(page.used as u64).to_le_bytes());
                    }
                }
                PageData::Bf16(layers_pages) => {
                    for page in layers_pages {
                        for &x in &page.content {
                            out.extend_from_slice(&x.to_le_bytes());
                        }
                        for &r in &page.rope {
                            out.extend_from_slice(&r.to_le_bytes());
                        }
                    }
                }
                PageData::Cold(layers_pages) => {
                    for cp in layers_pages {
                        out.extend_from_slice(&(cp.rank as u64).to_le_bytes());
                        for &b in &cp.basis {
                            out.extend_from_slice(&b.to_le_bytes());
                        }
                        out.extend_from_slice(&cp.codes);
                        for &s in &cp.scales {
                            out.extend_from_slice(&s.to_le_bytes());
                        }
                        for &r in &cp.rope {
                            out.extend_from_slice(&r.to_le_bytes());
                        }
                        for &s in &cp.src_scales {
                            out.extend_from_slice(&s.to_le_bytes());
                        }
                        out.extend_from_slice(&(cp.used as u64).to_le_bytes());
                    }
                }
            }
        }
        out
    }

    // --- prefix sharing ----------------------------------------------------

    /// Map the longest published full-page prefix of `prompt` into `seq`'s
    /// (empty) page table; returns the adopted token count. At least one
    /// prompt token is always left to prefill so the sequence gets its
    /// first-token logits from a real model step.
    pub fn adopt_prefix(&mut self, seq: SeqHandle, prompt: &[i32]) -> usize {
        let Some(state) = self.seqs.get(&seq) else { return 0 };
        if state.tokens > 0 {
            return 0;
        }
        let limit = prompt.len().saturating_sub(1);
        let pages = self.trie.lookup(prompt, limit);
        if pages.is_empty() {
            return 0;
        }
        for &p in &pages {
            self.alloc.share(seq, p).expect("trie-retained page is live");
        }
        let tokens = pages.len() * PAGE_TOKENS;
        self.seqs.get_mut(&seq).unwrap().tokens = tokens;
        tokens
    }

    /// Publish the full pages of `prompt_prefix` (tokens already appended by
    /// `seq`) to the prefix trie; the trie takes a retention reference on
    /// each newly-inserted page. Idempotent per page.
    pub fn publish_prefix(&mut self, seq: SeqHandle, prompt_prefix: &[i32]) {
        let full = prompt_prefix.len() / PAGE_TOKENS;
        if full == 0 {
            return;
        }
        debug_assert!(self.tokens_of(seq) >= full * PAGE_TOKENS);
        let Some(table) = self.alloc.pages_of(seq) else { return };
        if table.len() < full {
            return;
        }
        let pages: Vec<usize> = table[..full].to_vec();
        for p in self.trie.insert(prompt_prefix, &pages) {
            self.alloc.retain(p).expect("sequence page is live");
            self.alloc.track(p);
        }
    }

    // --- checkpoint / rollback (speculative decoding) ----------------------

    /// Snapshot `seq`'s length before speculative draft tokens are
    /// appended. O(1): only the token count and page-table length are
    /// recorded — the bytes beyond them are garbage after `rollback_to`
    /// erases them, so nothing needs copying.
    pub fn checkpoint(&self, seq: SeqHandle) -> Result<KvCheckpoint, AllocError> {
        let tokens = self.seqs.get(&seq).ok_or(AllocError::UnknownSequence)?.tokens;
        let pages = self.alloc.pages_of(seq).ok_or(AllocError::UnknownSequence)?.len();
        Ok(KvCheckpoint { seq, tokens, pages })
    }

    /// Rewind `seq` to `ckpt.tokens() + keep` tokens, erasing every draft
    /// token appended past the kept prefix: whole pages beyond the target
    /// return to the free list in exact reverse allocation order, and the
    /// reclaimed slots of the surviving partial page are zeroed — the cache
    /// (bytes, refcounts, free list) is indistinguishable from a run that
    /// only ever appended the kept tokens.
    ///
    /// Pages touched past the checkpoint are always private (`rc == 1`):
    /// prefix sharing is full-page-only and the append path copies-on-write
    /// before writing into a shared page, so erasure cannot reach another
    /// sequence's bytes.
    pub fn rollback_to(&mut self, ckpt: &KvCheckpoint, keep: usize) -> Result<(), AllocError> {
        let seq = ckpt.seq;
        let cur = self.seqs.get(&seq).ok_or(AllocError::UnknownSequence)?.tokens;
        let target = ckpt.tokens + keep;
        assert!(target <= cur, "rollback target {target} beyond live length {cur}");
        let keep_pages = PageAllocator::pages_for(target).max(ckpt.pages);
        for p in self.alloc.truncate(seq, keep_pages)? {
            self.pages[p] = None;
        }
        // erase rejected drafts inside the surviving last page
        let erase_until = cur.min(keep_pages * PAGE_TOKENS);
        if target < erase_until {
            let lp = keep_pages - 1;
            let phys = self.alloc.pages_of(seq).expect("live sequence")[lp];
            debug_assert_eq!(self.alloc.ref_count(phys), 1, "draft pages are private");
            // a deep rollback can rewind the tail into a page the cold sweep
            // compressed since the checkpoint; erasure is a write access
            self.promote_if_cold(phys);
            let (d_c, d_r) = (self.cfg.d_c, self.cfg.d_r);
            match self.pages[phys].as_mut().expect("allocated page") {
                PageData::Fp8(layers_pages) => {
                    for page in layers_pages {
                        for t in target..erase_until {
                            page.clear_token(t % PAGE_TOKENS, d_c, d_r);
                        }
                        page.used = target - lp * PAGE_TOKENS;
                    }
                }
                PageData::Bf16(layers_pages) => {
                    for page in layers_pages {
                        for t in target..erase_until {
                            let slot = t % PAGE_TOKENS;
                            page.content[slot * d_c..(slot + 1) * d_c].fill(0);
                            page.rope[slot * d_r..(slot + 1) * d_r].fill(0);
                        }
                    }
                }
                PageData::Cold(_) => unreachable!("promoted to the hot format above"),
            }
        }
        self.seqs.get_mut(&seq).unwrap().tokens = target;
        Ok(())
    }

    // --- spill / restore (page-spill preemption) ---------------------------

    /// Spill `seq`'s pages to host memory and free them in the pool. The
    /// snapshot is bit-exact: `restore` brings back the same KV bytes.
    ///
    /// Adopted shared-prefix pages are cloned into the snapshot too and
    /// become private copies on restore — exactness over dedup. (Re-adopting
    /// from the trie on restore would reclaim the sharing but needs an
    /// eviction-safe validity check; candidate for a future PR.)
    pub fn spill(&mut self, seq: SeqHandle) -> Result<SpilledKv, AllocError> {
        let tokens = self.seqs.get(&seq).ok_or(AllocError::UnknownSequence)?.tokens;
        let table = self.alloc.pages_of(seq).ok_or(AllocError::UnknownSequence)?.to_vec();
        let pages: Vec<PageData> =
            table.iter().map(|&p| self.pages[p].clone().expect("allocated page")).collect();
        self.release(seq);
        Ok(SpilledKv { tokens, pages })
    }

    /// Map a spilled snapshot back into the pool under `seq` (which must not
    /// be live). Evicts prefix-cache pages as needed; fails with
    /// `OutOfPages` — before touching anything, including the prefix
    /// cache — when even full eviction could not free enough pages.
    pub fn restore(&mut self, seq: SeqHandle, sp: SpilledKv) -> Result<(), AllocError> {
        assert!(!self.seqs.contains_key(&seq), "restore over a live sequence");
        if self.available_pages() < sp.pages.len() {
            return Err(AllocError::OutOfPages);
        }
        while self.alloc.free_pages() < sp.pages.len() {
            if !self.evict_one() {
                return Err(AllocError::OutOfPages);
            }
        }
        self.register(seq);
        for data in sp.pages {
            let p = self.alloc.grow(seq).expect("reserved above");
            self.pages[p] = Some(data);
            self.tier[p] = TierState::Hbm;
        }
        self.seqs.get_mut(&seq).unwrap().tokens = sp.tokens;
        Ok(())
    }

    // --- tiered residency (async spill/prefetch + cold compression) --------

    /// Residency state of physical page `p`.
    pub fn tier_of(&self, p: usize) -> TierState {
        self.tier[p]
    }

    /// Mark every page of `seq` as `SpillInFlight`: the bytes stay in HBM
    /// (reads remain valid) but the pages must NOT be treated as
    /// reclaimable until [`Self::finish_spill`] lands the transfer. Returns
    /// the page count riding the flight.
    pub fn begin_spill(&mut self, seq: SeqHandle) -> Result<usize, AllocError> {
        let table = self.alloc.pages_of(seq).ok_or(AllocError::UnknownSequence)?.to_vec();
        for &p in &table {
            debug_assert_eq!(
                self.tier[p],
                TierState::Hbm,
                "page {p} is already in a tier transition"
            );
            self.tier[p] = TierState::SpillInFlight;
        }
        Ok(table.len())
    }

    /// Land an async spill: clone the page bytes into a host snapshot
    /// (bit-exact, like [`Self::spill`]) and free the HBM pages. Freed
    /// slots keep a `Host` tombstone; pages still shared with other
    /// sequences (adopted prefixes) return to `Hbm`.
    pub fn finish_spill(&mut self, seq: SeqHandle) -> Result<SpilledKv, AllocError> {
        let tokens = self.seqs.get(&seq).ok_or(AllocError::UnknownSequence)?.tokens;
        let table = self.alloc.pages_of(seq).ok_or(AllocError::UnknownSequence)?.to_vec();
        let pages: Vec<PageData> = table
            .iter()
            .map(|&p| {
                debug_assert_eq!(
                    self.tier[p],
                    TierState::SpillInFlight,
                    "finish_spill without begin_spill on page {p}"
                );
                self.pages[p].clone().expect("allocated page")
            })
            .collect();
        for p in self.alloc.release(seq) {
            self.pages[p] = None;
            self.tier[p] = TierState::Host;
        }
        for &p in &table {
            if self.alloc.ref_count(p) > 0 {
                self.tier[p] = TierState::Hbm;
            }
        }
        self.seqs.remove(&seq);
        Ok(SpilledKv { tokens, pages })
    }

    /// Start an async prefetch: claim HBM pages for the snapshot NOW (the
    /// capacity is committed at issue, evicting prefix retention like
    /// [`Self::restore`]) and write the bytes in as `PrefetchInFlight` —
    /// unreadable until [`Self::finish_prefetch`] lands the transfer.
    pub fn begin_prefetch(&mut self, seq: SeqHandle, sp: SpilledKv) -> Result<(), AllocError> {
        assert!(!self.seqs.contains_key(&seq), "prefetch over a live sequence");
        if self.available_pages() < sp.pages.len() {
            return Err(AllocError::OutOfPages);
        }
        while self.alloc.free_pages() < sp.pages.len() {
            if !self.evict_one() {
                return Err(AllocError::OutOfPages);
            }
        }
        self.register(seq);
        for data in sp.pages {
            let p = self.alloc.grow(seq).expect("reserved above");
            self.pages[p] = Some(data);
            self.tier[p] = TierState::PrefetchInFlight;
        }
        self.seqs.get_mut(&seq).unwrap().tokens = sp.tokens;
        Ok(())
    }

    /// Land an async prefetch: the sequence's pages become readable HBM
    /// residents. Returns the page count that landed.
    pub fn finish_prefetch(&mut self, seq: SeqHandle) -> Result<usize, AllocError> {
        let table = self.alloc.pages_of(seq).ok_or(AllocError::UnknownSequence)?.to_vec();
        for &p in &table {
            debug_assert_eq!(
                self.tier[p],
                TierState::PrefetchInFlight,
                "finish_prefetch without begin_prefetch on page {p}"
            );
            self.tier[p] = TierState::Hbm;
        }
        Ok(table.len())
    }

    /// Re-encode `seq`'s pages behind the hot window into the rank-`rank`
    /// cold format: every full private page whose last token is more than
    /// `cold_after_tokens` behind the tail, excluding the tail page itself
    /// (append and rollback always meet hot bytes). Shared pages, pages in
    /// a tier transition, and BF16-mode caches are left alone. Returns the
    /// pages compressed by this sweep.
    pub fn compress_cold(
        &mut self,
        seq: SeqHandle,
        cold_after_tokens: usize,
        rank: usize,
    ) -> Result<usize, AllocError> {
        if self.cfg.mode != CacheMode::Fp8 {
            return Ok(0);
        }
        let tokens = self.seqs.get(&seq).ok_or(AllocError::UnknownSequence)?.tokens;
        let table = self.alloc.pages_of(seq).ok_or(AllocError::UnknownSequence)?.to_vec();
        let cold_pages = tokens.saturating_sub(cold_after_tokens) / PAGE_TOKENS;
        let limit = cold_pages.min(table.len().saturating_sub(1));
        let (d_c, d_r) = (self.cfg.d_c, self.cfg.d_r);
        let mut done = 0usize;
        for &phys in table.iter().take(limit) {
            if self.alloc.ref_count(phys) != 1 || self.tier[phys] != TierState::Hbm {
                continue;
            }
            if let Some(PageData::Fp8(layers)) = self.pages[phys].as_ref() {
                let cold: Vec<ColdPage> = layers
                    .iter()
                    .map(|p| ColdPage::encode(p, d_c, d_r, rank, phys as u64))
                    .collect();
                self.pages[phys] = Some(PageData::Cold(cold));
                done += 1;
            }
        }
        Ok(done)
    }

    /// Cold (rank-reduced) pages currently resident.
    pub fn cold_pages(&self) -> usize {
        self.pages.iter().flatten().filter(|d| matches!(d, PageData::Cold(_))).count()
    }

    /// Cold pages promoted back to the hot format by a write access.
    pub fn cold_promotions(&self) -> u64 {
        self.cold_promotions
    }

    /// Decompress a cold page back to the hot FP8 format in place (write
    /// access promotes). Reconstruction re-quantizes under the page's
    /// ORIGINAL per-token sigmas so the kernel view stays in the same
    /// scale domain; RoPE returns verbatim.
    fn promote_if_cold(&mut self, phys: usize) {
        let (d_c, d_r) = (self.cfg.d_c, self.cfg.d_r);
        let Some(PageData::Cold(layers)) = self.pages[phys].as_ref() else { return };
        let mut hot: Vec<Page> = Vec::with_capacity(layers.len());
        let mut rec = vec![0.0f32; d_c];
        for cp in layers {
            let mut page = Page::new(d_c, d_r);
            for t in 0..cp.used {
                cp.decode_token(t, d_c, &mut rec);
                let s = if cp.src_scales[t] != 0.0 { cp.src_scales[t] } else { 1.0 };
                for (o, &x) in page.content[t * d_c..(t + 1) * d_c].iter_mut().zip(&rec) {
                    *o = e4m3_encode(x / s);
                }
                page.scales[t] = s;
            }
            page.rope.copy_from_slice(&cp.rope);
            page.used = cp.used;
            hot.push(page);
        }
        self.pages[phys] = Some(PageData::Fp8(hot));
        self.cold_promotions += 1;
    }

    // --- wire transfer (prefill→decode KV migration) -----------------------

    /// Serialize `seq`'s KV state into the page-table-free wire format
    /// (`kvcache::transfer::KvWireBlock`): token-major u8 E4M3 codes + f32
    /// scales + bf16 RoPE in FP8 mode, bf16 content + RoPE in BF16 mode.
    /// Reads through shared (adopted-prefix) pages like any gather; the
    /// source sequence is left untouched.
    pub fn export_wire(&self, seq: SeqHandle) -> Result<KvWireBlock, AllocError> {
        let tokens = self.seqs.get(&seq).ok_or(AllocError::UnknownSequence)?.tokens;
        let table = self.alloc.pages_of(seq).ok_or(AllocError::UnknownSequence)?;
        let (d_c, d_r, layers) = (self.cfg.d_c, self.cfg.d_r, self.cfg.n_layers);
        let mut rope = Vec::with_capacity(tokens * layers * d_r);
        let mut payload = match self.cfg.mode {
            CacheMode::Fp8 => WirePayload::Fp8 {
                codes: Vec::with_capacity(tokens * layers * d_c),
                scales: Vec::with_capacity(tokens * layers),
            },
            CacheMode::Bf16 => {
                WirePayload::Bf16 { content: Vec::with_capacity(tokens * layers * d_c) }
            }
        };
        let mut rec = vec![0.0f32; d_c];
        for t in 0..tokens {
            let phys = table[t / PAGE_TOKENS];
            let slot = t % PAGE_TOKENS;
            match (self.pages[phys].as_ref().expect("mapped page"), &mut payload) {
                (PageData::Fp8(pages), WirePayload::Fp8 { codes, scales }) => {
                    for page in pages {
                        codes.extend_from_slice(&page.content[slot * d_c..(slot + 1) * d_c]);
                        scales.push(page.scales[slot]);
                        rope.extend_from_slice(&page.rope[slot * d_r..(slot + 1) * d_r]);
                    }
                }
                (PageData::Bf16(pages), WirePayload::Bf16 { content }) => {
                    for page in pages {
                        content.extend_from_slice(&page.content[slot * d_c..(slot + 1) * d_c]);
                        rope.extend_from_slice(&page.rope[slot * d_r..(slot + 1) * d_r]);
                    }
                }
                // cold pages decompress on access: reconstruct the full-domain
                // latent and re-quantize under the ORIGINAL per-token sigma so
                // the importer stays in the same scale domain
                (PageData::Cold(pages), WirePayload::Fp8 { codes, scales }) => {
                    for cp in pages {
                        cp.decode_token(slot, d_c, &mut rec);
                        let s = if cp.src_scales[slot] > 0.0 { cp.src_scales[slot] } else { 1.0 };
                        codes.extend(rec.iter().map(|&x| e4m3_encode(x / s)));
                        scales.push(s);
                        rope.extend_from_slice(&cp.rope[slot * d_r..(slot + 1) * d_r]);
                    }
                }
                _ => unreachable!("page data always matches the cache mode"),
            }
        }
        Ok(KvWireBlock { tokens, n_layers: layers, d_c, d_r, payload, rope })
    }

    /// Map a wire block into this pool under `seq` (which must not be
    /// live): allocates fresh pages (evicting prefix-cache retention under
    /// pressure, like `restore`) and writes the wire bytes back verbatim —
    /// the imported kernel views are bit-identical to the exporter's.
    pub fn import_wire(&mut self, seq: SeqHandle, block: &KvWireBlock) -> Result<(), AllocError> {
        assert!(!self.seqs.contains_key(&seq), "import over a live sequence");
        assert_eq!(block.mode(), self.cfg.mode, "wire/cache mode mismatch");
        assert_eq!(block.n_layers, self.cfg.n_layers, "wire/cache layer mismatch");
        assert_eq!((block.d_c, block.d_r), (self.cfg.d_c, self.cfg.d_r), "wire/cache dims");
        let need = block.tokens.div_ceil(PAGE_TOKENS);
        if self.available_pages() < need {
            return Err(AllocError::OutOfPages);
        }
        while self.alloc.free_pages() < need {
            if !self.evict_one() {
                return Err(AllocError::OutOfPages);
            }
        }
        self.register(seq);
        let (d_c, d_r, layers) = (self.cfg.d_c, self.cfg.d_r, self.cfg.n_layers);
        for t in 0..block.tokens {
            let slot = t % PAGE_TOKENS;
            let phys = if slot == 0 {
                let p = self.alloc.grow(seq).expect("reserved above");
                self.pages[p] = Some(self.new_page_data());
                p
            } else {
                *self.alloc.pages_of(seq).unwrap().last().unwrap()
            };
            let data = self.pages[phys].as_mut().unwrap();
            match (data, &block.payload) {
                (PageData::Fp8(pages), WirePayload::Fp8 { codes, scales }) => {
                    for (l, page) in pages.iter_mut().enumerate() {
                        let row = (t * layers + l) * d_c;
                        page.content[slot * d_c..(slot + 1) * d_c]
                            .copy_from_slice(&codes[row..row + d_c]);
                        let rrow = (t * layers + l) * d_r;
                        page.rope[slot * d_r..(slot + 1) * d_r]
                            .copy_from_slice(&block.rope[rrow..rrow + d_r]);
                        page.scales[slot] = scales[t * layers + l];
                        page.used = page.used.max(slot + 1);
                    }
                }
                (PageData::Bf16(pages), WirePayload::Bf16 { content }) => {
                    for (l, page) in pages.iter_mut().enumerate() {
                        let row = (t * layers + l) * d_c;
                        page.content[slot * d_c..(slot + 1) * d_c]
                            .copy_from_slice(&content[row..row + d_c]);
                        let rrow = (t * layers + l) * d_r;
                        page.rope[slot * d_r..(slot + 1) * d_r]
                            .copy_from_slice(&block.rope[rrow..rrow + d_r]);
                    }
                }
                _ => unreachable!("mode asserted above"),
            }
        }
        self.seqs.get_mut(&seq).unwrap().tokens = block.tokens;
        Ok(())
    }

    // --- allocation internals ---------------------------------------------

    /// Evict one prefix-trie page (LRU leaf, preferring pages whose only
    /// remaining reference is the trie's — evicting a page a live sequence
    /// still shares frees nothing). Returns false when the trie is empty.
    fn evict_one(&mut self) -> bool {
        let alloc = &self.alloc;
        match self.trie.evict_lru_preferring(|p| alloc.ref_count(p) == 1) {
            Some(page) => {
                self.alloc.untrack(page);
                if self.alloc.release_page(page).expect("trie page is live") {
                    self.pages[page] = None;
                }
                true
            }
            None => false,
        }
    }

    fn grow_page(&mut self, seq: SeqHandle) -> Result<usize, AllocError> {
        loop {
            match self.alloc.grow(seq) {
                Err(AllocError::OutOfPages) => {
                    if !self.evict_one() {
                        return Err(AllocError::OutOfPages);
                    }
                }
                r => {
                    // a reused slot may carry a Host tombstone from the
                    // tiered lifecycle; allocation re-arms residency
                    if let Ok(p) = r {
                        self.tier[p] = TierState::Hbm;
                    }
                    return r;
                }
            }
        }
    }

    fn alloc_slot(&mut self) -> Result<usize, AllocError> {
        loop {
            match self.alloc.alloc_unmapped() {
                Err(AllocError::OutOfPages) => {
                    if !self.evict_one() {
                        return Err(AllocError::OutOfPages);
                    }
                }
                r => {
                    if let Ok(p) = r {
                        self.tier[p] = TierState::Hbm;
                    }
                    return r;
                }
            }
        }
    }

    /// Physical page `seq` may write its `page_idx`-th page into: grows the
    /// table when past the end, and copies-on-write when the slot is shared
    /// (divergence inside a shared page).
    fn writable_page(&mut self, seq: SeqHandle, page_idx: usize) -> Result<usize, AllocError> {
        let table_len = self.alloc.pages_of(seq).map(|p| p.len()).unwrap_or(0);
        if page_idx >= table_len {
            debug_assert_eq!(page_idx, table_len, "pages are appended in order");
            let p = self.grow_page(seq)?;
            self.pages[p] = Some(self.new_page_data());
            return Ok(p);
        }
        let phys = self.alloc.pages_of(seq).unwrap()[page_idx];
        if self.alloc.ref_count(phys) <= 1 {
            // write access decompresses a cold page back to the hot format
            self.promote_if_cold(phys);
            return Ok(phys);
        }
        let fresh = self.alloc_slot()?;
        let copy = self.pages[phys].clone();
        self.pages[fresh] = copy;
        if let Some(old_freed) = self.alloc.replace(seq, page_idx, fresh)? {
            self.pages[old_freed] = None;
        }
        self.cow_copies += 1;
        self.promote_if_cold(fresh);
        Ok(fresh)
    }

    fn new_page_data(&self) -> PageData {
        match self.cfg.mode {
            CacheMode::Fp8 => PageData::Fp8(
                (0..self.cfg.n_layers).map(|_| Page::new(self.cfg.d_c, self.cfg.d_r)).collect(),
            ),
            CacheMode::Bf16 => PageData::Bf16(
                (0..self.cfg.n_layers)
                    .map(|_| Bf16Page {
                        content: vec![0; PAGE_TOKENS * self.cfg.d_c],
                        rope: vec![0; PAGE_TOKENS * self.cfg.d_r],
                    })
                    .collect(),
            ),
        }
    }

    // --- append / read paths ----------------------------------------------

    /// Fused-K-Append: quantize (mode-dependent) + paged write of ONE token
    /// across all layers. `c_kv` and `k_r` are [n_layers * d_c] / [n_layers *
    /// d_r] raw f32 values for this token.
    pub fn append_token(
        &mut self,
        seq: SeqHandle,
        c_kv: &[f32],
        k_r: &[f32],
    ) -> Result<(), AllocError> {
        let (d_c, d_r, layers) = (self.cfg.d_c, self.cfg.d_r, self.cfg.n_layers);
        assert_eq!(c_kv.len(), layers * d_c);
        assert_eq!(k_r.len(), layers * d_r);
        let pos = self.seqs.get(&seq).ok_or(AllocError::UnknownSequence)?.tokens;
        let slot = pos % PAGE_TOKENS;
        let phys = self.writable_page(seq, pos / PAGE_TOKENS)?;
        let data = self.pages[phys].as_mut().expect("allocated page must exist");
        match data {
            PageData::Fp8(layers_pages) => {
                for (l, page) in layers_pages.iter_mut().enumerate() {
                    page.append_raw(
                        slot,
                        d_c,
                        d_r,
                        &c_kv[l * d_c..(l + 1) * d_c],
                        &k_r[l * d_r..(l + 1) * d_r],
                    );
                }
            }
            PageData::Bf16(layers_pages) => {
                for (l, page) in layers_pages.iter_mut().enumerate() {
                    for i in 0..d_c {
                        page.content[slot * d_c + i] = bf16_encode(c_kv[l * d_c + i]);
                    }
                    for i in 0..d_r {
                        page.rope[slot * d_r + i] = bf16_encode(k_r[l * d_r + i]);
                    }
                }
            }
        }
        self.seqs.get_mut(&seq).unwrap().tokens = pos + 1;
        self.appends += 1;
        Ok(())
    }

    /// Append a token whose FP8 quantization was already done by the XLA
    /// graph (the decode step returns E4M3-grid values + scales): store the
    /// codes directly, bit-exact with the in-graph quantization.
    pub fn append_prequantized(
        &mut self,
        seq: SeqHandle,
        k_c_grid: &[f32], // [layers * d_c] values on the E4M3 grid
        k_r_aligned: &[f32],
        sigma: &[f32], // [layers]
    ) -> Result<(), AllocError> {
        assert_eq!(self.cfg.mode, CacheMode::Fp8);
        let (d_c, d_r, _layers) = (self.cfg.d_c, self.cfg.d_r, self.cfg.n_layers);
        let pos = self.seqs.get(&seq).ok_or(AllocError::UnknownSequence)?.tokens;
        let slot = pos % PAGE_TOKENS;
        let phys = self.writable_page(seq, pos / PAGE_TOKENS)?;
        let data = self.pages[phys].as_mut().unwrap();
        if let PageData::Fp8(layers_pages) = data {
            for (l, page) in layers_pages.iter_mut().enumerate() {
                let codes: Vec<u8> = k_c_grid[l * d_c..(l + 1) * d_c]
                    .iter()
                    .map(|&x| crate::fp8::e4m3_encode(x))
                    .collect();
                page.write_token(
                    slot,
                    d_c,
                    d_r,
                    &codes,
                    &k_r_aligned[l * d_r..(l + 1) * d_r],
                    sigma[l],
                );
            }
        }
        self.seqs.get_mut(&seq).unwrap().tokens = pos + 1;
        self.appends += 1;
        Ok(())
    }

    /// Gather the kernel view of one (sequence, layer) into contiguous
    /// buffers of `max_tokens` rows (padded with zeros): content values on
    /// the E4M3 grid (or bf16 values in BF16 mode), aligned rope, and
    /// per-token sigma (1.0 in BF16 mode).
    pub fn gather_kernel_view(
        &self,
        seq: SeqHandle,
        layer: usize,
        max_tokens: usize,
        content_out: &mut [f32],
        rope_out: &mut [f32],
        sigma_out: &mut [f32],
    ) {
        let (d_c, d_r) = (self.cfg.d_c, self.cfg.d_r);
        assert!(content_out.len() >= max_tokens * d_c);
        assert!(rope_out.len() >= max_tokens * d_r);
        assert!(sigma_out.len() >= max_tokens);
        content_out[..max_tokens * d_c].fill(0.0);
        rope_out[..max_tokens * d_r].fill(0.0);
        sigma_out[..max_tokens].fill(1.0);
        let tokens = self.tokens_of(seq).min(max_tokens);
        let Some(table) = self.alloc.pages_of(seq) else { return };
        for t in 0..tokens {
            let phys = table[t / PAGE_TOKENS];
            let slot = t % PAGE_TOKENS;
            debug_assert_ne!(
                self.tier[phys],
                TierState::PrefetchInFlight,
                "read through a page whose prefetch has not landed"
            );
            match self.pages[phys].as_ref().unwrap() {
                PageData::Fp8(layers_pages) => {
                    let page = &layers_pages[layer];
                    sigma_out[t] = page.kernel_view(
                        slot,
                        d_c,
                        d_r,
                        &mut content_out[t * d_c..(t + 1) * d_c],
                        &mut rope_out[t * d_r..(t + 1) * d_r],
                    );
                }
                PageData::Bf16(layers_pages) => {
                    let page = &layers_pages[layer];
                    for i in 0..d_c {
                        content_out[t * d_c + i] = bf16_decode(page.content[slot * d_c + i]);
                    }
                    for i in 0..d_r {
                        rope_out[t * d_r + i] = bf16_decode(page.rope[slot * d_r + i]);
                    }
                    sigma_out[t] = 1.0;
                }
                PageData::Cold(layers_pages) => {
                    // decompress-on-access: reconstruct full-domain, then map
                    // back onto the kernel's (grid, sigma) scale domain
                    let cp = &layers_pages[layer];
                    let s = if cp.src_scales[slot] > 0.0 { cp.src_scales[slot] } else { 1.0 };
                    let row = &mut content_out[t * d_c..(t + 1) * d_c];
                    cp.decode_token(slot, d_c, row);
                    for x in row.iter_mut() {
                        *x /= s;
                    }
                    for i in 0..d_r {
                        rope_out[t * d_r + i] = bf16_decode(cp.rope[slot * d_r + i]);
                    }
                    sigma_out[t] = s;
                }
            }
        }
    }

    /// Fused-Fetch-Dequant of a token range into f32 (chunked prefill /
    /// prefix-cache reuse path).
    pub fn fetch_dequant_range(
        &self,
        seq: SeqHandle,
        layer: usize,
        start: usize,
        count: usize,
        content_out: &mut [f32],
        rope_out: &mut [f32],
    ) {
        let (d_c, d_r) = (self.cfg.d_c, self.cfg.d_r);
        let table = self.alloc.pages_of(seq).expect("sequence registered");
        for k in 0..count {
            let t = start + k;
            let phys = table[t / PAGE_TOKENS];
            let slot = t % PAGE_TOKENS;
            debug_assert_ne!(
                self.tier[phys],
                TierState::PrefetchInFlight,
                "read through a page whose prefetch has not landed"
            );
            match self.pages[phys].as_ref().unwrap() {
                PageData::Fp8(layers_pages) => {
                    layers_pages[layer].fetch_dequant(
                        slot,
                        d_c,
                        d_r,
                        &mut content_out[k * d_c..(k + 1) * d_c],
                        &mut rope_out[k * d_r..(k + 1) * d_r],
                    );
                }
                PageData::Bf16(layers_pages) => {
                    let page = &layers_pages[layer];
                    for i in 0..d_c {
                        content_out[k * d_c + i] = bf16_decode(page.content[slot * d_c + i]);
                    }
                    for i in 0..d_r {
                        rope_out[k * d_r + i] = bf16_decode(page.rope[slot * d_r + i]);
                    }
                }
                PageData::Cold(layers_pages) => {
                    // full-domain reconstruction; rope rides along verbatim
                    // and rescales by the original sigma, like the hot path
                    let cp = &layers_pages[layer];
                    cp.decode_token(slot, d_c, &mut content_out[k * d_c..(k + 1) * d_c]);
                    let s = if cp.src_scales[slot] > 0.0 { cp.src_scales[slot] } else { 1.0 };
                    for i in 0..d_r {
                        rope_out[k * d_r + i] = bf16_decode(cp.rope[slot * d_r + i]) * s;
                    }
                }
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    fn cfg(mode: CacheMode) -> CacheConfig {
        CacheConfig { n_layers: 2, d_c: 16, d_r: 8, mode, capacity_pages: 8 }
    }

    fn rand_token(rng: &mut Rng, cfg: &CacheConfig) -> (Vec<f32>, Vec<f32>) {
        (
            rng.normal_vec(cfg.n_layers * cfg.d_c, 2.0),
            rng.normal_vec(cfg.n_layers * cfg.d_r, 30.0),
        )
    }

    #[test]
    fn append_and_gather_fp8() {
        let c = cfg(CacheMode::Fp8);
        let mut cache = PagedKvCache::new(c);
        cache.register(1);
        let mut rng = Rng::new(1);
        let mut raw = Vec::new();
        for _ in 0..70 {
            let (ck, kr) = rand_token(&mut rng, &c);
            cache.append_token(1, &ck, &kr).unwrap();
            raw.push((ck, kr));
        }
        assert_eq!(cache.tokens_of(1), 70);
        assert_eq!(cache.used_pages(), 2); // 70 tokens → 2 pages

        let mut content = vec![0.0f32; 128 * c.d_c];
        let mut rope = vec![0.0f32; 128 * c.d_r];
        let mut sigma = vec![0.0f32; 128];
        for layer in 0..2 {
            cache.gather_kernel_view(1, layer, 128, &mut content, &mut rope, &mut sigma);
            for (t, (ck, kr)) in raw.iter().enumerate() {
                let row = &ck[layer * c.d_c..(layer + 1) * c.d_c];
                let amax = row.iter().fold(0.0f32, |m, &x| m.max(x.abs()));
                for i in 0..c.d_c {
                    let got = content[t * c.d_c + i] * sigma[t];
                    assert!((got - row[i]).abs() <= amax * 0.0625 + 1e-6);
                }
                for i in 0..c.d_r {
                    let got = rope[t * c.d_r + i] * sigma[t];
                    let want = kr[layer * c.d_r + i];
                    assert!(((got - want) / want).abs() < 0.02, "{got} {want}");
                }
            }
            // padding rows zeroed with sigma 1
            assert_eq!(content[70 * c.d_c], 0.0);
            assert_eq!(sigma[127], 1.0);
        }
    }

    #[test]
    fn bf16_mode_roundtrip() {
        let c = cfg(CacheMode::Bf16);
        let mut cache = PagedKvCache::new(c);
        cache.register(9);
        let mut rng = Rng::new(2);
        let (ck, kr) = rand_token(&mut rng, &c);
        cache.append_token(9, &ck, &kr).unwrap();
        let mut content = vec![0.0f32; 64 * c.d_c];
        let mut rope = vec![0.0f32; 64 * c.d_r];
        let mut sigma = vec![0.0f32; 64];
        cache.gather_kernel_view(9, 1, 64, &mut content, &mut rope, &mut sigma);
        for i in 0..c.d_c {
            let want = ck[c.d_c + i];
            assert!(((content[i] - want) / want).abs() < 0.01);
        }
        assert_eq!(sigma[0], 1.0);
    }

    #[test]
    fn prequantized_append_is_bit_exact() {
        let c = cfg(CacheMode::Fp8);
        let mut cache = PagedKvCache::new(c);
        cache.register(5);
        // values already on the E4M3 grid
        let grid: Vec<f32> = (0..c.n_layers * c.d_c)
            .map(|i| crate::fp8::e4m3_round((i as f32 - 16.0) * 0.25))
            .collect();
        let rope: Vec<f32> = (0..c.n_layers * c.d_r).map(|i| i as f32 * 0.5).collect();
        let sigma = vec![0.013f32, 2.5];
        cache.append_prequantized(5, &grid, &rope, &sigma).unwrap();
        let mut content = vec![0.0f32; 64 * c.d_c];
        let mut r = vec![0.0f32; 64 * c.d_r];
        let mut s = vec![0.0f32; 64];
        for layer in 0..2 {
            cache.gather_kernel_view(5, layer, 64, &mut content, &mut r, &mut s);
            assert_eq!(s[0], sigma[layer]);
            for i in 0..c.d_c {
                assert_eq!(content[i], grid[layer * c.d_c + i], "layer {layer} i {i}");
            }
        }
    }

    #[test]
    fn release_frees_pages_and_data() {
        let c = cfg(CacheMode::Fp8);
        let mut cache = PagedKvCache::new(c);
        cache.register(1);
        let mut rng = Rng::new(3);
        for _ in 0..65 {
            let (ck, kr) = rand_token(&mut rng, &c);
            cache.append_token(1, &ck, &kr).unwrap();
        }
        assert_eq!(cache.used_pages(), 2);
        cache.release(1);
        assert_eq!(cache.used_pages(), 0);
        assert_eq!(cache.tokens_of(1), 0);
        cache.validate().unwrap();
    }

    #[test]
    fn capacity_exhaustion_and_can_append() {
        let mut c = cfg(CacheMode::Fp8);
        c.capacity_pages = 1;
        let mut cache = PagedKvCache::new(c);
        cache.register(1);
        let mut rng = Rng::new(4);
        for _ in 0..64 {
            let (ck, kr) = rand_token(&mut rng, &c);
            cache.append_token(1, &ck, &kr).unwrap();
        }
        assert!(!cache.can_append(1, 1));
        let (ck, kr) = rand_token(&mut rng, &c);
        assert!(cache.append_token(1, &ck, &kr).is_err());
    }

    #[test]
    fn memory_stats_show_reduction() {
        let c = cfg(CacheMode::Fp8);
        let mut cache = PagedKvCache::new(c);
        cache.register(1);
        let mut rng = Rng::new(5);
        let (ck, kr) = rand_token(&mut rng, &c);
        cache.append_token(1, &ck, &kr).unwrap();
        let (used, f32_equiv) = cache.memory_stats();
        assert!(used * 2 < f32_equiv, "{used} vs {f32_equiv}");
    }

    #[test]
    fn interleaved_sequences_stay_isolated() {
        let c = cfg(CacheMode::Fp8);
        let mut cache = PagedKvCache::new(c);
        cache.register(1);
        cache.register(2);
        let mut rng = Rng::new(6);
        let (ck1, kr1) = rand_token(&mut rng, &c);
        let (ck2, kr2) = rand_token(&mut rng, &c);
        cache.append_token(1, &ck1, &kr1).unwrap();
        cache.append_token(2, &ck2, &kr2).unwrap();
        cache.append_token(1, &ck1, &kr1).unwrap();
        assert_eq!(cache.tokens_of(1), 2);
        assert_eq!(cache.tokens_of(2), 1);
        let mut c1 = vec![0.0f32; 64 * c.d_c];
        let mut c2 = vec![0.0f32; 64 * c.d_c];
        let mut r = vec![0.0f32; 64 * c.d_r];
        let mut s = vec![0.0f32; 64];
        cache.gather_kernel_view(1, 0, 64, &mut c1, &mut r, &mut s);
        cache.gather_kernel_view(2, 0, 64, &mut c2, &mut r, &mut s);
        // token 0 of each sequence must reflect its own data
        assert_ne!(&c1[..c.d_c], &c2[..c.d_c]);
        // seq 1 token 1 equals token 0 (same input appended twice)
        assert_eq!(&c1[..c.d_c], &c1[c.d_c..2 * c.d_c]);
    }

    // --- prefix sharing / spill lifecycle -----------------------------------

    fn fill_tokens(cache: &mut PagedKvCache, seq: u64, n: usize, seed: u64) {
        let c = cache.cfg;
        let mut rng = Rng::new(seed);
        for _ in 0..n {
            let (ck, kr) = rand_token(&mut rng, &c);
            cache.append_token(seq, &ck, &kr).unwrap();
        }
    }

    #[test]
    fn publish_and_adopt_share_physical_pages() {
        let c = cfg(CacheMode::Fp8);
        let mut cache = PagedKvCache::new(c);
        let prompt: Vec<i32> = (0..130).collect(); // 2 full pages + 2 tokens
        cache.register(1);
        fill_tokens(&mut cache, 1, prompt.len(), 11);
        cache.publish_prefix(1, &prompt);
        assert_eq!(cache.retained_pages(), 2);
        let before = cache.used_pages();

        cache.register(2);
        let adopted = cache.adopt_prefix(2, &prompt);
        assert_eq!(adopted, 2 * PAGE_TOKENS);
        assert_eq!(cache.tokens_of(2), 128);
        // sharing allocated no new pages
        assert_eq!(cache.used_pages(), before);
        cache.validate().unwrap();

        // the adopted view is byte-identical to the publisher's
        let (n, dc, dr) = (128, c.d_c, c.d_r);
        let mut a = vec![0.0f32; n * dc];
        let mut b = vec![0.0f32; n * dc];
        let mut r = vec![0.0f32; n * dr];
        let mut s = vec![0.0f32; n];
        cache.gather_kernel_view(1, 0, n, &mut a, &mut r, &mut s);
        cache.gather_kernel_view(2, 0, n, &mut b, &mut r, &mut s);
        assert_eq!(a, b);

        // release both: pages stay retained by the trie, then drop to zero
        cache.release(1);
        cache.release(2);
        assert_eq!(cache.used_pages(), 2);
        cache.drop_prefix_cache();
        assert_eq!(cache.used_pages(), 0);
        cache.validate().unwrap();
    }

    #[test]
    fn prefix_match_probe_agrees_with_adopt() {
        let c = cfg(CacheMode::Fp8);
        let mut cache = PagedKvCache::new(c);
        let prompt: Vec<i32> = (0..130).collect(); // 2 full pages + 2 tokens
        cache.register(1);
        fill_tokens(&mut cache, 1, prompt.len(), 31);
        assert_eq!(cache.prefix_match_tokens(&prompt), 0);
        cache.publish_prefix(1, &prompt);
        // probe reports exactly what adopt_prefix would take…
        assert_eq!(cache.prefix_match_tokens(&prompt), 2 * PAGE_TOKENS);
        // …including the ≥1-token-to-prefill cap on an exact-page prompt
        let exact: Vec<i32> = (0..2 * PAGE_TOKENS as i32).collect();
        assert_eq!(cache.prefix_match_tokens(&exact), PAGE_TOKENS);
        cache.register(2);
        assert_eq!(cache.adopt_prefix(2, &prompt), 2 * PAGE_TOKENS);
        // publisher live + adopter live: nothing evictable; after both
        // release, the retained pages become reclaimable headroom
        assert_eq!(cache.evictable_pages(), 0);
        cache.release(1);
        cache.release(2);
        assert_eq!(cache.evictable_pages(), 2);
        assert_eq!(cache.available_pages(), c.capacity_pages);
        cache.validate().unwrap();
    }

    #[test]
    fn adopt_leaves_at_least_one_token_to_prefill() {
        let c = cfg(CacheMode::Fp8);
        let mut cache = PagedKvCache::new(c);
        let prompt: Vec<i32> = (0..64).collect(); // exactly one page
        cache.register(1);
        fill_tokens(&mut cache, 1, 64, 12);
        cache.publish_prefix(1, &prompt);
        cache.register(2);
        // matching all 64 tokens would leave nothing to prefill → adopt none
        assert_eq!(cache.adopt_prefix(2, &prompt), 0);
        // a longer prompt sharing the page adopts it
        let mut longer = prompt.clone();
        longer.push(999);
        cache.register(3);
        assert_eq!(cache.adopt_prefix(3, &longer), 64);
    }

    #[test]
    fn cow_on_divergence_inside_shared_page() {
        let c = cfg(CacheMode::Fp8);
        let mut cache = PagedKvCache::new(c);
        cache.register(1);
        fill_tokens(&mut cache, 1, 10, 13); // partial page
        // force-share seq 1's partial page into seq 2 (the trie never does
        // this; the append path must still be safe if it ever happens)
        let p = cache.alloc.pages_of(1).unwrap()[0];
        cache.register(2);
        cache.alloc.share(2, p).unwrap();
        cache.seqs.get_mut(&2).unwrap().tokens = 10;

        let mut rng = Rng::new(14);
        let (ck, kr) = rand_token(&mut rng, &c);
        cache.append_token(2, &ck, &kr).unwrap();
        assert_eq!(cache.cow_copies(), 1);
        // seq 1's page is untouched; seq 2 got its own copy
        assert_ne!(cache.alloc.pages_of(1).unwrap()[0], cache.alloc.pages_of(2).unwrap()[0]);
        assert_eq!(cache.tokens_of(1), 10);
        assert_eq!(cache.tokens_of(2), 11);
        cache.validate().unwrap();
    }

    #[test]
    fn spill_restore_is_bit_exact() {
        let c = cfg(CacheMode::Fp8);
        let mut cache = PagedKvCache::new(c);
        cache.register(1);
        fill_tokens(&mut cache, 1, 70, 15);
        let (n, dc, dr) = (70, c.d_c, c.d_r);
        let mut before_c = vec![0.0f32; 128 * dc];
        let mut before_r = vec![0.0f32; 128 * dr];
        let mut before_s = vec![0.0f32; 128];
        cache.gather_kernel_view(1, 1, n, &mut before_c, &mut before_r, &mut before_s);

        let sp = cache.spill(1).unwrap();
        assert_eq!(sp.tokens(), 70);
        assert_eq!(sp.pages(), 2);
        assert_eq!(cache.used_pages(), 0);

        cache.restore(1, sp).unwrap();
        assert_eq!(cache.tokens_of(1), 70);
        let mut after_c = vec![0.0f32; 128 * dc];
        let mut after_r = vec![0.0f32; 128 * dr];
        let mut after_s = vec![0.0f32; 128];
        cache.gather_kernel_view(1, 1, n, &mut after_c, &mut after_r, &mut after_s);
        assert_eq!(before_c, after_c);
        assert_eq!(before_r, after_r);
        assert_eq!(before_s, after_s);
        cache.validate().unwrap();
    }

    #[test]
    fn restore_evicts_prefix_cache_under_pressure() {
        let mut c = cfg(CacheMode::Fp8);
        c.capacity_pages = 2;
        let mut cache = PagedKvCache::new(c);
        let prompt: Vec<i32> = (0..65).collect();
        cache.register(1);
        fill_tokens(&mut cache, 1, 65, 16);
        cache.publish_prefix(1, &prompt); // retains page 0
        let sp = cache.spill(1).unwrap();
        assert_eq!(cache.retained_pages(), 1);
        assert_eq!(cache.free_pages(), 1);
        assert_eq!(cache.available_pages(), 2);
        // restore needs 2 pages → evicts the trie page
        cache.restore(1, sp).unwrap();
        assert_eq!(cache.retained_pages(), 0);
        assert_eq!(cache.tokens_of(1), 65);
        cache.validate().unwrap();
    }

    #[test]
    fn eviction_prefers_reclaimable_over_shared_pages() {
        let mut c = cfg(CacheMode::Fp8);
        c.capacity_pages = 3;
        let mut cache = PagedKvCache::new(c);
        // page A: published AND still shared with live seq 1 (rc 2)
        let prompt_a: Vec<i32> = (0..64).collect();
        cache.register(1);
        fill_tokens(&mut cache, 1, 64, 21);
        cache.publish_prefix(1, &prompt_a);
        // page B: published, publisher finished (rc 1 — trie only)
        let prompt_b: Vec<i32> = (1000..1064).collect();
        cache.register(2);
        fill_tokens(&mut cache, 2, 64, 22);
        cache.publish_prefix(2, &prompt_b);
        cache.release(2);
        assert_eq!(cache.retained_pages(), 2);

        // A is LRU-older, but evicting it would free nothing: pressure must
        // reclaim B and keep the still-hot shared retention of A
        cache.register(3);
        fill_tokens(&mut cache, 3, 65, 23); // needs 2 pages, only 1 free
        assert_eq!(cache.retained_pages(), 1);
        assert_eq!(cache.tokens_of(1), 64);
        let mut longer = prompt_a.clone();
        longer.push(7);
        cache.register(4);
        assert_eq!(cache.adopt_prefix(4, &longer), 64, "A's retention must survive");
        cache.validate().unwrap();
    }

    fn views(cache: &PagedKvCache, seq: u64, n: usize) -> (Vec<f32>, Vec<f32>, Vec<f32>) {
        let c = cache.cfg;
        let mut content = vec![0.0f32; n * c.d_c];
        let mut rope = vec![0.0f32; n * c.d_r];
        let mut sigma = vec![0.0f32; n];
        let mut all = (Vec::new(), Vec::new(), Vec::new());
        for layer in 0..c.n_layers {
            cache.gather_kernel_view(seq, layer, n, &mut content, &mut rope, &mut sigma);
            all.0.extend_from_slice(&content);
            all.1.extend_from_slice(&rope);
            all.2.extend_from_slice(&sigma);
        }
        all
    }

    #[test]
    fn checkpoint_rollback_matches_never_drafted_run() {
        for mode in [CacheMode::Fp8, CacheMode::Bf16] {
            let c = cfg(mode);
            let mut never = PagedKvCache::new(c);
            let mut spec = PagedKvCache::new(c);
            never.register(1);
            spec.register(1);
            let mut rng = Rng::new(77);
            for _ in 0..62 {
                let (ck, kr) = rand_token(&mut rng, &c);
                never.append_token(1, &ck, &kr).unwrap();
                spec.append_token(1, &ck, &kr).unwrap();
            }
            // the reference run appends only the 2 accepted tokens; the
            // spec run drafts 4 (crossing into a second page) and rolls the
            // rejected 2 back
            let drafts: Vec<_> = (0..4).map(|_| rand_token(&mut rng, &c)).collect();
            let ckpt = spec.checkpoint(1).unwrap();
            assert_eq!((ckpt.seq(), ckpt.tokens()), (1, 62));
            for (ck, kr) in &drafts[..2] {
                never.append_token(1, ck, kr).unwrap();
            }
            for (ck, kr) in &drafts {
                spec.append_token(1, ck, kr).unwrap();
            }
            assert_eq!(spec.used_pages(), 2);
            spec.rollback_to(&ckpt, 2).unwrap();
            assert_eq!(spec.tokens_of(1), 64);
            assert_eq!(spec.used_pages(), never.used_pages());
            assert_eq!(spec.free_pages(), never.free_pages());
            assert_eq!(spec.raw_seq_bytes(1), never.raw_seq_bytes(1));
            spec.validate().unwrap();

            // growth after rollback lands on the same physical pages with
            // the same bytes — the draft left no trace
            let (ck, kr) = rand_token(&mut rng, &c);
            never.append_token(1, &ck, &kr).unwrap();
            spec.append_token(1, &ck, &kr).unwrap();
            assert_eq!(spec.alloc.pages_of(1), never.alloc.pages_of(1));
            assert_eq!(spec.raw_seq_bytes(1), never.raw_seq_bytes(1));

            // full rejection erases mid-page drafts too
            let ckpt2 = spec.checkpoint(1).unwrap();
            for (ck, kr) in &drafts {
                spec.append_token(1, ck, kr).unwrap();
            }
            spec.rollback_to(&ckpt2, 0).unwrap();
            assert_eq!(spec.tokens_of(1), 65);
            assert_eq!(spec.raw_seq_bytes(1), never.raw_seq_bytes(1));
            spec.validate().unwrap();
        }
    }

    #[test]
    fn wire_roundtrip_matches_spill_restore() {
        for mode in [CacheMode::Fp8, CacheMode::Bf16] {
            let c = cfg(mode);
            let mut src = PagedKvCache::new(c);
            src.register(1);
            fill_tokens(&mut src, 1, 70, 41); // 2 pages, partial last
            let wire = src.export_wire(1).unwrap();
            assert_eq!(wire.tokens(), 70);
            assert_eq!(wire.mode(), mode);

            let mut dst = PagedKvCache::new(c);
            dst.import_wire(9, &wire).unwrap();
            assert_eq!(dst.tokens_of(9), 70);
            // the imported kernel views are bit-identical to the source's
            assert_eq!(views(&src, 1, 70), views(&dst, 9, 70));
            // and re-exporting reproduces the wire block byte for byte
            assert_eq!(dst.export_wire(9).unwrap(), wire);
            dst.validate().unwrap();

            // spill/restore within the source is the reference lifecycle:
            // the wire path must agree with it exactly
            let before = views(&src, 1, 70);
            let sp = src.spill(1).unwrap();
            src.restore(1, sp).unwrap();
            assert_eq!(views(&src, 1, 70), before);
        }
    }

    #[test]
    fn import_wire_evicts_prefix_cache_and_reports_exhaustion() {
        let mut c = cfg(CacheMode::Fp8);
        c.capacity_pages = 2;
        let mut cache = PagedKvCache::new(c);
        let prompt: Vec<i32> = (0..64).collect();
        cache.register(1);
        fill_tokens(&mut cache, 1, 70, 42); // 2 pages
        cache.publish_prefix(1, &prompt); // retains page 0
        let wire = cache.export_wire(1).unwrap();
        cache.release(1); // page 0 lives on via the trie; page 1 freed
        assert_eq!(cache.free_pages(), 1);
        assert_eq!(cache.retained_pages(), 1);

        // importing 2 pages needs the retained page back: trie evicted
        cache.import_wire(2, &wire).unwrap();
        assert_eq!(cache.retained_pages(), 0);
        assert_eq!(cache.tokens_of(2), 70);
        cache.validate().unwrap();

        // a second import cannot fit even with full eviction
        assert_eq!(cache.import_wire(3, &wire), Err(AllocError::OutOfPages));
        cache.validate().unwrap();
    }

    #[test]
    fn append_evicts_prefix_cache_under_pressure() {
        let mut c = cfg(CacheMode::Fp8);
        c.capacity_pages = 2;
        let mut cache = PagedKvCache::new(c);
        let prompt: Vec<i32> = (0..64).collect();
        cache.register(1);
        fill_tokens(&mut cache, 1, 64, 17);
        cache.publish_prefix(1, &prompt);
        cache.release(1); // page lives on via trie retention
        assert_eq!(cache.used_pages(), 1);

        cache.register(2);
        fill_tokens(&mut cache, 2, 65, 18); // needs 2 pages → evicts trie page
        assert_eq!(cache.retained_pages(), 0);
        assert_eq!(cache.tokens_of(2), 65);
        cache.validate().unwrap();
    }

    // --- cold-page compression tier -----------------------------------------

    /// Full-domain reconstruction (content * sigma) of the first layer.
    fn full_domain(cache: &PagedKvCache, seq: u64, n: usize) -> Vec<f32> {
        let c = cache.cfg;
        let (content, _, sigma) = views(cache, seq, n);
        (0..n * c.d_c).map(|i| content[i] * sigma[i / c.d_c]).collect()
    }

    #[test]
    fn compress_cold_spares_the_tail_and_reads_stay_within_the_rank_bound() {
        let c = cfg(CacheMode::Fp8);
        let mut cache = PagedKvCache::new(c);
        cache.register(1);
        fill_tokens(&mut cache, 1, 200, 21); // 4 pages: 64+64+64+8
        let hot = full_domain(&cache, 1, 200);
        let (_, hot_rope, _) = views(&cache, 1, 200);

        let rank = 12;
        // hot window 64 tokens → (200-64)/64 = 2 pages eligible
        let done = cache.compress_cold(1, 64, rank).unwrap();
        assert_eq!(done, 2);
        assert_eq!(cache.cold_pages(), 2);
        cache.validate().unwrap();

        // decompression-on-access: gather reads through the cold pages; the
        // reconstruction stays inside the rank's fidelity budget while the
        // hot pages (incl. the tail) are untouched bit for bit
        let cold = full_domain(&cache, 1, 200);
        assert_eq!(cold[128 * c.d_c..200 * c.d_c], hot[128 * c.d_c..200 * c.d_c]);
        let (num, den) = hot[..128 * c.d_c]
            .iter()
            .zip(&cold[..128 * c.d_c])
            .fold((0.0f64, 0.0f64), |(n, d), (&h, &r)| {
                (n + ((h - r) as f64).powi(2), d + (h as f64).powi(2))
            });
        let rel = (num / den.max(1e-30)).sqrt();
        assert!(rel < super::super::compress::rel_l2_bound(rank, c.d_c), "rel l2 {rel}");
        // rope rides along verbatim
        let (_, cold_rope, _) = views(&cache, 1, 200);
        assert_eq!(hot_rope, cold_rope);

        // appends keep landing in the hot tail
        let mut rng = Rng::new(22);
        let (ck, kr) = rand_token(&mut rng, &c);
        cache.append_token(1, &ck, &kr).unwrap();
        assert_eq!(cache.cold_pages(), 2);
        cache.validate().unwrap();
    }

    #[test]
    fn deep_rollback_promotes_a_cold_page_before_erasing_drafts() {
        let c = cfg(CacheMode::Fp8);
        let mut cache = PagedKvCache::new(c);
        cache.register(1);
        fill_tokens(&mut cache, 1, 70, 23);
        let ckpt = cache.checkpoint(1).unwrap();
        fill_tokens(&mut cache, 1, 60, 24); // 130 tokens, 3 pages
        // cold sweep since the checkpoint: pages 0 and 1 go cold
        assert_eq!(cache.compress_cold(1, 0, 12).unwrap(), 2);

        // rolling back to 70 erases drafts inside (now-cold) page 1: the
        // erase is a write access, so the page promotes back to hot first
        cache.rollback_to(&ckpt, 0).unwrap();
        assert_eq!(cache.tokens_of(1), 70);
        assert_eq!(cache.cold_promotions(), 1);
        assert_eq!(cache.cold_pages(), 1, "page 0 stays cold");
        cache.validate().unwrap();
        // and the cache still reads/extends normally
        fill_tokens(&mut cache, 1, 10, 25);
        assert_eq!(cache.tokens_of(1), 80);
    }

    #[test]
    fn export_wire_reads_through_cold_pages() {
        let c = cfg(CacheMode::Fp8);
        let mut src = PagedKvCache::new(c);
        src.register(1);
        fill_tokens(&mut src, 1, 130, 26);
        src.compress_cold(1, 64, 12).unwrap();
        assert_eq!(src.cold_pages(), 1);

        // the wire block re-quantizes the cold reconstruction under the
        // original sigmas, so hot pages, rope, and sigmas cross exactly;
        // the cold range picks up one extra E4M3 rounding (3-bit mantissa:
        // <= 2^-4 relative) between the exporter's direct reconstruction
        // and the importer's grid codes
        let wire = src.export_wire(1).unwrap();
        let mut dst = PagedKvCache::new(c);
        assert!(dst.import_wire(9, &wire).is_ok());
        let (sc, s_rope, s_sig) = views(&src, 1, 130);
        let (dc, d_rope, d_sig) = views(&dst, 9, 130);
        assert_eq!(s_sig, d_sig);
        assert_eq!(s_rope, d_rope);
        // the first page (tokens 0..64) went cold in every layer; the rest
        // stayed hot (`views` concatenates the layers)
        let n = 130 * c.d_c;
        for l in 0..c.n_layers {
            let (s_l, d_l) = (&sc[l * n..(l + 1) * n], &dc[l * n..(l + 1) * n]);
            assert_eq!(s_l[64 * c.d_c..], d_l[64 * c.d_c..], "hot pages verbatim, layer {l}");
            for (i, (&a, &b)) in s_l[..64 * c.d_c].iter().zip(&d_l[..64 * c.d_c]).enumerate() {
                let tol = a.abs().max(b.abs()) * 0.0625 + 1e-2;
                assert!((a - b).abs() <= tol, "cold elt {i} layer {l}: {a} vs {b}");
            }
        }
        dst.validate().unwrap();
    }

    #[test]
    fn tiered_spill_roundtrip_preserves_cold_pages() {
        let c = cfg(CacheMode::Fp8);
        let mut cache = PagedKvCache::new(c);
        cache.register(1);
        fill_tokens(&mut cache, 1, 130, 27);
        cache.compress_cold(1, 64, 12).unwrap();
        let before = views(&cache, 1, 130);
        let before_raw = cache.raw_seq_bytes(1);

        let p0 = cache.alloc.pages_of(1).unwrap()[0];
        cache.begin_spill(1).unwrap();
        assert_eq!(cache.tier_of(p0), TierState::SpillInFlight);
        let sp = cache.finish_spill(1).unwrap();
        assert_eq!(cache.used_pages(), 0);
        assert_eq!(cache.tier_of(p0), TierState::Host);

        cache.begin_prefetch(1, sp).unwrap();
        let p0 = cache.alloc.pages_of(1).unwrap()[0];
        assert_eq!(cache.tier_of(p0), TierState::PrefetchInFlight);
        cache.finish_prefetch(1).unwrap();
        assert_eq!(cache.tier_of(p0), TierState::Hbm);
        // bit-exact, cold format and all
        assert_eq!(cache.raw_seq_bytes(1), before_raw);
        assert_eq!(views(&cache, 1, 130), before);
        assert_eq!(cache.cold_pages(), 1);
        cache.validate().unwrap();
    }
}
