//! Prefix-sharing trie over full KV pages.
//!
//! Sequences that share a prompt prefix map the same *physical* pages: the
//! trie keys each level by the 64 token ids of one full page and stores the
//! physical page holding that page's KV. Only **full** pages are ever
//! published (partial pages stay private to their sequence), so shared pages
//! are immutable by construction; the cache still guards the append path
//! with copy-on-write in case a partially-filled page ever becomes shared.
//!
//! The MLA latent cache makes this cheap: a 64-token page is ~40 KB of
//! E4M3+bf16 per layer instead of multi-head f32 KV, so retaining popular
//! prefixes costs little (cf. *Hardware-Centric Analysis of DeepSeek's
//! MLA*). The trie holds one retention reference per published page; under
//! page pressure the cache evicts least-recently-used leaves.

use super::PAGE_TOKENS;
use std::collections::BTreeMap;

struct Node {
    /// the 64 token ids this level matched
    tokens: Vec<i32>,
    /// physical page holding the KV of those tokens (trie holds one ref)
    page: usize,
    parent: Option<usize>,
    children: BTreeMap<Vec<i32>, usize>,
    last_used: u64,
}

/// Trie of published full-page prompt prefixes → physical pages.
pub struct PrefixTrie {
    nodes: Vec<Option<Node>>,
    free_slots: Vec<usize>,
    roots: BTreeMap<Vec<i32>, usize>,
    clock: u64,
}

impl Default for PrefixTrie {
    fn default() -> Self {
        PrefixTrie::new()
    }
}

impl PrefixTrie {
    pub fn new() -> PrefixTrie {
        PrefixTrie { nodes: Vec::new(), free_slots: Vec::new(), roots: BTreeMap::new(), clock: 0 }
    }

    fn tick(&mut self) -> u64 {
        self.clock += 1;
        self.clock
    }

    fn node(&self, id: usize) -> &Node {
        self.nodes[id].as_ref().expect("live trie node")
    }

    /// Number of published pages currently retained by the trie.
    pub fn retained_pages(&self) -> usize {
        self.nodes.iter().filter(|n| n.is_some()).count()
    }

    pub fn is_empty(&self) -> bool {
        self.retained_pages() == 0
    }

    /// All physical pages the trie currently retains.
    pub fn pages(&self) -> Vec<usize> {
        self.nodes.iter().flatten().map(|n| n.page).collect()
    }

    /// Visit every retained physical page without allocating.
    pub fn for_each_page(&self, mut f: impl FnMut(usize)) {
        for n in self.nodes.iter().flatten() {
            f(n.page);
        }
    }

    /// Longest full-page prefix of `tokens` present in the trie, considering
    /// at most `max_tokens` tokens; returns the matched physical pages in
    /// prefix order (empty when nothing matches).
    pub fn lookup(&mut self, tokens: &[i32], max_tokens: usize) -> Vec<usize> {
        let now = self.tick();
        let full_pages = tokens.len().min(max_tokens) / PAGE_TOKENS;
        let mut matched = Vec::new();
        let mut level = None; // None = root
        for p in 0..full_pages {
            let key = &tokens[p * PAGE_TOKENS..(p + 1) * PAGE_TOKENS];
            let next = match level {
                None => self.roots.get(key).copied(),
                Some(id) => self.node(id).children.get(key).copied(),
            };
            let Some(id) = next else { break };
            let n = self.nodes[id].as_mut().expect("live trie node");
            n.last_used = now;
            matched.push(n.page);
            level = Some(id);
        }
        matched
    }

    /// Read-only probe: how many full pages of `tokens` (considering at most
    /// `max_tokens`) the trie already holds. Unlike [`PrefixTrie::lookup`]
    /// this touches no LRU state — the DP router calls it on every candidate
    /// rank per request, and a probe that refreshed recency would let mere
    /// routing queries pin prefixes that no sequence ever adopted.
    pub fn peek_match_pages(&self, tokens: &[i32], max_tokens: usize) -> usize {
        let full_pages = tokens.len().min(max_tokens) / PAGE_TOKENS;
        let mut matched = 0;
        let mut level = None;
        for p in 0..full_pages {
            let key = &tokens[p * PAGE_TOKENS..(p + 1) * PAGE_TOKENS];
            let next = match level {
                None => self.roots.get(key).copied(),
                Some(id) => self.node(id).children.get(key).copied(),
            };
            let Some(id) = next else { break };
            matched += 1;
            level = Some(id);
        }
        matched
    }

    /// Publish the full pages of `tokens` (a prompt prefix) backed by the
    /// sequence's physical `pages` (page i holds tokens `[64i, 64(i+1))`).
    /// Existing levels are kept (first publisher wins); returns the physical
    /// pages newly inserted — the caller must take one retention reference
    /// on each.
    pub fn insert(&mut self, tokens: &[i32], pages: &[usize]) -> Vec<usize> {
        let now = self.tick();
        let full_pages = (tokens.len() / PAGE_TOKENS).min(pages.len());
        let mut newly = Vec::new();
        let mut level = None;
        for p in 0..full_pages {
            let key = tokens[p * PAGE_TOKENS..(p + 1) * PAGE_TOKENS].to_vec();
            let existing = match level {
                None => self.roots.get(&key).copied(),
                Some(id) => self.node(id).children.get(&key).copied(),
            };
            let id = match existing {
                Some(id) => {
                    let n = self.nodes[id].as_mut().expect("live trie node");
                    n.last_used = now;
                    id
                }
                None => {
                    let node = Node {
                        tokens: key.clone(),
                        page: pages[p],
                        parent: level,
                        children: BTreeMap::new(),
                        last_used: now,
                    };
                    let id = match self.free_slots.pop() {
                        Some(slot) => {
                            self.nodes[slot] = Some(node);
                            slot
                        }
                        None => {
                            self.nodes.push(Some(node));
                            self.nodes.len() - 1
                        }
                    };
                    match level {
                        None => {
                            self.roots.insert(key, id);
                        }
                        Some(pid) => {
                            self.nodes[pid]
                                .as_mut()
                                .expect("live trie node")
                                .children
                                .insert(key, id);
                        }
                    }
                    newly.push(pages[p]);
                    id
                }
            };
            level = Some(id);
        }
        newly
    }

    /// Evict the least-recently-used **leaf**; returns its physical page so
    /// the caller can drop the trie's retention reference. None when the
    /// trie is empty.
    pub fn evict_lru(&mut self) -> Option<usize> {
        self.evict_lru_preferring(|_| true)
    }

    /// Evict the least-recently-used leaf, preferring leaves whose page the
    /// caller marks reclaimable (last reference = the trie's): burning a
    /// shared page's retention frees nothing. Falls back to any leaf so
    /// reclaimable internal pages can still be unlocked by peeling.
    pub fn evict_lru_preferring(
        &mut self,
        reclaimable: impl Fn(usize) -> bool,
    ) -> Option<usize> {
        let pick = |want_reclaimable: bool| {
            self.nodes
                .iter()
                .enumerate()
                .filter_map(|(id, n)| n.as_ref().map(|n| (id, n)))
                .filter(|(_, n)| n.children.is_empty())
                .filter(|(_, n)| !want_reclaimable || reclaimable(n.page))
                .min_by_key(|(id, n)| (n.last_used, *id))
                .map(|(id, _)| id)
        };
        let victim = pick(true).or_else(|| pick(false))?;
        Some(self.remove_node(victim))
    }

    fn remove_node(&mut self, victim: usize) -> usize {
        let node = self.nodes[victim].take().expect("victim is live");
        match node.parent {
            None => {
                self.roots.remove(&node.tokens);
            }
            Some(pid) => {
                self.nodes[pid]
                    .as_mut()
                    .expect("live parent")
                    .children
                    .remove(&node.tokens);
            }
        }
        self.free_slots.push(victim);
        node.page
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn toks(n: usize, offset: i32) -> Vec<i32> {
        (0..n as i32).map(|i| i + offset).collect()
    }

    #[test]
    fn insert_then_lookup_full_pages() {
        let mut t = PrefixTrie::new();
        let prompt = toks(3 * PAGE_TOKENS + 10, 0);
        let newly = t.insert(&prompt, &[7, 8, 9]);
        assert_eq!(newly, vec![7, 8, 9]); // only the 3 full pages
        assert_eq!(t.retained_pages(), 3);
        assert_eq!(t.lookup(&prompt, prompt.len()), vec![7, 8, 9]);
        // limited lookup stops at the full-page boundary under the cap
        assert_eq!(t.lookup(&prompt, 2 * PAGE_TOKENS + 5), vec![7, 8]);
    }

    #[test]
    fn diverging_suffix_shares_common_prefix() {
        let mut t = PrefixTrie::new();
        let a = toks(2 * PAGE_TOKENS, 0);
        let mut b = a.clone();
        b[PAGE_TOKENS] += 1000; // second page differs
        t.insert(&a, &[1, 2]);
        let newly = t.insert(&b, &[3, 4]);
        assert_eq!(newly, vec![4]); // first page deduped against a's
        assert_eq!(t.lookup(&a, a.len()), vec![1, 2]);
        assert_eq!(t.lookup(&b, b.len()), vec![3, 4]);
        assert_eq!(t.retained_pages(), 3);
    }

    #[test]
    fn first_publisher_wins() {
        let mut t = PrefixTrie::new();
        let a = toks(PAGE_TOKENS, 0);
        assert_eq!(t.insert(&a, &[5]), vec![5]);
        assert_eq!(t.insert(&a, &[9]), Vec::<usize>::new());
        assert_eq!(t.lookup(&a, a.len()), vec![5]);
    }

    #[test]
    fn partial_page_never_published() {
        let mut t = PrefixTrie::new();
        let a = toks(PAGE_TOKENS - 1, 0);
        assert_eq!(t.insert(&a, &[1]), Vec::<usize>::new());
        assert!(t.is_empty());
    }

    #[test]
    fn evict_lru_leaf_first() {
        let mut t = PrefixTrie::new();
        let a = toks(2 * PAGE_TOKENS, 0);
        t.insert(&a, &[1, 2]);
        // touch the chain so the leaf (page 2) is newest; eviction still
        // picks a leaf — the only leaf is page 2's node
        t.lookup(&a, a.len());
        assert_eq!(t.evict_lru(), Some(2));
        // now the former parent is a leaf
        assert_eq!(t.evict_lru(), Some(1));
        assert_eq!(t.evict_lru(), None);
        assert!(t.is_empty());
    }

    #[test]
    fn peek_matches_without_touching_lru() {
        let mut t = PrefixTrie::new();
        let a = toks(2 * PAGE_TOKENS, 0);
        let b = toks(PAGE_TOKENS, 5000);
        t.insert(&a, &[1, 2]);
        t.insert(&b, &[3]); // b is now the most recently used
        assert_eq!(t.peek_match_pages(&a, a.len()), 2);
        assert_eq!(t.peek_match_pages(&a, PAGE_TOKENS + 5), 1);
        assert_eq!(t.peek_match_pages(&b, b.len()), 1);
        assert_eq!(t.peek_match_pages(&toks(PAGE_TOKENS, 9000), PAGE_TOKENS), 0);
        // peeking at `a` (older) must NOT refresh it: LRU eviction still
        // removes a's leaf first, then a's root, then b
        assert_eq!(t.evict_lru(), Some(2));
        assert_eq!(t.evict_lru(), Some(1));
        assert_eq!(t.evict_lru(), Some(3));
    }

    #[test]
    fn eviction_unlinks_child_key() {
        let mut t = PrefixTrie::new();
        let a = toks(PAGE_TOKENS, 0);
        t.insert(&a, &[3]);
        assert_eq!(t.evict_lru(), Some(3));
        // re-publishing after eviction works (slot + key fully recycled)
        assert_eq!(t.insert(&a, &[4]), vec![4]);
        assert_eq!(t.lookup(&a, a.len()), vec![4]);
    }
}
