//! A single KV-cache page: 64 tokens of quantized latent content + aligned
//! RoPE + per-token scales.

use crate::fp8::{bf16_decode, bf16_encode, e4m3_decode, e4m3_encode};

/// Tokens per page — equals the kernel's BLOCK_N tile (paper §3.3.2: the
/// 64-token page keeps each atomic load 128-byte aligned on the content dim).
pub const PAGE_TOKENS: usize = 64;

/// One page of cache storage for a single layer.
#[derive(Clone)]
pub struct Page {
    /// u8 E4M3 codes, row-major [PAGE_TOKENS, d_c]
    pub content: Vec<u8>,
    /// u16 bf16 of (rope / sigma), row-major [PAGE_TOKENS, d_r]
    pub rope: Vec<u16>,
    /// f32 per-token content scales [PAGE_TOKENS]
    pub scales: Vec<f32>,
    /// valid tokens in this page (≤ PAGE_TOKENS)
    pub used: usize,
}

impl Page {
    pub fn new(d_c: usize, d_r: usize) -> Page {
        Page {
            content: vec![0; PAGE_TOKENS * d_c],
            rope: vec![0; PAGE_TOKENS * d_r],
            scales: vec![0.0; PAGE_TOKENS],
            used: 0,
        }
    }

    /// Bytes of real storage this page holds.
    pub fn nbytes(d_c: usize, d_r: usize) -> usize {
        PAGE_TOKENS * (d_c + 2 * d_r + 4)
    }

    /// Write one already-quantized token at `slot`.
    pub fn write_token(
        &mut self,
        slot: usize,
        d_c: usize,
        d_r: usize,
        content_codes: &[u8],
        rope_aligned: &[f32],
        scale: f32,
    ) {
        debug_assert!(slot < PAGE_TOKENS);
        debug_assert_eq!(content_codes.len(), d_c);
        debug_assert_eq!(rope_aligned.len(), d_r);
        self.content[slot * d_c..(slot + 1) * d_c].copy_from_slice(content_codes);
        for (o, &x) in self.rope[slot * d_r..(slot + 1) * d_r].iter_mut().zip(rope_aligned) {
            *o = bf16_encode(x);
        }
        self.scales[slot] = scale;
        self.used = self.used.max(slot + 1);
    }

    /// Quantize + write one raw token (the in-page half of Fused-K-Append).
    pub fn append_raw(&mut self, slot: usize, d_c: usize, d_r: usize, c_kv: &[f32], k_r: &[f32]) {
        let scale = crate::fp8::per_token_scale(c_kv);
        let codes: Vec<u8> = c_kv.iter().map(|&x| e4m3_encode(x / scale)).collect();
        // Key Step 1: align RoPE into the content-scale domain at bf16
        let aligned: Vec<f32> =
            k_r.iter().map(|&x| bf16_decode(bf16_encode(x)) / scale).collect();
        self.write_token(slot, d_c, d_r, &codes, &aligned, scale);
    }

    /// Erase token `slot` back to the fresh-page state (speculative
    /// rollback); the caller re-derives `used`.
    pub fn clear_token(&mut self, slot: usize, d_c: usize, d_r: usize) {
        self.content[slot * d_c..(slot + 1) * d_c].fill(0);
        self.rope[slot * d_r..(slot + 1) * d_r].fill(0);
        self.scales[slot] = 0.0;
    }

    /// Dequantize token `slot` into caller buffers (Fused-Fetch-Dequant).
    pub fn fetch_dequant(
        &self,
        slot: usize,
        d_c: usize,
        d_r: usize,
        content_out: &mut [f32],
        rope_out: &mut [f32],
    ) {
        let s = self.scales[slot];
        for (o, &b) in content_out.iter_mut().zip(&self.content[slot * d_c..(slot + 1) * d_c]) {
            *o = e4m3_decode(b) * s;
        }
        for (o, &b) in rope_out.iter_mut().zip(&self.rope[slot * d_r..(slot + 1) * d_r]) {
            *o = bf16_decode(b) * s;
        }
    }

    /// Read the *kernel view* of token `slot`: (content on E4M3 grid,
    /// rope/sigma, sigma) — what the SnapMLA kernel consumes directly.
    pub fn kernel_view(
        &self,
        slot: usize,
        d_c: usize,
        d_r: usize,
        content_out: &mut [f32],
        rope_out: &mut [f32],
    ) -> f32 {
        for (o, &b) in content_out.iter_mut().zip(&self.content[slot * d_c..(slot + 1) * d_c]) {
            *o = e4m3_decode(b);
        }
        for (o, &b) in rope_out.iter_mut().zip(&self.rope[slot * d_r..(slot + 1) * d_r]) {
            *o = bf16_decode(b);
        }
        self.scales[slot]
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    #[test]
    fn append_and_fetch_roundtrip() {
        let (d_c, d_r) = (32, 8);
        let mut page = Page::new(d_c, d_r);
        let mut rng = Rng::new(1);
        let c: Vec<f32> = rng.normal_vec(d_c, 3.0);
        let r: Vec<f32> = rng.normal_vec(d_r, 100.0);
        page.append_raw(5, d_c, d_r, &c, &r);
        assert_eq!(page.used, 6);

        let mut c_out = vec![0.0; d_c];
        let mut r_out = vec![0.0; d_r];
        page.fetch_dequant(5, d_c, d_r, &mut c_out, &mut r_out);
        let amax = c.iter().fold(0.0f32, |m, &x| m.max(x.abs()));
        for (x, y) in c.iter().zip(&c_out) {
            assert!((x - y).abs() <= amax * 0.0625 + 1e-6);
        }
        // rope restores to bf16 accuracy (sigma cancels exactly)
        for (x, y) in r.iter().zip(&r_out) {
            assert!(((x - y) / x).abs() <= 0.01, "{x} {y}");
        }
    }

    #[test]
    fn kernel_view_matches_grid() {
        let (d_c, d_r) = (16, 4);
        let mut page = Page::new(d_c, d_r);
        let c: Vec<f32> = (0..16).map(|i| i as f32 - 8.0).collect();
        let r = vec![7.0f32; 4];
        page.append_raw(0, d_c, d_r, &c, &r);
        let mut cq = vec![0.0; d_c];
        let mut rq = vec![0.0; d_r];
        let sigma = page.kernel_view(0, d_c, d_r, &mut cq, &mut rq);
        // reconstruct: cq * sigma ≈ c
        for (x, y) in c.iter().zip(&cq) {
            assert!((x - y * sigma).abs() <= 8.0 * 0.0625 + 1e-6);
        }
        // rq * sigma ≈ bf16(r)
        for y in &rq {
            assert!((y * sigma - 7.0).abs() < 0.05);
        }
    }

    #[test]
    fn memory_footprint() {
        // d_c=128 content + d_r=32 rope: u8+scales vs f32 baseline
        let nbytes = Page::nbytes(128, 32);
        let f32_bytes = PAGE_TOKENS * (128 + 32) * 4;
        assert!(nbytes * 2 < f32_bytes, "paged FP8 must halve f32 storage");
        assert_eq!(nbytes, 64 * (128 + 64 + 4));
    }

    #[test]
    fn partial_page_tracks_used() {
        let mut page = Page::new(8, 4);
        assert_eq!(page.used, 0);
        page.append_raw(0, 8, 4, &[1.0; 8], &[1.0; 4]);
        page.append_raw(1, 8, 4, &[1.0; 8], &[1.0; 4]);
        assert_eq!(page.used, 2);
    }
}
