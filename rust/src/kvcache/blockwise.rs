//! Block-granularity KV quantization with page-tail buffering — the
//! *rejected* alternative of §3.1.1, implemented for the granularity
//! ablation (`benches/ablation_granularity.rs`).
//!
//! FA3-style block-wise quantization needs a full 64-token block before it
//! can quantize. During decoding, the newest tokens therefore sit in a raw
//! f32 "tail buffer" until the block fills; every decode step over those
//! tokens either (a) reads mixed-precision inputs (complex kernels) or
//! (b) requantizes the partial block each step (wasted work). We model (b)
//! and count the overheads the paper's per-token design eliminates.

use super::page::PAGE_TOKENS;
use crate::fp8::{e4m3_encode, per_token_scale, E4M3_MAX, SCALE_EPS};

/// One sequence's block-granular content cache with a raw tail buffer.
pub struct BlockwiseSeqCache {
    d_c: usize,
    /// completed blocks: codes + one scale per block
    blocks: Vec<(Vec<u8>, f32)>,
    /// raw f32 tail (< PAGE_TOKENS tokens)
    tail: Vec<f32>,
    tail_tokens: usize,
    // ---- ablation counters -------------------------------------------------
    /// tokens requantized due to partial-block re-processing
    pub requant_tokens: u64,
    /// peak bytes held in raw f32 tail buffers
    pub peak_tail_bytes: usize,
    /// quantization kernel launches (per-block flushes + per-step re-quants)
    pub quant_launches: u64,
}

impl BlockwiseSeqCache {
    pub fn new(d_c: usize) -> Self {
        BlockwiseSeqCache {
            d_c,
            blocks: Vec::new(),
            tail: Vec::with_capacity(PAGE_TOKENS * d_c),
            tail_tokens: 0,
            requant_tokens: 0,
            peak_tail_bytes: 0,
            quant_launches: 0,
        }
    }

    pub fn tokens(&self) -> usize {
        self.blocks.len() * PAGE_TOKENS + self.tail_tokens
    }

    /// Append one token; flush the tail into a quantized block when full.
    pub fn append(&mut self, c_kv: &[f32]) {
        assert_eq!(c_kv.len(), self.d_c);
        self.tail.extend_from_slice(c_kv);
        self.tail_tokens += 1;
        self.peak_tail_bytes = self.peak_tail_bytes.max(self.tail.len() * 4);
        if self.tail_tokens == PAGE_TOKENS {
            // block-wise quantization: one scale for the whole 64-token block
            let scale = per_token_scale(&self.tail); // max/448 over the block
            let codes = self.tail.iter().map(|&x| e4m3_encode(x / scale)).collect();
            self.blocks.push((codes, scale));
            self.quant_launches += 1;
            self.tail.clear();
            self.tail_tokens = 0;
        }
    }

    /// Produce the decode-step view: quantized blocks as-is plus an on-the-fly
    /// requantization of the partial tail (the per-step overhead per-token
    /// granularity avoids). Returns (values, per-block scales incl. tail).
    pub fn decode_view(&mut self) -> (Vec<f32>, Vec<f32>) {
        let mut values = Vec::with_capacity(self.tokens() * self.d_c);
        let mut scales = Vec::new();
        for (codes, scale) in &self.blocks {
            values.extend(codes.iter().map(|&b| crate::fp8::e4m3_decode(b)));
            scales.push(*scale);
        }
        if self.tail_tokens > 0 {
            // requantize the partial block THIS step (and again next step…)
            let amax = self.tail.iter().fold(0.0f32, |m, &x| m.max(x.abs()));
            let scale = (amax / E4M3_MAX).max(SCALE_EPS);
            values.extend(self.tail.iter().map(|&x| {
                crate::fp8::e4m3_decode(e4m3_encode(x / scale))
            }));
            scales.push(scale);
            self.requant_tokens += self.tail_tokens as u64;
            self.quant_launches += 1;
        }
        (values, scales)
    }
}

/// Per-token comparator with the same interface (the SnapMLA design): appends
/// quantize instantly; decode views are free.
pub struct PerTokenSeqCache {
    d_c: usize,
    codes: Vec<u8>,
    scales: Vec<f32>,
    pub quant_launches: u64,
}

impl PerTokenSeqCache {
    pub fn new(d_c: usize) -> Self {
        PerTokenSeqCache { d_c, codes: Vec::new(), scales: Vec::new(), quant_launches: 0 }
    }

    pub fn tokens(&self) -> usize {
        self.scales.len()
    }

    pub fn append(&mut self, c_kv: &[f32]) {
        assert_eq!(c_kv.len(), self.d_c);
        let scale = per_token_scale(c_kv);
        self.codes.extend(c_kv.iter().map(|&x| e4m3_encode(x / scale)));
        self.scales.push(scale);
        self.quant_launches += 1; // fused into K-append: one launch per step
    }

    pub fn decode_view(&self) -> (Vec<f32>, Vec<f32>) {
        (
            self.codes.iter().map(|&b| crate::fp8::e4m3_decode(b)).collect(),
            self.scales.clone(),
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    #[test]
    fn blockwise_buffers_tail_then_flushes() {
        let mut c = BlockwiseSeqCache::new(8);
        let mut rng = Rng::new(1);
        for _ in 0..63 {
            c.append(&rng.normal_vec(8, 1.0));
        }
        assert_eq!(c.blocks.len(), 0);
        assert_eq!(c.tail_tokens, 63);
        c.append(&rng.normal_vec(8, 1.0));
        assert_eq!(c.blocks.len(), 1);
        assert_eq!(c.tail_tokens, 0);
        assert_eq!(c.tokens(), 64);
    }

    #[test]
    fn decode_view_requantizes_tail_every_step() {
        let mut c = BlockwiseSeqCache::new(8);
        let mut rng = Rng::new(2);
        let mut total_requant = 0;
        // simulate 100 decode steps
        for _ in 0..100 {
            c.append(&rng.normal_vec(8, 1.0));
            let (v, s) = c.decode_view();
            assert_eq!(v.len(), c.tokens() * 8);
            assert!(!s.is_empty());
            total_requant = c.requant_tokens;
        }
        // tail requant work is quadratic-ish within each block: for 100 steps
        // (one full block + 36 tail) the wasted tokens are large
        assert!(total_requant > 1000, "{total_requant}");
        assert!(c.peak_tail_bytes > 0);
    }

    #[test]
    fn per_token_has_no_requant_overhead() {
        let mut c = PerTokenSeqCache::new(8);
        let mut rng = Rng::new(3);
        for _ in 0..100 {
            c.append(&rng.normal_vec(8, 1.0));
            let (v, s) = c.decode_view();
            assert_eq!(v.len(), c.tokens() * 8);
            assert_eq!(s.len(), c.tokens());
        }
        assert_eq!(c.quant_launches, 100); // exactly one per append, none extra
    }

    #[test]
    fn blockwise_scale_is_shared_per_block() {
        let mut c = BlockwiseSeqCache::new(4);
        // one outlier token dominates the whole block's scale
        for i in 0..64 {
            let v = if i == 0 { vec![400.0; 4] } else { vec![0.5; 4] };
            c.append(&v);
        }
        let (_, scales) = c.decode_view();
        assert_eq!(scales.len(), 1);
        assert!((scales[0] - 400.0 / 448.0).abs() < 1e-6);
    }
}
