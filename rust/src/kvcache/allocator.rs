//! Page allocator: fixed page pool with a free list, per-sequence page maps,
//! and capacity accounting (the KV-memory budget drives Fig. 1's max batch
//! size per context length).

use std::collections::BTreeMap;

/// Allocates page slots from a bounded pool.
#[derive(Debug)]
pub struct PageAllocator {
    capacity: usize,
    free: Vec<usize>,
    /// seq id → allocated page indices, in sequence order
    maps: BTreeMap<u64, Vec<usize>>,
}

#[derive(Debug, PartialEq, Eq)]
pub enum AllocError {
    OutOfPages,
    UnknownSequence,
}

impl PageAllocator {
    pub fn new(capacity: usize) -> Self {
        PageAllocator {
            capacity,
            free: (0..capacity).rev().collect(),
            maps: BTreeMap::new(),
        }
    }

    pub fn capacity(&self) -> usize {
        self.capacity
    }

    pub fn free_pages(&self) -> usize {
        self.free.len()
    }

    pub fn used_pages(&self) -> usize {
        self.capacity - self.free.len()
    }

    /// Register a sequence (idempotent).
    pub fn register(&mut self, seq: u64) {
        self.maps.entry(seq).or_default();
    }

    /// The page table of a sequence.
    pub fn pages_of(&self, seq: u64) -> Option<&[usize]> {
        self.maps.get(&seq).map(|v| v.as_slice())
    }

    /// Grow a sequence by one page; returns the new page index.
    pub fn grow(&mut self, seq: u64) -> Result<usize, AllocError> {
        let map = self.maps.get_mut(&seq).ok_or(AllocError::UnknownSequence)?;
        let page = self.free.pop().ok_or(AllocError::OutOfPages)?;
        map.push(page);
        Ok(page)
    }

    /// Pages needed to hold `tokens` tokens.
    pub fn pages_for(tokens: usize) -> usize {
        tokens.div_ceil(super::PAGE_TOKENS)
    }

    /// Can `tokens` more tokens be appended to `seq` without exhausting the
    /// pool? (admission control / backpressure input)
    pub fn can_grow(&self, seq: u64, current_tokens: usize, extra: usize) -> bool {
        let have = self.maps.get(&seq).map(|m| m.len()).unwrap_or(0);
        let need = Self::pages_for(current_tokens + extra);
        need.saturating_sub(have) <= self.free.len()
    }

    /// Release a sequence's pages back to the pool.
    pub fn release(&mut self, seq: u64) -> usize {
        if let Some(pages) = self.maps.remove(&seq) {
            let n = pages.len();
            self.free.extend(pages);
            n
        } else {
            0
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn grow_and_release() {
        let mut a = PageAllocator::new(4);
        a.register(1);
        a.register(2);
        assert_eq!(a.grow(1).unwrap(), 0); // free list hands out 0,1,2,…
        assert_eq!(a.grow(1).unwrap(), 1);
        assert_eq!(a.grow(2).unwrap(), 2);
        assert_eq!(a.used_pages(), 3);
        assert_eq!(a.pages_of(1).unwrap(), &[0, 1]);
        assert_eq!(a.release(1), 2);
        assert_eq!(a.free_pages(), 3);
        assert_eq!(a.pages_of(1), None);
    }

    #[test]
    fn exhaustion() {
        let mut a = PageAllocator::new(2);
        a.register(1);
        a.grow(1).unwrap();
        a.grow(1).unwrap();
        assert_eq!(a.grow(1), Err(AllocError::OutOfPages));
    }

    #[test]
    fn unknown_sequence() {
        let mut a = PageAllocator::new(2);
        assert_eq!(a.grow(42), Err(AllocError::UnknownSequence));
    }

    #[test]
    fn can_grow_accounting() {
        let mut a = PageAllocator::new(3);
        a.register(1);
        // 64 tokens → 1 page
        assert!(a.can_grow(1, 0, 64));
        // 200 tokens → 4 pages > capacity
        assert!(!a.can_grow(1, 0, 200));
        a.grow(1).unwrap();
        // with 1 page held and 60 tokens used, +4 tokens fits the same page
        assert!(a.can_grow(1, 60, 4));
        // +5 tokens needs a second page; 2 free → ok
        assert!(a.can_grow(1, 60, 5));
    }

    #[test]
    fn pages_for_boundaries() {
        assert_eq!(PageAllocator::pages_for(0), 0);
        assert_eq!(PageAllocator::pages_for(1), 1);
        assert_eq!(PageAllocator::pages_for(64), 1);
        assert_eq!(PageAllocator::pages_for(65), 2);
    }

    #[test]
    fn release_returns_pages_for_reuse() {
        let mut a = PageAllocator::new(2);
        a.register(1);
        a.grow(1).unwrap();
        a.grow(1).unwrap();
        a.release(1);
        a.register(2);
        assert!(a.grow(2).is_ok());
        assert!(a.grow(2).is_ok());
    }
}
