//! Page allocator: fixed page pool with a free list, per-sequence page maps,
//! **per-page reference counts** (prefix-sharing KV reuse), and capacity
//! accounting (the KV-memory budget drives Fig. 1's max batch size per
//! context length).
//!
//! A physical page may be referenced by several sequences at once (shared
//! prompt-prefix pages) plus the prefix trie's retention reference; it
//! returns to the free list only when the last reference drops.

use std::collections::BTreeMap;

/// Allocates page slots from a bounded pool.
#[derive(Debug)]
pub struct PageAllocator {
    capacity: usize,
    free: Vec<usize>,
    /// per-physical-page reference count (0 = on the free list)
    rc: Vec<u32>,
    /// seq id → allocated page indices, in sequence order
    maps: BTreeMap<u64, Vec<usize>>,
    /// pages under trie retention (the cache marks them via `track`) —
    /// membership plus the rc==1 tally below give O(1) evictable accounting
    tracked: Vec<bool>,
    /// tracked pages whose only remaining reference is the tracker's
    /// (rc == 1): exactly the evictable-page count, maintained at every
    /// rc transition instead of swept from the trie
    tracked_rc1: usize,
}

#[derive(Debug, PartialEq, Eq)]
pub enum AllocError {
    OutOfPages,
    UnknownSequence,
    /// the referenced physical page is on the free list (stale share/retain)
    PageNotLive,
}

impl PageAllocator {
    pub fn new(capacity: usize) -> Self {
        PageAllocator {
            capacity,
            free: (0..capacity).rev().collect(),
            rc: vec![0; capacity],
            maps: BTreeMap::new(),
            tracked: vec![false; capacity],
            tracked_rc1: 0,
        }
    }

    /// Mark `page` as retention-tracked (idempotent). The caller must hold
    /// a reference on it already (trie retention ⇒ rc ≥ 1).
    pub fn track(&mut self, page: usize) {
        if !self.tracked[page] {
            debug_assert!(self.rc[page] > 0, "tracking a free page");
            self.tracked[page] = true;
            if self.rc[page] == 1 {
                self.tracked_rc1 += 1;
            }
        }
    }

    /// Stop tracking `page` (idempotent) — call BEFORE dropping the
    /// tracker's own reference.
    pub fn untrack(&mut self, page: usize) {
        if self.tracked[page] {
            self.tracked[page] = false;
            if self.rc[page] == 1 {
                self.tracked_rc1 -= 1;
            }
        }
    }

    /// Tracked pages whose only reference is the tracker's — maintained
    /// incrementally at every rc transition, O(1) to read.
    pub fn tracked_evictable(&self) -> usize {
        self.tracked_rc1
    }

    /// rc is about to move from `old` on `page`; fold the transition into
    /// the tracked-rc1 tally. Every rc mutation funnels through here.
    fn note_rc_change(&mut self, page: usize, old: u32, new: u32) {
        if self.tracked[page] {
            if old == 1 && new != 1 {
                self.tracked_rc1 -= 1;
            } else if old != 1 && new == 1 {
                self.tracked_rc1 += 1;
            }
            debug_assert!(new > 0, "a tracked page must be untracked before its last release");
        }
    }

    pub fn capacity(&self) -> usize {
        self.capacity
    }

    pub fn free_pages(&self) -> usize {
        self.free.len()
    }

    pub fn used_pages(&self) -> usize {
        self.capacity - self.free.len()
    }

    /// Reference count of a physical page (0 = free).
    pub fn ref_count(&self, page: usize) -> u32 {
        self.rc[page]
    }

    /// Register a sequence (idempotent).
    pub fn register(&mut self, seq: u64) {
        self.maps.entry(seq).or_default();
    }

    /// The page table of a sequence.
    pub fn pages_of(&self, seq: u64) -> Option<&[usize]> {
        self.maps.get(&seq).map(|v| v.as_slice())
    }

    /// Grow a sequence by one freshly-allocated page (rc = 1); returns the
    /// new page index.
    pub fn grow(&mut self, seq: u64) -> Result<usize, AllocError> {
        let map = self.maps.get_mut(&seq).ok_or(AllocError::UnknownSequence)?;
        let page = self.free.pop().ok_or(AllocError::OutOfPages)?;
        debug_assert!(!self.tracked[page], "free pages are never tracked");
        self.rc[page] = 1;
        map.push(page);
        Ok(page)
    }

    /// Allocate a page that is not attached to any sequence map (rc = 1) —
    /// the copy-on-write staging slot.
    pub fn alloc_unmapped(&mut self) -> Result<usize, AllocError> {
        let page = self.free.pop().ok_or(AllocError::OutOfPages)?;
        debug_assert!(!self.tracked[page], "free pages are never tracked");
        self.rc[page] = 1;
        Ok(page)
    }

    /// Append an existing live page to `seq`'s table (prefix sharing):
    /// increments the page's reference count.
    pub fn share(&mut self, seq: u64, page: usize) -> Result<(), AllocError> {
        if self.rc[page] == 0 {
            return Err(AllocError::PageNotLive);
        }
        let map = self.maps.get_mut(&seq).ok_or(AllocError::UnknownSequence)?;
        let old = self.rc[page];
        self.rc[page] += 1;
        self.note_rc_change(page, old, old + 1);
        map.push(page);
        Ok(())
    }

    /// Take an extra reference on a live page without attaching it to a
    /// sequence (the prefix trie's retention reference).
    pub fn retain(&mut self, page: usize) -> Result<(), AllocError> {
        if self.rc[page] == 0 {
            return Err(AllocError::PageNotLive);
        }
        let old = self.rc[page];
        self.rc[page] += 1;
        self.note_rc_change(page, old, old + 1);
        Ok(())
    }

    /// Drop one reference on a live page; returns true when this was the
    /// last reference and the page went back to the free list.
    pub fn release_page(&mut self, page: usize) -> Result<bool, AllocError> {
        if self.rc[page] == 0 {
            return Err(AllocError::PageNotLive);
        }
        let old = self.rc[page];
        self.rc[page] -= 1;
        self.note_rc_change(page, old, old - 1);
        if self.rc[page] == 0 {
            self.free.push(page);
            return Ok(true);
        }
        Ok(false)
    }

    /// Replace slot `idx` of `seq`'s table with `new_page` (already
    /// allocated via [`Self::alloc_unmapped`]); drops the old page's reference and
    /// returns `Some(old)` when the old page was freed by this.
    pub fn replace(
        &mut self,
        seq: u64,
        idx: usize,
        new_page: usize,
    ) -> Result<Option<usize>, AllocError> {
        let map = self.maps.get_mut(&seq).ok_or(AllocError::UnknownSequence)?;
        let old = map[idx];
        map[idx] = new_page;
        Ok(if self.release_page(old)? { Some(old) } else { None })
    }

    /// Truncate `seq`'s table to its first `keep` slots (speculative
    /// rollback), dropping one reference on each removed page. Removal runs
    /// tail-first so pages return to the free list in exact reverse
    /// allocation order — a rolled-back run leaves the free list identical
    /// to one that never grew. Returns the pages actually freed (rc hit 0).
    pub fn truncate(&mut self, seq: u64, keep: usize) -> Result<Vec<usize>, AllocError> {
        let map = self.maps.get_mut(&seq).ok_or(AllocError::UnknownSequence)?;
        if keep >= map.len() {
            return Ok(Vec::new());
        }
        let tail = map.split_off(keep);
        let mut freed = Vec::new();
        for p in tail.into_iter().rev() {
            if self.release_page(p).expect("mapped page must be live") {
                freed.push(p);
            }
        }
        Ok(freed)
    }

    /// Pages needed to hold `tokens` tokens.
    pub fn pages_for(tokens: usize) -> usize {
        tokens.div_ceil(super::PAGE_TOKENS)
    }

    /// Can `tokens` more tokens be appended to `seq` without exhausting the
    /// pool? (admission control / backpressure input)
    pub fn can_grow(&self, seq: u64, current_tokens: usize, extra: usize) -> bool {
        let have = self.maps.get(&seq).map(|m| m.len()).unwrap_or(0);
        let need = Self::pages_for(current_tokens + extra);
        need.saturating_sub(have) <= self.free.len()
    }

    /// Release a sequence's references; returns the pages actually freed
    /// (rc reached 0) so the owner can drop their storage.
    pub fn release(&mut self, seq: u64) -> Vec<usize> {
        let mut freed = Vec::new();
        if let Some(pages) = self.maps.remove(&seq) {
            for p in pages {
                if self.release_page(p).expect("mapped page must be live") {
                    freed.push(p);
                }
            }
        }
        freed
    }

    /// Structural consistency check (used by the property suite): per-page
    /// reference counts must equal the number of map slots referencing the
    /// page plus the caller-supplied external references, and the free list
    /// must hold exactly the rc==0 pages, each once.
    pub fn validate(&self, external_refs: &[usize]) -> Result<(), String> {
        let mut want = vec![0u32; self.capacity];
        for pages in self.maps.values() {
            for &p in pages {
                want[p] += 1;
            }
        }
        for &p in external_refs {
            want[p] += 1;
        }
        for p in 0..self.capacity {
            if self.rc[p] != want[p] {
                return Err(format!("page {p}: rc {} != referenced {}", self.rc[p], want[p]));
            }
        }
        let mut on_free = vec![false; self.capacity];
        for &p in &self.free {
            if on_free[p] {
                return Err(format!("page {p} on free list twice"));
            }
            on_free[p] = true;
        }
        for p in 0..self.capacity {
            if on_free[p] != (self.rc[p] == 0) {
                return Err(format!("page {p}: free-list {} but rc {}", on_free[p], self.rc[p]));
            }
        }
        let swept = (0..self.capacity).filter(|&p| self.tracked[p] && self.rc[p] == 1).count();
        if swept != self.tracked_rc1 {
            return Err(format!("tracked rc==1 sweep {swept} != incremental {}", self.tracked_rc1));
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn grow_and_release() {
        let mut a = PageAllocator::new(4);
        a.register(1);
        a.register(2);
        assert_eq!(a.grow(1).unwrap(), 0); // free list hands out 0,1,2,…
        assert_eq!(a.grow(1).unwrap(), 1);
        assert_eq!(a.grow(2).unwrap(), 2);
        assert_eq!(a.used_pages(), 3);
        assert_eq!(a.pages_of(1).unwrap(), &[0, 1]);
        assert_eq!(a.release(1), vec![0, 1]);
        assert_eq!(a.free_pages(), 3);
        assert_eq!(a.pages_of(1), None);
        a.validate(&[]).unwrap();
    }

    #[test]
    fn exhaustion() {
        let mut a = PageAllocator::new(2);
        a.register(1);
        a.grow(1).unwrap();
        a.grow(1).unwrap();
        assert_eq!(a.grow(1), Err(AllocError::OutOfPages));
    }

    #[test]
    fn unknown_sequence() {
        let mut a = PageAllocator::new(2);
        assert_eq!(a.grow(42), Err(AllocError::UnknownSequence));
    }

    #[test]
    fn can_grow_accounting() {
        let mut a = PageAllocator::new(3);
        a.register(1);
        // 64 tokens → 1 page
        assert!(a.can_grow(1, 0, 64));
        // 200 tokens → 4 pages > capacity
        assert!(!a.can_grow(1, 0, 200));
        a.grow(1).unwrap();
        // with 1 page held and 60 tokens used, +4 tokens fits the same page
        assert!(a.can_grow(1, 60, 4));
        // +5 tokens needs a second page; 2 free → ok
        assert!(a.can_grow(1, 60, 5));
    }

    #[test]
    fn pages_for_boundaries() {
        assert_eq!(PageAllocator::pages_for(0), 0);
        assert_eq!(PageAllocator::pages_for(1), 1);
        assert_eq!(PageAllocator::pages_for(64), 1);
        assert_eq!(PageAllocator::pages_for(65), 2);
    }

    #[test]
    fn release_returns_pages_for_reuse() {
        let mut a = PageAllocator::new(2);
        a.register(1);
        a.grow(1).unwrap();
        a.grow(1).unwrap();
        a.release(1);
        a.register(2);
        assert!(a.grow(2).is_ok());
        assert!(a.grow(2).is_ok());
    }

    #[test]
    fn shared_page_survives_one_release() {
        let mut a = PageAllocator::new(2);
        a.register(1);
        a.register(2);
        let p = a.grow(1).unwrap();
        a.share(2, p).unwrap();
        assert_eq!(a.ref_count(p), 2);
        assert_eq!(a.release(1), Vec::<usize>::new()); // still referenced by 2
        assert_eq!(a.used_pages(), 1);
        assert_eq!(a.release(2), vec![p]);
        assert_eq!(a.free_pages(), 2);
        a.validate(&[]).unwrap();
    }

    #[test]
    fn retain_keeps_page_live_after_owner_exits() {
        let mut a = PageAllocator::new(2);
        a.register(1);
        let p = a.grow(1).unwrap();
        a.retain(p).unwrap(); // trie reference
        assert_eq!(a.release(1), Vec::<usize>::new());
        assert_eq!(a.ref_count(p), 1);
        a.validate(&[p]).unwrap();
        assert!(a.release_page(p).unwrap()); // trie eviction frees it
        assert_eq!(a.free_pages(), 2);
    }

    #[test]
    fn share_and_retain_reject_free_pages() {
        let mut a = PageAllocator::new(2);
        a.register(1);
        assert_eq!(a.share(1, 0), Err(AllocError::PageNotLive));
        assert_eq!(a.retain(0), Err(AllocError::PageNotLive));
        assert_eq!(a.release_page(0), Err(AllocError::PageNotLive));
    }

    #[test]
    fn tracked_evictable_follows_rc_transitions() {
        let mut a = PageAllocator::new(4);
        a.register(1);
        a.register(2);
        let p = a.grow(1).unwrap();
        a.retain(p).unwrap(); // trie retention, rc 2
        a.track(p);
        assert_eq!(a.tracked_evictable(), 0, "live owner blocks eviction");
        a.release(1); // rc 2 → 1: only the trie reference remains
        assert_eq!(a.tracked_evictable(), 1);
        a.share(2, p).unwrap(); // rc 1 → 2: adopted again
        assert_eq!(a.tracked_evictable(), 0);
        a.release(2); // rc → 1
        assert_eq!(a.tracked_evictable(), 1);
        a.untrack(p); // trie eviction untracks, then drops its reference
        assert_eq!(a.tracked_evictable(), 0);
        assert!(a.release_page(p).unwrap());
        a.validate(&[]).unwrap();
    }

    #[test]
    fn replace_swaps_table_slot() {
        let mut a = PageAllocator::new(3);
        a.register(1);
        let p0 = a.grow(1).unwrap();
        let fresh = a.alloc_unmapped().unwrap();
        assert_eq!(a.replace(1, 0, fresh).unwrap(), Some(p0));
        assert_eq!(a.pages_of(1).unwrap(), &[fresh]);
        a.validate(&[]).unwrap();
    }
}
