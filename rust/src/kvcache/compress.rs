//! Rank-reduced cold-page codec (tiered KV cache, compression tier).
//!
//! Pages untouched for long enough re-encode into a latent format of rank
//! `r < d_c`: an orthonormal basis is fit to the page's own token rows
//! (modified Gram-Schmidt over the rows, deterministic seeded completion
//! when the rows are degenerate), each token keeps only its `r` projection
//! coefficients as E4M3 codes behind a fresh per-token scale, and the
//! decoupled RoPE half stays untouched at bf16 — position information is
//! exact, only the content latent is approximated.
//!
//! Per-layer cold bytes: `r·PAGE_TOKENS` coefficient codes +
//! `r·d_c·4` basis + `PAGE_TOKENS·4` scales + `2·d_r·PAGE_TOKENS` RoPE,
//! vs the hot page's `d_c·PAGE_TOKENS + 2·d_r·PAGE_TOKENS +
//! 4·PAGE_TOKENS`. At (d_c=512, r=192) the content payload shrinks ~2.6x;
//! [`cold_ratio`] is the bytes-per-token ratio the scheduler and the
//! simulate layer price capacity with.
//!
//! The codec is lossy by design. [`rel_l2_bound`] is the fidelity budget
//! the `mla::fidelity` gate enforces on decode-realistic stimuli: the
//! worst-case relative l2 of projecting onto an r-dimensional subspace
//! fit from the data, plus quantization headroom.

use super::page::{Page, PAGE_TOKENS};
use crate::fp8::{e4m3_decode, e4m3_encode, per_token_scale};

/// A cold (compressed) page of one layer: rank-`r` coefficients + basis
/// instead of full-width content codes. RoPE rides along untouched.
#[derive(Clone)]
pub struct ColdPage {
    /// reduction rank r < d_c
    pub rank: usize,
    /// orthonormal basis, row-major [rank, d_c] f32
    pub basis: Vec<f32>,
    /// E4M3 codes of the per-token coefficients, row-major [PAGE_TOKENS, rank]
    pub codes: Vec<u8>,
    /// f32 per-token coefficient scales [PAGE_TOKENS]
    pub scales: Vec<f32>,
    /// u16 bf16 aligned RoPE, copied verbatim from the hot page
    pub rope: Vec<u16>,
    /// per-token sigma of the SOURCE hot page — reconstruction returns to
    /// the same scale domain the kernels expect [PAGE_TOKENS]
    pub src_scales: Vec<f32>,
    /// valid tokens (≤ PAGE_TOKENS)
    pub used: usize,
}

/// Bytes-per-token ratio of a cold page vs a hot FP8 page (content codes +
/// rope + scale), ignoring the amortized per-page basis. This is the
/// `comp_ratio` the scheduler's `TieredConfig` prices resident capacity
/// with — keep the two derivations in sync.
pub fn cold_ratio(rank: usize, d_c: usize, d_r: usize) -> f64 {
    (rank as f64 + 2.0 * d_r as f64 + 4.0) / (d_c as f64 + 2.0 * d_r as f64 + 4.0)
}

/// Fidelity budget for the cold tier: the guaranteed-achievable relative
/// l2 of a rank-`r` projection on decode-realistic (decaying-spectrum)
/// stimuli, plus E4M3 re-quantization headroom. `mla::fidelity` gates the
/// codec against this; the property suite holds every random page under it.
pub fn rel_l2_bound(rank: usize, d_c: usize) -> f64 {
    (1.0 - rank as f64 / d_c as f64).sqrt() + 0.15
}

impl ColdPage {
    /// Bytes of real storage this cold page holds (codes + scales + rope +
    /// basis + source sigmas).
    pub fn nbytes(&self, d_r: usize) -> usize {
        self.codes.len()
            + self.scales.len() * 4
            + PAGE_TOKENS * d_r * 2
            + self.basis.len() * 4
            + self.src_scales.len() * 4
    }

    /// Compress one hot FP8 page. The basis is fit from the page's own
    /// dequantized token rows; `seed` keeps degenerate-row completion
    /// deterministic across runs (pass the physical page id).
    pub fn encode(page: &Page, d_c: usize, d_r: usize, rank: usize, seed: u64) -> ColdPage {
        assert!(rank >= 1 && rank < d_c, "cold rank must satisfy 1 <= r < d_c (got {rank})");
        let used = page.used;
        // dequantize the live rows back to f32 (scale domain removed; the
        // source sigmas are kept so reconstruction can restore it)
        let mut rows = vec![0.0f32; used * d_c];
        for t in 0..used {
            let s = page.scales[t];
            for i in 0..d_c {
                rows[t * d_c + i] = e4m3_decode(page.content[t * d_c + i]) * s;
            }
        }
        let basis = fit_basis(&rows, used, d_c, rank, seed);
        let mut codes = vec![0u8; PAGE_TOKENS * rank];
        let mut scales = vec![0.0f32; PAGE_TOKENS];
        let mut coeff = vec![0.0f32; rank];
        for t in 0..used {
            let row = &rows[t * d_c..(t + 1) * d_c];
            for (k, c) in coeff.iter_mut().enumerate() {
                *c = dot(row, &basis[k * d_c..(k + 1) * d_c]);
            }
            let s = per_token_scale(&coeff);
            scales[t] = s;
            for (k, &c) in coeff.iter().enumerate() {
                codes[t * rank + k] = e4m3_encode(c / s);
            }
        }
        ColdPage {
            rank,
            basis,
            codes,
            scales,
            rope: page.rope.clone(),
            src_scales: page.scales.clone(),
            used,
        }
    }

    /// Reconstruct token `slot`'s content row into `out` ([d_c] f32, full
    /// scale domain — directly comparable to `Page::fetch_dequant` output).
    pub fn decode_token(&self, slot: usize, d_c: usize, out: &mut [f32]) {
        debug_assert!(slot < self.used, "decoding a slot past the cold page's live rows");
        out[..d_c].fill(0.0);
        let s = self.scales[slot];
        for k in 0..self.rank {
            let c = e4m3_decode(self.codes[slot * self.rank + k]) * s;
            if c == 0.0 {
                continue;
            }
            for (o, &b) in out[..d_c].iter_mut().zip(&self.basis[k * d_c..(k + 1) * d_c]) {
                *o += c * b;
            }
        }
    }

    /// Relative l2 reconstruction error against the hot page this was
    /// encoded from (live rows only; 0.0 for an empty page).
    pub fn rel_l2_vs(&self, page: &Page, d_c: usize) -> f64 {
        let mut num = 0.0f64;
        let mut den = 0.0f64;
        let mut rec = vec![0.0f32; d_c];
        for t in 0..self.used {
            self.decode_token(t, d_c, &mut rec);
            let s = page.scales[t];
            for i in 0..d_c {
                let want = (e4m3_decode(page.content[t * d_c + i]) * s) as f64;
                let got = rec[i] as f64;
                num += (want - got) * (want - got);
                den += want * want;
            }
        }
        if den == 0.0 {
            0.0
        } else {
            (num / den).sqrt()
        }
    }
}

fn dot(a: &[f32], b: &[f32]) -> f32 {
    a.iter().zip(b).map(|(x, y)| x * y).sum()
}

/// Fit an orthonormal rank-`r` basis to `used` rows of width `d_c` by
/// modified Gram-Schmidt over the rows in order, skipping rows that are
/// (numerically) inside the span already collected. When fewer than `r`
/// independent rows exist, the basis completes with orthonormalized
/// deterministic pseudo-random directions from `seed` — the codec never
/// returns a rank-deficient basis.
fn fit_basis(rows: &[f32], used: usize, d_c: usize, rank: usize, seed: u64) -> Vec<f32> {
    let mut basis: Vec<f32> = Vec::with_capacity(rank * d_c);
    let mut have = 0usize;
    let mut push_direction = |basis: &mut Vec<f32>, have: &mut usize, cand: &[f32]| -> bool {
        let mut v = cand.to_vec();
        // two orthogonalization passes keep the basis orthonormal to f32
        // working precision even for nearly-dependent rows
        for _ in 0..2 {
            for k in 0..*have {
                let b = &basis[k * d_c..(k + 1) * d_c];
                let proj = dot(&v, b);
                for (x, &bi) in v.iter_mut().zip(b) {
                    *x -= proj * bi;
                }
            }
        }
        let norm = dot(&v, &v).sqrt();
        let cand_norm = dot(cand, cand).sqrt();
        // reject candidates that collapsed into the existing span
        if norm <= f32::EPSILON.sqrt() * cand_norm.max(1.0) {
            return false;
        }
        basis.extend(v.iter().map(|x| x / norm));
        *have += 1;
        true
    };
    for t in 0..used {
        if have == rank {
            break;
        }
        push_direction(&mut basis, &mut have, &rows[t * d_c..(t + 1) * d_c]);
    }
    // degenerate completion: seeded xorshift directions, orthonormalized
    let mut state = seed.wrapping_mul(0x9e37_79b9_7f4a_7c15).max(1);
    let mut cand = vec![0.0f32; d_c];
    while have < rank {
        for c in cand.iter_mut() {
            state ^= state << 13;
            state ^= state >> 7;
            state ^= state << 17;
            // uniform in [-1, 1)
            *c = (state >> 40) as f32 / (1u64 << 23) as f32 - 1.0;
        }
        push_direction(&mut basis, &mut have, &cand);
    }
    basis
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    fn filled_page(d_c: usize, d_r: usize, tokens: usize, seed: u64) -> Page {
        let mut page = Page::new(d_c, d_r);
        let mut rng = Rng::new(seed);
        for t in 0..tokens {
            let c = rng.normal_vec(d_c, 1.5);
            let r = rng.normal_vec(d_r, 20.0);
            page.append_raw(t, d_c, d_r, &c, &r);
        }
        page
    }

    /// Rows drawn from a `k`-dimensional latent with decaying amplitudes
    /// plus small isotropic noise — the decode-realistic stimulus family
    /// the fidelity gate uses.
    fn low_rank_page(d_c: usize, d_r: usize, tokens: usize, k: usize, seed: u64) -> Page {
        let mut page = Page::new(d_c, d_r);
        let mut rng = Rng::new(seed);
        let dirs: Vec<Vec<f32>> = (0..k).map(|_| rng.normal_vec(d_c, 1.0)).collect();
        for t in 0..tokens {
            let amps = rng.normal_vec(k, 1.0);
            let noise = rng.normal_vec(d_c, 0.01);
            let mut c = noise;
            for (j, dir) in dirs.iter().enumerate() {
                let a = amps[j] / (1.0 + j as f32);
                for (x, &d) in c.iter_mut().zip(dir) {
                    *x += a * d;
                }
            }
            let r = rng.normal_vec(d_r, 20.0);
            page.append_raw(t, d_c, d_r, &c, &r);
        }
        page
    }

    #[test]
    fn low_rank_pages_reconstruct_within_bound() {
        let (d_c, d_r, rank) = (64, 8, 24);
        for seed in [3, 4, 5] {
            let page = low_rank_page(d_c, d_r, PAGE_TOKENS, 12, seed);
            let cold = ColdPage::encode(&page, d_c, d_r, rank, seed);
            let err = cold.rel_l2_vs(&page, d_c);
            let bound = rel_l2_bound(rank, d_c);
            assert!(err < bound, "seed {seed}: rel l2 {err} >= bound {bound}");
            // genuinely low-rank content reconstructs far better than the
            // worst-case budget
            assert!(err < 0.25, "seed {seed}: rel l2 {err} too large for rank-12 data");
        }
    }

    #[test]
    fn full_rank_noise_stays_under_worst_case_budget() {
        let (d_c, d_r, rank) = (32, 4, 24);
        let page = filled_page(d_c, d_r, PAGE_TOKENS, 7);
        let cold = ColdPage::encode(&page, d_c, d_r, rank, 7);
        let err = cold.rel_l2_vs(&page, d_c);
        // Gram-Schmidt over the first r rows reproduces those rows near-
        // exactly, so even isotropic noise lands under sqrt(1 - r/d) + slack
        assert!(err < rel_l2_bound(rank, d_c), "rel l2 {err}");
    }

    #[test]
    fn rope_and_source_scales_ride_along_untouched() {
        let (d_c, d_r) = (32, 8);
        let page = filled_page(d_c, d_r, 50, 9);
        let cold = ColdPage::encode(&page, d_c, d_r, 8, 9);
        assert_eq!(cold.rope, page.rope);
        assert_eq!(cold.src_scales, page.scales);
        assert_eq!(cold.used, 50);
    }

    #[test]
    fn degenerate_rows_complete_the_basis_deterministically() {
        let (d_c, d_r, rank) = (16, 4, 8);
        let mut page = Page::new(d_c, d_r);
        // every row is the same direction: 1 independent row, 7 completions
        for t in 0..10 {
            page.append_raw(t, d_c, d_r, &[2.0; 16], &[1.0; 4]);
        }
        let a = ColdPage::encode(&page, d_c, d_r, rank, 42);
        let b = ColdPage::encode(&page, d_c, d_r, rank, 42);
        assert_eq!(a.basis.len(), rank * d_c);
        assert_eq!(a.basis, b.basis, "same seed must produce the same completion");
        // the basis is orthonormal
        for i in 0..rank {
            for j in 0..rank {
                let d = dot(&a.basis[i * d_c..(i + 1) * d_c], &a.basis[j * d_c..(j + 1) * d_c]);
                let want = if i == j { 1.0 } else { 0.0 };
                assert!((d - want).abs() < 1e-4, "basis[{i}]·basis[{j}] = {d}");
            }
        }
        // identical rows reconstruct near-exactly
        assert!(a.rel_l2_vs(&page, d_c) < 0.07);
    }

    #[test]
    fn cold_ratio_matches_the_scheduler_pricing() {
        // deepseek_v31 shape at rank 192: the ratio the benches configure
        let r = cold_ratio(192, 512, 64);
        assert!((r - 324.0 / 644.0).abs() < 1e-12);
        assert!(r < 0.51 && r > 0.50);
        // monotone in rank, 1.0 at full width
        assert!(cold_ratio(64, 512, 64) < r);
        assert!((cold_ratio(512, 512, 64) - 1.0).abs() < 1e-12);
    }
}
