//! `KvWireBlock` — the prefill→decode KV migration codec.
//!
//! SnapMLA's RoPE-aware per-token FP8 cache makes a sequence's KV state a
//! compact, self-describing wire format: per-token **u8 E4M3 NoPE codes** +
//! **f32 per-(token, layer) scales** + **u16 bf16 aligned RoPE** — exactly
//! the bytes the pages already hold, so encode→decode is bit-exact with
//! `PagedKvCache::spill`/`restore` and the transfer moves roughly half the
//! bytes of a bf16-everything migration (644 vs 1152 B/token/layer at
//! DeepSeek dims). The BF16 baseline mode serializes its native bf16
//! content instead (same bytes as its pages).
//!
//! The codec is storage-layout-free: tokens are packed densely in token
//! order, independent of page tables, so a block encoded on one rank maps
//! into any other rank's pool (`PagedKvCache::export_wire` /
//! `import_wire`). `cluster::collective::transfer_time_s` prices the block
//! over the inter-rank link for the virtual-time benches.

use super::cache::CacheMode;

/// Wire payload: the mode-dependent content planes (RoPE is shared).
#[derive(Clone, Debug, PartialEq)]
pub(crate) enum WirePayload {
    /// u8 E4M3 codes `[tokens][layers][d_c]` + f32 scales `[tokens][layers]`
    Fp8 { codes: Vec<u8>, scales: Vec<f32> },
    /// u16 bf16 content `[tokens][layers][d_c]` (FlashMLA baseline cache)
    Bf16 { content: Vec<u16> },
}

/// One sequence's KV state in wire form (all layers, token-major).
#[derive(Clone, Debug, PartialEq)]
pub struct KvWireBlock {
    pub(crate) tokens: usize,
    pub(crate) n_layers: usize,
    pub(crate) d_c: usize,
    pub(crate) d_r: usize,
    pub(crate) payload: WirePayload,
    /// u16 bf16 aligned RoPE `[tokens][layers][d_r]`
    pub(crate) rope: Vec<u16>,
}

impl KvWireBlock {
    /// Cache tokens this block carries.
    pub fn tokens(&self) -> usize {
        self.tokens
    }

    /// Cache mode the block was encoded from (decode must match).
    pub fn mode(&self) -> CacheMode {
        match self.payload {
            WirePayload::Fp8 { .. } => CacheMode::Fp8,
            WirePayload::Bf16 { .. } => CacheMode::Bf16,
        }
    }

    /// Bytes this block occupies on the wire (payload + rope; the
    /// fixed-size header is negligible and excluded, as in the perf model).
    pub fn wire_bytes(&self) -> usize {
        self.tokens * self.n_layers * Self::bytes_per_token_layer(self.mode(), self.d_c, self.d_r)
    }

    /// Bytes a bf16-everything transfer of the same tokens would move (the
    /// A/B stat: FP8 wire vs the naive bf16 migration format).
    pub fn bf16_equiv_bytes(&self) -> usize {
        self.tokens
            * self.n_layers
            * Self::bytes_per_token_layer(CacheMode::Bf16, self.d_c, self.d_r)
    }

    /// Wire bytes per (token, layer) for a mode: FP8 = d_c codes + bf16
    /// rope + one f32 scale; BF16 = bf16 content + bf16 rope.
    pub fn bytes_per_token_layer(mode: CacheMode, d_c: usize, d_r: usize) -> usize {
        match mode {
            CacheMode::Fp8 => d_c + 2 * d_r + 4,
            CacheMode::Bf16 => 2 * (d_c + d_r),
        }
    }

    /// KV pages a receiving rank must reserve to import this block and then
    /// generate `remaining_tokens` more — the admission check shared by the
    /// disaggregated handoff and failure-recovery re-migration paths.
    pub fn pages_needed(&self, remaining_tokens: usize) -> usize {
        (self.tokens + remaining_tokens).div_ceil(crate::kvcache::PAGE_TOKENS)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fp8_wire_is_roughly_half_of_bf16() {
        // DeepSeek dims: 644 vs 1152 B/token/layer
        let fp8 = KvWireBlock::bytes_per_token_layer(CacheMode::Fp8, 512, 64);
        let bf16 = KvWireBlock::bytes_per_token_layer(CacheMode::Bf16, 512, 64);
        assert_eq!(fp8, 644);
        assert_eq!(bf16, 1152);
        let ratio = fp8 as f64 / bf16 as f64;
        assert!(ratio < 0.6, "{ratio}");
    }

    #[test]
    fn wire_bytes_count_payload_and_rope() {
        let block = KvWireBlock {
            tokens: 3,
            n_layers: 2,
            d_c: 16,
            d_r: 8,
            payload: WirePayload::Fp8 { codes: vec![0; 3 * 2 * 16], scales: vec![1.0; 3 * 2] },
            rope: vec![0; 3 * 2 * 8],
        };
        // 3 tok × 2 layers × (16 codes + 16 rope bytes + 4 scale bytes)
        assert_eq!(block.wire_bytes(), 3 * 2 * (16 + 16 + 4));
        assert_eq!(block.bf16_equiv_bytes(), 3 * 2 * 2 * (16 + 8));
        assert_eq!(block.mode(), CacheMode::Fp8);
        assert_eq!(block.tokens(), 3);
    }

    #[test]
    fn pages_needed_reserves_block_plus_remaining_generation() {
        let block = KvWireBlock {
            tokens: 3,
            n_layers: 2,
            d_c: 16,
            d_r: 8,
            payload: WirePayload::Fp8 { codes: vec![0; 3 * 2 * 16], scales: vec![1.0; 3 * 2] },
            rope: vec![0; 3 * 2 * 8],
        };
        let page = crate::kvcache::PAGE_TOKENS;
        assert_eq!(block.pages_needed(0), 1);
        assert_eq!(block.pages_needed(page - 3), 1);
        assert_eq!(block.pages_needed(page - 2), 2);
    }
}
