//! Paged FP8 KV cache — the serving-grade cache manager (paper §3.1.1/§3.3.1).
//!
//! Stores the MLA latent cache exactly as SnapMLA's kernels consume it:
//! * content: **u8 E4M3 codes** (true FP8 storage, 4x smaller than f32)
//! * per-token scales: f32
//! * decoupled RoPE: **u16 bf16**, pre-scaled by 1/sigma (Key Step 1)
//!
//! Page size = 64 tokens = BLOCK_N, so a page maps 1:1 onto a kernel tile and
//! an L2-cache-aligned TMA descriptor in the paper's layer-2 optimization.
//!
//! `append` implements the Fused-K-Append semantics: per-token quantization,
//! scale-domain alignment and the paged non-contiguous write happen in one
//! call — no tail buffers, any token count, instant quantization (the
//! decoding-centric granularity argument of §3.1.1). The per-block
//! alternative with page-tail rebuffering lives in `blockwise.rs` for the
//! granularity ablation.
//!
//! Serving lifecycle: pages are **refcounted** (`allocator`), full prompt-
//! prefix pages are shared across sequences via a prefix trie (`prefix`),
//! preemption spills page bytes to host memory instead of discarding the
//! KV state (`cache::spill`/`restore`), and a sequence's whole KV state
//! serializes into the page-table-free [`transfer::KvWireBlock`] wire
//! format for prefill→decode rank migration (bit-exact with
//! spill/restore, ~half the bytes of a bf16-everything transfer).
//!
//! The **tiered** extension (`tiered`, `compress`) makes the host tier a
//! first-class citizen: spills and prefetches become asynchronous flights
//! priced on a per-direction PCIe link and overlapped with decode
//! (`TierState` tracks per-page residency), and pages that have gone cold
//! re-encode into the rank-reduced [`compress::ColdPage`] latent format —
//! the page table is a heterogeneous heap (`cache::PageData`) mixing hot
//! FP8, bf16, and cold low-rank pages, with decompression on access.

pub mod allocator;
pub mod blockwise;
pub mod cache;
pub mod compress;
pub mod page;
pub mod prefix;
pub mod tiered;
pub mod transfer;

pub use allocator::PageAllocator;
pub use cache::{CacheConfig, CacheMode, KvCheckpoint, PagedKvCache, SeqHandle, SpilledKv};
pub use compress::{cold_ratio, rel_l2_bound, ColdPage};
pub use page::{Page, PAGE_TOKENS};
pub use prefix::PrefixTrie;
pub use tiered::{TierEngine, TierState};
pub use transfer::KvWireBlock;
