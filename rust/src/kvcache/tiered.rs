//! Tiered KV cache: async host spill/prefetch overlapped with decode.
//!
//! [`TierState`] tags every physical page of a [`PagedKvCache`]:
//!
//! * `Hbm` — resident, readable, the default.
//! * `SpillInFlight` — a spill transfer is copying the page down to host
//!   memory. The bytes are still in HBM (reads stay valid) but the page is
//!   **not yet free**: the scheduler must not count it as reclaimable
//!   until the flight lands.
//! * `Host` — the page's last HBM slot was freed after its bytes landed on
//!   the host (a tombstone on the free slot; reallocation re-arms `Hbm`).
//! * `PrefetchInFlight` — an HBM slot is claimed and being filled from
//!   host memory; the page is **not yet readable** until the flight lands.
//!
//! [`TierEngine`] drives the lifecycle in virtual time: `begin_spill` /
//! `begin_prefetch` start a transfer on the rank's PCIe link (one clock
//! per direction — same-direction transfers serialize, opposite
//! directions are full-duplex, exactly the pricing `simulate::harness`
//! and its Python port apply), and `poll(now)` completes every flight
//! whose landing time has passed. Between begin and poll the decode loop
//! keeps stepping — that overlap is the tentpole win the `serve_tiered`
//! bench measures against the synchronous spill baseline.
//!
//! The engine also owns the cold sweep: [`TierEngine::compress_cold`]
//! re-encodes pages that fell behind the hot window into the rank-reduced
//! format of [`super::compress`].

use super::allocator::AllocError;
use super::cache::{PagedKvCache, SeqHandle, SpilledKv};
use std::collections::BTreeMap;

/// Residency state of one physical page (see module docs).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum TierState {
    /// resident and readable
    Hbm,
    /// spill transfer in flight: readable, but NOT reclaimable yet
    SpillInFlight,
    /// bytes live on the host; the HBM slot is free (tombstone)
    Host,
    /// prefetch transfer in flight: slot claimed, NOT readable yet
    PrefetchInFlight,
}

/// Async spill/prefetch driver for one rank's cache (virtual time).
pub struct TierEngine {
    /// spill-direction (device→host) link busy-until clock
    dn_free: f64,
    /// prefetch-direction (host→device) link busy-until clock
    up_free: f64,
    /// spills in flight: seq → landing time
    spilling: BTreeMap<SeqHandle, f64>,
    /// prefetches in flight: seq → landing time
    prefetching: BTreeMap<SeqHandle, f64>,
    /// landed spills parked on the host, awaiting prefetch
    host: BTreeMap<SeqHandle, SpilledKv>,
    pub spills: u64,
    pub prefetches: u64,
    pub cold_pages_encoded: u64,
}

impl Default for TierEngine {
    fn default() -> Self {
        Self::new()
    }
}

impl TierEngine {
    pub fn new() -> TierEngine {
        TierEngine {
            dn_free: 0.0,
            up_free: 0.0,
            spilling: BTreeMap::new(),
            prefetching: BTreeMap::new(),
            host: BTreeMap::new(),
            spills: 0,
            prefetches: 0,
            cold_pages_encoded: 0,
        }
    }

    /// Sequences parked on the host (landed spills).
    pub fn host_seqs(&self) -> usize {
        self.host.len()
    }

    /// Transfers currently in flight (either direction).
    pub fn in_flight(&self) -> usize {
        self.spilling.len() + self.prefetching.len()
    }

    /// Is `seq` parked on the host, ready to prefetch?
    pub fn is_on_host(&self, seq: SeqHandle) -> bool {
        self.host.contains_key(&seq)
    }

    /// Start spilling `seq` down to the host at virtual time `now`; the
    /// transfer occupies the down link for `transfer_s` seconds after any
    /// earlier down transfer drains. Returns the landing time. Until then
    /// the pages stay `SpillInFlight`: readable, allocated, not free.
    pub fn begin_spill(
        &mut self,
        cache: &mut PagedKvCache,
        seq: SeqHandle,
        now: f64,
        transfer_s: f64,
    ) -> Result<f64, AllocError> {
        assert!(
            !self.spilling.contains_key(&seq) && !self.prefetching.contains_key(&seq),
            "seq {seq} already has a tier transfer in flight"
        );
        cache.begin_spill(seq)?;
        let start = self.dn_free.max(now);
        self.dn_free = start + transfer_s;
        self.spilling.insert(seq, self.dn_free);
        self.spills += 1;
        Ok(self.dn_free)
    }

    /// Start prefetching a host-parked `seq` back into HBM at `now`: the
    /// pages are claimed (and written) immediately as `PrefetchInFlight`,
    /// the up link is occupied for `transfer_s`, and the sequence becomes
    /// readable when `poll` passes the returned landing time.
    pub fn begin_prefetch(
        &mut self,
        cache: &mut PagedKvCache,
        seq: SeqHandle,
        now: f64,
        transfer_s: f64,
    ) -> Result<f64, AllocError> {
        let sp = self.host.get(&seq).ok_or(AllocError::UnknownSequence)?;
        if cache.available_pages() < sp.pages() {
            return Err(AllocError::OutOfPages);
        }
        let sp = self.host.remove(&seq).expect("checked above");
        cache.begin_prefetch(seq, sp)?;
        let start = self.up_free.max(now);
        self.up_free = start + transfer_s;
        self.prefetching.insert(seq, self.up_free);
        self.prefetches += 1;
        Ok(self.up_free)
    }

    /// Complete every flight that has landed by `now`. Landed spills free
    /// their HBM pages and park on the host; landed prefetches become
    /// readable. Returns (spilled, prefetched) sequence ids, id-ordered.
    pub fn poll(
        &mut self,
        cache: &mut PagedKvCache,
        now: f64,
    ) -> (Vec<SeqHandle>, Vec<SeqHandle>) {
        let landed_spills: Vec<SeqHandle> =
            self.spilling.iter().filter(|&(_, &t)| t <= now).map(|(&s, _)| s).collect();
        for &seq in &landed_spills {
            self.spilling.remove(&seq);
            let sp = cache.finish_spill(seq).expect("spill flight tracks a live sequence");
            self.host.insert(seq, sp);
        }
        let landed_pf: Vec<SeqHandle> =
            self.prefetching.iter().filter(|&(_, &t)| t <= now).map(|(&s, _)| s).collect();
        for &seq in &landed_pf {
            self.prefetching.remove(&seq);
            cache.finish_prefetch(seq).expect("prefetch flight tracks a live sequence");
        }
        (landed_spills, landed_pf)
    }

    /// Earliest pending landing time, if any flight is outstanding — the
    /// event-loop wake-up candidate.
    pub fn next_landing(&self) -> Option<f64> {
        self.spilling
            .values()
            .chain(self.prefetching.values())
            .cloned()
            .fold(None, |m: Option<f64>, t| Some(m.map_or(t, |m| m.min(t))))
    }

    /// Re-encode `seq`'s pages outside the hot window (everything more
    /// than `cold_after_tokens` behind the tail, excluding the tail page)
    /// into the rank-`rank` cold format. Returns pages compressed.
    pub fn compress_cold(
        &mut self,
        cache: &mut PagedKvCache,
        seq: SeqHandle,
        cold_after_tokens: usize,
        rank: usize,
    ) -> Result<usize, AllocError> {
        let n = cache.compress_cold(seq, cold_after_tokens, rank)?;
        self.cold_pages_encoded += n as u64;
        Ok(n)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::kvcache::cache::{CacheConfig, CacheMode};
    use crate::util::rng::Rng;

    fn cache(capacity_pages: usize) -> PagedKvCache {
        PagedKvCache::new(CacheConfig {
            n_layers: 2,
            d_c: 16,
            d_r: 8,
            mode: CacheMode::Fp8,
            capacity_pages,
        })
    }

    fn fill(cache: &mut PagedKvCache, seq: u64, tokens: usize, seed: u64) {
        let c = cache.cfg;
        let mut rng = Rng::new(seed);
        cache.register(seq);
        for _ in 0..tokens {
            let ck = rng.normal_vec(c.n_layers * c.d_c, 2.0);
            let kr = rng.normal_vec(c.n_layers * c.d_r, 30.0);
            cache.append_token(seq, &ck, &kr).unwrap();
        }
    }

    fn view(cache: &PagedKvCache, seq: u64, n: usize) -> (Vec<f32>, Vec<f32>, Vec<f32>) {
        let c = cache.cfg;
        let mut content = vec![0.0f32; n * c.d_c];
        let mut rope = vec![0.0f32; n * c.d_r];
        let mut sigma = vec![0.0f32; n];
        cache.gather_kernel_view(seq, 0, n, &mut content, &mut rope, &mut sigma);
        (content, rope, sigma)
    }

    #[test]
    fn spill_flight_keeps_pages_allocated_until_it_lands() {
        let mut kv = cache(8);
        let mut eng = TierEngine::new();
        fill(&mut kv, 1, 70, 5); // 2 pages
        let used = kv.used_pages();
        let before = view(&kv, 1, 70);

        let lands = eng.begin_spill(&mut kv, 1, 0.0, 1.0).unwrap();
        assert_eq!(lands, 1.0);
        // in flight: still allocated (NOT free), still readable
        assert_eq!(kv.used_pages(), used);
        assert_eq!(view(&kv, 1, 70), before);
        assert_eq!(eng.poll(&mut kv, 0.5), (vec![], vec![]));
        assert_eq!(kv.used_pages(), used, "flight must not free pages early");

        // landing frees the pages and parks the sequence on the host
        assert_eq!(eng.poll(&mut kv, 1.0), (vec![1], vec![]));
        assert_eq!(kv.used_pages(), 0);
        assert!(eng.is_on_host(1));
        kv.validate().unwrap();

        // prefetch claims pages immediately; readable after it lands
        let lands = eng.begin_prefetch(&mut kv, 1, 2.0, 1.0).unwrap();
        assert_eq!(lands, 3.0);
        assert_eq!(kv.used_pages(), used, "prefetch claims its pages at issue");
        assert_eq!(eng.poll(&mut kv, 3.0), (vec![], vec![1]));
        assert_eq!(view(&kv, 1, 70), before, "tiered roundtrip is bit-exact");
        kv.validate().unwrap();
    }

    #[test]
    fn same_direction_transfers_serialize_opposite_directions_overlap() {
        let mut kv = cache(16);
        let mut eng = TierEngine::new();
        fill(&mut kv, 1, 64, 6);
        fill(&mut kv, 2, 64, 7);
        // two down transfers serialize on the down link
        assert_eq!(eng.begin_spill(&mut kv, 1, 0.0, 1.0).unwrap(), 1.0);
        assert_eq!(eng.begin_spill(&mut kv, 2, 0.0, 1.0).unwrap(), 2.0);
        let (sp, _) = eng.poll(&mut kv, 1.0);
        assert_eq!(sp, vec![1], "only the first down transfer has landed");
        // an up transfer starts while seq 2 still occupies the down link
        let up = eng.begin_prefetch(&mut kv, 1, 1.0, 1.0).unwrap();
        assert_eq!(up, 2.0, "opposite directions are full-duplex");
        assert_eq!(eng.poll(&mut kv, 2.0), (vec![2], vec![1]));
        assert_eq!(eng.in_flight(), 0);
        assert_eq!((eng.spills, eng.prefetches), (2, 1));
        kv.validate().unwrap();
    }

    #[test]
    fn next_landing_tracks_the_earliest_flight() {
        let mut kv = cache(16);
        let mut eng = TierEngine::new();
        fill(&mut kv, 1, 64, 8);
        fill(&mut kv, 2, 64, 9);
        assert_eq!(eng.next_landing(), None);
        eng.begin_spill(&mut kv, 1, 0.0, 2.0).unwrap();
        eng.begin_spill(&mut kv, 2, 0.0, 2.0).unwrap();
        assert_eq!(eng.next_landing(), Some(2.0));
        eng.poll(&mut kv, 2.0);
        assert_eq!(eng.next_landing(), Some(4.0));
        eng.poll(&mut kv, 4.0);
        assert_eq!(eng.next_landing(), None);
    }

    #[test]
    fn prefetch_without_room_reports_out_of_pages_and_keeps_host_copy() {
        let mut kv = cache(2);
        let mut eng = TierEngine::new();
        fill(&mut kv, 1, 128, 10); // both pages
        eng.begin_spill(&mut kv, 1, 0.0, 1.0).unwrap();
        eng.poll(&mut kv, 1.0);
        // another sequence takes the room
        fill(&mut kv, 2, 128, 11);
        assert_eq!(eng.begin_prefetch(&mut kv, 1, 2.0, 1.0), Err(AllocError::OutOfPages));
        assert!(eng.is_on_host(1), "a failed prefetch must not lose the host copy");
        kv.release(2);
        eng.begin_prefetch(&mut kv, 1, 3.0, 1.0).unwrap();
        eng.poll(&mut kv, 4.0);
        assert_eq!(kv.tokens_of(1), 128);
        kv.validate().unwrap();
    }
}
