//! Pluggable decode-kernel variants behind one `KernelVariant` trait.
//!
//! A variant bundles its *numerics* (query/KV quantization hooks, softmax
//! scaling, PV rescaling rule) with its matching `perfmodel::kernel` cost
//! model, so every future kernel paper is a ~200-line variant instead of a
//! fork of the pipeline. Three variants ship:
//!
//! * [`SnapMla`] — the paper's Algorithm 1 (this module owns the exact
//!   implementation; the retired `mla::pipeline` shims used to delegate
//!   here). Per-64-block online softmax,
//!   scale fusion P' = P ⊙ S_V, block-wise dynamic P quantization, and the
//!   Appendix-E [`PvOrder`] accumulation-schedule study.
//! * [`Amla`] — AMLA-style exponent-ADD rescaling (arXiv 2509.25224): the
//!   online softmax runs in base 2 with the running max snapped to the
//!   integer grid and sigma_P snapped to a power of two, so every
//!   accumulator rescale factor gamma is an exact power of two. The FMA
//!   rescale MUL becomes an exponent ADD — lossless in f32 and cheaper on
//!   the vector pipe (priced by `KernelKind::AmlaFp8`).
//! * [`PCast`] — P-Cast-style fixed-scale probability cast
//!   (arXiv 2606.06521): probabilities are cast to FP8 with the *static*
//!   scale S = 2^8 (block-local e ≤ 1 ⇒ codes ≤ 256 < 448, never
//!   saturating), skipping the per-block amax reduction and scale division
//!   entirely. Value scales are applied unfused in the PV stage. Because
//!   normalization is block-local, a sink token cannot collapse the scale
//!   domain of the long tail — the failure mode of naive per-max global
//!   scaling (see the sink-stimulus test in `tests/prop_variants.rs`).
//!
//! Quantization *cache* policy also lives here: [`CachePolicy`] absorbs the
//! Table-3 cache rewriting that `QuantConfig::apply` used to hand-roll, so
//! quantization policy is defined in exactly one place.

use super::{Cache, Query, Shape};
use crate::fp8::{
    bf16_round, dequant_per_block, e4m3_round, per_token_scale, quant_per_block,
    quant_per_tensor, quant_per_token, E4M3_MAX, SCALE_EPS,
};
use crate::perfmodel::kernel::KernelKind;

/// KV block size — matches the Pallas kernel's BLOCK_N, the PV GEMM tile
/// (paper §3.2.2 "BlockN=64") and the KV-cache page size.
pub const BLOCK_N: usize = 64;

pub(crate) const NEG_INF: f32 = -1e30;

/// P-Cast's fixed probability scale S = 2^8: block-local e ∈ (0, 1] maps to
/// codes ≤ 256, inside the E4M3 range without any dynamic amax pass.
pub const PCAST_P_SCALE: f32 = 256.0;

/// A SnapMLA-quantized KV cache (the algorithmic view; the serving-grade
/// paged container with u8 storage lives in `crate::kvcache`). All three
/// shipped variants share this layout — per-token E4M3 content plus
/// 1/sigma-aligned bf16 RoPE — so a cache built once serves any variant.
#[derive(Clone, Debug)]
pub struct QuantCache {
    /// content on the E4M3 grid, row-major [n, d_c] (f32 staging of codes)
    pub k_c_q: Vec<f32>,
    /// per-token content scales [n]
    pub sigma_k: Vec<f32>,
    /// RoPE pre-scaled by 1/sigma_k (Key Step 1), row-major [n, d_r]
    pub k_r_al: Vec<f32>,
    pub n: usize,
}

/// A quantized query: E4M3-grid content rows, per-head scales, and RoPE
/// aligned into each head's scale domain.
#[derive(Clone, Debug)]
pub struct QuantQuery {
    /// [heads, d_c] content codes (f32 staging)
    pub q_c_q: Vec<f32>,
    /// [heads] per-head content scales
    pub sigma_q: Vec<f32>,
    /// [heads, d_r] RoPE pre-scaled by 1/sigma_q
    pub q_r_al: Vec<f32>,
}

/// PV accumulation schedule (Appendix E). Only meaningful for [`SnapMla`];
/// the ablation bench instantiates `SnapMla::with_order` to compare them.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum PvOrder {
    Monotonic,
    InvertedRescaleP,
    InvertedRollback,
}

#[derive(Clone, Debug)]
pub struct PipelineOut {
    pub o: Vec<f32>,   // [heads, d_c]
    pub lse: Vec<f32>, // [heads]
}

/// Which decode-kernel variant to run; the runtime-selectable handle that
/// the CLI (`--kernel`), `ModelEngine`, `SimBackend` and the fidelity
/// harness thread through.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum VariantKind {
    SnapMla,
    Amla,
    PCast,
}

static SNAPMLA: SnapMla = SnapMla { order: PvOrder::Monotonic };
static AMLA: Amla = Amla;
static PCAST: PCast = PCast;

impl VariantKind {
    pub const ALL: [VariantKind; 3] = [VariantKind::SnapMla, VariantKind::Amla, VariantKind::PCast];

    /// Parse a CLI spelling (`--kernel snapmla|amla|pcast`).
    pub fn parse(s: &str) -> Option<VariantKind> {
        match s {
            "snapmla" => Some(VariantKind::SnapMla),
            "amla" => Some(VariantKind::Amla),
            "pcast" => Some(VariantKind::PCast),
            _ => None,
        }
    }

    /// The CLI / artifact-name spelling.
    pub fn name(&self) -> &'static str {
        match self {
            VariantKind::SnapMla => "snapmla",
            VariantKind::Amla => "amla",
            VariantKind::PCast => "pcast",
        }
    }

    /// The matching `perfmodel` cost-model entry.
    pub fn kernel_kind(&self) -> KernelKind {
        match self {
            VariantKind::SnapMla => KernelKind::SnapMlaFp8,
            VariantKind::Amla => KernelKind::AmlaFp8,
            VariantKind::PCast => KernelKind::PCastFp8,
        }
    }

    /// The canonical static instance of the variant's numerics.
    pub fn instance(&self) -> &'static dyn KernelVariant {
        match self {
            VariantKind::SnapMla => &SNAPMLA,
            VariantKind::Amla => &AMLA,
            VariantKind::PCast => &PCAST,
        }
    }
}

/// KV-cache quantization policy (Table 3). The variant descriptor names one;
/// `QuantConfig::apply` delegates here so cache rewriting lives in one place.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum CachePolicy {
    /// SnapMLA: per-token FP8 content, bf16 RoPE (RoPE-aware).
    PerTokenRopeAware,
    /// Config A: per-token RoPE-unaware — one shared scale over [content;rope].
    PerTokenCoupled,
    /// Config B: per-tensor static (fixed scale 1.0), RoPE-aware.
    PerTensorStatic,
    /// Config C: per-tensor dynamic, RoPE-aware.
    PerTensorDynamic,
    /// Config D: per-block (64x64), RoPE-aware.
    PerBlock,
}

impl CachePolicy {
    /// Apply the policy to a cache, returning dequantized-equivalent values.
    pub fn apply(&self, shape: &Shape, cache: &Cache) -> Cache {
        let (d_c, d_r, n) = (shape.d_c, shape.d_r, cache.n);
        let mut out = Cache::new(n, shape);
        match self {
            CachePolicy::PerTokenRopeAware => {
                for j in 0..n {
                    let q = quant_per_token(&cache.k_c[j * d_c..(j + 1) * d_c]);
                    q.dequant_into(&mut out.k_c[j * d_c..(j + 1) * d_c]);
                }
                bf16_rope(&cache.k_r, &mut out.k_r);
            }
            CachePolicy::PerTokenCoupled => {
                // one shared per-token scale over the concatenated KV vector
                let mut row = vec![0.0f32; d_c + d_r];
                for j in 0..n {
                    row[..d_c].copy_from_slice(&cache.k_c[j * d_c..(j + 1) * d_c]);
                    row[d_c..].copy_from_slice(&cache.k_r[j * d_r..(j + 1) * d_r]);
                    let q = quant_per_token(&row);
                    let d = q.dequant();
                    out.k_c[j * d_c..(j + 1) * d_c].copy_from_slice(&d[..d_c]);
                    out.k_r[j * d_r..(j + 1) * d_r].copy_from_slice(&d[d_c..]);
                }
            }
            CachePolicy::PerTensorStatic => {
                for (o, &x) in out.k_c.iter_mut().zip(&cache.k_c) {
                    *o = e4m3_round(x); // scale 1.0
                }
                bf16_rope(&cache.k_r, &mut out.k_r);
            }
            CachePolicy::PerTensorDynamic => {
                let (codes, s) = quant_per_tensor(&cache.k_c, None);
                for (o, &c) in out.k_c.iter_mut().zip(&codes) {
                    *o = crate::fp8::e4m3_decode(c) * s;
                }
                bf16_rope(&cache.k_r, &mut out.k_r);
            }
            CachePolicy::PerBlock => {
                // 64x64 blocks over [n, d_c]; degrade gracefully if not divisible
                let br = if n % 64 == 0 { 64 } else { n };
                let bc = if d_c % 64 == 0 { 64 } else { d_c };
                let q = quant_per_block(&cache.k_c, n, d_c, br, bc);
                out.k_c = dequant_per_block(&q);
                bf16_rope(&cache.k_r, &mut out.k_r);
            }
        }
        out
    }
}

fn bf16_rope(src: &[f32], dst: &mut [f32]) {
    for (o, &x) in dst.iter_mut().zip(src) {
        *o = bf16_round(x);
    }
}

/// One decode-kernel variant: numerics + the matching cost-model entry.
///
/// The default `build_cache`/`quantize_query` are the SnapMLA fused
/// append/quant steps — all shipped variants share the cache layout, so a
/// cache built by one variant is valid input to another's `pipeline`. The
/// `pipeline` stage is where variants differ.
pub trait KernelVariant: Sync {
    fn kind(&self) -> VariantKind;

    /// The `perfmodel::kernel` entry pricing this variant.
    fn kernel_kind(&self) -> KernelKind {
        self.kind().kernel_kind()
    }

    /// The KV-cache quantization policy this variant's cache uses.
    fn cache_policy(&self) -> CachePolicy {
        CachePolicy::PerTokenRopeAware
    }

    /// Fused-K-Append over a full cache: per-token quantize + domain-align.
    fn build_cache(&self, shape: &Shape, k_c: &[f32], k_r: &[f32], n: usize) -> QuantCache {
        snapmla_build_cache(shape, k_c, k_r, n)
    }

    /// Fused-Q-Quant: per-head-row quantize + align.
    fn quantize_query(&self, shape: &Shape, q: &Query) -> QuantQuery {
        snapmla_quantize_query(shape, q)
    }

    /// Run the variant's decode pipeline for one step over pre-quantized
    /// operands. `length` ≤ `cache.n`; trailing rows are masked exactly like
    /// the kernel.
    #[allow(clippy::too_many_arguments)]
    fn pipeline(
        &self,
        shape: &Shape,
        q_c_q: &[f32],
        sigma_q: &[f32],
        q_r_al: &[f32],
        cache: &QuantCache,
        length: usize,
        sm_scale: f32,
    ) -> PipelineOut;

    /// Full decode from f32 operands: pad to a whole number of KV blocks,
    /// build the cache, quantize the query, run the pipeline.
    fn decode(
        &self,
        shape: &Shape,
        q: &Query,
        k_c: &[f32],
        k_r: &[f32],
        length: usize,
        sm_scale: f32,
    ) -> PipelineOut {
        let n_pad = length.div_ceil(BLOCK_N) * BLOCK_N;
        let mut k_c_pad = k_c[..length * shape.d_c].to_vec();
        k_c_pad.resize(n_pad * shape.d_c, 0.0);
        let mut k_r_pad = k_r[..length * shape.d_r].to_vec();
        k_r_pad.resize(n_pad * shape.d_r, 0.0);
        let cache = self.build_cache(shape, &k_c_pad, &k_r_pad, n_pad);
        let qq = self.quantize_query(shape, q);
        self.pipeline(shape, &qq.q_c_q, &qq.sigma_q, &qq.q_r_al, &cache, length, sm_scale)
    }
}

// ---------------------------------------------------------------------------
// Shared SnapMLA-layout quantization steps (Key Steps 1–2 of the paper)
// ---------------------------------------------------------------------------

/// Per-token quantize + domain-align a full cache (the shared fused append).
pub fn snapmla_build_cache(shape: &Shape, k_c: &[f32], k_r: &[f32], n: usize) -> QuantCache {
    let (d_c, d_r) = (shape.d_c, shape.d_r);
    let mut out = QuantCache {
        k_c_q: vec![0.0; n * d_c],
        sigma_k: vec![0.0; n],
        k_r_al: vec![0.0; n * d_r],
        n,
    };
    for j in 0..n {
        let row = &k_c[j * d_c..(j + 1) * d_c];
        let s = per_token_scale(row);
        out.sigma_k[j] = s;
        for i in 0..d_c {
            out.k_c_q[j * d_c + i] = e4m3_round(row[i] / s);
        }
        for i in 0..d_r {
            out.k_r_al[j * d_r + i] = bf16_round(k_r[j * d_r + i]) / s;
        }
    }
    out
}

/// Per-head-row quantize + align the query (the shared fused Q-quant).
pub fn snapmla_quantize_query(shape: &Shape, q: &Query) -> QuantQuery {
    let (h, d_c, d_r) = (shape.heads, shape.d_c, shape.d_r);
    let mut q_c_q = vec![0.0f32; h * d_c];
    let mut sigma_q = vec![0.0f32; h];
    let mut q_r_al = vec![0.0f32; h * d_r];
    for head in 0..h {
        let row = &q.q_c[head * d_c..(head + 1) * d_c];
        let s = per_token_scale(row);
        sigma_q[head] = s;
        for i in 0..d_c {
            q_c_q[head * d_c + i] = e4m3_round(row[i] / s);
        }
        for i in 0..d_r {
            q_r_al[head * d_r + i] = bf16_round(q.q_r[head * d_r + i]) / s;
        }
    }
    QuantQuery { q_c_q, sigma_q, q_r_al }
}

// ---------------------------------------------------------------------------
// SnapMLA (paper Algorithm 1, incl. the Appendix-E ordering study)
// ---------------------------------------------------------------------------

/// The paper's pipeline. `order` selects the Appendix-E PV accumulation
/// schedule; the shipped kernel (and `VariantKind::SnapMla.instance()`) uses
/// `Monotonic`.
#[derive(Clone, Copy, Debug)]
pub struct SnapMla {
    pub order: PvOrder,
}

impl Default for SnapMla {
    fn default() -> Self {
        SnapMla { order: PvOrder::Monotonic }
    }
}

impl SnapMla {
    pub fn with_order(order: PvOrder) -> SnapMla {
        SnapMla { order }
    }
}

impl KernelVariant for SnapMla {
    fn kind(&self) -> VariantKind {
        VariantKind::SnapMla
    }

    fn pipeline(
        &self,
        shape: &Shape,
        q_c_q: &[f32],
        sigma_q: &[f32],
        q_r_al: &[f32],
        cache: &QuantCache,
        length: usize,
        sm_scale: f32,
    ) -> PipelineOut {
        snapmla_pipeline_impl(shape, q_c_q, sigma_q, q_r_al, cache, length, sm_scale, self.order)
    }
}

/// One processed block: quantized fused probabilities + its scale domain.
struct BlockP {
    start: usize,
    valid: usize,
    pq: Vec<f32>, // FP8-grid codes of P' / sigma_p
    /// rescale factor bringing the accumulator from the previous block's
    /// (m, sigma_p) domain into this block's domain (gamma of Eq. 13)
    gamma: f32,
}

/// The exact Algorithm-1 implementation (moved verbatim from the legacy
/// `pipeline::snapmla_pipeline`, whose deprecated shim is now removed).
#[allow(clippy::too_many_arguments)]
pub(crate) fn snapmla_pipeline_impl(
    shape: &Shape,
    q_c_q: &[f32],
    sigma_q: &[f32],
    q_r_al: &[f32],
    cache: &QuantCache,
    length: usize,
    sm_scale: f32,
    order: PvOrder,
) -> PipelineOut {
    let (h, d_c, d_r) = (shape.heads, shape.d_c, shape.d_r);
    assert!(length <= cache.n);
    let num_blocks = cache.n.div_ceil(BLOCK_N);

    let mut o = vec![0.0f32; h * d_c];
    let mut lse = vec![0.0f32; h];
    let mut s_blk = vec![0.0f32; BLOCK_N];

    for head in 0..h {
        let qc = &q_c_q[head * d_c..(head + 1) * d_c];
        let qr = &q_r_al[head * d_r..(head + 1) * d_r];
        let sq = sigma_q[head];

        let mut m = NEG_INF;
        let mut l = 0.0f32;
        let mut sp = 1.0f32;
        let acc = &mut o[head * d_c..(head + 1) * d_c];

        // ---- stages 1-3 for every block, with monotonic (m, l, sigma_p)
        // progression; PV accumulation order is applied afterwards per pair.
        let mut blocks: Vec<BlockP> = Vec::with_capacity(num_blocks);
        for b in 0..num_blocks {
            let start = b * BLOCK_N;
            let valid = length.saturating_sub(start).min(BLOCK_N);
            if valid == 0 {
                break;
            }
            let mut m_cur = NEG_INF;
            for j in 0..valid {
                let row = start + j;
                let kc = &cache.k_c_q[row * d_c..(row + 1) * d_c];
                let kr = &cache.k_r_al[row * d_r..(row + 1) * d_r];
                let mut s = 0.0f32;
                for i in 0..d_c {
                    s += qc[i] * kc[i];
                }
                for i in 0..d_r {
                    s += qr[i] * kr[i];
                }
                s_blk[j] = s * sq * cache.sigma_k[row] * sm_scale;
                m_cur = m_cur.max(s_blk[j]);
            }
            let m_new = m.max(m_cur);
            let mut l_cur = 0.0f32;
            let mut et_max = 0.0f32;
            let mut et = vec![0.0f32; valid];
            for j in 0..valid {
                let e = (s_blk[j] - m_new).exp();
                l_cur += e;
                // stage 2: scale fusion P' = P ⊙ S_V
                et[j] = e * cache.sigma_k[start + j];
                et_max = et_max.max(et[j]);
            }
            // stage 3: block-wise dynamic P quantization
            let sp_cur = (et_max / E4M3_MAX).max(SCALE_EPS);
            let pq: Vec<f32> = et.iter().map(|&x| e4m3_round(x / sp_cur)).collect();

            let alpha = if m > NEG_INF / 2.0 { (m - m_new).exp() } else { 0.0 };
            let gamma = alpha * sp / sp_cur;
            l = l * gamma + l_cur / sp_cur;
            blocks.push(BlockP { start, valid, pq, gamma });
            m = m_new;
            sp = sp_cur;
        }

        // ---- stage 4: PV accumulation under the selected schedule --------
        match order {
            PvOrder::Monotonic => {
                for blk in &blocks {
                    for a in acc.iter_mut() {
                        *a *= blk.gamma;
                    }
                    accumulate_pv(acc, &blk.pq, cache, blk.start, blk.valid, d_c);
                }
            }
            PvOrder::InvertedRescaleP | PvOrder::InvertedRollback => {
                let mut i = 0;
                while i < blocks.len() {
                    if i + 1 < blocks.len() {
                        let (b0, b1) = (&blocks[i], &blocks[i + 1]);
                        // rescale the accumulator straight to b1's domain
                        for a in acc.iter_mut() {
                            *a *= b0.gamma * b1.gamma;
                        }
                        // WG1 lands P1·V1 first…
                        accumulate_pv(acc, &b1.pq, cache, b1.start, b1.valid, d_c);
                        // …then P0·V0 must be folded in. b0's codes live in
                        // (m0, sp0); the exact factor from b0's domain to
                        // b1's is b1.gamma.
                        let r = b1.gamma;
                        match order {
                            PvOrder::InvertedRescaleP => {
                                // Problem 1: requantize P0 into b1's domain
                                let pq0r: Vec<f32> =
                                    b0.pq.iter().map(|&p| e4m3_round(p * r)).collect();
                                accumulate_pv(acc, &pq0r, cache, b0.start, b0.valid, d_c);
                            }
                            PvOrder::InvertedRollback => {
                                // Problem 2: roll the accumulator back to b0's
                                // domain, accumulate exactly, roll forward.
                                let inv = 1.0 / r;
                                for a in acc.iter_mut() {
                                    *a *= inv;
                                }
                                accumulate_pv(acc, &b0.pq, cache, b0.start, b0.valid, d_c);
                                for a in acc.iter_mut() {
                                    *a *= r;
                                }
                            }
                            PvOrder::Monotonic => unreachable!(),
                        }
                        i += 2;
                    } else {
                        let b0 = &blocks[i];
                        for a in acc.iter_mut() {
                            *a *= b0.gamma;
                        }
                        accumulate_pv(acc, &b0.pq, cache, b0.start, b0.valid, d_c);
                        i += 1;
                    }
                }
            }
        }

        // epilogue: o = O/L (scale domain cancels), lse = m + ln(sp·l)
        let safe_l = if l > 0.0 { l } else { 1.0 };
        for a in acc.iter_mut() {
            *a /= safe_l;
        }
        lse[head] = m + (sp * l).max(1e-37).ln();
    }

    PipelineOut { o, lse }
}

fn accumulate_pv(
    acc: &mut [f32],
    pq: &[f32],
    cache: &QuantCache,
    start: usize,
    valid: usize,
    d_c: usize,
) {
    for j in 0..valid {
        let row = start + j;
        let p = pq[j];
        if p == 0.0 {
            continue;
        }
        let kc = &cache.k_c_q[row * d_c..(row + 1) * d_c];
        for i in 0..d_c {
            acc[i] += p * kc[i];
        }
    }
}

// ---------------------------------------------------------------------------
// AMLA: exponent-ADD rescaling (arXiv 2509.25224)
// ---------------------------------------------------------------------------

/// AMLA-style base-2 online softmax with all rescale factors snapped to
/// powers of two, turning the accumulator rescale MUL into an exponent ADD.
#[derive(Clone, Copy, Debug, Default)]
pub struct Amla;

/// Per-head stage-1..3 state for the AMLA pipeline. `m` is the running max
/// on the base-2 integer grid, `l` the softmax stat in the current scale
/// domain, `sp` the (power-of-two) probability scale.
struct AmlaHead {
    blocks: Vec<BlockP>,
    m: f32,
    l: f32,
    sp: f32,
}

/// Floor for the power-of-two probability scale (replaces `SCALE_EPS`,
/// which is not a power of two and would break exact-pow2 gammas).
const AMLA_SP_FLOOR: f32 = 9.094947e-13; // 2^-40

fn amla_head_blocks(
    qc: &[f32],
    qr: &[f32],
    sq: f32,
    cache: &QuantCache,
    length: usize,
    sm_scale: f32,
    d_c: usize,
    d_r: usize,
) -> AmlaHead {
    let num_blocks = cache.n.div_ceil(BLOCK_N);
    let mut s_blk = vec![0.0f32; BLOCK_N];
    let mut blocks: Vec<BlockP> = Vec::with_capacity(num_blocks);
    let mut m = NEG_INF; // integer-grid running max of t = s·log2(e)
    let mut l = 0.0f32;
    let mut sp = 1.0f32;
    for b in 0..num_blocks {
        let start = b * BLOCK_N;
        let valid = length.saturating_sub(start).min(BLOCK_N);
        if valid == 0 {
            break;
        }
        let mut m_cur = NEG_INF;
        for j in 0..valid {
            let row = start + j;
            let kc = &cache.k_c_q[row * d_c..(row + 1) * d_c];
            let kr = &cache.k_r_al[row * d_r..(row + 1) * d_r];
            let mut s = 0.0f32;
            for i in 0..d_c {
                s += qc[i] * kc[i];
            }
            for i in 0..d_r {
                s += qr[i] * kr[i];
            }
            // base-2 logit: t = s·sq·sk·sm·log2(e)
            s_blk[j] = s * sq * cache.sigma_k[row] * sm_scale * std::f32::consts::LOG2_E;
            m_cur = m_cur.max(s_blk[j]);
        }
        // running max snapped UP to the integer grid → exp2(m - m_new) of
        // any later rescale is an exact power of two
        let m_new = m.max(m_cur.ceil());
        let mut l_cur = 0.0f32;
        let mut et_max = 0.0f32;
        let mut et = vec![0.0f32; valid];
        for j in 0..valid {
            let e = (s_blk[j] - m_new).exp2(); // e ∈ (0, 1]
            l_cur += e;
            et[j] = e * cache.sigma_k[start + j];
            et_max = et_max.max(et[j]);
        }
        // sigma_P snapped to a power of two with 8 bits of headroom:
        // codes et/sp ∈ (2^7, 2^8] ≤ 256 < 448 — never saturates.
        let sp_cur = if et_max > 0.0 {
            (et_max.log2().ceil() - 8.0).exp2().max(AMLA_SP_FLOOR)
        } else {
            AMLA_SP_FLOOR
        };
        let pq: Vec<f32> = et.iter().map(|&x| e4m3_round(x / sp_cur)).collect();

        // alpha = 2^(m - m_new) with both on the integer grid, and sp/sp_cur
        // a ratio of powers of two: gamma is an EXACT power of two, so the
        // accumulator rescale is a lossless exponent add.
        let alpha = if m > NEG_INF / 2.0 { (m - m_new).exp2() } else { 0.0 };
        let gamma = alpha * sp / sp_cur;
        l = l * gamma + l_cur / sp_cur;
        blocks.push(BlockP { start, valid, pq, gamma });
        m = m_new;
        sp = sp_cur;
    }
    AmlaHead { blocks, m, l, sp }
}

impl KernelVariant for Amla {
    fn kind(&self) -> VariantKind {
        VariantKind::Amla
    }

    fn pipeline(
        &self,
        shape: &Shape,
        q_c_q: &[f32],
        sigma_q: &[f32],
        q_r_al: &[f32],
        cache: &QuantCache,
        length: usize,
        sm_scale: f32,
    ) -> PipelineOut {
        let (h, d_c, d_r) = (shape.heads, shape.d_c, shape.d_r);
        assert!(length <= cache.n);
        let mut o = vec![0.0f32; h * d_c];
        let mut lse = vec![0.0f32; h];
        for head in 0..h {
            let qc = &q_c_q[head * d_c..(head + 1) * d_c];
            let qr = &q_r_al[head * d_r..(head + 1) * d_r];
            let state =
                amla_head_blocks(qc, qr, sigma_q[head], cache, length, sm_scale, d_c, d_r);
            let acc = &mut o[head * d_c..(head + 1) * d_c];
            for blk in &state.blocks {
                for a in acc.iter_mut() {
                    *a *= blk.gamma;
                }
                accumulate_pv(acc, &blk.pq, cache, blk.start, blk.valid, d_c);
            }
            let safe_l = if state.l > 0.0 { state.l } else { 1.0 };
            for a in acc.iter_mut() {
                *a /= safe_l;
            }
            // lse in base e: m·ln2 + ln(sp·l)
            lse[head] = state.m * std::f32::consts::LN_2
                + (state.sp * state.l).max(1e-37).ln();
        }
        PipelineOut { o, lse }
    }
}

// ---------------------------------------------------------------------------
// P-Cast: fixed-scale probability cast (arXiv 2606.06521)
// ---------------------------------------------------------------------------

/// P-Cast-style pipeline: the probability cast uses the static scale
/// S = 2^8 (no per-block amax pass, no scale fusion); value scales are
/// applied unfused in the PV stage.
#[derive(Clone, Copy, Debug, Default)]
pub struct PCast;

impl KernelVariant for PCast {
    fn kind(&self) -> VariantKind {
        VariantKind::PCast
    }

    fn pipeline(
        &self,
        shape: &Shape,
        q_c_q: &[f32],
        sigma_q: &[f32],
        q_r_al: &[f32],
        cache: &QuantCache,
        length: usize,
        sm_scale: f32,
    ) -> PipelineOut {
        let (h, d_c, d_r) = (shape.heads, shape.d_c, shape.d_r);
        assert!(length <= cache.n);
        let num_blocks = cache.n.div_ceil(BLOCK_N);
        let mut o = vec![0.0f32; h * d_c];
        let mut lse = vec![0.0f32; h];
        let mut s_blk = vec![0.0f32; BLOCK_N];
        for head in 0..h {
            let qc = &q_c_q[head * d_c..(head + 1) * d_c];
            let qr = &q_r_al[head * d_r..(head + 1) * d_r];
            let sq = sigma_q[head];
            let mut m = NEG_INF;
            let mut l = 0.0f32;
            let acc = &mut o[head * d_c..(head + 1) * d_c];
            for b in 0..num_blocks {
                let start = b * BLOCK_N;
                let valid = length.saturating_sub(start).min(BLOCK_N);
                if valid == 0 {
                    break;
                }
                let mut m_cur = NEG_INF;
                for j in 0..valid {
                    let row = start + j;
                    let kc = &cache.k_c_q[row * d_c..(row + 1) * d_c];
                    let kr = &cache.k_r_al[row * d_r..(row + 1) * d_r];
                    let mut s = 0.0f32;
                    for i in 0..d_c {
                        s += qc[i] * kc[i];
                    }
                    for i in 0..d_r {
                        s += qr[i] * kr[i];
                    }
                    s_blk[j] = s * sq * cache.sigma_k[row] * sm_scale;
                    m_cur = m_cur.max(s_blk[j]);
                }
                let m_new = m.max(m_cur);
                let alpha = if m > NEG_INF / 2.0 { (m - m_new).exp() } else { 0.0 };
                // accumulator rescale is alpha alone: the probability scale
                // domain is fixed (S = 2^8), only the max shifts.
                for a in acc.iter_mut() {
                    *a *= alpha;
                }
                let mut l_cur = 0.0f32;
                for j in 0..valid {
                    let row = start + j;
                    let e = (s_blk[j] - m_new).exp(); // e ∈ (0, 1]
                    l_cur += e;
                    // static-scale cast: codes ≤ 256 < 448, no amax pass
                    let p = e4m3_round(e * PCAST_P_SCALE);
                    if p == 0.0 {
                        continue;
                    }
                    // value scale applied unfused in the PV accumulation
                    let w = p * cache.sigma_k[row];
                    let kc = &cache.k_c_q[row * d_c..(row + 1) * d_c];
                    for i in 0..d_c {
                        acc[i] += w * kc[i];
                    }
                }
                l = l * alpha + l_cur;
                m = m_new;
            }
            let safe_l = if l > 0.0 { l } else { 1.0 };
            for a in acc.iter_mut() {
                *a /= PCAST_P_SCALE * safe_l;
            }
            lse[head] = m + l.max(1e-37).ln();
        }
        PipelineOut { o, lse }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::mla::ref_attn;
    use crate::mla::{decode, Cache, Shape};
    use crate::util::rng::Rng;
    use crate::util::stats::rel_l2;

    fn case(seed: u64, n: usize, shape: &Shape) -> (Query, Cache) {
        let mut rng = Rng::new(seed);
        let q = Query {
            q_c: rng.normal_vec(shape.heads * shape.d_c, 1.0),
            q_r: rng.normal_vec(shape.heads * shape.d_r, 0.3),
        };
        let mut cache = Cache::new(n, shape);
        cache.k_c = rng.normal_vec(n * shape.d_c, 2.0);
        cache.k_r = rng.normal_vec(n * shape.d_r, 8.0);
        (q, cache)
    }

    #[test]
    fn kind_roundtrips_through_parse() {
        for kind in VariantKind::ALL {
            assert_eq!(VariantKind::parse(kind.name()), Some(kind));
            assert_eq!(kind.instance().kind(), kind);
        }
        assert_eq!(VariantKind::parse("flashmla"), None);
    }

    #[test]
    fn every_variant_matches_reference_within_quant_error() {
        let shape = Shape { heads: 4, d_c: 64, d_r: 16 };
        // per-variant tolerance: SnapMLA's dynamic scale is tightest; AMLA's
        // pow2-snapped scale and P-Cast's static scale give up a little
        // mantissa headroom but must stay in the same error regime.
        let tol = [
            (VariantKind::SnapMla, 0.09),
            (VariantKind::Amla, 0.12),
            (VariantKind::PCast, 0.15),
        ];
        for seed in [1, 2, 3] {
            let (q, cache) = case(seed, 256, &shape);
            let sm = shape.sm_scale();
            let want = ref_attn::attention(&shape, &q, &cache, 200, sm);
            for (kind, max_rel) in tol {
                let got = decode(kind, &shape, &q, &cache.k_c, &cache.k_r, 200, sm);
                let rel = rel_l2(&got.o, &want.o);
                assert!(rel < max_rel, "{kind:?} seed {seed}: rel {rel}");
                for h in 0..shape.heads {
                    assert!(
                        (got.lse[h] - want.lse[h]).abs() < 0.06,
                        "{kind:?} lse head {h}: {} vs {}",
                        got.lse[h],
                        want.lse[h]
                    );
                }
            }
        }
    }

    #[test]
    fn variants_match_over_block_boundaries() {
        let shape = Shape { heads: 2, d_c: 32, d_r: 8 };
        let (q, cache) = case(6, 192, &shape);
        let sm = shape.sm_scale();
        for length in [1, 63, 64, 65, 128, 191] {
            let want = ref_attn::attention(&shape, &q, &cache, length, sm);
            for kind in VariantKind::ALL {
                let got = decode(kind, &shape, &q, &cache.k_c, &cache.k_r, length, sm);
                let rel = rel_l2(&got.o, &want.o);
                assert!(rel < 0.15, "{kind:?} length {length}: rel {rel}");
            }
        }
    }

    #[test]
    fn variants_mask_the_tail() {
        let shape = Shape { heads: 2, d_c: 32, d_r: 8 };
        let (q, mut cache) = case(5, 192, &shape);
        let sm = shape.sm_scale();
        for kind in VariantKind::ALL {
            let a = decode(kind, &shape, &q, &cache.k_c, &cache.k_r, 100, sm);
            for j in 100..192 {
                for i in 0..32 {
                    cache.k_c[j * 32 + i] = 1e5;
                }
            }
            let b = decode(kind, &shape, &q, &cache.k_c, &cache.k_r, 100, sm);
            assert_eq!(a.o, b.o, "{kind:?}");
            for j in 100..192 {
                for i in 0..32 {
                    cache.k_c[j * 32 + i] = 0.0;
                }
            }
        }
    }

    #[test]
    fn amla_gammas_are_exact_powers_of_two() {
        let shape = Shape { heads: 2, d_c: 32, d_r: 8 };
        for seed in [1u64, 7, 42] {
            let (q, cache) = case(seed, 256, &shape);
            let amla = Amla;
            let qcache = amla.build_cache(&shape, &cache.k_c, &cache.k_r, 256);
            let qq = amla.quantize_query(&shape, &q);
            for head in 0..shape.heads {
                let st = amla_head_blocks(
                    &qq.q_c_q[head * 32..(head + 1) * 32],
                    &qq.q_r_al[head * 8..(head + 1) * 8],
                    qq.sigma_q[head],
                    &qcache,
                    256,
                    shape.sm_scale(),
                    32,
                    8,
                );
                assert!(!st.blocks.is_empty());
                for blk in &st.blocks {
                    let g = blk.gamma;
                    // exact power of two ⇔ zero mantissa bits (0.0 for the
                    // first block, whose alpha is 0)
                    assert!(
                        g == 0.0 || (g.to_bits() & 0x007F_FFFF) == 0,
                        "seed {seed} head {head}: gamma {g} not a power of two"
                    );
                }
                // the power-of-two sigma_P never saturates the FP8 grid
                for blk in &st.blocks {
                    for &p in &blk.pq {
                        assert!(p <= 256.0, "code {p} above the 2^8 headroom");
                    }
                }
            }
        }
    }

    #[test]
    fn pcast_codes_never_saturate() {
        // block-local e ≤ 1 ⇒ codes ≤ 256 < 448 by construction: the static
        // scale cannot saturate no matter the value distribution.
        let shape = Shape { heads: 1, d_c: 32, d_r: 8 };
        let mut rng = Rng::new(17);
        let n = 256;
        let mut k_c = rng.normal_vec(n * 32, 1.0);
        for i in 0..32 {
            k_c[i] *= 1e5; // violent sink token
        }
        let k_r = rng.normal_vec(n * 8, 2.0);
        let q = Query { q_c: rng.normal_vec(32, 1.0), q_r: rng.normal_vec(8, 0.3) };
        let out = decode(VariantKind::PCast, &shape, &q, &k_c, &k_r, n, shape.sm_scale());
        assert!(out.o.iter().all(|x| x.is_finite()));
        assert!(out.lse.iter().all(|x| x.is_finite()));
    }

    #[test]
    fn cache_policy_backs_every_quant_config() {
        use crate::mla::quant_configs::QuantConfig;
        use crate::mla::synth;
        let shape = Shape { heads: 1, d_c: 64, d_r: 16 };
        let mut rng = Rng::new(5);
        let cache = Cache {
            k_c: synth::content(&mut rng, 256, shape.d_c),
            k_r: synth::rope(&mut rng, 256, shape.d_r),
            n: 256,
        };
        for (cfg, policy) in [
            (QuantConfig::SnapMla, CachePolicy::PerTokenRopeAware),
            (QuantConfig::ConfigA, CachePolicy::PerTokenCoupled),
            (QuantConfig::ConfigB, CachePolicy::PerTensorStatic),
            (QuantConfig::ConfigC, CachePolicy::PerTensorDynamic),
            (QuantConfig::ConfigD, CachePolicy::PerBlock),
        ] {
            assert_eq!(cfg.cache_policy(), policy);
            let a = cfg.apply(&shape, &cache);
            let b = policy.apply(&shape, &cache);
            assert_eq!(a.k_c, b.k_c, "{cfg:?}");
            assert_eq!(a.k_r, b.k_r, "{cfg:?}");
        }
    }

    // ---- Appendix-E PV ordering study (moved from mla::pipeline) ---------

    #[test]
    fn rollback_agrees_on_benign_data() {
        // Rollback is algebraically exact; on benign data (f32 headroom) it
        // coincides with the monotonic order. Rescale-P does NOT in general:
        // requantizing P0 saturates whenever the domain ratio exceeds 1 —
        // the "irreversible precision loss" of Problem 1 is present even in
        // ordinary operation, which is exactly why the paper rejects it.
        let shape = Shape { heads: 2, d_c: 32, d_r: 8 };
        let (q, cache) = case(4, 256, &shape);
        let sm = shape.sm_scale();
        let dec = |order| {
            SnapMla::with_order(order).decode(&shape, &q, &cache.k_c, &cache.k_r, 256, sm)
        };
        let mono = dec(PvOrder::Monotonic);
        let roll = dec(PvOrder::InvertedRollback);
        let rel = rel_l2(&roll.o, &mono.o);
        assert!(rel < 0.02, "rollback diverged on benign data: {rel}");
        let resc = dec(PvOrder::InvertedRescaleP);
        assert!(resc.o.iter().all(|x| x.is_finite()));
    }

    fn adversarial_case(seed: u64, n: usize, shape: &Shape) -> (Query, Vec<f32>, Vec<f32>) {
        // Problem-1 trigger: within each block PAIR, the FIRST block holds a
        // sink token (huge value magnitude → huge sigma_V → huge sigma_P)
        // that dominates the attention output, while the second block is
        // weak (tiny values → tiny sigma_P). The domain ratio r = sp0/sp1 is
        // then >> 1, and requantizing the already-FP8 P0 into P1's domain
        // SATURATES its dominant entries at 448 — the "large rescaling
        // factor disrupts its value distribution" failure of App. E. Logits
        // are kept moderate and value-independent (tiny q_c, rope-driven) so
        // probability mass is spread and the effect is purely scale-driven.
        let mut rng = Rng::new(seed);
        let mut k_c = rng.normal_vec(n * shape.d_c, 1e-2);
        let k_r = rng.normal_vec(n * shape.d_r, 1.0);
        for b in (0..(n / BLOCK_N)).step_by(2) {
            let sink = b * BLOCK_N; // first token of each even block
            for i in 0..shape.d_c {
                k_c[sink * shape.d_c + i] *= 1e6; // values ~1e4
            }
        }
        let q = Query {
            q_c: rng.normal_vec(shape.heads * shape.d_c, 1e-3),
            q_r: rng.normal_vec(shape.heads * shape.d_r, 0.6),
        };
        (q, k_c, k_r)
    }

    #[test]
    fn inverted_rescale_p_degrades_on_adversarial_scales() {
        let shape = Shape { heads: 1, d_c: 32, d_r: 8 };
        let n = 256;
        let (q, k_c, k_r) = adversarial_case(9, n, &shape);
        let sm = shape.sm_scale();
        let exact = {
            let cache = Cache { k_c: k_c.clone(), k_r: k_r.clone(), n };
            ref_attn::attention(&shape, &q, &cache, n, sm)
        };
        let dec = |order| SnapMla::with_order(order).decode(&shape, &q, &k_c, &k_r, n, sm);
        let mono = dec(PvOrder::Monotonic);
        let resc = dec(PvOrder::InvertedRescaleP);
        let e_mono = rel_l2(&mono.o, &exact.o);
        let e_resc = rel_l2(&resc.o, &exact.o);
        assert!(
            e_resc > 2.0 * e_mono,
            "rescale-P should degrade: mono {e_mono} vs rescale {e_resc}"
        );
    }

    #[test]
    fn monotonic_stable_on_adversarial_scales() {
        let shape = Shape { heads: 1, d_c: 32, d_r: 8 };
        let n = 256;
        let (q, k_c, k_r) = adversarial_case(11, n, &shape);
        let sm = shape.sm_scale();
        let exact = {
            let cache = Cache { k_c: k_c.clone(), k_r: k_r.clone(), n };
            ref_attn::attention(&shape, &q, &cache, n, sm)
        };
        let mono = decode(VariantKind::SnapMla, &shape, &q, &k_c, &k_r, n, sm);
        let rel = rel_l2(&mono.o, &exact.o);
        assert!(rel < 0.1, "monotonic should stay stable: {rel}");
        assert!(mono.o.iter().all(|x| x.is_finite()));
    }
}
