//! f32 reference MLA decode attention (the oracle the pipeline is tested
//! against; V = latent content per the absorbed form, paper Eq. 5).

use super::{Cache, Query, Shape};

/// Output of one decode-attention call.
#[derive(Clone, Debug)]
pub struct AttnOut {
    /// row-major [heads, d_c]
    pub o: Vec<f32>,
    /// per-head logsumexp
    pub lse: Vec<f32>,
}

/// Full-precision decode attention of `q` over the first `length` cache rows.
pub fn attention(shape: &Shape, q: &Query, cache: &Cache, length: usize, sm_scale: f32) -> AttnOut {
    attention_with_values(shape, q, &cache.k_c, &cache.k_r, length, sm_scale)
}

/// Same, over explicit (possibly dequantized) key/value buffers.
pub fn attention_with_values(
    shape: &Shape,
    q: &Query,
    k_c: &[f32],
    k_r: &[f32],
    length: usize,
    sm_scale: f32,
) -> AttnOut {
    let (h, d_c, d_r) = (shape.heads, shape.d_c, shape.d_r);
    assert!(length * d_c <= k_c.len() && length * d_r <= k_r.len());
    let mut o = vec![0.0f32; h * d_c];
    let mut lse = vec![0.0f32; h];

    let mut logits = vec![0.0f32; length];
    for head in 0..h {
        let qc = &q.q_c[head * d_c..(head + 1) * d_c];
        let qr = &q.q_r[head * d_r..(head + 1) * d_r];
        for j in 0..length {
            let kc = &k_c[j * d_c..(j + 1) * d_c];
            let kr = &k_r[j * d_r..(j + 1) * d_r];
            let mut s = 0.0f32;
            for i in 0..d_c {
                s += qc[i] * kc[i];
            }
            for i in 0..d_r {
                s += qr[i] * kr[i];
            }
            logits[j] = s * sm_scale;
        }
        let m = logits.iter().cloned().fold(f32::NEG_INFINITY, f32::max);
        let mut l = 0.0f32;
        for j in 0..length {
            logits[j] = (logits[j] - m).exp();
            l += logits[j];
        }
        let out = &mut o[head * d_c..(head + 1) * d_c];
        for j in 0..length {
            let p = logits[j] / l;
            let kc = &k_c[j * d_c..(j + 1) * d_c];
            for i in 0..d_c {
                out[i] += p * kc[i];
            }
        }
        lse[head] = m + l.ln();
    }
    AttnOut { o, lse }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    fn rand_case(seed: u64, n: usize, shape: &Shape) -> (Query, Cache) {
        let mut rng = Rng::new(seed);
        let q = Query {
            q_c: rng.normal_vec(shape.heads * shape.d_c, 1.0),
            q_r: rng.normal_vec(shape.heads * shape.d_r, 0.5),
        };
        let mut cache = Cache::new(n, shape);
        cache.k_c = rng.normal_vec(n * shape.d_c, 2.0);
        cache.k_r = rng.normal_vec(n * shape.d_r, 2.0);
        (q, cache)
    }

    #[test]
    fn single_token_returns_that_value() {
        let shape = Shape { heads: 2, d_c: 8, d_r: 4 };
        let (q, cache) = rand_case(1, 4, &shape);
        let out = attention(&shape, &q, &cache, 1, 0.1);
        for head in 0..2 {
            for i in 0..8 {
                assert!((out.o[head * 8 + i] - cache.k_c[i]).abs() < 1e-6);
            }
        }
    }

    #[test]
    fn identical_keys_give_mean_value() {
        let shape = Shape { heads: 1, d_c: 4, d_r: 2 };
        let n = 6;

        let mut cache = Cache::new(n, &shape);
        for j in 0..n {
            for i in 0..4 {
                cache.k_c[j * 4 + i] = (j + i) as f32; // varying values…
            }
        }
        // …but identical keys → set content equal per row for the K side?
        // Instead: make all logits equal by zeroing q.
        let q0 = Query { q_c: vec![0.0; 4], q_r: vec![0.0; 2] };
        let out = attention(&shape, &q0, &cache, n, 0.5);
        for i in 0..4 {
            let mean: f32 = (0..n).map(|j| cache.k_c[j * 4 + i]).sum::<f32>() / n as f32;
            assert!((out.o[i] - mean).abs() < 1e-5);
        }
    }

    #[test]
    fn lse_matches_direct() {
        let shape = Shape { heads: 3, d_c: 16, d_r: 8 };
        let (q, cache) = rand_case(2, 32, &shape);
        let sm = shape.sm_scale();
        let out = attention(&shape, &q, &cache, 32, sm);
        for head in 0..3 {
            let mut direct = 0.0f64;
            let mut logits = Vec::new();
            for j in 0..32 {
                let mut s = 0.0f32;
                for i in 0..16 {
                    s += q.q_c[head * 16 + i] * cache.k_c[j * 16 + i];
                }
                for i in 0..8 {
                    s += q.q_r[head * 8 + i] * cache.k_r[j * 8 + i];
                }
                logits.push((s * sm) as f64);
            }
            let m = logits.iter().cloned().fold(f64::NEG_INFINITY, f64::max);
            for &l in &logits {
                direct += (l - m).exp();
            }
            let want = m + direct.ln();
            assert!((out.lse[head] as f64 - want).abs() < 1e-4);
        }
    }

    #[test]
    fn length_masks_tail() {
        let shape = Shape { heads: 1, d_c: 8, d_r: 4 };
        let (q, mut cache) = rand_case(3, 16, &shape);
        let out1 = attention(&shape, &q, &cache, 10, 0.2);
        for j in 10..16 {
            for i in 0..8 {
                cache.k_c[j * 8 + i] = 1e6;
            }
        }
        let out2 = attention(&shape, &q, &cache, 10, 0.2);
        assert_eq!(out1.o, out2.o);
    }

    #[test]
    fn softmax_weights_sum_property() {
        // o lies in the convex hull of the value rows (per coordinate within
        // [min, max] of values).
        let shape = Shape { heads: 2, d_c: 8, d_r: 4 };
        let (q, cache) = rand_case(4, 24, &shape);
        let out = attention(&shape, &q, &cache, 24, 0.1);
        for i in 0..8 {
            let (mut lo, mut hi) = (f32::INFINITY, f32::NEG_INFINITY);
            for j in 0..24 {
                lo = lo.min(cache.k_c[j * 8 + i]);
                hi = hi.max(cache.k_c[j * 8 + i]);
            }
            for head in 0..2 {
                let v = out.o[head * 8 + i];
                assert!(v >= lo - 1e-5 && v <= hi + 1e-5);
            }
        }
    }
}
