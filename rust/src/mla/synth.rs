//! Synthetic MLA KV-cache statistics matched to the paper's Fig. 3a
//! (mirrors `python/compile/kernels/synthkv.py` — see that module's docstring
//! for the mechanism rationale: sink tokens + massive phase-coherent RoPE
//! channels).

use crate::util::rng::Rng;

pub const ROPE_MASSIVE_AMP: f32 = 800.0;
pub const ROPE_MASSIVE_AMP2: f32 = 250.0;
pub const ROPE_BULK_SCALE: f32 = 20.0;
pub const CONTENT_SCALE: f32 = 2.5;
pub const CONTENT_TOKEN_SPREAD: f64 = 1.0;
pub const SINK_FRACTION: f64 = 0.01;
pub const SINK_MAGNIFICATION: f32 = 40.0;

/// Latent content cache [n, d_c]: Gaussian bulk x lognormal token spread
/// plus sparse sink tokens.
pub fn content(rng: &mut Rng, n: usize, d_c: usize) -> Vec<f32> {
    let mut out = vec![0.0f32; n * d_c];
    let n_sink = ((n as f64 * SINK_FRACTION) as usize).max(1);
    let mut sinks = vec![false; n];
    for _ in 0..n_sink {
        sinks[rng.below(n)] = true;
    }
    for j in 0..n {
        let tok_scale = rng.lognormal(0.0, CONTENT_TOKEN_SPREAD) as f32;
        let mag = if sinks[j] { SINK_MAGNIFICATION } else { 1.0 };
        for i in 0..d_c {
            out[j * d_c + i] = rng.normal() as f32 * CONTENT_SCALE * tok_scale * mag;
        }
    }
    out
}

/// Decoupled RoPE cache [n, d_r] with phase-coherent massive channel pairs.
pub fn rope(rng: &mut Rng, n: usize, d_r: usize) -> Vec<f32> {
    assert!(d_r >= 4);
    let mut out = vec![0.0f32; n * d_r];
    for j in 0..n {
        for i in 0..d_r {
            out[j * d_r + i] = rng.normal() as f32 * ROPE_BULK_SCALE;
        }
    }
    for (c0, amp, omega) in [(0usize, ROPE_MASSIVE_AMP, 0.013f64), (2, ROPE_MASSIVE_AMP2, 0.11)] {
        let phi = rng.range_f64(0.0, std::f64::consts::TAU);
        for j in 0..n {
            let phase = j as f64 * omega + phi + rng.normal_scaled(0.0, 0.05);
            let jitter = |r: &mut Rng| 1.0 + r.normal_scaled(0.0, 0.02) as f32;
            out[j * d_r + c0] = amp * phase.cos() as f32 * jitter(rng);
            out[j * d_r + c0 + 1] = amp * phase.sin() as f32 * jitter(rng);
        }
    }
    out
}

/// Queries giving realistic logit composition (positional swings of
/// ~±rope_logit_amp plus a content term of std ~content_logit_std).
pub fn queries(
    rng: &mut Rng,
    heads: usize,
    d_c: usize,
    d_r: usize,
    sm_scale: f32,
    rope_logit_amp: f32,
    content_logit_std: f32,
) -> (Vec<f32>, Vec<f32>) {
    let qs = content_logit_std / (CONTENT_SCALE * (d_c as f32).sqrt() * sm_scale);
    let row_std = qs / (d_c as f32).sqrt();
    let mut q_c = vec![0.0f32; heads * d_c];
    for x in q_c.iter_mut() {
        *x = rng.normal() as f32 * row_std * (d_c as f32).sqrt() / (d_c as f32).sqrt();
    }
    // normalize rows to the target rms
    for h in 0..heads {
        let row = &mut q_c[h * d_c..(h + 1) * d_c];
        let rms = (row.iter().map(|&x| (x * x) as f64).sum::<f64>() / d_c as f64).sqrt() as f32;
        let target = qs / (d_c as f32).sqrt();
        if rms > 0.0 {
            for x in row.iter_mut() {
                *x *= target / rms;
            }
        }
    }
    let mut q_r = vec![0.0f32; heads * d_r];
    for x in q_r.iter_mut() {
        *x = rng.normal() as f32 * 0.02;
    }
    let b = rope_logit_amp / (ROPE_MASSIVE_AMP * sm_scale);
    let b2 = 0.4 * rope_logit_amp / (ROPE_MASSIVE_AMP2 * sm_scale);
    for h in 0..heads {
        let psi = rng.range_f64(0.0, std::f64::consts::TAU);
        q_r[h * d_r] = b * psi.cos() as f32;
        q_r[h * d_r + 1] = b * psi.sin() as f32;
        let psi2 = rng.range_f64(0.0, std::f64::consts::TAU);
        q_r[h * d_r + 2] = b2 * psi2.cos() as f32;
        q_r[h * d_r + 3] = b2 * psi2.sin() as f32;
    }
    (q_c, q_r)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn content_matches_paper_ranges() {
        let mut rng = Rng::new(1);
        let xs = content(&mut rng, 4096, 128);
        // bulk concentrated: 99th percentile of |x| below ~60
        let mut abs: Vec<f32> = xs.iter().map(|x| x.abs()).collect();
        abs.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let p99 = abs[(abs.len() as f64 * 0.99) as usize];
        assert!(p99 < 100.0, "{p99}");
        // sinks push the max well beyond the E4M3 range
        assert!(abs[abs.len() - 1] > 448.0);
    }

    #[test]
    fn rope_reaches_e3_and_is_heavy_tailed() {
        let mut rng = Rng::new(2);
        let xs = rope(&mut rng, 4096, 32);
        let amax = xs.iter().fold(0.0f32, |m, &x| m.max(x.abs()));
        assert!(amax > 500.0, "{amax}");
        let mut abs: Vec<f32> = xs.iter().map(|x| x.abs()).collect();
        abs.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let median = abs[abs.len() / 2];
        assert!(median < 60.0, "{median}"); // bulk is moderate
    }

    #[test]
    fn rope_massive_channels_are_phase_coherent() {
        // cos²+sin² of the massive pair ≈ amp² per token
        let mut rng = Rng::new(3);
        let d_r = 16;
        let xs = rope(&mut rng, 512, d_r);
        for j in 0..512 {
            let c = xs[j * d_r];
            let s = xs[j * d_r + 1];
            let r = (c * c + s * s).sqrt();
            assert!((r / ROPE_MASSIVE_AMP - 1.0).abs() < 0.15, "token {j}: {r}");
        }
    }

    #[test]
    fn queries_give_moderate_logits() {
        let mut rng = Rng::new(4);
        let (d_c, d_r, h) = (128, 32, 8);
        let sm = 1.0 / ((d_c + d_r) as f32).sqrt();
        let k_c = content(&mut rng, 512, d_c);
        let k_r = rope(&mut rng, 512, d_r);
        let (q_c, q_r) = queries(&mut rng, h, d_c, d_r, sm, 4.0, 2.0);
        let mut logits = Vec::new();
        for head in 0..h {
            for j in 0..512 {
                let mut s = 0.0f32;
                for i in 0..d_c {
                    s += q_c[head * d_c + i] * k_c[j * d_c + i];
                }
                for i in 0..d_r {
                    s += q_r[head * d_r + i] * k_r[j * d_r + i];
                }
                logits.push((s * sm) as f64);
            }
        }
        let n = logits.len() as f64;
        let mean = logits.iter().sum::<f64>() / n;
        let std = (logits.iter().map(|x| (x - mean).powi(2)).sum::<f64>() / n).sqrt();
        assert!(std > 1.0 && std < 30.0, "logit std {std}");
    }
}
