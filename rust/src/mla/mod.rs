//! MLA decode-attention math in rust: the f32 oracle, the exact SnapMLA
//! Algorithm-1 software pipeline (incl. the Appendix-E dual-warp-group
//! ordering hazards), Table-3 quantization configs, synthetic KV statistics
//! and fidelity metrics.
//!
//! This module is the *numerics twin* of the Pallas kernel: it shares the
//! E4M3/BF16 grid with `crate::fp8` (itself bit-matched to the python side),
//! so pipeline properties proven here transfer to the kernel. It also powers
//! the long-context fidelity bench (Fig. 5) where running the interpret-mode
//! kernel at 32k tokens would be impractical.

pub mod fidelity;
pub mod pipeline;
pub mod quant_configs;
pub mod ref_attn;
pub mod study;
pub mod synth;
pub mod variant;

pub use variant::{KernelVariant, VariantKind};

/// Shape of one decode-attention call (T*H query rows over an N-token cache).
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct Shape {
    pub heads: usize,
    pub d_c: usize,
    pub d_r: usize,
}

impl Shape {
    pub fn sm_scale(&self) -> f32 {
        1.0 / ((self.d_c + self.d_r) as f32).sqrt()
    }

    /// The paper's kernel shape (DeepSeek-V3: nine 64-wide QK groups).
    pub fn paper(heads: usize) -> Shape {
        Shape { heads, d_c: 512, d_r: 64 }
    }

    /// The small serving model's shape.
    pub fn small() -> Shape {
        Shape { heads: 8, d_c: 128, d_r: 32 }
    }
}

/// The single decode entry point: run one decode-attention step under the
/// selected kernel variant (quantize the operands with the variant's hooks,
/// then its pipeline). The sole successor of the retired free functions
/// `pipeline::snapmla_decode` / `pipeline::snapmla_pipeline`.
pub fn decode(
    variant: VariantKind,
    shape: &Shape,
    q: &Query,
    k_c: &[f32],
    k_r: &[f32],
    length: usize,
    sm_scale: f32,
) -> variant::PipelineOut {
    variant.instance().decode(shape, q, k_c, k_r, length, sm_scale)
}

/// Query operands for one decode step: row-major [heads, d_c] / [heads, d_r].
#[derive(Clone, Debug)]
pub struct Query {
    pub q_c: Vec<f32>,
    pub q_r: Vec<f32>,
}

/// Full-precision KV cache: row-major [n, d_c] content + [n, d_r] rope.
#[derive(Clone, Debug)]
pub struct Cache {
    pub k_c: Vec<f32>,
    pub k_r: Vec<f32>,
    pub n: usize,
}

impl Cache {
    pub fn new(n: usize, shape: &Shape) -> Cache {
        Cache { k_c: vec![0.0; n * shape.d_c], k_r: vec![0.0; n * shape.d_r], n }
    }
}
