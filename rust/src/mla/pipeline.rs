//! Legacy SnapMLA pipeline entry points — deprecated shims over the
//! [`crate::mla::variant`] API (kept for one release).
//!
//! The exact Algorithm-1 implementation (including the Appendix-E
//! dual-warp-group ordering study) moved verbatim into `mla::variant`,
//! where it is the [`crate::mla::variant::SnapMla`] kernel variant. New code
//! should call [`crate::mla::decode`] with a [`crate::mla::VariantKind`], or
//! go through [`crate::mla::variant::KernelVariant`] for the staged
//! (build-cache / quantize-query / pipeline) form. The shims here delegate
//! to the exact same implementation, so they remain byte-identical to the
//! pre-refactor pipeline (pinned by `tests/prop_variants.rs`).

use super::variant::{self, SnapMla};
use super::{Query, Shape};

pub use super::variant::{PipelineOut, PvOrder, QuantCache, BLOCK_N};

/// Fused-K-Append over a full cache: per-token quantize + domain-align.
#[deprecated(since = "0.6.0", note = "use KernelVariant::build_cache (mla::variant)")]
pub fn build_quant_cache(shape: &Shape, k_c: &[f32], k_r: &[f32], n: usize) -> QuantCache {
    variant::snapmla_build_cache(shape, k_c, k_r, n)
}

/// Fused-Q-Quant: per-head-row quantize + align. Returns (q_c_q, sigma_q, q_r_al).
#[deprecated(since = "0.6.0", note = "use KernelVariant::quantize_query (mla::variant)")]
pub fn quantize_query(shape: &Shape, q: &Query) -> (Vec<f32>, Vec<f32>, Vec<f32>) {
    let qq = variant::snapmla_quantize_query(shape, q);
    (qq.q_c_q, qq.sigma_q, qq.q_r_al)
}

/// Run the SnapMLA pipeline for one decode step.
#[deprecated(since = "0.6.0", note = "use KernelVariant::pipeline (mla::variant)")]
#[allow(clippy::too_many_arguments)]
pub fn snapmla_pipeline(
    shape: &Shape,
    q_c_q: &[f32],
    sigma_q: &[f32],
    q_r_al: &[f32],
    cache: &QuantCache,
    length: usize,
    sm_scale: f32,
    order: PvOrder,
) -> PipelineOut {
    variant::snapmla_pipeline_impl(shape, q_c_q, sigma_q, q_r_al, cache, length, sm_scale, order)
}

/// Convenience: full SnapMLA decode from f32 operands (quantize + pipeline).
#[deprecated(since = "0.6.0", note = "use mla::decode(VariantKind::SnapMla, ...)")]
pub fn snapmla_decode(
    shape: &Shape,
    q: &Query,
    k_c: &[f32],
    k_r: &[f32],
    length: usize,
    sm_scale: f32,
    order: PvOrder,
) -> PipelineOut {
    use super::variant::KernelVariant;
    SnapMla::with_order(order).decode(shape, q, k_c, k_r, length, sm_scale)
}
