//! Retired module — the legacy SnapMLA pipeline entry points are gone.
//!
//! The deprecated 0.6.0 shims (`build_quant_cache`, `quantize_query`,
//! `snapmla_pipeline`, `snapmla_decode`) lived here for one release and have
//! been removed. The exact Algorithm-1 implementation (including the
//! Appendix-E dual-warp-group ordering study) lives in [`crate::mla::variant`]
//! as the [`crate::mla::variant::SnapMla`] kernel variant:
//!
//! * one-shot decode — [`crate::mla::decode`] with a
//!   [`crate::mla::VariantKind`];
//! * staged form — [`crate::mla::variant::KernelVariant`]'s
//!   `build_cache` / `quantize_query` / `pipeline` methods, or the free
//!   functions [`crate::mla::variant::snapmla_build_cache`] /
//!   [`crate::mla::variant::snapmla_quantize_query`].
//!
//! The staged-vs-one-shot byte identity the shims used to pin is still
//! enforced by `tests/prop_variants.rs`.
