//! The SnapMLA decode pipeline (paper Algorithm 1) as an exact software
//! simulation, including the Appendix-E dual-warp-group ordering study.
//!
//! Stages per KV block of `BLOCK_N` = 64 (paper §3.2.3):
//!   1. online softmax over restored logits (running max m, stat l)
//!   2. scale fusion  P' = P ⊙ S_V                     (Key Step 2)
//!   3. block-wise dynamic quantization of P' (sigma_P = max/448)
//!   4. FP8 PV GEMM with scale-aware accumulation (Eqs. 12/13)
//!
//! `PvOrder` selects the accumulation schedule of the PV stage:
//!   * `Monotonic`        — the paper's "lossless pipeline reconstruction":
//!     strictly forward scale-domain progression (what SnapMLA ships).
//!   * `InvertedRescaleP` — App. E Problem 1: within each block pair, WG1
//!     lands P1·V1 before P0·V0, so the already-FP8 P0 must be *requantized*
//!     into P1's scale domain. When the domains differ wildly the rescaled
//!     codes underflow (or saturate) the FP8 grid — irreversible loss.
//!   * `InvertedRollback` — App. E Problem 2: roll O_acc back to P0's domain,
//!     accumulate, then restore. Algebraically exact, but the bidirectional
//!     ratios explode/vanish in f32 for adversarial scale streams.

use super::{Query, Shape};
use crate::fp8::{bf16_round, e4m3_round, per_token_scale, E4M3_MAX, SCALE_EPS};

/// KV block size — matches the Pallas kernel's BLOCK_N, the PV GEMM tile
/// (paper §3.2.2 "BlockN=64") and the KV-cache page size.
pub const BLOCK_N: usize = 64;

const NEG_INF: f32 = -1e30;

/// A SnapMLA-quantized KV cache (the algorithmic view; the serving-grade
/// paged container with u8 storage lives in `crate::kvcache`).
#[derive(Clone, Debug)]
pub struct QuantCache {
    /// content on the E4M3 grid, row-major [n, d_c] (f32 staging of codes)
    pub k_c_q: Vec<f32>,
    /// per-token content scales [n]
    pub sigma_k: Vec<f32>,
    /// RoPE pre-scaled by 1/sigma_k (Key Step 1), row-major [n, d_r]
    pub k_r_al: Vec<f32>,
    pub n: usize,
}

/// Fused-K-Append over a full cache: per-token quantize + domain-align.
pub fn build_quant_cache(shape: &Shape, k_c: &[f32], k_r: &[f32], n: usize) -> QuantCache {
    let (d_c, d_r) = (shape.d_c, shape.d_r);
    let mut out = QuantCache {
        k_c_q: vec![0.0; n * d_c],
        sigma_k: vec![0.0; n],
        k_r_al: vec![0.0; n * d_r],
        n,
    };
    for j in 0..n {
        let row = &k_c[j * d_c..(j + 1) * d_c];
        let s = per_token_scale(row);
        out.sigma_k[j] = s;
        for i in 0..d_c {
            out.k_c_q[j * d_c + i] = e4m3_round(row[i] / s);
        }
        for i in 0..d_r {
            out.k_r_al[j * d_r + i] = bf16_round(k_r[j * d_r + i]) / s;
        }
    }
    out
}

/// Fused-Q-Quant: per-head-row quantize + align. Returns (q_c_q, sigma_q, q_r_al).
pub fn quantize_query(shape: &Shape, q: &Query) -> (Vec<f32>, Vec<f32>, Vec<f32>) {
    let (h, d_c, d_r) = (shape.heads, shape.d_c, shape.d_r);
    let mut q_c_q = vec![0.0f32; h * d_c];
    let mut sigma_q = vec![0.0f32; h];
    let mut q_r_al = vec![0.0f32; h * d_r];
    for head in 0..h {
        let row = &q.q_c[head * d_c..(head + 1) * d_c];
        let s = per_token_scale(row);
        sigma_q[head] = s;
        for i in 0..d_c {
            q_c_q[head * d_c + i] = e4m3_round(row[i] / s);
        }
        for i in 0..d_r {
            q_r_al[head * d_r + i] = bf16_round(q.q_r[head * d_r + i]) / s;
        }
    }
    (q_c_q, sigma_q, q_r_al)
}

/// PV accumulation schedule (Appendix E).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum PvOrder {
    Monotonic,
    InvertedRescaleP,
    InvertedRollback,
}

#[derive(Clone, Debug)]
pub struct PipelineOut {
    pub o: Vec<f32>,   // [heads, d_c]
    pub lse: Vec<f32>, // [heads]
}

/// One processed block: quantized fused probabilities + its scale domain.
struct BlockP {
    start: usize,
    valid: usize,
    pq: Vec<f32>,   // FP8-grid codes of P' / sigma_p
    sigma_p: f32,
    /// rescale factor bringing the accumulator from the previous block's
    /// (m, sigma_p) domain into this block's domain (gamma of Eq. 13)
    gamma: f32,
}

/// Run the SnapMLA pipeline for one decode step.
///
/// `length` ≤ cache.n; trailing rows are masked exactly like the kernel.
pub fn snapmla_pipeline(
    shape: &Shape,
    q_c_q: &[f32],
    sigma_q: &[f32],
    q_r_al: &[f32],
    cache: &QuantCache,
    length: usize,
    sm_scale: f32,
    order: PvOrder,
) -> PipelineOut {
    let (h, d_c, d_r) = (shape.heads, shape.d_c, shape.d_r);
    assert!(length <= cache.n);
    let num_blocks = cache.n.div_ceil(BLOCK_N);

    let mut o = vec![0.0f32; h * d_c];
    let mut lse = vec![0.0f32; h];
    let mut s_blk = vec![0.0f32; BLOCK_N];

    for head in 0..h {
        let qc = &q_c_q[head * d_c..(head + 1) * d_c];
        let qr = &q_r_al[head * d_r..(head + 1) * d_r];
        let sq = sigma_q[head];

        let mut m = NEG_INF;
        let mut l = 0.0f32;
        let mut sp = 1.0f32;
        let acc = &mut o[head * d_c..(head + 1) * d_c];

        // ---- stages 1-3 for every block, with monotonic (m, l, sigma_p)
        // progression; PV accumulation order is applied afterwards per pair.
        let mut blocks: Vec<BlockP> = Vec::with_capacity(num_blocks);
        for b in 0..num_blocks {
            let start = b * BLOCK_N;
            let valid = length.saturating_sub(start).min(BLOCK_N);
            if valid == 0 {
                break;
            }
            let mut m_cur = NEG_INF;
            for j in 0..valid {
                let row = start + j;
                let kc = &cache.k_c_q[row * d_c..(row + 1) * d_c];
                let kr = &cache.k_r_al[row * d_r..(row + 1) * d_r];
                let mut s = 0.0f32;
                for i in 0..d_c {
                    s += qc[i] * kc[i];
                }
                for i in 0..d_r {
                    s += qr[i] * kr[i];
                }
                s_blk[j] = s * sq * cache.sigma_k[row] * sm_scale;
                m_cur = m_cur.max(s_blk[j]);
            }
            let m_new = m.max(m_cur);
            let mut l_cur = 0.0f32;
            let mut et_max = 0.0f32;
            let mut et = vec![0.0f32; valid];
            for j in 0..valid {
                let e = (s_blk[j] - m_new).exp();
                l_cur += e;
                // stage 2: scale fusion P' = P ⊙ S_V
                et[j] = e * cache.sigma_k[start + j];
                et_max = et_max.max(et[j]);
            }
            // stage 3: block-wise dynamic P quantization
            let sp_cur = (et_max / E4M3_MAX).max(SCALE_EPS);
            let pq: Vec<f32> = et.iter().map(|&x| e4m3_round(x / sp_cur)).collect();

            let alpha = if m > NEG_INF / 2.0 { (m - m_new).exp() } else { 0.0 };
            let gamma = alpha * sp / sp_cur;
            l = l * gamma + l_cur / sp_cur;
            blocks.push(BlockP { start, valid, pq, sigma_p: sp_cur, gamma });
            m = m_new;
            sp = sp_cur;
        }

        // ---- stage 4: PV accumulation under the selected schedule --------
        match order {
            PvOrder::Monotonic => {
                for blk in &blocks {
                    for a in acc.iter_mut() {
                        *a *= blk.gamma;
                    }
                    accumulate_pv(acc, &blk.pq, cache, blk.start, blk.valid, d_c);
                }
            }
            PvOrder::InvertedRescaleP | PvOrder::InvertedRollback => {
                let mut i = 0;
                while i < blocks.len() {
                    if i + 1 < blocks.len() {
                        let (b0, b1) = (&blocks[i], &blocks[i + 1]);
                        // rescale the accumulator straight to b1's domain
                        for a in acc.iter_mut() {
                            *a *= b0.gamma * b1.gamma;
                        }
                        // WG1 lands P1·V1 first…
                        accumulate_pv(acc, &b1.pq, cache, b1.start, b1.valid, d_c);
                        // …then P0·V0 must be folded in. b0's codes live in
                        // (m0, sp0); the conversion to b1's domain is 1/gamma1
                        // … i.e. multiply contributions by b1.gamma^-1?  No:
                        // contribution_in_b1 = pq0 · gamma1_inverse? The exact
                        // factor from b0's domain to b1's is b1.gamma.
                        let r = b1.gamma;
                        match order {
                            PvOrder::InvertedRescaleP => {
                                // Problem 1: requantize P0 into b1's domain
                                let pq0r: Vec<f32> =
                                    b0.pq.iter().map(|&p| e4m3_round(p * r)).collect();
                                accumulate_pv(acc, &pq0r, cache, b0.start, b0.valid, d_c);
                            }
                            PvOrder::InvertedRollback => {
                                // Problem 2: roll the accumulator back to b0's
                                // domain, accumulate exactly, roll forward.
                                let inv = 1.0 / r;
                                for a in acc.iter_mut() {
                                    *a *= inv;
                                }
                                accumulate_pv(acc, &b0.pq, cache, b0.start, b0.valid, d_c);
                                for a in acc.iter_mut() {
                                    *a *= r;
                                }
                            }
                            PvOrder::Monotonic => unreachable!(),
                        }
                        i += 2;
                    } else {
                        let b0 = &blocks[i];
                        for a in acc.iter_mut() {
                            *a *= b0.gamma;
                        }
                        accumulate_pv(acc, &b0.pq, cache, b0.start, b0.valid, d_c);
                        i += 1;
                    }
                }
            }
        }

        // epilogue: o = O/L (scale domain cancels), lse = m + ln(sp·l)
        let safe_l = if l > 0.0 { l } else { 1.0 };
        for a in acc.iter_mut() {
            *a /= safe_l;
        }
        lse[head] = m + (sp * l).max(1e-37).ln();
    }

    PipelineOut { o, lse }
}

fn accumulate_pv(
    acc: &mut [f32],
    pq: &[f32],
    cache: &QuantCache,
    start: usize,
    valid: usize,
    d_c: usize,
) {
    for j in 0..valid {
        let row = start + j;
        let p = pq[j];
        if p == 0.0 {
            continue;
        }
        let kc = &cache.k_c_q[row * d_c..(row + 1) * d_c];
        for i in 0..d_c {
            acc[i] += p * kc[i];
        }
    }
}

/// Convenience: full SnapMLA decode from f32 operands (quantize + pipeline).
pub fn snapmla_decode(
    shape: &Shape,
    q: &Query,
    k_c: &[f32],
    k_r: &[f32],
    length: usize,
    sm_scale: f32,
    order: PvOrder,
) -> PipelineOut {
    let n_pad = length.div_ceil(BLOCK_N) * BLOCK_N;
    let mut k_c_pad = k_c[..length * shape.d_c].to_vec();
    k_c_pad.resize(n_pad * shape.d_c, 0.0);
    let mut k_r_pad = k_r[..length * shape.d_r].to_vec();
    k_r_pad.resize(n_pad * shape.d_r, 0.0);
    let cache = build_quant_cache(shape, &k_c_pad, &k_r_pad, n_pad);
    let (q_c_q, sigma_q, q_r_al) = quantize_query(shape, q);
    snapmla_pipeline(shape, &q_c_q, &sigma_q, &q_r_al, &cache, length, sm_scale, order)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::mla::ref_attn;
    use crate::mla::{Cache, Shape};
    use crate::util::rng::Rng;
    use crate::util::stats::rel_l2;

    fn case(seed: u64, n: usize, shape: &Shape) -> (Query, Cache) {
        let mut rng = Rng::new(seed);
        let q = Query {
            q_c: rng.normal_vec(shape.heads * shape.d_c, 1.0),
            q_r: rng.normal_vec(shape.heads * shape.d_r, 0.3),
        };
        let mut cache = Cache::new(n, shape);
        cache.k_c = rng.normal_vec(n * shape.d_c, 2.0);
        cache.k_r = rng.normal_vec(n * shape.d_r, 8.0);
        (q, cache)
    }

    #[test]
    fn monotonic_matches_reference_within_quant_error() {
        let shape = Shape { heads: 4, d_c: 64, d_r: 16 };
        for seed in [1, 2, 3] {
            let (q, cache) = case(seed, 256, &shape);
            let sm = shape.sm_scale();
            let want = ref_attn::attention(&shape, &q, &cache, 200, sm);
            let got = snapmla_decode(
                &shape, &q, &cache.k_c, &cache.k_r, 200, sm, PvOrder::Monotonic,
            );
            let rel = rel_l2(&got.o, &want.o);
            assert!(rel < 0.09, "seed {seed}: rel {rel}");
            for h in 0..shape.heads {
                assert!((got.lse[h] - want.lse[h]).abs() < 0.05);
            }
        }
    }

    #[test]
    fn rollback_agrees_on_benign_data() {
        // Rollback is algebraically exact; on benign data (f32 headroom) it
        // coincides with the monotonic order. Rescale-P does NOT in general:
        // requantizing P0 saturates whenever the domain ratio exceeds 1 —
        // the "irreversible precision loss" of Problem 1 is present even in
        // ordinary operation, which is exactly why the paper rejects it.
        let shape = Shape { heads: 2, d_c: 32, d_r: 8 };
        let (q, cache) = case(4, 256, &shape);
        let sm = shape.sm_scale();
        let mono = snapmla_decode(&shape, &q, &cache.k_c, &cache.k_r, 256, sm, PvOrder::Monotonic);
        let roll = snapmla_decode(
            &shape, &q, &cache.k_c, &cache.k_r, 256, sm, PvOrder::InvertedRollback,
        );
        let rel = rel_l2(&roll.o, &mono.o);
        assert!(rel < 0.02, "rollback diverged on benign data: {rel}");
        let resc = snapmla_decode(
            &shape, &q, &cache.k_c, &cache.k_r, 256, sm, PvOrder::InvertedRescaleP,
        );
        assert!(resc.o.iter().all(|x| x.is_finite()));
    }

    #[test]
    fn partial_tail_block_masked() {
        let shape = Shape { heads: 2, d_c: 32, d_r: 8 };
        let (q, mut cache) = case(5, 192, &shape);
        let sm = shape.sm_scale();
        let a = snapmla_decode(&shape, &q, &cache.k_c, &cache.k_r, 100, sm, PvOrder::Monotonic);
        for j in 100..192 {
            for i in 0..32 {
                cache.k_c[j * 32 + i] = 1e5;
            }
        }
        let b = snapmla_decode(&shape, &q, &cache.k_c, &cache.k_r, 100, sm, PvOrder::Monotonic);
        assert_eq!(a.o, b.o);
    }

    #[test]
    fn matches_over_block_boundaries() {
        let shape = Shape { heads: 2, d_c: 32, d_r: 8 };
        let (q, cache) = case(6, 192, &shape);
        let sm = shape.sm_scale();
        for length in [1, 63, 64, 65, 128, 191] {
            let want = ref_attn::attention(&shape, &q, &cache, length, sm);
            let got = snapmla_decode(
                &shape, &q, &cache.k_c, &cache.k_r, length, sm, PvOrder::Monotonic,
            );
            let rel = rel_l2(&got.o, &want.o);
            assert!(rel < 0.08, "length {length}: rel {rel}");
        }
    }

    fn adversarial_case(seed: u64, n: usize, shape: &Shape) -> (Query, Vec<f32>, Vec<f32>) {
        // Problem-1 trigger: within each block PAIR, the FIRST block holds a
        // sink token (huge value magnitude → huge sigma_V → huge sigma_P)
        // that dominates the attention output, while the second block is
        // weak (tiny values → tiny sigma_P). The domain ratio r = sp0/sp1 is
        // then >> 1, and requantizing the already-FP8 P0 into P1's domain
        // SATURATES its dominant entries at 448 — the "large rescaling
        // factor disrupts its value distribution" failure of App. E. Logits
        // are kept moderate and value-independent (tiny q_c, rope-driven) so
        // probability mass is spread and the effect is purely scale-driven.
        let mut rng = Rng::new(seed);
        let mut k_c = rng.normal_vec(n * shape.d_c, 1e-2);
        let k_r = rng.normal_vec(n * shape.d_r, 1.0);
        for b in (0..(n / BLOCK_N)).step_by(2) {
            let sink = b * BLOCK_N; // first token of each even block
            for i in 0..shape.d_c {
                k_c[sink * shape.d_c + i] *= 1e6; // values ~1e4
            }
        }
        let q = Query {
            q_c: rng.normal_vec(shape.heads * shape.d_c, 1e-3),
            q_r: rng.normal_vec(shape.heads * shape.d_r, 0.6),
        };
        (q, k_c, k_r)
    }

    #[test]
    fn inverted_rescale_p_degrades_on_adversarial_scales() {
        let shape = Shape { heads: 1, d_c: 32, d_r: 8 };
        let n = 256;
        let (q, k_c, k_r) = adversarial_case(9, n, &shape);
        let sm = shape.sm_scale();
        let exact = {
            let cache = Cache { k_c: k_c.clone(), k_r: k_r.clone(), n };
            ref_attn::attention(&shape, &q, &cache, n, sm)
        };
        let mono = snapmla_decode(&shape, &q, &k_c, &k_r, n, sm, PvOrder::Monotonic);
        let resc = snapmla_decode(&shape, &q, &k_c, &k_r, n, sm, PvOrder::InvertedRescaleP);
        let e_mono = rel_l2(&mono.o, &exact.o);
        let e_resc = rel_l2(&resc.o, &exact.o);
        assert!(
            e_resc > 2.0 * e_mono,
            "rescale-P should degrade: mono {e_mono} vs rescale {e_resc}"
        );
    }

    #[test]
    fn monotonic_stable_on_adversarial_scales() {
        let shape = Shape { heads: 1, d_c: 32, d_r: 8 };
        let n = 256;
        let (q, k_c, k_r) = adversarial_case(11, n, &shape);
        let sm = shape.sm_scale();
        let exact = {
            let cache = Cache { k_c: k_c.clone(), k_r: k_r.clone(), n };
            ref_attn::attention(&shape, &q, &cache, n, sm)
        };
        let mono = snapmla_decode(&shape, &q, &k_c, &k_r, n, sm, PvOrder::Monotonic);
        let rel = rel_l2(&mono.o, &exact.o);
        assert!(rel < 0.1, "monotonic should stay stable: {rel}");
        assert!(mono.o.iter().all(|x| x.is_finite()));
    }
}
