//! Table-3 KV-cache quantization configurations (mirrors
//! `python/compile/kernels/ref.py::QUANT_CONFIGS`).
//!
//! Each config maps a full-precision cache to its dequantized-equivalent
//! values; attention is then evaluated in f32 so the measured error isolates
//! the cache treatment (the Fig. 5 methodology).

use super::{Cache, Shape};
use crate::fp8::{
    bf16_round, dequant_per_block, e4m3_round, quant_per_block, quant_per_tensor,
    quant_per_token,
};

#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum QuantConfig {
    /// SnapMLA: per-token FP8 content, bf16 RoPE (RoPE-aware).
    SnapMla,
    /// Config A: per-token RoPE-unaware — one shared scale over [content;rope].
    ConfigA,
    /// Config B: per-tensor static (fixed scale 1.0), RoPE-aware.
    ConfigB,
    /// Config C: per-tensor dynamic, RoPE-aware.
    ConfigC,
    /// Config D: per-block (64x64), RoPE-aware.
    ConfigD,
}

impl QuantConfig {
    pub const ALL: [QuantConfig; 5] = [
        QuantConfig::SnapMla,
        QuantConfig::ConfigA,
        QuantConfig::ConfigB,
        QuantConfig::ConfigC,
        QuantConfig::ConfigD,
    ];

    pub fn name(&self) -> &'static str {
        match self {
            QuantConfig::SnapMla => "SnapMLA (Per-Token RoPE-Aware)",
            QuantConfig::ConfigA => "Config A (Per-Token RoPE-Unaware)",
            QuantConfig::ConfigB => "Config B (Per-Tensor Static 1.0)",
            QuantConfig::ConfigC => "Config C (Per-Tensor Dynamic)",
            QuantConfig::ConfigD => "Config D (Per-Block)",
        }
    }

    /// Apply the config to a cache, returning dequantized-equivalent values.
    pub fn apply(&self, shape: &Shape, cache: &Cache) -> Cache {
        let (d_c, d_r, n) = (shape.d_c, shape.d_r, cache.n);
        let mut out = Cache::new(n, shape);
        match self {
            QuantConfig::SnapMla => {
                for j in 0..n {
                    let q = quant_per_token(&cache.k_c[j * d_c..(j + 1) * d_c]);
                    q.dequant_into(&mut out.k_c[j * d_c..(j + 1) * d_c]);
                }
                bf16_rope(&cache.k_r, &mut out.k_r);
            }
            QuantConfig::ConfigA => {
                // one shared per-token scale over the concatenated KV vector
                let mut row = vec![0.0f32; d_c + d_r];
                for j in 0..n {
                    row[..d_c].copy_from_slice(&cache.k_c[j * d_c..(j + 1) * d_c]);
                    row[d_c..].copy_from_slice(&cache.k_r[j * d_r..(j + 1) * d_r]);
                    let q = quant_per_token(&row);
                    let d = q.dequant();
                    out.k_c[j * d_c..(j + 1) * d_c].copy_from_slice(&d[..d_c]);
                    out.k_r[j * d_r..(j + 1) * d_r].copy_from_slice(&d[d_c..]);
                }
            }
            QuantConfig::ConfigB => {
                for (o, &x) in out.k_c.iter_mut().zip(&cache.k_c) {
                    *o = e4m3_round(x); // scale 1.0
                }
                bf16_rope(&cache.k_r, &mut out.k_r);
            }
            QuantConfig::ConfigC => {
                let (codes, s) = quant_per_tensor(&cache.k_c, None);
                for (o, &c) in out.k_c.iter_mut().zip(&codes) {
                    *o = crate::fp8::e4m3_decode(c) * s;
                }
                bf16_rope(&cache.k_r, &mut out.k_r);
            }
            QuantConfig::ConfigD => {
                // 64x64 blocks over [n, d_c]; degrade gracefully if not divisible
                let br = if n % 64 == 0 { 64 } else { n };
                let bc = if d_c % 64 == 0 { 64 } else { d_c };
                let q = quant_per_block(&cache.k_c, n, d_c, br, bc);
                out.k_c = dequant_per_block(&q);
                bf16_rope(&cache.k_r, &mut out.k_r);
            }
        }
        out
    }
}

fn bf16_rope(src: &[f32], dst: &mut [f32]) {
    for (o, &x) in dst.iter_mut().zip(src) {
        *o = bf16_round(x);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::mla::synth;
    use crate::util::rng::Rng;
    use crate::util::stats::mse;

    fn synth_cache(seed: u64, n: usize, shape: &Shape) -> Cache {
        let mut rng = Rng::new(seed);
        Cache {
            k_c: synth::content(&mut rng, n, shape.d_c),
            k_r: synth::rope(&mut rng, n, shape.d_r),
            n,
        }
    }

    #[test]
    fn snapmla_keeps_rope_at_bf16() {
        let shape = Shape { heads: 1, d_c: 64, d_r: 16 };
        let cache = synth_cache(1, 128, &shape);
        let out = QuantConfig::SnapMla.apply(&shape, &cache);
        for (x, y) in cache.k_r.iter().zip(&out.k_r) {
            assert_eq!(*y, bf16_round(*x));
        }
    }

    #[test]
    fn config_a_couples_rope_and_content_scale() {
        // with a huge rope outlier, config A's content error grows vs SnapMLA
        let shape = Shape { heads: 1, d_c: 64, d_r: 16 };
        let cache = synth_cache(2, 256, &shape);
        let snap = QuantConfig::SnapMla.apply(&shape, &cache);
        let a = QuantConfig::ConfigA.apply(&shape, &cache);
        let rope_err_snap = mse(&snap.k_r, &cache.k_r);
        let rope_err_a = mse(&a.k_r, &cache.k_r);
        assert!(rope_err_a > 5.0 * rope_err_snap.max(1e-12),
            "rope: snap {rope_err_snap} vs A {rope_err_a}");
    }

    #[test]
    fn config_b_saturates_sinks() {
        let shape = Shape { heads: 1, d_c: 64, d_r: 16 };
        let cache = synth_cache(3, 512, &shape);
        let amax = cache.k_c.iter().fold(0.0f32, |m, &x| m.max(x.abs()));
        assert!(amax > 448.0, "generator must produce sink tokens: {amax}");
        let b = QuantConfig::ConfigB.apply(&shape, &cache);
        let bmax = b.k_c.iter().fold(0.0f32, |m, &x| m.max(x.abs()));
        assert_eq!(bmax, 448.0);
        // and the MSE blows up vs per-token
        let snap = QuantConfig::SnapMla.apply(&shape, &cache);
        assert!(mse(&b.k_c, &cache.k_c) > 5.0 * mse(&snap.k_c, &cache.k_c));
    }

    #[test]
    fn per_token_not_worse_than_coarse_on_ptre() {
        let shape = Shape { heads: 1, d_c: 64, d_r: 16 };
        let cache = synth_cache(4, 512, &shape);
        let ptre = |out: &Cache| -> f64 {
            let mut total = 0.0;
            for j in 0..cache.n {
                let a = &out.k_c[j * 64..(j + 1) * 64];
                let b = &cache.k_c[j * 64..(j + 1) * 64];
                let num: f64 =
                    a.iter().zip(b).map(|(&x, &y)| ((x - y) as f64).powi(2)).sum();
                let den: f64 = b.iter().map(|&y| (y as f64).powi(2)).sum();
                total += (num / den.max(1e-18)).sqrt();
            }
            total / cache.n as f64
        };
        let e_snap = ptre(&QuantConfig::SnapMla.apply(&shape, &cache));
        let e_c = ptre(&QuantConfig::ConfigC.apply(&shape, &cache));
        let e_d = ptre(&QuantConfig::ConfigD.apply(&shape, &cache));
        assert!(e_snap <= e_c * 1.01, "snap {e_snap} vs C {e_c}");
        assert!(e_snap <= e_d * 1.01, "snap {e_snap} vs D {e_d}");
    }

    #[test]
    fn all_configs_produce_finite_values() {
        let shape = Shape { heads: 1, d_c: 64, d_r: 16 };
        let cache = synth_cache(5, 256, &shape);
        for cfg in QuantConfig::ALL {
            let out = cfg.apply(&shape, &cache);
            assert!(out.k_c.iter().all(|x| x.is_finite()), "{cfg:?}");
            assert!(out.k_r.iter().all(|x| x.is_finite()), "{cfg:?}");
        }
    }
}
