//! Table-3 KV-cache quantization configurations (mirrors
//! `python/compile/kernels/ref.py::QUANT_CONFIGS`).
//!
//! Each config maps a full-precision cache to its dequantized-equivalent
//! values; attention is then evaluated in f32 so the measured error isolates
//! the cache treatment (the Fig. 5 methodology). The cache-rewriting bodies
//! live in [`crate::mla::variant::CachePolicy`] — the variant descriptor —
//! so quantization policy is defined in exactly one place; a `QuantConfig`
//! is now just the Table-3 *label* for a policy.

use super::variant::CachePolicy;
use super::{Cache, Shape};

#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum QuantConfig {
    /// SnapMLA: per-token FP8 content, bf16 RoPE (RoPE-aware).
    SnapMla,
    /// Config A: per-token RoPE-unaware — one shared scale over [content;rope].
    ConfigA,
    /// Config B: per-tensor static (fixed scale 1.0), RoPE-aware.
    ConfigB,
    /// Config C: per-tensor dynamic, RoPE-aware.
    ConfigC,
    /// Config D: per-block (64x64), RoPE-aware.
    ConfigD,
}

impl QuantConfig {
    pub const ALL: [QuantConfig; 5] = [
        QuantConfig::SnapMla,
        QuantConfig::ConfigA,
        QuantConfig::ConfigB,
        QuantConfig::ConfigC,
        QuantConfig::ConfigD,
    ];

    pub fn name(&self) -> &'static str {
        match self {
            QuantConfig::SnapMla => "SnapMLA (Per-Token RoPE-Aware)",
            QuantConfig::ConfigA => "Config A (Per-Token RoPE-Unaware)",
            QuantConfig::ConfigB => "Config B (Per-Tensor Static 1.0)",
            QuantConfig::ConfigC => "Config C (Per-Tensor Dynamic)",
            QuantConfig::ConfigD => "Config D (Per-Block)",
        }
    }

    /// The variant-descriptor cache policy this Table-3 row names.
    pub fn cache_policy(&self) -> CachePolicy {
        match self {
            QuantConfig::SnapMla => CachePolicy::PerTokenRopeAware,
            QuantConfig::ConfigA => CachePolicy::PerTokenCoupled,
            QuantConfig::ConfigB => CachePolicy::PerTensorStatic,
            QuantConfig::ConfigC => CachePolicy::PerTensorDynamic,
            QuantConfig::ConfigD => CachePolicy::PerBlock,
        }
    }

    /// Apply the config to a cache, returning dequantized-equivalent values.
    pub fn apply(&self, shape: &Shape, cache: &Cache) -> Cache {
        self.cache_policy().apply(shape, cache)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::fp8::bf16_round;
    use crate::mla::synth;
    use crate::util::rng::Rng;
    use crate::util::stats::mse;

    fn synth_cache(seed: u64, n: usize, shape: &Shape) -> Cache {
        let mut rng = Rng::new(seed);
        Cache {
            k_c: synth::content(&mut rng, n, shape.d_c),
            k_r: synth::rope(&mut rng, n, shape.d_r),
            n,
        }
    }

    #[test]
    fn snapmla_keeps_rope_at_bf16() {
        let shape = Shape { heads: 1, d_c: 64, d_r: 16 };
        let cache = synth_cache(1, 128, &shape);
        let out = QuantConfig::SnapMla.apply(&shape, &cache);
        for (x, y) in cache.k_r.iter().zip(&out.k_r) {
            assert_eq!(*y, bf16_round(*x));
        }
    }

    #[test]
    fn config_a_couples_rope_and_content_scale() {
        // with a huge rope outlier, config A's content error grows vs SnapMLA
        let shape = Shape { heads: 1, d_c: 64, d_r: 16 };
        let cache = synth_cache(2, 256, &shape);
        let snap = QuantConfig::SnapMla.apply(&shape, &cache);
        let a = QuantConfig::ConfigA.apply(&shape, &cache);
        let rope_err_snap = mse(&snap.k_r, &cache.k_r);
        let rope_err_a = mse(&a.k_r, &cache.k_r);
        assert!(rope_err_a > 5.0 * rope_err_snap.max(1e-12),
            "rope: snap {rope_err_snap} vs A {rope_err_a}");
    }

    #[test]
    fn config_b_saturates_sinks() {
        let shape = Shape { heads: 1, d_c: 64, d_r: 16 };
        let cache = synth_cache(3, 512, &shape);
        let amax = cache.k_c.iter().fold(0.0f32, |m, &x| m.max(x.abs()));
        assert!(amax > 448.0, "generator must produce sink tokens: {amax}");
        let b = QuantConfig::ConfigB.apply(&shape, &cache);
        let bmax = b.k_c.iter().fold(0.0f32, |m, &x| m.max(x.abs()));
        assert_eq!(bmax, 448.0);
        // and the MSE blows up vs per-token
        let snap = QuantConfig::SnapMla.apply(&shape, &cache);
        assert!(mse(&b.k_c, &cache.k_c) > 5.0 * mse(&snap.k_c, &cache.k_c));
    }

    #[test]
    fn per_token_not_worse_than_coarse_on_ptre() {
        let shape = Shape { heads: 1, d_c: 64, d_r: 16 };
        let cache = synth_cache(4, 512, &shape);
        let ptre = |out: &Cache| -> f64 {
            let mut total = 0.0;
            for j in 0..cache.n {
                let a = &out.k_c[j * 64..(j + 1) * 64];
                let b = &cache.k_c[j * 64..(j + 1) * 64];
                let num: f64 =
                    a.iter().zip(b).map(|(&x, &y)| ((x - y) as f64).powi(2)).sum();
                let den: f64 = b.iter().map(|&y| (y as f64).powi(2)).sum();
                total += (num / den.max(1e-18)).sqrt();
            }
            total / cache.n as f64
        };
        let e_snap = ptre(&QuantConfig::SnapMla.apply(&shape, &cache));
        let e_c = ptre(&QuantConfig::ConfigC.apply(&shape, &cache));
        let e_d = ptre(&QuantConfig::ConfigD.apply(&shape, &cache));
        assert!(e_snap <= e_c * 1.01, "snap {e_snap} vs C {e_c}");
        assert!(e_snap <= e_d * 1.01, "snap {e_snap} vs D {e_d}");
    }

    #[test]
    fn all_configs_produce_finite_values() {
        let shape = Shape { heads: 1, d_c: 64, d_r: 16 };
        let cache = synth_cache(5, 256, &shape);
        for cfg in QuantConfig::ALL {
            let out = cfg.apply(&shape, &cache);
            assert!(out.k_c.iter().all(|x| x.is_finite()), "{cfg:?}");
            assert!(out.k_r.iter().all(|x| x.is_finite()), "{cfg:?}");
        }
    }
}
