//! Fidelity metrics + the layer-compounded error study backing Fig. 5.
//!
//! The paper's Fig. 5 measures layer-wise attention-output error on real
//! inference data, where quantization error *compounds*: layer l's query and
//! cache derive from layer l-1's (quantization-perturbed) output. We model
//! that compounding by feeding each layer's perturbed attention output
//! through a random (fixed) mixing projection to produce the next layer's
//! operands — capturing the error-propagation dynamics without needing the
//! full model at 32k tokens.

use super::quant_configs::QuantConfig;
use super::ref_attn;
use super::variant::VariantKind;
use super::{Cache, Query, Shape};
use crate::util::rng::Rng;
use crate::util::stats::{cosine, mse, rel_l2};

#[derive(Clone, Debug)]
pub struct LayerError {
    pub layer: usize,
    pub mse: f64,
    pub rel_l2: f64,
    pub cosine: f64,
}

#[derive(Clone, Debug)]
pub struct FidelityReport {
    pub config: QuantConfig,
    pub per_layer: Vec<LayerError>,
}

impl FidelityReport {
    pub fn final_rel(&self) -> f64 {
        self.per_layer.last().map(|l| l.rel_l2).unwrap_or(f64::NAN)
    }

    pub fn mean_rel(&self) -> f64 {
        mean_rel(&self.per_layer)
    }
}

/// Layer-compounded error of one decode-kernel *variant* (full quantized
/// pipeline, not just the cache rewrite that [`FidelityReport`] measures).
#[derive(Clone, Debug)]
pub struct VariantFidelity {
    pub kind: VariantKind,
    pub per_layer: Vec<LayerError>,
}

impl VariantFidelity {
    pub fn final_rel(&self) -> f64 {
        self.per_layer.last().map(|l| l.rel_l2).unwrap_or(f64::NAN)
    }

    pub fn mean_rel(&self) -> f64 {
        mean_rel(&self.per_layer)
    }
}

fn mean_rel(per_layer: &[LayerError]) -> f64 {
    if per_layer.is_empty() {
        return f64::NAN;
    }
    per_layer.iter().map(|l| l.rel_l2).sum::<f64>() / per_layer.len() as f64
}

/// A fixed per-layer stimulus: cache + queries from the synthetic generator.
pub struct LayerStimulus {
    pub cache: Cache,
    pub query: Query,
}

/// Build `layers` stimuli at context length `n`.
pub fn build_stimuli(seed: u64, layers: usize, n: usize, shape: &Shape) -> Vec<LayerStimulus> {
    let mut rng = Rng::new(seed);
    (0..layers)
        .map(|_| {
            let k_c = super::synth::content(&mut rng, n, shape.d_c);
            let k_r = super::synth::rope(&mut rng, n, shape.d_r);
            let (q_c, q_r) = super::synth::queries(
                &mut rng, shape.heads, shape.d_c, shape.d_r, shape.sm_scale(), 10.0, 2.0,
            );
            LayerStimulus {
                cache: Cache { k_c, k_r, n },
                query: Query { q_c, q_r },
            }
        })
        .collect()
}

/// Run the layer-compounded fidelity study for one quantization config.
///
/// Per layer: the clean path attends over the clean cache; the quantized path
/// attends over the config-quantized cache with a query perturbed by the
/// previous layer's output error (projected through a fixed random mixing
/// matrix, modelling residual-stream propagation).
pub fn layerwise_errors(
    config: QuantConfig,
    stimuli: &[LayerStimulus],
    shape: &Shape,
    seed: u64,
) -> FidelityReport {
    let mut rng = Rng::new(seed ^ 0xF1DE11);
    let sm = shape.sm_scale();
    let h = shape.heads;
    let d_c = shape.d_c;
    // fixed mixing matrix rows (d_c → d_c), reused across layers; entries
    // scaled so the spectral norm ≈ 0.7 (errors propagate and compound but
    // stay bounded, like a residual stream with layernorm damping)
    let mix: Vec<f32> = rng.normal_vec(d_c * d_c, 0.35 / (d_c as f32).sqrt());

    let mut per_layer = Vec::with_capacity(stimuli.len());
    // propagated error in the quantized path's query operands
    let mut carry = vec![0.0f32; h * d_c];

    for (li, stim) in stimuli.iter().enumerate() {
        let clean = ref_attn::attention(shape, &stim.query, &stim.cache, stim.cache.n, sm);

        let qcache = config.apply(shape, &stim.cache);
        let mut q_pert = stim.query.clone();
        for (q, c) in q_pert.q_c.iter_mut().zip(&carry) {
            *q += c;
        }
        let noisy = ref_attn::attention(shape, &q_pert, &qcache, qcache.n, sm);

        per_layer.push(LayerError {
            layer: li,
            mse: mse(&noisy.o, &clean.o),
            rel_l2: rel_l2(&noisy.o, &clean.o),
            cosine: cosine(&noisy.o, &clean.o),
        });

        propagate_carry(&mut carry, &mix, &clean.o, &noisy.o, &stim.query, h, d_c);
    }

    FidelityReport { config, per_layer }
}

/// Run the layer-compounded fidelity study for one decode-kernel variant.
///
/// Same compounding harness as [`layerwise_errors`], but the quantized path
/// runs the variant's *full* decode pipeline (fused Q/K quantization plus the
/// variant's online-softmax numerics) rather than only a rewritten cache —
/// so AMLA's pow2-snapped scales and P-Cast's static S = 2^8 show up in the
/// propagated error.
pub fn variant_errors(
    kind: VariantKind,
    stimuli: &[LayerStimulus],
    shape: &Shape,
    seed: u64,
) -> VariantFidelity {
    let mut rng = Rng::new(seed ^ 0xF1DE11);
    let sm = shape.sm_scale();
    let h = shape.heads;
    let d_c = shape.d_c;
    let mix: Vec<f32> = rng.normal_vec(d_c * d_c, 0.35 / (d_c as f32).sqrt());

    let mut per_layer = Vec::with_capacity(stimuli.len());
    let mut carry = vec![0.0f32; h * d_c];

    for (li, stim) in stimuli.iter().enumerate() {
        let clean = ref_attn::attention(shape, &stim.query, &stim.cache, stim.cache.n, sm);

        let mut q_pert = stim.query.clone();
        for (q, c) in q_pert.q_c.iter_mut().zip(&carry) {
            *q += c;
        }
        let noisy = super::decode(
            kind,
            shape,
            &q_pert,
            &stim.cache.k_c,
            &stim.cache.k_r,
            stim.cache.n,
            sm,
        );

        per_layer.push(LayerError {
            layer: li,
            mse: mse(&noisy.o, &clean.o),
            rel_l2: rel_l2(&noisy.o, &clean.o),
            cosine: cosine(&noisy.o, &clean.o),
        });

        propagate_carry(&mut carry, &mix, &clean.o, &noisy.o, &stim.query, h, d_c);
    }

    VariantFidelity { kind, per_layer }
}

/// Propagate one layer's output error into the next layer's query operands:
/// the *relative* output error becomes a proportional perturbation of the
/// next layer's query (residual-stream semantics: layernorm keeps magnitudes
/// normalized, so what propagates is the direction error scaled by the
/// stream's own magnitude), mixed through the fixed projection.
fn propagate_carry(
    carry: &mut [f32],
    mix: &[f32],
    clean_o: &[f32],
    noisy_o: &[f32],
    query: &Query,
    h: usize,
    d_c: usize,
) {
    for head in 0..h {
        let o_norm = (0..d_c)
            .map(|i| (clean_o[head * d_c + i] as f64).powi(2))
            .sum::<f64>()
            .sqrt()
            .max(1e-12) as f32;
        let q_norm = (0..d_c)
            .map(|i| (query.q_c[head * d_c + i] as f64).powi(2))
            .sum::<f64>()
            .sqrt() as f32;
        let err: Vec<f32> = (0..d_c)
            .map(|i| (noisy_o[head * d_c + i] - clean_o[head * d_c + i]) / o_norm * q_norm)
            .collect();
        let dst = &mut carry[head * d_c..(head + 1) * d_c];
        for i in 0..d_c {
            let mut acc = 0.0f32;
            for k in 0..d_c {
                acc += err[k] * mix[k * d_c + i];
            }
            dst[i] = acc;
        }
    }
}

/// Cold-tier fidelity gate (tiered KV cache): encode one page of
/// decay-spectrum latents — energy concentrated in the leading directions,
/// the distribution trained MLA latent caches exhibit and the premise the
/// rank-reduced cold format rests on — and measure the reconstruction's
/// relative L2 error against the hot FP8 page it replaced. Returns
/// `(rel_l2, bound)` where `bound` is the rank's admissible budget from
/// [`crate::kvcache::rel_l2_bound`]; the cold sweep is only sound while
/// `rel_l2 < bound` holds.
pub fn cold_tier_fidelity(rank: usize, d_c: usize, d_r: usize, seed: u64) -> (f64, f64) {
    use crate::kvcache::{ColdPage, Page, PAGE_TOKENS};
    let mut rng = Rng::new(seed);
    let k = d_c.min(PAGE_TOKENS);
    let dirs: Vec<Vec<f32>> = (0..k).map(|_| rng.normal_vec(d_c, 1.0)).collect();
    let mut page = Page::new(d_c, d_r);
    for t in 0..PAGE_TOKENS {
        let coeffs = rng.normal_vec(k, 1.0);
        let mut x = vec![0.0f32; d_c];
        for (j, dir) in dirs.iter().enumerate() {
            // geometric spectrum decay: direction j carries 0.82^j of the
            // leading direction's amplitude
            let g = coeffs[j] * (0.82f32).powi(j as i32) * 3.0;
            for (o, &b) in x.iter_mut().zip(dir) {
                *o += g * b;
            }
        }
        let r = rng.normal_vec(d_r, 30.0);
        page.append_raw(t, d_c, d_r, &x, &r);
    }
    let cold = ColdPage::encode(&page, d_c, d_r, rank, seed);
    (cold.rel_l2_vs(&page, d_c), crate::kvcache::rel_l2_bound(rank, d_c))
}

#[cfg(test)]
mod tests {
    use super::*;

    fn run(n: usize, layers: usize) -> Vec<FidelityReport> {
        let shape = Shape { heads: 8, d_c: 128, d_r: 32 };
        let stimuli = build_stimuli(7, layers, n, &shape);
        QuantConfig::ALL
            .iter()
            .map(|&c| layerwise_errors(c, &stimuli, &shape, 13))
            .collect()
    }

    #[test]
    fn snapmla_lowest_final_error() {
        let reports = run(512, 6);
        let by = |c: QuantConfig| {
            reports.iter().find(|r| r.config == c).unwrap().mean_rel()
        };
        let snap = by(QuantConfig::SnapMla);
        // Config A is consistently worse (RoPE quantized; the kernel-level
        // logit-noise gap is ~10x, its output-level footprint here is a
        // steady >15% excess), Config B explodes outright (sink saturation).
        let (a, b) = (by(QuantConfig::ConfigA), by(QuantConfig::ConfigB));
        assert!(a > 1.15 * snap, "A {a} snap {snap}");
        assert!(b > 1.5 * snap, "B {b} snap {snap}");
        // C/D are in the same ballpark as snap (E4M3's exponent absorbs much
        // of the cross-token spread — the paper's Fig. 5 insets likewise show
        // only slight degradation); they must not be catastrophically worse
        // or better beyond noise.
        assert!(by(QuantConfig::ConfigC) > 0.5 * snap);
        assert!(by(QuantConfig::ConfigD) > 0.5 * snap);
        assert!(by(QuantConfig::ConfigC) < 5.0 * snap);
        assert!(by(QuantConfig::ConfigD) < 5.0 * snap);
    }

    #[test]
    fn errors_compound_over_layers() {
        let reports = run(512, 6);
        for r in &reports {
            let first = r.per_layer.first().unwrap().rel_l2;
            let last = r.per_layer.last().unwrap().rel_l2;
            assert!(
                last >= first * 0.5,
                "{:?}: error should not collapse ({first} → {last})",
                r.config
            );
            for le in &r.per_layer {
                assert!(le.rel_l2.is_finite() && le.cosine.is_finite());
            }
        }
        // the RoPE-unaware config's error does not wash out with depth
        let a = reports.iter().find(|r| r.config == QuantConfig::ConfigA).unwrap();
        assert!(a.per_layer.last().unwrap().rel_l2 > 0.8 * a.per_layer[0].rel_l2);
    }

    #[test]
    fn variant_fidelity_tracks_the_kernel_numerics() {
        let shape = Shape { heads: 8, d_c: 128, d_r: 32 };
        let stimuli = build_stimuli(7, 4, 512, &shape);
        let reports: Vec<VariantFidelity> = VariantKind::ALL
            .iter()
            .map(|&k| variant_errors(k, &stimuli, &shape, 13))
            .collect();
        for r in &reports {
            assert_eq!(r.per_layer.len(), 4);
            for le in &r.per_layer {
                assert!(le.rel_l2.is_finite() && le.rel_l2 < 0.5, "{:?}: {le:?}", r.kind);
                assert!(le.cosine.is_finite());
            }
        }
        // on benign synthetic stimuli all three variants share the cache
        // quantization floor; their compounded errors stay in one regime
        // (the frontier *separation* lives in mla::study's sink stimulus)
        let snap = reports[0].mean_rel();
        assert!(snap > 0.0);
        for r in &reports[1..] {
            assert!(r.mean_rel() < 5.0 * snap, "{:?}: {} vs snap {snap}", r.kind, r.mean_rel());
        }
    }

    #[test]
    fn cosine_and_rel_consistent() {
        let reports = run(256, 3);
        for r in &reports {
            for le in &r.per_layer {
                // small rel error ⇒ cosine near 1
                if le.rel_l2 < 0.05 {
                    assert!(le.cosine > 0.99, "{:?}: {le:?}", r.config);
                }
            }
        }
    }

    #[test]
    fn cold_tier_passes_its_fidelity_gate() {
        let (d_c, d_r) = (64, 8);
        let mut last = f64::INFINITY;
        for rank in [16, 32, 48] {
            let (rel, bound) = cold_tier_fidelity(rank, d_c, d_r, 31);
            assert!(rel.is_finite() && rel > 0.0);
            assert!(rel < bound, "rank {rank}: rel {rel} vs bound {bound}");
            // on the decay spectrum the error also sits well inside the
            // bound — the budget is a worst-case envelope, not a fit
            assert!(rel < 0.6 * bound, "rank {rank}: rel {rel} vs bound {bound}");
            last = last.min(rel);
        }
        // more rank, more fidelity: the rank-48 encoding beats rank-16
        let (lo, _) = cold_tier_fidelity(16, d_c, d_r, 31);
        let (hi, _) = cold_tier_fidelity(48, d_c, d_r, 31);
        assert!(hi < lo, "rank 48 {hi} should beat rank 16 {lo}");
        assert_eq!(last.min(hi), hi);
    }
}
