//! Workload generation: serving traces and the synthetic benchmark suite
//! whose generated-length profiles match the paper's Table 2.

pub mod benchsuite;
pub mod evalrun;
pub mod tracegen;

pub use benchsuite::{BenchFamily, BenchTask, Suite};
pub use evalrun::{run_family, run_suite, EvalConfig, FamilyResult};
pub use tracegen::{Request, TokenBudget, TraceConfig, TraceGen};
