//! Shared evaluation runner: drive the benchmark suite through a serving
//! `Server` and collect per-family scores and generation lengths.
//! Used by `benches/table1_quality.rs`, `benches/table2_genlen.rs` and
//! `examples/quality_eval.rs`-style drivers.

use super::benchsuite::{BenchFamily, BenchTask, Suite};
use crate::anyhow;
use crate::coordinator::{ServeRequest, Server};

#[derive(Clone, Debug)]
pub struct FamilyResult {
    pub family: &'static str,
    pub domain: &'static str,
    pub score: f64,
    pub mean_genlen: f64,
    pub tasks: usize,
}

#[derive(Clone, Copy, Debug)]
pub struct EvalConfig {
    pub tasks_per_family: usize,
    pub seed: u64,
    /// cap on generation length (CPU substrate)
    pub max_gen: usize,
    /// greedy (0.0) isolates pipeline parity; family temperature exercises
    /// sampling (genlen study)
    pub use_family_temperature: bool,
    /// stop on EOS (genlen study) or always run to target (quality study)
    pub stop_on_eos: bool,
}

/// Run the whole suite; returns one result per family.
pub fn run_suite(
    server: &mut Server,
    cfg: &EvalConfig,
) -> anyhow::Result<Vec<FamilyResult>> {
    let mut results = Vec::new();
    for fam in &super::benchsuite::SUITE {
        results.push(run_family(server, fam, cfg)?);
    }
    Ok(results)
}

/// Run one family's tasks through the server.
pub fn run_family(
    server: &mut Server,
    fam: &BenchFamily,
    cfg: &EvalConfig,
) -> anyhow::Result<FamilyResult> {
    let tasks: Vec<BenchTask> = Suite::tasks(fam, cfg.tasks_per_family, cfg.seed)
        .into_iter()
        .filter(|t| t.prompt.len() <= server.scheduler.cfg.max_prefill_tokens)
        .collect();
    anyhow::ensure!(!tasks.is_empty(), "family {} produced no usable tasks", fam.name);
    for (i, t) in tasks.iter().enumerate() {
        server.submit(ServeRequest {
            id: i as u64,
            prompt: t.prompt.clone(),
            max_new_tokens: t.max_new_tokens.min(cfg.max_gen),
            temperature: if cfg.use_family_temperature { t.temperature } else { 0.0 },
            seed: cfg.seed.wrapping_add(i as u64),
            ignore_eos: !cfg.stop_on_eos,
        });
    }
    server.run_to_completion()?;
    let mut outcomes = std::mem::take(&mut server.finished);
    outcomes.sort_by_key(|o| o.id);
    let mut score = 0.0;
    let mut genlen = 0.0;
    for (t, o) in tasks.iter().zip(&outcomes) {
        score += Suite::score(t, &o.generated);
        genlen += o.generated.len() as f64;
    }
    Ok(FamilyResult {
        family: fam.name,
        domain: fam.domain,
        score: score / tasks.len() as f64,
        mean_genlen: genlen / tasks.len() as f64,
        tasks: tasks.len(),
    })
}
