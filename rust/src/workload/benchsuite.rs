//! The synthetic benchmark suite standing in for the paper's closed
//! evaluation suites (Table 1 / Table 2; DESIGN.md §Substitutions).
//!
//! Each family mirrors one of the paper's benchmark rows with:
//! * a *task generator* producing prompts in the small model's synthetic
//!   token language together with a programmatically checkable target
//!   (the corpus families are deterministic continuations, so "accuracy" =
//!   fraction of continuation tokens predicted correctly — an objective,
//!   repeatable metric like IFEval's verifiable constraints),
//! * a *generated-length profile* matched to Table 2 (scaled 1/16 for the
//!   CPU substrate; the scale factor is reported alongside results).
//!
//! Quality parity (Table 1) is then: run the same tasks through the BF16 and
//! FP8 decode pipelines and compare per-family scores; genlen parity
//! (Table 2) compares the achieved generation lengths.

use crate::util::rng::Rng;

/// Length-profile scale factor vs the paper's Table 2 (CPU substrate).
pub const GENLEN_SCALE: usize = 16;

/// One benchmark family (a Table-1/Table-2 row).
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct BenchFamily {
    pub name: &'static str,
    pub domain: &'static str,
    /// paper's observed average generated length (Table 2, BF16 column)
    pub paper_avg_genlen: usize,
    /// corpus family used for the prompt structure
    pub corpus_family: &'static str,
    /// sampling temperature
    pub temperature: f32,
}

/// The suite (paper Table 2 rows, DeepSeek-V3.1 lengths).
pub const SUITE: [BenchFamily; 8] = [
    BenchFamily { name: "MMLU-Pro", domain: "General", paper_avg_genlen: 2447,
        corpus_family: "nested", temperature: 0.3 },
    BenchFamily { name: "MMLU-Redux", domain: "General", paper_avg_genlen: 562,
        corpus_family: "repeat", temperature: 0.3 },
    BenchFamily { name: "IFEval", domain: "Instruction", paper_avg_genlen: 680,
        corpus_family: "copy", temperature: 0.2 },
    BenchFamily { name: "Arena-Hard", domain: "Instruction", paper_avg_genlen: 3275,
        corpus_family: "nested", temperature: 0.7 },
    BenchFamily { name: "MATH-500", domain: "Math", paper_avg_genlen: 2346,
        corpus_family: "arith", temperature: 0.2 },
    BenchFamily { name: "AIME-24", domain: "Math", paper_avg_genlen: 11909,
        corpus_family: "arith", temperature: 0.4 },
    BenchFamily { name: "GPQA-Diamond", domain: "Reasoning", paper_avg_genlen: 9183,
        corpus_family: "nested", temperature: 0.4 },
    BenchFamily { name: "LCB", domain: "Coding", paper_avg_genlen: 13034,
        corpus_family: "copy", temperature: 0.3 },
];

/// A concrete task instance: prompt tokens + ground-truth continuation.
#[derive(Clone, Debug)]
pub struct BenchTask {
    pub family: &'static str,
    pub prompt: Vec<i32>,
    /// deterministic continuation implied by the prompt's structure
    pub target: Vec<i32>,
    pub max_new_tokens: usize,
    pub temperature: f32,
}

pub struct Suite;

const BOS: i32 = 1;
const CONTENT_BASE: i32 = 64;
const CONTENT_RANGE: i32 = 256;
const OP_BASE: i32 = 2;

fn content(rng: &mut Rng) -> i32 {
    CONTENT_BASE + rng.below(CONTENT_RANGE as usize) as i32
}

impl Suite {
    /// Target mean generated length for a family on this substrate.
    pub fn scaled_genlen(fam: &BenchFamily) -> usize {
        (fam.paper_avg_genlen / GENLEN_SCALE).clamp(8, 1500)
    }

    /// Generate `n` tasks for a family. Prompts are structured so that the
    /// continuation is *deterministic* given the structure:
    ///   repeat — motif repeated; target continues the motif
    ///   arith  — arithmetic progression; target continues it
    ///   copy   — span + separator + start of the span; target finishes copy
    ///   nested — open brackets + content; target mirrors the closes
    pub fn tasks(fam: &BenchFamily, n: usize, seed: u64) -> Vec<BenchTask> {
        let mut rng = Rng::new(seed ^ fam.name.len() as u64 * 0x9E37);
        let genlen = Self::scaled_genlen(fam);
        (0..n)
            .map(|_| {
                let target_len = (genlen as f64 * rng.range_f64(0.7, 1.3)) as usize;
                let target_len = target_len.clamp(4, 1500);
                let (prompt, target) = match fam.corpus_family {
                    "repeat" => {
                        let mlen = rng.range_usize(2, 8);
                        let motif: Vec<i32> = (0..mlen).map(|_| content(&mut rng)).collect();
                        let shown = rng.range_usize(3, 6) * mlen;
                        let mut prompt = vec![BOS];
                        for i in 0..shown {
                            prompt.push(motif[i % mlen]);
                        }
                        let target: Vec<i32> =
                            (0..target_len).map(|i| motif[(shown + i) % mlen]).collect();
                        (prompt, target)
                    }
                    "arith" => {
                        let start = rng.below(CONTENT_RANGE as usize) as i32;
                        let step = rng.range_usize(1, 17) as i32;
                        let shown = rng.range_usize(8, 24);
                        let tok = |k: i32| CONTENT_BASE + (start + step * k) % CONTENT_RANGE;
                        let mut prompt = vec![BOS];
                        prompt.extend((0..shown as i32).map(tok));
                        let target: Vec<i32> = (0..target_len as i32)
                            .map(|i| tok(shown as i32 + i))
                            .collect();
                        (prompt, target)
                    }
                    "copy" => {
                        // span capped so prompts fit the prefill bucket; long
                        // outputs are produced by LOOP-copying the span (the
                        // deterministic continuation of a periodic prompt)
                        let span_len = target_len.clamp(8, 100);
                        let span: Vec<i32> =
                            (0..span_len).map(|_| content(&mut rng)).collect();
                        let sep = OP_BASE + rng.below(62) as i32;
                        let mut prompt = vec![BOS];
                        prompt.extend(&span);
                        prompt.push(sep);
                        let target: Vec<i32> =
                            (0..target_len).map(|i| span[i % span_len]).collect();
                        (prompt, target)
                    }
                    _ => {
                        // nested: opens + content; target = mirrored closes
                        let depth = target_len.clamp(2, 30);
                        let opens: Vec<i32> =
                            (0..depth).map(|_| OP_BASE + rng.below(31) as i32).collect();
                        let inner = rng.range_usize(4, 16);
                        let mut prompt = vec![BOS];
                        prompt.extend(&opens);
                        for _ in 0..inner {
                            prompt.push(content(&mut rng));
                        }
                        let target: Vec<i32> =
                            opens.iter().rev().map(|&o| o + 31).collect();
                        (prompt, target)
                    }
                };
                BenchTask {
                    family: fam.name,
                    prompt,
                    // long-output families decode to their scaled profile
                    // even when the scoreable target is shorter (nested):
                    // achieved length is then model/EOS-driven, which is
                    // what the Table-2 parity study wants
                    max_new_tokens: (target.len() + 8).max(genlen),
                    target,
                    temperature: fam.temperature,
                }
            })
            .collect()
    }

    /// Score a generation against the task target: fraction of positions
    /// matching until the first divergence-insensitive window ends (we use
    /// plain positional accuracy — objective and pipeline-comparable).
    pub fn score(task: &BenchTask, generated: &[i32]) -> f64 {
        if task.target.is_empty() {
            return 1.0;
        }
        let n = task.target.len().min(generated.len());
        let hits = (0..n).filter(|&i| generated[i] == task.target[i]).count();
        hits as f64 / task.target.len() as f64
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn suite_covers_all_domains() {
        let domains: std::collections::BTreeSet<_> =
            SUITE.iter().map(|f| f.domain).collect();
        assert!(domains.len() >= 4);
    }

    #[test]
    fn genlen_scaling() {
        let lcb = SUITE.iter().find(|f| f.name == "LCB").unwrap();
        assert_eq!(Suite::scaled_genlen(lcb), 13034 / 16);
        let redux = SUITE.iter().find(|f| f.name == "MMLU-Redux").unwrap();
        assert_eq!(Suite::scaled_genlen(redux), 562 / 16);
    }

    #[test]
    fn tasks_are_deterministic_given_seed() {
        let fam = &SUITE[4]; // MATH-500 / arith
        let a = Suite::tasks(fam, 5, 7);
        let b = Suite::tasks(fam, 5, 7);
        for (x, y) in a.iter().zip(&b) {
            assert_eq!(x.prompt, y.prompt);
            assert_eq!(x.target, y.target);
        }
    }

    #[test]
    fn repeat_target_continues_motif() {
        let fam = &SUITE[1];
        for t in Suite::tasks(fam, 10, 3) {
            // the target must be consistent with the motif visible in the
            // prompt: find the motif length by the prompt periodicity
            let body = &t.prompt[1..];
            for m in 2..8 {
                if body.len() % m == 0
                    && (0..body.len()).all(|i| body[i] == body[i % m])
                {
                    assert_eq!(t.target[0], body[body.len() % m]);
                    break;
                }
            }
        }
    }

    #[test]
    fn arith_target_is_progression() {
        let fam = &SUITE[4];
        for t in Suite::tasks(fam, 10, 11) {
            let step_in_prompt =
                (t.prompt[2] - t.prompt[1]).rem_euclid(CONTENT_RANGE);
            let step_in_target =
                (t.target[1] - t.target[0]).rem_euclid(CONTENT_RANGE);
            assert_eq!(step_in_prompt, step_in_target);
        }
    }

    #[test]
    fn copy_target_loops_span() {
        let fam = &SUITE[7]; // LCB / copy
        for t in Suite::tasks(fam, 3, 13) {
            // prompt = BOS + span + sep; target cycles the span
            let span = &t.prompt[1..t.prompt.len() - 1];
            assert!(t.prompt.len() <= 110, "prompt must fit prefill bucket");
            for (i, &tok) in t.target.iter().enumerate() {
                assert_eq!(tok, span[i % span.len()]);
            }
        }
    }

    #[test]
    fn score_bounds_and_exactness() {
        let t = BenchTask {
            family: "x",
            prompt: vec![1],
            target: vec![70, 71, 72, 73],
            max_new_tokens: 8,
            temperature: 0.0,
        };
        assert_eq!(Suite::score(&t, &[70, 71, 72, 73]), 1.0);
        assert_eq!(Suite::score(&t, &[70, 71, 0, 0]), 0.5);
        assert_eq!(Suite::score(&t, &[]), 0.0);
    }

    #[test]
    fn long_output_families_have_long_targets() {
        let aime = SUITE.iter().find(|f| f.name == "AIME-24").unwrap();
        let tasks = Suite::tasks(aime, 5, 1);
        let mean: f64 =
            tasks.iter().map(|t| t.target.len() as f64).sum::<f64>() / tasks.len() as f64;
        let want = Suite::scaled_genlen(aime) as f64;
        assert!((mean / want - 1.0).abs() < 0.4, "mean {mean} want {want}");
    }
}
