//! SnapMLA — FP8 MLA decoding via hardware-aware quantized pipelining.
//!
//! A full reproduction of the SnapMLA paper as a three-layer
//! Rust + JAX + Pallas serving stack:
//!
//! * **L1/L2 (build-time Python)** — the SnapMLA FP8 decode-attention Pallas
//!   kernel and an absorbed-mode MLA transformer, AOT-lowered to HLO text
//!   artifacts (`make artifacts`, see `python/compile/`).
//! * **L3 (this crate)** — the serving coordinator: request router,
//!   continuous batcher, prefill/decode scheduler, paged FP8 KV cache,
//!   DP/TP cluster simulation, and a PJRT runtime (`xla` crate) that loads
//!   and executes the artifacts. Python never runs on the request path.
//!
//! The offline crate set contains only the `xla` closure, so `util` provides
//! hand-rolled JSON, CLI parsing, RNG, statistics, property testing and a
//! criterion-style bench harness (see DESIGN.md "Deliberate deviations").
//!
//! Module map (DESIGN.md has the full inventory):
//! * [`fp8`] — bit-exact E4M3/BF16 codecs and the paper's quantizers
//! * [`mla`] — f32 MLA attention reference, the Algorithm-1 software
//!   pipeline (incl. the App. E dual-warp-group hazard study), synthetic
//!   KV statistics and fidelity metrics
//! * [`kvcache`] — paged KV cache: u8 FP8 content + bf16 RoPE + f32 scales
//! * [`runtime`] — PJRT artifact registry, weight loading, model engine
//! * [`coordinator`] — requests, sequences, batcher, scheduler, router,
//!   serving loop, metrics
//! * [`cluster`] — DP/TP topology and collective cost model
//! * [`perfmodel`] — calibrated Hopper roofline/kernel/E2E timing model
//! * [`workload`] — trace generators and the synthetic benchmark suite
//! * [`bench`] — timing harness used by `cargo bench` targets

pub mod bench;
pub mod cluster;
pub mod coordinator;
pub mod fp8;
pub mod kvcache;
pub mod mla;
pub mod perfmodel;
pub mod runtime;
pub mod util;
pub mod workload;
