//! SnapMLA — FP8 MLA decoding via hardware-aware quantized pipelining.
//!
//! A full reproduction of the SnapMLA paper as a three-layer
//! Rust + JAX + Pallas serving stack:
//!
//! * **L1/L2 (build-time Python)** — the SnapMLA FP8 decode-attention Pallas
//!   kernel and an absorbed-mode MLA transformer, AOT-lowered to HLO text
//!   artifacts (`make artifacts`, see `python/compile/`).
//! * **L3 (this crate)** — the serving coordinator: request router,
//!   continuous batcher, prefill/decode scheduler, paged FP8 KV cache,
//!   DP/TP cluster simulation, and a backend-abstracted model engine.
//!
//! Execution is decoupled from the device behind
//! [`runtime::backend::ExecBackend`]:
//!
//! * the default build is **fully offline** — [`runtime::sim::SimBackend`]
//!   executes decode/prefill through the `mla` reference math + bit-exact
//!   `fp8` quantizers over a deterministic hand-constructed induction model;
//! * the `pjrt` cargo feature enables the PJRT path (`runtime::client`) that
//!   compiles and runs the AOT HLO artifacts via the `xla` crate (the
//!   in-repo `third_party/xla-stub` keeps it type-checking offline).
//!
//! The offline crate set is dependency-free, so `util` provides hand-rolled
//! JSON, CLI parsing, RNG, statistics, error handling ([`anyhow`]), property
//! testing and a criterion-style bench harness (see DESIGN.md "Deliberate
//! deviations").
//!
//! Module map (DESIGN.md has the full inventory):
//! * [`fp8`] — bit-exact E4M3/BF16 codecs and the paper's quantizers
//! * [`mla`] — f32 MLA attention reference, the Algorithm-1 software
//!   pipeline (incl. the App. E dual-warp-group hazard study), synthetic
//!   KV statistics and fidelity metrics
//! * [`kvcache`] — paged KV cache: u8 FP8 content + bf16 RoPE + f32 scales,
//!   refcounted prefix-sharing pages, page-spill preemption
//! * [`runtime`] — backend trait, sim + PJRT backends, model engine
//!   (decode / prefill / mixed chunked-prefill steps)
//! * [`coordinator`] — requests, sequences, mixed-batch scheduler, router,
//!   serving loop, metrics
//! * [`cluster`] — DP/TP topology and collective cost model
//! * [`simulate`] — deterministic virtual-time serving simulation: the
//!   event loop, the lock-step/event-driven harness, and the scenario
//!   configs every serve bench is a thin wrapper over
//! * [`perfmodel`] — calibrated Hopper roofline/kernel/E2E timing model
//! * [`workload`] — trace generators and the synthetic benchmark suite
//! * [`bench`] — timing harness used by `cargo bench` targets

pub mod bench;
pub mod cluster;
pub mod coordinator;
pub mod fp8;
pub mod kvcache;
pub mod mla;
pub mod perfmodel;
pub mod runtime;
pub mod simulate;
pub mod util;
pub mod workload;

/// `anyhow`-compatible facade over [`util::error`] (the offline crate set
/// has no external dependencies): `use snapmla::anyhow;` then
/// `anyhow::Result<T>` / `anyhow::anyhow!` / `anyhow::bail!` /
/// `anyhow::ensure!` exactly as with the real crate.
pub mod anyhow {
    pub use crate::util::error::{Error, Result};
    pub use crate::{__anyhow as anyhow, bail, ensure};
}
