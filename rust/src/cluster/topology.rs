//! Node topology: (DP, TP) layouts over an 8-GPU node and per-rank memory
//! accounting (weights + KV budget), feeding the Fig. 1 batch-capacity model.

use crate::anyhow;
use crate::perfmodel::{DeploymentConfig, GpuSpec, KernelKind, ModelSpec};

#[derive(Clone, Copy, Debug)]
pub struct NodeTopology {
    pub gpus: usize,
    pub config: DeploymentConfig,
}

#[derive(Clone, Copy, Debug)]
pub struct RankMemory {
    pub weight_bytes: f64,
    pub kv_budget_bytes: f64,
    pub reserve_bytes: f64,
}

impl RankMemory {
    /// Whether the layout leaves any KV budget at all (weights + reserve
    /// fit the device); a layout that does not fit serves zero sequences.
    pub fn fits(&self) -> bool {
        self.kv_budget_bytes > 0.0
    }
}

impl NodeTopology {
    pub fn new(gpus: usize, dp: usize, tp: usize) -> anyhow::Result<NodeTopology> {
        anyhow::ensure!(dp * tp == gpus, "DP{dp} x TP{tp} != {gpus} GPUs");
        anyhow::ensure!(dp >= 1 && tp >= 1);
        Ok(NodeTopology { gpus, config: DeploymentConfig { dp, tp } })
    }

    /// All valid layouts of an 8-GPU node.
    pub fn enumerate(gpus: usize) -> Vec<NodeTopology> {
        (1..=gpus)
            .filter(|dp| gpus % dp == 0)
            .map(|dp| NodeTopology::new(gpus, dp, gpus / dp).unwrap())
            .collect()
    }

    /// Per-GPU memory budget under this layout. Weights shard across the
    /// **TP group only** and replicate across DP replicas (each replica
    /// serves independently, so it holds a full copy of its shard) — the
    /// earlier `total_params / gpus` accounting undercounted per-rank
    /// weight bytes at DP > 1 and inflated the Fig. 1 capacity of DP-heavy
    /// layouts. Expert-parallel spreading (which `perfmodel::e2e` assumes
    /// for its throughput model) would relax this; the topology module
    /// prices the plain DP×TP layout.
    pub fn rank_memory(&self, gpu: &GpuSpec, model: &ModelSpec) -> RankMemory {
        let reserve = 8e9;
        let weight = model.total_params / self.config.tp as f64;
        RankMemory {
            weight_bytes: weight,
            kv_budget_bytes: (gpu.hbm_bytes - weight - reserve).max(0.0),
            reserve_bytes: reserve,
        }
    }

    /// Max concurrent sequences at `context` under a cache `kind`.
    /// The MLA latent cache is replicated across TP ranks (shared by all
    /// heads), so capacity scales with DP only.
    pub fn max_sequences(
        &self,
        gpu: &GpuSpec,
        model: &ModelSpec,
        context: usize,
        kind: KernelKind,
    ) -> usize {
        let mem = self.rank_memory(gpu, model);
        let per_seq = model.kv_bytes_per_token(kind) * context as f64;
        let per_rank = (mem.kv_budget_bytes / per_seq).floor() as usize;
        per_rank * self.config.dp
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn validates_layouts() {
        assert!(NodeTopology::new(8, 4, 2).is_ok());
        assert!(NodeTopology::new(8, 3, 2).is_err());
        assert_eq!(NodeTopology::enumerate(8).len(), 4); // 1,2,4,8 DP
    }

    #[test]
    fn memory_accounting() {
        let g = GpuSpec::h20();
        let m = ModelSpec::deepseek_v31();
        let t = NodeTopology::new(8, 1, 8).unwrap();
        let mem = t.rank_memory(&g, &m);
        // 671e9 / 8 ≈ 84 GB weights per GPU, leaving ~49 GB of KV on a 141 GB part
        assert!((mem.weight_bytes - 83.9e9).abs() < 1e9);
        assert!(mem.kv_budget_bytes > 40e9 && mem.kv_budget_bytes < 60e9);
    }

    #[test]
    fn fp8_cache_doubles_capacity() {
        let g = GpuSpec::h20();
        let m = ModelSpec::deepseek_v31();
        let mut compared = 0;
        for t in NodeTopology::enumerate(8) {
            if !t.rank_memory(&g, &m).fits() {
                continue; // weights alone exceed HBM under this layout
            }
            compared += 1;
            let c8 = t.max_sequences(&g, &m, 65_536, KernelKind::SnapMlaFp8);
            let c16 = t.max_sequences(&g, &m, 65_536, KernelKind::FlashMlaBf16);
            assert!(c8 as f64 >= 1.6 * c16.max(1) as f64, "{:?}", t.config);
        }
        assert!(compared >= 1, "no layout fits the model at all");
    }

    #[test]
    fn weight_replication_pins_dp8_vs_tp8_capacity_ordering() {
        let g = GpuSpec::h20();
        let dp8 = NodeTopology::new(8, 8, 1).unwrap();
        let tp8 = NodeTopology::new(8, 1, 8).unwrap();

        // DeepSeek-671B: a DP8 replica must hold the FULL weights — they do
        // not fit a 141 GB part, so DP8 serves zero sequences while TP8
        // (weights sharded 8-ways, cache replicated) still serves plenty.
        // The old `/ gpus` accounting got this exactly backwards.
        let m = ModelSpec::deepseek_v31();
        assert!(!dp8.rank_memory(&g, &m).fits());
        assert_eq!(dp8.max_sequences(&g, &m, 32_768, KernelKind::SnapMlaFp8), 0);
        assert!(tp8.max_sequences(&g, &m, 32_768, KernelKind::SnapMlaFp8) > 0);

        // A model small enough to replicate per rank flips the ordering:
        // DP8 holds 8 independent KV pools while TP8 replicates the latent
        // cache across all 8 GPUs — DP wins once weights fit.
        let small = ModelSpec { total_params: 60e9, ..m };
        assert!(dp8.rank_memory(&g, &small).fits());
        assert!(
            dp8.max_sequences(&g, &small, 32_768, KernelKind::SnapMlaFp8)
                > 4 * tp8.max_sequences(&g, &small, 32_768, KernelKind::SnapMlaFp8)
        );
    }
}
