//! Multi-rank serving: `ClusterServer` owns real `Server` replicas — each
//! with its own `ModelEngine`, `PagedKvCache` and mixed chunked-prefill
//! scheduler — and drives them lock-step (one scheduling step per rank per
//! round) in one of two topologies:
//!
//! * **Colocated** (classic DP): every rank serves the full request
//!   lifecycle; requests enter through the `coordinator::Router` policy
//!   (shortest-queue or prefix-affinity), so a shared prompt prefix can
//!   land every group member on the rank already holding those pages.
//! * **Disaggregated**: dedicated *prefill* ranks run prefill only — each
//!   completed prompt is serialized into a `kvcache::transfer::KvWireBlock`
//!   (per-token FP8 codes + scales + bf16 RoPE, ~half the bytes of a
//!   bf16-everything transfer) and migrated to a *decode* rank chosen by
//!   `pick_handoff_rank` (headroom/affinity). The imported KV is bit-exact,
//!   so a sequence prefilled on rank A and decoded on rank B emits the same
//!   tokens as a colocated run.

use crate::anyhow;
use crate::coordinator::metrics::ClusterMetrics;
use crate::coordinator::router::{pick_handoff_rank, RankLoad, RoutePolicy, Router};
use crate::coordinator::{RequestOutcome, Sequence, ServeRequest, Server};
use crate::kvcache::{CacheMode, KvWireBlock, PAGE_TOKENS};
use crate::runtime::ModelEngine;
use std::collections::VecDeque;
use std::time::Instant;

/// Cluster topology: every rank full-lifecycle, or prefill/decode split.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ClusterMode {
    /// classic data parallelism: all ranks prefill and decode
    Colocated,
    /// ranks `0..prefill_ranks` prefill + hand off; the remaining
    /// `decode_ranks` ranks decode migrated sequences
    Disaggregated { prefill_ranks: usize, decode_ranks: usize },
}

pub struct ClusterServer {
    pub router: Router,
    pub metrics: ClusterMetrics,
    pub mode: ClusterMode,
    /// disaggregated mode: serialized sequences in transit between a
    /// prefill rank's outbox and a decode rank with room (FIFO)
    in_flight: VecDeque<(Sequence, KvWireBlock)>,
}

impl ClusterServer {
    pub fn new(ranks: Vec<Server>, policy: RoutePolicy) -> ClusterServer {
        let dp = ranks.len();
        let metrics = ClusterMetrics::new(dp);
        ClusterServer {
            router: Router::with_policy(ranks, policy),
            metrics,
            mode: ClusterMode::Colocated,
            in_flight: VecDeque::new(),
        }
    }

    /// A disaggregated cluster: the first `prefill_ranks` ranks prefill
    /// and hand off, the rest decode. Admissions go to the least-loaded
    /// prefill rank (`RoutePolicy::Disagg`).
    pub fn disaggregated(mut ranks: Vec<Server>, prefill_ranks: usize) -> ClusterServer {
        let dp = ranks.len();
        assert!(prefill_ranks >= 1 && prefill_ranks < dp, "need ≥1 prefill and ≥1 decode rank");
        for r in ranks.iter_mut().take(prefill_ranks) {
            r.set_disagg_prefill();
        }
        let metrics = ClusterMetrics::new(dp);
        ClusterServer {
            router: Router::disaggregated(ranks, prefill_ranks),
            metrics,
            mode: ClusterMode::Disaggregated { prefill_ranks, decode_ranks: dp - prefill_ranks },
            in_flight: VecDeque::new(),
        }
    }

    /// A cluster of `dp` offline sim ranks (each its own engine + cache +
    /// scheduler) — the multi-rank quickstart and test entry point.
    pub fn sim(
        dp: usize,
        capacity_pages: usize,
        mode: CacheMode,
        policy: RoutePolicy,
    ) -> anyhow::Result<ClusterServer> {
        Ok(ClusterServer::new(Self::sim_ranks(dp, capacity_pages, mode)?, policy))
    }

    /// A disaggregated cluster of offline sim ranks: `prefill_ranks`
    /// prefill + `decode_ranks` decode.
    pub fn sim_disagg(
        prefill_ranks: usize,
        decode_ranks: usize,
        capacity_pages: usize,
        mode: CacheMode,
    ) -> anyhow::Result<ClusterServer> {
        let ranks = Self::sim_ranks(prefill_ranks + decode_ranks, capacity_pages, mode)?;
        Ok(ClusterServer::disaggregated(ranks, prefill_ranks))
    }

    fn sim_ranks(
        dp: usize,
        capacity_pages: usize,
        mode: CacheMode,
    ) -> anyhow::Result<Vec<Server>> {
        (0..dp).map(|_| Ok(Server::new(ModelEngine::sim(mode)?, capacity_pages))).collect()
    }

    pub fn dp(&self) -> usize {
        self.router.dp()
    }

    pub fn rank(&self, i: usize) -> &Server {
        &self.router.ranks[i]
    }

    pub fn pending(&self) -> usize {
        self.router.pending() + self.in_flight.len()
    }

    /// Sequences currently serialized and awaiting a decode rank.
    pub fn in_flight(&self) -> usize {
        self.in_flight.len()
    }

    /// Route and enqueue one request; returns the chosen rank.
    pub fn submit(&mut self, req: ServeRequest) -> usize {
        let rank = self.router.submit(req);
        self.metrics.routed[rank] += 1;
        rank
    }

    /// One lock-step round: every rank takes one scheduling step; in
    /// disaggregated mode, completed prefills then migrate — outboxes drain
    /// into the transfer queue and every transfer whose target decode rank
    /// has room is delivered (FIFO; an undeliverable transfer parks until a
    /// decode rank drains). Finally the cluster-wide page allocation is
    /// sampled for the peak-pages metric.
    pub fn step_all(&mut self) -> anyhow::Result<bool> {
        let mut any = self.router.step_all()?;
        if let ClusterMode::Disaggregated { prefill_ranks, .. } = self.mode {
            for r in self.router.ranks.iter_mut().take(prefill_ranks) {
                self.in_flight.extend(std::mem::take(&mut r.handoff_outbox));
            }
            any |= self.deliver_handoffs(prefill_ranks)?;
        }
        let used: usize = self.router.ranks.iter().map(|r| r.cache.used_pages()).sum();
        self.metrics.observe_pages(used);
        Ok(any)
    }

    /// Deliver every in-flight transfer that fits a decode rank right now.
    fn deliver_handoffs(&mut self, prefill_ranks: usize) -> anyhow::Result<bool> {
        let mut delivered_any = false;
        let mut parked = VecDeque::new();
        while let Some((seq, wire)) = self.in_flight.pop_front() {
            let remaining = seq.request.max_new_tokens - seq.generated.len();
            let needed = (wire.tokens() + remaining).div_ceil(PAGE_TOKENS);
            let loads: Vec<RankLoad> = self.router.ranks[prefill_ranks..]
                .iter()
                .map(|r| {
                    let open = r.can_accept_handoff(wire.tokens(), remaining);
                    RankLoad {
                        tokens: r.load_tokens(),
                        free_pages: r.cache.free_pages(),
                        // a slot-saturated rank is marked infeasible by
                        // inflating its need past any possible headroom
                        pages_needed: if open { needed } else { r.cache.cfg.capacity_pages + 1 },
                        prefix_hit_tokens: 0,
                        evictable_pages: r.cache.evictable_pages(),
                    }
                })
                .collect();
            match pick_handoff_rank(&loads) {
                Some(j) => {
                    self.router.ranks[prefill_ranks + j].accept_handoff(seq, wire)?;
                    delivered_any = true;
                }
                None => parked.push_back((seq, wire)),
            }
        }
        self.in_flight = parked;
        Ok(delivered_any)
    }

    /// Drive every rank to completion; outcomes are merged and id-sorted.
    /// Unlike `Router::run_to_completion`, every round goes through
    /// `step_all` so the peak-pages metric keeps sampling.
    pub fn run_to_completion(&mut self) -> anyhow::Result<Vec<RequestOutcome>> {
        let t0 = Instant::now();
        while self.pending() > 0 {
            if !self.step_all()? && self.pending() > 0 {
                anyhow::bail!(
                    "cluster deadlock: {} requests pending ({} in flight) over {} ranks",
                    self.pending(),
                    self.in_flight.len(),
                    self.dp()
                );
            }
        }
        Ok(self.router.drain_finished(t0.elapsed().as_secs_f64()))
    }

    /// Total prompt tokens served from prefix caches instead of re-prefilled.
    pub fn prefix_hit_tokens(&self) -> u64 {
        self.router.ranks.iter().map(|r| r.metrics.prefix_hit_tokens).sum()
    }

    /// Total sequences migrated prefill→decode (disaggregated mode).
    pub fn handoffs(&self) -> u64 {
        self.router.ranks.iter().map(|r| r.metrics.handoffs_in).sum()
    }

    /// Total KV bytes serialized onto the wire by handoffs.
    pub fn handoff_wire_bytes(&self) -> u64 {
        self.router.ranks.iter().map(|r| r.metrics.handoff_wire_bytes).sum()
    }

    /// Wall-clock-free counters for the whole cluster: routing decisions,
    /// the page peak, and every rank's deterministic serving counters —
    /// two runs over the same submissions must agree on all of these.
    pub fn counters(&self) -> Vec<(String, u64)> {
        let mut out = vec![("peak_pages_used".to_string(), self.metrics.peak_pages_used as u64)];
        for (i, r) in self.router.ranks.iter().enumerate() {
            out.push((format!("rank{i}_routed"), self.metrics.routed[i]));
            for (k, v) in r.metrics.counters() {
                out.push((format!("rank{i}_{k}"), v));
            }
        }
        out
    }
}
