//! Multi-rank serving: `ClusterServer` owns real `Server` replicas — each
//! with its own `ModelEngine`, `PagedKvCache` and mixed chunked-prefill
//! scheduler — and drives them on **per-rank virtual clocks** through the
//! deterministic `simulate::clock::EventLoop`, in one of two topologies:
//!
//! * **Colocated** (classic DP): every rank serves the full request
//!   lifecycle; requests enter through the `coordinator::Router` policy
//!   (shortest-queue or prefix-affinity), so a shared prompt prefix can
//!   land every group member on the rank already holding those pages.
//! * **Disaggregated**: dedicated *prefill* ranks run prefill only — each
//!   completed prompt is serialized into a `kvcache::transfer::KvWireBlock`
//!   (per-token FP8 codes + scales + bf16 RoPE, ~half the bytes of a
//!   bf16-everything transfer) and migrated to a *decode* rank chosen by
//!   `pick_handoff_rank` (headroom/affinity). The imported KV is bit-exact,
//!   so a sequence prefilled on rank A and decoded on rank B emits the same
//!   tokens as a colocated run.
//!
//! Membership is **elastic**: between drive calls the fleet can lose a
//! rank ([`ClusterServer::fail_rank`] — its fresh queue re-routes and its
//! live KV re-migrates to survivors over the same wire path as a
//! disaggregated handoff), shed one gracefully
//! ([`ClusterServer::drain_rank`] — out of the routing set immediately,
//! retired once empty) or gain one ([`ClusterServer::join_rank`]). A fixed
//! fleet never touches these paths and stays byte-identical to the
//! pre-elastic behavior.
//!
//! The drive ([`ClusterServer::run_until`]) pops `(time, rank, seq)`
//! batches off the event loop: every rank whose clock reaches the batch
//! time takes one scheduling step and re-arms at `time + step_costs[rank]`.
//! **Lock-step is the degenerate uniform-cost mode**: with equal per-rank
//! step costs every batch contains every busy rank in rank order — exactly
//! one legacy [`ClusterServer::step_all`] round, pinned byte-for-byte by
//! `rust/tests/integration_simulate.rs`. Heterogeneous costs let a slow
//! rank genuinely fall behind (stragglers, prefill/decode asymmetry)
//! instead of slowing every round.

use crate::anyhow;
use crate::coordinator::metrics::ClusterMetrics;
use crate::coordinator::router::{pick_handoff_rank, RankHealth, RankLoad, RoutePolicy, Router};
use crate::coordinator::{RequestOutcome, Sequence, ServeRequest, Server};
use crate::kvcache::{CacheMode, KvWireBlock};
use crate::runtime::ModelEngine;
use crate::simulate::{EventLoop, MembershipEvent};
use std::collections::{HashSet, VecDeque};
use std::time::Instant;

/// Host-tier link model for the virtual drive: per-rank, per-direction
/// (spill-down / prefetch-up) busy-until clocks over the PCIe link. With
/// `async_io` a rank's spills and restores ride the link *concurrently*
/// with its decode steps — the rank re-arms at its normal step cost and
/// only the link clock advances. Without it each transfer blocks the rank
/// until the link drains (the synchronous baseline). Transfers on one
/// direction serialize per rank; the two directions are full-duplex.
#[derive(Clone, Debug)]
pub struct TierLinkModel {
    /// virtual seconds one page-set transfer occupies the link
    pub transfer_s: f64,
    /// overlap transfers with decode instead of blocking the rank
    pub async_io: bool,
    /// per-rank spill-direction busy-until clock
    dn_free: Vec<f64>,
    /// per-rank prefetch-direction busy-until clock
    up_free: Vec<f64>,
    /// transfers that rode the link under live decode steps (async mode)
    pub overlapped: u64,
    /// transfers that stalled their rank until the link drained (sync mode)
    pub stalls: u64,
}

/// Cluster topology: every rank full-lifecycle, or prefill/decode split.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ClusterMode {
    /// classic data parallelism: all ranks prefill and decode
    Colocated,
    /// ranks `0..prefill_ranks` prefill + hand off; the remaining
    /// `decode_ranks` ranks decode migrated sequences
    Disaggregated { prefill_ranks: usize, decode_ranks: usize },
}

pub struct ClusterServer {
    pub router: Router,
    pub metrics: ClusterMetrics,
    pub mode: ClusterMode,
    /// membership history: (virtual time, event, rank, active ranks after)
    pub membership_log: Vec<(f64, MembershipEvent, usize, usize)>,
    /// serialized sequences in transit toward a rank with room (FIFO):
    /// disaggregated prefill→decode handoffs, and failure-recovery
    /// re-migrations off a dead rank
    in_flight: VecDeque<(Sequence, KvWireBlock)>,
    /// per-rank virtual clocks: when each rank is next ready to step
    /// (advanced by `run_until`; `step_all` rounds do not touch them)
    vclock: Vec<f64>,
    /// set by the first membership operation: enables the drop-not-park
    /// rule for transfers no surviving rank could ever place (a fixed
    /// fleet keeps the legacy park-forever semantics byte-for-byte)
    elastic: bool,
    /// ids evacuated off a failed rank and still awaiting re-placement
    evac_ids: HashSet<u64>,
    /// last observed `used_pages()` per rank (0 once a rank is dead) —
    /// re-read only at the points a rank's cache can change (its own
    /// step, an accepted handoff, failure/retirement) so the page peak
    /// is O(ranks touched) per round instead of a fleet-wide sweep; a
    /// debug assert re-derives the sweep and pins the two equal
    used_cache: Vec<usize>,
    /// Σ of `used_cache` — the fleet-wide page allocation
    used_total: usize,
    /// optional host-tier link model: when armed, `run_until` prices every
    /// rank spill/restore onto per-direction link clocks instead of
    /// (async) or in addition to (sync) the rank's step clock
    tier: Option<TierLinkModel>,
}

impl ClusterServer {
    pub fn new(ranks: Vec<Server>, policy: RoutePolicy) -> ClusterServer {
        let dp = ranks.len();
        let metrics = ClusterMetrics::new(dp);
        let used_cache: Vec<usize> = ranks.iter().map(|r| r.cache.used_pages()).collect();
        let used_total = used_cache.iter().sum();
        ClusterServer {
            router: Router::with_policy(ranks, policy),
            metrics,
            mode: ClusterMode::Colocated,
            membership_log: Vec::new(),
            in_flight: VecDeque::new(),
            vclock: vec![0.0; dp],
            elastic: false,
            evac_ids: HashSet::new(),
            used_cache,
            used_total,
            tier: None,
        }
    }

    /// A disaggregated cluster: the first `prefill_ranks` ranks prefill
    /// and hand off, the rest decode. Admissions go to the least-loaded
    /// prefill rank (`RoutePolicy::Disagg`).
    pub fn disaggregated(mut ranks: Vec<Server>, prefill_ranks: usize) -> ClusterServer {
        let dp = ranks.len();
        assert!(prefill_ranks >= 1 && prefill_ranks < dp, "need ≥1 prefill and ≥1 decode rank");
        for r in ranks.iter_mut().take(prefill_ranks) {
            r.set_disagg_prefill();
        }
        let metrics = ClusterMetrics::new(dp);
        let used_cache: Vec<usize> = ranks.iter().map(|r| r.cache.used_pages()).collect();
        let used_total = used_cache.iter().sum();
        ClusterServer {
            router: Router::disaggregated(ranks, prefill_ranks),
            metrics,
            mode: ClusterMode::Disaggregated { prefill_ranks, decode_ranks: dp - prefill_ranks },
            membership_log: Vec::new(),
            in_flight: VecDeque::new(),
            vclock: vec![0.0; dp],
            elastic: false,
            evac_ids: HashSet::new(),
            used_cache,
            used_total,
            tier: None,
        }
    }

    /// A cluster of `dp` offline sim ranks (each its own engine + cache +
    /// scheduler) — the multi-rank quickstart and test entry point.
    pub fn sim(
        dp: usize,
        capacity_pages: usize,
        mode: CacheMode,
        policy: RoutePolicy,
    ) -> anyhow::Result<ClusterServer> {
        Ok(ClusterServer::new(Self::sim_ranks(dp, capacity_pages, mode)?, policy))
    }

    /// A disaggregated cluster of offline sim ranks: `prefill_ranks`
    /// prefill + `decode_ranks` decode.
    pub fn sim_disagg(
        prefill_ranks: usize,
        decode_ranks: usize,
        capacity_pages: usize,
        mode: CacheMode,
    ) -> anyhow::Result<ClusterServer> {
        let ranks = Self::sim_ranks(prefill_ranks + decode_ranks, capacity_pages, mode)?;
        Ok(ClusterServer::disaggregated(ranks, prefill_ranks))
    }

    fn sim_ranks(
        dp: usize,
        capacity_pages: usize,
        mode: CacheMode,
    ) -> anyhow::Result<Vec<Server>> {
        (0..dp).map(|_| Ok(Server::new(ModelEngine::sim(mode)?, capacity_pages))).collect()
    }

    pub fn dp(&self) -> usize {
        self.router.dp()
    }

    pub fn rank(&self, i: usize) -> &Server {
        &self.router.ranks[i]
    }

    pub fn pending(&self) -> usize {
        self.router.pending() + self.in_flight.len()
    }

    /// Sequences currently serialized and awaiting a decode rank.
    pub fn in_flight(&self) -> usize {
        self.in_flight.len()
    }

    /// The cluster's virtual time: the latest per-rank clock reached by
    /// `run_until` (0 until the virtual drive has run).
    pub fn virtual_time(&self) -> f64 {
        self.vclock.iter().cloned().fold(0.0, f64::max)
    }

    /// Arm the host-tier link model: subsequent `run_until` drives price
    /// every rank spill/restore as a `transfer_s`-second occupation of that
    /// rank's per-direction PCIe link clock. With `async_io` the transfers
    /// overlap decode (the rank keeps stepping under them); without it each
    /// transfer blocks its rank until the link drains — the synchronous
    /// baseline the tiered benches compare against.
    pub fn set_tier_link(&mut self, transfer_s: f64, async_io: bool) {
        assert!(
            transfer_s.is_finite() && transfer_s >= 0.0,
            "tier transfer cost must be finite and non-negative: {transfer_s}"
        );
        let dp = self.dp();
        self.tier = Some(TierLinkModel {
            transfer_s,
            async_io,
            dn_free: vec![0.0; dp],
            up_free: vec![0.0; dp],
            overlapped: 0,
            stalls: 0,
        });
    }

    /// The armed tier link model, if any (overlap/stall counters live on it).
    pub fn tier_link(&self) -> Option<&TierLinkModel> {
        self.tier.as_ref()
    }

    /// Route and enqueue one request; returns the chosen rank.
    pub fn submit(&mut self, req: ServeRequest) -> usize {
        let rank = self.router.submit(req);
        self.metrics.routed[rank] += 1;
        rank
    }

    /// First rank index eligible to receive in-flight transfers: decode
    /// ranks in disaggregated mode, every rank in colocated mode (the
    /// failure-recovery path re-migrates onto any survivor).
    fn handoff_base(&self) -> usize {
        match self.mode {
            ClusterMode::Disaggregated { prefill_ranks, .. } => prefill_ranks,
            ClusterMode::Colocated => 0,
        }
    }

    fn log_membership(&mut self, kind: MembershipEvent, ri: usize) {
        let active = self.router.active_ranks().len();
        self.membership_log.push((self.virtual_time(), kind, ri, active));
    }

    /// A wake-up heap entry is live iff its rank still holds work and the
    /// entry time is the rank's current clock (bitwise — pushes use the
    /// exact `vclock` value, so equality is the identity test).
    fn entry_live(&self, t: f64, i: usize) -> bool {
        #[allow(clippy::float_cmp)]
        {
            self.router.ranks[i].pending() > 0 && t == self.vclock[i]
        }
    }

    /// Re-read rank `i`'s page allocation into the incremental total. A
    /// dead rank contributes 0 — the same exclusion the fleet-wide sweep
    /// applied — regardless of what its cache still holds.
    fn resample_pages(&mut self, i: usize) {
        let now = if self.router.health(i) == RankHealth::Dead {
            0
        } else {
            self.router.ranks[i].cache.used_pages()
        };
        self.used_total = self.used_total + now - self.used_cache[i];
        self.used_cache[i] = now;
    }

    /// Kill rank `ri` at the current virtual time. Its fresh queue
    /// re-routes through the cluster; with `recover` its live KV exports
    /// to the wire format and re-migrates to survivors (delivered by the
    /// same path as disaggregated handoffs); spilled or unrecoverable
    /// sequences are dropped and counted, never panicked on. Errors if
    /// the failure leaves no active rank.
    pub fn fail_rank(&mut self, ri: usize, recover: bool) -> anyhow::Result<()> {
        anyhow::ensure!(
            self.router.health(ri) != RankHealth::Dead,
            "rank {ri} is already dead"
        );
        self.router.set_health(ri, RankHealth::Dead);
        self.elastic = true;
        self.metrics.fails += 1;
        anyhow::ensure!(
            !self.router.active_ranks().is_empty(),
            "rank {ri} failed but no active ranks remain ({} requests stranded)",
            self.pending()
        );
        let ev = self.router.ranks[ri].evacuate(recover)?;
        self.resample_pages(ri);
        self.metrics.dropped += ev.dropped as u64;
        for (seq, wire) in ev.migrate {
            self.metrics.evacuated += 1;
            self.evac_ids.insert(seq.id());
            self.in_flight.push_back((seq, wire));
        }
        for req in ev.resubmit {
            self.submit(req);
        }
        self.log_membership(MembershipEvent::RankFail, ri);
        // place what fits right now; the rest rides the delivery path
        // every subsequent step retries
        self.deliver_handoffs(self.handoff_base())?;
        Ok(())
    }

    /// Begin draining rank `ri`: it leaves the routing set immediately,
    /// finishes its queued work, and retires (→ `Dead`) once empty.
    pub fn drain_rank(&mut self, ri: usize) -> anyhow::Result<()> {
        anyhow::ensure!(
            self.router.health(ri) == RankHealth::Active,
            "can only drain an active rank (rank {ri} is {:?})",
            self.router.health(ri)
        );
        anyhow::ensure!(
            self.router.active_ranks().len() > 1,
            "cannot drain the last active rank {ri}"
        );
        self.router.set_health(ri, RankHealth::Draining);
        self.elastic = true;
        self.metrics.drains += 1;
        self.log_membership(MembershipEvent::RankDrain, ri);
        Ok(())
    }

    /// Add a fresh rank to the fleet at the current virtual time; it
    /// enters the routing set immediately and returns its index. Callers
    /// of `run_until` must grow their step-cost slice to the new `dp()`.
    pub fn join_rank(&mut self, rank: Server) -> usize {
        let used = rank.cache.used_pages();
        let ri = self.router.push_rank(rank);
        self.metrics.routed.push(0);
        self.used_cache.push(used);
        self.used_total += used;
        self.vclock.push(self.virtual_time());
        if let Some(link) = self.tier.as_mut() {
            link.dn_free.push(0.0);
            link.up_free.push(0.0);
        }
        self.elastic = true;
        self.metrics.joins += 1;
        self.log_membership(MembershipEvent::RankJoin, ri);
        ri
    }

    /// One lock-step round: every rank takes one scheduling step; in
    /// disaggregated mode, completed prefills then migrate — outboxes drain
    /// into the transfer queue and every transfer whose target decode rank
    /// has room is delivered (FIFO; an undeliverable transfer parks until a
    /// decode rank drains). Finally the cluster-wide page allocation is
    /// sampled for the peak-pages metric. (The virtual drive `run_until`
    /// reproduces this exactly under uniform step costs.)
    pub fn step_all(&mut self) -> anyhow::Result<bool> {
        let mut any = self.router.step_all()?;
        // a lock-step round steps every rank, so every allocation moved
        for i in 0..self.dp() {
            self.resample_pages(i);
        }
        any |= self.migrate_and_sample()?;
        Ok(any)
    }

    /// Post-step bookkeeping shared by the lock-step and virtual drives:
    /// drain prefill outboxes, deliver ready transfers, retire drained
    /// ranks that emptied, sample peak pages (dead ranks excluded).
    fn migrate_and_sample(&mut self) -> anyhow::Result<bool> {
        let mut any = false;
        if let ClusterMode::Disaggregated { prefill_ranks, .. } = self.mode {
            for r in self.router.ranks.iter_mut().take(prefill_ranks) {
                self.in_flight.extend(std::mem::take(&mut r.handoff_outbox));
            }
        }
        if !self.in_flight.is_empty() {
            any |= self.deliver_handoffs(self.handoff_base())?;
        }
        if self.elastic {
            for i in 0..self.dp() {
                if self.router.health(i) == RankHealth::Draining
                    && self.router.ranks[i].pending() == 0
                {
                    self.router.set_health(i, RankHealth::Dead);
                    self.resample_pages(i);
                }
            }
        }
        #[cfg(debug_assertions)]
        {
            let sweep: usize = (0..self.dp())
                .filter(|&i| self.router.health(i) != RankHealth::Dead)
                .map(|i| self.router.ranks[i].cache.used_pages())
                .sum();
            debug_assert_eq!(
                self.used_total, sweep,
                "incremental page accounting drifted from the fleet sweep"
            );
        }
        self.metrics.observe_pages(self.used_total);
        Ok(any)
    }

    /// Deliver every in-flight transfer that fits a live target right now.
    /// Targets are the *active* ranks at or above `base` (decode ranks in
    /// disaggregated mode, everyone in colocated recovery). On an elastic
    /// fleet a transfer that no surviving rank could place even when empty
    /// is dropped and counted — parking it forever would wedge the drive.
    fn deliver_handoffs(&mut self, base: usize) -> anyhow::Result<bool> {
        let mut progressed = false;
        let mut parked = VecDeque::new();
        while let Some((seq, wire)) = self.in_flight.pop_front() {
            // mid-prefill evacuees still owe prompt tokens on top of the
            // remaining generation (zero for disaggregated handoffs)
            let remaining =
                seq.pending_prefill() + (seq.request.max_new_tokens - seq.generated.len());
            let needed = wire.pages_needed(remaining);
            let targets: Vec<usize> = (base..self.dp())
                .filter(|&i| self.router.health(i) == RankHealth::Active)
                .collect();
            if self.elastic
                && targets
                    .iter()
                    .all(|&i| needed > self.router.ranks[i].cache.cfg.capacity_pages)
            {
                self.evac_ids.remove(&seq.id());
                self.metrics.dropped += 1;
                progressed = true;
                continue;
            }
            let loads: Vec<RankLoad> = targets
                .iter()
                .map(|&i| {
                    let r = &self.router.ranks[i];
                    let open = r.can_accept_handoff(wire.tokens(), remaining);
                    RankLoad {
                        tokens: r.load_tokens(),
                        free_pages: r.cache.free_pages(),
                        // a slot-saturated rank is marked infeasible by
                        // inflating its need past any possible headroom
                        pages_needed: if open { needed } else { r.cache.cfg.capacity_pages + 1 },
                        prefix_hit_tokens: 0,
                        evictable_pages: r.cache.evictable_pages(),
                    }
                })
                .collect();
            match pick_handoff_rank(&loads) {
                Some(j) => {
                    let id = seq.id();
                    self.router.ranks[targets[j]].accept_handoff(seq, wire)?;
                    self.resample_pages(targets[j]);
                    if self.evac_ids.remove(&id) {
                        self.metrics.recovered += 1;
                    }
                    progressed = true;
                }
                None => parked.push_back((seq, wire)),
            }
        }
        self.in_flight = parked;
        Ok(progressed)
    }

    /// Event-driven virtual drive: pop `(time, rank)` wake-ups off the
    /// [`EventLoop`] and let every rank whose clock reached the batch time
    /// take one scheduling step, re-arming it at `time + step_costs[rank]`.
    /// A rank woken by a mid-run handoff delivery re-enters at the batch
    /// time plus its own cost (it steps in the next batch, exactly where a
    /// lock-step round would have picked it up). Stops once every rank's
    /// clock would pass `until` (returns false) or the cluster drains
    /// (returns true).
    ///
    /// With uniform `step_costs` this reproduces the legacy lock-step
    /// `step_all` loop byte-for-byte — same per-request outputs, same
    /// `ServerMetrics`/`ClusterMetrics` counters (pinned by
    /// `integration_simulate`). Heterogeneous costs model stragglers and
    /// prefill/decode asymmetry: a slow rank falls behind instead of
    /// stretching every round.
    ///
    /// When no rank can make progress while requests are still pending,
    /// returns a hard error naming the stuck rank and its queue depth
    /// instead of looping or relying on the caller to notice.
    pub fn run_until(&mut self, step_costs: &[f64], until: f64) -> anyhow::Result<bool> {
        let dp = self.dp();
        assert_eq!(step_costs.len(), dp, "one virtual step cost per rank");
        assert!(
            step_costs.iter().all(|c| c.is_finite() && *c > 0.0),
            "step costs must be positive and finite: {step_costs:?}"
        );
        // ranks polled without progress since the cluster last progressed
        let mut stalled = vec![false; dp];
        // persistent wake-up heap: every rank holding work owns one live
        // entry at its current clock; entries orphaned by a clock bump or
        // a drained queue are discarded lazily at the head (previously
        // this heap was rebuilt from scratch every batch — O(dp) pushes
        // per pop even when one rank was due)
        let mut ready: EventLoop<()> = EventLoop::new();
        for i in 0..dp {
            if self.router.ranks[i].pending() > 0 {
                ready.push(self.vclock[i], i, ());
            }
        }
        while self.pending() > 0 {
            loop {
                let (t, i) = match ready.peek() {
                    Some(e) => (e.time, e.rank),
                    None => break,
                };
                if self.entry_live(t, i) {
                    break;
                }
                ready.pop();
            }
            if ready.is_empty() {
                // work exists only as in-flight transfers; deliver or stop
                if self.migrate_and_sample()? {
                    for i in 0..dp {
                        if self.router.ranks[i].pending() > 0 {
                            ready.push(self.vclock[i], i, ());
                        }
                    }
                    continue;
                }
                anyhow::bail!(
                    "cluster stuck: {} transfers in flight and no decode rank can accept \
                     them (no rank holds queued work)",
                    self.in_flight.len()
                );
            }
            let batch = ready.pop_batch();
            let t = batch[0].time;
            if t > until {
                return Ok(false);
            }
            let was_idle: Vec<bool> =
                (0..dp).map(|i| self.router.ranks[i].pending() == 0).collect();
            let mut progressed = false;
            // the batch can carry entries orphaned at the same instant (or
            // duplicates of one rank); act once per rank, live entries only
            let mut seen = vec![false; dp];
            for e in &batch {
                let i = e.rank;
                if seen[i] || !self.entry_live(e.time, i) {
                    continue;
                }
                seen[i] = true;
                let pre_tier = self.tier.as_ref().map(|_| {
                    let m = &self.router.ranks[i].metrics;
                    (m.spills, m.restores)
                });
                if self.router.ranks[i].step()? {
                    progressed = true;
                } else {
                    stalled[i] = true;
                }
                self.vclock[i] = t + step_costs[i];
                if let Some((sp0, rs0)) = pre_tier {
                    let (sp1, rs1) = {
                        let m = &self.router.ranks[i].metrics;
                        (m.spills, m.restores)
                    };
                    let link = self.tier.as_mut().expect("pre_tier implies an armed link");
                    // each transfer serializes on its direction's link clock;
                    // spills ride the down link, restores the up link
                    let mut landed = 0.0f64;
                    for _ in sp0..sp1 {
                        let start = link.dn_free[i].max(t);
                        link.dn_free[i] = start + link.transfer_s;
                        landed = landed.max(link.dn_free[i]);
                    }
                    for _ in rs0..rs1 {
                        let start = link.up_free[i].max(t);
                        link.up_free[i] = start + link.transfer_s;
                        landed = landed.max(link.up_free[i]);
                    }
                    let moved = (sp1 - sp0) + (rs1 - rs0);
                    if moved > 0 {
                        if link.async_io {
                            // decode keeps stepping under the transfer: the
                            // rank clock stays at its normal step cadence
                            link.overlapped += moved;
                        } else {
                            // synchronous baseline: the rank blocks until
                            // its last transfer lands
                            link.stalls += moved;
                            self.vclock[i] = self.vclock[i].max(landed);
                        }
                    }
                }
                self.resample_pages(i);
            }
            progressed |= self.migrate_and_sample()?;
            // a rank woken by this batch's deliveries steps NEXT batch —
            // its stale clock must not let it run ahead of the batch time
            for i in 0..dp {
                if was_idle[i] && self.router.ranks[i].pending() > 0 {
                    self.vclock[i] = self.vclock[i].max(t + step_costs[i]);
                }
            }
            // restore the heap invariant for every rank this batch touched:
            // stepped ranks re-arm at their advanced clock, freshly woken
            // ranks arm at their (possibly bumped) clock; untouched busy
            // ranks still own their live entry
            for i in 0..dp {
                if self.router.ranks[i].pending() > 0 && (seen[i] || was_idle[i]) {
                    ready.push(self.vclock[i], i, ());
                }
            }
            if progressed {
                stalled.iter_mut().for_each(|s| *s = false);
            } else if (0..dp).all(|i| self.router.ranks[i].pending() == 0 || stalled[i]) {
                // every rank holding work has been polled since the last
                // progress and none moved: name the stuck rank + queues
                let (worst, waiting, running) = (0..dp)
                    .filter(|&i| self.router.ranks[i].pending() > 0)
                    .map(|i| {
                        let (w, r) = self.router.ranks[i].queue_depths();
                        (i, w, r)
                    })
                    .max_by_key(|&(_, w, r)| w + r)
                    .expect("pending > 0 implies a rank holds work or a transfer is parked");
                anyhow::bail!(
                    "cluster stuck: rank {worst} made no progress with {waiting} waiting + \
                     {running} running sequences and {} free pages; {} requests pending \
                     over {dp} ranks ({} transfers in flight)",
                    self.router.ranks[worst].cache.free_pages(),
                    self.pending(),
                    self.in_flight.len()
                );
            }
        }
        Ok(true)
    }

    /// Drive every rank to completion on per-rank virtual clocks; outcomes
    /// are merged and id-sorted.
    pub fn run_virtual(&mut self, step_costs: &[f64]) -> anyhow::Result<Vec<RequestOutcome>> {
        let t0 = Instant::now();
        let done = self.run_until(step_costs, f64::INFINITY)?;
        debug_assert!(done, "an unbounded run_until drains or errors");
        Ok(self.router.drain_finished(t0.elapsed().as_secs_f64()))
    }

    /// Drive every rank to completion in the degenerate uniform-cost mode
    /// (every step costs 1.0 virtual second on every rank — the lock-step
    /// equivalent). A stuck cluster returns the `run_until` error naming
    /// the wedged rank and its queue depth.
    pub fn run_to_completion(&mut self) -> anyhow::Result<Vec<RequestOutcome>> {
        let costs = vec![1.0; self.dp()];
        self.run_virtual(&costs)
    }

    /// Total prompt tokens served from prefix caches instead of re-prefilled.
    pub fn prefix_hit_tokens(&self) -> u64 {
        self.router.ranks.iter().map(|r| r.metrics.prefix_hit_tokens).sum()
    }

    /// Total sequences migrated prefill→decode (disaggregated mode).
    pub fn handoffs(&self) -> u64 {
        self.router.ranks.iter().map(|r| r.metrics.handoffs_in).sum()
    }

    /// Total KV bytes serialized onto the wire by handoffs.
    pub fn handoff_wire_bytes(&self) -> u64 {
        self.router.ranks.iter().map(|r| r.metrics.handoff_wire_bytes).sum()
    }

    /// Wall-clock-free counters for the whole cluster: routing decisions,
    /// the page peak, and every rank's deterministic serving counters —
    /// two runs over the same submissions must agree on all of these.
    pub fn counters(&self) -> Vec<(String, u64)> {
        let mut out = vec![("peak_pages_used".to_string(), self.metrics.peak_pages_used as u64)];
        for (k, v) in [
            ("fails", self.metrics.fails),
            ("joins", self.metrics.joins),
            ("drains", self.metrics.drains),
            ("evacuated", self.metrics.evacuated),
            ("recovered", self.metrics.recovered),
            ("dropped", self.metrics.dropped),
        ] {
            out.push((k.to_string(), v));
        }
        if let Some(link) = &self.tier {
            out.push(("tier_overlapped".to_string(), link.overlapped));
            out.push(("tier_stalls".to_string(), link.stalls));
        }
        for (i, r) in self.router.ranks.iter().enumerate() {
            out.push((format!("rank{i}_routed"), self.metrics.routed[i]));
            for (k, v) in r.metrics.counters() {
                out.push((format!("rank{i}_{k}"), v));
            }
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn req(id: u64, prompt_len: usize, out: usize) -> ServeRequest {
        let prompt: Vec<i32> =
            (0..prompt_len).map(|i| 40 + (id as i32 * 7 + i as i32) % 50).collect();
        ServeRequest {
            id,
            prompt,
            max_new_tokens: out,
            temperature: 0.0,
            seed: id,
            ignore_eos: true,
        }
    }

    /// Drive a capacity-starved 2-rank fleet under the given link mode and
    /// return (outcomes, final virtual time, overlapped, stalls).
    fn drive_tiered(async_io: bool) -> (Vec<RequestOutcome>, f64, u64, u64) {
        let mut c = ClusterServer::sim(2, 10, CacheMode::Fp8, RoutePolicy::ShortestQueue).unwrap();
        c.set_tier_link(0.5, async_io);
        for id in 0..8u64 {
            c.submit(req(id, 256 + (id as usize % 3) * 64, 24));
        }
        let out = c.run_virtual(&[1.0, 1.0]).unwrap();
        let link = c.tier_link().unwrap();
        (out, c.virtual_time(), link.overlapped, link.stalls)
    }

    #[test]
    fn async_tier_link_overlaps_transfers_with_decode() {
        let (sync_out, sync_t, sync_ov, sync_st) = drive_tiered(false);
        let (async_out, async_t, async_ov, async_st) = drive_tiered(true);
        // the link model only re-prices the clock: scheduling decisions and
        // emitted tokens are identical across the two modes
        assert_eq!(sync_out.len(), async_out.len());
        for (a, b) in sync_out.iter().zip(async_out.iter()) {
            assert_eq!(a.id, b.id);
            assert_eq!(a.generated, b.generated);
        }
        assert!(sync_st > 0, "a capacity-starved fleet must spill");
        assert_eq!(async_ov, sync_st, "every sync stall overlaps in async mode");
        assert_eq!((async_st, sync_ov), (0, 0));
        assert!(
            async_t <= sync_t,
            "overlapping transfers with decode cannot lengthen the drive: \
             async {async_t} vs sync {sync_t}"
        );
    }
}
