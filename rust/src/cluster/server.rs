//! Multi-rank data-parallel serving: `ClusterServer` owns `dp` real
//! `Server` replicas — each with its own `ModelEngine`, `PagedKvCache` and
//! mixed chunked-prefill scheduler — and drives them lock-step (one
//! scheduling step per rank per round). Requests enter through the
//! `coordinator::Router` policy (shortest-queue or prefix-affinity), so a
//! shared prompt prefix can land every group member on the rank already
//! holding those pages.

use crate::anyhow;
use crate::coordinator::metrics::ClusterMetrics;
use crate::coordinator::router::{RoutePolicy, Router};
use crate::coordinator::{RequestOutcome, ServeRequest, Server};
use crate::kvcache::CacheMode;
use crate::runtime::ModelEngine;
use std::time::Instant;

pub struct ClusterServer {
    pub router: Router,
    pub metrics: ClusterMetrics,
}

impl ClusterServer {
    pub fn new(ranks: Vec<Server>, policy: RoutePolicy) -> ClusterServer {
        let dp = ranks.len();
        let metrics = ClusterMetrics::new(dp);
        ClusterServer { router: Router::with_policy(ranks, policy), metrics }
    }

    /// A cluster of `dp` offline sim ranks (each its own engine + cache +
    /// scheduler) — the multi-rank quickstart and test entry point.
    pub fn sim(
        dp: usize,
        capacity_pages: usize,
        mode: CacheMode,
        policy: RoutePolicy,
    ) -> anyhow::Result<ClusterServer> {
        let ranks = (0..dp)
            .map(|_| Ok(Server::new(ModelEngine::sim(mode)?, capacity_pages)))
            .collect::<anyhow::Result<Vec<Server>>>()?;
        Ok(ClusterServer::new(ranks, policy))
    }

    pub fn dp(&self) -> usize {
        self.router.dp()
    }

    pub fn rank(&self, i: usize) -> &Server {
        &self.router.ranks[i]
    }

    pub fn pending(&self) -> usize {
        self.router.pending()
    }

    /// Route and enqueue one request; returns the chosen rank.
    pub fn submit(&mut self, req: ServeRequest) -> usize {
        let rank = self.router.submit(req);
        self.metrics.routed[rank] += 1;
        rank
    }

    /// One lock-step round: every rank takes one scheduling step, then the
    /// cluster-wide page allocation is sampled for the peak-pages metric.
    pub fn step_all(&mut self) -> anyhow::Result<bool> {
        let any = self.router.step_all()?;
        let used: usize = self.router.ranks.iter().map(|r| r.cache.used_pages()).sum();
        self.metrics.observe_pages(used);
        Ok(any)
    }

    /// Drive every rank to completion; outcomes are merged and id-sorted.
    /// Unlike `Router::run_to_completion`, every round goes through
    /// `step_all` so the peak-pages metric keeps sampling.
    pub fn run_to_completion(&mut self) -> anyhow::Result<Vec<RequestOutcome>> {
        let t0 = Instant::now();
        while self.pending() > 0 {
            if !self.step_all()? && self.pending() > 0 {
                anyhow::bail!(
                    "cluster deadlock: {} requests pending over {} ranks",
                    self.pending(),
                    self.dp()
                );
            }
        }
        Ok(self.router.drain_finished(t0.elapsed().as_secs_f64()))
    }

    /// Total prompt tokens served from prefix caches instead of re-prefilled.
    pub fn prefix_hit_tokens(&self) -> u64 {
        self.router.ranks.iter().map(|r| r.metrics.prefix_hit_tokens).sum()
    }

    /// Wall-clock-free counters for the whole cluster: routing decisions,
    /// the page peak, and every rank's deterministic serving counters —
    /// two runs over the same submissions must agree on all of these.
    pub fn counters(&self) -> Vec<(String, u64)> {
        let mut out = vec![("peak_pages_used".to_string(), self.metrics.peak_pages_used as u64)];
        for (i, r) in self.router.ranks.iter().enumerate() {
            out.push((format!("rank{i}_routed"), self.metrics.routed[i]));
            for (k, v) in r.metrics.counters() {
                out.push((format!("rank{i}_{k}"), v));
            }
        }
        out
    }
}
