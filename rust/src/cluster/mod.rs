//! Cluster topology + collective cost model for the DP/TP study (Fig. 1).
//!
//! The real 8-GPU node is simulated (DESIGN.md §Substitutions): `topology`
//! enumerates and validates (DP, TP) layouts and accounts per-rank memory;
//! `collective` prices the TP all-reduce. The Fig. 1 bench combines these
//! with `perfmodel` to regenerate the paper's throughput comparison; the
//! serving examples use real multi-`Server` DP via `coordinator::Router`.

pub mod collective;
pub mod topology;

pub use collective::{allreduce_time_s, CollectiveSpec};
pub use topology::{NodeTopology, RankMemory};
