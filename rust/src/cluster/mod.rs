//! The multi-rank cluster layer: DP/TP topology accounting, the collective
//! cost model, and the real data-parallel serving subsystem.
//!
//! `topology` enumerates and validates (DP, TP) layouts of the simulated
//! 8-GPU node and accounts per-rank memory (weights shard across the TP
//! group but replicate across DP replicas); `collective` prices the TP
//! all-reduce that `perfmodel::e2e` folds into step times; `server` is the
//! working subsystem — `ClusterServer` drives `dp` real `Server` replicas
//! lock-step behind the prefix-affinity/shortest-queue `Router`. The Fig. 1
//! bench combines topology + collectives with `perfmodel`; the
//! `serve_cluster` bench A/Bs the routing policies in virtual time.

pub mod collective;
pub mod server;
pub mod topology;

pub use collective::{allreduce_time_s, CollectiveSpec};
pub use server::ClusterServer;
pub use topology::{NodeTopology, RankMemory};
