//! The multi-rank cluster layer: DP/TP topology accounting, the collective
//! cost model, and the real data-parallel serving subsystem.
//!
//! `topology` enumerates and validates (DP, TP) layouts of the simulated
//! 8-GPU node and accounts per-rank memory (weights shard across the TP
//! group but replicate across DP replicas); `collective` prices the TP
//! all-reduce that `perfmodel::e2e` folds into step times plus the
//! point-to-point KV-migration transfer; `server` is the working
//! subsystem — `ClusterServer` drives real `Server` replicas lock-step,
//! either colocated behind the prefix-affinity/shortest-queue `Router` or
//! **disaggregated** (dedicated prefill ranks migrating finished prompts
//! to decode ranks over the `KvWireBlock` wire format). The Fig. 1 bench
//! combines topology + collectives with `perfmodel`; the `serve_cluster`
//! and `serve_disagg` benches A/B the topologies in virtual time.

pub mod collective;
pub mod server;
pub mod topology;

pub use collective::{allreduce_time_s, transfer_time_s, CollectiveSpec};
pub use server::{ClusterMode, ClusterServer, TierLinkModel};
pub use topology::{NodeTopology, RankMemory};
