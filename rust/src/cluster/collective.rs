//! Collective cost model (ring all-reduce over NVLink) for TP layouts.

#[derive(Clone, Copy, Debug)]
pub struct CollectiveSpec {
    /// per-GPU link bandwidth, bytes/s
    pub link_bw: f64,
    /// per-collective launch/sync latency, seconds
    pub latency_s: f64,
}

impl CollectiveSpec {
    pub fn nvlink() -> CollectiveSpec {
        CollectiveSpec { link_bw: 450.0e9, latency_s: 5.0e-6 }
    }
}

/// Ring all-reduce time: 2·(n-1)/n · bytes / bw + latency.
pub fn allreduce_time_s(spec: &CollectiveSpec, bytes: f64, ranks: usize) -> f64 {
    if ranks <= 1 {
        return 0.0;
    }
    let n = ranks as f64;
    2.0 * (n - 1.0) / n * bytes / spec.link_bw + spec.latency_s
}

/// Point-to-point transfer time over one link: bytes / bw + latency. Prices
/// the prefill→decode `KvWireBlock` migration in disaggregated serving.
pub fn transfer_time_s(spec: &CollectiveSpec, bytes: f64) -> f64 {
    bytes / spec.link_bw + spec.latency_s
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn single_rank_is_free() {
        assert_eq!(allreduce_time_s(&CollectiveSpec::nvlink(), 1e9, 1), 0.0);
    }

    #[test]
    fn scales_with_bytes_and_saturates_with_ranks() {
        let s = CollectiveSpec::nvlink();
        let t2 = allreduce_time_s(&s, 1e6, 2);
        let t8 = allreduce_time_s(&s, 1e6, 8);
        assert!(t8 > t2);
        // ring factor approaches 2x as n → ∞: t8/t2 < 2
        assert!(t8 / t2 < 2.0);
        let tbig = allreduce_time_s(&s, 2e6, 8);
        assert!(tbig > t8 && tbig < 2.0 * t8);
    }

    #[test]
    fn latency_floor() {
        let s = CollectiveSpec::nvlink();
        assert!(allreduce_time_s(&s, 8.0, 8) >= s.latency_s);
    }

    #[test]
    fn transfer_scales_linearly_with_a_latency_floor() {
        let s = CollectiveSpec::nvlink();
        assert!(transfer_time_s(&s, 0.0) == s.latency_s);
        let t1 = transfer_time_s(&s, 1e9) - s.latency_s;
        let t2 = transfer_time_s(&s, 2e9) - s.latency_s;
        assert!((t2 - 2.0 * t1).abs() < 1e-12);
    }
}
