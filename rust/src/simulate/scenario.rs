//! Scenario definitions: each serve bench is a THIN configuration of the
//! shared harness — rank count and roles, routing policy, timing mode,
//! scheduler profiles, cost model, per-rank speed factors — plus the exact
//! report-field selection its committed BENCH_*.json baseline carries.
//!
//! | bench            | ranks              | routing           | timing |
//! |------------------|--------------------|-------------------|--------|
//! | serve_mixed      | 1                  | single            | event  |
//! | serve_cluster    | DP ∈ {1,2,4}       | shortest/affinity | lock-step |
//! | serve_disagg     | n/2 prefill + n/2  | disagg / affinity | event  |
//! | serve_straggler  | 4 (rank 0 @ 1.5x)  | shortest/affinity | event  |
//! | serve_elastic    | 4 fail / 1→6 auto  | affinity/shortest | event  |
//! | serve_spec       | 1 (MTP draft/verify) | single          | event  |
//!
//! Adding a new serving study should be a new `Scenario` constructor here
//! (plus a Python mirror in `serve_port_common.py` wrappers), not another
//! hand-rolled simulator.

use super::harness::{CostModel, Harness, SimResult};
use crate::anyhow;
use crate::coordinator::scheduler::SchedulerConfig;
use crate::perfmodel::{DeploymentConfig, GpuSpec, KernelKind, ModelSpec};
use crate::util::json::Json;
use crate::workload::Request;

/// GPUs per simulated node: DP ranks run TP = NODE_GPUS / DP.
pub const NODE_GPUS: usize = 8;

/// How arrivals are routed onto ranks.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum SimRoute {
    /// one rank, no routing decision (serve_mixed)
    Single,
    /// capacity-aware shortest queue (`router::pick_rank`)
    ShortestQueue,
    /// prefix-affinity (`router::pick_rank_affinity`)
    PrefixAffinity,
    /// least-loaded prefill rank; decode ranks receive only migrants
    /// placed by `router::pick_handoff_rank`
    Disagg,
}

/// How virtual time advances.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum SimTiming {
    /// one action per rank per round, charged the slowest rank's step
    LockStep,
    /// per-rank clocks; the global clock follows the earliest wake-up
    EventDriven,
}

/// SLO-driven autoscaler policy (`serve_elastic` autoscale arm): scale up
/// on queue-depth or TTFT-p95 breach, drain-then-remove the
/// highest-numbered active rank after sustained low load.
#[derive(Clone, Copy, Debug)]
pub struct AutoscaleConfig {
    /// never drain below this many active ranks
    pub min_ranks: usize,
    /// never provision above this many (active + joining)
    pub max_ranks: usize,
    /// evaluation cadence (virtual seconds)
    pub eval_interval_s: f64,
    /// scale up when mean waiting per active rank exceeds this
    pub queue_high: f64,
    /// eligible to drain when mean (waiting + running) per active rank is
    /// at or below this
    pub queue_low: f64,
    /// sustained-low-load window before a drain fires
    pub idle_for_s: f64,
    /// provisioning latency: a join lands this long after the breach
    pub join_delay_s: f64,
    /// scale up when TTFT p95 over the recent window exceeds this
    /// (0 disables the SLO signal)
    pub ttft_slo_s: f64,
}

/// Elastic-membership configuration (event-driven colocated mode only).
/// No `Default`: a caller must state `recover` explicitly — silently
/// defaulting to drop-everything would be a trap.
#[derive(Clone, Debug)]
pub struct ElasticConfig {
    /// injected failures as (virtual time, rank index)
    pub failures: Vec<(f64, usize)>,
    /// re-migrate a failed rank's in-progress KV over the FP8 wire path
    /// (false = the no-migration baseline: those sequences drop)
    pub recover: bool,
    /// SLO-driven autoscaler; None = fixed fleet (failures only)
    pub autoscale: Option<AutoscaleConfig>,
}

/// Speculative-decoding arm configuration (`serve_spec`): the scheduler
/// upgrades pure-decode steps to [`crate::coordinator::Action::SpecDecode`]
/// draft/verify steps, and the harness draws each draft token's acceptance
/// from a dedicated deterministic stream at this rate.
#[derive(Clone, Copy, Debug)]
pub struct SpecSim {
    /// draft tokens proposed per sequence per speculative step
    pub draft_len: usize,
    /// probability each drafted token matches the verify pass's target
    pub accept_rate: f64,
}

/// Tiered-KV-cache arm configuration (`serve_tiered`): the async host
/// spill/prefetch engine (`kvcache::tiered`) whose PCIe transfers complete
/// as event-loop flights overlapped with decode, plus an optional
/// rank-reduced cold-page compression tier (`kvcache::compress`) that
/// discounts residency for pages older than the hot window.
#[derive(Clone, Copy, Debug)]
pub struct TieredSim {
    /// spill/preempt and resume become non-blocking SpillAsync/Prefetch
    /// flights (false = the tier engine exists but every transfer still
    /// blocks the rank, like the synchronous baseline)
    pub async_io: bool,
    /// hot window in tokens (must be a page multiple); 0 = compression off
    pub cold_after: usize,
    /// resident-bytes ratio of a compressed cold page vs the hot FP8 page
    /// format (in (0, 1]; 1.0 = no discount)
    pub comp_ratio: f64,
    /// latent rank r of the cold-page codec — prices the
    /// decompression-on-access surcharge (`perfmodel::e2e::decompress_s`)
    pub comp_rank: usize,
}

/// One simulated serving arm (see module docs for the bench mapping).
#[derive(Clone, Debug)]
pub struct Scenario {
    pub ranks: usize,
    /// ranks `0..prefill_ranks` prefill + hand off (0 = colocated)
    pub prefill_ranks: usize,
    pub routing: SimRoute,
    pub timing: SimTiming,
    /// scheduler profile of colocated/decode ranks (includes the policy)
    pub sched: SchedulerConfig,
    /// scheduler profile of prefill ranks (disaggregated scenarios)
    pub prefill_sched: Option<SchedulerConfig>,
    /// KV pages per rank
    pub capacity_pages: usize,
    pub cost: CostModel,
    /// per-rank step-cost multipliers; empty = all 1.0. Only event timing
    /// can express a straggler — a lock-step round would charge every rank
    /// the slow rank's step.
    pub speeds: Vec<f64>,
    /// elastic membership (failure injection + autoscaling); None = the
    /// fixed fleet every non-elastic scenario runs
    pub elastic: Option<ElasticConfig>,
    /// speculative decoding (MTP draft/verify); None = every step is a
    /// plain prefill/decode/mixed step and the scheduler gate stays off
    pub spec: Option<SpecSim>,
    /// tiered KV cache (async host spill/prefetch + cold-page compression);
    /// None = the plain binary synchronous-spill cache every other
    /// scenario runs
    pub tiered: Option<TieredSim>,
    /// Run the pre-optimization reference paths (full linear scans per
    /// routing decision, full waiting views per scheduler call, per-round
    /// Σ-sweep page sampling, rebuilt per-iteration candidate lists)
    /// instead of the indexed ones. Both arms are byte-identical —
    /// `prop_simperf` pins it — so this exists for the perf_sim bench's
    /// before/after arms and the property test, not for callers.
    pub naive: bool,
}

impl Scenario {
    /// Run this scenario over a trace (deterministic: two runs produce
    /// byte-identical results). Errors — never panics — on a wedged or
    /// malformed simulation (the diagnostics name the stuck state).
    pub fn run(&self, trace: &[Request]) -> anyhow::Result<SimResult> {
        Harness::new(self, trace).run(trace)
    }

    /// The calibrated analytical cost model for a DP layout on the node.
    pub fn h20_cost(dp: usize, tp: usize) -> CostModel {
        CostModel::Analytic {
            gpu: GpuSpec::h20(),
            model: ModelSpec::deepseek_v31(),
            dcfg: DeploymentConfig { dp, tp },
            kind: KernelKind::SnapMlaFp8,
        }
    }

    /// serve_mixed arm: one rank, scheduler-policy A/B (the policy rides in
    /// `sched.policy`), DP8/TP1 per-rank cost shape.
    pub fn mixed(sched: SchedulerConfig, capacity_pages: usize) -> Scenario {
        Scenario {
            ranks: 1,
            prefill_ranks: 0,
            routing: SimRoute::Single,
            timing: SimTiming::EventDriven,
            sched,
            prefill_sched: None,
            capacity_pages,
            cost: Self::h20_cost(8, 1),
            speeds: Vec::new(),
            elastic: None,
            spec: None,
            tiered: None,
            naive: false,
        }
    }

    /// serve_cluster arm: DP colocated ranks (TP = 8/DP) driven lock-step.
    pub fn cluster(
        routing: SimRoute,
        dp: usize,
        sched: SchedulerConfig,
        capacity_pages: usize,
    ) -> Scenario {
        Scenario {
            ranks: dp,
            prefill_ranks: 0,
            routing,
            timing: SimTiming::LockStep,
            sched,
            prefill_sched: None,
            capacity_pages,
            cost: Self::h20_cost(dp, NODE_GPUS / dp),
            speeds: Vec::new(),
            elastic: None,
            spec: None,
            tiered: None,
            naive: false,
        }
    }

    /// serve_disagg arm: `prefill_ranks` dedicated prefill ranks handing
    /// off over the FP8 wire (0 = the colocated reference arm), event time.
    pub fn disagg(
        n: usize,
        prefill_ranks: usize,
        sched: SchedulerConfig,
        prefill_sched: SchedulerConfig,
        capacity_pages: usize,
    ) -> Scenario {
        Scenario {
            ranks: n,
            prefill_ranks,
            routing: if prefill_ranks == 0 { SimRoute::PrefixAffinity } else { SimRoute::Disagg },
            timing: SimTiming::EventDriven,
            sched,
            prefill_sched: Some(prefill_sched),
            capacity_pages,
            cost: Self::h20_cost(n, NODE_GPUS / n),
            speeds: Vec::new(),
            elastic: None,
            spec: None,
            tiered: None,
            naive: false,
        }
    }

    /// serve_straggler arm: DP colocated ranks in event time with per-rank
    /// speed factors — the scenario lock-step could not express.
    pub fn straggler(
        routing: SimRoute,
        dp: usize,
        speeds: Vec<f64>,
        sched: SchedulerConfig,
        capacity_pages: usize,
    ) -> Scenario {
        Scenario {
            ranks: dp,
            prefill_ranks: 0,
            routing,
            timing: SimTiming::EventDriven,
            sched,
            prefill_sched: None,
            capacity_pages,
            cost: Self::h20_cost(dp, NODE_GPUS / dp),
            speeds,
            elastic: None,
            spec: None,
            tiered: None,
            naive: false,
        }
    }

    /// serve_spec arm: the serve_mixed single-rank scenario with the MTP
    /// draft/verify gate on — the scheduler upgrades pure-decode steps to
    /// `SpecDecode` and the harness plays the acceptance stream.
    pub fn spec_serve(
        sched: SchedulerConfig,
        capacity_pages: usize,
        draft_len: usize,
        accept_rate: f64,
    ) -> Scenario {
        Scenario {
            spec: Some(SpecSim { draft_len, accept_rate }),
            ..Self::mixed(sched, capacity_pages)
        }
    }

    /// serve_tiered arm: the serve_mixed single-rank scenario with the
    /// tiered KV cache armed — `None` is the synchronous binary-spill
    /// baseline, `Some` turns preempt/resume into overlapped SpillAsync/
    /// Prefetch flights and (with `cold_after > 0`) compresses cold pages.
    pub fn tiered_serve(
        sched: SchedulerConfig,
        capacity_pages: usize,
        tiered: Option<TieredSim>,
    ) -> Scenario {
        Scenario { tiered, ..Self::mixed(sched, capacity_pages) }
    }

    /// serve_elastic arm: colocated event-driven ranks with elastic
    /// membership. Takes the cost model explicitly because the fleet size
    /// is no longer the cost shape: the autoscale arm STARTS at one rank
    /// but prices every rank as one DP4/TP2 slice of the node (a joining
    /// rank is another identical slice, not a re-shard).
    pub fn elastic(
        routing: SimRoute,
        ranks: usize,
        cost: CostModel,
        sched: SchedulerConfig,
        capacity_pages: usize,
        elastic: ElasticConfig,
    ) -> Scenario {
        Scenario {
            ranks,
            prefill_ranks: 0,
            routing,
            timing: SimTiming::EventDriven,
            sched,
            prefill_sched: None,
            capacity_pages,
            cost,
            speeds: Vec::new(),
            elastic: Some(elastic),
            spec: None,
            tiered: None,
            naive: false,
        }
    }
}

fn routed_json(r: &SimResult) -> Json {
    Json::arr(r.routed.iter().map(|&x| Json::num(x as f64)))
}

/// The exact result-row field set of BENCH_serve.json.
pub fn mixed_result_json(policy: &str, r: &SimResult) -> Json {
    Json::obj(vec![
        ("policy", Json::str(policy)),
        ("requests", Json::num(r.requests as f64)),
        ("gen_tokens", Json::num(r.gen_tokens as f64)),
        ("wall_s", Json::num(r.wall_s)),
        ("decode_tok_per_s", Json::num(r.tok_per_s())),
        ("ttft_p50_ms", Json::num(r.ttft.median() * 1e3)),
        ("ttft_p95_ms", Json::num(r.ttft.percentile(95.0) * 1e3)),
        ("ttft_short_p95_ms", Json::num(r.ttft_short.percentile(95.0) * 1e3)),
        ("mean_decode_batch", Json::num(r.mean_decode_batch())),
        ("decode_steps", Json::num(r.decode_steps as f64)),
        ("chunk_tokens", Json::num(r.chunk_tokens as f64)),
        ("spills", Json::num(r.spills as f64)),
        ("restores", Json::num(r.restores as f64)),
    ])
}

/// The exact result-row field set of BENCH_cluster.json.
pub fn cluster_result_json(policy: &str, dp: usize, r: &SimResult) -> Json {
    Json::obj(vec![
        ("policy", Json::str(policy)),
        ("dp", Json::num(dp as f64)),
        ("requests", Json::num(r.requests as f64)),
        ("gen_tokens", Json::num(r.gen_tokens as f64)),
        ("wall_s", Json::num(r.wall_s)),
        ("tok_per_s", Json::num(r.tok_per_s())),
        ("ttft_p50_ms", Json::num(r.ttft.median() * 1e3)),
        ("ttft_p95_ms", Json::num(r.ttft.percentile(95.0) * 1e3)),
        ("peak_pages", Json::num(r.peak_pages as f64)),
        ("prefill_tokens", Json::num(r.prefill_tokens as f64)),
        ("prefix_hit_tokens", Json::num(r.prefix_hit_tokens as f64)),
        ("mean_decode_batch", Json::num(r.mean_decode_batch())),
        ("rounds", Json::num(r.rounds as f64)),
        ("spills", Json::num(r.spills as f64)),
        ("routed", routed_json(r)),
    ])
}

/// The exact result-row field set of BENCH_disagg.json.
pub fn disagg_result_json(r: &SimResult) -> Json {
    let policy = if r.prefill_ranks == 0 { "colocated" } else { "disagg" };
    Json::obj(vec![
        ("policy", Json::str(policy)),
        ("ranks", Json::num(r.ranks as f64)),
        ("prefill_ranks", Json::num(r.prefill_ranks as f64)),
        ("decode_ranks", Json::num(r.decode_ranks as f64)),
        ("requests", Json::num(r.requests as f64)),
        ("gen_tokens", Json::num(r.gen_tokens as f64)),
        ("wall_s", Json::num(r.wall_s)),
        ("tok_per_s", Json::num(r.tok_per_s())),
        ("ttft_p50_ms", Json::num(r.ttft.median() * 1e3)),
        ("ttft_p95_ms", Json::num(r.ttft.percentile(95.0) * 1e3)),
        ("itl_p50_ms", Json::num(r.itl.median() * 1e3)),
        ("itl_p95_ms", Json::num(r.itl.percentile(95.0) * 1e3)),
        ("peak_pages", Json::num(r.peak_pages as f64)),
        ("prefill_tokens", Json::num(r.prefill_tokens as f64)),
        ("prefix_hit_tokens", Json::num(r.prefix_hit_tokens as f64)),
        ("mean_decode_batch", Json::num(r.mean_decode_batch())),
        ("steps", Json::num(r.steps as f64)),
        ("spills", Json::num(r.spills as f64)),
        ("handoffs", Json::num(r.handoffs as f64)),
        ("transferred_gb_fp8", Json::num(r.wire_fp8_bytes as f64 / 1e9)),
        ("transferred_gb_bf16", Json::num(r.wire_bf16_bytes as f64 / 1e9)),
        ("routed", routed_json(r)),
    ])
}

/// The exact result-row field set of BENCH_elastic.json's failure arms
/// (recover / no_migration).
pub fn elastic_failure_result_json(r: &SimResult) -> Json {
    Json::obj(vec![
        ("requests", Json::num(r.requests as f64)),
        ("completed", Json::num(r.completed as f64)),
        ("dropped", Json::num(r.dropped as f64)),
        ("evacuated", Json::num(r.evacuated as f64)),
        ("recovered", Json::num(r.recovered as f64)),
        ("fails", Json::num(r.fails as f64)),
        ("gen_tokens", Json::num(r.gen_tokens as f64)),
        ("wall_s", Json::num(r.wall_s)),
        ("tok_per_s", Json::num(r.tok_per_s())),
        ("ttft_p50_ms", Json::num(r.ttft.median() * 1e3)),
        ("ttft_p95_ms", Json::num(r.ttft.percentile(95.0) * 1e3)),
        ("handoffs", Json::num(r.handoffs as f64)),
        ("prefix_hit_tokens", Json::num(r.prefix_hit_tokens as f64)),
        ("transferred_gb_fp8", Json::num(r.wire_fp8_bytes as f64 / 1e9)),
        ("routed", routed_json(r)),
    ])
}

/// The exact result-row field set of BENCH_elastic.json's autoscale arm.
pub fn elastic_autoscale_result_json(r: &SimResult) -> Json {
    let timeline = Json::arr(r.rank_timeline.iter().map(|&(t, kind, ri, after)| {
        Json::arr(vec![
            Json::num(t),
            Json::str(kind.as_str()),
            Json::num(ri as f64),
            Json::num(after as f64),
        ])
    }));
    Json::obj(vec![
        ("requests", Json::num(r.requests as f64)),
        ("completed", Json::num(r.completed as f64)),
        ("dropped", Json::num(r.dropped as f64)),
        ("joins", Json::num(r.joins as f64)),
        ("drains", Json::num(r.drains as f64)),
        ("peak_active_ranks", Json::num(r.peak_active_ranks as f64)),
        ("final_active_ranks", Json::num(r.final_active_ranks as f64)),
        ("mean_active_ranks", Json::num(r.mean_active_ranks)),
        ("gen_tokens", Json::num(r.gen_tokens as f64)),
        ("wall_s", Json::num(r.wall_s)),
        ("tok_per_s", Json::num(r.tok_per_s())),
        ("ttft_p95_ms", Json::num(r.ttft.percentile(95.0) * 1e3)),
        ("steps", Json::num(r.steps as f64)),
        ("rank_timeline", timeline),
    ])
}

/// The exact result-row field set of BENCH_spec.json (baseline and spec
/// arms; the spec extras appear only when the arm carried a [`SpecSim`]).
pub fn spec_result_json(spec: Option<SpecSim>, r: &SimResult) -> Json {
    let mut fields = vec![
        ("requests", Json::num(r.requests as f64)),
        ("gen_tokens", Json::num(r.gen_tokens as f64)),
        ("wall_s", Json::num(r.wall_s)),
        ("tok_per_s", Json::num(r.tok_per_s())),
        ("ttft_p95_ms", Json::num(r.ttft.percentile(95.0) * 1e3)),
        ("itl_p50_ms", Json::num(r.itl.median() * 1e3)),
        ("itl_p95_ms", Json::num(r.itl.percentile(95.0) * 1e3)),
        ("decode_steps", Json::num(r.decode_steps as f64)),
        ("steps", Json::num(r.steps as f64)),
    ];
    if let Some(sp) = spec {
        fields.push(("draft_len", Json::num(sp.draft_len as f64)));
        fields.push(("accept_rate", Json::num(sp.accept_rate)));
        fields.push(("spec_steps", Json::num(r.spec_steps as f64)));
        fields.push(("spec_drafted_tokens", Json::num(r.spec_drafted_tokens as f64)));
        fields.push(("spec_tokens", Json::num(r.spec_tokens as f64)));
        fields.push(("accepted_tokens_per_step", Json::num(r.accepted_per_spec_step())));
    }
    Json::obj(fields)
}

/// The exact result-row field set of BENCH_tiered.json (sync and tiered
/// arms; `prefetches` appears only when the arm carried a [`TieredSim`]).
pub fn tiered_result_json(tiered: bool, r: &SimResult) -> Json {
    let mut fields = vec![
        ("requests", Json::num(r.requests as f64)),
        ("gen_tokens", Json::num(r.gen_tokens as f64)),
        ("wall_s", Json::num(r.wall_s)),
        ("tok_per_s", Json::num(r.tok_per_s())),
        ("ttft_p95_ms", Json::num(r.ttft.percentile(95.0) * 1e3)),
        ("itl_p50_ms", Json::num(r.itl.median() * 1e3)),
        ("itl_p95_ms", Json::num(r.itl.percentile(95.0) * 1e3)),
        ("peak_running", Json::num(r.peak_running as f64)),
        ("peak_pages", Json::num(r.peak_pages as f64)),
        ("spills", Json::num(r.spills as f64)),
        ("restores", Json::num(r.restores as f64)),
        ("steps", Json::num(r.steps as f64)),
    ];
    if tiered {
        fields.push(("prefetches", Json::num(r.prefetches as f64)));
    }
    Json::obj(fields)
}

/// The exact result-row field set of BENCH_straggler.json.
pub fn straggler_result_json(policy: &str, speeds: &[f64], r: &SimResult) -> Json {
    Json::obj(vec![
        ("policy", Json::str(policy)),
        ("speeds", Json::arr(speeds.iter().map(|&s| Json::num(s)))),
        ("requests", Json::num(r.requests as f64)),
        ("gen_tokens", Json::num(r.gen_tokens as f64)),
        ("wall_s", Json::num(r.wall_s)),
        ("tok_per_s", Json::num(r.tok_per_s())),
        ("ttft_p50_ms", Json::num(r.ttft.median() * 1e3)),
        ("ttft_p95_ms", Json::num(r.ttft.percentile(95.0) * 1e3)),
        ("itl_p50_ms", Json::num(r.itl.median() * 1e3)),
        ("itl_p95_ms", Json::num(r.itl.percentile(95.0) * 1e3)),
        ("peak_pages", Json::num(r.peak_pages as f64)),
        ("prefill_tokens", Json::num(r.prefill_tokens as f64)),
        ("prefix_hit_tokens", Json::num(r.prefix_hit_tokens as f64)),
        ("mean_decode_batch", Json::num(r.mean_decode_batch())),
        ("steps", Json::num(r.steps as f64)),
        ("spills", Json::num(r.spills as f64)),
        ("routed", routed_json(r)),
    ])
}
