//! The virtual-time serving harness: ONE simulation engine behind every
//! serve bench (`serve_mixed`, `serve_cluster`, `serve_disagg`,
//! `serve_straggler`, `serve_elastic`) and their Python ports
//! (`python/tests/serve_port_common.py` mirrors this file function for
//! function — the committed BENCH_*.json baselines are generated there, so
//! any edit here must be mirrored and the baselines regenerated).
//!
//! The harness owns everything the benches used to copy-paste: trace
//! replay and arrival injection, per-rank queue/page state, prefix-page
//! publication/adoption, routing through the REAL `coordinator::router`
//! policies, scheduling through the REAL `coordinator::Scheduler`, step
//! costs from the calibrated analytical model (`perfmodel::e2e`), and the
//! TTFT/ITL/throughput recorders (backed by [`crate::util::stats::Stats`]).
//! Two timing modes:
//!
//! * [`SimTiming::LockStep`] — every rank takes one scheduler action per
//!   round off the pre-round state; the round costs the slowest rank's
//!   step, and tokens produced in a round are stamped at the round barrier.
//! * [`SimTiming::EventDriven`] — every rank owns its clock and advances by
//!   its own (speed-scaled) step costs; the global clock follows the
//!   earliest candidate wake-up popped from [`super::clock::EventLoop`]: a
//!   busy rank's local time, the next arrival, or an in-flight transfer's
//!   ready-time. A rank's clock may LAG the global clock while it idles —
//!   its next action is charged from its own clock (the committed
//!   asynchronous semantics; see DESIGN.md "Simulation core").
//!
//! The event-driven mode optionally carries **elastic membership**
//! ([`crate::simulate::ElasticConfig`]): injected rank failures whose
//! in-progress sequences re-migrate to survivors over the FP8 wire path,
//! SLO-driven autoscaling (join on queue-depth / TTFT-p95 breach,
//! drain-then-retire on sustained idle), and drop-not-panic semantics for
//! sequences that can never place. Each membership transition is recorded
//! on the rank timeline as a [`MembershipEvent`].
//!
//! No wall clock anywhere: two runs produce byte-identical numbers.

use super::clock::EventLoop;
use super::scenario::{Scenario, SimRoute, SimTiming};
use crate::anyhow;
use crate::coordinator::router::{pick_handoff_rank, pick_rank, pick_rank_affinity, RankLoad};
use crate::coordinator::scheduler::{
    Action, RunningSeq, SchedPolicy, Scheduler, SpecConfig, TieredConfig, WaitingSeq,
};
use crate::kvcache::PAGE_TOKENS;
use crate::perfmodel::e2e::{
    decode_step_s, decompress_s, handoff_s, host_spill_s, mixed_step_s, prefetch_s,
    prefill_step_s, spec_step_s, spill_s,
};
use crate::perfmodel::{DeploymentConfig, GpuSpec, KernelKind, ModelSpec};
use crate::util::rng::Rng;
use crate::util::stats::Stats;
use crate::workload::Request;

/// Sliding window of recent TTFT samples feeding the autoscaler's SLO
/// breach signal.
const TTFT_WINDOW: usize = 32;

/// Seed of the deterministic acceptance-pattern stream the simulated
/// verify draws from (mirrored by serve_port_common.py SPEC_RNG_SEED).
const SPEC_RNG_SEED: u64 = 0x05BE_C0DE_5EED;

/// A fleet-membership transition, recorded on [`SimResult::rank_timeline`]
/// (and mirrored by `cluster::ClusterServer`'s elastic operations).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum MembershipEvent {
    /// a freshly provisioned rank came up: empty queues, cold cache
    RankJoin,
    /// a rank died: its queues evacuate or drop, its published prefixes die
    RankFail,
    /// a rank stopped taking new work and will retire once drained
    RankDrain,
}

impl MembershipEvent {
    /// The timeline label carried by the committed baselines.
    pub fn as_str(self) -> &'static str {
        match self {
            MembershipEvent::RankJoin => "join",
            MembershipEvent::RankFail => "fail",
            MembershipEvent::RankDrain => "drain",
        }
    }
}

/// Rank lifecycle under elastic membership (every rank is `Active` for the
/// whole run when the scenario carries no elastic config).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
enum RankState {
    Active,
    Draining,
    Dead,
}

/// Step-cost model for one scenario's ranks.
#[derive(Clone, Copy, Debug)]
pub enum CostModel {
    /// the calibrated H20-class analytical model (`perfmodel::e2e`)
    Analytic {
        gpu: GpuSpec,
        model: ModelSpec,
        dcfg: DeploymentConfig,
        kind: KernelKind,
    },
    /// every action costs the same constant — the degenerate mode in which
    /// the event-driven loop reproduces lock-step byte-for-byte (pinned by
    /// `integration_simulate`)
    Uniform { step_s: f64 },
}

impl CostModel {
    fn decode(&self, batch: usize, context: usize) -> f64 {
        match self {
            CostModel::Analytic { gpu, model, dcfg, kind } => {
                decode_step_s(gpu, model, dcfg, batch, context, *kind)
            }
            CostModel::Uniform { step_s } => *step_s,
        }
    }

    fn prefill(&self, tokens: usize) -> f64 {
        match self {
            CostModel::Analytic { gpu, model, dcfg, kind } => {
                prefill_step_s(gpu, model, dcfg, tokens, *kind)
            }
            CostModel::Uniform { step_s } => *step_s,
        }
    }

    fn mixed(&self, batch: usize, dctx: usize, chunk: usize, cctx: usize) -> f64 {
        match self {
            CostModel::Analytic { gpu, model, dcfg, kind } => {
                mixed_step_s(gpu, model, dcfg, batch, dctx, chunk, cctx, *kind)
            }
            CostModel::Uniform { step_s } => *step_s,
        }
    }

    fn spec(&self, batch: usize, context: usize, draft_len: usize) -> f64 {
        match self {
            CostModel::Analytic { gpu, model, dcfg, kind } => {
                spec_step_s(gpu, model, dcfg, batch, context, draft_len, *kind)
            }
            CostModel::Uniform { step_s } => *step_s,
        }
    }

    fn spill(&self, tokens: usize) -> f64 {
        match self {
            CostModel::Analytic { gpu, model, kind, .. } => spill_s(gpu, model, tokens, *kind),
            CostModel::Uniform { step_s } => *step_s,
        }
    }

    /// Device→host PCIe copy time of an async tier eviction (rides the
    /// down-link overlapped with decode, never charged to the rank).
    fn host_spill(&self, tokens: usize) -> f64 {
        match self {
            CostModel::Analytic { gpu, model, kind, .. } => {
                host_spill_s(gpu, model, tokens, *kind)
            }
            CostModel::Uniform { step_s } => *step_s,
        }
    }

    /// Host→device PCIe copy time of an async tier prefetch.
    fn prefetch(&self, tokens: usize) -> f64 {
        match self {
            CostModel::Analytic { gpu, model, kind, .. } => {
                prefetch_s(gpu, model, tokens, *kind)
            }
            CostModel::Uniform { step_s } => *step_s,
        }
    }

    /// Decompression-on-access surcharge for `tokens` of rank-`rank_r` cold
    /// cache attended this step (zero under the Uniform model: the tiered
    /// scenarios all run Analytic, and Uniform must keep its lock-step
    /// equivalence untouched).
    fn decompress(&self, rank_r: usize, tokens: usize) -> f64 {
        match self {
            CostModel::Analytic { gpu, model, .. } => decompress_s(gpu, model, rank_r, tokens),
            CostModel::Uniform { .. } => 0.0,
        }
    }

    fn handoff(&self, tokens: usize) -> f64 {
        match self {
            CostModel::Analytic { gpu, model, kind, .. } => {
                handoff_s(gpu, model, tokens, *kind)
            }
            CostModel::Uniform { step_s } => *step_s,
        }
    }

    /// (FP8 wire bytes, bf16-everything wire bytes) for `tokens` of KV.
    fn wire_bytes(&self, tokens: usize) -> (u64, u64) {
        match self {
            CostModel::Analytic { model, .. } => (
                model.kv_bytes_per_token(KernelKind::SnapMlaFp8) as u64 * tokens as u64,
                model.kv_bytes_per_token(KernelKind::FlashMlaBf16) as u64 * tokens as u64,
            ),
            CostModel::Uniform { .. } => (tokens as u64, tokens as u64),
        }
    }
}

/// Recorders + counters of one simulated arm — every field a serve bench
/// reports comes out of this one struct (`scenario.rs` selects the exact
/// field set each committed baseline carries).
#[derive(Debug)]
pub struct SimResult {
    pub ranks: usize,
    pub prefill_ranks: usize,
    pub decode_ranks: usize,
    pub requests: usize,
    /// requests that finished their full output (not dropped, not stranded)
    pub completed: usize,
    /// requests dropped by the elastic drop rule (0 without elastic config)
    pub dropped: usize,
    pub gen_tokens: u64,
    pub wall_s: f64,
    /// TTFT over requests that emitted at least one token (a dropped
    /// request never contributes a sample)
    pub ttft: Stats,
    /// TTFT over requests NOT drawn from the long-prompt mixture
    pub ttft_short: Stats,
    /// inter-token latencies (every gap after a sequence's first token)
    pub itl: Stats,
    pub peak_pages: usize,
    pub prefill_tokens: u64,
    pub chunk_tokens: u64,
    pub prefix_hit_tokens: u64,
    pub decode_steps: u64,
    pub decode_batch_sum: u64,
    /// lock-step rounds executed (lock-step timing only)
    pub rounds: u64,
    /// per-rank scheduler actions executed (event timing only)
    pub steps: u64,
    pub spills: u64,
    pub restores: u64,
    pub handoffs: u64,
    pub wire_fp8_bytes: u64,
    pub wire_bf16_bytes: u64,
    pub routed: Vec<u64>,
    /// failed-rank sequences whose KV re-migrated over the wire
    pub evacuated: u64,
    /// evacuated sequences that later placed on a survivor
    pub recovered: u64,
    pub fails: u64,
    pub joins: u64,
    pub drains: u64,
    /// high-water mark of the active-rank count
    pub peak_active_ranks: usize,
    /// active ranks when the run ended
    pub final_active_ranks: usize,
    /// time-weighted mean active-rank count (the fixed fleet size without
    /// elastic config)
    pub mean_active_ranks: f64,
    /// (time, event, rank, active ranks after) membership transitions
    pub rank_timeline: Vec<(f64, MembershipEvent, usize, usize)>,
    /// draft/verify steps executed (0 without a spec scenario)
    pub spec_steps: u64,
    /// Σ over spec steps of the batch size (denominator of the frontier
    /// accepted-tokens/step metric)
    pub spec_seq_steps: u64,
    /// draft tokens proposed across all spec steps
    pub spec_drafted_tokens: u64,
    /// tokens emitted by spec steps (accepted run + bonus, post-cap)
    pub spec_tokens: u64,
    /// high-water mark of Σ running across ranks — the tiered headline
    /// (max concurrent sequences at fixed HBM)
    pub peak_running: usize,
    /// async tier prefetches issued (0 without a tiered scenario)
    pub prefetches: u64,
}

impl SimResult {
    pub fn tok_per_s(&self) -> f64 {
        self.gen_tokens as f64 / self.wall_s
    }

    pub fn mean_decode_batch(&self) -> f64 {
        self.decode_batch_sum as f64 / self.decode_steps.max(1) as f64
    }

    /// The headline frontier metric: tokens emitted per sequence per
    /// draft/verify step (the bonus token makes the floor 1.0).
    pub fn accepted_per_spec_step(&self) -> f64 {
        self.spec_tokens as f64 / self.spec_seq_steps.max(1) as f64
    }
}

struct SimSeq {
    prompt: usize,
    out: usize,
    arrival: f64,
    long: bool,
    group: Option<u32>,
    prefix_tokens: usize,
    cached: usize,
    prefilled: usize,
    generated: usize,
    spilled: bool,
    /// prefix pages adopted from the rank's published set (never allocated)
    adopted: usize,
    /// own pages that became the rank's published copy (never freed)
    transferred: usize,
    first_token: Option<f64>,
    last_token: Option<f64>,
    /// dropped by the elastic drop rule — excluded from the latency stats
    dropped: bool,
    /// evacuated off a failed rank, currently riding the wire
    evac: bool,
}

struct SimRank {
    waiting: Vec<usize>,
    running: Vec<usize>,
    free: usize,
    /// published prefix pages per group (the rank's trie, page-granular)
    shared: Vec<usize>,
    /// rank-local clock (event timing; stays 0 under lock-step)
    t: f64,
    state: RankState,
}

#[derive(Default)]
struct SimStats {
    gen_tokens: u64,
    prefill_tokens: u64,
    chunk_tokens: u64,
    prefix_hit_tokens: u64,
    decode_steps: u64,
    decode_batch_sum: u64,
    rounds: u64,
    steps: u64,
    peak_pages: usize,
    spills: u64,
    restores: u64,
    handoffs: u64,
    wire_fp8_bytes: u64,
    wire_bf16_bytes: u64,
    routed: Vec<u64>,
    dropped: u64,
    recovered: u64,
    evacuated: u64,
    fails: u64,
    joins: u64,
    drains: u64,
    spec_steps: u64,
    spec_seq_steps: u64,
    spec_drafted: u64,
    spec_tokens: u64,
    prefetches: u64,
    peak_running: usize,
}

/// The simulation state machine. Construct via [`Scenario::run`].
pub(super) struct Harness<'a> {
    scen: &'a Scenario,
    sched: Scheduler,
    prefill_sched: Scheduler,
    speeds: Vec<f64>,
    page: usize,
    /// prefix-group count (sizes every rank's published-page table,
    /// including ranks joining mid-run)
    groups: usize,
    seqs: Vec<SimSeq>,
    ranks: Vec<SimRank>,
    /// (sid, ready_at) FIFO of serialized sequences in transit
    in_flight: Vec<(usize, f64)>,
    stats: SimStats,
    itl: Vec<f64>,
    /// lock-step: tokens produced this round, stamped at the barrier
    pending_emits: Vec<usize>,
    /// deterministic acceptance stream: one draw per drafted token, in
    /// apply() order — identical across the naive/indexed and timing arms
    spec_rng: Option<Rng>,
    // --- indexed bookkeeping (mirrored by serve_port_common.py): per-rank
    // token loads and the fleet page count are maintained incrementally at
    // every queue/page mutation instead of re-summed per event, and `ready`
    // is a lazy min-heap over busy ranks keyed by next-actionable time.
    // `scen.naive` keeps the pre-optimization read paths; the counters are
    // maintained in BOTH arms (only the reads differ), and `prop_simperf`
    // pins the arms byte-identical. ---
    naive: bool,
    /// per rank: Σ over waiting of (prompt + out)
    wait_po: Vec<usize>,
    /// per rank: Σ over waiting of (out - generated)
    wait_rem: Vec<usize>,
    /// per rank: Σ over running of (out - generated)
    run_rem: Vec<usize>,
    /// fleet-wide Σ of (capacity - free) across all ranks
    used_pages_total: usize,
    /// ranks with any queued or running work, plus an O(1) population count
    busy: Vec<bool>,
    busy_count: usize,
    /// lazy min-heap of (t, rank) over busy ranks — an entry is stale
    /// unless the rank is busy and its clock still matches the entry time
    ready: EventLoop<()>,
    // --- elastic membership state (inert without scen.elastic) ---
    /// failure injections sorted by (time, rank)
    fail_sched: Vec<(f64, usize)>,
    next_fail: usize,
    /// virtual times at which provisioning ranks come up
    pending_joins: Vec<f64>,
    /// the autoscaler's next evaluation instant
    next_eval: f64,
    /// start of the current sustained-low-load window
    low_since: Option<f64>,
    /// sliding TTFT window feeding the autoscale SLO signal
    recent_ttft: Vec<f64>,
    rank_timeline: Vec<(f64, MembershipEvent, usize, usize)>,
    /// time integral of the active-rank count (last stamp + accumulator)
    a_last: f64,
    a_int: f64,
    peak_active: usize,
    // --- tiered KV cache state (inert without scen.tiered; mirrors the
    // kvcache::tiered TierEngine): in-flight spills hold their pages until
    // the device→host PCIe copy lands, in-flight prefetches hold their
    // pages from issue, and each direction of the full-duplex host link
    // serializes independently. ---
    /// the scheduler-side residency/action gate (disabled without tiered)
    tiered: TieredConfig,
    /// tiered AND async: spill/preempt become non-blocking flights
    tiered_async: bool,
    /// per rank: (sid, ready_at, private pages) of in-flight spills
    spill_fl: Vec<Vec<(usize, f64, usize)>>,
    /// per rank: (sid, ready_at) of in-flight prefetches
    prefetch_fl: Vec<Vec<(usize, f64)>>,
    /// per rank: device→host link busy-until
    dn_free: Vec<f64>,
    /// per rank: host→device link busy-until
    up_free: Vec<f64>,
}

fn pages_for(tokens: usize, page: usize) -> usize {
    tokens.div_ceil(page)
}

impl<'a> Harness<'a> {
    pub(super) fn new(scen: &'a Scenario, trace: &[Request]) -> Harness<'a> {
        let n = scen.ranks;
        assert!(scen.prefill_ranks < n, "need at least one non-prefill rank");
        assert_eq!(scen.sched.page_tokens, PAGE_TOKENS, "page size mismatch");
        let speeds = if scen.speeds.is_empty() {
            vec![1.0; n]
        } else {
            assert_eq!(scen.speeds.len(), n, "one speed factor per rank");
            scen.speeds.clone()
        };
        if scen.timing == SimTiming::LockStep {
            assert_eq!(scen.prefill_ranks, 0, "lock-step cannot express handoffs");
            assert!(
                speeds.iter().all(|&s| s == 1.0),
                "lock-step cannot express per-rank speed factors — that is \
                 exactly why the straggler scenario is event-driven"
            );
        }
        if scen.elastic.is_some() {
            assert!(
                scen.timing == SimTiming::EventDriven && scen.prefill_ranks == 0,
                "elastic membership requires the colocated event-driven mode"
            );
        }
        let groups = trace
            .iter()
            .filter_map(|r| r.prefix_group)
            .max()
            .map(|g| g as usize + 1)
            .unwrap_or(0);
        let seqs = trace
            .iter()
            .map(|r| SimSeq {
                prompt: r.prompt_tokens,
                out: r.max_new_tokens,
                arrival: r.arrival_s,
                long: r.long_prompt,
                group: r.prefix_group,
                prefix_tokens: r.prefix_tokens,
                cached: 0,
                prefilled: 0,
                generated: 0,
                spilled: false,
                adopted: 0,
                transferred: 0,
                first_token: None,
                last_token: None,
                dropped: false,
                evac: false,
            })
            .collect();
        let ranks = (0..n)
            .map(|_| SimRank {
                waiting: Vec::new(),
                running: Vec::new(),
                free: scen.capacity_pages,
                shared: vec![0; groups],
                t: 0.0,
                state: RankState::Active,
            })
            .collect();
        let fail_sched = scen
            .elastic
            .as_ref()
            .map(|e| {
                let mut f = e.failures.clone();
                f.sort_by(|a, b| a.0.total_cmp(&b.0).then(a.1.cmp(&b.1)));
                f
            })
            .unwrap_or_default();
        let next_eval = scen
            .elastic
            .as_ref()
            .and_then(|e| e.autoscale.as_ref())
            .map(|a| a.eval_interval_s)
            .unwrap_or(0.0);
        // a spec scenario enables the scheduler's draft/verify gate; every
        // other scenario runs the config untouched (byte-identity when off)
        let mut sched_cfg = scen.sched;
        if let Some(sp) = &scen.spec {
            sched_cfg.spec = SpecConfig::mtp(sp.draft_len);
        }
        // a tiered scenario arms the scheduler's TieredConfig gate:
        // residency-aware page math plus the async spill/prefetch actions
        let tiered = scen
            .tiered
            .map(|ts| TieredConfig {
                enabled: true,
                async_io: ts.async_io,
                cold_after: ts.cold_after,
                comp_ratio: ts.comp_ratio,
                comp_rank: ts.comp_rank,
            })
            .unwrap_or_else(TieredConfig::disabled);
        if tiered.enabled {
            assert!(
                scen.timing == SimTiming::EventDriven
                    && scen.prefill_ranks == 0
                    && scen.elastic.is_none()
                    && scen.spec.is_none()
                    && scen.sched.policy == SchedPolicy::MixedChunked,
                "tiered cache requires the colocated event-driven mixed mode"
            );
            assert_eq!(
                tiered.cold_after % scen.sched.page_tokens,
                0,
                "cold_after must be a page multiple (every page wholly hot or \
                 wholly cold; residency deltas stay in {{-1, 0, 1}})"
            );
            assert!(
                trace.iter().all(|r| r.prefix_group.is_none()),
                "the compression tier does not compose with shared prefixes yet"
            );
            sched_cfg.tiered = tiered;
        }
        Harness {
            scen,
            sched: Scheduler::new(sched_cfg),
            prefill_sched: Scheduler::new(scen.prefill_sched.unwrap_or(scen.sched)),
            speeds,
            page: scen.sched.page_tokens,
            groups,
            seqs,
            ranks,
            in_flight: Vec::new(),
            stats: SimStats { routed: vec![0; n], ..SimStats::default() },
            itl: Vec::new(),
            pending_emits: Vec::new(),
            spec_rng: scen.spec.as_ref().map(|_| Rng::new(SPEC_RNG_SEED)),
            naive: scen.naive,
            wait_po: vec![0; n],
            wait_rem: vec![0; n],
            run_rem: vec![0; n],
            used_pages_total: 0,
            busy: vec![false; n],
            busy_count: 0,
            ready: EventLoop::new(),
            fail_sched,
            next_fail: 0,
            pending_joins: Vec::new(),
            next_eval,
            low_since: None,
            recent_ttft: Vec::new(),
            rank_timeline: Vec::new(),
            a_last: 0.0,
            a_int: 0.0,
            peak_active: n,
            tiered,
            tiered_async: tiered.enabled && tiered.async_io,
            spill_fl: vec![Vec::new(); n],
            prefetch_fl: vec![Vec::new(); n],
            dn_free: vec![0.0; n],
            up_free: vec![0.0; n],
        }
    }

    /// One generated token for `sid`; event timing stamps it at `t`,
    /// lock-step passes None and the run loop stamps at the round barrier.
    fn emit(&mut self, sid: usize, t: Option<f64>) {
        self.stats.gen_tokens += 1;
        let Some(t) = t else {
            self.pending_emits.push(sid);
            return;
        };
        let s = &mut self.seqs[sid];
        if let Some(last) = s.last_token {
            self.itl.push(t - last);
        }
        s.last_token = Some(t);
    }

    /// Event-mode first-token stamp; feeds the autoscale SLO window.
    fn stamp_first(&mut self, sid: usize, t_emit: Option<f64>) {
        let Some(t) = t_emit else { return };
        let s = &mut self.seqs[sid];
        s.first_token = Some(t);
        if self.scen.elastic.is_some() {
            self.recent_ttft.push(t - s.arrival);
            if self.recent_ttft.len() > TTFT_WINDOW {
                self.recent_ttft.remove(0);
            }
        }
    }

    fn active_count(&self) -> usize {
        self.ranks.iter().filter(|r| r.state == RankState::Active).count()
    }

    /// Resident pages for `tokens` of cache: pages fully older than the hot
    /// window live in the compressed cold tier at the codec's page ratio.
    /// Equals `pages_for` exactly when compression is off, so every
    /// accounting site below stays byte-identical for plain runs.
    fn respages(&self, tokens: usize) -> usize {
        self.tiered.resident_pages(tokens, self.page)
    }

    /// Pages a one-token append claims: 0 or 1 in plain mode (the
    /// equivalent of the old `cached % page == 0` boundary check), and
    /// possibly -1 under compression — a page crossing into the cold window
    /// FREES capacity, so callers treat this as signed.
    fn grow_pages(&self, tokens: usize) -> isize {
        self.respages(tokens + 1) as isize - self.respages(tokens) as isize
    }

    fn private_pages(&self, sid: usize) -> usize {
        let s = &self.seqs[sid];
        self.respages(s.cached) - s.adopted - s.transferred
    }

    /// Tokens resident in the compressed cold tier across a decode batch
    /// (whole pages fully older than the hot window) — the decompression-
    /// on-access surcharge prices exactly these.
    fn cold_tokens(&self, ids: &[usize]) -> usize {
        ids.iter()
            .map(|&sid| {
                self.seqs[sid].cached.saturating_sub(self.tiered.cold_after) / self.page
                    * self.page
            })
            .sum()
    }

    /// Published pages of `sid`'s group usable by a fresh admission (the
    /// adopt limit: ≥1 prompt token always left to prefill).
    fn hit_pages(&self, rank: usize, sid: usize) -> usize {
        let s = &self.seqs[sid];
        match s.group {
            Some(g) => self.ranks[rank].shared[g as usize].min((s.prompt - 1) / self.page),
            None => 0,
        }
    }

    /// Routing view of the colocated fleet. Dead and draining ranks leave
    /// the routing set — affinity probes skip them, so a retiring rank's
    /// published prefixes attract nothing. Returns (rank indices, loads).
    fn colocated_loads(&self, sid: usize) -> (Vec<usize>, Vec<RankLoad>) {
        let s = &self.seqs[sid];
        let needed = pages_for(s.prompt + s.out, self.page);
        let mut idxs = Vec::new();
        let mut loads = Vec::new();
        for (ri, r) in self.ranks.iter().enumerate() {
            if r.state != RankState::Active {
                continue;
            }
            let tokens = if self.naive {
                let queued: usize = r
                    .waiting
                    .iter()
                    .map(|&w| self.seqs[w].prompt + self.seqs[w].out)
                    .sum();
                let remaining: usize = r
                    .running
                    .iter()
                    .map(|&x| self.seqs[x].out - self.seqs[x].generated)
                    .sum();
                queued + remaining
            } else {
                self.wait_po[ri] + self.run_rem[ri]
            };
            idxs.push(ri);
            loads.push(RankLoad {
                tokens,
                free_pages: r.free,
                pages_needed: needed,
                prefix_hit_tokens: self.hit_pages(ri, sid) * self.page,
                evictable_pages: 0,
            });
        }
        (idxs, loads)
    }

    fn route(&mut self, sid: usize) -> anyhow::Result<()> {
        let rank = match self.scen.routing {
            SimRoute::Single => 0,
            SimRoute::Disagg => {
                // least-loaded prefill rank; a prefill rank holds just the
                // prompt's pages (the KV migrates at handoff)
                let needed = pages_for(self.seqs[sid].prompt, self.page);
                let loads: Vec<RankLoad> = (0..self.scen.prefill_ranks)
                    .map(|ri| {
                        let r = &self.ranks[ri];
                        let tokens = if self.naive {
                            let queued: usize = r
                                .waiting
                                .iter()
                                .map(|&w| self.seqs[w].prompt + self.seqs[w].out)
                                .sum();
                            let remaining: usize = r
                                .running
                                .iter()
                                .map(|&x| self.seqs[x].out - self.seqs[x].generated)
                                .sum();
                            queued + remaining
                        } else {
                            self.wait_po[ri] + self.run_rem[ri]
                        };
                        RankLoad {
                            tokens,
                            free_pages: r.free,
                            pages_needed: needed,
                            prefix_hit_tokens: 0,
                            evictable_pages: 0,
                        }
                    })
                    .collect();
                pick_rank(&loads)
            }
            SimRoute::PrefixAffinity => {
                let (idxs, loads) = self.colocated_loads(sid);
                if idxs.is_empty() {
                    anyhow::bail!(
                        "no active ranks to route request {sid} ({} total, {} joining)",
                        self.ranks.len(),
                        self.pending_joins.len()
                    );
                }
                idxs[pick_rank_affinity(&loads, self.page)]
            }
            SimRoute::ShortestQueue if self.naive => {
                let (idxs, loads) = self.colocated_loads(sid);
                if idxs.is_empty() {
                    anyhow::bail!(
                        "no active ranks to route request {sid} ({} total, {} joining)",
                        self.ranks.len(),
                        self.pending_joins.len()
                    );
                }
                idxs[pick_rank(&loads)]
            }
            SimRoute::ShortestQueue => {
                // inline pick_rank over the incremental load counters:
                // capacity-aware shortest queue needs only (tokens, free)
                // per rank, so the per-arrival load-list construction is
                // pure overhead here. Ascending scan + strict < keeps
                // pick_rank's (tokens, idx) tie-break exactly.
                let s = &self.seqs[sid];
                let needed = pages_for(s.prompt + s.out, self.page);
                let mut best_fit: Option<usize> = None;
                let mut best_any: Option<usize> = None;
                let mut rank = usize::MAX;
                for (ri, r) in self.ranks.iter().enumerate() {
                    if r.state != RankState::Active {
                        continue;
                    }
                    let tokens = self.wait_po[ri] + self.run_rem[ri];
                    if r.free >= needed {
                        if best_fit.map_or(true, |b| tokens < b) {
                            best_fit = Some(tokens);
                            rank = ri;
                        }
                    } else if best_fit.is_none() && best_any.map_or(true, |b| tokens < b) {
                        best_any = Some(tokens);
                        rank = ri;
                    }
                }
                if rank == usize::MAX {
                    anyhow::bail!(
                        "no active ranks to route request {sid} ({} total, {} joining)",
                        self.ranks.len(),
                        self.pending_joins.len()
                    );
                }
                rank
            }
        };
        self.stats.routed[rank] += 1;
        self.ranks[rank].waiting.push(sid);
        self.wait_po[rank] += self.seqs[sid].prompt + self.seqs[sid].out;
        self.wait_rem[rank] += self.seqs[sid].out - self.seqs[sid].generated;
        self.touch(rank);
        Ok(())
    }

    /// Every ready transfer lands on the decode rank with headroom;
    /// slot-saturated ranks are marked infeasible by inflating their need.
    /// Only ACTIVE ranks take migrants — a draining or dead rank never
    /// adopts work. Under elastic membership a transfer that can NEVER
    /// place (needs more pages than one rank holds, or the fleet is gone)
    /// is dropped and recorded, not parked forever and not panicked.
    fn deliver(&mut self, clock: f64) -> bool {
        let mut delivered = false;
        let mut keep = Vec::new();
        let pending = std::mem::take(&mut self.in_flight);
        let prefill_ranks = self.scen.prefill_ranks;
        let elastic = self.scen.elastic.is_some();
        let targets: Vec<usize> = (prefill_ranks..self.ranks.len())
            .filter(|&ri| self.ranks[ri].state == RankState::Active)
            .collect();
        for (sid, ready) in pending {
            if ready > clock {
                keep.push((sid, ready));
                continue;
            }
            let s = &self.seqs[sid];
            let remaining = s.out - s.generated;
            let needed = pages_for(s.cached + remaining, self.page);
            if elastic
                && (needed > self.scen.capacity_pages
                    || (targets.is_empty() && self.pending_joins.is_empty()))
            {
                self.seqs[sid].dropped = true;
                self.stats.dropped += 1;
                delivered = true;
                continue;
            }
            let loads: Vec<RankLoad> = targets
                .iter()
                .map(|&ri| {
                    let r = &self.ranks[ri];
                    let tokens: usize = if self.naive {
                        r.running
                            .iter()
                            .chain(r.waiting.iter())
                            .map(|&x| self.seqs[x].out - self.seqs[x].generated)
                            .sum()
                    } else {
                        self.run_rem[ri] + self.wait_rem[ri]
                    };
                    let open_slot = r.running.len() < self.scen.sched.max_running;
                    RankLoad {
                        tokens,
                        free_pages: r.free,
                        pages_needed: if open_slot {
                            needed
                        } else {
                            self.scen.capacity_pages + 1
                        },
                        prefix_hit_tokens: 0,
                        evictable_pages: 0,
                    }
                })
                .collect();
            match pick_handoff_rank(&loads) {
                Some(j) => {
                    let tj = targets[j];
                    let cached = self.seqs[sid].cached;
                    let pg = pages_for(cached, self.page);
                    let r = &mut self.ranks[tj];
                    r.free -= pg;
                    r.running.push(sid);
                    self.used_pages_total += pg;
                    self.run_rem[tj] += self.seqs[sid].out - self.seqs[sid].generated;
                    self.touch(tj);
                    self.stats.handoffs += 1;
                    let s = &mut self.seqs[sid];
                    if s.evac {
                        s.evac = false;
                        self.stats.recovered += 1;
                    }
                    delivered = true;
                }
                None => keep.push((sid, ready)),
            }
        }
        self.in_flight = keep;
        delivered
    }

    fn note_membership(&mut self, kind: MembershipEvent, ri: usize, clock: f64) {
        let na = self.active_count();
        self.peak_active = self.peak_active.max(na);
        self.rank_timeline.push((clock, kind, ri, na));
    }

    /// A failed rank's in-progress sequence: with recovery on, its KV
    /// re-migrates to a survivor over the FP8 wire path (priced exactly
    /// like a prefill→decode handoff); a still-fresh request (no KV yet)
    /// simply re-routes; otherwise the request is dropped and recorded.
    fn evacuate(&mut self, sid: usize, clock: f64) -> anyhow::Result<()> {
        let recover = self.scen.elastic.as_ref().is_some_and(|e| e.recover);
        let s = &mut self.seqs[sid];
        s.spilled = false;
        s.adopted = 0;
        s.transferred = 0;
        if recover && s.cached > 0 {
            s.evac = true;
            let cached = s.cached;
            self.stats.evacuated += 1;
            let (fp8, bf16) = self.scen.cost.wire_bytes(cached);
            self.stats.wire_fp8_bytes += fp8;
            self.stats.wire_bf16_bytes += bf16;
            let transfer = self.scen.cost.handoff(cached);
            self.in_flight.push((sid, clock + transfer));
        } else if s.cached == 0 {
            // no KV built yet — this is still just a request; re-route it
            self.route(sid)?;
        } else {
            s.dropped = true;
            self.stats.dropped += 1;
        }
        Ok(())
    }

    /// [`MembershipEvent::RankFail`] — the rank leaves the routing set
    /// immediately; queued-but-fresh requests re-route, sequences with KV
    /// either re-migrate (recover) or drop; the rank's published prefixes
    /// die with it.
    fn fail_rank(&mut self, ri: usize, clock: f64) -> anyhow::Result<()> {
        self.ranks[ri].state = RankState::Dead;
        self.stats.fails += 1;
        if self.active_count() == 0 {
            anyhow::bail!(
                "rank {ri} failed but no active ranks remain ({} waiting + {} running \
                 stranded, {} joining)",
                self.ranks[ri].waiting.len(),
                self.ranks[ri].running.len(),
                self.pending_joins.len()
            );
        }
        let waiting = std::mem::take(&mut self.ranks[ri].waiting);
        let running = std::mem::take(&mut self.ranks[ri].running);
        self.ranks[ri].shared.iter_mut().for_each(|g| *g = 0);
        self.used_pages_total -= self.scen.capacity_pages - self.ranks[ri].free;
        self.ranks[ri].free = self.scen.capacity_pages;
        self.wait_po[ri] = 0;
        self.wait_rem[ri] = 0;
        self.run_rem[ri] = 0;
        if self.busy[ri] {
            self.busy[ri] = false;
            self.busy_count -= 1;
        }
        for sid in waiting.into_iter().chain(running) {
            self.evacuate(sid, clock)?;
        }
        self.note_membership(MembershipEvent::RankFail, ri, clock);
        Ok(())
    }

    /// [`MembershipEvent::RankJoin`] — a freshly provisioned rank: empty
    /// queues, a cold cache (no published prefixes), clock at now.
    fn join_rank(&mut self, clock: f64) {
        self.ranks.push(SimRank {
            waiting: Vec::new(),
            running: Vec::new(),
            free: self.scen.capacity_pages,
            shared: vec![0; self.groups],
            t: clock,
            state: RankState::Active,
        });
        self.speeds.push(1.0);
        self.wait_po.push(0);
        self.wait_rem.push(0);
        self.run_rem.push(0);
        self.busy.push(false);
        self.stats.routed.push(0);
        self.stats.joins += 1;
        self.note_membership(MembershipEvent::RankJoin, self.ranks.len() - 1, clock);
    }

    /// Scale up on queue-depth or TTFT-p95 SLO breach; drain-then-remove
    /// the highest-numbered active rank after sustained low load.
    fn autoscale_eval(&mut self, clock: f64) {
        let Some(auto) = self.scen.elastic.as_ref().and_then(|e| e.autoscale) else {
            return;
        };
        let na = self.active_count();
        let q_up = self
            .ranks
            .iter()
            .filter(|r| r.state == RankState::Active)
            .map(|r| r.waiting.len())
            .sum::<usize>() as f64
            / na as f64;
        let busy = self
            .ranks
            .iter()
            .filter(|r| r.state == RankState::Active)
            .map(|r| r.waiting.len() + r.running.len())
            .sum::<usize>() as f64
            / na as f64;
        let breach = q_up > auto.queue_high
            || (auto.ttft_slo_s > 0.0
                && self.recent_ttft.len() >= 8
                && Stats::from(&self.recent_ttft).percentile(95.0) > auto.ttft_slo_s);
        if breach {
            self.low_since = None;
            if na + self.pending_joins.len() < auto.max_ranks {
                self.pending_joins.push(clock + auto.join_delay_s);
            }
        } else if busy <= auto.queue_low && self.pending_joins.is_empty() {
            match self.low_since {
                None => self.low_since = Some(clock),
                Some(since) if clock - since >= auto.idle_for_s && na > auto.min_ranks => {
                    let victim = (0..self.ranks.len())
                        .filter(|&ri| self.ranks[ri].state == RankState::Active)
                        .max()
                        .expect("na > min_ranks >= 1 active ranks");
                    // MembershipEvent::RankDrain — stops taking new work
                    // now, finishes its queue, then retires
                    self.ranks[victim].state = RankState::Draining;
                    self.stats.drains += 1;
                    self.low_since = Some(clock);
                    self.note_membership(MembershipEvent::RankDrain, victim, clock);
                }
                Some(_) => {}
            }
        } else {
            self.low_since = None;
        }
    }

    fn publish(&mut self, rank: usize, sid: usize) {
        let Some(g) = self.seqs[sid].group else { return };
        let done = self.seqs[sid].prefilled.min(self.seqs[sid].prefix_tokens) / self.page;
        let have = self.ranks[rank].shared[g as usize];
        if done > have {
            self.seqs[sid].transferred += done - have;
            self.ranks[rank].shared[g as usize] = done;
        }
    }

    fn decide(&self, ri: usize) -> Action {
        let r = &self.ranks[ri];
        let sched = if ri < self.scen.prefill_ranks { &self.prefill_sched } else { &self.sched };
        let wsrc: &[usize] = if self.naive {
            &r.waiting
        } else {
            // both policies inspect at most a max_prefill_batch-sized FCFS
            // prefix of the queue plus one break-check entry (admission is
            // prefix-only and every non-breaking iteration fills one of at
            // most max_prefill_batch candidate slots), so a capped view is
            // decision-identical while the queue itself can hold thousands
            &r.waiting[..r.waiting.len().min(sched.waiting_view_bound())]
        };
        let wview: Vec<WaitingSeq> = wsrc
            .iter()
            .enumerate()
            .map(|(i, &sid)| WaitingSeq {
                idx: i,
                tokens: if self.seqs[sid].spilled {
                    self.seqs[sid].cached
                } else {
                    self.seqs[sid].prompt
                },
                spilled: self.seqs[sid].spilled,
            })
            .collect();
        let rview: Vec<RunningSeq> = r
            .running
            .iter()
            .enumerate()
            .map(|(i, &sid)| RunningSeq {
                idx: i,
                context: self.seqs[sid].cached,
                pending_prefill: self.seqs[sid].prompt - self.seqs[sid].prefilled,
            })
            .collect();
        let act = sched.decide(&wview, &rview, r.free);
        if self.tiered_async {
            // the tier engine serializes host evictions: one spill in
            // flight per rank, and a sequence cannot prefetch back until
            // its own spill has landed. Blocked ops wait on the flight's
            // ready-time (an event-loop candidate), not on a poll.
            match act {
                Action::SpillAsync(_) if !self.spill_fl[ri].is_empty() => return Action::Idle,
                Action::Prefetch(_) => {
                    let head = r.waiting[0];
                    if self.spill_fl[ri].iter().any(|f| f.0 == head) {
                        return Action::Idle;
                    }
                }
                _ => {}
            }
        }
        act
    }

    /// Apply one scheduler action on rank `ri`; returns its (speed-scaled)
    /// cost. Event timing passes `t_start = Some(rank clock)` and stamps
    /// tokens at `t_start + cost`; lock-step passes None and the run loop
    /// stamps at the round barrier. Errors instead of panicking on a
    /// malformed action (e.g. an empty decode batch).
    fn apply(&mut self, ri: usize, action: Action, t_start: Option<f64>) -> anyhow::Result<f64> {
        let cost;
        match action {
            Action::Idle => cost = 0.0,
            Action::Prefill(idxs) => {
                let ids: Vec<usize> = idxs.iter().map(|&i| self.ranks[ri].waiting[i]).collect();
                self.ranks[ri].waiting.drain(..ids.len());
                for &sid in &ids {
                    self.wait_po[ri] -= self.seqs[sid].prompt + self.seqs[sid].out;
                    self.wait_rem[ri] -= self.seqs[sid].out - self.seqs[sid].generated;
                }
                let total: usize = ids.iter().map(|&sid| self.seqs[sid].prompt).sum();
                cost = self.scen.cost.prefill(total) * self.speeds[ri];
                self.stats.prefill_tokens += total as u64;
                let t_emit = t_start.map(|t| t + cost);
                for sid in ids {
                    let prompt = self.seqs[sid].prompt;
                    let pg = self.respages(prompt);
                    self.ranks[ri].free -= pg;
                    self.used_pages_total += pg;
                    let s = &mut self.seqs[sid];
                    s.cached = prompt;
                    s.prefilled = prompt;
                    self.publish(ri, sid);
                    self.seqs[sid].generated = 1;
                    self.stamp_first(sid, t_emit);
                    self.emit(sid, t_emit);
                    if self.seqs[sid].generated >= self.seqs[sid].out {
                        let freed = self.private_pages(sid);
                        self.ranks[ri].free += freed;
                        self.used_pages_total -= freed;
                    } else {
                        self.ranks[ri].running.push(sid);
                        self.run_rem[ri] += self.seqs[sid].out - self.seqs[sid].generated;
                    }
                }
            }
            Action::Handoff(idx) => {
                // serialize + free this rank's pages; the wire block rides
                // the link (unscaled: the link's time, not the rank's)
                // overlapped with the rank's next step
                let t_start = t_start.expect("handoffs only exist under event timing");
                let sid = self.ranks[ri].running.remove(idx);
                self.run_rem[ri] -= self.seqs[sid].out - self.seqs[sid].generated;
                let freed = self.private_pages(sid);
                self.ranks[ri].free += freed;
                self.used_pages_total -= freed;
                let s = &mut self.seqs[sid];
                s.adopted = 0;
                s.transferred = 0;
                let cached = s.cached;
                let (fp8, bf16) = self.scen.cost.wire_bytes(cached);
                self.stats.wire_fp8_bytes += fp8;
                self.stats.wire_bf16_bytes += bf16;
                let transfer = self.scen.cost.handoff(cached);
                self.in_flight.push((sid, t_start + transfer));
                cost = 0.0;
            }
            Action::Decode(idxs) => {
                if idxs.is_empty() {
                    anyhow::bail!(
                        "scheduler produced an empty decode batch on rank {ri} \
                         ({} waiting, {} running)",
                        self.ranks[ri].waiting.len(),
                        self.ranks[ri].running.len()
                    );
                }
                let ids: Vec<usize> = idxs.iter().map(|&i| self.ranks[ri].running[i]).collect();
                let ctx = ids.iter().map(|&sid| self.seqs[sid].cached).max().unwrap() + 1;
                let mut c = self.scen.cost.decode(ids.len(), ctx) * self.speeds[ri];
                if self.tiered.enabled && self.tiered.cold_after > 0 {
                    // decompression-on-access: cold pages hold rank-r
                    // latents that the attention step first up-projects
                    // back to d_c
                    let cold = self.cold_tokens(&ids);
                    c += self.scen.cost.decompress(self.tiered.comp_rank, cold)
                        * self.speeds[ri];
                }
                cost = c;
                self.stats.decode_steps += 1;
                self.stats.decode_batch_sum += ids.len() as u64;
                let t_emit = t_start.map(|t| t + cost);
                let mut done = Vec::new();
                for &sid in &ids {
                    let grow = self.grow_pages(self.seqs[sid].cached);
                    self.ranks[ri].free = (self.ranks[ri].free as isize - grow) as usize;
                    self.used_pages_total = (self.used_pages_total as isize + grow) as usize;
                    let s = &mut self.seqs[sid];
                    s.cached += 1;
                    s.generated += 1;
                    self.run_rem[ri] -= 1;
                    self.emit(sid, t_emit);
                    if self.seqs[sid].generated >= self.seqs[sid].out {
                        done.push(sid);
                    }
                }
                for sid in done {
                    self.run_rem[ri] -= self.seqs[sid].out - self.seqs[sid].generated;
                    let freed = self.private_pages(sid);
                    self.ranks[ri].free += freed;
                    self.used_pages_total -= freed;
                    self.ranks[ri].running.retain(|&x| x != sid);
                }
            }
            Action::SpecDecode { idxs, draft_len } => {
                // one draft-then-verify step. Each sequence drafts
                // `draft_len` tokens; the verify pass accepts the leading
                // run of matching drafts plus one corrected/bonus target
                // token, and the rejected suffix's KV is rolled back
                // (checkpoint/rollback_to), so pages grow for EMITTED
                // tokens only — exactly the state a run that never wrote
                // the rejects would hold.
                if idxs.is_empty() {
                    anyhow::bail!(
                        "scheduler produced an empty spec batch on rank {ri} \
                         ({} waiting, {} running)",
                        self.ranks[ri].waiting.len(),
                        self.ranks[ri].running.len()
                    );
                }
                let ids: Vec<usize> = idxs.iter().map(|&i| self.ranks[ri].running[i]).collect();
                let ctx = ids.iter().map(|&sid| self.seqs[sid].cached).max().unwrap() + 1;
                cost = self.scen.cost.spec(ids.len(), ctx, draft_len) * self.speeds[ri];
                self.stats.spec_steps += 1;
                self.stats.spec_seq_steps += ids.len() as u64;
                let accept_rate =
                    self.scen.spec.as_ref().expect("SpecDecode without spec config").accept_rate;
                let max_context = self.scen.sched.max_context;
                let t_emit = t_start.map(|t| t + cost);
                let mut done = Vec::new();
                for &sid in &ids {
                    // fixed draft_len draws per sequence keeps the
                    // acceptance stream aligned across arms regardless of
                    // where the run breaks
                    let rng = self.spec_rng.as_mut().expect("SpecDecode without spec rng");
                    let draws: Vec<bool> =
                        (0..draft_len).map(|_| rng.bool(accept_rate)).collect();
                    let accepted = draws.iter().take_while(|&&ok| ok).count();
                    self.stats.spec_drafted += draft_len as u64;
                    let s = &self.seqs[sid];
                    let take = (accepted + 1)
                        .min(s.out - s.generated)
                        .min(max_context - s.cached);
                    for _ in 0..take {
                        let grow = self.grow_pages(self.seqs[sid].cached);
                        self.ranks[ri].free = (self.ranks[ri].free as isize - grow) as usize;
                        self.used_pages_total = (self.used_pages_total as isize + grow) as usize;
                        let s = &mut self.seqs[sid];
                        s.cached += 1;
                        s.generated += 1;
                        self.run_rem[ri] -= 1;
                        self.emit(sid, t_emit);
                    }
                    self.stats.spec_tokens += take as u64;
                    if self.seqs[sid].generated >= self.seqs[sid].out {
                        done.push(sid);
                    }
                }
                for sid in done {
                    self.run_rem[ri] -= self.seqs[sid].out - self.seqs[sid].generated;
                    let freed = self.private_pages(sid);
                    self.ranks[ri].free += freed;
                    self.used_pages_total -= freed;
                    self.ranks[ri].running.retain(|&x| x != sid);
                }
            }
            Action::Mixed { prefill_chunks, decode_idxs } => {
                // admissions are a FCFS prefix of `waiting`; chunk-list
                // order is service order (SRPT), idx is the waiting position
                let n_admit = prefill_chunks.iter().filter(|c| c.from_waiting).count();
                let admitted: Vec<usize> = self.ranks[ri].waiting.drain(..n_admit).collect();
                // admitted sequences move waiting -> running in this action
                for &sid in &admitted {
                    self.wait_po[ri] -= self.seqs[sid].prompt + self.seqs[sid].out;
                    self.wait_rem[ri] -= self.seqs[sid].out - self.seqs[sid].generated;
                    self.run_rem[ri] += self.seqs[sid].out - self.seqs[sid].generated;
                }
                // admission adopts the rank's published prefix pages
                // (shared, no allocation) — mirrors PagedKvCache::adopt_prefix
                for &sid in &admitted {
                    let hit = self.hit_pages(ri, sid);
                    if hit > 0 {
                        let s = &mut self.seqs[sid];
                        s.adopted = hit;
                        s.cached = hit * self.page;
                        s.prefilled = hit * self.page;
                        self.stats.prefix_hit_tokens += (hit * self.page) as u64;
                    }
                }
                let chunk_plan: Vec<(usize, usize)> = prefill_chunks
                    .iter()
                    .map(|c| {
                        let sid = if c.from_waiting {
                            admitted[c.idx]
                        } else {
                            self.ranks[ri].running[c.idx]
                        };
                        let s = &self.seqs[sid];
                        (sid, c.tokens.min(s.prompt - s.prefilled))
                    })
                    .collect();
                self.ranks[ri].running.extend(&admitted);
                let decode_ids: Vec<usize> =
                    decode_idxs.iter().map(|&i| self.ranks[ri].running[i]).collect();
                let total_chunk: usize = chunk_plan.iter().map(|&(_, t)| t).sum();
                let dctx = decode_ids
                    .iter()
                    .map(|&sid| self.seqs[sid].cached)
                    .max()
                    .map(|c| c + 1)
                    .unwrap_or(0);
                let cctx = chunk_plan
                    .iter()
                    .map(|&(sid, t)| self.seqs[sid].cached + t)
                    .max()
                    .unwrap_or(0);
                let mut c = self.scen.cost.mixed(decode_ids.len(), dctx, total_chunk, cctx)
                    * self.speeds[ri];
                if self.tiered.enabled && self.tiered.cold_after > 0 && !decode_ids.is_empty()
                {
                    let cold = self.cold_tokens(&decode_ids);
                    c += self.scen.cost.decompress(self.tiered.comp_rank, cold)
                        * self.speeds[ri];
                }
                cost = c;
                if !decode_ids.is_empty() {
                    self.stats.decode_steps += 1;
                    self.stats.decode_batch_sum += decode_ids.len() as u64;
                }
                let t_emit = t_start.map(|t| t + cost);
                let mut done = Vec::new();
                for &(sid, take) in &chunk_plan {
                    let cached = self.seqs[sid].cached;
                    let need =
                        self.respages(cached + take) as isize - self.respages(cached) as isize;
                    self.ranks[ri].free = (self.ranks[ri].free as isize - need) as usize;
                    self.used_pages_total = (self.used_pages_total as isize + need) as usize;
                    let s = &mut self.seqs[sid];
                    s.cached += take;
                    s.prefilled += take;
                    self.stats.chunk_tokens += take as u64;
                    self.stats.prefill_tokens += take as u64;
                    self.publish(ri, sid);
                    let s = &mut self.seqs[sid];
                    if s.prefilled == s.prompt {
                        s.generated = 1;
                        self.run_rem[ri] -= 1;
                        self.stamp_first(sid, t_emit);
                        self.emit(sid, t_emit);
                        if self.seqs[sid].generated >= self.seqs[sid].out {
                            done.push(sid);
                        }
                    }
                }
                for &sid in &decode_ids {
                    let grow = self.grow_pages(self.seqs[sid].cached);
                    self.ranks[ri].free = (self.ranks[ri].free as isize - grow) as usize;
                    self.used_pages_total = (self.used_pages_total as isize + grow) as usize;
                    let s = &mut self.seqs[sid];
                    s.cached += 1;
                    s.generated += 1;
                    self.run_rem[ri] -= 1;
                    self.emit(sid, t_emit);
                    if self.seqs[sid].generated >= self.seqs[sid].out {
                        done.push(sid);
                    }
                }
                for sid in done {
                    self.run_rem[ri] -= self.seqs[sid].out - self.seqs[sid].generated;
                    let freed = self.private_pages(sid);
                    self.ranks[ri].free += freed;
                    self.used_pages_total -= freed;
                    self.ranks[ri].running.retain(|&x| x != sid);
                }
            }
            Action::Resume(_) => {
                let sid = self.ranks[ri].waiting.remove(0);
                self.wait_po[ri] -= self.seqs[sid].prompt + self.seqs[sid].out;
                self.wait_rem[ri] -= self.seqs[sid].out - self.seqs[sid].generated;
                let cached = self.seqs[sid].cached;
                cost = self.scen.cost.spill(cached) * self.speeds[ri];
                let pg = self.respages(cached);
                self.ranks[ri].free -= pg;
                self.used_pages_total += pg;
                let s = &mut self.seqs[sid];
                s.spilled = false;
                s.adopted = 0;
                s.transferred = 0;
                self.stats.restores += 1;
                self.ranks[ri].running.push(sid);
                self.run_rem[ri] += self.seqs[sid].out - self.seqs[sid].generated;
            }
            Action::Prefetch(_) => {
                // async resume: the pages are claimed now (PrefetchInFlight),
                // the PCIe copy rides the host→device link, and the sequence
                // joins the batch when the flight lands — the rank pays
                // nothing and keeps decoding in the meantime
                let t_start = t_start.expect("tiered prefetch only exists under event timing");
                let sid = self.ranks[ri].waiting.remove(0);
                self.wait_po[ri] -= self.seqs[sid].prompt + self.seqs[sid].out;
                self.wait_rem[ri] -= self.seqs[sid].out - self.seqs[sid].generated;
                let cached = self.seqs[sid].cached;
                let pg = self.respages(cached);
                self.ranks[ri].free -= pg;
                self.used_pages_total += pg;
                let s = &mut self.seqs[sid];
                s.spilled = false;
                s.adopted = 0;
                s.transferred = 0;
                self.stats.restores += 1;
                self.stats.prefetches += 1;
                let start = t_start.max(self.up_free[ri]);
                self.up_free[ri] = start + self.scen.cost.prefetch(cached) * self.speeds[ri];
                self.prefetch_fl[ri].push((sid, self.up_free[ri]));
                cost = 0.0;
            }
            Action::Preempt(idx) => {
                let sid = self.ranks[ri].running.remove(idx);
                self.run_rem[ri] -= self.seqs[sid].out - self.seqs[sid].generated;
                let cached = self.seqs[sid].cached;
                cost = self.scen.cost.spill(cached) * self.speeds[ri];
                let freed = self.private_pages(sid);
                self.ranks[ri].free += freed;
                self.used_pages_total -= freed;
                // the spill snapshot privatizes adopted pages (exactness
                // over dedup): the restore reallocates every page
                let s = &mut self.seqs[sid];
                s.adopted = 0;
                s.transferred = 0;
                s.spilled = true;
                self.stats.spills += 1;
                self.ranks[ri].waiting.insert(0, sid);
                self.wait_po[ri] += self.seqs[sid].prompt + self.seqs[sid].out;
                self.wait_rem[ri] += self.seqs[sid].out - self.seqs[sid].generated;
            }
            Action::SpillAsync(idx) => {
                // async preempt: the victim leaves the batch now, but its
                // pages stay SpillInFlight (not yet free) until the
                // device→host copy lands; the rank pays nothing for the
                // eviction itself
                let t_start = t_start.expect("tiered spill only exists under event timing");
                let sid = self.ranks[ri].running.remove(idx);
                self.run_rem[ri] -= self.seqs[sid].out - self.seqs[sid].generated;
                let cached = self.seqs[sid].cached;
                let pp = self.private_pages(sid);
                let start = t_start.max(self.dn_free[ri]);
                self.dn_free[ri] = start + self.scen.cost.host_spill(cached) * self.speeds[ri];
                self.spill_fl[ri].push((sid, self.dn_free[ri], pp));
                let s = &mut self.seqs[sid];
                s.adopted = 0;
                s.transferred = 0;
                s.spilled = true;
                self.stats.spills += 1;
                self.ranks[ri].waiting.insert(0, sid);
                self.wait_po[ri] += self.seqs[sid].prompt + self.seqs[sid].out;
                self.wait_rem[ri] += self.seqs[sid].out - self.seqs[sid].generated;
                cost = 0.0;
            }
        }
        self.untouch(ri);
        Ok(cost)
    }

    /// Name the most-loaded stuck rank for a deadlock diagnostic.
    fn stuck_report(&self) -> String {
        let worst = (0..self.ranks.len())
            .filter(|&ri| self.rank_busy(ri))
            .max_by_key(|&ri| self.ranks[ri].waiting.len() + self.ranks[ri].running.len())
            .unwrap_or(0);
        let r = &self.ranks[worst];
        format!(
            "rank {worst} stuck with {} waiting + {} running and {} free pages",
            r.waiting.len(),
            r.running.len(),
            r.free
        )
    }

    /// The event loop found no schedulable event — name the full state
    /// (per-rank busy queues, pending arrivals, in-flight transfers)
    /// instead of panicking on an empty candidate set.
    fn wedge_report(&self, pending_arrivals: usize) -> String {
        let busy: Vec<String> = self
            .ranks
            .iter()
            .enumerate()
            .filter(|(_, r)| !r.waiting.is_empty() || !r.running.is_empty())
            .map(|(ri, r)| {
                format!(
                    "(rank {ri}: {} waiting, {} running, t={})",
                    r.waiting.len(),
                    r.running.len(),
                    r.t
                )
            })
            .collect();
        format!(
            "event loop wedged: no schedulable event (busy ranks [{}], {} pending \
             arrivals, {} in-flight transfers); {}",
            busy.join(", "),
            pending_arrivals,
            self.in_flight.len(),
            self.stuck_report()
        )
    }

    pub(super) fn run(mut self, trace: &[Request]) -> anyhow::Result<SimResult> {
        match self.scen.timing {
            SimTiming::LockStep => self.run_lockstep(trace)?,
            SimTiming::EventDriven => self.run_event(trace)?,
        }
        Ok(self.summarize(trace))
    }

    fn rank_busy(&self, ri: usize) -> bool {
        !self.ranks[ri].waiting.is_empty() || !self.ranks[ri].running.is_empty()
    }

    fn any_busy(&self) -> bool {
        (0..self.ranks.len()).any(|ri| self.rank_busy(ri))
    }

    /// A rank that just gained its first work item becomes schedulable:
    /// enter the busy set and the ready-heap at its current local time.
    /// An already-busy rank already owns a live heap entry (pushed here or
    /// re-pushed by the event sweep after its last action).
    fn touch(&mut self, ri: usize) {
        if !self.busy[ri] && self.rank_busy(ri) {
            self.busy[ri] = true;
            self.busy_count += 1;
            self.ready.push(self.ranks[ri].t, ri, ());
        }
    }

    /// Dropping the last work item retires the rank from the busy set; its
    /// heap entries go stale and are discarded lazily.
    fn untouch(&mut self, ri: usize) {
        if self.busy[ri] && !self.rank_busy(ri) {
            self.busy[ri] = false;
            self.busy_count -= 1;
        }
    }

    /// A ready-heap entry is live iff its rank still has work and the
    /// entry's time is the rank's current clock (bitwise, like the heap's
    /// own `total_cmp` ordering over the finite times `push` asserts).
    fn heap_entry_live(&self, t: f64, ri: usize) -> bool {
        #[allow(clippy::float_cmp)]
        {
            self.rank_busy(ri) && t == self.ranks[ri].t
        }
    }

    fn sample_pages(&mut self) {
        let used: usize = if self.naive {
            self.ranks.iter().map(|r| self.scen.capacity_pages - r.free).sum()
        } else {
            self.used_pages_total
        };
        self.stats.peak_pages = self.stats.peak_pages.max(used);
        let running: usize = self.ranks.iter().map(|r| r.running.len()).sum();
        self.stats.peak_running = self.stats.peak_running.max(running);
    }

    /// Any tier transfer still riding the host link (keeps the event loop
    /// alive until every flight lands).
    fn tier_flights_pending(&self) -> bool {
        self.spill_fl.iter().any(|fl| !fl.is_empty())
            || self.prefetch_fl.iter().any(|fl| !fl.is_empty())
    }

    /// Advance the active-rank time integral to `to` (elastic only).
    fn advance_active_integral(&mut self, to: f64) {
        self.a_int += self.active_count() as f64 * (to - self.a_last);
        self.a_last = to;
    }

    fn run_lockstep(&mut self, trace: &[Request]) -> anyhow::Result<()> {
        let mut clock = 0.0f64;
        let mut next_arrival = 0usize;
        let mut rounds = 0usize;
        while next_arrival < trace.len()
            || (if self.naive { self.any_busy() } else { self.busy_count > 0 })
        {
            rounds += 1;
            anyhow::ensure!(rounds <= 500_000, "sim runaway");
            while next_arrival < trace.len() && trace[next_arrival].arrival_s <= clock {
                self.route(next_arrival)?;
                next_arrival += 1;
            }

            // one lock-step round: every rank takes one scheduler action off
            // the pre-round state; the round costs the slowest rank's step
            // (the indexed path sweeps only the busy set, in rank order —
            // exactly the set the naive full scan acts on)
            let order: Vec<usize> = if self.naive {
                (0..self.ranks.len()).collect()
            } else {
                (0..self.ranks.len()).filter(|&ri| self.busy[ri]).collect()
            };
            let mut decisions: Vec<(usize, Action)> = Vec::new();
            for ri in order {
                if !self.rank_busy(ri) {
                    continue;
                }
                let action = self.decide(ri);
                if action != Action::Idle {
                    decisions.push((ri, action));
                }
            }
            if decisions.is_empty() {
                if next_arrival < trace.len() {
                    clock = clock.max(trace[next_arrival].arrival_s);
                    continue;
                }
                anyhow::bail!("lockstep deadlock: {}", self.stuck_report());
            }
            // costs depend only on each rank's own pre-apply state, so
            // apply per rank, then charge the round's max (lock-step barrier)
            let mut round_cost = 0.0f64;
            for (ri, action) in decisions {
                round_cost = round_cost.max(self.apply(ri, action, None)?);
            }
            clock += round_cost;
            // tokens produced this round are stamped at the round boundary
            let emitted = std::mem::take(&mut self.pending_emits);
            for &sid in &emitted {
                let s = &mut self.seqs[sid];
                if let Some(last) = s.last_token {
                    self.itl.push(clock - last);
                }
                s.last_token = Some(clock);
            }
            if self.naive {
                for s in self.seqs.iter_mut() {
                    if s.first_token.is_none() && s.generated > 0 {
                        s.first_token = Some(clock);
                    }
                }
            } else {
                // a sequence's first token is born the round `generated`
                // goes 0 -> 1, and that transition always emits — so every
                // unstamped first token is in this round's pending_emits
                // (no O(seqs) sweep per round)
                for &sid in &emitted {
                    let s = &mut self.seqs[sid];
                    if s.first_token.is_none() {
                        s.first_token = Some(clock);
                    }
                }
            }
            self.stats.rounds += 1;
            self.sample_pages();
        }
        // lock-step wall time is the global clock; park it on rank 0 so
        // summarize()'s max-over-clocks sees it
        self.ranks[0].t = clock;
        Ok(())
    }

    fn run_event(&mut self, trace: &[Request]) -> anyhow::Result<()> {
        let mut clock = 0.0f64;
        let mut next_arrival = 0usize;
        let mut iters = 0usize;
        let elastic = self.scen.elastic.is_some();
        let eval_interval = self
            .scen
            .elastic
            .as_ref()
            .and_then(|e| e.autoscale.as_ref())
            .map(|a| a.eval_interval_s);
        while next_arrival < trace.len()
            || !self.in_flight.is_empty()
            || (self.tiered_async && self.tier_flights_pending())
            || (if self.naive { self.any_busy() } else { self.busy_count > 0 })
        {
            iters += 1;
            anyhow::ensure!(iters <= 2_000_000, "sim runaway");
            // the next instant anything can happen: a busy rank's local
            // clock, the next arrival, an in-flight transfer's ready-time,
            // or (elastic) a scheduled failure / a provisioning rank coming
            // up / the autoscaler's next evaluation
            //
            // the no-progress jump below must use THIS iteration's candidate
            // set: an autoscale decision made mid-iteration publishes its
            // join (and advances next_eval) for the NEXT iteration
            let eval_at_start = self.next_eval;
            let joins_at_start = self.pending_joins.len();
            let mut naive_later = f64::INFINITY;
            let new_clock = if self.naive {
                // reference arm: rebuild the full candidate event loop every
                // iteration and drain it (computing the eager `later` jump)
                let mut cands: EventLoop<()> = EventLoop::new();
                let n = self.ranks.len();
                for ri in 0..n {
                    if self.rank_busy(ri) {
                        cands.push(self.ranks[ri].t, ri, ());
                    }
                }
                if next_arrival < trace.len() {
                    cands.push(trace[next_arrival].arrival_s, n, ());
                }
                for &(_, ready) in &self.in_flight {
                    cands.push(ready, n + 1, ());
                }
                if self.tiered_async {
                    for fl in &self.spill_fl {
                        for f in fl {
                            cands.push(f.1, n + 5, ());
                        }
                    }
                    for fl in &self.prefetch_fl {
                        for f in fl {
                            cands.push(f.1, n + 6, ());
                        }
                    }
                }
                if elastic {
                    if self.next_fail < self.fail_sched.len() {
                        cands.push(self.fail_sched[self.next_fail].0, n + 2, ());
                    }
                    for &jt in &self.pending_joins {
                        cands.push(jt, n + 3, ());
                    }
                    if eval_interval.is_some() {
                        cands.push(self.next_eval, n + 4, ());
                    }
                }
                let Some(min_cand) = cands.peek_time() else {
                    anyhow::bail!("{}", self.wedge_report(trace.len() - next_arrival));
                };
                let nc = clock.max(min_cand);
                while let Some(e) = cands.pop() {
                    if e.time > nc {
                        naive_later = naive_later.min(e.time);
                    }
                }
                nc
            } else {
                // indexed candidate minimum: the ready-heap head is the
                // earliest busy rank (stale entries discarded lazily); the
                // other sources are O(pending) scalar folds
                loop {
                    let (t, ri) = match self.ready.peek() {
                        Some(e) => (e.time, e.rank),
                        None => break,
                    };
                    if self.heap_entry_live(t, ri) {
                        break;
                    }
                    self.ready.pop();
                }
                let mut min_c: Option<f64> = self.ready.peek_time();
                if next_arrival < trace.len() {
                    let at = trace[next_arrival].arrival_s;
                    if min_c.map_or(true, |m| at < m) {
                        min_c = Some(at);
                    }
                }
                for &(_, ready_at) in &self.in_flight {
                    if min_c.map_or(true, |m| ready_at < m) {
                        min_c = Some(ready_at);
                    }
                }
                if self.tiered_async {
                    for fl in &self.spill_fl {
                        for f in fl {
                            if min_c.map_or(true, |m| f.1 < m) {
                                min_c = Some(f.1);
                            }
                        }
                    }
                    for fl in &self.prefetch_fl {
                        for f in fl {
                            if min_c.map_or(true, |m| f.1 < m) {
                                min_c = Some(f.1);
                            }
                        }
                    }
                }
                if elastic {
                    if self.next_fail < self.fail_sched.len() {
                        let ft = self.fail_sched[self.next_fail].0;
                        if min_c.map_or(true, |m| ft < m) {
                            min_c = Some(ft);
                        }
                    }
                    for &jt in &self.pending_joins {
                        if min_c.map_or(true, |m| jt < m) {
                            min_c = Some(jt);
                        }
                    }
                    if eval_interval.is_some() && min_c.map_or(true, |m| self.next_eval < m) {
                        min_c = Some(self.next_eval);
                    }
                }
                let Some(min_c) = min_c else {
                    anyhow::bail!("{}", self.wedge_report(trace.len() - next_arrival));
                };
                clock.max(min_c)
            };
            if elastic && new_clock > clock {
                self.advance_active_integral(new_clock);
            }
            clock = new_clock;

            let mut progressed = false;
            if elastic {
                while self.next_fail < self.fail_sched.len()
                    && self.fail_sched[self.next_fail].0 <= clock
                {
                    let ri = self.fail_sched[self.next_fail].1;
                    self.fail_rank(ri, clock)?;
                    self.next_fail += 1;
                    progressed = true;
                }
                let due = self.pending_joins.iter().filter(|&&jt| jt <= clock).count();
                if due > 0 {
                    for _ in 0..due {
                        self.join_rank(clock);
                    }
                    self.pending_joins.retain(|&jt| jt > clock);
                    progressed = true;
                }
            }
            while next_arrival < trace.len() && trace[next_arrival].arrival_s <= clock {
                self.route(next_arrival)?;
                next_arrival += 1;
                progressed = true;
            }
            if (self.scen.prefill_ranks > 0 || elastic) && self.deliver(clock) {
                progressed = true;
            }
            if self.tiered_async {
                // pump the tier engine: landed spills release their pages
                // (SpillInFlight → Host), landed prefetches join the batch
                // (PrefetchInFlight → Hbm) and wake their rank. Per-direction
                // link serialization makes each list's ready-times monotone,
                // so the head check is a sound fast path.
                for ri in 0..self.ranks.len() {
                    if self.spill_fl[ri].first().is_some_and(|f| f.1 <= clock) {
                        let fl = std::mem::take(&mut self.spill_fl[ri]);
                        let mut keep = Vec::new();
                        for (sid, ready_at, pp) in fl {
                            if ready_at <= clock {
                                self.ranks[ri].free += pp;
                                self.used_pages_total -= pp;
                                progressed = true;
                            } else {
                                keep.push((sid, ready_at, pp));
                            }
                        }
                        self.spill_fl[ri] = keep;
                    }
                    if self.prefetch_fl[ri].first().is_some_and(|f| f.1 <= clock) {
                        let fl = std::mem::take(&mut self.prefetch_fl[ri]);
                        let mut keep = Vec::new();
                        for (sid, ready_at) in fl {
                            if ready_at <= clock {
                                self.ranks[ri].running.push(sid);
                                self.run_rem[ri] += self.seqs[sid].out - self.seqs[sid].generated;
                                self.touch(ri);
                                progressed = true;
                            } else {
                                keep.push((sid, ready_at));
                            }
                        }
                        self.prefetch_fl[ri] = keep;
                    }
                }
            }
            if let Some(interval) = eval_interval {
                if clock >= self.next_eval {
                    while self.next_eval <= clock {
                        self.next_eval += interval;
                    }
                    self.autoscale_eval(clock);
                }
            }

            let due: Vec<usize> = if self.naive {
                (0..self.ranks.len()).collect()
            } else {
                // batched pop: drain every live heap entry at or before the
                // new clock in one sweep (clock::EventLoop::pop_batch's
                // shape), then act in rank order — the same order the naive
                // rank scan visits, and cross-rank effects within an instant
                // only ride `in_flight`, so order beyond rank id can't matter
                let mut due = Vec::new();
                let mut seen = vec![false; self.ranks.len()];
                loop {
                    let (t, ri) = match self.ready.peek() {
                        Some(e) => (e.time, e.rank),
                        None => break,
                    };
                    if !self.heap_entry_live(t, ri) {
                        self.ready.pop();
                        continue;
                    }
                    if t > clock {
                        break;
                    }
                    self.ready.pop();
                    if !seen[ri] {
                        seen[ri] = true;
                        due.push(ri);
                    }
                }
                due.sort_unstable();
                due
            };
            for ri in due {
                if self.ranks[ri].t <= clock {
                    // handoffs cost the rank nothing (serialize + async
                    // send): a prefill rank drains every completed prefill
                    // and still takes its real action at the same instant
                    let action = loop {
                        if !self.rank_busy(ri) {
                            break Action::Idle;
                        }
                        let action = self.decide(ri);
                        if !matches!(action, Action::Handoff(_)) {
                            break action;
                        }
                        let t = self.ranks[ri].t;
                        self.apply(ri, action, Some(t))?;
                        progressed = true;
                    };
                    if action != Action::Idle {
                        let t = self.ranks[ri].t;
                        let cost = self.apply(ri, action, Some(t))?;
                        self.ranks[ri].t += cost;
                        self.stats.steps += 1;
                        progressed = true;
                    }
                }
                if !self.naive && self.rank_busy(ri) {
                    // restore the heap invariant: every busy rank owns one
                    // live entry (at its advanced time, or unchanged if the
                    // scheduler had nothing feasible this instant)
                    self.ready.push(self.ranks[ri].t, ri, ());
                }
            }

            if elastic {
                // a draining rank that has emptied its queue retires: its
                // published prefixes and page pool are released
                let capacity = self.scen.capacity_pages;
                for ri in 0..self.ranks.len() {
                    if self.ranks[ri].state == RankState::Draining
                        && self.ranks[ri].waiting.is_empty()
                        && self.ranks[ri].running.is_empty()
                    {
                        let r = &mut self.ranks[ri];
                        r.state = RankState::Dead;
                        r.shared.iter_mut().for_each(|g| *g = 0);
                        self.used_pages_total -= capacity - r.free;
                        r.free = capacity;
                    }
                }
            }

            if !progressed {
                let later = if self.naive {
                    naive_later
                } else {
                    // lazy `later`: pop live at-or-before-clock entries into
                    // a stash until the first strictly-later live entry
                    // surfaces, re-push everything, then fold the scalar
                    // sources. `pending_joins[..joins_at_start]` is safe: a
                    // join firing implies progressed, so the list can only
                    // have grown since the snapshot on this branch
                    let mut lat: Option<f64> = None;
                    let mut stash: Vec<(f64, usize)> = Vec::new();
                    while let Some(e) = self.ready.pop() {
                        let (t, ri) = (e.time, e.rank);
                        if !self.heap_entry_live(t, ri) {
                            continue;
                        }
                        if t <= clock {
                            stash.push((t, ri));
                            continue;
                        }
                        self.ready.push(t, ri, ());
                        lat = Some(t);
                        break;
                    }
                    for (t, ri) in stash {
                        self.ready.push(t, ri, ());
                    }
                    if next_arrival < trace.len() {
                        let at = trace[next_arrival].arrival_s;
                        if at > clock && lat.map_or(true, |l| at < l) {
                            lat = Some(at);
                        }
                    }
                    for &(_, ready_at) in &self.in_flight {
                        if ready_at > clock && lat.map_or(true, |l| ready_at < l) {
                            lat = Some(ready_at);
                        }
                    }
                    if self.tiered_async {
                        for fl in &self.spill_fl {
                            for f in fl {
                                if f.1 > clock && lat.map_or(true, |l| f.1 < l) {
                                    lat = Some(f.1);
                                }
                            }
                        }
                        for fl in &self.prefetch_fl {
                            for f in fl {
                                if f.1 > clock && lat.map_or(true, |l| f.1 < l) {
                                    lat = Some(f.1);
                                }
                            }
                        }
                    }
                    if elastic {
                        if self.next_fail < self.fail_sched.len() {
                            let ft = self.fail_sched[self.next_fail].0;
                            if ft > clock && lat.map_or(true, |l| ft < l) {
                                lat = Some(ft);
                            }
                        }
                        for &jt in &self.pending_joins[..joins_at_start] {
                            if jt > clock && lat.map_or(true, |l| jt < l) {
                                lat = Some(jt);
                            }
                        }
                        if eval_interval.is_some()
                            && eval_at_start > clock
                            && lat.map_or(true, |l| eval_at_start < l)
                        {
                            lat = Some(eval_at_start);
                        }
                    }
                    lat.unwrap_or(f64::INFINITY)
                };
                if !later.is_finite() {
                    anyhow::bail!("{}", self.wedge_report(trace.len() - next_arrival));
                }
                if elastic {
                    self.advance_active_integral(later);
                }
                clock = later;
                continue;
            }
            self.sample_pages();
        }
        // the final global clock is covered by summarize()'s max over rank
        // clocks: the last progressing action always ran at a rank clock
        // that `clock` had caught up to
        self.ranks[0].t = self.ranks[0].t.max(clock);
        Ok(())
    }

    fn summarize(self, trace: &[Request]) -> SimResult {
        let mut wall = 0.0f64;
        for r in &self.ranks {
            wall = wall.max(r.t);
        }
        // TTFT/ITL tolerate unfinished or dropped sequences: a request that
        // never emitted a token is excluded from the latency stats and
        // shows up in the `dropped` / unfinished counts instead of
        // panicking
        let mut ttft = Stats::new();
        let mut ttft_short = Stats::new();
        for s in &self.seqs {
            let Some(first) = s.first_token else { continue };
            let t = first - s.arrival;
            ttft.push(t);
            if !s.long {
                ttft_short.push(t);
            }
        }
        let mut itl = Stats::new();
        for &x in &self.itl {
            itl.push(x);
        }
        let dropped = self.seqs.iter().filter(|s| s.dropped).count();
        let unfinished =
            self.seqs.iter().filter(|s| !s.dropped && s.generated < s.out).count();
        let elastic = self.scen.elastic.is_some();
        let final_active = self.active_count();
        let mut a_int = self.a_int;
        if elastic && wall > self.a_last {
            a_int += final_active as f64 * (wall - self.a_last);
        }
        let mean_active = if elastic {
            if wall > 0.0 { a_int / wall } else { final_active as f64 }
        } else {
            self.scen.ranks as f64
        };
        let st = self.stats;
        SimResult {
            ranks: self.scen.ranks,
            prefill_ranks: self.scen.prefill_ranks,
            decode_ranks: if self.scen.prefill_ranks == 0 {
                self.scen.ranks
            } else {
                self.scen.ranks - self.scen.prefill_ranks
            },
            requests: trace.len(),
            completed: trace.len() - dropped - unfinished,
            dropped,
            gen_tokens: st.gen_tokens,
            wall_s: wall,
            ttft,
            ttft_short,
            itl,
            peak_pages: st.peak_pages,
            prefill_tokens: st.prefill_tokens,
            chunk_tokens: st.chunk_tokens,
            prefix_hit_tokens: st.prefix_hit_tokens,
            decode_steps: st.decode_steps,
            decode_batch_sum: st.decode_batch_sum,
            rounds: st.rounds,
            steps: st.steps,
            spills: st.spills,
            restores: st.restores,
            handoffs: st.handoffs,
            wire_fp8_bytes: st.wire_fp8_bytes,
            wire_bf16_bytes: st.wire_bf16_bytes,
            routed: st.routed,
            evacuated: st.evacuated,
            recovered: st.recovered,
            fails: st.fails,
            joins: st.joins,
            drains: st.drains,
            peak_active_ranks: self.peak_active,
            final_active_ranks: final_active,
            mean_active_ranks: mean_active,
            rank_timeline: self.rank_timeline,
            spec_steps: st.spec_steps,
            spec_seq_steps: st.spec_seq_steps,
            spec_drafted_tokens: st.spec_drafted,
            spec_tokens: st.spec_tokens,
            peak_running: st.peak_running,
            prefetches: st.prefetches,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::scheduler::{SchedPolicy, SchedulerConfig};
    use crate::simulate::ElasticConfig;
    use crate::workload::{TraceConfig, TraceGen};

    fn sched_cfg() -> SchedulerConfig {
        SchedulerConfig {
            max_decode_batch: 8,
            max_prefill_batch: 4,
            max_prefill_tokens: 4096,
            max_context: 8192,
            page_tokens: PAGE_TOKENS,
            prefill_chunk_tokens: 128,
            chunk_per_seq: 64,
            max_step_items: 12,
            max_running: 12,
            disagg_prefill: false,
            spec: SpecConfig::disabled(),
            tiered: TieredConfig::disabled(),
            policy: SchedPolicy::MixedChunked,
        }
    }

    fn scen(elastic: Option<ElasticConfig>) -> Scenario {
        Scenario {
            ranks: 2,
            prefill_ranks: 0,
            routing: SimRoute::ShortestQueue,
            timing: SimTiming::EventDriven,
            sched: sched_cfg(),
            prefill_sched: None,
            capacity_pages: 256,
            cost: CostModel::Uniform { step_s: 1.0 },
            speeds: Vec::new(),
            elastic,
            spec: None,
            tiered: None,
            naive: false,
        }
    }

    fn trace() -> Vec<Request> {
        TraceGen::generate(&TraceConfig {
            seed: 17,
            num_requests: 12,
            mean_interarrival_s: 0.5,
            prompt_min: 16,
            prompt_max: 64,
            out_min: 8,
            out_max: 24,
            ..Default::default()
        })
    }

    /// Regression for the old `max().unwrap()` panic: an empty decode
    /// batch must surface as a named error, not a panic.
    #[test]
    fn empty_decode_batch_is_a_named_error() {
        let scenario = scen(None);
        let trace = trace();
        let mut h = Harness::new(&scenario, &trace);
        let err = h.apply(0, Action::Decode(Vec::new()), Some(0.0)).unwrap_err();
        let msg = err.to_string();
        assert!(msg.contains("empty decode batch"), "{msg}");
        assert!(msg.contains("rank 0"), "{msg}");
    }

    /// Regression for the old `peek_time().expect(...)` / `later`-assert
    /// panics: a transfer that can never deliver (no deliver path in the
    /// non-elastic colocated mode) must wedge with a named diagnostic
    /// listing the in-flight transfer, not panic.
    #[test]
    fn undeliverable_transfer_is_a_named_wedge_error() {
        let scenario = Scenario { routing: SimRoute::Single, ..scen(None) };
        let trace = trace();
        let mut h = Harness::new(&scenario, &trace);
        h.in_flight.push((0, 0.25));
        let err = h.run_event(&trace).unwrap_err();
        let msg = err.to_string();
        assert!(msg.contains("event loop wedged"), "{msg}");
        assert!(msg.contains("1 in-flight transfers"), "{msg}");
        assert!(msg.contains("0 pending arrivals"), "{msg}");
    }

    /// Regression for the old `first_token.expect("all sequences
    /// finished")` panic: summarize must report sequences that never
    /// emitted instead of crashing on them.
    #[test]
    fn summarize_tolerates_tokenless_sequences() {
        let scenario = scen(None);
        let trace = trace();
        let mut h = Harness::new(&scenario, &trace);
        h.seqs[3].dropped = true;
        let r = h.summarize(&trace);
        assert_eq!(r.dropped, 1);
        assert_eq!(r.completed, 0); // nothing ran: the rest are unfinished
        assert!(r.ttft.is_empty());
    }

    /// Same trace + same failure/autoscale schedule → bit-identical
    /// outcomes, membership churn included.
    #[test]
    fn elastic_membership_is_deterministic() {
        let run = || {
            let scenario = scen(Some(ElasticConfig {
                failures: vec![(2.5, 1)],
                recover: true,
                autoscale: None,
            }));
            let trace = trace();
            scenario.run(&trace).unwrap()
        };
        let (a, b) = (run(), run());
        assert_eq!(a.wall_s.to_bits(), b.wall_s.to_bits());
        assert_eq!(a.gen_tokens, b.gen_tokens);
        assert_eq!(a.dropped, b.dropped);
        assert_eq!(a.evacuated, b.evacuated);
        assert_eq!(a.recovered, b.recovered);
        assert_eq!(a.rank_timeline.len(), b.rank_timeline.len());
        for (x, y) in a.rank_timeline.iter().zip(&b.rank_timeline) {
            assert_eq!(x.0.to_bits(), y.0.to_bits());
            assert_eq!((x.1, x.2, x.3), (y.1, y.2, y.3));
        }
    }

    /// An elastic config with no failures and no autoscaler must be
    /// byte-identical to the plain event-driven run: every elastic branch
    /// is fully gated.
    #[test]
    fn empty_elastic_config_is_byte_identical_to_plain_event_mode() {
        let trace = trace();
        let plain = scen(None).run(&trace).unwrap();
        let idle = scen(Some(ElasticConfig {
            failures: Vec::new(),
            recover: true,
            autoscale: None,
        }))
        .run(&trace)
        .unwrap();
        assert_eq!(plain.wall_s.to_bits(), idle.wall_s.to_bits());
        assert_eq!(plain.gen_tokens, idle.gen_tokens);
        assert_eq!(plain.steps, idle.steps);
        assert_eq!(plain.peak_pages, idle.peak_pages);
        assert_eq!(plain.routed, idle.routed);
        assert_eq!(
            plain.ttft.percentile(95.0).to_bits(),
            idle.ttft.percentile(95.0).to_bits()
        );
        assert_eq!(idle.dropped, 0);
        assert_eq!(idle.fails + idle.joins + idle.drains, 0);
    }

    /// A spec scenario is deterministic and its frontier metric respects
    /// the bonus-token floor; the non-spec arm of the same trace carries
    /// zeroed spec counters.
    #[test]
    fn spec_arm_is_deterministic_with_floor_one_accepted() {
        use crate::simulate::scenario::SpecSim;
        let run = || {
            let scenario = Scenario {
                routing: SimRoute::Single,
                ranks: 1,
                spec: Some(SpecSim { draft_len: 2, accept_rate: 0.7 }),
                ..scen(None)
            };
            let trace = trace();
            scenario.run(&trace).unwrap()
        };
        let (a, b) = (run(), run());
        assert_eq!(a.wall_s.to_bits(), b.wall_s.to_bits());
        assert_eq!(a.spec_steps, b.spec_steps);
        assert_eq!(a.spec_tokens, b.spec_tokens);
        assert!(a.spec_steps > 0, "decode-bearing trace must draft");
        assert!(a.spec_drafted_tokens >= a.spec_steps * 2);
        // every spec sequence-step emits at least the bonus token and at
        // most draft_len + 1
        assert!(a.accepted_per_spec_step() >= 1.0);
        assert!(a.accepted_per_spec_step() <= 3.0);
    }

    /// `spec: None` leaves the scheduler gate off: the run is byte-identical
    /// to the pre-spec harness and every spec counter stays zero.
    #[test]
    fn no_spec_config_keeps_counters_zero() {
        let trace = trace();
        let r = scen(None).run(&trace).unwrap();
        assert_eq!(r.spec_steps, 0);
        assert_eq!(r.spec_seq_steps, 0);
        assert_eq!(r.spec_drafted_tokens, 0);
        assert_eq!(r.spec_tokens, 0);
        assert_eq!(r.accepted_per_spec_step(), 0.0);
    }

    /// A failure with recovery on re-migrates the failed rank's KV; the
    /// same failure without recovery drops it. Fresh waiting requests
    /// re-route either way.
    #[test]
    fn failed_rank_sequences_recover_or_drop() {
        let trace = trace();
        let with = scen(Some(ElasticConfig {
            failures: vec![(2.5, 1)],
            recover: true,
            autoscale: None,
        }))
        .run(&trace)
        .unwrap();
        let without = scen(Some(ElasticConfig {
            failures: vec![(2.5, 1)],
            recover: false,
            autoscale: None,
        }))
        .run(&trace)
        .unwrap();
        assert_eq!(with.fails, 1);
        assert_eq!(with.recovered, with.evacuated);
        assert_eq!(with.dropped, 0);
        assert_eq!(without.evacuated, 0);
        assert_eq!(without.dropped as u64 + without.completed as u64, trace.len() as u64);
        assert!(with.completed > without.completed);
    }

    /// A page-pressure trace that forces preemption churn on one rank:
    /// every prompt is several pages and the pool holds only a fraction of
    /// the fleet (mirrors the serve_tiered regime at miniature scale).
    fn pressure_trace() -> Vec<Request> {
        TraceGen::generate(&TraceConfig {
            seed: 23,
            num_requests: 8,
            mean_interarrival_s: 0.0,
            prompt_min: 256,
            prompt_max: 512,
            out_min: 32,
            out_max: 64,
            ..Default::default()
        })
    }

    fn tiered_scen(tiered: Option<crate::simulate::TieredSim>) -> Scenario {
        Scenario {
            ranks: 1,
            routing: SimRoute::Single,
            capacity_pages: 24,
            cost: Scenario::h20_cost(8, 1),
            tiered,
            ..scen(None)
        }
    }

    /// `tiered: None` leaves every tier branch gated: no prefetches, and
    /// the peak_running recorder works for plain runs too.
    #[test]
    fn no_tiered_config_keeps_flight_counters_zero() {
        let trace = pressure_trace();
        let r = tiered_scen(None).run(&trace).unwrap();
        assert_eq!(r.prefetches, 0);
        assert!(r.spills > 0, "pressure trace must preempt");
        assert_eq!(r.spills, r.restores);
        assert!(r.peak_running > 0);
        assert_eq!(r.completed, trace.len());
    }

    /// The async tier arm is deterministic, every spill gets a matching
    /// prefetch flight (restores == prefetches), and the run still
    /// completes the full trace — no flight ever strands a sequence.
    #[test]
    fn tiered_async_arm_is_deterministic_and_flights_land() {
        use crate::simulate::TieredSim;
        let run = || {
            let trace = pressure_trace();
            tiered_scen(Some(TieredSim {
                async_io: true,
                cold_after: 0,
                comp_ratio: 1.0,
                comp_rank: 0,
            }))
            .run(&trace)
            .unwrap()
        };
        let (a, b) = (run(), run());
        assert_eq!(a.wall_s.to_bits(), b.wall_s.to_bits());
        assert_eq!(a.gen_tokens, b.gen_tokens);
        assert_eq!(a.peak_running, b.peak_running);
        assert!(a.spills > 0, "pressure trace must spill");
        assert_eq!(a.restores, a.prefetches, "every async resume is a prefetch flight");
        assert_eq!(a.completed, 8);
    }

    /// The compressed cold tier fits more concurrent sequences into the
    /// same page pool than the uncompressed async arm, and both emit the
    /// same tokens (compression changes residency, never the output).
    #[test]
    fn tiered_compression_raises_concurrency_at_fixed_pages() {
        use crate::simulate::TieredSim;
        let trace = pressure_trace();
        let plain = tiered_scen(Some(TieredSim {
            async_io: true,
            cold_after: 0,
            comp_ratio: 1.0,
            comp_rank: 0,
        }))
        .run(&trace)
        .unwrap();
        let comp = tiered_scen(Some(TieredSim {
            async_io: true,
            cold_after: 4 * PAGE_TOKENS,
            comp_ratio: 324.0 / 644.0,
            comp_rank: 192,
        }))
        .run(&trace)
        .unwrap();
        assert_eq!(plain.gen_tokens, comp.gen_tokens);
        assert_eq!(comp.completed, trace.len());
        assert!(
            comp.peak_running >= plain.peak_running,
            "compressed {} < plain {}",
            comp.peak_running,
            plain.peak_running
        );
        assert!(comp.peak_pages <= 24);
    }
}
