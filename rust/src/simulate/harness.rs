//! The virtual-time serving harness: ONE simulation engine behind every
//! serve bench (`serve_mixed`, `serve_cluster`, `serve_disagg`,
//! `serve_straggler`) and their Python ports
//! (`python/tests/serve_port_common.py` mirrors this file function for
//! function — the committed BENCH_*.json baselines are generated there, so
//! any edit here must be mirrored and the baselines regenerated).
//!
//! The harness owns everything the benches used to copy-paste: trace
//! replay and arrival injection, per-rank queue/page state, prefix-page
//! publication/adoption, routing through the REAL `coordinator::router`
//! policies, scheduling through the REAL `coordinator::Scheduler`, step
//! costs from the calibrated analytical model (`perfmodel::e2e`), and the
//! TTFT/ITL/throughput recorders (backed by [`crate::util::stats::Stats`]).
//! Two timing modes:
//!
//! * [`SimTiming::LockStep`] — every rank takes one scheduler action per
//!   round off the pre-round state; the round costs the slowest rank's
//!   step, and tokens produced in a round are stamped at the round barrier.
//! * [`SimTiming::EventDriven`] — every rank owns its clock and advances by
//!   its own (speed-scaled) step costs; the global clock follows the
//!   earliest candidate wake-up popped from [`super::clock::EventLoop`]: a
//!   busy rank's local time, the next arrival, or an in-flight transfer's
//!   ready-time. A rank's clock may LAG the global clock while it idles —
//!   its next action is charged from its own clock (the committed
//!   asynchronous semantics; see DESIGN.md "Simulation core").
//!
//! No wall clock anywhere: two runs produce byte-identical numbers.

use super::clock::EventLoop;
use super::scenario::{Scenario, SimRoute, SimTiming};
use crate::coordinator::router::{pick_handoff_rank, pick_rank, pick_rank_affinity, RankLoad};
use crate::coordinator::scheduler::{Action, RunningSeq, Scheduler, WaitingSeq};
use crate::kvcache::PAGE_TOKENS;
use crate::perfmodel::e2e::{
    decode_step_s, handoff_s, mixed_step_s, prefill_step_s, spill_s,
};
use crate::perfmodel::{DeploymentConfig, GpuSpec, KernelKind, ModelSpec};
use crate::util::stats::Stats;
use crate::workload::Request;

/// Step-cost model for one scenario's ranks.
#[derive(Clone, Copy, Debug)]
pub enum CostModel {
    /// the calibrated H20-class analytical model (`perfmodel::e2e`)
    Analytic {
        gpu: GpuSpec,
        model: ModelSpec,
        dcfg: DeploymentConfig,
        kind: KernelKind,
    },
    /// every action costs the same constant — the degenerate mode in which
    /// the event-driven loop reproduces lock-step byte-for-byte (pinned by
    /// `integration_simulate`)
    Uniform { step_s: f64 },
}

impl CostModel {
    fn decode(&self, batch: usize, context: usize) -> f64 {
        match self {
            CostModel::Analytic { gpu, model, dcfg, kind } => {
                decode_step_s(gpu, model, dcfg, batch, context, *kind)
            }
            CostModel::Uniform { step_s } => *step_s,
        }
    }

    fn prefill(&self, tokens: usize) -> f64 {
        match self {
            CostModel::Analytic { gpu, model, dcfg, kind } => {
                prefill_step_s(gpu, model, dcfg, tokens, *kind)
            }
            CostModel::Uniform { step_s } => *step_s,
        }
    }

    fn mixed(&self, batch: usize, dctx: usize, chunk: usize, cctx: usize) -> f64 {
        match self {
            CostModel::Analytic { gpu, model, dcfg, kind } => {
                mixed_step_s(gpu, model, dcfg, batch, dctx, chunk, cctx, *kind)
            }
            CostModel::Uniform { step_s } => *step_s,
        }
    }

    fn spill(&self, tokens: usize) -> f64 {
        match self {
            CostModel::Analytic { gpu, model, kind, .. } => spill_s(gpu, model, tokens, *kind),
            CostModel::Uniform { step_s } => *step_s,
        }
    }

    fn handoff(&self, tokens: usize) -> f64 {
        match self {
            CostModel::Analytic { gpu, model, kind, .. } => {
                handoff_s(gpu, model, tokens, *kind)
            }
            CostModel::Uniform { step_s } => *step_s,
        }
    }

    /// (FP8 wire bytes, bf16-everything wire bytes) for `tokens` of KV.
    fn wire_bytes(&self, tokens: usize) -> (u64, u64) {
        match self {
            CostModel::Analytic { model, .. } => (
                model.kv_bytes_per_token(KernelKind::SnapMlaFp8) as u64 * tokens as u64,
                model.kv_bytes_per_token(KernelKind::FlashMlaBf16) as u64 * tokens as u64,
            ),
            CostModel::Uniform { .. } => (tokens as u64, tokens as u64),
        }
    }
}

/// Recorders + counters of one simulated arm — every field a serve bench
/// reports comes out of this one struct (`scenario.rs` selects the exact
/// field set each committed baseline carries).
#[derive(Debug)]
pub struct SimResult {
    pub ranks: usize,
    pub prefill_ranks: usize,
    pub decode_ranks: usize,
    pub requests: usize,
    pub gen_tokens: u64,
    pub wall_s: f64,
    pub ttft: Stats,
    /// TTFT over requests NOT drawn from the long-prompt mixture
    pub ttft_short: Stats,
    /// inter-token latencies (every gap after a sequence's first token)
    pub itl: Stats,
    pub peak_pages: usize,
    pub prefill_tokens: u64,
    pub chunk_tokens: u64,
    pub prefix_hit_tokens: u64,
    pub decode_steps: u64,
    pub decode_batch_sum: u64,
    /// lock-step rounds executed (lock-step timing only)
    pub rounds: u64,
    /// per-rank scheduler actions executed (event timing only)
    pub steps: u64,
    pub spills: u64,
    pub restores: u64,
    pub handoffs: u64,
    pub wire_fp8_bytes: u64,
    pub wire_bf16_bytes: u64,
    pub routed: Vec<u64>,
}

impl SimResult {
    pub fn tok_per_s(&self) -> f64 {
        self.gen_tokens as f64 / self.wall_s
    }

    pub fn mean_decode_batch(&self) -> f64 {
        self.decode_batch_sum as f64 / self.decode_steps.max(1) as f64
    }
}

struct SimSeq {
    prompt: usize,
    out: usize,
    arrival: f64,
    long: bool,
    group: Option<u32>,
    prefix_tokens: usize,
    cached: usize,
    prefilled: usize,
    generated: usize,
    spilled: bool,
    /// prefix pages adopted from the rank's published set (never allocated)
    adopted: usize,
    /// own pages that became the rank's published copy (never freed)
    transferred: usize,
    first_token: Option<f64>,
    last_token: Option<f64>,
}

struct SimRank {
    waiting: Vec<usize>,
    running: Vec<usize>,
    free: usize,
    /// published prefix pages per group (the rank's trie, page-granular)
    shared: Vec<usize>,
    /// rank-local clock (event timing; stays 0 under lock-step)
    t: f64,
}

#[derive(Default)]
struct SimStats {
    gen_tokens: u64,
    prefill_tokens: u64,
    chunk_tokens: u64,
    prefix_hit_tokens: u64,
    decode_steps: u64,
    decode_batch_sum: u64,
    rounds: u64,
    steps: u64,
    peak_pages: usize,
    spills: u64,
    restores: u64,
    handoffs: u64,
    wire_fp8_bytes: u64,
    wire_bf16_bytes: u64,
    routed: Vec<u64>,
}

/// The simulation state machine. Construct via [`Scenario::run`].
pub(super) struct Harness<'a> {
    scen: &'a Scenario,
    sched: Scheduler,
    prefill_sched: Scheduler,
    speeds: Vec<f64>,
    page: usize,
    seqs: Vec<SimSeq>,
    ranks: Vec<SimRank>,
    /// (sid, ready_at) FIFO of serialized sequences in transit
    in_flight: Vec<(usize, f64)>,
    stats: SimStats,
    itl: Vec<f64>,
    /// lock-step: tokens produced this round, stamped at the barrier
    pending_emits: Vec<usize>,
}

fn pages_for(tokens: usize, page: usize) -> usize {
    tokens.div_ceil(page)
}

impl<'a> Harness<'a> {
    pub(super) fn new(scen: &'a Scenario, trace: &[Request]) -> Harness<'a> {
        let n = scen.ranks;
        assert!(scen.prefill_ranks < n, "need at least one non-prefill rank");
        assert_eq!(scen.sched.page_tokens, PAGE_TOKENS, "page size mismatch");
        let speeds = if scen.speeds.is_empty() {
            vec![1.0; n]
        } else {
            assert_eq!(scen.speeds.len(), n, "one speed factor per rank");
            scen.speeds.clone()
        };
        if scen.timing == SimTiming::LockStep {
            assert_eq!(scen.prefill_ranks, 0, "lock-step cannot express handoffs");
            assert!(
                speeds.iter().all(|&s| s == 1.0),
                "lock-step cannot express per-rank speed factors — that is \
                 exactly why the straggler scenario is event-driven"
            );
        }
        let groups = trace
            .iter()
            .filter_map(|r| r.prefix_group)
            .max()
            .map(|g| g as usize + 1)
            .unwrap_or(0);
        let seqs = trace
            .iter()
            .map(|r| SimSeq {
                prompt: r.prompt_tokens,
                out: r.max_new_tokens,
                arrival: r.arrival_s,
                long: r.long_prompt,
                group: r.prefix_group,
                prefix_tokens: r.prefix_tokens,
                cached: 0,
                prefilled: 0,
                generated: 0,
                spilled: false,
                adopted: 0,
                transferred: 0,
                first_token: None,
                last_token: None,
            })
            .collect();
        let ranks = (0..n)
            .map(|_| SimRank {
                waiting: Vec::new(),
                running: Vec::new(),
                free: scen.capacity_pages,
                shared: vec![0; groups],
                t: 0.0,
            })
            .collect();
        Harness {
            scen,
            sched: Scheduler::new(scen.sched),
            prefill_sched: Scheduler::new(scen.prefill_sched.unwrap_or(scen.sched)),
            speeds,
            page: scen.sched.page_tokens,
            seqs,
            ranks,
            in_flight: Vec::new(),
            stats: SimStats { routed: vec![0; n], ..SimStats::default() },
            itl: Vec::new(),
            pending_emits: Vec::new(),
        }
    }

    /// One generated token for `sid`; event timing stamps it at `t`,
    /// lock-step passes None and the run loop stamps at the round barrier.
    fn emit(&mut self, sid: usize, t: Option<f64>) {
        self.stats.gen_tokens += 1;
        let Some(t) = t else {
            self.pending_emits.push(sid);
            return;
        };
        let s = &mut self.seqs[sid];
        if let Some(last) = s.last_token {
            self.itl.push(t - last);
        }
        s.last_token = Some(t);
    }

    fn private_pages(&self, sid: usize) -> usize {
        let s = &self.seqs[sid];
        pages_for(s.cached, self.page) - s.adopted - s.transferred
    }

    /// Published pages of `sid`'s group usable by a fresh admission (the
    /// adopt limit: ≥1 prompt token always left to prefill).
    fn hit_pages(&self, rank: usize, sid: usize) -> usize {
        let s = &self.seqs[sid];
        match s.group {
            Some(g) => self.ranks[rank].shared[g as usize].min((s.prompt - 1) / self.page),
            None => 0,
        }
    }

    fn colocated_loads(&self, sid: usize) -> Vec<RankLoad> {
        let s = &self.seqs[sid];
        let needed = pages_for(s.prompt + s.out, self.page);
        (0..self.ranks.len())
            .map(|ri| {
                let r = &self.ranks[ri];
                let queued: usize = r
                    .waiting
                    .iter()
                    .map(|&w| self.seqs[w].prompt + self.seqs[w].out)
                    .sum();
                let remaining: usize = r
                    .running
                    .iter()
                    .map(|&x| self.seqs[x].out - self.seqs[x].generated)
                    .sum();
                RankLoad {
                    tokens: queued + remaining,
                    free_pages: r.free,
                    pages_needed: needed,
                    prefix_hit_tokens: self.hit_pages(ri, sid) * self.page,
                    evictable_pages: 0,
                }
            })
            .collect()
    }

    fn route(&mut self, sid: usize) {
        let rank = match self.scen.routing {
            SimRoute::Single => 0,
            SimRoute::Disagg => {
                // least-loaded prefill rank; a prefill rank holds just the
                // prompt's pages (the KV migrates at handoff)
                let needed = pages_for(self.seqs[sid].prompt, self.page);
                let loads: Vec<RankLoad> = (0..self.scen.prefill_ranks)
                    .map(|ri| {
                        let r = &self.ranks[ri];
                        let queued: usize = r
                            .waiting
                            .iter()
                            .map(|&w| self.seqs[w].prompt + self.seqs[w].out)
                            .sum();
                        let remaining: usize = r
                            .running
                            .iter()
                            .map(|&x| self.seqs[x].out - self.seqs[x].generated)
                            .sum();
                        RankLoad {
                            tokens: queued + remaining,
                            free_pages: r.free,
                            pages_needed: needed,
                            prefix_hit_tokens: 0,
                            evictable_pages: 0,
                        }
                    })
                    .collect();
                pick_rank(&loads)
            }
            SimRoute::PrefixAffinity => {
                pick_rank_affinity(&self.colocated_loads(sid), self.page)
            }
            SimRoute::ShortestQueue => pick_rank(&self.colocated_loads(sid)),
        };
        self.stats.routed[rank] += 1;
        self.ranks[rank].waiting.push(sid);
    }

    /// Every ready transfer lands on the decode rank with headroom;
    /// slot-saturated ranks are marked infeasible by inflating their need.
    fn deliver(&mut self, clock: f64) -> bool {
        let mut delivered = false;
        let mut keep = Vec::new();
        let pending = std::mem::take(&mut self.in_flight);
        let prefill_ranks = self.scen.prefill_ranks;
        for (sid, ready) in pending {
            if ready > clock {
                keep.push((sid, ready));
                continue;
            }
            let s = &self.seqs[sid];
            let remaining = s.out - s.generated;
            let needed = pages_for(s.cached + remaining, self.page);
            let loads: Vec<RankLoad> = (prefill_ranks..self.ranks.len())
                .map(|ri| {
                    let r = &self.ranks[ri];
                    let tokens: usize = r
                        .running
                        .iter()
                        .chain(r.waiting.iter())
                        .map(|&x| self.seqs[x].out - self.seqs[x].generated)
                        .sum();
                    let open_slot = r.running.len() < self.scen.sched.max_running;
                    RankLoad {
                        tokens,
                        free_pages: r.free,
                        pages_needed: if open_slot {
                            needed
                        } else {
                            self.scen.capacity_pages + 1
                        },
                        prefix_hit_tokens: 0,
                        evictable_pages: 0,
                    }
                })
                .collect();
            match pick_handoff_rank(&loads) {
                Some(j) => {
                    let cached = self.seqs[sid].cached;
                    let r = &mut self.ranks[prefill_ranks + j];
                    r.free -= pages_for(cached, self.page);
                    r.running.push(sid);
                    self.stats.handoffs += 1;
                    delivered = true;
                }
                None => keep.push((sid, ready)),
            }
        }
        self.in_flight = keep;
        delivered
    }

    fn publish(&mut self, rank: usize, sid: usize) {
        let Some(g) = self.seqs[sid].group else { return };
        let done = self.seqs[sid].prefilled.min(self.seqs[sid].prefix_tokens) / self.page;
        let have = self.ranks[rank].shared[g as usize];
        if done > have {
            self.seqs[sid].transferred += done - have;
            self.ranks[rank].shared[g as usize] = done;
        }
    }

    fn decide(&self, ri: usize) -> Action {
        let r = &self.ranks[ri];
        let wview: Vec<WaitingSeq> = r
            .waiting
            .iter()
            .enumerate()
            .map(|(i, &sid)| WaitingSeq {
                idx: i,
                tokens: if self.seqs[sid].spilled {
                    self.seqs[sid].cached
                } else {
                    self.seqs[sid].prompt
                },
                spilled: self.seqs[sid].spilled,
            })
            .collect();
        let rview: Vec<RunningSeq> = r
            .running
            .iter()
            .enumerate()
            .map(|(i, &sid)| RunningSeq {
                idx: i,
                context: self.seqs[sid].cached,
                pending_prefill: self.seqs[sid].prompt - self.seqs[sid].prefilled,
            })
            .collect();
        let sched = if ri < self.scen.prefill_ranks { &self.prefill_sched } else { &self.sched };
        sched.decide(&wview, &rview, r.free)
    }

    /// Apply one scheduler action on rank `ri`; returns its (speed-scaled)
    /// cost. Event timing passes `t_start = Some(rank clock)` and stamps
    /// tokens at `t_start + cost`; lock-step passes None and the run loop
    /// stamps at the round barrier.
    fn apply(&mut self, ri: usize, action: Action, t_start: Option<f64>) -> f64 {
        let cost;
        match action {
            Action::Idle => cost = 0.0,
            Action::Prefill(idxs) => {
                let ids: Vec<usize> = idxs.iter().map(|&i| self.ranks[ri].waiting[i]).collect();
                self.ranks[ri].waiting.drain(..ids.len());
                let total: usize = ids.iter().map(|&sid| self.seqs[sid].prompt).sum();
                cost = self.scen.cost.prefill(total) * self.speeds[ri];
                self.stats.prefill_tokens += total as u64;
                let t_emit = t_start.map(|t| t + cost);
                for sid in ids {
                    let prompt = self.seqs[sid].prompt;
                    self.ranks[ri].free -= pages_for(prompt, self.page);
                    let s = &mut self.seqs[sid];
                    s.cached = prompt;
                    s.prefilled = prompt;
                    self.publish(ri, sid);
                    let s = &mut self.seqs[sid];
                    s.generated = 1;
                    if t_emit.is_some() {
                        s.first_token = t_emit;
                    }
                    self.emit(sid, t_emit);
                    if self.seqs[sid].generated >= self.seqs[sid].out {
                        let freed = self.private_pages(sid);
                        self.ranks[ri].free += freed;
                    } else {
                        self.ranks[ri].running.push(sid);
                    }
                }
            }
            Action::Handoff(idx) => {
                // serialize + free this rank's pages; the wire block rides
                // the link (unscaled: the link's time, not the rank's)
                // overlapped with the rank's next step
                let t_start = t_start.expect("handoffs only exist under event timing");
                let sid = self.ranks[ri].running.remove(idx);
                let freed = self.private_pages(sid);
                self.ranks[ri].free += freed;
                let s = &mut self.seqs[sid];
                s.adopted = 0;
                s.transferred = 0;
                let cached = s.cached;
                let (fp8, bf16) = self.scen.cost.wire_bytes(cached);
                self.stats.wire_fp8_bytes += fp8;
                self.stats.wire_bf16_bytes += bf16;
                let transfer = self.scen.cost.handoff(cached);
                self.in_flight.push((sid, t_start + transfer));
                cost = 0.0;
            }
            Action::Decode(idxs) => {
                let ids: Vec<usize> = idxs.iter().map(|&i| self.ranks[ri].running[i]).collect();
                let ctx = ids.iter().map(|&sid| self.seqs[sid].cached).max().unwrap() + 1;
                cost = self.scen.cost.decode(ids.len(), ctx) * self.speeds[ri];
                self.stats.decode_steps += 1;
                self.stats.decode_batch_sum += ids.len() as u64;
                let t_emit = t_start.map(|t| t + cost);
                let mut done = Vec::new();
                for &sid in &ids {
                    let s = &mut self.seqs[sid];
                    if s.cached % self.page == 0 {
                        self.ranks[ri].free -= 1;
                    }
                    let s = &mut self.seqs[sid];
                    s.cached += 1;
                    s.generated += 1;
                    self.emit(sid, t_emit);
                    if self.seqs[sid].generated >= self.seqs[sid].out {
                        done.push(sid);
                    }
                }
                for sid in done {
                    let freed = self.private_pages(sid);
                    self.ranks[ri].free += freed;
                    self.ranks[ri].running.retain(|&x| x != sid);
                }
            }
            Action::Mixed { prefill_chunks, decode_idxs } => {
                // admissions are a FCFS prefix of `waiting`; chunk-list
                // order is service order (SRPT), idx is the waiting position
                let n_admit = prefill_chunks.iter().filter(|c| c.from_waiting).count();
                let admitted: Vec<usize> = self.ranks[ri].waiting.drain(..n_admit).collect();
                // admission adopts the rank's published prefix pages
                // (shared, no allocation) — mirrors PagedKvCache::adopt_prefix
                for &sid in &admitted {
                    let hit = self.hit_pages(ri, sid);
                    if hit > 0 {
                        let s = &mut self.seqs[sid];
                        s.adopted = hit;
                        s.cached = hit * self.page;
                        s.prefilled = hit * self.page;
                        self.stats.prefix_hit_tokens += (hit * self.page) as u64;
                    }
                }
                let chunk_plan: Vec<(usize, usize)> = prefill_chunks
                    .iter()
                    .map(|c| {
                        let sid = if c.from_waiting {
                            admitted[c.idx]
                        } else {
                            self.ranks[ri].running[c.idx]
                        };
                        let s = &self.seqs[sid];
                        (sid, c.tokens.min(s.prompt - s.prefilled))
                    })
                    .collect();
                self.ranks[ri].running.extend(&admitted);
                let decode_ids: Vec<usize> =
                    decode_idxs.iter().map(|&i| self.ranks[ri].running[i]).collect();
                let total_chunk: usize = chunk_plan.iter().map(|&(_, t)| t).sum();
                let dctx = decode_ids
                    .iter()
                    .map(|&sid| self.seqs[sid].cached)
                    .max()
                    .map(|c| c + 1)
                    .unwrap_or(0);
                let cctx = chunk_plan
                    .iter()
                    .map(|&(sid, t)| self.seqs[sid].cached + t)
                    .max()
                    .unwrap_or(0);
                cost = self.scen.cost.mixed(decode_ids.len(), dctx, total_chunk, cctx)
                    * self.speeds[ri];
                if !decode_ids.is_empty() {
                    self.stats.decode_steps += 1;
                    self.stats.decode_batch_sum += decode_ids.len() as u64;
                }
                let t_emit = t_start.map(|t| t + cost);
                let mut done = Vec::new();
                for &(sid, take) in &chunk_plan {
                    let s = &self.seqs[sid];
                    let need =
                        pages_for(s.cached + take, self.page) - pages_for(s.cached, self.page);
                    self.ranks[ri].free -= need;
                    let s = &mut self.seqs[sid];
                    s.cached += take;
                    s.prefilled += take;
                    self.stats.chunk_tokens += take as u64;
                    self.stats.prefill_tokens += take as u64;
                    self.publish(ri, sid);
                    let s = &mut self.seqs[sid];
                    if s.prefilled == s.prompt {
                        s.generated = 1;
                        if t_emit.is_some() {
                            s.first_token = t_emit;
                        }
                        self.emit(sid, t_emit);
                        if self.seqs[sid].generated >= self.seqs[sid].out {
                            done.push(sid);
                        }
                    }
                }
                for &sid in &decode_ids {
                    let s = &mut self.seqs[sid];
                    if s.cached % self.page == 0 {
                        self.ranks[ri].free -= 1;
                    }
                    let s = &mut self.seqs[sid];
                    s.cached += 1;
                    s.generated += 1;
                    self.emit(sid, t_emit);
                    if self.seqs[sid].generated >= self.seqs[sid].out {
                        done.push(sid);
                    }
                }
                for sid in done {
                    let freed = self.private_pages(sid);
                    self.ranks[ri].free += freed;
                    self.ranks[ri].running.retain(|&x| x != sid);
                }
            }
            Action::Resume(_) => {
                let sid = self.ranks[ri].waiting.remove(0);
                let cached = self.seqs[sid].cached;
                cost = self.scen.cost.spill(cached) * self.speeds[ri];
                self.ranks[ri].free -= pages_for(cached, self.page);
                let s = &mut self.seqs[sid];
                s.spilled = false;
                s.adopted = 0;
                s.transferred = 0;
                self.stats.restores += 1;
                self.ranks[ri].running.push(sid);
            }
            Action::Preempt(idx) => {
                let sid = self.ranks[ri].running.remove(idx);
                let cached = self.seqs[sid].cached;
                cost = self.scen.cost.spill(cached) * self.speeds[ri];
                let freed = self.private_pages(sid);
                self.ranks[ri].free += freed;
                // the spill snapshot privatizes adopted pages (exactness
                // over dedup): the restore reallocates every page
                let s = &mut self.seqs[sid];
                s.adopted = 0;
                s.transferred = 0;
                s.spilled = true;
                self.stats.spills += 1;
                self.ranks[ri].waiting.insert(0, sid);
            }
        }
        cost
    }

    /// Name the most-loaded stuck rank for a deadlock diagnostic.
    fn stuck_report(&self) -> String {
        let worst = (0..self.ranks.len())
            .filter(|&ri| self.rank_busy(ri))
            .max_by_key(|&ri| self.ranks[ri].waiting.len() + self.ranks[ri].running.len())
            .unwrap_or(0);
        let r = &self.ranks[worst];
        format!(
            "rank {worst} stuck with {} waiting + {} running and {} free pages",
            r.waiting.len(),
            r.running.len(),
            r.free
        )
    }

    pub(super) fn run(mut self, trace: &[Request]) -> SimResult {
        match self.scen.timing {
            SimTiming::LockStep => self.run_lockstep(trace),
            SimTiming::EventDriven => self.run_event(trace),
        }
        self.summarize(trace)
    }

    fn rank_busy(&self, ri: usize) -> bool {
        !self.ranks[ri].waiting.is_empty() || !self.ranks[ri].running.is_empty()
    }

    fn any_busy(&self) -> bool {
        (0..self.ranks.len()).any(|ri| self.rank_busy(ri))
    }

    fn sample_pages(&mut self) {
        let used: usize = self.ranks.iter().map(|r| self.scen.capacity_pages - r.free).sum();
        self.stats.peak_pages = self.stats.peak_pages.max(used);
    }

    fn run_lockstep(&mut self, trace: &[Request]) {
        let mut clock = 0.0f64;
        let mut next_arrival = 0usize;
        let mut rounds = 0usize;
        while next_arrival < trace.len() || self.any_busy() {
            rounds += 1;
            assert!(rounds <= 500_000, "sim runaway");
            while next_arrival < trace.len() && trace[next_arrival].arrival_s <= clock {
                self.route(next_arrival);
                next_arrival += 1;
            }

            // one lock-step round: every rank takes one scheduler action off
            // the pre-round state; the round costs the slowest rank's step
            let decisions: Vec<(usize, Action)> = (0..self.ranks.len())
                .filter(|&ri| self.rank_busy(ri))
                .map(|ri| (ri, self.decide(ri)))
                .filter(|(_, a)| *a != Action::Idle)
                .collect();
            if decisions.is_empty() {
                if next_arrival < trace.len() {
                    clock = clock.max(trace[next_arrival].arrival_s);
                    continue;
                }
                panic!("lockstep deadlock: {}", self.stuck_report());
            }
            // costs depend only on each rank's own pre-apply state, so
            // apply per rank, then charge the round's max (lock-step barrier)
            let mut round_cost = 0.0f64;
            for (ri, action) in decisions {
                round_cost = round_cost.max(self.apply(ri, action, None));
            }
            clock += round_cost;
            // tokens produced this round are stamped at the round boundary
            let emitted = std::mem::take(&mut self.pending_emits);
            for sid in emitted {
                let s = &mut self.seqs[sid];
                if let Some(last) = s.last_token {
                    self.itl.push(clock - last);
                }
                s.last_token = Some(clock);
            }
            for s in self.seqs.iter_mut() {
                if s.first_token.is_none() && s.generated > 0 {
                    s.first_token = Some(clock);
                }
            }
            self.stats.rounds += 1;
            self.sample_pages();
        }
        // lock-step wall time is the global clock; park it on rank 0 so
        // summarize()'s max-over-clocks sees it
        self.ranks[0].t = clock;
    }

    fn run_event(&mut self, trace: &[Request]) {
        let mut clock = 0.0f64;
        let mut next_arrival = 0usize;
        let mut iters = 0usize;
        while next_arrival < trace.len() || !self.in_flight.is_empty() || self.any_busy() {
            iters += 1;
            assert!(iters <= 2_000_000, "sim runaway");
            // the next instant anything can happen, popped off the event
            // loop in its documented (time, rank, seq) order: a busy rank's
            // local clock, the next arrival, or an in-flight transfer's
            // ready-time
            let mut cands: EventLoop<()> = EventLoop::new();
            let n = self.ranks.len();
            for ri in 0..n {
                if self.rank_busy(ri) {
                    cands.push(self.ranks[ri].t, ri, ());
                }
            }
            if next_arrival < trace.len() {
                cands.push(trace[next_arrival].arrival_s, n, ());
            }
            for &(_, ready) in &self.in_flight {
                cands.push(ready, n + 1, ());
            }
            let mut later = f64::INFINITY;
            {
                let min_cand = cands.peek_time().expect("busy sim has a next event");
                clock = clock.max(min_cand);
                while let Some(e) = cands.pop() {
                    if e.time > clock {
                        later = later.min(e.time);
                    }
                }
            }

            let mut progressed = false;
            while next_arrival < trace.len() && trace[next_arrival].arrival_s <= clock {
                self.route(next_arrival);
                next_arrival += 1;
                progressed = true;
            }
            if self.scen.prefill_ranks > 0 && self.deliver(clock) {
                progressed = true;
            }

            for ri in 0..n {
                if self.ranks[ri].t > clock {
                    continue;
                }
                // handoffs cost the rank nothing (serialize + async send):
                // a prefill rank drains every completed prefill and still
                // takes its real action at the same instant
                let action = loop {
                    if !self.rank_busy(ri) {
                        break Action::Idle;
                    }
                    let action = self.decide(ri);
                    if !matches!(action, Action::Handoff(_)) {
                        break action;
                    }
                    let t = self.ranks[ri].t;
                    self.apply(ri, action, Some(t));
                    progressed = true;
                };
                if action == Action::Idle {
                    continue;
                }
                let t = self.ranks[ri].t;
                let cost = self.apply(ri, action, Some(t));
                self.ranks[ri].t += cost;
                self.stats.steps += 1;
                progressed = true;
            }

            if !progressed {
                assert!(later.is_finite(), "event-loop deadlock: {}", self.stuck_report());
                clock = later;
                continue;
            }
            self.sample_pages();
        }
        // the final global clock is covered by summarize()'s max over rank
        // clocks: the last progressing action always ran at a rank clock
        // that `clock` had caught up to
        self.ranks[0].t = self.ranks[0].t.max(clock);
    }

    fn summarize(self, trace: &[Request]) -> SimResult {
        let mut wall = 0.0f64;
        for r in &self.ranks {
            wall = wall.max(r.t);
        }
        let mut ttft = Stats::new();
        let mut ttft_short = Stats::new();
        for s in &self.seqs {
            let t = s.first_token.expect("all sequences finished") - s.arrival;
            ttft.push(t);
            if !s.long {
                ttft_short.push(t);
            }
        }
        let mut itl = Stats::new();
        for &x in &self.itl {
            itl.push(x);
        }
        let st = self.stats;
        SimResult {
            ranks: self.scen.ranks,
            prefill_ranks: self.scen.prefill_ranks,
            decode_ranks: if self.scen.prefill_ranks == 0 {
                self.scen.ranks
            } else {
                self.scen.ranks - self.scen.prefill_ranks
            },
            requests: trace.len(),
            gen_tokens: st.gen_tokens,
            wall_s: wall,
            ttft,
            ttft_short,
            itl,
            peak_pages: st.peak_pages,
            prefill_tokens: st.prefill_tokens,
            chunk_tokens: st.chunk_tokens,
            prefix_hit_tokens: st.prefix_hit_tokens,
            decode_steps: st.decode_steps,
            decode_batch_sum: st.decode_batch_sum,
            rounds: st.rounds,
            steps: st.steps,
            spills: st.spills,
            restores: st.restores,
            handoffs: st.handoffs,
            wire_fp8_bytes: st.wire_fp8_bytes,
            wire_bf16_bytes: st.wire_bf16_bytes,
            routed: st.routed,
        }
    }
}
