//! Deterministic virtual-time event loop: a min-heap of `(time, rank,
//! event)` entries with a **documented total order** — earliest time first,
//! ties broken by rank id, then by push sequence id. Two runs that push the
//! same events pop them in the same order, bit for bit; that determinism is
//! what lets the event-driven drives (`simulate::harness` event timing,
//! `cluster::ClusterServer::run_until`) pin themselves byte-for-byte
//! against the legacy lock-step loops in the uniform-cost degenerate case.

use std::cmp::Ordering;
use std::collections::BinaryHeap;

/// One scheduled event: a wake-up at virtual `time` for `rank`, carrying a
/// caller-defined payload. `seq` is the push sequence id (assigned by the
/// loop) — the final tie-break, so same-(time, rank) events pop FIFO.
#[derive(Clone, Copy, Debug)]
pub struct Event<T> {
    /// virtual seconds (finite; asserted on push)
    pub time: f64,
    /// rank id — the second tie-break key
    pub rank: usize,
    /// push sequence id — the third tie-break key (FIFO among exact ties)
    pub seq: u64,
    /// caller payload
    pub payload: T,
}

/// Heap adapter: `BinaryHeap` is a max-heap, so the ordering is reversed —
/// the SMALLEST `(time, rank, seq)` key is the heap maximum.
struct HeapEntry<T>(Event<T>);

impl<T> HeapEntry<T> {
    fn key_cmp(&self, other: &Self) -> Ordering {
        self.0
            .time
            .total_cmp(&other.0.time)
            .then_with(|| self.0.rank.cmp(&other.0.rank))
            .then_with(|| self.0.seq.cmp(&other.0.seq))
    }
}

impl<T> PartialEq for HeapEntry<T> {
    fn eq(&self, other: &Self) -> bool {
        self.key_cmp(other) == Ordering::Equal
    }
}

impl<T> Eq for HeapEntry<T> {}

impl<T> PartialOrd for HeapEntry<T> {
    fn partial_cmp(&self, other: &Self) -> Option<Ordering> {
        Some(self.cmp(other))
    }
}

impl<T> Ord for HeapEntry<T> {
    fn cmp(&self, other: &Self) -> Ordering {
        other.key_cmp(self) // reversed: min-key pops first
    }
}

/// The event loop: push wake-ups, pop them in `(time, rank, seq)` order.
pub struct EventLoop<T> {
    heap: BinaryHeap<HeapEntry<T>>,
    next_seq: u64,
}

impl<T> EventLoop<T> {
    pub fn new() -> EventLoop<T> {
        EventLoop { heap: BinaryHeap::new(), next_seq: 0 }
    }

    /// Schedule `payload` for `rank` at virtual `time` (must be finite).
    pub fn push(&mut self, time: f64, rank: usize, payload: T) {
        assert!(time.is_finite(), "event time must be finite, got {time}");
        let seq = self.next_seq;
        self.next_seq += 1;
        self.heap.push(HeapEntry(Event { time, rank, seq, payload }));
    }

    /// Remove and return the earliest event (ties: lowest rank, then FIFO).
    pub fn pop(&mut self) -> Option<Event<T>> {
        self.heap.pop().map(|e| e.0)
    }

    /// The earliest event (by the documented total order) without removing
    /// it, if any is pending.
    pub fn peek(&self) -> Option<&Event<T>> {
        self.heap.peek().map(|e| &e.0)
    }

    /// The earliest scheduled time, if any event is pending.
    pub fn peek_time(&self) -> Option<f64> {
        self.heap.peek().map(|e| e.0.time)
    }

    /// Remove and return EVERY event whose time equals the earliest time
    /// (bitwise `==`), in `(rank, seq)` order — one synchronized "batch".
    /// With uniform per-step costs all ranks' wake-ups carry bit-identical
    /// times, so a batch is exactly one legacy lock-step round.
    pub fn pop_batch(&mut self) -> Vec<Event<T>> {
        let mut batch = Vec::new();
        let Some(first) = self.pop() else {
            return batch;
        };
        let t = first.time;
        batch.push(first);
        while self.peek_time() == Some(t) {
            batch.push(self.pop().unwrap());
        }
        batch
    }

    pub fn len(&self) -> usize {
        self.heap.len()
    }

    pub fn is_empty(&self) -> bool {
        self.heap.is_empty()
    }
}

impl<T> Default for EventLoop<T> {
    fn default() -> Self {
        EventLoop::new()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn pops_in_time_order() {
        let mut ev = EventLoop::new();
        ev.push(3.0, 0, "c");
        ev.push(1.0, 0, "a");
        ev.push(2.0, 0, "b");
        let order: Vec<&str> = std::iter::from_fn(|| ev.pop().map(|e| e.payload)).collect();
        assert_eq!(order, vec!["a", "b", "c"]);
    }

    #[test]
    fn ties_break_by_rank_then_push_order() {
        let mut ev = EventLoop::new();
        ev.push(1.0, 2, "r2-first");
        ev.push(1.0, 0, "r0");
        ev.push(1.0, 2, "r2-second");
        ev.push(1.0, 1, "r1");
        let order: Vec<&str> = std::iter::from_fn(|| ev.pop().map(|e| e.payload)).collect();
        assert_eq!(order, vec!["r0", "r1", "r2-first", "r2-second"]);
    }

    #[test]
    fn batch_extracts_one_synchronized_round() {
        let mut ev = EventLoop::new();
        ev.push(1.0, 1, ());
        ev.push(1.0, 0, ());
        ev.push(2.0, 0, ());
        let batch = ev.pop_batch();
        assert_eq!(batch.len(), 2);
        assert_eq!(batch[0].rank, 0);
        assert_eq!(batch[1].rank, 1);
        assert_eq!(ev.len(), 1);
        assert_eq!(ev.peek_time(), Some(2.0));
        assert_eq!(ev.pop_batch().len(), 1);
        assert!(ev.pop_batch().is_empty());
    }

    #[test]
    #[should_panic(expected = "finite")]
    fn rejects_non_finite_times() {
        EventLoop::new().push(f64::INFINITY, 0, ());
    }

    #[test]
    fn peek_matches_next_pop() {
        let mut ev = EventLoop::new();
        ev.push(2.0, 1, "late");
        ev.push(1.0, 3, "early");
        let (t, rank) = {
            let e = ev.peek().unwrap();
            (e.time, e.rank)
        };
        assert_eq!((t, rank), (1.0, 3));
        let popped = ev.pop().unwrap();
        assert_eq!((popped.time, popped.rank, popped.payload), (1.0, 3, "early"));
        assert_eq!(ev.peek().unwrap().payload, "late");
    }

    #[test]
    fn ordering_stable_under_membership_churn() {
        // ranks join (new higher ids pushed mid-drain) and fail (their
        // pending wake-ups popped and discarded) while the loop drains;
        // popped times must stay globally non-decreasing and two identical
        // churn schedules must produce the identical pop sequence
        let drive = || {
            let mut ev = EventLoop::new();
            for ri in 0..3usize {
                ev.push(0.5 + ri as f64 * 0.25, ri, ri);
            }
            let mut order = Vec::new();
            let mut spawned = 3usize;
            while let Some(e) = ev.pop() {
                if e.payload == 1 && e.time < 2.0 {
                    continue; // rank 1 failed: drop its wake-up on the floor
                }
                order.push((e.time.to_bits(), e.rank, e.seq));
                if spawned < 8 {
                    // a join schedules the new rank's first wake-up later
                    // than everything already popped
                    ev.push(e.time + 0.75, spawned, spawned);
                    spawned += 1;
                }
            }
            for w in order.windows(2) {
                assert!(f64::from_bits(w[1].0) >= f64::from_bits(w[0].0));
            }
            order
        };
        assert_eq!(drive(), drive());
    }

    #[test]
    fn deterministic_across_runs() {
        let drive = || {
            let mut ev = EventLoop::new();
            for i in 0..32usize {
                ev.push((i % 5) as f64 * 0.125, i % 3, i);
            }
            let mut order = Vec::new();
            while let Some(e) = ev.pop() {
                order.push((e.time.to_bits(), e.rank, e.seq, e.payload));
            }
            order
        };
        assert_eq!(drive(), drive());
    }
}
