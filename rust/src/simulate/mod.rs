//! Event-driven virtual-time serving simulation: ONE engine behind every
//! serve bench, its Python port, and the cluster layer's virtual drive.
//!
//! * [`clock`] — the deterministic [`clock::EventLoop`]: a min-heap of
//!   `(time, rank, event)` with the documented tie-break (time, then rank
//!   id, then push sequence id).
//! * [`harness`] — trace replay, arrival injection, per-rank queue/page
//!   state, routing + scheduling through the REAL coordinator policies,
//!   TTFT/ITL/throughput recorders backed by [`crate::util::stats::Stats`],
//!   in lock-step or event-driven timing.
//! * [`scenario`] — each serve bench as a thin [`scenario::Scenario`]
//!   config plus its exact baseline field selection.
//!
//! `python/tests/serve_port_common.py` mirrors this module line for line —
//! the committed BENCH_*.json baselines are generated there (this repo
//! grows in containers without a Rust toolchain), so any semantic edit
//! here must be mirrored and the baselines regenerated in the same PR
//! (`ci/port_drift.py` pins the pairing).

pub mod clock;
pub mod harness;
pub mod scenario;

pub use clock::{Event, EventLoop};
pub use harness::{CostModel, MembershipEvent, SimResult};
pub use scenario::{
    AutoscaleConfig, ElasticConfig, Scenario, SimRoute, SimTiming, SpecSim, TieredSim, NODE_GPUS,
};
