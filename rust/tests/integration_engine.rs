//! Integration: the model engine over the execution-backend abstraction.
//!
//! Runs against the offline `SimBackend` by default (no artifacts needed);
//! with `--features pjrt` and compiled artifacts the same tests exercise the
//! PJRT path. Exercises the full composition: prefill a prompt, append the
//! quantized entries to the paged cache, decode tokens autoregressively,
//! and check FP8-vs-BF16 pipeline parity on identical inputs.

use snapmla::kvcache::{CacheMode, PagedKvCache};
use snapmla::runtime::ModelEngine;
use snapmla::util::rng::argmax;
use std::path::{Path, PathBuf};

fn artifacts_dir() -> PathBuf {
    Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts")
}

fn engine(mode: CacheMode) -> (ModelEngine, PagedKvCache) {
    let engine = ModelEngine::auto(&artifacts_dir(), mode).expect("engine load");
    let cache = PagedKvCache::new(engine.cache_config(256));
    (engine, cache)
}

fn prompt(seed: u64, len: usize) -> Vec<i32> {
    // a repeat-family prompt in the synthetic token language
    let motif = [70 + seed as i32 % 100, 90, 130, 200];
    let mut p = vec![1]; // BOS
    for i in 0..len - 1 {
        p.push(motif[i % motif.len()]);
    }
    p
}

#[test]
fn prefill_then_decode_roundtrip_fp8() {
    let (mut eng, mut cache) = engine(CacheMode::Fp8);
    cache.register(1);
    let p = prompt(0, 24);
    let out = eng.prefill(&mut cache, &[(1, p.clone())]).unwrap();
    assert_eq!(out.logits.len(), 1);
    assert_eq!(out.logits[0].len(), eng.manifest.model.vocab);
    assert!(out.logits[0].iter().all(|x| x.is_finite()));
    assert_eq!(cache.tokens_of(1), 24);

    // decode 8 tokens greedily
    let mut tok = argmax(&out.logits[0]) as i32;
    for _ in 0..8 {
        let r = eng.decode(&mut cache, &[(1, tok)]).unwrap();
        assert!(r.logits[0].iter().all(|x| x.is_finite()));
        tok = argmax(&r.logits[0]) as i32;
    }
    assert_eq!(cache.tokens_of(1), 32);
    assert!(eng.stats.decode_steps == 8 && eng.stats.prefill_calls == 1);
}

#[test]
fn model_prefers_motif_tokens() {
    // The sim model's constructed induction circuit (and, with artifacts,
    // the build-time-trained model) must put far more probability mass on
    // the repeated motif's tokens than the vocabulary average.
    let (mut eng, mut cache) = engine(CacheMode::Fp8);
    cache.register(1);
    let motif = [80i32, 120, 77];
    let mut p = vec![1];
    for i in 0..23 {
        p.push(motif[i % 3]);
    }
    let out = eng.prefill(&mut cache, &[(1, p)]).unwrap();
    let logits = &out.logits[0];
    let m = logits.iter().cloned().fold(f32::NEG_INFINITY, f32::max);
    let exps: Vec<f64> = logits.iter().map(|&x| ((x - m) as f64).exp()).collect();
    let z: f64 = exps.iter().sum();
    let p_motif: f64 = motif.iter().map(|&t| exps[t as usize] / z).sum();
    let uniform = 3.0 / logits.len() as f64;
    assert!(
        p_motif > 10.0 * uniform,
        "motif tokens should be strongly preferred: p={p_motif:.4} vs uniform {uniform:.5}"
    );
}

#[test]
fn greedy_decode_continues_the_motif() {
    // Stronger than motif preference: the induction circuit must continue
    // the motif exactly under greedy decoding through the FP8 pipeline.
    let (mut eng, mut cache) = engine(CacheMode::Fp8);
    cache.register(1);
    let motif = [70i32, 105, 230];
    let plen = 24usize;
    let p = {
        let mut p = vec![1];
        for i in 0..plen - 1 {
            p.push(motif[i % 3]);
        }
        p
    };
    let out = eng.prefill(&mut cache, &[(1, p)]).unwrap();
    let mut tok = argmax(&out.logits[0]) as i32;
    let mut generated = vec![tok];
    for _ in 0..8 {
        let r = eng.decode(&mut cache, &[(1, tok)]).unwrap();
        tok = argmax(&r.logits[0]) as i32;
        generated.push(tok);
    }
    let expected: Vec<i32> = (0..9).map(|i| motif[(plen - 1 + i) % 3]).collect();
    let hits = generated.iter().zip(&expected).filter(|(a, b)| a == b).count();
    assert!(hits >= 8, "motif continuation {generated:?} vs expected {expected:?}");
}

#[test]
fn batched_decode_isolated_sequences() {
    let (mut eng, mut cache) = engine(CacheMode::Fp8);
    // two sequences with different prompts, decoded (a) in one batch and
    // (b) separately — logits must agree and sequences must not interfere
    for id in [1, 2, 11, 12] {
        cache.register(id);
    }
    let p1 = prompt(1, 16);
    let p2 = prompt(2, 20);
    eng.prefill(&mut cache, &[(1, p1.clone()), (2, p2.clone())]).unwrap();
    eng.prefill(&mut cache, &[(11, p1), (12, p2)]).unwrap();

    let batched = eng.decode(&mut cache, &[(1, 70), (2, 71)]).unwrap();
    let solo1 = eng.decode(&mut cache, &[(11, 70)]).unwrap();
    let solo2 = eng.decode(&mut cache, &[(12, 71)]).unwrap();
    for (a, b) in [(&batched.logits[0], &solo1.logits[0]), (&batched.logits[1], &solo2.logits[0])] {
        let max_diff = a
            .iter()
            .zip(b.iter())
            .map(|(x, y)| (x - y).abs())
            .fold(0.0f32, f32::max);
        // identical math up to bucket padding → tight tolerance
        assert!(max_diff < 2e-3, "batched vs solo logits differ: {max_diff}");
    }
}

#[test]
fn fp8_bf16_parity_on_greedy_decode() {
    // Table-1 flavour at integration level: same prompt, both pipelines,
    // greedy decode — the sampled continuations should agree at the start
    // and logits should correlate strongly.
    let (mut e8, mut c8) = engine(CacheMode::Fp8);
    let (mut e16, mut c16) = engine(CacheMode::Bf16);
    c8.register(1);
    c16.register(1);
    let p = prompt(3, 32);
    let o8 = e8.prefill(&mut c8, &[(1, p.clone())]).unwrap();
    let o16 = e16.prefill(&mut c16, &[(1, p)]).unwrap();
    assert_eq!(argmax(&o8.logits[0]), argmax(&o16.logits[0]));

    let mut t8 = argmax(&o8.logits[0]) as i32;
    let mut t16 = t8;
    let mut agree = 0;
    for _ in 0..12 {
        let r8 = e8.decode(&mut c8, &[(1, t8)]).unwrap();
        let r16 = e16.decode(&mut c16, &[(1, t16)]).unwrap();
        t8 = argmax(&r8.logits[0]) as i32;
        t16 = argmax(&r16.logits[0]) as i32;
        if t8 == t16 {
            agree += 1;
        }
    }
    assert!(agree >= 10, "greedy agreement too low: {agree}/12");
}

#[test]
fn cache_pressure_reported() {
    let (mut eng, _) = engine(CacheMode::Fp8);
    // tiny cache: 1 page = 64 tokens; a 65th token must fail cleanly
    let mut cache = PagedKvCache::new(eng.cache_config(1));
    cache.register(1);
    let p = prompt(4, 64);
    eng.prefill(&mut cache, &[(1, p)]).unwrap();
    assert!(!cache.can_append(1, 1));
    assert!(eng.decode(&mut cache, &[(1, 70)]).is_err());
}
