//! Integration: the event-driven virtual-time core reproduces the legacy
//! lock-step semantics byte-for-byte in the degenerate uniform-cost mode —
//! at BOTH layers of the refactor seam:
//!
//! * `cluster::ClusterServer` over real engines: the `run_until` virtual
//!   drive with uniform per-rank step costs vs the legacy `step_all` round
//!   loop — same per-request outputs, same `ServerMetrics`/
//!   `ClusterMetrics` counters, across seeded traces in all three serving
//!   scenarios (single-rank mixed, colocated DP with prefix affinity,
//!   disaggregated prefill/decode).
//! * `simulate::Scenario` (the perfmodel-costed bench harness): lock-step
//!   timing vs event-driven timing under `CostModel::Uniform` — identical
//!   recorders bit for bit.
//!
//! Plus the new failure contract: a wedged cluster returns a hard error
//! naming the stuck rank and its queue depth instead of relying on the
//! caller to notice a false `step_all`.

use snapmla::cluster::ClusterServer;
use snapmla::coordinator::scheduler::{SchedPolicy, SchedulerConfig, SpecConfig, TieredConfig};
use snapmla::coordinator::{RankHealth, RequestOutcome, RoutePolicy, ServeRequest, Server};
use snapmla::kvcache::CacheMode;
use snapmla::runtime::ModelEngine;
use snapmla::simulate::{CostModel, Scenario, SimResult, SimRoute, SimTiming};
use snapmla::workload::{TraceConfig, TraceGen};

// --- ClusterServer: run_until(uniform) == legacy step_all loop --------------

/// Prompt = [1] + shared 512-token motif + per-request divergent tail.
fn prefix_prompt(id: u64, prefix_tokens: usize, prompt_tokens: usize) -> Vec<i32> {
    let motif = [70, 91, 130];
    let mut p = vec![1];
    for i in 0..prefix_tokens {
        p.push(motif[i % 3]);
    }
    while p.len() < prompt_tokens {
        p.push(40 + (id as i32 * 7 + p.len() as i32) % 50);
    }
    p
}

fn req(id: u64, prompt: Vec<i32>, out: usize) -> ServeRequest {
    ServeRequest { id, prompt, max_new_tokens: out, temperature: 0.0, seed: id, ignore_eos: true }
}

/// The pre-refactor drive: lock-step rounds until drained.
fn run_legacy(cluster: &mut ClusterServer) -> Vec<RequestOutcome> {
    let t0 = std::time::Instant::now();
    while cluster.pending() > 0 {
        assert!(cluster.step_all().expect("step"), "legacy drive wedged");
    }
    cluster.router.drain_finished(t0.elapsed().as_secs_f64())
}

fn signature(outcomes: Vec<RequestOutcome>) -> Vec<(u64, Vec<i32>)> {
    let mut sig: Vec<(u64, Vec<i32>)> = outcomes.into_iter().map(|o| (o.id, o.generated)).collect();
    sig.sort_by_key(|&(id, _)| id);
    sig
}

/// Build two identically-configured clusters, submit the same requests,
/// drive one with the legacy lock-step loop and one with the uniform-cost
/// virtual drive, and require identical outputs + counters.
fn assert_drives_equivalent(
    make: impl Fn() -> ClusterServer,
    requests: impl Fn() -> Vec<ServeRequest>,
    label: &str,
) {
    let mut legacy = make();
    let mut virt = make();
    for r in requests() {
        legacy.submit(r);
    }
    for r in requests() {
        virt.submit(r);
    }
    let legacy_out = signature(run_legacy(&mut legacy));
    let virt_out = signature(virt.run_to_completion().expect("virtual drive"));
    assert_eq!(legacy_out, virt_out, "{label}: per-request outputs diverged");
    assert_eq!(legacy.counters(), virt.counters(), "{label}: counters diverged");
    assert!(virt.virtual_time() > 0.0, "{label}: virtual clock never advanced");
}

#[test]
fn uniform_cost_drive_matches_lockstep_single_rank() {
    // the serve_mixed shape: one colocated rank, a burst of short prompts
    assert_drives_equivalent(
        || ClusterServer::sim(1, 128, CacheMode::Fp8, RoutePolicy::ShortestQueue).unwrap(),
        || (0..6).map(|id| req(id, prefix_prompt(id, 0, 24 + 9 * id as usize), 6)).collect(),
        "single rank",
    );
}

#[test]
fn uniform_cost_drive_matches_lockstep_colocated_affinity() {
    // the serve_cluster shape: DP2 prefix-affinity over a shared prefix
    for policy in [RoutePolicy::PrefixAffinity, RoutePolicy::ShortestQueue] {
        assert_drives_equivalent(
            || ClusterServer::sim(2, 256, CacheMode::Fp8, policy).unwrap(),
            || (0..5).map(|id| req(id, prefix_prompt(id, 512, 545), 4)).collect(),
            "colocated DP",
        );
    }
}

#[test]
fn uniform_cost_drive_matches_lockstep_disaggregated() {
    // the serve_disagg shape: one prefill rank migrating into two decode
    // ranks over the FP8 wire
    assert_drives_equivalent(
        || ClusterServer::sim_disagg(1, 2, 256, CacheMode::Fp8).unwrap(),
        || (0..5).map(|id| req(id, prefix_prompt(id, 0, 96 + 32 * id as usize), 8)).collect(),
        "disaggregated",
    );
}

#[test]
fn heterogeneous_costs_change_timing_but_never_outputs() {
    // a 3x-slow rank reorders the virtual schedule; token streams are
    // placement- and order-invariant so outputs must not move
    let make = || ClusterServer::sim(2, 256, CacheMode::Fp8, RoutePolicy::ShortestQueue).unwrap();
    let reqs =
        || (0..6).map(|id| req(id, prefix_prompt(id, 0, 40 + 16 * id as usize), 6)).collect();
    let mut uniform = make();
    let mut skewed = make();
    for r in reqs() {
        uniform.submit(r);
    }
    for r in reqs() {
        skewed.submit(r);
    }
    let base = signature(uniform.run_to_completion().expect("uniform"));
    let skew = signature(skewed.run_virtual(&[3.0, 1.0]).expect("skewed"));
    assert_eq!(base, skew, "straggler cost skew changed generated tokens");
    assert!(skewed.virtual_time() > uniform.virtual_time());
}

#[test]
fn run_until_pauses_at_the_horizon_and_resumes() {
    let mut cluster =
        ClusterServer::sim(2, 256, CacheMode::Fp8, RoutePolicy::ShortestQueue).unwrap();
    for id in 0..4 {
        cluster.submit(req(id, prefix_prompt(id, 0, 64), 16));
    }
    let costs = [1.0, 1.0];
    let done = cluster.run_until(&costs, 3.0).expect("bounded drive");
    assert!(!done, "a 3-step horizon cannot drain 4 multi-step requests");
    assert!(cluster.pending() > 0);
    assert!(cluster.virtual_time() <= 4.0, "clock ran past the horizon + one step");
    let done = cluster.run_until(&costs, f64::INFINITY).expect("resume");
    assert!(done);
    assert_eq!(cluster.pending(), 0);
}

#[test]
fn stuck_cluster_names_the_wedged_rank_and_queue_depth() {
    // capacity of ONE page can never admit a 100-token prompt (2 pages):
    // the scheduler idles forever — the drive must say which rank and why
    let mut cluster = ClusterServer::sim(2, 1, CacheMode::Fp8, RoutePolicy::ShortestQueue)
        .expect("cluster");
    cluster.submit(req(0, prefix_prompt(0, 0, 100), 4));
    let err = cluster.run_to_completion().expect_err("a wedged cluster must error");
    let msg = err.to_string();
    assert!(msg.contains("rank 0"), "error names the stuck rank: {msg}");
    assert!(msg.contains("1 waiting"), "error names the queue depth: {msg}");
}

// --- ClusterServer: elastic membership ---------------------------------------

#[test]
fn failed_rank_migrates_live_kv_to_survivors() {
    // token streams are placement-invariant, so sequences recovered off a
    // failed rank must emit exactly the tokens a failure-free run emits
    let make = || ClusterServer::sim(3, 256, CacheMode::Fp8, RoutePolicy::ShortestQueue).unwrap();
    let reqs = || -> Vec<ServeRequest> {
        (0..6).map(|id| req(id, prefix_prompt(id, 0, 48 + 8 * id as usize), 12)).collect()
    };
    let mut base = make();
    for r in reqs() {
        base.submit(r);
    }
    let base_out = signature(base.run_to_completion().expect("baseline"));

    let costs = [1.0, 1.0, 1.0];
    let mut el = make();
    for r in reqs() {
        el.submit(r);
    }
    el.run_until(&costs, 4.0).expect("pre-failure drive");
    el.fail_rank(2, true).expect("failure with recovery");
    let out = signature(el.run_virtual(&costs).expect("post-failure drive"));
    assert_eq!(el.metrics.fails, 1);
    assert_eq!(el.metrics.dropped, 0, "recovery must not drop anything here");
    assert!(el.metrics.evacuated > 0, "rank 2 held live sequences at t=4");
    assert_eq!(el.metrics.recovered, el.metrics.evacuated);
    assert_eq!(out, base_out, "recovered sequences changed their tokens");
    assert_eq!(el.membership_log.len(), 1);

    // the no-migration fleet drops what recovery saves
    let mut nomig = make();
    for r in reqs() {
        nomig.submit(r);
    }
    nomig.run_until(&costs, 4.0).expect("pre-failure drive");
    nomig.fail_rank(2, false).expect("failure without recovery");
    let lost = signature(nomig.run_virtual(&costs).expect("post-failure drive"));
    assert_eq!(nomig.metrics.dropped as usize, el.metrics.evacuated as usize);
    assert_eq!(lost.len() + nomig.metrics.dropped as usize, base_out.len());
}

#[test]
fn drain_and_join_reshape_the_fleet() {
    let mut c = ClusterServer::sim(2, 256, CacheMode::Fp8, RoutePolicy::ShortestQueue).unwrap();
    for id in 0..4 {
        c.submit(req(id, prefix_prompt(id, 0, 40), 8));
    }
    c.drain_rank(1).expect("drain");
    // a draining rank receives no new admissions
    for id in 4..8 {
        assert_eq!(c.submit(req(id, prefix_prompt(id, 0, 40), 8)), 0);
    }
    assert!(c.run_until(&[1.0, 1.0], f64::INFINITY).expect("drive through the drain"));
    // the drained rank finished its queue and retired
    assert_eq!(c.router.health(1), RankHealth::Dead);

    let ri = c.join_rank(Server::new(ModelEngine::sim(CacheMode::Fp8).unwrap(), 256));
    assert_eq!(ri, 2);
    for id in 8..12 {
        c.submit(req(id, prefix_prompt(id, 0, 40), 8));
    }
    let out = c.run_virtual(&[1.0, 1.0, 1.0]).expect("post-join drive");
    assert_eq!(out.len(), 12, "every request across the reshapes completes");
    assert_eq!((c.metrics.drains, c.metrics.joins), (1, 1));
    assert!(c.metrics.routed[2] > 0, "the joined rank serves new work");
    let kinds: Vec<&str> = c.membership_log.iter().map(|(_, k, _, _)| k.as_str()).collect();
    assert_eq!(kinds, ["drain", "join"]);
}

// --- simulate harness: lock-step == event-driven under uniform costs --------

fn bench_sched(policy: SchedPolicy) -> SchedulerConfig {
    SchedulerConfig {
        max_decode_batch: 8,
        max_prefill_batch: 4,
        max_prefill_tokens: 4096,
        max_context: 8192,
        page_tokens: 64,
        prefill_chunk_tokens: 96,
        chunk_per_seq: 64,
        max_step_items: 12,
        max_running: 12,
        disagg_prefill: false,
        spec: SpecConfig::disabled(),
        tiered: TieredConfig::disabled(),
        policy,
    }
}

fn burst_trace() -> Vec<snapmla::workload::Request> {
    TraceGen::generate(&TraceConfig {
        seed: 77,
        num_requests: 24,
        mean_interarrival_s: 0.0, // burst: no rank ever idles mid-trace,
        // the only regime where lock-step and per-rank clocks can agree
        prompt_min: 16,
        prompt_max: 96,
        out_min: 16,
        out_max: 48,
        temperature: 0.0,
        shared_prefix_frac: 0.5,
        shared_prefix_groups: 4,
        shared_prefix_tokens: 256,
        ..TraceConfig::default()
    })
}

fn harness_arm(timing: SimTiming, routing: SimRoute) -> SimResult {
    Scenario {
        ranks: 3,
        prefill_ranks: 0,
        routing,
        timing,
        sched: bench_sched(SchedPolicy::MixedChunked),
        prefill_sched: None,
        capacity_pages: 256,
        cost: CostModel::Uniform { step_s: 1.0 },
        speeds: Vec::new(),
        elastic: None,
        spec: None,
        naive: false,
    }
    .run(&burst_trace())
    .expect("harness sim")
}

fn assert_recorders_identical(a: &SimResult, b: &SimResult) {
    assert_eq!(a.gen_tokens, b.gen_tokens);
    assert_eq!(a.wall_s.to_bits(), b.wall_s.to_bits(), "wall {} vs {}", a.wall_s, b.wall_s);
    for p in [0.0, 25.0, 50.0, 95.0, 100.0] {
        assert_eq!(a.ttft.percentile(p).to_bits(), b.ttft.percentile(p).to_bits(), "ttft p{p}");
        assert_eq!(a.itl.percentile(p).to_bits(), b.itl.percentile(p).to_bits(), "itl p{p}");
    }
    assert_eq!(a.ttft.len(), b.ttft.len());
    assert_eq!(a.itl.len(), b.itl.len());
    assert_eq!(a.peak_pages, b.peak_pages);
    assert_eq!(a.prefill_tokens, b.prefill_tokens);
    assert_eq!(a.chunk_tokens, b.chunk_tokens);
    assert_eq!(a.prefix_hit_tokens, b.prefix_hit_tokens);
    assert_eq!(a.decode_steps, b.decode_steps);
    assert_eq!(a.decode_batch_sum, b.decode_batch_sum);
    assert_eq!(a.spills, b.spills);
    assert_eq!(a.restores, b.restores);
    assert_eq!(a.routed, b.routed);
}

#[test]
fn harness_event_mode_reproduces_lockstep_under_uniform_costs() {
    for routing in [SimRoute::PrefixAffinity, SimRoute::ShortestQueue] {
        let lock = harness_arm(SimTiming::LockStep, routing);
        let event = harness_arm(SimTiming::EventDriven, routing);
        assert!(lock.gen_tokens > 0 && lock.rounds > 0 && event.steps > 0);
        assert_recorders_identical(&lock, &event);
    }
}

#[test]
fn harness_speed_factors_slow_the_straggler_arm() {
    let scen = |speeds: Vec<f64>| Scenario {
        ranks: 3,
        prefill_ranks: 0,
        routing: SimRoute::ShortestQueue,
        timing: SimTiming::EventDriven,
        sched: bench_sched(SchedPolicy::MixedChunked),
        prefill_sched: None,
        capacity_pages: 256,
        cost: CostModel::Uniform { step_s: 1.0 },
        speeds,
        elastic: None,
        spec: None,
        naive: false,
    };
    let trace = burst_trace();
    let uniform = scen(Vec::new()).run(&trace).expect("uniform sim");
    let strag = scen(vec![2.0, 1.0, 1.0]).run(&trace).expect("straggler sim");
    assert_eq!(uniform.requests, strag.requests);
    assert!(
        strag.wall_s > uniform.wall_s,
        "a 2x-slow rank must stretch the run: {} vs {}",
        strag.wall_s,
        uniform.wall_s
    );
}
