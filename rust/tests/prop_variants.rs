//! Property tests for the `mla::variant` API redesign.
//!
//! 1. The `SnapMla` variant's one-shot `mla::decode` path is BYTE-identical
//!    to the manually staged build/quantize/pipeline composition (what the
//!    retired `mla::pipeline` shims used to chain) — random shapes/seeds,
//!    lengths crossing block boundaries, and both engine cache modes.
//! 2. P-Cast's online running-max rescale keeps sink-token streams bounded
//!    where a naive per-row global-max probability scaling collapses to
//!    zero output.

use snapmla::fp8::e4m3_round;
use snapmla::kvcache::{CacheMode, PagedKvCache};
use snapmla::mla::variant::{
    snapmla_build_cache, snapmla_quantize_query, KernelVariant, QuantCache, BLOCK_N,
};
use snapmla::mla::{decode, ref_attn, Cache, Query, Shape, VariantKind};
use snapmla::runtime::{EngineBuilder, ModelEngine};
use snapmla::util::rng::Rng;
use snapmla::util::stats::rel_l2;

const SHAPES: [(usize, usize, usize); 3] = [(2, 32, 8), (4, 64, 16), (8, 128, 32)];

fn random_case(rng: &mut Rng, shape: &Shape, n: usize) -> (Query, Vec<f32>, Vec<f32>) {
    let q = Query {
        q_c: rng.normal_vec(shape.heads * shape.d_c, 1.0),
        q_r: rng.normal_vec(shape.heads * shape.d_r, 0.3),
    };
    let k_c = rng.normal_vec(n * shape.d_c, 1.5);
    let k_r = rng.normal_vec(n * shape.d_r, 4.0);
    (q, k_c, k_r)
}

fn assert_bits_eq(a: &[f32], b: &[f32], what: &str) {
    assert_eq!(a.len(), b.len(), "{what}: length");
    for (i, (x, y)) in a.iter().zip(b).enumerate() {
        assert_eq!(x.to_bits(), y.to_bits(), "{what}[{i}]: {x} vs {y}");
    }
}

/// One-shot `mla::decode` == the manually staged pad/build/quantize/pipeline
/// composition (what the retired `mla::pipeline::snapmla_decode` shim used
/// to chain), bit for bit, on random shapes/seeds and lengths crossing
/// block boundaries.
#[test]
fn snapmla_one_shot_is_byte_identical_to_staged_composition() {
    for (heads, d_c, d_r) in SHAPES {
        let shape = Shape { heads, d_c, d_r };
        let sm = shape.sm_scale();
        for seed in [1u64, 7, 42] {
            let mut rng = Rng::new(seed ^ (heads as u64) << 8);
            let n = 256;
            let (q, k_c, k_r) = random_case(&mut rng, &shape, n);
            for length in [1usize, 63, 64, 65, 130, 256] {
                // stage by hand exactly as KernelVariant::decode documents:
                // pad to whole KV blocks, build, quantize, pipeline
                let n_pad = length.div_ceil(BLOCK_N) * BLOCK_N;
                let mut k_c_pad = k_c[..length * d_c].to_vec();
                k_c_pad.resize(n_pad * d_c, 0.0);
                let mut k_r_pad = k_r[..length * d_r].to_vec();
                k_r_pad.resize(n_pad * d_r, 0.0);
                let cache = snapmla_build_cache(&shape, &k_c_pad, &k_r_pad, n_pad);
                let qq = snapmla_quantize_query(&shape, &q);
                let staged = VariantKind::SnapMla.instance().pipeline(
                    &shape, &qq.q_c_q, &qq.sigma_q, &qq.q_r_al, &cache, length, sm,
                );
                let one_shot = decode(VariantKind::SnapMla, &shape, &q, &k_c, &k_r, length, sm);
                assert_bits_eq(&one_shot.o, &staged.o, "o");
                assert_bits_eq(&one_shot.lse, &staged.lse, "lse");
            }
        }
    }
}

/// The trait's default `build_cache`/`quantize_query` ARE the shared free
/// functions — every variant builds the same cache layout, so a cache built
/// through one path is byte-valid input to the other.
#[test]
fn trait_staging_defaults_match_the_free_functions() {
    for (heads, d_c, d_r) in SHAPES {
        let shape = Shape { heads, d_c, d_r };
        let mut rng = Rng::new(heads as u64 * 1000 + 17);
        let n = 192; // 3 blocks
        let (q, k_c, k_r) = random_case(&mut rng, &shape, n);

        let free_cache: QuantCache = snapmla_build_cache(&shape, &k_c, &k_r, n);
        let free_q = snapmla_quantize_query(&shape, &q);
        let v = VariantKind::SnapMla.instance();
        let trait_cache = v.build_cache(&shape, &k_c, &k_r, n);
        let trait_q = v.quantize_query(&shape, &q);
        assert_bits_eq(&trait_cache.k_c_q, &free_cache.k_c_q, "k_c_q");
        assert_bits_eq(&trait_cache.sigma_k, &free_cache.sigma_k, "sigma_k");
        assert_bits_eq(&trait_cache.k_r_al, &free_cache.k_r_al, "k_r_al");
        assert_bits_eq(&trait_q.q_c_q, &free_q.q_c_q, "q_c_q");
        assert_bits_eq(&trait_q.sigma_q, &free_q.sigma_q, "sigma_q");
        assert_bits_eq(&trait_q.q_r_al, &free_q.q_r_al, "q_r_al");
    }
}

/// Engine-level identity in BOTH cache modes: the default engine and an
/// explicit `--kernel snapmla` engine produce bitwise-equal logits.
#[test]
fn default_engine_equals_explicit_snapmla_in_both_cache_modes() {
    for mode in [CacheMode::Fp8, CacheMode::Bf16] {
        let mut legacy = ModelEngine::sim(mode).unwrap();
        let mut explicit =
            EngineBuilder::new(mode).kernel(VariantKind::SnapMla).build().unwrap();
        let run = |eng: &mut ModelEngine| {
            let mut cache = PagedKvCache::new(eng.cache_config(8));
            cache.register(1);
            eng.prefill(&mut cache, &[(1, vec![1, 70, 71, 70, 9, 3])]).unwrap();
            let r = eng.decode(&mut cache, &[(1, 71)]).unwrap();
            r.logits[0].clone()
        };
        let a = run(&mut legacy);
        let b = run(&mut explicit);
        assert_bits_eq(&a, &b, &format!("{mode:?} logits"));
    }
}

/// The f32 production pipelines track the f64 study twin (the twin feeds the
/// committed frontier numbers): same stimulus, same variant, small rel-L2.
#[test]
fn f32_pipelines_track_the_f64_study_twin() {
    use snapmla::mla::study;
    let ctx = 4096usize;
    let stim = study::stimulus(ctx);
    let shape = Shape { heads: 1, d_c: study::STUDY_D_C, d_r: study::STUDY_D_R };
    let q = Query {
        q_c: stim.q_c.iter().map(|&x| x as f32).collect(),
        q_r: stim.q_r.iter().map(|&x| x as f32).collect(),
    };
    let k_c: Vec<f32> = stim.k_c.iter().map(|&x| x as f32).collect();
    let k_r: Vec<f32> = stim.k_r.iter().map(|&x| x as f32).collect();
    let sm = shape.sm_scale();
    for kind in VariantKind::ALL {
        let f32_out = decode(kind, &shape, &q, &k_c, &k_r, ctx, sm);
        let f64_out = study::variant_out(kind, &stim);
        let num: f64 = f32_out
            .o
            .iter()
            .zip(&f64_out)
            .map(|(&a, &b)| (a as f64 - b) * (a as f64 - b))
            .sum();
        let den: f64 = f64_out.iter().map(|&b| b * b).sum();
        let rel = (num / den.max(1e-30)).sqrt();
        assert!(rel < 0.01, "{kind:?}: f32 pipeline vs f64 study twin rel {rel}");
    }
}

/// Naive baseline: per-row GLOBAL max probability scaling (amax code = FP8
/// max), values unfused — every token in the row quantized against the one
/// global scale domain.
fn naive_global_max_decode(
    shape: &Shape,
    qq: (&[f32], &[f32], &[f32]),
    cache: &QuantCache,
    length: usize,
    sm: f32,
) -> Vec<f32> {
    let (h, d_c, d_r) = (shape.heads, shape.d_c, shape.d_r);
    let (q_c_q, sigma_q, q_r_al) = qq;
    let mut o = vec![0.0f32; h * d_c];
    for head in 0..h {
        let qc = &q_c_q[head * d_c..(head + 1) * d_c];
        let qr = &q_r_al[head * d_r..(head + 1) * d_r];
        let mut s = vec![0.0f32; length];
        let mut m = f32::NEG_INFINITY;
        for (j, sj) in s.iter_mut().enumerate() {
            let kc = &cache.k_c_q[j * d_c..(j + 1) * d_c];
            let kr = &cache.k_r_al[j * d_r..(j + 1) * d_r];
            let mut acc = 0.0f32;
            for i in 0..d_c {
                acc += qc[i] * kc[i];
            }
            for i in 0..d_r {
                acc += qr[i] * kr[i];
            }
            *sj = acc * sigma_q[head] * cache.sigma_k[j] * sm;
            m = m.max(*sj);
        }
        let mut l = 0.0f32;
        let acc = &mut o[head * d_c..(head + 1) * d_c];
        for (j, &sj) in s.iter().enumerate() {
            let e = (sj - m).exp();
            l += e;
            let p = e4m3_round(e * 448.0);
            if p == 0.0 {
                continue;
            }
            let w = p * cache.sigma_k[j];
            let kc = &cache.k_c_q[j * d_c..(j + 1) * d_c];
            for i in 0..d_c {
                acc[i] += w * kc[i];
            }
        }
        for a in acc.iter_mut() {
            *a /= 448.0 * l.max(1e-37);
        }
    }
    o
}

/// Sink-token stimulus: one zero-value token whose logit overshoots the
/// band by ~17 nats, placed LAST. P-Cast's already-accumulated band blocks
/// are rescaled exactly (f32 multiply) when the running max jumps, so its
/// error stays bounded; the naive global-max baseline quantizes the whole
/// band against the sink's scale domain and flushes it to zero.
#[test]
fn pcast_bounds_sink_stream_where_global_max_scaling_collapses() {
    let shape = Shape { heads: 1, d_c: 64, d_r: 16 };
    let sm = shape.sm_scale();
    let n = 512usize;
    let mut rng = Rng::new(5);
    let (q, mut k_c, mut k_r) = random_case(&mut rng, &shape, n);

    // the last token is the sink: zero content, rope aligned with q_r so its
    // logit lands ~17 nats above the band maximum (band logits are O(3))
    let sink = n - 1;
    for i in 0..shape.d_c {
        k_c[sink * shape.d_c + i] = 0.0;
    }
    let qr_norm2: f32 = q.q_r.iter().map(|x| x * x).sum();
    let amp = 20.0 / (qr_norm2 * sm);
    for i in 0..shape.d_r {
        k_r[sink * shape.d_r + i] = amp * q.q_r[i];
    }

    let cache = Cache { k_c: k_c.clone(), k_r: k_r.clone(), n };
    let want = ref_attn::attention(&shape, &q, &cache, n, sm);

    let pcast = decode(VariantKind::PCast, &shape, &q, &k_c, &k_r, n, sm);
    let pcast_rel = rel_l2(&pcast.o, &want.o);

    let qcache = snapmla_build_cache(&shape, &k_c, &k_r, n);
    let qq = snapmla_quantize_query(&shape, &q);
    let naive = naive_global_max_decode(
        &shape,
        (&qq.q_c_q, &qq.sigma_q, &qq.q_r_al),
        &qcache,
        n,
        sm,
    );
    let naive_rel = rel_l2(&naive, &want.o);

    assert!(
        naive_rel > 0.9,
        "global-max scaling should collapse the band: rel {naive_rel}"
    );
    assert!(pcast_rel < 0.25, "P-Cast should stay bounded: rel {pcast_rel}");
    assert!(
        pcast_rel < naive_rel / 3.0,
        "P-Cast {pcast_rel} vs naive {naive_rel}"
    );
}
