//! Integration: the full serving coordinator over the real engine —
//! continuous batching, admission, EOS/max-token termination, preemption
//! under KV pressure, and DP routing across two ranks.
//!
//! Runs against the offline `SimBackend` by default; with `--features pjrt`
//! and compiled artifacts the same tests drive the PJRT engine.

use snapmla::coordinator::{FinishReason, Router, ServeRequest, Server};
use snapmla::kvcache::CacheMode;
use snapmla::runtime::ModelEngine;
use snapmla::util::rng::Rng;
use snapmla::workload::{TraceConfig, TraceGen};
use std::path::{Path, PathBuf};

fn artifacts_dir() -> PathBuf {
    Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts")
}

fn server(mode: CacheMode, pages: usize) -> Server {
    let engine = ModelEngine::auto(&artifacts_dir(), mode).expect("engine");
    Server::new(engine, pages)
}

fn repeat_prompt(seed: i32, len: usize) -> Vec<i32> {
    let motif = [70 + seed % 50, 90 + seed % 30, 130];
    let mut p = vec![1];
    for i in 0..len - 1 {
        p.push(motif[i as usize % 3]);
    }
    p
}

#[test]
fn serves_batch_to_completion() {
    let mut srv = server(CacheMode::Fp8, 256);
    for i in 0..6 {
        srv.submit(ServeRequest {
            id: i,
            prompt: repeat_prompt(i as i32, 12 + i as usize * 7),
            max_new_tokens: 12,
            temperature: 0.7,
            seed: i,
            ignore_eos: false,
        });
    }
    srv.run_to_completion().unwrap();
    assert_eq!(srv.finished.len(), 6);
    for o in &srv.finished {
        assert!(!o.generated.is_empty());
        assert!(o.generated.len() <= 12);
        assert!(matches!(o.finish, FinishReason::Eos | FinishReason::MaxTokens));
        assert!(o.metrics.e2e_s >= o.metrics.ttft_s);
    }
    // continuous batching actually batched decodes
    assert!(srv.metrics.decode_batch.mean() > 1.5, "{}", srv.metrics.decode_batch.mean());
    // all KV released at the end
    assert_eq!(srv.cache.used_pages(), 0);
}

#[test]
fn preemption_under_kv_pressure_still_completes() {
    // 4 pages total; 3 long-ish requests force page churn + preemption.
    // ignore_eos pins the generation lengths (benchmark mode) so the KV
    // pressure pattern is deterministic.
    let mut srv = server(CacheMode::Fp8, 4);
    for i in 0..3 {
        srv.submit(ServeRequest {
            id: i,
            prompt: repeat_prompt(i as i32, 50),
            max_new_tokens: 30,
            temperature: 0.0,
            seed: i,
            ignore_eos: true,
        });
    }
    srv.run_to_completion().unwrap();
    assert_eq!(srv.finished.len(), 3);
    for o in &srv.finished {
        assert_eq!(o.generated.len(), 30, "id {} finished early: {:?}", o.id, o.finish);
    }
    assert!(
        srv.metrics.total_preemptions > 0,
        "this workload must trigger preemption"
    );
}

#[test]
fn deterministic_outputs_given_seeds() {
    let mut a = server(CacheMode::Fp8, 128);
    let mut b = server(CacheMode::Fp8, 128);
    for srv in [&mut a, &mut b] {
        for i in 0..3 {
            srv.submit(ServeRequest {
                id: i,
                prompt: repeat_prompt(i as i32, 16),
                max_new_tokens: 10,
                temperature: 0.9,
                seed: 1000 + i,
                ignore_eos: false,
            });
        }
        srv.run_to_completion().unwrap();
    }
    for (x, y) in a.finished.iter().zip(&b.finished) {
        assert_eq!(x.id, y.id);
        assert_eq!(x.generated, y.generated, "sampling must be reproducible");
    }
}

#[test]
fn preempted_and_resumed_run_is_byte_identical() {
    // page-spill preemption must preserve the generated-token KV state: a
    // run on a page-starved server (forced preempt/resume churn) emits
    // byte-identical outputs to an uninterrupted run. Prompts exceed the
    // monolithic prefill bucket so both runs take the chunked path, whose
    // per-token math is chunk-schedule-invariant.
    // each sequence: 3 prompt pages + decode growth into a 4th page
    // (prompt + 70 tokens crosses the 192-token boundary); all three admit
    // concurrently into 9 pages, then 3 x 4 = 12 pages of demand forces
    // page-spill preemption
    let reqs: Vec<ServeRequest> = (0..3u64)
        .map(|i| ServeRequest {
            id: i,
            prompt: repeat_prompt(i as i32, 130 + 10 * i as usize),
            max_new_tokens: 70,
            temperature: 0.8,
            seed: 100 + i,
            ignore_eos: true,
        })
        .collect();
    let mut tight = server(CacheMode::Fp8, 9);
    let mut roomy = server(CacheMode::Fp8, 128);
    for r in &reqs {
        tight.submit(r.clone());
        roomy.submit(r.clone());
    }
    tight.run_to_completion().unwrap();
    roomy.run_to_completion().unwrap();
    assert!(tight.metrics.spills > 0, "the tight pool must preempt");
    assert_eq!(tight.metrics.spills, tight.metrics.restores);
    assert_eq!(roomy.metrics.spills, 0, "the roomy pool must not preempt");
    let by_id = |srv: &Server| {
        let mut v: Vec<(u64, Vec<i32>)> =
            srv.finished.iter().map(|o| (o.id, o.generated.clone())).collect();
        v.sort_by_key(|(id, _)| *id);
        v
    };
    assert_eq!(
        by_id(&tight),
        by_id(&roomy),
        "preempt/resume changed the generated tokens"
    );
}

#[test]
fn determinism_same_trace_seed_same_outcomes_and_counters() {
    // two full serving runs over the same tracegen seed must agree on every
    // outcome and every wall-clock-free metrics counter
    let run = || {
        let trace = TraceGen::generate(&TraceConfig {
            seed: 11,
            num_requests: 8,
            mean_interarrival_s: 0.0,
            prompt_min: 16,
            prompt_max: 90,
            out_min: 6,
            out_max: 18,
            temperature: 0.7,
            long_frac: 0.25,
            long_prompt_min: 192,
            long_prompt_max: 400,
            ..TraceConfig::default()
        });
        let mut srv = server(CacheMode::Fp8, 32);
        let mut rng = Rng::new(5);
        for r in &trace {
            let mlen = rng.range_usize(2, 6);
            let motif: Vec<i32> = (0..mlen).map(|_| 64 + rng.below(256) as i32).collect();
            let mut prompt = vec![1];
            for i in 0..r.prompt_tokens.saturating_sub(1) {
                prompt.push(motif[i % mlen]);
            }
            srv.submit(ServeRequest {
                id: r.id,
                prompt,
                max_new_tokens: r.max_new_tokens,
                temperature: r.temperature,
                seed: r.id,
                ignore_eos: false,
            });
        }
        srv.run_to_completion().unwrap();
        let outcomes: Vec<(u64, Vec<i32>, FinishReason)> = srv
            .finished
            .iter()
            .map(|o| (o.id, o.generated.clone(), o.finish))
            .collect();
        (outcomes, srv.metrics.counters())
    };
    let (fin_a, counters_a) = run();
    let (fin_b, counters_b) = run();
    // identical finish ORDER, tokens and reasons — not just identical sets
    assert_eq!(fin_a, fin_b, "finished outcomes diverged across identical runs");
    assert_eq!(counters_a, counters_b, "metrics counters diverged across identical runs");
    // the trace's long-prompt mixture actually exercised chunked prefill
    let chunks = counters_a.iter().find(|(k, _)| *k == "chunk_tokens").unwrap().1;
    assert!(chunks > 0, "expected chunked prefill in this trace");
}

#[test]
fn speculative_decoding_matches_baseline_outputs() {
    // verify logits are bit-exact with stepwise decode logits and each
    // emitted token consumes exactly one rng draw either way, so a
    // spec-enabled run must emit token-identical outputs to the baseline —
    // speculation changes the step count, never the text
    let reqs: Vec<ServeRequest> = (0..4u64)
        .map(|i| ServeRequest {
            id: i,
            prompt: repeat_prompt(i as i32, 20 + 6 * i as usize),
            max_new_tokens: 24,
            temperature: 0.7,
            seed: 40 + i,
            ignore_eos: false,
        })
        .collect();
    let mut base = server(CacheMode::Fp8, 128);
    let mut spec = server(CacheMode::Fp8, 128);
    spec.enable_spec(3).unwrap();
    for r in &reqs {
        base.submit(r.clone());
        spec.submit(r.clone());
    }
    base.run_to_completion().unwrap();
    spec.run_to_completion().unwrap();
    let by_id = |srv: &Server| {
        let mut v: Vec<(u64, Vec<i32>)> =
            srv.finished.iter().map(|o| (o.id, o.generated.clone())).collect();
        v.sort_by_key(|(id, _)| *id);
        v
    };
    assert_eq!(by_id(&base), by_id(&spec), "speculation changed the generated tokens");
    assert_eq!(base.metrics.spec_steps, 0);
    assert!(spec.metrics.spec_steps > 0, "pure-decode steps must upgrade");
    assert!(spec.metrics.spec_accepted > 0, "repeat-motif prompts must accept drafts");
    assert!(spec.metrics.spec_accepted <= spec.metrics.spec_drafted);
    // speculation saves engine rounds: fewer verify+decode calls than the
    // baseline's decode steps
    assert!(spec.engine.stats.verify_calls > 0);
    assert_eq!(spec.cache.used_pages(), 0, "rollback/release must free all pages");
}

#[test]
fn dp_router_spreads_and_completes() {
    let ranks: Vec<Server> = (0..2).map(|_| server(CacheMode::Fp8, 64)).collect();
    let mut router = Router::new(ranks);
    let mut placements = Vec::new();
    for i in 0..8 {
        placements.push(router.submit(ServeRequest {
            id: i,
            prompt: repeat_prompt(i as i32, 20),
            max_new_tokens: 8,
            temperature: 0.5,
            seed: i,
            ignore_eos: false,
        }));
    }
    // shortest-queue must use both ranks
    assert!(placements.iter().any(|&r| r == 0) && placements.iter().any(|&r| r == 1));
    let outcomes = router.run_to_completion().unwrap();
    assert_eq!(outcomes.len(), 8);
    assert_eq!(
        outcomes.iter().map(|o| o.id).collect::<Vec<_>>(),
        (0..8).collect::<Vec<_>>()
    );
}
