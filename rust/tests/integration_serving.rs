//! Integration: the full serving coordinator over the real engine —
//! continuous batching, admission, EOS/max-token termination, preemption
//! under KV pressure, and DP routing across two ranks.
//!
//! Runs against the offline `SimBackend` by default; with `--features pjrt`
//! and compiled artifacts the same tests drive the PJRT engine.

use snapmla::coordinator::{FinishReason, Router, ServeRequest, Server};
use snapmla::kvcache::CacheMode;
use snapmla::runtime::ModelEngine;
use std::path::{Path, PathBuf};

fn artifacts_dir() -> PathBuf {
    Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts")
}

fn server(mode: CacheMode, pages: usize) -> Server {
    let engine = ModelEngine::auto(&artifacts_dir(), mode).expect("engine");
    Server::new(engine, pages)
}

fn repeat_prompt(seed: i32, len: usize) -> Vec<i32> {
    let motif = [70 + seed % 50, 90 + seed % 30, 130];
    let mut p = vec![1];
    for i in 0..len - 1 {
        p.push(motif[i as usize % 3]);
    }
    p
}

#[test]
fn serves_batch_to_completion() {
    let mut srv = server(CacheMode::Fp8, 256);
    for i in 0..6 {
        srv.submit(ServeRequest {
            id: i,
            prompt: repeat_prompt(i as i32, 12 + i as usize * 7),
            max_new_tokens: 12,
            temperature: 0.7,
            seed: i,
            ignore_eos: false,
        });
    }
    srv.run_to_completion().unwrap();
    assert_eq!(srv.finished.len(), 6);
    for o in &srv.finished {
        assert!(!o.generated.is_empty());
        assert!(o.generated.len() <= 12);
        assert!(matches!(o.finish, FinishReason::Eos | FinishReason::MaxTokens));
        assert!(o.metrics.e2e_s >= o.metrics.ttft_s);
    }
    // continuous batching actually batched decodes
    assert!(srv.metrics.decode_batch.mean() > 1.5, "{}", srv.metrics.decode_batch.mean());
    // all KV released at the end
    assert_eq!(srv.cache.used_pages(), 0);
}

#[test]
fn preemption_under_kv_pressure_still_completes() {
    // 4 pages total; 3 long-ish requests force page churn + preemption.
    // ignore_eos pins the generation lengths (benchmark mode) so the KV
    // pressure pattern is deterministic.
    let mut srv = server(CacheMode::Fp8, 4);
    for i in 0..3 {
        srv.submit(ServeRequest {
            id: i,
            prompt: repeat_prompt(i as i32, 50),
            max_new_tokens: 30,
            temperature: 0.0,
            seed: i,
            ignore_eos: true,
        });
    }
    srv.run_to_completion().unwrap();
    assert_eq!(srv.finished.len(), 3);
    for o in &srv.finished {
        assert_eq!(o.generated.len(), 30, "id {} finished early: {:?}", o.id, o.finish);
    }
    assert!(
        srv.metrics.total_preemptions > 0,
        "this workload must trigger preemption"
    );
}

#[test]
fn deterministic_outputs_given_seeds() {
    let mut a = server(CacheMode::Fp8, 128);
    let mut b = server(CacheMode::Fp8, 128);
    for srv in [&mut a, &mut b] {
        for i in 0..3 {
            srv.submit(ServeRequest {
                id: i,
                prompt: repeat_prompt(i as i32, 16),
                max_new_tokens: 10,
                temperature: 0.9,
                seed: 1000 + i,
                ignore_eos: false,
            });
        }
        srv.run_to_completion().unwrap();
    }
    for (x, y) in a.finished.iter().zip(&b.finished) {
        assert_eq!(x.id, y.id);
        assert_eq!(x.generated, y.generated, "sampling must be reproducible");
    }
}

#[test]
fn dp_router_spreads_and_completes() {
    let ranks: Vec<Server> = (0..2).map(|_| server(CacheMode::Fp8, 64)).collect();
    let mut router = Router::new(ranks);
    let mut placements = Vec::new();
    for i in 0..8 {
        placements.push(router.submit(ServeRequest {
            id: i,
            prompt: repeat_prompt(i as i32, 20),
            max_new_tokens: 8,
            temperature: 0.5,
            seed: i,
            ignore_eos: false,
        }));
    }
    // shortest-queue must use both ranks
    assert!(placements.iter().any(|&r| r == 0) && placements.iter().any(|&r| r == 1));
    let outcomes = router.run_to_completion().unwrap();
    assert_eq!(outcomes.len(), 8);
    assert_eq!(
        outcomes.iter().map(|o| o.id).collect::<Vec<_>>(),
        (0..8).collect::<Vec<_>>()
    );
}
