//! Property tests for the FP8 substrate: E4M3/BF16 encode-decode roundtrips
//! and the per-token quantizer's scale invariants, via the `util::prop`
//! harness (shrinking mini-proptest; proptest itself is not in the offline
//! crate set).

use snapmla::fp8::{
    bf16_decode, bf16_encode, bf16_round, e4m3_decode, e4m3_encode, e4m3_round, per_token_scale,
    quant_per_token, E4M3_MAX, SCALE_EPS,
};
use snapmla::util::prop::{check, Gen, Pair, UsizeIn, VecF32};
use snapmla::util::rng::Rng;

/// Generator: one finite f32 of magnitude up to ~1e4 (covers the full E4M3
/// range incl. saturation), shrinking toward 0.
struct F32Gen {
    std: f32,
}

impl Gen for F32Gen {
    type Value = f32;

    fn generate(&self, rng: &mut Rng) -> f32 {
        // mix of scales: bulk normal plus occasional huge/tiny magnitudes
        let base = (rng.normal() as f32) * self.std;
        match rng.below(8) {
            0 => base * 1e3,
            1 => base * 1e-3,
            2 => base * 1e-6,
            _ => base,
        }
    }

    fn shrink(&self, v: &f32) -> Vec<f32> {
        let mut out = Vec::new();
        if *v != 0.0 {
            out.push(0.0);
            out.push(v / 2.0);
            out.push(v.trunc());
        }
        out.dedup();
        out
    }
}

// ---------------------------------------------------------------------------
// E4M3 roundtrip
// ---------------------------------------------------------------------------

#[test]
fn e4m3_roundtrip_is_idempotent_and_bounded() {
    check(11, 500, &F32Gen { std: 50.0 }, |&x| {
        let r = e4m3_round(x);
        if !r.is_finite() {
            return Err(format!("non-finite round of {x}"));
        }
        // idempotence: grid points are fixed points
        if e4m3_round(r) != r {
            return Err(format!("not idempotent: {x} -> {r} -> {}", e4m3_round(r)));
        }
        // sign symmetry
        if e4m3_round(-x) != -r {
            return Err(format!("sign asymmetry at {x}"));
        }
        // error bound: relative 2^-4 for in-range normals, absolute half-step
        // for subnormals, saturation at the max
        let a = x.abs();
        let ok = if a >= E4M3_MAX {
            r.abs() == E4M3_MAX
        } else if a >= 2.0f32.powi(-6) {
            (r - x).abs() <= a * 0.0625 + 1e-9
        } else {
            (r - x).abs() <= 2.0f32.powi(-10) + 1e-12
        };
        if !ok {
            return Err(format!("error bound violated: {x} -> {r}"));
        }
        Ok(())
    });
}

#[test]
fn e4m3_round_is_monotone() {
    let gen = Pair(F32Gen { std: 30.0 }, F32Gen { std: 30.0 });
    check(12, 500, &gen, |&(a, b)| {
        let (lo, hi) = if a <= b { (a, b) } else { (b, a) };
        if e4m3_round(lo) <= e4m3_round(hi) {
            Ok(())
        } else {
            Err(format!("monotonicity violated on ({lo}, {hi})"))
        }
    });
}

#[test]
fn e4m3_all_codes_roundtrip_exactly() {
    // exhaustive: every finite code decodes to a fixed point of the codec
    for b in 0u16..256 {
        let v = e4m3_decode(b as u8);
        if v.is_nan() {
            continue;
        }
        let re = e4m3_decode(e4m3_encode(v));
        assert_eq!(re, v, "byte {b:#04x}");
    }
}

// ---------------------------------------------------------------------------
// BF16 roundtrip
// ---------------------------------------------------------------------------

#[test]
fn bf16_roundtrip_is_idempotent_and_bounded() {
    check(13, 500, &F32Gen { std: 100.0 }, |&x| {
        let r = bf16_round(x);
        if bf16_round(r) != r {
            return Err(format!("not idempotent at {x}"));
        }
        if x != 0.0 && ((r - x) / x).abs() > 2.0f32.powi(-8) + 1e-9 {
            return Err(format!("bf16 relative error too large: {x} -> {r}"));
        }
        // encode/decode agree with round
        if bf16_decode(bf16_encode(x)) != r {
            return Err(format!("encode/decode disagree with round at {x}"));
        }
        Ok(())
    });
}

// ---------------------------------------------------------------------------
// Per-token quantizer scale invariants
// ---------------------------------------------------------------------------

#[test]
fn per_token_scale_is_amax_over_max_with_floor() {
    let gen = VecF32 { min_len: 1, max_len: 256, std: 20.0 };
    check(14, 300, &gen, |xs| {
        let s = per_token_scale(xs);
        let amax = xs.iter().fold(0.0f32, |m, &x| m.max(x.abs()));
        let want = (amax / E4M3_MAX).max(SCALE_EPS);
        if s != want {
            return Err(format!("scale {s} != {want} (amax {amax})"));
        }
        if s < SCALE_EPS {
            return Err(format!("scale below floor: {s}"));
        }
        Ok(())
    });
}

#[test]
fn per_token_quant_error_within_grid_bound() {
    let gen = VecF32 { min_len: 1, max_len: 256, std: 20.0 };
    check(15, 300, &gen, |xs| {
        let q = quant_per_token(xs);
        let d = q.dequant();
        for (i, (&x, &y)) in xs.iter().zip(&d).enumerate() {
            let tol = (x.abs() * 0.0625).max(q.scale * 2.0f32.powi(-9) * 0.5 + 1e-12);
            if (x - y).abs() > tol + 1e-9 {
                return Err(format!("elem {i}: {x} -> {y}, tol {tol}"));
            }
        }
        Ok(())
    });
}

#[test]
fn per_token_codes_invariant_under_pow2_rescale() {
    // scaling a token by a power of two scales sigma exactly and leaves the
    // E4M3 codes untouched (x / sigma is unchanged bit-for-bit)
    let gen = Pair(VecF32 { min_len: 1, max_len: 128, std: 5.0 }, UsizeIn(0, 6));
    check(16, 300, &gen, |(xs, k)| {
        let amax = xs.iter().fold(0.0f32, |m, &x| m.max(x.abs()));
        if amax < 1e-4 {
            return Ok(()); // near the eps floor the sigma law changes by design
        }
        let c = 2.0f32.powi(*k as i32);
        let scaled: Vec<f32> = xs.iter().map(|&x| x * c).collect();
        if scaled.iter().any(|x| !x.is_finite()) {
            return Ok(());
        }
        let q1 = quant_per_token(xs);
        let q2 = quant_per_token(&scaled);
        if (q2.scale - q1.scale * c).abs() > q1.scale * c * 1e-6 {
            return Err(format!("sigma not scaled: {} vs {}", q2.scale, q1.scale * c));
        }
        if q1.codes != q2.codes {
            return Err("codes changed under power-of-two rescale".to_string());
        }
        Ok(())
    });
}

#[test]
fn per_token_double_roundtrip_is_stable() {
    // re-quantizing dequantized values must reproduce them (the cache can be
    // rebuilt from its own dequantized view without drift)
    let gen = VecF32 { min_len: 1, max_len: 128, std: 10.0 };
    check(17, 300, &gen, |xs| {
        let d1 = quant_per_token(xs).dequant();
        let d2 = quant_per_token(&d1).dequant();
        for (i, (&a, &b)) in d1.iter().zip(&d2).enumerate() {
            let tol = a.abs() * 1e-6 + 1e-12;
            if (a - b).abs() > tol {
                return Err(format!("elem {i}: {a} vs {b}"));
            }
        }
        Ok(())
    });
}
